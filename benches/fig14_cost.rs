//! Figure 14 (+ the §7.1 headline checks): performance-per-dollar vs
//! parallel efficiency, normalized to TL-OoO.

mod common;

use twinload::coordinator::experiments as exp;
use twinload::cost;

fn main() {
    common::emit("fig14", exp::fig14);
    println!(
        "cluster/TL crossover at parallel efficiency {:.1}% (paper: ~60%)",
        cost::cluster_crossover() * 100.0
    );
    let s = cost::table5_systems();
    println!(
        "TL vs NUMA perf/$ advantage at c2=1: {:+.1}% (paper: >=7%)",
        (s[1].perf_per_dollar(1.0) / s[2].perf_per_dollar(1.0) - 1.0) * 100.0
    );
}
