//! Hot-path microbenchmarks (the §Perf baseline/after numbers in
//! EXPERIMENTS.md): DRAM controller service rate, end-to-end simulator
//! throughput, cache ops, and PJRT fast-path classification rate.

mod common;

use std::time::Instant;
use twinload::cache::{CacheConfig, DataKind, SetAssocCache};
use twinload::config::{RunSpec, SystemConfig};
use twinload::coordinator::fastpath;
use twinload::dram::address::DecodedAddr;
use twinload::dram::timing::{Geometry, TimingParams};
use twinload::dram::{MemController, Transaction};
use twinload::sim::run_spec;
use twinload::twinload::Mechanism;
use twinload::util::Rng;
use twinload::workloads::WorkloadKind;

fn timeit(name: &str, units: f64, unit_name: &str, f: impl FnOnce()) {
    let t0 = Instant::now();
    f();
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "{name:<34} {:>9.3} s   {:>12.0} {unit_name}/s",
        dt,
        units / dt
    );
}

fn bench_controller(n: u64) {
    let geo = Geometry::sim_small();
    let mut ctrl = MemController::new(TimingParams::ddr3_1600(), geo);
    let mut rng = Rng::new(1);
    let mut now = 0u64;
    let mut done = 0u64;
    while done < n {
        // Keep ~32 in flight.
        for _ in 0..32 {
            let addr = DecodedAddr {
                channel: 0,
                rank: (rng.below(2)) as u32,
                bank: (rng.below(8)) as u32,
                row: (rng.below(1024)) as u32,
                col: (rng.below(128)) as u32,
            };
            ctrl.enqueue(Transaction { id: done, addr, is_write: false, arrive: now });
        }
        loop {
            let (res, wake) = ctrl.pump(now);
            done += res.len() as u64;
            match wake {
                Some(w) => now = w,
                None => break,
            }
        }
    }
}

fn bench_cache(n: u64) {
    let mut c = SetAssocCache::new(CacheConfig::llc_scaled());
    let mut rng = Rng::new(2);
    for _ in 0..n {
        let a = rng.below(1 << 24) * 64;
        if c.probe(a).is_none() {
            c.fill(a, false, DataKind::Real);
        }
        c.access(a, false);
    }
}

fn bench_sim(kind: WorkloadKind, cfg: &SystemConfig, ops: u64) -> u64 {
    let spec = RunSpec { workload: kind, footprint: 32 << 20, ops_per_core: ops, seed: 5 };
    let r = run_spec(cfg, &spec);
    assert!(!r.deadlocked);
    r.retired_insts
}

fn main() {
    println!("== hot-path microbenchmarks ==");
    let n_ctrl = 2_000_000u64;
    timeit("dram controller (random txns)", n_ctrl as f64, "txn", || {
        bench_controller(n_ctrl)
    });

    let n_cache = 20_000_000u64;
    timeit("LLC access+fill (random)", n_cache as f64, "op", || bench_cache(n_cache));

    let ops = 200_000u64;
    for (name, cfg) in [
        ("sim ideal/gups", SystemConfig::ideal()),
        ("sim tl-ooo/gups", SystemConfig::tl_ooo()),
        ("sim tl-ooo/memcached", SystemConfig::tl_ooo()),
    ] {
        let wl = if name.contains("memcached") {
            WorkloadKind::Memcached
        } else {
            WorkloadKind::Gups
        };
        let mut cfg = cfg;
        cfg.cores = 4;
        let total_ops = ops * cfg.cores as u64;
        timeit(name, total_ops as f64, "logical-op", || {
            bench_sim(wl, &cfg, ops);
        });
    }

    // PJRT fast-path classification throughput.
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if let Ok(fp) = fastpath::FastPath::new(dir) {
        let cfg = SystemConfig::tl_ooo();
        let (b, r) =
            fastpath::synthesize_trace(&cfg, WorkloadKind::Gups, Mechanism::TlOoO, 8, 9);
        let n = b.len() as f64;
        timeit("pjrt trace classification", n, "access", || {
            fp.classify(&b, &r).expect("classify");
        });
    } else {
        println!("(pjrt fast path unavailable — run `make artifacts`)");
    }
}
