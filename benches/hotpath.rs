//! Hot-path microbenchmarks (the §Perf baseline/after numbers in
//! EXPERIMENTS.md): DRAM controller service rate, event-engine push/pop
//! rate, end-to-end simulator throughput, cache ops, and PJRT fast-path
//! classification rate.
//!
//! Every optimized engine/policy is benched next to its retained
//! reference implementation (`… [calendar]` / `… [adaptive]` vs
//! `… [ref-heap]`, `… [bank-indexed]` / `… [rank-inval]` vs
//! `… [ref-scan]`, `… [frontend]` vs `… [frontend-ref]`), so the
//! before/after ratio is read directly off one run and the CI perf gate
//! can enforce it.
//!
//! Emits a human table on stdout and a machine-readable
//! `BENCH_hotpath.json` at the repo root so the perf trajectory can be
//! tracked across PRs (compared against `BENCH_baseline.json` by
//! `perf_gate`). `TWINLOAD_BENCH_QUICK=1` (or `--quick`) shrinks every
//! run for CI smoke coverage and repeats each bench 3× (the JSON then
//! carries the median, which is what the gate thresholds).

mod common;

use std::time::Instant;
use twinload::cache::{CacheConfig, DataKind, SetAssocCache};
use twinload::config::{RunSpec, SystemConfig};
use twinload::coordinator::fastpath;
use twinload::dram::address::DecodedAddr;
use twinload::dram::timing::{Geometry, TimingParams};
use twinload::dram::{MemController, SchedPolicy, ServiceResult, Transaction};
use twinload::cpu::FrontEnd;
use twinload::sim::engine::{EngineKind, Ev, EventQueue};
use twinload::sim::run_spec;
use twinload::twinload::Mechanism;
use twinload::util::Rng;
use twinload::workloads::WorkloadKind;

/// One timed row: name, median wall seconds across trials, work units,
/// unit label.
struct Row {
    name: String,
    seconds: f64,
    units: f64,
    unit: String,
    trials: u32,
}

impl Row {
    fn rate(&self) -> f64 {
        self.units / self.seconds
    }
}

/// Time `f` `trials` times and record the median wall time (upper median
/// for even counts — the conservative side).
fn timeit(
    rows: &mut Vec<Row>,
    name: &str,
    units: f64,
    unit_name: &str,
    trials: u32,
    mut f: impl FnMut(),
) {
    let mut secs: Vec<f64> = Vec::with_capacity(trials as usize);
    for _ in 0..trials.max(1) {
        let t0 = Instant::now();
        f();
        secs.push(t0.elapsed().as_secs_f64());
    }
    secs.sort_by(|a, b| a.total_cmp(b));
    let dt = secs[secs.len() / 2];
    println!(
        "{name:<40} {:>9.3} s   {:>12.0} {unit_name}/s",
        dt,
        units / dt
    );
    rows.push(Row {
        name: name.to_string(),
        seconds: dt,
        units,
        unit: unit_name.to_string(),
        trials: trials.max(1),
    });
}

fn json_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

/// Hand-rolled JSON (the crate carries no serde): one object per row.
/// Parsed back by `twinload::stats::bench::BenchReport`.
fn write_json(path: &str, rows: &[Row]) {
    let mut body = String::from("{\n  \"bench\": \"hotpath\",\n  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        body.push_str(&format!(
            "    {{\"name\": \"{}\", \"seconds\": {:.6}, \"units\": {}, \
             \"unit\": \"{}\", \"units_per_s\": {:.1}, \"trials\": {}}}{}\n",
            json_escape(&r.name),
            r.seconds,
            r.units,
            json_escape(&r.unit),
            r.rate(),
            r.trials,
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    body.push_str("  ]\n}\n");
    match std::fs::write(path, body) {
        Ok(()) => println!("[bench] wrote {path}"),
        Err(e) => eprintln!("[bench] could not write {path}: {e}"),
    }
}

fn bench_controller(n: u64, policy: SchedPolicy) {
    let geo = Geometry::sim_small();
    let mut ctrl = MemController::with_policy(TimingParams::ddr3_1600(), geo, policy);
    let mut rng = Rng::new(1);
    let mut now = 0u64;
    let mut done = 0u64;
    let mut id = 0u64;
    let mut out: Vec<ServiceResult> = Vec::with_capacity(64);
    while done < n {
        // Keep ~32 in flight.
        for _ in 0..32 {
            let addr = DecodedAddr {
                channel: 0,
                rank: (rng.below(2)) as u32,
                bank: (rng.below(8)) as u32,
                row: (rng.below(1024)) as u32,
                col: (rng.below(128)) as u32,
            };
            ctrl.enqueue(Transaction { id, addr, is_write: false, arrive: now });
            id += 1;
        }
        loop {
            out.clear();
            let wake = ctrl.pump(now, &mut out);
            done += out.len() as u64;
            match wake {
                Some(w) => now = w,
                None => break,
            }
        }
    }
}

/// Event-engine push/pop throughput on a simulator-shaped stream: 256
/// events in flight (a production-scale platform's wakes + pumps +
/// in-flight deliveries), clustered arrivals over a ~40 ns horizon,
/// occasional refresh-scale far-future events.
fn bench_engine(n: u64, kind: EngineKind) {
    const IN_FLIGHT: usize = 256;
    let mut q = EventQueue::with_kind(kind, 1_250);
    let mut rng = Rng::new(3);
    for i in 0..IN_FLIGHT {
        q.push(rng.below(40_000), Ev::CoreWake { core: i });
    }
    let mut done = 0u64;
    while done < n {
        let e = q.pop().expect("queue kept primed");
        let t = if rng.chance(0.01) {
            e.t + 7_800_000
        } else {
            e.t + rng.below(40_000)
        };
        q.push(t, e.ev);
        done += 1;
    }
    assert_eq!(q.len(), IN_FLIGHT);
}

fn bench_cache(n: u64) {
    let mut c = SetAssocCache::new(CacheConfig::llc_scaled());
    let mut rng = Rng::new(2);
    for _ in 0..n {
        let a = rng.below(1 << 24) * 64;
        if c.probe(a).is_none() {
            c.fill(a, false, DataKind::Real);
        }
        c.access(a, false);
    }
}

fn bench_sim(kind: WorkloadKind, cfg: &SystemConfig, ops: u64) -> u64 {
    let spec = RunSpec {
        workload: kind,
        footprint: 32 << 20,
        ops_per_core: ops,
        seed: 5,
        ..RunSpec::smoke(kind)
    };
    let r = run_spec(cfg, &spec);
    assert!(!r.deadlocked);
    r.retired_insts
}

fn main() {
    let quick = common::quick();
    let scale = if quick { 20 } else { 1 };
    // Quick (CI) runs repeat each bench and keep the median so the perf
    // gate compares medians, not single noisy samples.
    let trials = if quick { 3 } else { 1 };
    println!("== hot-path microbenchmarks =={}", if quick { " (quick)" } else { "" });
    let mut rows: Vec<Row> = Vec::new();

    let n_ctrl = 2_000_000u64 / scale;
    for (tag, policy) in [
        ("bank-indexed", SchedPolicy::BankIndexed),
        ("rank-inval", SchedPolicy::RankInval),
        ("ref-scan", SchedPolicy::ReferenceScan),
    ] {
        let name = format!("dram controller [{tag}]");
        timeit(&mut rows, &name, n_ctrl as f64, "txn", trials, || {
            bench_controller(n_ctrl, policy)
        });
    }

    let n_evq = 10_000_000u64 / scale;
    for (tag, kind) in [
        ("calendar", EngineKind::Calendar),
        ("adaptive", EngineKind::AdaptiveCalendar),
        ("ref-heap", EngineKind::ReferenceHeap),
    ] {
        let name = format!("event engine [{tag}]");
        timeit(&mut rows, &name, n_evq as f64, "event", trials, || {
            bench_engine(n_evq, kind)
        });
    }

    let n_cache = 20_000_000u64 / scale;
    timeit(&mut rows, "LLC access+fill (random)", n_cache as f64, "op", trials, || {
        bench_cache(n_cache)
    });

    // End-to-end simulator throughput, all four event engines per
    // workload so the pair rule reads the win off the same run (the
    // sharded engine's gain only materializes on multi-core hosts with
    // enough queued work; on a single-CPU runner it pumps serially and
    // the pair rule's tolerance absorbs the dispatch overhead).
    let ops = 200_000u64 / scale;
    for (engine_tag, engine) in [
        (" [calendar]", EngineKind::Calendar),
        (" [adaptive]", EngineKind::AdaptiveCalendar),
        (" [ref-heap]", EngineKind::ReferenceHeap),
        (" [sharded]", EngineKind::Sharded),
    ] {
        for (name, wl, cfg) in [
            ("sim ideal/gups", WorkloadKind::Gups, SystemConfig::ideal()),
            ("sim tl-ooo/gups", WorkloadKind::Gups, SystemConfig::tl_ooo()),
            ("sim tl-ooo/memcached", WorkloadKind::Memcached, SystemConfig::tl_ooo()),
            ("sim amu/gups", WorkloadKind::Gups, SystemConfig::amu()),
        ] {
            let mut cfg = cfg;
            cfg.cores = 4;
            cfg.engine = engine;
            let total_ops = ops * cfg.cores as u64;
            let row_name = format!("{name}{engine_tag}");
            timeit(&mut rows, &row_name, total_ops as f64, "logical-op", trials, || {
                bench_sim(wl, &cfg, ops);
            });
        }
    }

    // SMARTS-sampled rows: the same end-to-end sims with a 6.4%
    // detailed fraction (128 of every 2000 ops). The speedup over the
    // matching [calendar] rows is the sampling win the §Perf table
    // reports; correctness of the estimate is covered by the physics
    // integration test, not the bench.
    for (name, wl, cfg) in [
        ("sim ideal/gups [sampled]", WorkloadKind::Gups, SystemConfig::ideal()),
        ("sim tl-ooo/gups [sampled]", WorkloadKind::Gups, SystemConfig::tl_ooo()),
        ("sim tl-ooo/memcached [sampled]", WorkloadKind::Memcached, SystemConfig::tl_ooo()),
        ("sim amu/gups [sampled]", WorkloadKind::Gups, SystemConfig::amu()),
    ] {
        let mut cfg = cfg;
        cfg.cores = 4;
        let total_ops = ops * cfg.cores as u64;
        timeit(&mut rows, name, total_ops as f64, "logical-op", trials, || {
            let spec = RunSpec {
                workload: wl,
                footprint: 32 << 20,
                ops_per_core: ops,
                seed: 5,
                ..RunSpec::smoke(wl)
            }
            .sampled(2_000, 64, 64);
            let r = run_spec(&cfg, &spec);
            assert!(!r.deadlocked);
        });
    }

    // Front-end pair: the slab issue/complete path vs the retained
    // map-based reference, end to end on the same workloads (default
    // engine/sched so the row isolates the front-end change).
    for (fe_tag, fe) in [
        (" [frontend]", FrontEnd::Slab),
        (" [frontend-ref]", FrontEnd::Reference),
    ] {
        for (name, wl, cfg) in [
            ("sim ideal/gups", WorkloadKind::Gups, SystemConfig::ideal()),
            ("sim tl-ooo/gups", WorkloadKind::Gups, SystemConfig::tl_ooo()),
            ("sim tl-ooo/memcached", WorkloadKind::Memcached, SystemConfig::tl_ooo()),
            ("sim amu/gups", WorkloadKind::Gups, SystemConfig::amu()),
        ] {
            let mut cfg = cfg;
            cfg.cores = 4;
            cfg.frontend = fe;
            let total_ops = ops * cfg.cores as u64;
            let row_name = format!("{name}{fe_tag}");
            timeit(&mut rows, &row_name, total_ops as f64, "logical-op", trials, || {
                bench_sim(wl, &cfg, ops);
            });
        }
    }

    // PJRT fast-path classification throughput.
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    match fastpath::FastPath::new(dir) {
        Ok(fp) => {
            let cfg = SystemConfig::tl_ooo();
            let (b, r) =
                fastpath::synthesize_trace(&cfg, WorkloadKind::Gups, Mechanism::TlOoO, 8, 9);
            let n = b.len() as f64;
            timeit(&mut rows, "pjrt trace classification", n, "access", trials, || {
                fp.classify(&b, &r).expect("classify");
            });
        }
        Err(e) => println!("(pjrt fast path unavailable: {e})"),
    }

    write_json(concat!(env!("CARGO_MANIFEST_DIR"), "/BENCH_hotpath.json"), &rows);
}
