//! Hot-path microbenchmarks (the §Perf baseline/after numbers in
//! EXPERIMENTS.md): DRAM controller service rate, end-to-end simulator
//! throughput, cache ops, and PJRT fast-path classification rate.
//!
//! Emits a human table on stdout and a machine-readable
//! `BENCH_hotpath.json` at the repo root so the perf trajectory can be
//! tracked across PRs. `TWINLOAD_BENCH_QUICK=1` (or `--quick`) shrinks
//! every run for CI smoke coverage.

mod common;

use std::time::Instant;
use twinload::cache::{CacheConfig, DataKind, SetAssocCache};
use twinload::config::{RunSpec, SystemConfig};
use twinload::coordinator::fastpath;
use twinload::dram::address::DecodedAddr;
use twinload::dram::timing::{Geometry, TimingParams};
use twinload::dram::{MemController, SchedPolicy, ServiceResult, Transaction};
use twinload::sim::run_spec;
use twinload::twinload::Mechanism;
use twinload::util::Rng;
use twinload::workloads::WorkloadKind;

/// One timed row: name, wall seconds, work units, unit label.
struct Row {
    name: String,
    seconds: f64,
    units: f64,
    unit: String,
}

impl Row {
    fn rate(&self) -> f64 {
        self.units / self.seconds
    }
}

fn timeit(rows: &mut Vec<Row>, name: &str, units: f64, unit_name: &str, f: impl FnOnce()) {
    let t0 = Instant::now();
    f();
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "{name:<34} {:>9.3} s   {:>12.0} {unit_name}/s",
        dt,
        units / dt
    );
    rows.push(Row {
        name: name.to_string(),
        seconds: dt,
        units,
        unit: unit_name.to_string(),
    });
}

fn json_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

/// Hand-rolled JSON (the crate carries no serde): one object per row.
fn write_json(path: &str, rows: &[Row]) {
    let mut body = String::from("{\n  \"bench\": \"hotpath\",\n  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        body.push_str(&format!(
            "    {{\"name\": \"{}\", \"seconds\": {:.6}, \"units\": {}, \
             \"unit\": \"{}\", \"units_per_s\": {:.1}}}{}\n",
            json_escape(&r.name),
            r.seconds,
            r.units,
            json_escape(&r.unit),
            r.rate(),
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    body.push_str("  ]\n}\n");
    match std::fs::write(path, body) {
        Ok(()) => println!("[bench] wrote {path}"),
        Err(e) => eprintln!("[bench] could not write {path}: {e}"),
    }
}

fn bench_controller(n: u64, policy: SchedPolicy) {
    let geo = Geometry::sim_small();
    let mut ctrl = MemController::with_policy(TimingParams::ddr3_1600(), geo, policy);
    let mut rng = Rng::new(1);
    let mut now = 0u64;
    let mut done = 0u64;
    let mut id = 0u64;
    let mut out: Vec<ServiceResult> = Vec::with_capacity(64);
    while done < n {
        // Keep ~32 in flight.
        for _ in 0..32 {
            let addr = DecodedAddr {
                channel: 0,
                rank: (rng.below(2)) as u32,
                bank: (rng.below(8)) as u32,
                row: (rng.below(1024)) as u32,
                col: (rng.below(128)) as u32,
            };
            ctrl.enqueue(Transaction { id, addr, is_write: false, arrive: now });
            id += 1;
        }
        loop {
            out.clear();
            let wake = ctrl.pump(now, &mut out);
            done += out.len() as u64;
            match wake {
                Some(w) => now = w,
                None => break,
            }
        }
    }
}

fn bench_cache(n: u64) {
    let mut c = SetAssocCache::new(CacheConfig::llc_scaled());
    let mut rng = Rng::new(2);
    for _ in 0..n {
        let a = rng.below(1 << 24) * 64;
        if c.probe(a).is_none() {
            c.fill(a, false, DataKind::Real);
        }
        c.access(a, false);
    }
}

fn bench_sim(kind: WorkloadKind, cfg: &SystemConfig, ops: u64) -> u64 {
    let spec = RunSpec { workload: kind, footprint: 32 << 20, ops_per_core: ops, seed: 5 };
    let r = run_spec(cfg, &spec);
    assert!(!r.deadlocked);
    r.retired_insts
}

fn main() {
    let quick = common::quick();
    let scale = if quick { 20 } else { 1 };
    println!("== hot-path microbenchmarks =={}", if quick { " (quick)" } else { "" });
    let mut rows: Vec<Row> = Vec::new();

    let n_ctrl = 2_000_000u64 / scale;
    timeit(&mut rows, "dram controller (random txns)", n_ctrl as f64, "txn", || {
        bench_controller(n_ctrl, SchedPolicy::BankIndexed)
    });
    timeit(&mut rows, "dram controller (reference scan)", n_ctrl as f64, "txn", || {
        bench_controller(n_ctrl, SchedPolicy::ReferenceScan)
    });

    let n_cache = 20_000_000u64 / scale;
    timeit(&mut rows, "LLC access+fill (random)", n_cache as f64, "op", || {
        bench_cache(n_cache)
    });

    let ops = 200_000u64 / scale;
    for (name, cfg) in [
        ("sim ideal/gups", SystemConfig::ideal()),
        ("sim tl-ooo/gups", SystemConfig::tl_ooo()),
        ("sim tl-ooo/memcached", SystemConfig::tl_ooo()),
    ] {
        let wl = if name.contains("memcached") {
            WorkloadKind::Memcached
        } else {
            WorkloadKind::Gups
        };
        let mut cfg = cfg;
        cfg.cores = 4;
        let total_ops = ops * cfg.cores as u64;
        timeit(&mut rows, name, total_ops as f64, "logical-op", || {
            bench_sim(wl, &cfg, ops);
        });
    }

    // PJRT fast-path classification throughput.
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    match fastpath::FastPath::new(dir) {
        Ok(fp) => {
            let cfg = SystemConfig::tl_ooo();
            let (b, r) =
                fastpath::synthesize_trace(&cfg, WorkloadKind::Gups, Mechanism::TlOoO, 8, 9);
            let n = b.len() as f64;
            timeit(&mut rows, "pjrt trace classification", n, "access", || {
                fp.classify(&b, &r).expect("classify");
            });
        }
        Err(e) => println!("(pjrt fast path unavailable: {e})"),
    }

    write_json(concat!(env!("CARGO_MANIFEST_DIR"), "/BENCH_hotpath.json"), &rows);
}
