//! Figure 13: PCIe page-swapping performance as the share of data in
//! extended memory sweeps 0–90 % (five representative workloads).

mod common;

use twinload::coordinator::experiments as exp;

fn main() {
    let scale = common::scale();
    common::emit("fig13", || exp::fig13(&scale));
}
