//! Figure 15: twin-load vs simply increasing tRL, sweeping the extra
//! latency to tolerate (cycle-accurate sweep + the PJRT analytic fast
//! path cross-check).

mod common;

use twinload::config::SystemConfig;
use twinload::coordinator::{experiments as exp, fastpath};
use twinload::twinload::Mechanism;
use twinload::workloads::WorkloadKind;

fn main() {
    let scale = common::scale();
    common::emit("fig15", || exp::fig15(&scale));

    // Analytic (PJRT / Pallas) estimate of the same crossover.
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    match fastpath::FastPath::new(dir) {
        Err(e) => println!("(fast path unavailable: {e})"),
        Ok(fp) => {
            let cfg = SystemConfig::tl_ooo();
            let (tb, tr) =
                fastpath::synthesize_trace(&cfg, WorkloadKind::Gups, Mechanism::TlOoO, 2, 42);
            let (sb, sr) =
                fastpath::synthesize_trace(&cfg, WorkloadKind::Gups, Mechanism::Ideal, 2, 42);
            let twin = fp.classify(&tb, &tr).expect("classify");
            let single = fp.classify(&sb, &sr).expect("classify");
            println!("PJRT analytic serial-latency estimate (GUPS trace):");
            println!("  extra(ns)  twin(us)  inc-tRL(us)  winner");
            for d in [0i64, 35, 70, 105, 135] {
                let (t, s) = fp.twin_vs_inc_trl(&twin, &single, d);
                println!(
                    "  {:>8}  {:>8.1}  {:>11.1}  {}",
                    d,
                    t as f64 / 1000.0,
                    s as f64 / 1000.0,
                    if s < t { "inc-tRL" } else { "twin-load" }
                );
            }
        }
    }
}
