//! Shared bench plumbing: scale selection + timed table emission.

use std::time::Instant;
use twinload::coordinator::experiments::Scale;
use twinload::stats::Table;

/// `TWINLOAD_BENCH_QUICK=1` (or --quick in argv) shrinks every sweep.
pub fn scale() -> Scale {
    let quick = std::env::var_os("TWINLOAD_BENCH_QUICK").is_some()
        || std::env::args().any(|a| a == "--quick");
    if quick {
        Scale::quick()
    } else {
        Scale::full()
    }
}

/// Run one experiment closure, print its table + wall time, optionally
/// save CSV under results/.
pub fn emit(name: &str, f: impl FnOnce() -> Table) {
    let t0 = Instant::now();
    let table = f();
    let dt = t0.elapsed();
    println!("{}", table.render());
    println!("[bench] {name}: {:.2} s\n", dt.as_secs_f64());
    let _ = table.save_csv(&format!("results/{name}.csv"));
}
