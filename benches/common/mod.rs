//! Shared bench plumbing: quick-mode detection, scale selection, timed
//! table emission.

// Each bench target includes this module but uses its own subset.
#![allow(dead_code)]

use std::time::Instant;
use twinload::coordinator::experiments::Scale;
use twinload::stats::Table;

/// `TWINLOAD_BENCH_QUICK=1` (or `--quick` in argv) shrinks every sweep;
/// unset, empty, or `0` means a full run.
pub fn quick() -> bool {
    std::env::var("TWINLOAD_BENCH_QUICK").is_ok_and(|v| !v.is_empty() && v != "0")
        || std::env::args().any(|a| a == "--quick")
}

pub fn scale() -> Scale {
    if quick() {
        Scale::quick()
    } else {
        Scale::full()
    }
}

/// Run one experiment closure, print its table + wall time, optionally
/// save CSV under results/.
pub fn emit(name: &str, f: impl FnOnce() -> Table) {
    let t0 = Instant::now();
    let table = f();
    let dt = t0.elapsed();
    println!("{}", table.render());
    println!("[bench] {name}: {:.2} s\n", dt.as_secs_f64());
    let _ = table.save_csv(&format!("results/{name}.csv"));
}
