//! Design-choice ablations (DESIGN.md §6): LVC sizing, MEC tree depth,
//! batched TL-LF, and the emulation-fidelity comparison.

mod common;

use twinload::config::{RunSpec, SystemConfig};
use twinload::coordinator::experiments as exp;
use twinload::sim::run_spec;
use twinload::stats::Table;
use twinload::workloads::WorkloadKind;

fn main() {
    let scale = common::scale();
    common::emit("ablate_lvc", || exp::ablate_lvc(&scale));
    common::emit("ablate_layers", || exp::ablate_layers(&scale));
    common::emit("ablate_batch", || exp::ablate_batch(&scale));
    common::emit("ablate_scm", || exp::ablate_scm(&scale).expect("ablate_scm presets"));
    common::emit("ablate_smt", || exp::ablate_smt(&scale));
    common::emit("ablate_faults", || exp::ablate_faults(&scale).expect("ablate_faults presets"));
    common::emit("emulation_fidelity", emulation_fidelity);
}

/// The paper's emulation vs the real MEC content protocol: quantifies the
/// approximation error of the paper's own §5 methodology — something only
/// a simulator can measure.
fn emulation_fidelity() -> Table {
    let mut t = Table::new(
        "Emulation fidelity: paper-emulation content vs real MEC1 content",
        &["Workload", "Emulated (us)", "Real (us)", "Emu/Real", "Real retries"],
    );
    for wl in [WorkloadKind::Gups, WorkloadKind::Cg, WorkloadKind::ScalParC] {
        let spec = RunSpec {
            workload: wl,
            footprint: 32 << 20,
            ops_per_core: 20_000,
            seed: 3,
            ..RunSpec::smoke(wl)
        };
        let emu = run_spec(&SystemConfig::tl_ooo(), &spec);
        let mut real_cfg = SystemConfig::tl_ooo();
        real_cfg.emulate_content = false;
        let real = run_spec(&real_cfg, &spec);
        t.row(&[
            wl.name().into(),
            format!("{:.1}", emu.runtime_ns() / 1000.0),
            format!("{:.1}", real.runtime_ns() / 1000.0),
            format!("{:.3}", real.finish as f64 / emu.finish.max(1) as f64),
            real.twin_retries.to_string(),
        ]);
    }
    t
}
