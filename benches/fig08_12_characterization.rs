//! Figures 8–12 from one characterization dataset:
//!   Fig 8  — instruction count + IPC of TL-OoO vs Ideal
//!   Fig 9  — LLC MPKI
//!   Fig 10 — TLB MPKI
//!   Fig 11 — outstanding off-core reads
//!   Fig 12 — average read bandwidth

mod common;

use twinload::coordinator::experiments as exp;

fn main() {
    let scale = common::scale();
    let t0 = std::time::Instant::now();
    let data = exp::characterize(&scale);
    println!(
        "[bench] characterization runs: {:.2} s\n",
        t0.elapsed().as_secs_f64()
    );
    common::emit("fig08", || exp::fig8(&data));
    common::emit("fig09", || exp::fig9(&data));
    common::emit("fig10", || exp::fig10(&data));
    common::emit("fig11", || exp::fig11(&data));
    common::emit("fig12", || exp::fig12(&data));
}
