//! Figure 7: normalized performance of TL-LF / TL-OoO / NUMA vs Ideal,
//! medium + large footprints, all ten Table-4 workloads.

mod common;

use twinload::coordinator::experiments as exp;

fn main() {
    let scale = common::scale();
    common::emit("fig07", || exp::fig7(&scale));
}
