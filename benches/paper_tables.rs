//! Regenerate the paper's tables: Table 1 (timing), Table 2 (cache
//! states), Table 3 (systems), Table 4 (workloads), Table 5 (costs).

mod common;

use twinload::coordinator::experiments as exp;

fn main() {
    let scale = common::scale();
    common::emit("table1", exp::table1);
    common::emit("table2", exp::table2);
    common::emit("table3", || exp::table3().expect("table3 presets"));
    common::emit("table4", || exp::table4(&scale));
    common::emit("table5", exp::table5);
}
