# Twin-Load reproduction — build / test / perf entry points.

.PHONY: build test fmt clippy perf smoke perf-gate baseline golden-update artifacts clean

build:
	cargo build --release

test:
	cargo test -q

fmt:
	cargo fmt --check

clippy:
	cargo clippy --all-targets -- -D warnings

# Full hot-path benchmark; writes BENCH_hotpath.json at the repo root.
perf:
	cargo bench --bench hotpath

# Reduced-size smoke run of the same benchmark (CI).
smoke:
	TWINLOAD_BENCH_QUICK=1 cargo bench --bench hotpath

# Compare a fresh BENCH_hotpath.json (from `make smoke`/`make perf`)
# against the checked-in baseline; fails on >25% median regression on any
# benchmarked policy/engine, and whenever an optimized engine falls
# behind its retained reference implementation.
perf-gate:
	cargo run --release --bin perf_gate -- BENCH_hotpath.json BENCH_baseline.json

# Regenerate the perf-gate baseline after an *intentional* perf change
# (run on the CI runner class; commit the result). The emitted file has
# no "provisional" flag, so committing it arms the full 25% gate.
baseline:
	TWINLOAD_BENCH_QUICK=1 cargo bench --bench hotpath
	cp BENCH_hotpath.json BENCH_baseline.json

# Regenerate the golden SimReport snapshot corpus (rust/tests/golden.snap)
# after an *intentional* end-to-end behaviour change; commit the result.
golden-update:
	TWINLOAD_GOLDEN_UPDATE=1 cargo test --test golden -- --nocapture

# PJRT fast-path artifacts. Producing the real AOT-compiled artifacts
# requires the python/compile JAX/Pallas toolchain (see python/compile/aot.py);
# everything else — simulator, tests, benches — runs without them, and the
# hotpath bench degrades gracefully when the directory is empty.
artifacts:
	mkdir -p artifacts
	@echo "artifacts/: stub created. To build the PJRT fast-path artifacts run:"
	@echo "  python -m python.compile.aot --out artifacts/   (requires JAX/Pallas)"

clean:
	cargo clean
	rm -f BENCH_hotpath.json
