# Twin-Load reproduction — build / test / perf entry points.

.PHONY: build test fmt clippy perf smoke artifacts clean

build:
	cargo build --release

test:
	cargo test -q

fmt:
	cargo fmt --check

clippy:
	cargo clippy --all-targets -- -D warnings

# Full hot-path benchmark; writes BENCH_hotpath.json at the repo root.
perf:
	cargo bench --bench hotpath

# Reduced-size smoke run of the same benchmark (CI).
smoke:
	TWINLOAD_BENCH_QUICK=1 cargo bench --bench hotpath

# PJRT fast-path artifacts. Producing the real AOT-compiled artifacts
# requires the python/compile JAX/Pallas toolchain (see python/compile/aot.py);
# everything else — simulator, tests, benches — runs without them, and the
# hotpath bench degrades gracefully when the directory is empty.
artifacts:
	mkdir -p artifacts
	@echo "artifacts/: stub created. To build the PJRT fast-path artifacts run:"
	@echo "  python -m python.compile.aot --out artifacts/   (requires JAX/Pallas)"

clean:
	cargo clean
	rm -f BENCH_hotpath.json
