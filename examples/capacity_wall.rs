//! The capacity-wall scenario the paper's introduction motivates: how
//! deep can the MEC tree go (how much capacity can one channel fan out
//! to) before each mechanism breaks?
//!
//! Sweeps MEC tree depth with the paper's 3.4 ns simple-forwarding hops
//! (§2.1), reports the TL-OoO tolerance boundary (§3.1: "enough to
//! tolerate propagation delays for up to five MEC layers"), and shows
//! TL-LF sailing past it — the scalability headline of the paper.
//!
//! ```sh
//! cargo run --release --example capacity_wall
//! ```

use twinload::config::{RunSpec, SystemConfig};
use twinload::dram::timing::TimingParams;
use twinload::mec::Topology;
use twinload::sim::run_spec;
use twinload::stats::Table;
use twinload::workloads::WorkloadKind;

fn main() {
    let host = TimingParams::ddr3_1600();
    let spec = RunSpec {
        workload: WorkloadKind::Cg,
        footprint: 32 << 20,
        ops_per_core: 16_000,
        seed: 7,
        ..RunSpec::smoke(WorkloadKind::Cg)
    };

    let mut table = Table::new(
        "Capacity wall: MEC tree depth vs mechanism (CG workload)",
        &[
            "Layers",
            "Leaves",
            "Capacity x",
            "RTT (ns)",
            "OoO ok?",
            "TL-OoO (us)",
            "2nd-load real %",
            "TL-LF (us)",
        ],
    );

    for layers in [1u32, 2, 3, 4, 5, 6, 8] {
        let topo = Topology { layers, fanout: 2, hop_delay: 3_400 };
        let mut ooo = SystemConfig::tl_ooo();
        ooo.mec.topology = topo;
        // The real-content mode shows the tolerance wall (late second
        // loads start returning fake data and retrying).
        ooo.emulate_content = false;
        let mut lf = SystemConfig::tl_lf();
        lf.mec.topology = topo;
        lf.emulate_content = false;

        let r_ooo = run_spec(&ooo, &spec);
        let r_lf = run_spec(&lf, &spec);
        let real_pct = 100.0 * r_ooo.mec_second_real as f64
            / (r_ooo.mec_second_real + r_ooo.mec_second_late).max(1) as f64;
        table.row(&[
            layers.to_string(),
            topo.num_leaves().to_string(),
            format!("{}x", topo.num_leaves() * 2), // dual-rank leaves
            format!("{:.1}", topo.round_trip() as f64 / 1000.0),
            topo.ooo_tolerable(&host, &host).to_string(),
            format!("{:.1}", r_ooo.runtime_ns() / 1000.0),
            format!("{real_pct:.1}"),
            format!("{:.1}", r_lf.runtime_ns() / 1000.0),
        ]);
    }
    println!("{}", table.render());
    println!(
        "Reading: TL-OoO's forced row-miss window covers ~5 simple layers \
         (paper §3.1); beyond it the LVC data arrives late, second loads\n\
         return fake values and software retries erode performance. TL-LF \
         tolerates arbitrary depth at its (fence-serialized) pace."
    );
}
