//! Quickstart: simulate one workload on the twin-load system and the
//! Ideal baseline, and print the comparison the paper's Figure 7 makes.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use twinload::config::{RunSpec, SystemConfig};
use twinload::sim::run_spec;
use twinload::workloads::WorkloadKind;

fn main() {
    let workload = WorkloadKind::Gups;
    let spec = RunSpec {
        workload,
        footprint: 64 << 20, // "medium" (paper's ~4 GB, scaled 64x)
        ops_per_core: 40_000,
        seed: 42,
        ..RunSpec::smoke(workload)
    };

    println!("== twin-load quickstart: {} ==", workload.name());
    let ideal = run_spec(&SystemConfig::ideal(), &spec);
    println!("  {}", ideal.summary());

    let tl = run_spec(&SystemConfig::tl_ooo(), &spec);
    println!("  {}", tl.summary());

    let norm = tl.perf_vs(&ideal);
    println!(
        "\nTL-OoO achieves {:.1}% of Ideal performance on {}.",
        norm * 100.0,
        workload.name()
    );
    println!(
        "Twin-load costs: {:.0}% more instructions, {:.0}% more LLC misses, \
         {} twin retries, {} CAS retries.",
        (tl.retired_insts as f64 / ideal.retired_insts as f64 - 1.0) * 100.0,
        (tl.llc_misses as f64 / ideal.llc_misses.max(1) as f64 - 1.0) * 100.0,
        tl.twin_retries,
        tl.cas_fails,
    );
    println!(
        "MEC1 served {} first loads; {:.1}% of second loads found their \
         data in the LVC in time.",
        tl.mec_first_loads,
        100.0 * tl.mec_second_real as f64
            / (tl.mec_second_real + tl.mec_second_late).max(1) as f64
    );
    assert!(!ideal.deadlocked && !tl.deadlocked);
}
