//! Key-value serving scenario (paper Table 4's Memcached row): how much
//! serving capacity survives each memory-extension mechanism when the
//! item store lives almost entirely (97.3 %) in extended memory?
//!
//! The memcached workload generator reproduces memslap-style traffic:
//! zipf-popular keys, hash-chain walks, mostly GETs. We serve the same
//! request volume on every mechanism and report throughput plus the
//! memory-system health indicators a service operator would watch.
//!
//! ```sh
//! cargo run --release --example memcached_serving
//! ```

use twinload::config::{RunSpec, SystemConfig};
use twinload::sim::run_spec;
use twinload::stats::Table;
use twinload::workloads::WorkloadKind;

/// Logical ops per memcached request in the generator (hash + chain +
/// value + response ≈ 8 ops/request).
const OPS_PER_REQUEST: f64 = 8.0;

fn main() {
    let spec = RunSpec {
        workload: WorkloadKind::Memcached,
        footprint: 64 << 20,
        ops_per_core: 40_000,
        seed: 11,
        ..RunSpec::smoke(WorkloadKind::Memcached)
    };
    let systems = [
        ("ideal", SystemConfig::ideal()),
        ("tl-ooo", SystemConfig::tl_ooo()),
        ("tl-lf", SystemConfig::tl_lf()),
        ("numa", SystemConfig::numa()),
        ("pcie-75%", SystemConfig::pcie(0.75)),
    ];

    let mut table = Table::new(
        "Memcached serving: 97.3% of the item store in extended memory",
        &["System", "kReq/s", "vs ideal", "LLC MPKI", "IPC", "Retries"],
    );
    let mut base_rate = None;
    for (name, cfg) in systems {
        let r = run_spec(&cfg, &spec);
        assert!(!r.deadlocked, "{name} deadlocked");
        let requests =
            (r.transform.logical_mem as f64 / OPS_PER_REQUEST).max(1.0);
        let krps = requests / (r.finish as f64 * 1e-12) / 1e3;
        let base = *base_rate.get_or_insert(krps);
        table.row(&[
            name.into(),
            format!("{krps:.0}"),
            format!("{:.2}", krps / base),
            format!("{:.1}", r.llc_mpki(r.retired_insts)),
            format!("{:.2}", r.ipc()),
            format!("{}", r.twin_retries + r.cas_fails),
        ]);
    }
    println!("{}", table.render());
    println!(
        "Reading: the paper's Memcached is insensitive to the memory system \
         until PCIe swapping enters (Figure 7 vs Figure 13's 0.13x) —\n\
         twin-load keeps the serving rate in the same order as Ideal, while \
         page swapping collapses it."
    );
}
