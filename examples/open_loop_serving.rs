//! Open-loop serving: what a *user* of the memcached fleet sees.
//!
//! Closed-loop runs (every other example) measure capacity — cores
//! issue as fast as the ROB drains. Open-loop runs pace requests at a
//! configured offered load through a bounded queue, so queueing delay,
//! tail latency, and drops become visible. This example drives TL-OoO
//! and AMU across a Poisson offered-load ladder and prints the serving
//! fields of `SimReport`; `twinload serve` runs the full sweep.
//!
//! ```sh
//! cargo run --release --example open_loop_serving
//! ```

use twinload::config::{RunSpec, SystemConfig};
use twinload::sim::run_spec;
use twinload::stats::Table;
use twinload::workloads::arrival::ArrivalKind;
use twinload::workloads::WorkloadKind;

fn main() {
    let base = RunSpec {
        workload: WorkloadKind::Memcached,
        footprint: 32 << 20,
        ops_per_core: 20_000,
        seed: 11,
        ..RunSpec::smoke(WorkloadKind::Memcached)
    };
    let systems = [("tl-ooo", SystemConfig::tl_ooo()), ("amu", SystemConfig::amu())];
    let loads: [u64; 3] = [1_000_000, 4_000_000, 16_000_000];

    let mut table = Table::new(
        "Open-loop memcached: Poisson arrivals, bounded per-core queue",
        &[
            "System",
            "Offered (kreq/s)",
            "Served",
            "Dropped",
            "p50 (ns)",
            "p99 (ns)",
            "p99.9 (ns)",
            "Queue peak",
        ],
    );
    for (name, cfg) in &systems {
        // Closed-loop sanity row first: the default arrival discipline
        // must leave the serving machinery entirely inert.
        let closed = run_spec(cfg, &base);
        assert_eq!(closed.arrived_requests, 0, "{name}: closed loop queued requests");
        println!("{name} closed-loop: {}", closed.summary());

        for rps in loads {
            let r = run_spec(cfg, &base.open_loop(ArrivalKind::Poisson, rps));
            assert!(!r.deadlocked, "{name} deadlocked at {rps} req/s");
            table.row(&[
                (*name).into(),
                format!("{}", rps / 1000),
                format!("{}", r.served_requests),
                format!("{}", r.dropped_requests),
                format!("{}", r.req_p50_ns),
                format!("{}", r.req_p99_ns),
                format!("{}", r.req_p999_ns),
                format!("{}", r.queue_peak),
            ]);
        }
    }
    println!("\n{}", table.render());
    println!(
        "Reading: below the knee the latency columns are flat and drops are \
         zero; past it the queue pins at its bound,\ndrops grow with offered \
         load, and p99/p99.9 inflate first. AMU's asynchronous issue should \
         hold the knee closer\nto ideal than the twin-load variants — see \
         EXPERIMENTS.md \u{00a7}Serving and `twinload serve` for the full \
         mechanism sweep."
    );
}
