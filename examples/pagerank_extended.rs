//! End-to-end driver (EXPERIMENTS.md §E2E): the full three-layer stack on
//! a real small workload.
//!
//! 1. **Application compute** — the Rust coordinator loads the
//!    AOT-compiled `pagerank_step` artifact (L2 JAX graph calling the L1
//!    Pallas gather kernel) and runs PageRank to convergence on a
//!    synthetic 4096-node / 32768-edge graph via PJRT. Results are
//!    verified against a pure-Rust reference implementation.
//! 2. **Memory-system evaluation** — the same application's access
//!    pattern (the `pagerank` Table-4 workload) runs through the platform
//!    simulator on Ideal, TL-OoO, and NUMA, reproducing the Figure-7
//!    comparison for this app.
//!
//! Requires `make artifacts` first.
//!
//! ```sh
//! cargo run --release --example pagerank_extended
//! ```

use twinload::config::{RunSpec, SystemConfig};
use twinload::runtime::{ArgValue, PjrtRuntime};
use twinload::sim::run_spec;
use twinload::util::Rng;
use twinload::workloads::WorkloadKind;

const NODES: usize = 4_096;
const EDGES: usize = 32_768;
const DAMPING: f32 = 0.85;

/// Pure-Rust PageRank step (the correctness oracle for the PJRT path).
fn reference_step(ranks: &[f32], src: &[i32], dst: &[i32], inv_deg: &[f32]) -> Vec<f32> {
    let n = ranks.len();
    let mut out = vec![(1.0 - DAMPING) / n as f32; n];
    for e in 0..src.len() {
        out[dst[e] as usize] += DAMPING * ranks[src[e] as usize] * inv_deg[src[e] as usize];
    }
    out
}

fn main() -> anyhow::Result<()> {
    // --- Build the graph ---
    let mut rng = Rng::new(2026);
    let src: Vec<i32> = (0..EDGES).map(|_| rng.below(NODES as u64) as i32).collect();
    let dst: Vec<i32> = (0..EDGES).map(|_| rng.below(NODES as u64) as i32).collect();
    let mut deg = vec![0f32; NODES];
    for &s in &src {
        deg[s as usize] += 1.0;
    }
    let inv_deg: Vec<f32> =
        deg.iter().map(|&d| if d > 0.0 { 1.0 / d } else { 0.0 }).collect();
    let mut ranks = vec![1.0f32 / NODES as f32; NODES];

    // --- Layer 3 loads the AOT artifact (L2 JAX + L1 Pallas) ---
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    let mut rt = PjrtRuntime::cpu()?;
    rt.load_hlo("pagerank_step", format!("{dir}/pagerank_step.hlo.txt"))?;
    println!(
        "loaded pagerank_step on {} (graph: {NODES} nodes, {EDGES} edges)",
        rt.platform()
    );

    // --- Iterate to convergence via PJRT ---
    let n_i64 = &[NODES as i64][..];
    let e_i64 = &[EDGES as i64][..];
    let t0 = std::time::Instant::now();
    let mut iters = 0;
    loop {
        let outs = rt.execute(
            "pagerank_step",
            &[
                ArgValue::f32(ranks.clone(), n_i64),
                ArgValue::i32(src.clone(), e_i64),
                ArgValue::i32(dst.clone(), e_i64),
                ArgValue::f32(inv_deg.clone(), n_i64),
            ],
        )?;
        let new_ranks = outs[0].as_f32()?.to_vec();
        let delta: f32 =
            new_ranks.iter().zip(&ranks).map(|(a, b)| (a - b).abs()).sum();
        ranks = new_ranks;
        iters += 1;
        if delta < 1e-6 || iters >= 100 {
            println!("converged after {iters} iterations (L1 delta {delta:.2e})");
            break;
        }
    }
    let elapsed = t0.elapsed();
    println!(
        "PJRT throughput: {:.1} M edges/s over {iters} iterations ({:.1} ms total)",
        (EDGES as f64 * iters as f64) / elapsed.as_secs_f64() / 1e6,
        elapsed.as_secs_f64() * 1e3
    );

    // --- Verify against the Rust oracle ---
    let mut check = vec![1.0f32 / NODES as f32; NODES];
    for _ in 0..iters {
        check = reference_step(&check, &src, &dst, &inv_deg);
    }
    let max_err = ranks
        .iter()
        .zip(&check)
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    println!("max |PJRT - Rust oracle| = {max_err:.3e}");
    assert!(max_err < 1e-5, "PJRT result diverges from the oracle");
    let sum: f32 = ranks.iter().sum();
    assert!((sum - 1.0).abs() < 1e-3, "rank mass not conserved: {sum}");

    // --- Memory-system evaluation of the same application ---
    println!("\nmemory-system comparison (pagerank access pattern):");
    let spec = RunSpec {
        workload: WorkloadKind::PageRank,
        footprint: 64 << 20,
        ops_per_core: 30_000,
        seed: 2026,
        ..RunSpec::smoke(WorkloadKind::PageRank)
    };
    let ideal = run_spec(&SystemConfig::ideal(), &spec);
    let tl = run_spec(&SystemConfig::tl_ooo(), &spec);
    let numa = run_spec(&SystemConfig::numa(), &spec);
    println!("  {}", ideal.summary());
    println!("  {}", tl.summary());
    println!("  {}", numa.summary());
    println!(
        "\nnormalized performance: TL-OoO {:.2}, NUMA {:.2} (Ideal = 1.0) — \
         with 87.9% of the application's data in extended memory (Table 4).",
        tl.perf_vs(&ideal),
        numa.perf_vs(&ideal)
    );
    Ok(())
}
