//! In-house property-testing harness.
//!
//! The vendored registry carries no `proptest`, so this module provides
//! the subset the test-suite needs: seeded case generation with
//! per-failure reproduction seeds, and linear input shrinking for
//! integer parameters. Properties return `Ok(())` or a failure message.

use crate::util::Rng;

/// Configuration for a property run.
#[derive(Debug, Clone, Copy)]
pub struct PropConfig {
    pub cases: u32,
    pub seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        // Env knobs mirror proptest's: TWINLOAD_PROP_CASES / _SEED.
        let cases = std::env::var("TWINLOAD_PROP_CASES")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(64);
        let seed = std::env::var("TWINLOAD_PROP_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0x7e57_5eed);
        PropConfig { cases, seed }
    }
}

/// Run `prop` against `cases` seeded RNGs; panics with the failing case
/// seed on the first failure (re-run with `TWINLOAD_PROP_SEED=<seed>
/// TWINLOAD_PROP_CASES=1` to reproduce).
pub fn check<F>(name: &str, cfg: PropConfig, prop: F)
where
    F: Fn(&mut Rng) -> Result<(), String>,
{
    for case in 0..cfg.cases {
        let case_seed = cfg.seed.wrapping_add(case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Rng::new(case_seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed on case {case} (seed {case_seed:#x}): {msg}\n\
                 reproduce: TWINLOAD_PROP_SEED={case_seed} TWINLOAD_PROP_CASES=1"
            );
        }
    }
}

/// Shrink a failing integer input toward `lo` while `fails` keeps
/// failing; returns the smallest failing value found.
pub fn shrink_u64<F: Fn(u64) -> bool>(mut failing: u64, lo: u64, fails: F) -> u64 {
    debug_assert!(fails(failing));
    while failing > lo {
        let candidate = lo + (failing - lo) / 2;
        if fails(candidate) {
            failing = candidate;
        } else if failing - candidate <= 1 {
            break;
        } else {
            // Try closer to the failing point.
            let near = failing - 1;
            if fails(near) {
                failing = near;
            } else {
                break;
            }
        }
    }
    failing
}

/// Sample helpers for common simulation inputs.
pub mod gen {
    use crate::util::Rng;

    /// A random cache-line-aligned address below `span`.
    pub fn line_addr(rng: &mut Rng, span: u64) -> u64 {
        rng.below(span / 64) * 64
    }

    /// A vector of `n` random values in `[0, bound)`.
    pub fn vec_below(rng: &mut Rng, n: usize, bound: u64) -> Vec<u64> {
        (0..n).map(|_| rng.below(bound)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("tautology", PropConfig { cases: 16, seed: 1 }, |rng| {
            let v = rng.below(100);
            if v < 100 {
                Ok(())
            } else {
                Err(format!("{v} out of range"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails'")]
    fn failing_property_panics_with_seed() {
        check("always-fails", PropConfig { cases: 4, seed: 2 }, |_| {
            Err("nope".to_string())
        });
    }

    #[test]
    fn shrink_finds_boundary() {
        // Fails for v >= 37.
        let smallest = shrink_u64(1000, 0, |v| v >= 37);
        assert_eq!(smallest, 37);
    }

    #[test]
    fn gen_helpers_in_bounds() {
        let mut rng = crate::util::Rng::new(3);
        for _ in 0..100 {
            let a = gen::line_addr(&mut rng, 1 << 20);
            assert_eq!(a % 64, 0);
            assert!(a < 1 << 20);
        }
        let v = gen::vec_below(&mut rng, 10, 5);
        assert!(v.iter().all(|&x| x < 5));
    }
}
