//! Radix sort (PARSEC kernel): streaming key reads + scattered
//! bucket-counter updates and permuted writes (Table 4: 100 % extended).

use super::common::TraceBuf;
use super::params::WorkloadKind;
use super::DataRegions;
use crate::twinload::{LogicalOp, LogicalSource};

pub struct Radix {
    buf: TraceBuf,
    compute: u32,
    hot_lines: u64,
    phase: u8,
}

impl Radix {
    pub fn new(data: DataRegions, ops: u64, seed: u64) -> Radix {
        let sig = WorkloadKind::Radix.signature();
        let mut buf = TraceBuf::new(data, ops, seed);
        buf.set_accesses_per_line(sig.accesses_per_line);
        Radix {
            buf,
            compute: sig.compute_per_access,
            hot_lines: sig.hot_lines,
            phase: 0,
        }
    }
}

impl LogicalSource for Radix {
    fn next_logical(&mut self) -> Option<LogicalOp> {
        loop {
            if let Some(op) = self.buf.pop() {
                return Some(op);
            }
            if self.buf.exhausted() {
                return None;
            }
            match self.phase {
                // Counting pass: a sequential run of key reads, then hot
                // histogram bumps for the digit counts.
                0 => {
                    let run = self.buf.rng.burst(0.7, 4);
                    let mut last = None;
                    for _ in 0..run {
                        let key = self.buf.ext_next_seq();
                        last = Some(self.buf.mem(key, false, None));
                    }
                    self.buf.compute(self.compute * run as u32);
                    let hist = self.buf.ext_hot(self.hot_lines);
                    self.buf.mem(hist, false, last);
                    self.buf.mem(hist, true, last);
                }
                // Permute pass: sequential read, scattered write.
                _ => {
                    let key = self.buf.ext_next_seq();
                    self.buf.compute(self.compute);
                    let ld = self.buf.mem(key, false, None);
                    let dst = self.buf.ext_random();
                    self.buf.mem(dst, true, Some(ld));
                }
            }
            self.phase = (self.phase + 1) % 2;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::testutil::{characterize, small_regions};

    #[test]
    fn all_extended_with_heavy_stores() {
        let data = small_regions(&WorkloadKind::Radix.signature());
        let (mem, ext, stores, _) = characterize(Box::new(Radix::new(data, 10_000, 5)));
        assert_eq!(mem, ext);
        let sf = stores as f64 / mem as f64;
        assert!(sf > 0.25 && sf < 0.6, "store fraction {sf}");
    }

    #[test]
    fn mixes_sequential_and_scattered() {
        let data = small_regions(&WorkloadKind::Radix.signature());
        let mut r = Radix::new(data, 8_000, 5);
        let mut prev = None;
        let mut seq_pairs = 0;
        let mut total = 0;
        while let Some(op) = r.next_logical() {
            if let LogicalOp::Mem(m) = op {
                if let Some(p) = prev {
                    total += 1;
                    if m.vaddr == p + 64 {
                        seq_pairs += 1;
                    }
                }
                prev = Some(m.vaddr);
            }
        }
        assert!(seq_pairs > 0, "no sequential runs");
        assert!(seq_pairs < total, "no scattered accesses");
    }
}
