//! Graph workloads: BFS (Graph500), BC (SSCA2), PageRank (in-house).
//!
//! The defining features (paper §6.1–6.2): irregular accesses with
//! *dependent* address chains (frontier → neighbor list → vertex state),
//! small hot vertex-metadata structures that thrash the TLB, and limited
//! intra-thread MLP — which is why TL-OoO beats NUMA on these.

use super::common::TraceBuf;
use super::params::{SignatureParams, WorkloadKind};
use super::DataRegions;
use crate::twinload::{LogicalOp, LogicalSource};

pub struct GraphWalk {
    buf: TraceBuf,
    sig: SignatureParams,
    kind: WorkloadKind,
}

impl GraphWalk {
    pub fn bfs(data: DataRegions, ops: u64, seed: u64) -> GraphWalk {
        GraphWalk {
            buf: TraceBuf::new(data, ops, seed),
            sig: WorkloadKind::Bfs.signature(),
            kind: WorkloadKind::Bfs,
        }
    }

    pub fn bc(data: DataRegions, ops: u64, seed: u64) -> GraphWalk {
        GraphWalk {
            buf: TraceBuf::new(data, ops, seed),
            sig: WorkloadKind::Bc.signature(),
            kind: WorkloadKind::Bc,
        }
    }

    pub fn pagerank(data: DataRegions, ops: u64, seed: u64) -> GraphWalk {
        GraphWalk {
            buf: TraceBuf::new(data, ops, seed),
            sig: WorkloadKind::PageRank.signature(),
            kind: WorkloadKind::PageRank,
        }
    }

    /// One vertex visit: pop from the frontier (hot), chase into the
    /// adjacency list (dependent, random), stream a few edges, touch
    /// destination vertex state (dependent, random), update.
    ///
    /// Every access independently lands in local memory with probability
    /// `1 - ext_fraction` — BC keeps ~23 % of its data local (Table 4).
    fn visit(&mut self) {
        let sig = self.sig;
        let b = &mut self.buf;
        let place = |b: &mut TraceBuf, preferred: u64| -> u64 {
            if b.rng.chance(sig.ext_fraction) {
                preferred
            } else {
                b.local_random()
            }
        };

        // Frontier / work-queue access (hot lines, metadata).
        let hot = b.ext_hot(sig.hot_lines);
        let frontier = place(b, hot);
        let f = b.mem(frontier, false, None);
        b.compute(sig.compute_per_access);

        // Dependent chase into the adjacency array.
        let adj_pref = if b.rng.chance(sig.reuse_fraction) {
            b.ext_hot(sig.hot_lines * 8)
        } else {
            b.ext_random()
        };
        let adj = place(b, adj_pref);
        let dep = if b.rng.chance(sig.dep_fraction) { Some(f) } else { None };
        let a = b.mem(adj, false, dep);

        // Stream a short edge run.
        b.reseek();
        let run = b.rng.burst(sig.seq_locality, 4);
        for _ in 0..run {
            let seq = b.ext_next_seq();
            let e = place(b, seq);
            b.mem(e, false, None);
            b.compute(2);
        }

        // Dependent destination-vertex access (+ occasional update).
        let dst_pref = b.ext_random();
        let dst = place(b, dst_pref);
        let chase = if b.rng.chance(sig.dep_fraction) { Some(a) } else { None };
        let d = b.mem(dst, false, chase);
        if b.rng.chance(sig.store_fraction * 3.0) {
            b.mem(dst, true, Some(d));
        }
        b.compute(sig.compute_per_access / 2 + 1);
    }
}

impl LogicalSource for GraphWalk {
    fn next_logical(&mut self) -> Option<LogicalOp> {
        loop {
            if let Some(op) = self.buf.pop() {
                return Some(op);
            }
            if self.buf.exhausted() {
                return None;
            }
            self.visit();
            let _ = self.kind;
        }
    }

    /// Between vertex visits: one visit = one serving "request".
    fn at_request_boundary(&self) -> bool {
        self.buf.pending_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::testutil::{characterize, small_regions};

    #[test]
    fn bfs_has_dependency_chains() {
        let data = small_regions(&WorkloadKind::Bfs.signature());
        let mut g = GraphWalk::bfs(data, 20_000, 3);
        let (mut deps, mut loads) = (0u64, 0u64);
        while let Some(op) = g.next_logical() {
            if let LogicalOp::Mem(m) = op {
                if !m.is_store {
                    loads += 1;
                    if m.dep_on.is_some() {
                        deps += 1;
                    }
                }
            }
        }
        let frac = deps as f64 / loads as f64;
        assert!(frac > 0.1, "dep fraction {frac}");
    }

    #[test]
    fn bc_has_local_fraction_near_table4() {
        let data = small_regions(&WorkloadKind::Bc.signature());
        let (mem, ext, _, _) = characterize(Box::new(GraphWalk::bc(data, 40_000, 3)));
        let frac = ext as f64 / mem as f64;
        assert!((frac - 0.7692).abs() < 0.15, "bc ext fraction {frac}");
    }

    #[test]
    fn pagerank_mostly_extended() {
        let data = small_regions(&WorkloadKind::PageRank.signature());
        let (mem, ext, _, _) =
            characterize(Box::new(GraphWalk::pagerank(data, 40_000, 3)));
        let frac = ext as f64 / mem as f64;
        assert!((frac - 0.8793).abs() < 0.15, "pagerank ext fraction {frac}");
    }

    #[test]
    fn metadata_is_hot_and_small() {
        // A meaningful share of accesses concentrate in the hot metadata
        // region (the TLB-thrash driver of Figure 10).
        let data = small_regions(&WorkloadKind::Bfs.signature());
        let sig = WorkloadKind::Bfs.signature();
        let hot_end = data.ext_base + sig.hot_lines * 64;
        let mut g = GraphWalk::bfs(data, 20_000, 3);
        let (mut hot, mut total) = (0u64, 0u64);
        while let Some(op) = g.next_logical() {
            if let LogicalOp::Mem(m) = op {
                total += 1;
                if m.vaddr >= data.ext_base && m.vaddr < hot_end {
                    hot += 1;
                }
            }
        }
        assert!(hot as f64 / total as f64 > 0.15);
    }
}
