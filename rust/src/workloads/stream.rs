//! Streaming / data-mining workloads: ScalParC (NU-MineBench parallel
//! classification) and StreamCluster (PARSEC online clustering).
//!
//! Both have the best locality of the suite (paper §6.3: ScalParC's low
//! LLC and TLB MPKI make it the most PCIe-swap-tolerant workload).

use super::common::TraceBuf;
use super::params::{SignatureParams, WorkloadKind};
use super::DataRegions;
use crate::twinload::{LogicalOp, LogicalSource};

/// ScalParC: long sequential scans of attribute arrays with periodic
/// split-point updates into a hot structure; 94.48 % extended.
pub struct ScalParC {
    buf: TraceBuf,
    sig: SignatureParams,
}

impl ScalParC {
    pub fn new(data: DataRegions, ops: u64, seed: u64) -> ScalParC {
        let sig = WorkloadKind::ScalParC.signature();
        let mut buf = TraceBuf::new(data, ops, seed);
        buf.set_accesses_per_line(sig.accesses_per_line);
        ScalParC { buf, sig }
    }
}

impl LogicalSource for ScalParC {
    fn next_logical(&mut self) -> Option<LogicalOp> {
        loop {
            if let Some(op) = self.buf.pop() {
                return Some(op);
            }
            if self.buf.exhausted() {
                return None;
            }
            let run =
                self.buf.rng.burst(self.sig.seq_locality, 32) * self.sig.accesses_per_line as u64;
            for _ in 0..run {
                let ext = self.buf.rng.chance(self.sig.ext_fraction);
                let a = if ext { self.buf.ext_next_seq() } else { self.buf.local_random() };
                self.buf.mem(a, false, None);
                self.buf.compute(self.sig.compute_per_access);
            }
            // Split-point histogram update (hot; index depends on the
            // just-scanned attribute values).
            let h = self.buf.ext_hot(self.sig.hot_lines);
            let dep = self.buf.chain(self.sig.dep_fraction * 4.0);
            let ld = self.buf.mem(h, false, dep);
            if self.buf.rng.chance(self.sig.store_fraction * 4.0) {
                self.buf.mem(h, true, Some(ld));
            }
            self.buf.reseek();
        }
    }
}

/// StreamCluster: distance evaluation of streamed points against a hot
/// set of cluster centers; compute-heavy; 92.93 % extended.
pub struct StreamCluster {
    buf: TraceBuf,
    sig: SignatureParams,
}

impl StreamCluster {
    pub fn new(data: DataRegions, ops: u64, seed: u64) -> StreamCluster {
        let sig = WorkloadKind::StreamCluster.signature();
        let mut buf = TraceBuf::new(data, ops, seed);
        buf.set_accesses_per_line(sig.accesses_per_line);
        StreamCluster { buf, sig }
    }
}

impl LogicalSource for StreamCluster {
    fn next_logical(&mut self) -> Option<LogicalOp> {
        loop {
            if let Some(op) = self.buf.pop() {
                return Some(op);
            }
            if self.buf.exhausted() {
                return None;
            }
            // Stream one point (a few lines), compare against k centers.
            let point_lines =
                self.buf.rng.burst(self.sig.seq_locality, 4) * self.sig.accesses_per_line as u64;
            for _ in 0..point_lines {
                let ext = self.buf.rng.chance(self.sig.ext_fraction);
                let p = if ext { self.buf.ext_next_seq() } else { self.buf.local_random() };
                self.buf.mem(p, false, None);
            }
            for _ in 0..3 {
                let c = self.buf.ext_hot(self.sig.hot_lines);
                let dep = self.buf.chain(self.sig.dep_fraction);
                self.buf.mem(c, false, dep);
                self.buf.compute(self.sig.compute_per_access);
            }
            if self.buf.rng.chance(self.sig.store_fraction * 8.0) {
                let c = self.buf.ext_hot(self.sig.hot_lines);
                self.buf.mem(c, true, None);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::testutil::{characterize, small_regions};

    #[test]
    fn scalparc_is_sequential_dominated() {
        let data = small_regions(&WorkloadKind::ScalParC.signature());
        let mut s = ScalParC::new(data, 20_000, 9);
        let (mut seq, mut total) = (0u64, 0u64);
        let mut prev = None;
        while let Some(op) = s.next_logical() {
            if let LogicalOp::Mem(m) = op {
                if let Some(p) = prev {
                    total += 1;
                    // Element-granular scans: same line or the next one.
                    if m.vaddr == p || m.vaddr == p + 64 {
                        seq += 1;
                    }
                }
                prev = Some(m.vaddr);
            }
        }
        let frac = seq as f64 / total as f64;
        assert!(frac > 0.4, "sequential fraction {frac}");
    }

    #[test]
    fn streamcluster_center_reuse() {
        let data = small_regions(&WorkloadKind::StreamCluster.signature());
        let sig = WorkloadKind::StreamCluster.signature();
        let hot_end = data.ext_base + sig.hot_lines * 64;
        let mut s = StreamCluster::new(data, 20_000, 9);
        let (mut hot, mut total) = (0u64, 0u64);
        while let Some(op) = s.next_logical() {
            if let LogicalOp::Mem(m) = op {
                total += 1;
                if m.vaddr >= data.ext_base && m.vaddr < hot_end {
                    hot += 1;
                }
            }
        }
        // Centers are a small share of accesses once points stream at
        // element granularity, but must still be visibly reused.
        assert!(hot as f64 / total as f64 > 0.08, "center reuse too low");
    }

    #[test]
    fn both_have_low_store_fractions() {
        for (kind, src) in [
            (WorkloadKind::ScalParC, 0usize),
            (WorkloadKind::StreamCluster, 1usize),
        ] {
            let data = small_regions(&kind.signature());
            let boxed: Box<dyn LogicalSource + Send> = if src == 0 {
                Box::new(ScalParC::new(data, 20_000, 4))
            } else {
                Box::new(StreamCluster::new(data, 20_000, 4))
            };
            let (mem, _, stores, _) = characterize(boxed);
            assert!((stores as f64 / mem as f64) < 0.2, "{kind:?}");
        }
    }
}
