//! Workload taxonomy and access signatures (paper Table 4).

/// The ten evaluated benchmarks.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum WorkloadKind {
    /// HPC Challenge random access microbenchmark.
    Gups,
    /// PARSEC integer sort kernel.
    Radix,
    /// NPB conjugate gradient.
    Cg,
    /// PARSEC N-body (fast multipole method).
    Fmm,
    /// Graph500 breadth-first search.
    Bfs,
    /// SSCA2 betweenness centrality.
    Bc,
    /// In-house PageRank.
    PageRank,
    /// NU-MineBench parallel classification.
    ScalParC,
    /// PARSEC online clustering.
    StreamCluster,
    /// Memcached-1.4.20 key-value serving.
    Memcached,
}

/// All Table-4 workloads, in the paper's row order.
pub const ALL_WORKLOADS: &[WorkloadKind] = &[
    WorkloadKind::Gups,
    WorkloadKind::Radix,
    WorkloadKind::Cg,
    WorkloadKind::Fmm,
    WorkloadKind::Bfs,
    WorkloadKind::Bc,
    WorkloadKind::PageRank,
    WorkloadKind::ScalParC,
    WorkloadKind::StreamCluster,
    WorkloadKind::Memcached,
];

/// The five Figure-13 (PCIe) representatives.
pub const FIG13_WORKLOADS: &[WorkloadKind] = &[
    WorkloadKind::Gups,
    WorkloadKind::Cg,
    WorkloadKind::Bfs,
    WorkloadKind::ScalParC,
    WorkloadKind::Memcached,
];

/// Statistical signature of a workload's memory behaviour.
#[derive(Debug, Clone, Copy)]
pub struct SignatureParams {
    /// Table 4: fraction of data placed in extended memory.
    pub ext_fraction: f64,
    /// Non-memory instructions per logical access (compute density).
    pub compute_per_access: u32,
    /// Fraction of accesses that are stores.
    pub store_fraction: f64,
    /// Probability the next access continues a sequential run.
    pub seq_locality: f64,
    /// Fraction of loads whose address depends on the previous load
    /// (pointer chasing → intrinsic MLP limit).
    pub dep_fraction: f64,
    /// Reuse-set size in lines (0 = no temporal reuse): accesses draw
    /// from a hot subset with probability `reuse_fraction`.
    pub hot_lines: u64,
    pub reuse_fraction: f64,
    /// Element-granularity streaming: how many consecutive accesses land
    /// in one cache line before the stream advances (real code touches
    /// each 64 B line ~8 times at 8 B elements; 1 = line-granular).
    pub accesses_per_line: u32,
}

impl WorkloadKind {
    pub fn name(&self) -> &'static str {
        match self {
            WorkloadKind::Gups => "gups",
            WorkloadKind::Radix => "radix",
            WorkloadKind::Cg => "cg",
            WorkloadKind::Fmm => "fmm",
            WorkloadKind::Bfs => "bfs",
            WorkloadKind::Bc => "bc",
            WorkloadKind::PageRank => "pagerank",
            WorkloadKind::ScalParC => "scalparc",
            WorkloadKind::StreamCluster => "streamcluster",
            WorkloadKind::Memcached => "memcached",
        }
    }

    pub fn from_name(s: &str) -> Option<WorkloadKind> {
        ALL_WORKLOADS.iter().copied().find(|k| k.name() == s)
    }

    /// Table-4 signature. `ext_fraction` values are the paper's column;
    /// the behavioural parameters are derived from the paper's Figure
    /// 8–12 characterization (e.g. GUPS: pure random, CG: high MLP
    /// gather, graph codes: dependent irregular accesses, ScalParC /
    /// StreamCluster: streaming with good locality).
    pub fn signature(&self) -> SignatureParams {
        match self {
            WorkloadKind::Gups => SignatureParams {
                ext_fraction: 1.00,
                compute_per_access: 10,
                store_fraction: 0.5, // read-modify-write updates
                seq_locality: 0.0,
                dep_fraction: 0.0,
                hot_lines: 0,
                reuse_fraction: 0.0,
                accesses_per_line: 1,
            },
            WorkloadKind::Radix => SignatureParams {
                ext_fraction: 1.00,
                compute_per_access: 16,
                store_fraction: 0.45,
                seq_locality: 0.5, // streaming key reads, scattered bucket writes
                dep_fraction: 0.25,
                hot_lines: 4096, // bucket headers
                reuse_fraction: 0.2,
                accesses_per_line: 4,
            },
            WorkloadKind::Cg => SignatureParams {
                ext_fraction: 0.9943,
                compute_per_access: 16,
                store_fraction: 0.06,
                seq_locality: 0.55, // row_ptr/val streaming + x[] gather
                dep_fraction: 0.25,  // indices come from streamed arrays
                hot_lines: 16_384,  // x vector band
                reuse_fraction: 0.35,
                accesses_per_line: 4,
            },
            WorkloadKind::Fmm => SignatureParams {
                ext_fraction: 0.9439,
                compute_per_access: 34, // N-body is compute-dense
                store_fraction: 0.12,
                seq_locality: 0.7, // cluster-local particle sweeps
                dep_fraction: 0.25,
                hot_lines: 8_192,
                reuse_fraction: 0.4,
                accesses_per_line: 6,
            },
            WorkloadKind::Bfs => SignatureParams {
                ext_fraction: 0.9979,
                compute_per_access: 18,
                store_fraction: 0.10, // visited marks
                seq_locality: 0.15,   // edge lists short runs
                dep_fraction: 0.45,   // frontier → neighbor chase
                hot_lines: 2_048,     // frontier queue
                reuse_fraction: 0.15,
                accesses_per_line: 2,
            },
            WorkloadKind::Bc => SignatureParams {
                ext_fraction: 0.7692,
                compute_per_access: 22,
                store_fraction: 0.15,
                seq_locality: 0.15,
                dep_fraction: 0.40,
                hot_lines: 4_096, // vertex metadata
                reuse_fraction: 0.30,
                accesses_per_line: 2,
            },
            WorkloadKind::PageRank => SignatureParams {
                ext_fraction: 0.8793,
                compute_per_access: 20,
                store_fraction: 0.08,
                seq_locality: 0.35, // edge stream + rank gather
                dep_fraction: 0.35,
                hot_lines: 8_192,
                reuse_fraction: 0.25,
                accesses_per_line: 4,
            },
            WorkloadKind::ScalParC => SignatureParams {
                ext_fraction: 0.9448,
                compute_per_access: 26,
                store_fraction: 0.08,
                seq_locality: 0.88, // attribute-array scans: best locality
                dep_fraction: 0.15,
                hot_lines: 16_384,
                reuse_fraction: 0.5,
                accesses_per_line: 8,
            },
            WorkloadKind::StreamCluster => SignatureParams {
                ext_fraction: 0.9293,
                compute_per_access: 34,
                store_fraction: 0.05,
                seq_locality: 0.80, // distance sweeps over points
                dep_fraction: 0.2,
                hot_lines: 4_096, // cluster centers
                reuse_fraction: 0.45,
                accesses_per_line: 8,
            },
            WorkloadKind::Memcached => SignatureParams {
                ext_fraction: 0.9730,
                compute_per_access: 120, // hashing + protocol glue
                store_fraction: 0.10,   // mostly GETs (small-object test)
                seq_locality: 0.25,     // item structs span a couple lines
                dep_fraction: 0.50,     // hash-bucket chain walk
                hot_lines: 32_768,      // zipf-hot items
                reuse_fraction: 0.6,
                accesses_per_line: 1,
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table4_ext_fractions() {
        assert_eq!(WorkloadKind::Gups.signature().ext_fraction, 1.00);
        assert_eq!(WorkloadKind::Cg.signature().ext_fraction, 0.9943);
        assert_eq!(WorkloadKind::Bc.signature().ext_fraction, 0.7692);
        assert_eq!(WorkloadKind::Memcached.signature().ext_fraction, 0.9730);
    }

    #[test]
    fn names_roundtrip() {
        for &k in ALL_WORKLOADS {
            assert_eq!(WorkloadKind::from_name(k.name()), Some(k));
        }
        assert_eq!(WorkloadKind::from_name("nope"), None);
    }

    #[test]
    fn ten_workloads_five_for_fig13() {
        assert_eq!(ALL_WORKLOADS.len(), 10);
        assert_eq!(FIG13_WORKLOADS.len(), 5);
    }

    #[test]
    fn signatures_sane() {
        for &k in ALL_WORKLOADS {
            let s = k.signature();
            assert!((0.0..=1.0).contains(&s.ext_fraction), "{k:?}");
            assert!((0.0..=1.0).contains(&s.store_fraction));
            assert!((0.0..=1.0).contains(&s.seq_locality));
            assert!((0.0..=1.0).contains(&s.dep_fraction));
            assert!(s.compute_per_access > 0);
        }
    }
}
