//! GUPS (HPC Challenge RandomAccess): uniformly random read-modify-write
//! updates over a giant table — the paper's worst case (Table 4: 100 % in
//! extended memory; Figure 13: 0.0003× under PCIe swapping).

use super::common::TraceBuf;
use super::params::WorkloadKind;
use super::DataRegions;
use crate::twinload::{LogicalOp, LogicalSource};

pub struct Gups {
    buf: TraceBuf,
    compute: u32,
}

impl Gups {
    pub fn new(data: DataRegions, ops: u64, seed: u64) -> Gups {
        Gups {
            buf: TraceBuf::new(data, ops, seed),
            compute: WorkloadKind::Gups.signature().compute_per_access,
        }
    }
}

impl LogicalSource for Gups {
    fn next_logical(&mut self) -> Option<LogicalOp> {
        loop {
            if let Some(op) = self.buf.pop() {
                return Some(op);
            }
            if self.buf.exhausted() {
                return None;
            }
            // for i in ...: table[rand()] ^= rand_value
            let addr = self.buf.ext_random();
            self.buf.compute(self.compute);
            let ld = self.buf.mem(addr, false, None);
            self.buf.compute(2); // the xor
            self.buf.mem(addr, true, Some(ld));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::testutil::{characterize, small_regions};

    #[test]
    fn pure_random_rmw_all_extended() {
        let data = small_regions(&WorkloadKind::Gups.signature());
        let (mem, ext, stores, _) = characterize(Box::new(Gups::new(data, 10_000, 3)));
        assert_eq!(mem, ext, "GUPS is 100% extended");
        // RMW: half the accesses are stores.
        let sf = stores as f64 / mem as f64;
        assert!((sf - 0.5).abs() < 0.01, "store fraction {sf}");
    }

    #[test]
    fn addresses_spread_widely() {
        let data = small_regions(&WorkloadKind::Gups.signature());
        let mut g = Gups::new(data, 4_000, 3);
        let mut lines = std::collections::HashSet::new();
        while let Some(op) = g.next_logical() {
            if let LogicalOp::Mem(m) = op {
                lines.insert(m.vaddr);
            }
        }
        // RMW pairs share addresses; distinct lines ≈ mem/2, far beyond
        // any cache-friendly hot set.
        assert!(lines.len() > 500, "only {} distinct lines", lines.len());
    }
}
