//! Shared trace-building machinery for the workload generators.

use super::DataRegions;
use crate::twinload::{LogicalMem, LogicalOp};
use crate::util::Rng;
use std::collections::VecDeque;

/// Buffered logical-op builder. Tracks the same logical-index numbering
/// the protocol transform will assign (one index per `Mem` op, in order),
/// so generators can express value dependencies (`dep_on`) correctly.
#[derive(Debug)]
pub struct TraceBuf {
    pub rng: Rng,
    pub data: DataRegions,
    pending: VecDeque<LogicalOp>,
    emitted: u64,
    budget: u64,
    mem_count: u64,
    seq_cursor: u64,
    /// Sub-line stepping for element-granularity streams.
    seq_subline: u32,
    accesses_per_line: u32,
    /// Most recent mem op (for value-dependence chains).
    last_mem: Option<u64>,
}

impl TraceBuf {
    /// Seed-mixing constant: decorrelates workload streams from other
    /// consumers of the same master seed.
    const SEED_MIX: u64 = 0x5A5A_5A5A_F00D_CAFE;

    pub fn new(data: DataRegions, ops_budget: u64, seed: u64) -> TraceBuf {
        let mut rng = Rng::new(seed ^ Self::SEED_MIX);
        // Start sequential cursors at a random offset so cores don't
        // convoy on the same lines.
        let seq_cursor = rng.next_u64() % (data.ext_len / 64);
        TraceBuf {
            rng,
            data,
            pending: VecDeque::with_capacity(16),
            emitted: 0,
            budget: ops_budget,
            mem_count: 0,
            seq_cursor,
            seq_subline: 0,
            accesses_per_line: 1,
            last_mem: None,
        }
    }

    /// Enable element-granularity streaming (see SignatureParams).
    pub fn set_accesses_per_line(&mut self, k: u32) {
        self.accesses_per_line = k.max(1);
    }

    /// With probability `p`, chain this access's address on the most
    /// recent memory op's value (pointer-dependence).
    pub fn chain(&mut self, p: f64) -> Option<u64> {
        if self.rng.chance(p) {
            self.last_mem
        } else {
            None
        }
    }

    /// Ops still owed (generators stop iterating when this hits zero).
    pub fn exhausted(&self) -> bool {
        self.emitted >= self.budget
    }

    pub fn pop(&mut self) -> Option<LogicalOp> {
        self.pending.pop_front()
    }

    /// True between requests: every op of the last generated request has
    /// been popped (request-boundary detection for open-loop serving).
    pub fn pending_empty(&self) -> bool {
        self.pending.is_empty()
    }

    pub fn compute(&mut self, n: u32) {
        self.emitted += 1;
        self.pending.push_back(LogicalOp::Compute(n));
    }

    /// Emit a memory op; returns its logical index for later `dep_on`s.
    pub fn mem(&mut self, vaddr: u64, is_store: bool, dep_on: Option<u64>) -> u64 {
        let idx = self.mem_count;
        self.mem_count += 1;
        self.emitted += 1;
        self.last_mem = Some(idx);
        self.pending
            .push_back(LogicalOp::Mem(LogicalMem { vaddr, is_store, dep_on }));
        idx
    }

    /// Random line in the extended object.
    pub fn ext_random(&mut self) -> u64 {
        let r = self.rng.next_u64();
        self.data.ext_line(r)
    }

    /// Random line within the hot subset (first `hot` lines of ext).
    pub fn ext_hot(&mut self, hot_lines: u64) -> u64 {
        let lines = (self.data.ext_len / 64).min(hot_lines.max(1));
        let r = self.rng.below(lines);
        self.data.ext_base + r * 64
    }

    /// Next sequential access in the extended object (wrapping stream):
    /// the line advances only every `accesses_per_line` calls, modeling
    /// element-granularity scans.
    pub fn ext_next_seq(&mut self) -> u64 {
        let a = self.data.ext_seq(self.seq_cursor);
        self.seq_subline += 1;
        if self.seq_subline >= self.accesses_per_line {
            self.seq_subline = 0;
            self.seq_cursor = self.seq_cursor.wrapping_add(1);
        }
        a
    }

    /// Jump the sequential cursor to a random position (new run).
    pub fn reseek(&mut self) {
        self.seq_cursor = self.rng.next_u64() % (self.data.ext_len / 64);
        self.seq_subline = 0;
    }

    /// Random line in the local object.
    pub fn local_random(&mut self) -> u64 {
        let r = self.rng.next_u64();
        self.data.local_line(r)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::params::WorkloadKind;
    use crate::workloads::testutil::small_regions;

    #[test]
    fn logical_indices_count_mem_ops_only() {
        let data = small_regions(&WorkloadKind::Gups.signature());
        let mut t = TraceBuf::new(data, 100, 1);
        t.compute(5);
        let i0 = t.mem(data.ext_base, false, None);
        t.compute(2);
        let i1 = t.mem(data.ext_base + 64, false, Some(i0));
        assert_eq!(i0, 0);
        assert_eq!(i1, 1);
    }

    #[test]
    fn budget_counts_all_ops() {
        let data = small_regions(&WorkloadKind::Gups.signature());
        let mut t = TraceBuf::new(data, 3, 1);
        t.compute(1);
        t.mem(data.ext_base, false, None);
        assert!(!t.exhausted());
        t.compute(1);
        assert!(t.exhausted());
    }

    #[test]
    fn addresses_in_bounds() {
        let data = small_regions(&WorkloadKind::Gups.signature());
        let mut t = TraceBuf::new(data, 1000, 9);
        for _ in 0..1000 {
            let a = t.ext_random();
            assert!(a >= data.ext_base && a < data.ext_base + data.ext_len);
            let h = t.ext_hot(128);
            assert!(h >= data.ext_base && h < data.ext_base + 128 * 64);
            let l = t.local_random();
            assert!(l >= data.local_base && l < data.local_base + data.local_len);
            let s = t.ext_next_seq();
            assert!(s >= data.ext_base && s < data.ext_base + data.ext_len);
        }
    }

    #[test]
    fn seq_cursor_advances_linewise() {
        let data = small_regions(&WorkloadKind::Gups.signature());
        let mut t = TraceBuf::new(data, 10, 2);
        let a = t.ext_next_seq();
        let b = t.ext_next_seq();
        // wraps at the region end; otherwise adjacent
        assert!(b == a + 64 || b == data.ext_base);
    }
}
