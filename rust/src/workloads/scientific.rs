//! Scientific-computing workloads: CG (NPB conjugate gradient) and FMM
//! (PARSEC N-body).

use super::common::TraceBuf;
use super::params::{SignatureParams, WorkloadKind};
use super::DataRegions;
use crate::twinload::{LogicalOp, LogicalSource};

/// CG: sparse matrix-vector products — streaming reads of the matrix
/// (values + column indices) with gathers into the dense vector `x`.
/// Independent gathers → high intrinsic MLP; 99.43 % extended.
pub struct Cg {
    buf: TraceBuf,
    sig: SignatureParams,
}

impl Cg {
    pub fn new(data: DataRegions, ops: u64, seed: u64) -> Cg {
        let sig = WorkloadKind::Cg.signature();
        let mut buf = TraceBuf::new(data, ops, seed);
        buf.set_accesses_per_line(sig.accesses_per_line);
        Cg { buf, sig }
    }
}

impl LogicalSource for Cg {
    fn next_logical(&mut self) -> Option<LogicalOp> {
        loop {
            if let Some(op) = self.buf.pop() {
                return Some(op);
            }
            if self.buf.exhausted() {
                return None;
            }
            // One row segment: stream a[] (+ col idx) then gather x[col].
            let run = self.buf.rng.burst(self.sig.seq_locality, 8) * self.sig.accesses_per_line as u64;
            for _ in 0..run {
                let a = self.buf.ext_next_seq();
                self.buf.mem(a, false, None);
                self.buf.compute(self.sig.compute_per_access);
                // Gather: banded access — hot band with given probability.
                let x = if self.buf.rng.chance(self.sig.reuse_fraction) {
                    self.buf.ext_hot(self.sig.hot_lines)
                } else {
                    self.buf.ext_random()
                };
                // Index arrays resolve some gathers only after prior
                // loads complete (col idx loaded from memory).
                let dep = self.buf.chain(self.sig.dep_fraction);
                self.buf.mem(x, false, dep);
            }
            // Accumulate into y[i] (sequential, occasional store).
            if self.buf.rng.chance(self.sig.store_fraction * 2.0) {
                let y = self.buf.ext_next_seq();
                self.buf.mem(y, true, None);
            }
        }
    }
}

/// FMM: compute-dense particle interactions — long sequential sweeps
/// within a cluster, random jumps between clusters; 94.39 % extended.
pub struct Fmm {
    buf: TraceBuf,
    sig: SignatureParams,
}

impl Fmm {
    pub fn new(data: DataRegions, ops: u64, seed: u64) -> Fmm {
        let sig = WorkloadKind::Fmm.signature();
        let mut buf = TraceBuf::new(data, ops, seed);
        buf.set_accesses_per_line(sig.accesses_per_line);
        Fmm { buf, sig }
    }
}

impl LogicalSource for Fmm {
    fn next_logical(&mut self) -> Option<LogicalOp> {
        loop {
            if let Some(op) = self.buf.pop() {
                return Some(op);
            }
            if self.buf.exhausted() {
                return None;
            }
            // Jump to a cluster, sweep its particles.
            self.buf.reseek();
            let particles =
                self.buf.rng.burst(self.sig.seq_locality, 16) * self.sig.accesses_per_line as u64;
            for _ in 0..particles {
                let p = self.buf.ext_next_seq();
                let is_ext = !self.buf.rng.chance(1.0 - self.sig.ext_fraction);
                let addr = if is_ext { p } else { self.buf.local_random() };
                let dep = self.buf.chain(self.sig.dep_fraction);
                let ld = self.buf.mem(addr, false, dep);
                self.buf.compute(self.sig.compute_per_access);
                if self.buf.rng.chance(self.sig.store_fraction) {
                    self.buf.mem(addr, true, Some(ld)); // force update
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::testutil::{characterize, small_regions};

    #[test]
    fn cg_mostly_extended_few_stores() {
        let data = small_regions(&WorkloadKind::Cg.signature());
        let (mem, ext, stores, _) = characterize(Box::new(Cg::new(data, 20_000, 7)));
        assert!(ext as f64 / mem as f64 > 0.95);
        assert!((stores as f64 / mem as f64) < 0.15);
    }

    #[test]
    fn cg_gathers_mostly_independent() {
        // CG's MLP comes from mostly-independent gathers; only the
        // signature's dep_fraction of loads chain.
        let data = small_regions(&WorkloadKind::Cg.signature());
        let mut cg = Cg::new(data, 20_000, 7);
        let (mut dep, mut loads) = (0u64, 0u64);
        while let Some(op) = cg.next_logical() {
            if let LogicalOp::Mem(m) = op {
                if !m.is_store {
                    loads += 1;
                    dep += u64::from(m.dep_on.is_some());
                }
            }
        }
        let frac = dep as f64 / loads as f64;
        assert!(frac > 0.02 && frac < 0.4, "chain fraction {frac}");
    }

    #[test]
    fn fmm_is_compute_dense() {
        let data = small_regions(&WorkloadKind::Fmm.signature());
        let (mem, _, _, insts) = characterize(Box::new(Fmm::new(data, 20_000, 7)));
        let density = insts as f64 / mem as f64;
        assert!(density > 10.0, "insts/access = {density}");
    }

    #[test]
    fn fmm_has_local_component() {
        let data = small_regions(&WorkloadKind::Fmm.signature());
        let (mem, ext, _, _) = characterize(Box::new(Fmm::new(data, 30_000, 7)));
        let frac = ext as f64 / mem as f64;
        assert!(frac < 0.99 && frac > 0.85, "ext fraction {frac}");
    }
}
