//! The ten Table-4 benchmark workloads as statistical trace generators.
//!
//! Each generator reproduces the *memory-access signature* of its
//! benchmark — footprint split between local and extended space (the
//! Table-4 "Proportion in extended memory" column), spatial locality,
//! store ratio, pointer-chase dependency depth, and compute density —
//! which are the inputs that determine every figure in the paper's
//! evaluation (LLC/TLB MPKI, MLP, bandwidth, and therefore normalized
//! performance). See DESIGN.md's substitution table: we do not execute
//! the real programs; we generate dependency-annotated logical traces in
//! their image, exactly the methodology of the paper's own §7.2
//! trace-driven comparison.

pub mod arrival;
pub mod common;
pub mod graph;
pub mod gups;
pub mod memcached;
pub mod params;
pub mod radix;
pub mod scientific;
pub mod stream;

pub use params::{SignatureParams, WorkloadKind, ALL_WORKLOADS, FIG13_WORKLOADS};

use crate::memmgr::{Allocator, Space};
use crate::twinload::{LogicalOp, LogicalSource};

/// A concrete workload generator, enum-dispatched.
///
/// The simulator's per-micro-op pull path used to go through a
/// `Box<dyn LogicalSource>` virtual call; this enum devirtualizes it —
/// `next_logical` is a direct match over the concrete generators, which
/// the compiler can inline into the transform's lowering loop.
pub enum WorkloadSource {
    Gups(gups::Gups),
    Radix(radix::Radix),
    Cg(scientific::Cg),
    Fmm(scientific::Fmm),
    Graph(graph::GraphWalk),
    ScalParC(stream::ScalParC),
    StreamCluster(stream::StreamCluster),
    Memcached(memcached::Memcached),
}

impl LogicalSource for WorkloadSource {
    #[inline]
    fn next_logical(&mut self) -> Option<LogicalOp> {
        match self {
            WorkloadSource::Gups(s) => s.next_logical(),
            WorkloadSource::Radix(s) => s.next_logical(),
            WorkloadSource::Cg(s) => s.next_logical(),
            WorkloadSource::Fmm(s) => s.next_logical(),
            WorkloadSource::Graph(s) => s.next_logical(),
            WorkloadSource::ScalParC(s) => s.next_logical(),
            WorkloadSource::StreamCluster(s) => s.next_logical(),
            WorkloadSource::Memcached(s) => s.next_logical(),
        }
    }

    #[inline]
    fn at_request_boundary(&self) -> bool {
        match self {
            WorkloadSource::Gups(s) => s.at_request_boundary(),
            WorkloadSource::Radix(s) => s.at_request_boundary(),
            WorkloadSource::Cg(s) => s.at_request_boundary(),
            WorkloadSource::Fmm(s) => s.at_request_boundary(),
            WorkloadSource::Graph(s) => s.at_request_boundary(),
            WorkloadSource::ScalParC(s) => s.at_request_boundary(),
            WorkloadSource::StreamCluster(s) => s.at_request_boundary(),
            WorkloadSource::Memcached(s) => s.at_request_boundary(),
        }
    }
}

/// Build a generator for one core's share of the workload.
///
/// `alloc` places the shared data objects (call once per *system*, then
/// clone regions per core via the returned builder); `ops` is the number
/// of logical operations this core will emit; `seed` decorrelates cores.
pub fn build(
    kind: WorkloadKind,
    alloc: &mut Allocator,
    footprint: u64,
    ops: u64,
    seed: u64,
) -> Box<dyn LogicalSource + Send> {
    let sig = kind.signature();
    let data = DataRegions::place(alloc, footprint, &sig);
    build_with_regions(kind, data, ops, seed)
}

/// Build a devirtualized source with pre-placed regions (multi-core
/// setups share one placement). This is the simulator's entry point.
pub fn build_source(kind: WorkloadKind, data: DataRegions, ops: u64, seed: u64) -> WorkloadSource {
    build_source_with(kind, data, ops, seed, 0.9)
}

/// [`build_source`] with an explicit Zipf key-popularity skew
/// (`zipf_theta` serving knob). Only memcached consumes it today; every
/// other workload's stream is independent of `theta`, and `theta = 0.9`
/// reproduces [`build_source`] exactly.
pub fn build_source_with(
    kind: WorkloadKind,
    data: DataRegions,
    ops: u64,
    seed: u64,
    zipf_theta: f64,
) -> WorkloadSource {
    match kind {
        WorkloadKind::Gups => WorkloadSource::Gups(gups::Gups::new(data, ops, seed)),
        WorkloadKind::Radix => WorkloadSource::Radix(radix::Radix::new(data, ops, seed)),
        WorkloadKind::Cg => WorkloadSource::Cg(scientific::Cg::new(data, ops, seed)),
        WorkloadKind::Fmm => WorkloadSource::Fmm(scientific::Fmm::new(data, ops, seed)),
        WorkloadKind::Bfs => WorkloadSource::Graph(graph::GraphWalk::bfs(data, ops, seed)),
        WorkloadKind::Bc => WorkloadSource::Graph(graph::GraphWalk::bc(data, ops, seed)),
        WorkloadKind::PageRank => {
            WorkloadSource::Graph(graph::GraphWalk::pagerank(data, ops, seed))
        }
        WorkloadKind::ScalParC => WorkloadSource::ScalParC(stream::ScalParC::new(data, ops, seed)),
        WorkloadKind::StreamCluster => {
            WorkloadSource::StreamCluster(stream::StreamCluster::new(data, ops, seed))
        }
        WorkloadKind::Memcached => WorkloadSource::Memcached(memcached::Memcached::with_theta(
            data, ops, seed, zipf_theta,
        )),
    }
}

/// Boxed convenience wrapper for trait-object consumers (the PJRT fast
/// path, tests); identical streams to [`build_source`].
pub fn build_with_regions(
    kind: WorkloadKind,
    data: DataRegions,
    ops: u64,
    seed: u64,
) -> Box<dyn LogicalSource + Send> {
    Box::new(build_source(kind, data, ops, seed))
}

/// The shared data placement: one extended-space object (the big data)
/// and one local object (stack/metadata/indices), sized by the Table-4
/// extended proportion.
#[derive(Debug, Clone, Copy)]
pub struct DataRegions {
    pub ext_base: u64,
    pub ext_len: u64,
    pub local_base: u64,
    pub local_len: u64,
}

impl DataRegions {
    pub fn place(alloc: &mut Allocator, footprint: u64, sig: &SignatureParams) -> DataRegions {
        let ext_len = ((footprint as f64 * sig.ext_fraction) as u64).max(1 << 20);
        let local_len = (footprint - ext_len.min(footprint)).max(1 << 20);
        let ext = alloc
            .alloc(Space::Extended, ext_len)
            .expect("extended space exhausted — shrink the footprint");
        let local = alloc
            .alloc(Space::Local, local_len)
            .expect("local space exhausted — shrink the footprint");
        DataRegions {
            ext_base: ext.base,
            ext_len: ext.len,
            local_base: local.base,
            local_len: local.len,
        }
    }

    /// A random cache line in the extended object.
    #[inline]
    pub fn ext_line(&self, r: u64) -> u64 {
        self.ext_base + (r % (self.ext_len / 64)) * 64
    }

    /// A random cache line in the local object.
    #[inline]
    pub fn local_line(&self, r: u64) -> u64 {
        self.local_base + (r % (self.local_len / 64)) * 64
    }

    /// Sequential line `i` (wrapping) in the extended object.
    #[inline]
    pub fn ext_seq(&self, i: u64) -> u64 {
        self.ext_base + (i % (self.ext_len / 64)) * 64
    }
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use crate::memmgr::MemLayout;
    use crate::twinload::{LogicalOp, LogicalSource};

    pub fn small_regions(sig: &SignatureParams) -> DataRegions {
        let mut alloc = Allocator::new(MemLayout::new(32 << 20, 64 << 20), 1 << 20);
        DataRegions::place(&mut alloc, 16 << 20, sig)
    }

    /// Drain a source, asserting basic well-formedness; returns
    /// (mem_ops, ext_accesses, stores, insts).
    pub fn characterize(mut src: Box<dyn LogicalSource + Send>) -> (u64, u64, u64, u64) {
        let layout = MemLayout::new(32 << 20, 64 << 20);
        let (mut mem, mut ext, mut stores, mut insts) = (0u64, 0u64, 0u64, 0u64);
        while let Some(op) = src.next_logical() {
            insts += op.insts() as u64;
            if let LogicalOp::Mem(m) = op {
                mem += 1;
                assert_eq!(m.vaddr % 64, 0, "unaligned access");
                assert!(
                    layout.is_local(m.vaddr) || layout.is_extended(m.vaddr),
                    "address {:#x} outside data spaces",
                    m.vaddr
                );
                if layout.is_extended(m.vaddr) {
                    ext += 1;
                }
                if m.is_store {
                    stores += 1;
                }
            }
        }
        (mem, ext, stores, insts)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memmgr::MemLayout;

    #[test]
    fn every_workload_builds_and_terminates() {
        for &kind in ALL_WORKLOADS {
            let mut alloc = Allocator::new(MemLayout::new(32 << 20, 64 << 20), 1 << 20);
            let src = build(kind, &mut alloc, 16 << 20, 2_000, 7);
            let (mem, _ext, _stores, insts) = testutil::characterize(src);
            assert!(mem > 100, "{kind:?}: too few mem ops ({mem})");
            assert!(insts > mem, "{kind:?}: no compute between accesses");
        }
    }

    #[test]
    fn ext_fraction_tracks_table4() {
        // The generated access mix should land near the Table-4 extended
        // proportion for every workload (within 15 points: proportions in
        // the table are *data* fractions; access fractions track them).
        for &kind in ALL_WORKLOADS {
            let mut alloc = Allocator::new(MemLayout::new(32 << 20, 64 << 20), 1 << 20);
            let src = build(kind, &mut alloc, 16 << 20, 20_000, 11);
            let (mem, ext, _, _) = testutil::characterize(src);
            let frac = ext as f64 / mem as f64;
            let want = kind.signature().ext_fraction;
            assert!(
                (frac - want).abs() < 0.15,
                "{kind:?}: access ext fraction {frac:.2} vs table {want:.2}"
            );
        }
    }

    #[test]
    fn enum_source_matches_boxed_source() {
        // Devirtualization must be a pure representation change: the
        // enum-dispatched source and the boxed trait object emit the
        // exact same logical stream for every workload.
        use crate::twinload::LogicalOp;
        for &kind in ALL_WORKLOADS {
            let data = testutil::small_regions(&kind.signature());
            let mut a = build_source(kind, data, 600, 13);
            let mut b = build_with_regions(kind, data, 600, 13);
            loop {
                let (x, y) = (a.next_logical(), b.next_logical());
                match (x, y) {
                    (None, None) => break,
                    (Some(LogicalOp::Compute(m)), Some(LogicalOp::Compute(n))) => {
                        assert_eq!(m, n, "{kind:?}: compute diverged")
                    }
                    (Some(LogicalOp::Mem(m)), Some(LogicalOp::Mem(n))) => {
                        assert_eq!(
                            (m.vaddr, m.is_store, m.dep_on),
                            (n.vaddr, n.is_store, n.dep_on),
                            "{kind:?}: mem op diverged"
                        )
                    }
                    (x, y) => panic!("{kind:?}: stream shape diverged: {x:?} vs {y:?}"),
                }
            }
        }
    }

    #[test]
    fn seeds_decorrelate() {
        let sig = WorkloadKind::Gups.signature();
        let data = testutil::small_regions(&sig);
        let a = build_with_regions(WorkloadKind::Gups, data, 500, 1);
        let b = build_with_regions(WorkloadKind::Gups, data, 500, 2);
        let (_, _, _, ia) = testutil::characterize(a);
        let (_, _, _, ib) = testutil::characterize(b);
        // Same structure, but not byte-identical traces (checked via the
        // op count which matches and addresses which differ — proxied by
        // instruction totals being equal and a direct spot check below).
        assert_eq!(ia, ib);
        let mut a = build_with_regions(WorkloadKind::Gups, data, 500, 1);
        let mut b = build_with_regions(WorkloadKind::Gups, data, 500, 2);
        let mut diff = 0;
        for _ in 0..200 {
            match (a.next_logical(), b.next_logical()) {
                (
                    Some(crate::twinload::LogicalOp::Mem(x)),
                    Some(crate::twinload::LogicalOp::Mem(y)),
                ) => {
                    if x.vaddr != y.vaddr {
                        diff += 1;
                    }
                }
                _ => {}
            }
        }
        assert!(diff > 10, "seeds produced identical address streams");
    }
}
