//! Memcached-1.4.20 serving small objects via memslap (paper Table 4 /
//! §5): zipf-popular keys, hash-bucket chain walks (dependent loads),
//! mostly GETs, item data preallocated in one big slab (97.30 % extended).

use super::common::TraceBuf;
use super::params::{SignatureParams, WorkloadKind};
use super::DataRegions;
use crate::twinload::{LogicalOp, LogicalSource};

pub struct Memcached {
    buf: TraceBuf,
    sig: SignatureParams,
    items: u64,
    /// Zipf skew of key popularity (θ; 0 = uniform, → 1 = heavily
    /// skewed). Table-4 default is 0.9; the open-loop serving knob
    /// `zipf_theta` overrides it.
    theta: f64,
}

impl Memcached {
    pub fn new(data: DataRegions, ops: u64, seed: u64) -> Memcached {
        Memcached::with_theta(data, ops, seed, 0.9)
    }

    /// Like [`new`](Memcached::new) with an explicit key-popularity skew.
    pub fn with_theta(data: DataRegions, ops: u64, seed: u64, theta: f64) -> Memcached {
        let items = (data.ext_len / 64 / 2).max(1);
        Memcached {
            buf: TraceBuf::new(data, ops, seed),
            sig: WorkloadKind::Memcached.signature(),
            items,
            theta,
        }
    }

    /// One request: hash table bucket (hot) → item chain (dependent,
    /// zipf-popular) → value lines; SETs additionally write the item.
    fn request(&mut self) {
        let sig = self.sig;
        let b = &mut self.buf;
        // Protocol parsing / hashing compute.
        b.compute(sig.compute_per_access);

        // Hash-bucket array access.
        let bucket = b.ext_hot(sig.hot_lines);
        let h = b.mem(bucket, false, None);

        // Zipf-popular item, reached by a dependent chain walk of 1–2.
        let zipf_line = b.rng.zipf(self.items, self.theta);
        let item = b.data.ext_base + zipf_line * 64;
        let chain1 = b.mem(item, false, Some(h));
        let item2 = if b.rng.chance(0.3) {
            // Collision chain: one more dependent hop.
            let next = b.ext_random();
            Some(b.mem(next, false, Some(chain1)))
        } else {
            None
        };
        b.compute(4); // key compare

        // Value read (next line of the item).
        let val_dep = item2.unwrap_or(chain1);
        let v = b.mem(item + 64, false, Some(val_dep));

        if b.rng.chance(sig.store_fraction) {
            // SET: write item header + value.
            b.mem(item, true, Some(v));
            b.mem(item + 64, true, Some(v));
        }
        // Response assembly.
        b.compute(sig.compute_per_access / 2);
    }
}

impl LogicalSource for Memcached {
    fn next_logical(&mut self) -> Option<LogicalOp> {
        loop {
            if let Some(op) = self.buf.pop() {
                return Some(op);
            }
            if self.buf.exhausted() {
                return None;
            }
            self.request();
        }
    }

    /// Between GET/SET requests: the last generated request's ops have
    /// all been popped.
    fn at_request_boundary(&self) -> bool {
        self.buf.pending_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::testutil::{characterize, small_regions};
    use std::collections::HashMap;

    #[test]
    fn mostly_reads_mostly_extended() {
        let data = small_regions(&WorkloadKind::Memcached.signature());
        let (mem, ext, stores, _) =
            characterize(Box::new(Memcached::new(data, 30_000, 13)));
        assert!(ext as f64 / mem as f64 > 0.9);
        assert!((stores as f64 / mem as f64) < 0.2);
    }

    #[test]
    fn popularity_is_skewed() {
        let data = small_regions(&WorkloadKind::Memcached.signature());
        let mut m = Memcached::new(data, 30_000, 13);
        let mut counts: HashMap<u64, u64> = HashMap::new();
        while let Some(op) = m.next_logical() {
            if let LogicalOp::Mem(a) = op {
                *counts.entry(a.vaddr).or_insert(0) += 1;
            }
        }
        let mut freqs: Vec<u64> = counts.values().copied().collect();
        freqs.sort_unstable_by(|a, b| b.cmp(a));
        let total: u64 = freqs.iter().sum();
        let top1pct: u64 = freqs.iter().take(freqs.len() / 100 + 1).sum();
        assert!(
            top1pct as f64 / total as f64 > 0.05,
            "no hot keys: top1% = {:.3}",
            top1pct as f64 / total as f64
        );
    }

    #[test]
    fn chain_walks_are_dependent() {
        let data = small_regions(&WorkloadKind::Memcached.signature());
        let mut m = Memcached::new(data, 10_000, 13);
        let (mut dep, mut loads) = (0u64, 0u64);
        while let Some(op) = m.next_logical() {
            if let LogicalOp::Mem(a) = op {
                if !a.is_store {
                    loads += 1;
                    dep += u64::from(a.dep_on.is_some());
                }
            }
        }
        assert!(dep as f64 / loads as f64 > 0.5, "chains not dependent");
    }
}
