//! Experiment coordinator: the L3 orchestration layer.
//!
//! * [`runner`] — parallel fan-out of (system, workload) simulation jobs
//!   across OS threads (no tokio in the vendored registry; std::thread
//!   scoped parallelism is all this needs).
//! * [`experiments`] — one entry point per paper table/figure; each runs
//!   the required simulations and renders the same rows/series the paper
//!   reports. The benches and the `twinload repro` subcommand are thin
//!   wrappers over these.
//! * [`fastpath`] — the PJRT-accelerated analytic timing model: trace
//!   chunks are batched through the AOT-compiled JAX/Pallas artifact for
//!   wide sweeps, cross-validated against the cycle-accurate simulator.

pub mod experiments;
pub mod fastpath;
pub mod runner;

pub use experiments::Scale;
pub use runner::{run_parallel, try_run_parallel, JobError};
