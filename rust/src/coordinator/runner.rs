//! Parallel simulation fan-out.

use crate::config::{RunSpec, SystemConfig};
use crate::sim::{run_spec, SimReport};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Run every (system, spec) job, work-stealing across `threads` OS
/// threads; results are returned in job order. Panics in workers are
/// propagated.
pub fn run_parallel(jobs: &[(SystemConfig, RunSpec)], threads: usize) -> Vec<SimReport> {
    let threads = threads.max(1).min(jobs.len().max(1));
    let next = AtomicUsize::new(0);
    let results: Mutex<Vec<Option<SimReport>>> = Mutex::new(vec![None; jobs.len()]);
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= jobs.len() {
                    break;
                }
                let (cfg, spec) = &jobs[i];
                let report = run_spec(cfg, spec);
                results.lock().unwrap()[i] = Some(report);
            });
        }
    });
    results
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|r| r.expect("job not completed"))
        .collect()
}

/// Default parallelism: physical cores minus one, at least one.
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get().saturating_sub(1)).unwrap_or(1).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::WorkloadKind;

    #[test]
    fn parallel_matches_serial() {
        let mut spec = RunSpec::smoke(WorkloadKind::Gups);
        spec.ops_per_core = 2_000;
        let mut cfg = SystemConfig::ideal();
        cfg.cores = 2;
        let jobs: Vec<(SystemConfig, RunSpec)> =
            (0..4).map(|_| (cfg.clone(), spec)).collect();
        let par = run_parallel(&jobs, 4);
        let serial: Vec<_> = jobs.iter().map(|(c, s)| crate::sim::run_spec(c, s)).collect();
        for (p, s) in par.iter().zip(&serial) {
            assert_eq!(p.finish, s.finish, "parallel result differs from serial");
            assert_eq!(p.retired_insts, s.retired_insts);
        }
    }

    #[test]
    #[should_panic(expected = "scoped thread panicked")]
    fn worker_panic_propagates() {
        // Documented behavior: a panic in any worker propagates out of
        // run_parallel (std::thread::scope re-panics after joining). An
        // invalid config makes Platform::build panic inside the worker.
        let mut cfg = SystemConfig::ideal();
        cfg.cores = 0;
        let spec = RunSpec::smoke(WorkloadKind::Gups);
        let _ = run_parallel(&[(cfg, spec)], 2);
    }

    #[test]
    fn thread_count_does_not_change_reports() {
        // Mixed-mechanism job list: 1 thread vs N must be bit-identical.
        let mut jobs = Vec::new();
        for name in ["ideal", "tl-ooo", "tl-lf", "numa", "pcie"] {
            let mut c = SystemConfig::by_name(name).unwrap();
            c.cores = 2;
            let mut s = RunSpec::smoke(WorkloadKind::Gups);
            s.ops_per_core = 1_500;
            jobs.push((c, s));
        }
        let serial = run_parallel(&jobs, 1);
        let fanned = run_parallel(&jobs, 4);
        for (a, b) in serial.iter().zip(&fanned) {
            assert_eq!(a.mechanism, b.mechanism);
            assert_eq!(a.finish, b.finish, "{} diverged", a.mechanism);
            assert_eq!(a.retired_insts, b.retired_insts, "{} diverged", a.mechanism);
            assert_eq!(a.llc_misses, b.llc_misses, "{} diverged", a.mechanism);
            assert_eq!(a.dram_reads, b.dram_reads, "{} diverged", a.mechanism);
        }
    }

    #[test]
    fn preserves_job_order() {
        let mut spec = RunSpec::smoke(WorkloadKind::Gups);
        spec.ops_per_core = 500;
        let mut jobs = Vec::new();
        for kind in [WorkloadKind::Gups, WorkloadKind::Cg, WorkloadKind::Bfs] {
            let mut s = spec;
            s.workload = kind;
            let mut c = SystemConfig::ideal();
            c.cores = 1;
            jobs.push((c, s));
        }
        let out = run_parallel(&jobs, 2);
        assert_eq!(out[0].workload, "gups");
        assert_eq!(out[1].workload, "cg");
        assert_eq!(out[2].workload, "bfs");
    }
}
