//! Parallel simulation fan-out.

use crate::config::{RunSpec, SystemConfig};
use crate::sim::{shard, try_run_spec, SimReport};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Mutex, MutexGuard, PoisonError};

/// One sweep job that could not produce a report: the typed error (or
/// captured panic message) plus enough identity to name the job in
/// sweep output.
#[derive(Debug, Clone)]
pub struct JobError {
    /// Index into the submitted job list.
    pub index: usize,
    pub mechanism: &'static str,
    pub workload: &'static str,
    pub message: String,
}

impl std::fmt::Display for JobError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "job {} ({}/{}): {}",
            self.index, self.mechanism, self.workload, self.message
        )
    }
}

/// Run every (system, spec) job, work-stealing across `threads` OS
/// threads; results are returned in job order. Each job's failure —
/// a rejected config or a panic inside the simulator — is captured as
/// a typed [`JobError`] instead of tearing down the whole sweep, so
/// one bad job cannot poison the shared result set (continue-on-error
/// mode for long sweeps).
pub fn try_run_parallel(
    jobs: &[(SystemConfig, RunSpec)],
    threads: usize,
) -> Vec<Result<SimReport, JobError>> {
    let threads = threads.max(1).min(jobs.len().max(1));
    // Thread-budget guard: with `threads` sweep workers each allowed to
    // open an intra-sim shard pool, the product must not oversubscribe
    // the host — lower every job's shard cap to the per-sim budget.
    let budget = shard::shard_budget(host_threads(), threads);
    let next = AtomicUsize::new(0);
    type Slot = Option<Result<SimReport, JobError>>;
    let results: Mutex<Vec<Slot>> = Mutex::new(vec![None; jobs.len()]);
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= jobs.len() {
                    break;
                }
                let (cfg, spec) = &jobs[i];
                let spec = capped_spec(spec, budget);
                // Workers never panic across the lock: build/run errors
                // become typed results, and any residual panic is caught
                // here. Should one slip through anyway (e.g. a panic in
                // a Drop while the slot is held), the write-back path
                // recovers the data instead of unwrapping the poison.
                let outcome = catch_unwind(AssertUnwindSafe(|| try_run_spec(cfg, &spec)))
                    .unwrap_or_else(|p| Err(anyhow::anyhow!("{}", panic_message(&p))))
                    .map_err(|e| JobError {
                        index: i,
                        mechanism: cfg.mechanism.name(),
                        workload: spec.workload.name(),
                        message: format!("{e:#}"),
                    });
                lock_slots(&results)[i] = Some(outcome);
            });
        }
    });
    let slots = results.into_inner().unwrap_or_else(PoisonError::into_inner);
    slots
        .into_iter()
        .enumerate()
        .map(|(i, slot)| finish_slot(i, jobs, slot))
        .collect()
}

/// Poison-recovering lock on the shared result slots: a mutex poisoned
/// by a worker that died mid-write still hands back the data (each slot
/// is a single `Option` assignment, so partially-written state is not a
/// concern — the slot is either the old value or the new one).
fn lock_slots<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Resolve one result slot. A vacant slot means the worker that claimed
/// job `i` terminated without writing back (it died outside the
/// `catch_unwind` envelope); that job failed, not the whole sweep, so
/// it becomes a typed [`JobError`] in its own slot.
fn finish_slot(
    i: usize,
    jobs: &[(SystemConfig, RunSpec)],
    slot: Option<Result<SimReport, JobError>>,
) -> Result<SimReport, JobError> {
    slot.unwrap_or_else(|| {
        let (cfg, spec) = &jobs[i];
        Err(JobError {
            index: i,
            mechanism: cfg.mechanism.name(),
            workload: spec.workload.name(),
            message: "worker terminated before completing job".to_string(),
        })
    })
}

/// A job spec with its shard cap lowered to the sweep's per-sim budget
/// (an explicitly tighter cap on the spec is kept — never raised).
fn capped_spec(spec: &RunSpec, budget: usize) -> RunSpec {
    let mut s = *spec;
    s.shard_cap = s.shard_cap.min(budget);
    s
}

/// Hardware threads available to the whole process (≥ 1).
fn host_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

fn panic_message(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "worker panicked".to_string()
    }
}

/// Run every job, propagating the first failure as a panic. Callers
/// whose job lists are static (the experiment tables) keep the simple
/// all-or-nothing contract; sweeps that want to survive bad jobs use
/// [`try_run_parallel`].
pub fn run_parallel(jobs: &[(SystemConfig, RunSpec)], threads: usize) -> Vec<SimReport> {
    try_run_parallel(jobs, threads)
        .into_iter()
        .map(|r| r.unwrap_or_else(|e| panic!("{e}")))
        .collect()
}

/// Default parallelism: physical cores minus one, at least one.
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get().saturating_sub(1)).unwrap_or(1).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::WorkloadKind;

    #[test]
    fn parallel_matches_serial() {
        let mut spec = RunSpec::smoke(WorkloadKind::Gups);
        spec.ops_per_core = 2_000;
        let mut cfg = SystemConfig::ideal();
        cfg.cores = 2;
        let jobs: Vec<(SystemConfig, RunSpec)> =
            (0..4).map(|_| (cfg.clone(), spec)).collect();
        let par = run_parallel(&jobs, 4);
        let serial: Vec<_> = jobs.iter().map(|(c, s)| crate::sim::run_spec(c, s)).collect();
        for (p, s) in par.iter().zip(&serial) {
            assert_eq!(p.finish, s.finish, "parallel result differs from serial");
            assert_eq!(p.retired_insts, s.retired_insts);
        }
    }

    #[test]
    #[should_panic(expected = "cores")]
    fn worker_panic_propagates() {
        // Documented behavior: run_parallel keeps the all-or-nothing
        // contract — the first failed job panics with its typed error
        // (which names the offending knob).
        let mut cfg = SystemConfig::ideal();
        cfg.cores = 0;
        let spec = RunSpec::smoke(WorkloadKind::Gups);
        let _ = run_parallel(&[(cfg, spec)], 2);
    }

    #[test]
    fn bad_job_does_not_poison_the_sweep() {
        // Continue-on-error: a rejected config yields a JobError in its
        // slot; every other job still completes, in order.
        let mut good = SystemConfig::ideal();
        good.cores = 1;
        let mut bad = SystemConfig::ideal();
        bad.cores = 0;
        let mut spec = RunSpec::smoke(WorkloadKind::Gups);
        spec.ops_per_core = 500;
        let jobs = vec![(good.clone(), spec), (bad, spec), (good, spec)];
        let out = try_run_parallel(&jobs, 2);
        assert!(out[0].is_ok() && out[2].is_ok(), "good jobs must survive");
        let err = out[1].as_ref().err().expect("bad job must fail");
        assert_eq!(err.index, 1);
        assert_eq!(err.mechanism, "ideal");
        assert!(err.message.contains("cores"), "untyped error: {}", err.message);
        assert_eq!(
            out[0].as_ref().unwrap().finish,
            out[2].as_ref().unwrap().finish,
            "surviving jobs must be unaffected by the failed one"
        );
    }

    #[test]
    fn worker_panics_are_captured_as_job_errors() {
        // A panic that is not a typed config error (here: forced via an
        // unvalidated internal inconsistency) still lands in its slot.
        let mut cfg = SystemConfig::amu();
        cfg.cores = 1;
        cfg.amu_depth = 0; // typed build error path through try_run_spec
        let spec = RunSpec::smoke(WorkloadKind::Gups);
        let out = try_run_parallel(&[(cfg, spec)], 1);
        let err = out[0].as_ref().err().expect("invalid amu depth must fail");
        assert!(err.message.contains("amu_depth"), "{}", err.message);
    }

    #[test]
    fn thread_count_does_not_change_reports() {
        // Mixed-mechanism job list: 1 thread vs N must be bit-identical.
        let mut jobs = Vec::new();
        for name in ["ideal", "tl-ooo", "tl-lf", "numa", "pcie"] {
            let mut c = SystemConfig::by_name(name).unwrap();
            c.cores = 2;
            let mut s = RunSpec::smoke(WorkloadKind::Gups);
            s.ops_per_core = 1_500;
            jobs.push((c, s));
        }
        let serial = run_parallel(&jobs, 1);
        let fanned = run_parallel(&jobs, 4);
        for (a, b) in serial.iter().zip(&fanned) {
            assert_eq!(a.mechanism, b.mechanism);
            assert_eq!(a.finish, b.finish, "{} diverged", a.mechanism);
            assert_eq!(a.retired_insts, b.retired_insts, "{} diverged", a.mechanism);
            assert_eq!(a.llc_misses, b.llc_misses, "{} diverged", a.mechanism);
            assert_eq!(a.dram_reads, b.dram_reads, "{} diverged", a.mechanism);
        }
    }

    #[test]
    fn vacant_slot_becomes_a_typed_job_error() {
        // Regression: a worker that dies without writing its slot back
        // (formerly `.expect("job not completed")`, a sweep-wide panic)
        // must surface as a JobError naming the job, not tear down the
        // collection of every other result.
        let mut cfg = SystemConfig::ideal();
        cfg.cores = 1;
        let spec = RunSpec::smoke(WorkloadKind::Gups);
        let jobs = vec![(cfg, spec)];
        let err = finish_slot(0, &jobs, None).err().expect("vacant slot must be an error");
        assert_eq!(err.index, 0);
        assert_eq!(err.mechanism, "ideal");
        assert_eq!(err.workload, "gups");
        assert!(err.message.contains("terminated"), "{}", err.message);
        // A filled slot passes through untouched.
        let ok = finish_slot(
            0,
            &jobs,
            Some(Err(JobError {
                index: 0,
                mechanism: "ideal",
                workload: "gups",
                message: "x".into(),
            })),
        );
        assert_eq!(ok.err().unwrap().message, "x");
    }

    #[test]
    fn poisoned_result_mutex_is_recovered_not_propagated() {
        // Regression for the `results.lock().unwrap()` panic path: a
        // mutex poisoned by one worker must still yield its data.
        let m = Mutex::new(vec![0usize; 2]);
        let _ = std::panic::catch_unwind(AssertUnwindSafe(|| {
            let _guard = m.lock().unwrap();
            panic!("poison the lock");
        }));
        assert!(m.is_poisoned());
        lock_slots(&m)[1] = 7;
        assert_eq!(lock_slots(&m)[1], 7);
    }

    #[test]
    fn sweep_caps_shard_fanout_within_the_thread_budget() {
        // The budget guard must hold `per-sim shards × sweep threads`
        // within the host budget, never raise an explicitly tighter
        // cap, and never push a cap below 1.
        let spec = RunSpec::smoke(WorkloadKind::Gups);
        assert_eq!(spec.shard_cap, usize::MAX, "default spec is host-bounded only");
        for host in 1..=32usize {
            for sweep in 1..=8usize {
                let budget = shard::shard_budget(host, sweep);
                let capped = capped_spec(&spec, budget);
                assert!(capped.shard_cap >= 1);
                if capped.shard_cap > 1 {
                    assert!(
                        capped.shard_cap * sweep <= host,
                        "host={host} sweep={sweep} cap={} oversubscribes",
                        capped.shard_cap
                    );
                }
            }
        }
        let mut tight = spec;
        tight.shard_cap = 2;
        assert_eq!(capped_spec(&tight, 8).shard_cap, 2, "tighter caps are kept");
        assert_eq!(capped_spec(&tight, 1).shard_cap, 1, "budget still wins when lower");
    }

    #[test]
    fn preserves_job_order() {
        let mut spec = RunSpec::smoke(WorkloadKind::Gups);
        spec.ops_per_core = 500;
        let mut jobs = Vec::new();
        for kind in [WorkloadKind::Gups, WorkloadKind::Cg, WorkloadKind::Bfs] {
            let mut s = spec;
            s.workload = kind;
            let mut c = SystemConfig::ideal();
            c.cores = 1;
            jobs.push((c, s));
        }
        let out = run_parallel(&jobs, 2);
        assert_eq!(out[0].workload, "gups");
        assert_eq!(out[1].workload, "cg");
        assert_eq!(out[2].workload, "bfs");
    }
}
