//! One entry point per paper table / figure (DESIGN.md §Experiment index).
//!
//! Each function runs the simulations it needs (fanned out via
//! [`super::runner`]) and renders the same rows/series the paper reports.
//! Absolute numbers differ from the paper's testbed (see EXPERIMENTS.md);
//! the *shape* — who wins, by what factor, where crossovers sit — is the
//! reproduction target.

use crate::cache::{DataKind, SetAssocCache};
use crate::config::{RunSpec, SystemConfig};
use crate::cost;
use crate::dram::address::AddressMapping;
use crate::dram::command::Command;
use crate::dram::timing::{Geometry, TimingParams};
use crate::mec::{Mec1, MecConfig, Topology};
use crate::sim::SimReport;
use crate::stats::table::{f2, f3, pct};
use crate::stats::{Summary, Table};
use crate::twinload::Mechanism;
use crate::util::time::{Ps, NS};
use crate::workloads::arrival::ArrivalKind;
use crate::workloads::{WorkloadKind, ALL_WORKLOADS, FIG13_WORKLOADS};
use anyhow::{anyhow, Result};

use super::runner::{default_threads, run_parallel, try_run_parallel};

/// Typed lookup of a named system preset: unknown names surface as
/// errors the caller reports, instead of `.unwrap()` panics mid-sweep.
fn preset(name: &str) -> Result<SystemConfig> {
    SystemConfig::by_name(name).ok_or_else(|| anyhow!("unknown system preset '{name}'"))
}

/// Experiment sizing.
#[derive(Debug, Clone, Copy)]
pub struct Scale {
    /// Logical ops per core per run.
    pub ops: u64,
    pub cores: usize,
    /// Medium / large footprints (paper: ~4 GB / ~16 GB, scaled 64×).
    pub medium: u64,
    pub large: u64,
    pub seed: u64,
    pub threads: usize,
    /// Quick mode: medium footprint only, fewer sweep points.
    pub quick: bool,
}

impl Scale {
    pub fn full() -> Scale {
        Scale {
            ops: 60_000,
            cores: 4,
            medium: 64 << 20,
            large: 192 << 20,
            seed: 42,
            threads: default_threads(),
            quick: false,
        }
    }

    pub fn quick() -> Scale {
        Scale { ops: 12_000, quick: true, ..Scale::full() }
    }

    fn spec(&self, wl: WorkloadKind, footprint: u64) -> RunSpec {
        let mut s = RunSpec::smoke(wl);
        s.footprint = footprint;
        s.ops_per_core = self.ops;
        s.seed = self.seed;
        s
    }

    fn cfg(&self, mut c: SystemConfig) -> SystemConfig {
        c.cores = self.cores;
        c
    }
}

// ---------------------------------------------------------------- Table 1

/// Table 1: DDRx timing parameters of the active preset.
pub fn table1() -> Table {
    let p = TimingParams::ddr3_1600();
    let mut t = Table::new(
        "Table 1: DDRx timing parameters (DDR3-1600 preset)",
        &["Parameter", "Description", "Value (ns)"],
    );
    let ns = |v: Ps| format!("{:.2}", v as f64 / 1000.0);
    t.row(&["tRL".into(), "RD command to first data".into(), ns(p.t_rl)]);
    t.row(&["tBURST".into(), "Data transfer duration".into(), ns(p.t_burst)]);
    t.row(&["tCCD".into(), "Min delay between RD commands".into(), ns(p.t_ccd)]);
    t.row(&["tRTP".into(), "Min RD to PRE".into(), ns(p.t_rtp)]);
    t.row(&["tRP".into(), "Min PRE to ACT".into(), ns(p.t_rp)]);
    t.row(&["tRCD".into(), "Min ACT to RD".into(), ns(p.t_rcd)]);
    t.row(&[
        "row-miss".into(),
        "tRTP+tRP+tRCD (twin spacing)".into(),
        ns(p.row_miss_turnaround()),
    ]);
    t
}

// ---------------------------------------------------------------- Table 2

/// Table 2: twin-load results with respect to cache state, reproduced by
/// driving MEC1 + a cache model through all four states.
pub fn table2() -> Table {
    let mut t = Table::new(
        "Table 2: Twin-load results per cache state",
        &["State", "v", "v'", "DRAM reads", "Result"],
    );
    for state in 1..=4u32 {
        let (v_cached, s_cached) = match state {
            1 => (false, false),
            2 => (true, true),
            3 => (true, false),
            _ => (false, true),
        };
        let obs = drive_state(v_cached, s_cached);
        t.row(&[
            state.to_string(),
            if v_cached { "in cache" } else { "not in cache" }.into(),
            if s_cached { "in cache" } else { "not in cache" }.into(),
            obs.dram_reads.to_string(),
            obs.result,
        ]);
    }
    t
}

struct StateObs {
    dram_reads: u32,
    result: String,
}

/// Drive one Table-2 scenario: place (or not) the twins in a cache with
/// given contents, then perform the twin-load and observe MEC traffic.
fn drive_state(v_cached: bool, shadow_cached: bool) -> StateObs {
    // A tiny host channel: 32 MiB ext + shadow.
    let geo = Geometry { ranks: 2, banks_per_rank: 8, rows_per_bank: 64, cols_per_row: 128 };
    let map = AddressMapping::new(&geo, 1);
    let host = TimingParams::ddr3_1600();
    let mut mec = Mec1::new(MecConfig::default_tl(), geo.capacity_bytes() / 2, map, &host);
    let mut cache = SetAssocCache::new(crate::cache::CacheConfig::l1d());

    let ext = 0x40u64;
    let shadow = map.twin(ext);
    // Pre-state: when cached, ext holds real and shadow holds fake
    // (the steady state after a completed twin-load — states 2 & 3), but
    // state 4 is "v not in cache, v' in cache": the paper's state 4 has
    // the *fake* value cached at v'.
    if v_cached {
        cache.fill(ext, false, DataKind::Real);
    }
    if shadow_cached {
        cache.fill(shadow, false, DataKind::Fake);
    }

    let mut dram_reads = 0;
    let mut results = Vec::new();
    let mut t: Ps = 100 * NS;
    for addr in [shadow, ext] {
        match cache.probe(addr) {
            Some(d) => results.push(d),
            None => {
                // Miss: the RD reaches MEC1 (ACT first, as the host
                // controller would issue).
                let d = map.decode(addr);
                mec.on_command(&Command::act(d.rank, d.bank, d.row, t));
                let out = mec
                    .on_command(&Command::rd(d.rank, d.bank, d.col, t + 14 * NS))
                    .expect("rd outcome");
                dram_reads += 1;
                results.push(out.data());
                cache.fill(addr, false, out.data());
                // The twin spacing before the second access.
                t += host.row_miss_turnaround() + 14 * NS;
            }
        }
    }
    let fmt = |d: &DataKind| match d {
        DataKind::Real => "v",
        DataKind::Fake => "v'",
    };
    StateObs {
        dram_reads,
        result: format!("{}, {}", fmt(&results[0]), fmt(&results[1])),
    }
}

// ---------------------------------------------------------------- Table 3

/// Table 3: the emulated systems.
pub fn table3() -> Result<Table> {
    let mut t = Table::new(
        "Table 3: Emulated systems (scaled 64x; see DESIGN.md)",
        &["System", "Local", "Extended", "Shadow", "Ext interface", "Mechanism"],
    );
    let mb = |b: u64| format!("{} MiB", b >> 20);
    for name in ["tl-ooo", "tl-lf", "numa", "pcie", "ideal"] {
        let c = preset(name)?;
        let l = c.layout;
        let (iface, shadow) = match c.mechanism {
            Mechanism::TlOoO | Mechanism::TlLf | Mechanism::TlLfBatched(_) => {
                ("DDRx+MEC", mb(l.ext_size))
            }
            Mechanism::Numa => ("QPI", "-".into()),
            Mechanism::Pcie => ("PCIe swap", "-".into()),
            _ => ("-", "-".into()),
        };
        t.row(&[
            name.into(),
            mb(l.local_size),
            mb(l.ext_size),
            shadow,
            iface.into(),
            c.mechanism.name().into(),
        ]);
    }
    Ok(t)
}

// ---------------------------------------------------------------- Table 4

/// Table 4: workloads + measured extended-memory access proportion.
pub fn table4(scale: &Scale) -> Table {
    let jobs: Vec<(SystemConfig, RunSpec)> = ALL_WORKLOADS
        .iter()
        .map(|&wl| (scale.cfg(SystemConfig::tl_ooo()), scale.spec(wl, scale.medium)))
        .collect();
    let reports = run_parallel(&jobs, scale.threads);
    let mut t = Table::new(
        "Table 4: Workloads (paper data proportion vs measured access proportion)",
        &["Benchmark", "Paper % ext (data)", "Measured % ext (accesses)"],
    );
    for (wl, r) in ALL_WORKLOADS.iter().zip(&reports) {
        t.row(&[
            wl.name().into(),
            pct(wl.signature().ext_fraction),
            pct(r.transform.ext_fraction()),
        ]);
    }
    t
}

// ---------------------------------------------------------------- Fig 7

/// Figure 7: normalized performance of TL-LF / TL-OoO / NUMA vs Ideal.
pub fn fig7(scale: &Scale) -> Table {
    let systems = [
        SystemConfig::ideal(),
        SystemConfig::tl_lf(),
        SystemConfig::tl_ooo(),
        SystemConfig::numa(),
    ];
    let footprints: Vec<(&str, u64)> = if scale.quick {
        vec![("medium", scale.medium)]
    } else {
        vec![("medium", scale.medium), ("large", scale.large)]
    };
    let mut t = Table::new(
        "Figure 7: Normalized performance (vs Ideal)",
        &["Workload", "Footprint", "TL-LF", "TL-OoO", "NUMA"],
    );
    let mut avgs = vec![Vec::new(); 3];
    for (fp_name, fp) in &footprints {
        let mut jobs = Vec::new();
        for &wl in ALL_WORKLOADS {
            for sys in &systems {
                jobs.push((scale.cfg(sys.clone()), scale.spec(wl, *fp)));
            }
        }
        let reports = run_parallel(&jobs, scale.threads);
        for (i, &wl) in ALL_WORKLOADS.iter().enumerate() {
            let base = &reports[i * systems.len()];
            let perf: Vec<f64> = (1..systems.len())
                .map(|s| reports[i * systems.len() + s].perf_vs(base))
                .collect();
            for (k, p) in perf.iter().enumerate() {
                avgs[k].push(*p);
            }
            t.row(&[
                wl.name().into(),
                (*fp_name).into(),
                f3(perf[0]),
                f3(perf[1]),
                f3(perf[2]),
            ]);
        }
    }
    t.row(&[
        "geomean".into(),
        "all".into(),
        f3(Summary::geomean(&avgs[0])),
        f3(Summary::geomean(&avgs[1])),
        f3(Summary::geomean(&avgs[2])),
    ]);
    t
}

// ------------------------------------------------- Fig 8–12 (one dataset)

/// Shared characterization runs for Figures 8–12.
pub struct CharData {
    pub workloads: Vec<WorkloadKind>,
    pub ideal: Vec<SimReport>,
    pub ooo: Vec<SimReport>,
    pub lf: Vec<SimReport>,
}

pub fn characterize(scale: &Scale) -> CharData {
    let mut jobs = Vec::new();
    for &wl in ALL_WORKLOADS {
        for sys in [SystemConfig::ideal(), SystemConfig::tl_ooo(), SystemConfig::tl_lf()] {
            jobs.push((scale.cfg(sys), scale.spec(wl, scale.medium)));
        }
    }
    let mut reports = run_parallel(&jobs, scale.threads).into_iter();
    let (mut ideal, mut ooo, mut lf) = (Vec::new(), Vec::new(), Vec::new());
    for _ in ALL_WORKLOADS {
        ideal.push(reports.next().unwrap());
        ooo.push(reports.next().unwrap());
        lf.push(reports.next().unwrap());
    }
    CharData { workloads: ALL_WORKLOADS.to_vec(), ideal, ooo, lf }
}

/// Figure 8: instruction count and IPC of TL-OoO relative to Ideal.
pub fn fig8(d: &CharData) -> Table {
    let mut t = Table::new(
        "Figure 8: TL-OoO instructions and IPC relative to Ideal",
        &["Workload", "Inst ratio", "IPC Ideal", "IPC TL-OoO", "IPC ratio"],
    );
    let mut ratios = Vec::new();
    for (i, wl) in d.workloads.iter().enumerate() {
        let ir = d.ooo[i].retired_insts as f64 / d.ideal[i].retired_insts.max(1) as f64;
        ratios.push(ir);
        t.row(&[
            wl.name().into(),
            f2(ir),
            f2(d.ideal[i].ipc()),
            f2(d.ooo[i].ipc()),
            f2(d.ooo[i].ipc() / d.ideal[i].ipc().max(1e-9)),
        ]);
    }
    t.row(&[
        "average".into(),
        f2(ratios.iter().sum::<f64>() / ratios.len() as f64),
        "-".into(),
        "-".into(),
        "-".into(),
    ]);
    t
}

/// Figure 9: LLC MPKI (TL-OoO normalized to Ideal instructions).
pub fn fig9(d: &CharData) -> Table {
    let mut t = Table::new(
        "Figure 9: LLC MPKI",
        &["Workload", "Ideal", "TL-OoO", "Miss increase"],
    );
    for (i, wl) in d.workloads.iter().enumerate() {
        let base = d.ideal[i].retired_insts;
        t.row(&[
            wl.name().into(),
            f2(d.ideal[i].llc_mpki(base)),
            f2(d.ooo[i].llc_mpki(base)),
            pct(d.ooo[i].llc_misses as f64 / d.ideal[i].llc_misses.max(1) as f64 - 1.0),
        ]);
    }
    t
}

/// Figure 10: TLB MPKI.
pub fn fig10(d: &CharData) -> Table {
    let mut t = Table::new(
        "Figure 10: TLB MPKI",
        &["Workload", "Ideal", "TL-OoO", "Miss increase"],
    );
    for (i, wl) in d.workloads.iter().enumerate() {
        let base = d.ideal[i].retired_insts;
        t.row(&[
            wl.name().into(),
            f2(d.ideal[i].tlb_mpki(base)),
            f2(d.ooo[i].tlb_mpki(base)),
            pct(d.ooo[i].tlb_misses as f64 / d.ideal[i].tlb_misses.max(1) as f64 - 1.0),
        ]);
    }
    t
}

/// Figure 11: average outstanding off-core reads.
pub fn fig11(d: &CharData) -> Table {
    let mut t = Table::new(
        "Figure 11: Outstanding off-core reads (mean)",
        &["Workload", "Ideal", "TL-OoO", "TL-LF"],
    );
    let (mut a, mut b, mut c) = (Vec::new(), Vec::new(), Vec::new());
    for (i, wl) in d.workloads.iter().enumerate() {
        a.push(d.ideal[i].mlp_mean);
        b.push(d.ooo[i].mlp_mean);
        c.push(d.lf[i].mlp_mean);
        t.row(&[
            wl.name().into(),
            f2(d.ideal[i].mlp_mean),
            f2(d.ooo[i].mlp_mean),
            f2(d.lf[i].mlp_mean),
        ]);
    }
    let avg = |v: &Vec<f64>| v.iter().sum::<f64>() / v.len() as f64;
    t.row(&["average".into(), f2(avg(&a)), f2(avg(&b)), f2(avg(&c))]);
    t
}

/// Figure 12: average DRAM read bandwidth, plus the data-bus
/// utilization the channels sustained (the `Channel` estimate
/// `SimReport` now surfaces — how close each system runs to the pin
/// bandwidth the non-scalable interface actually offers).
pub fn fig12(d: &CharData) -> Table {
    let mut t = Table::new(
        "Figure 12: Average read bandwidth (GB/s) and data-bus utilization",
        &["Workload", "Ideal", "TL-OoO", "TL-LF", "Bus util (TL-OoO)"],
    );
    for (i, wl) in d.workloads.iter().enumerate() {
        t.row(&[
            wl.name().into(),
            f2(d.ideal[i].read_bandwidth_gbps()),
            f2(d.ooo[i].read_bandwidth_gbps()),
            f2(d.lf[i].read_bandwidth_gbps()),
            pct(d.ooo[i].data_bus_util),
        ]);
    }
    t
}

// ---------------------------------------------------------------- Fig 13

/// Figure 13: PCIe page-swapping performance vs % of data in extended
/// memory (normalized to the non-swapping run; the paper's ×2 software
/// compensation applied — §6.3).
pub fn fig13(scale: &Scale) -> Table {
    let ext_fracs: &[f64] = if scale.quick { &[0.25, 0.90] } else { &[0.25, 0.50, 0.75, 0.90] };
    let mut header = vec!["Workload".to_string(), "0% (base)".to_string()];
    header.extend(ext_fracs.iter().map(|f| format!("{:.0}%", f * 100.0)));
    let hdr: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new("Figure 13: PCIe swapping, normalized performance", &hdr);

    let mut jobs = Vec::new();
    for &wl in FIG13_WORKLOADS {
        jobs.push((scale.cfg(SystemConfig::pcie(1.0)), scale.spec(wl, scale.medium)));
        for &f in ext_fracs {
            jobs.push((scale.cfg(SystemConfig::pcie(1.0 - f)), scale.spec(wl, scale.medium)));
        }
    }
    let reports = run_parallel(&jobs, scale.threads);
    let per_wl = 1 + ext_fracs.len();
    for (i, &wl) in FIG13_WORKLOADS.iter().enumerate() {
        let base = &reports[i * per_wl];
        let mut cells = vec![wl.name().to_string(), "1.000".to_string()];
        for k in 0..ext_fracs.len() {
            let r = &reports[i * per_wl + 1 + k];
            // ×2 compensation for the slow Linux swap path (paper §6.3).
            let perf = (r.perf_vs(base) * 2.0).min(1.0);
            cells.push(format!("{perf:.4}"));
        }
        t.row(&cells);
    }
    t
}

// ------------------------------------------------------- Table 5 / Fig 14

pub fn table5() -> Table {
    cost::table5()
}

pub fn fig14() -> Table {
    let mut t = Table::new(
        "Figure 14: Perf/$ normalized to TL-OoO vs parallel efficiency",
        &["Efficiency", "TL-OoO", "NUMA", "Cluster"],
    );
    for (eff, tl, numa, cluster) in cost::fig14_series(10) {
        t.row(&[f2(eff), f2(tl), f3(numa), f3(cluster)]);
    }
    t
}

// ---------------------------------------------------------------- Fig 15

/// Figure 15: TL vs increased tRL, sweeping the extra latency to
/// tolerate. TL systems tolerate extra propagation via deeper MEC trees
/// (hop delay = extra/2·layers); increased-tRL adds it to the read
/// latency and holds banks open.
pub fn fig15(scale: &Scale) -> Table {
    let deltas: &[Ps] = if scale.quick {
        &[0, 35 * NS, 105 * NS]
    } else {
        &[0, 35 * NS, 70 * NS, 105 * NS, 135 * NS]
    };
    let workloads: &[WorkloadKind] = &[
        WorkloadKind::Gups,
        WorkloadKind::Cg,
        WorkloadKind::Bfs,
        WorkloadKind::ScalParC,
    ];
    let mut t = Table::new(
        "Figure 15: TL vs increased tRL (normalized to inc-tRL at +0ns)",
        &["Extra (ns)", "inc-tRL", "TL-OoO", "TL-LF"],
    );

    // The paper's §7.2 comparison is trace-driven DRAMSim2 with
    // dependences only — no TLB modeling. Match that methodology by
    // giving every system full TLB coverage.
    let no_tlb = |mut c: SystemConfig| {
        c.tlb_entries = 1 << 20;
        c
    };
    let mut jobs = Vec::new();
    for &d in deltas {
        for &wl in workloads {
            jobs.push((
                scale.cfg(no_tlb(SystemConfig::increased_trl(d))),
                scale.spec(wl, scale.medium),
            ));
            let mut tl = SystemConfig::tl_ooo();
            tl.mec.topology = Topology {
                layers: 2,
                fanout: 4,
                hop_delay: (d / 4).max(2 * NS),
            };
            jobs.push((scale.cfg(no_tlb(tl)), scale.spec(wl, scale.medium)));
            let mut lf = SystemConfig::tl_lf();
            lf.mec.topology =
                Topology { layers: 2, fanout: 4, hop_delay: (d / 4).max(2 * NS) };
            jobs.push((scale.cfg(no_tlb(lf)), scale.spec(wl, scale.medium)));
        }
    }
    let reports = run_parallel(&jobs, scale.threads);
    let per_delta = workloads.len() * 3;
    // Baseline: inc-tRL at delta 0, averaged over workloads.
    let base: Vec<&SimReport> =
        (0..workloads.len()).map(|w| &reports[w * 3]).collect();
    for (di, &d) in deltas.iter().enumerate() {
        let mut cols = [Vec::new(), Vec::new(), Vec::new()];
        for w in 0..workloads.len() {
            let b = base[w];
            for s in 0..3 {
                let r = &reports[di * per_delta + w * 3 + s];
                cols[s].push(r.perf_vs(b));
            }
        }
        t.row(&[
            format!("{}", d / NS),
            f3(Summary::geomean(&cols[0])),
            f3(Summary::geomean(&cols[1])),
            f3(Summary::geomean(&cols[2])),
        ]);
    }
    t
}

// ------------------------------------------------------------- Ablations

/// LVC size sweep (paper §4.3: M > 10 suffices for TL-OoO; twins observed
/// ~6 loads apart).
pub fn ablate_lvc(scale: &Scale) -> Table {
    let sizes: &[usize] = if scale.quick { &[4, 16, 64] } else { &[2, 4, 8, 16, 32, 64] };
    let mut jobs = Vec::new();
    for &m in sizes {
        let mut c = SystemConfig::tl_ooo();
        c.mec.lvc_entries = m;
        jobs.push((scale.cfg(c), scale.spec(WorkloadKind::Gups, scale.medium)));
    }
    let reports = run_parallel(&jobs, scale.threads);
    let mut t = Table::new(
        "Ablation: LVC entries (M) — GUPS",
        &["M", "Runtime (us)", "Twin retries", "LVC evictions", "2nd-load real %"],
    );
    for (&m, r) in sizes.iter().zip(&reports) {
        let real_pct = r.mec_second_real as f64
            / (r.mec_second_real + r.mec_second_late).max(1) as f64;
        t.row(&[
            m.to_string(),
            f2(r.runtime_ns() / 1000.0),
            r.twin_retries.to_string(),
            r.lvc_evictions.to_string(),
            pct(real_pct),
        ]);
    }
    t
}

/// MEC layer-depth sweep: the latency-tolerance wall (§3.1: ~5 layers).
pub fn ablate_layers(scale: &Scale) -> Table {
    let layer_counts: &[u32] = if scale.quick { &[1, 3, 6] } else { &[1, 2, 3, 4, 5, 6, 8] };
    let mut jobs = Vec::new();
    for &l in layer_counts {
        let mut c = SystemConfig::tl_ooo();
        c.mec.topology = Topology { layers: l, fanout: 2, hop_delay: 3_400 };
        jobs.push((scale.cfg(c), scale.spec(WorkloadKind::Cg, scale.medium)));
    }
    let reports = run_parallel(&jobs, scale.threads);
    let mut t = Table::new(
        "Ablation: MEC layers (3.4ns hops) — CG",
        &["Layers", "RTT (ns)", "OoO tolerable", "Runtime (us)", "Twin retries"],
    );
    let host = TimingParams::ddr3_1600();
    for (&l, r) in layer_counts.iter().zip(&reports) {
        let topo = Topology { layers: l, fanout: 2, hop_delay: 3_400 };
        t.row(&[
            l.to_string(),
            format!("{:.1}", topo.round_trip() as f64 / 1000.0),
            topo.ooo_tolerable(&host, &host).to_string(),
            f2(r.runtime_ns() / 1000.0),
            r.twin_retries.to_string(),
        ]);
    }
    t
}

/// Batched TL-LF (§6.1 future work): batch size sweep.
pub fn ablate_batch(scale: &Scale) -> Table {
    let batches: &[u32] = if scale.quick { &[1, 8] } else { &[1, 2, 4, 8, 16, 32] };
    let mut jobs =
        vec![(scale.cfg(SystemConfig::tl_lf()), scale.spec(WorkloadKind::Cg, scale.medium))];
    for &k in batches {
        jobs.push((
            scale.cfg(SystemConfig::tl_lf_batched(k)),
            scale.spec(WorkloadKind::Cg, scale.medium),
        ));
    }
    let reports = run_parallel(&jobs, scale.threads);
    let mut t = Table::new(
        "Ablation: batched TL-LF (fence per k prefetches) — CG",
        &["Batch", "Runtime (us)", "Speedup vs TL-LF", "MLP", "Fences"],
    );
    let base = &reports[0];
    t.row(&[
        "tl-lf".into(),
        f2(base.runtime_ns() / 1000.0),
        "1.00".into(),
        f2(base.mlp_mean),
        base.fences.to_string(),
    ]);
    for (&k, r) in batches.iter().zip(&reports[1..]) {
        t.row(&[
            k.to_string(),
            f2(r.runtime_ns() / 1000.0),
            f2(r.perf_vs(base)),
            f2(r.mlp_mean),
            r.fences.to_string(),
        ]);
    }
    t
}

/// §8 outlook: heterogeneous leaves — DRAM vs SCM (PCM-like) behind the
/// same MEC tree. SCM's slower reads eat the TL-OoO row-miss window;
/// TL-LF tolerates them (the paper's argument for TL-LF's adaptability).
pub fn ablate_scm(scale: &Scale) -> Result<Table> {
    let mut t = Table::new(
        "Extension: DRAM vs SCM (PCM-like) leaf memory behind MECs",
        &["Mechanism", "Leaf", "Runtime (us)", "2nd-load real %", "Twin retries"],
    );
    let mut jobs = Vec::new();
    for mech in ["tl-ooo", "tl-lf"] {
        for scm in [false, true] {
            let mut c = preset(mech)?;
            c.emulate_content = false; // the effect is in MEC content timing
            if scm {
                c.mec.leaf_timing = TimingParams::scm_leaf();
            }
            jobs.push((scale.cfg(c), scale.spec(WorkloadKind::Cg, scale.medium)));
        }
    }
    let reports = run_parallel(&jobs, scale.threads);
    for (i, r) in reports.iter().enumerate() {
        let real = r.mec_second_real as f64
            / (r.mec_second_real + r.mec_second_late).max(1) as f64;
        t.row(&[
            if i < 2 { "TL-OoO" } else { "TL-LF" }.into(),
            if i % 2 == 0 { "DRAM" } else { "SCM" }.into(),
            f2(r.runtime_ns() / 1000.0),
            pct(real),
            r.twin_retries.to_string(),
        ]);
    }
    Ok(t)
}

/// AMU ablation: the asynchronous-access unit's bounded request-queue
/// depth × workloads (alongside the existing LVC/layer/batch sweeps).
/// MIMS-style message interfaces stand or fall on how many requests the
/// unit accepts before software has to back off: a shallow queue
/// serializes misses like TL-LF's fence does, a deep one recovers the
/// workload's intrinsic MLP at the cost of unit buffering.
pub fn ablate_amu(scale: &Scale) -> Table {
    let depths: &[usize] = if scale.quick { &[4, 32] } else { &[2, 8, 32, 128] };
    let workloads: &[WorkloadKind] =
        &[WorkloadKind::Gups, WorkloadKind::Cg, WorkloadKind::Memcached];
    let mut jobs = Vec::new();
    // Ideal anchors (one per workload) for normalized performance.
    for &wl in workloads {
        jobs.push((scale.cfg(SystemConfig::ideal()), scale.spec(wl, scale.medium)));
    }
    for &d in depths {
        for &wl in workloads {
            let mut c = SystemConfig::amu();
            c.amu_depth = d;
            jobs.push((scale.cfg(c), scale.spec(wl, scale.medium)));
        }
    }
    let reports = run_parallel(&jobs, scale.threads);
    let mut t = Table::new(
        "Ablation: AMU request-queue depth (normalized to Ideal)",
        &["Depth", "Workload", "Perf vs Ideal", "MLP", "Queue stalls", "Occ mean", "Occ peak"],
    );
    for (di, &d) in depths.iter().enumerate() {
        for (wi, &wl) in workloads.iter().enumerate() {
            let base = &reports[wi];
            let r = &reports[workloads.len() + di * workloads.len() + wi];
            t.row(&[
                d.to_string(),
                wl.name().into(),
                f3(r.perf_vs(base)),
                f2(r.mlp_mean),
                r.amu_queue_stalls.to_string(),
                f2(r.amu_occ_mean),
                r.amu_occ_peak.to_string(),
            ]);
        }
    }
    t
}

/// MIMS ablation: message packing factor × pointer-chasing workload,
/// against the unpacked twin-load baseline (`tl-lf` — the exact stream
/// `mims` degenerates to at pack 1). The interesting column is
/// `data_bus_util`: packing amortizes the prefetch/fence round trip, so
/// the same bytes move across a less idle bus. Failed jobs surface as
/// FAILED rows (continue-on-error), mirroring [`ablate_faults`].
pub fn ablate_mims(scale: &Scale) -> Result<Table> {
    let packs: &[u32] = if scale.quick { &[1, 4] } else { &[1, 2, 4, 8, 16] };
    // The workloads whose effective bus utilization the paper's
    // synchronous interface serves worst: pure pointer-chasing RMW
    // (gups) and dependency-chained graph walks (bfs).
    let workloads: &[WorkloadKind] = &[WorkloadKind::Gups, WorkloadKind::Bfs];
    let mut jobs = Vec::new();
    // Unpacked twin-load anchors (one per workload).
    for &wl in workloads {
        jobs.push((scale.cfg(SystemConfig::tl_lf()), scale.spec(wl, scale.medium)));
    }
    for &k in packs {
        for &wl in workloads {
            jobs.push((scale.cfg(SystemConfig::mims_packed(k)), scale.spec(wl, scale.medium)));
        }
    }
    let outcomes = try_run_parallel(&jobs, scale.threads);
    let mut t = Table::new(
        "Ablation: MIMS message packing factor (vs unpacked TL-LF)",
        &[
            "Pack",
            "Workload",
            "Perf vs TL-LF",
            "Bus util (%)",
            "TL-LF bus (%)",
            "Fences",
            "Messages",
            "Pack mean",
        ],
    );
    for (ki, &k) in packs.iter().enumerate() {
        for (wi, &wl) in workloads.iter().enumerate() {
            let base = outcomes[wi].as_ref().ok();
            match &outcomes[workloads.len() + ki * workloads.len() + wi] {
                Ok(r) => t.row(&[
                    k.to_string(),
                    wl.name().into(),
                    base.map(|b| f3(r.perf_vs(b))).unwrap_or_else(|| "-".into()),
                    f2(r.data_bus_util * 100.0),
                    base.map(|b| f2(b.data_bus_util * 100.0)).unwrap_or_else(|| "-".into()),
                    r.transform.fences.to_string(),
                    r.mims_messages.to_string(),
                    f2(r.mims_pack_mean),
                ]),
                Err(e) => t.row(&[
                    k.to_string(),
                    wl.name().into(),
                    format!("FAILED: {}", e.message),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                ]),
            }
        }
    }
    Ok(t)
}

/// Robustness ablation: deterministic fault rate × mechanism swept into
/// degradation curves. Each mechanism exercises its own fault class
/// (not-ready responses + MEC fill faults for the twin systems, lost
/// completion notifies for the AMU, DMA transfer failures for PCIe; ECC
/// bit errors everywhere on the extension path) and its own recovery
/// machinery — §4.4 retries, `demote_after` safe-path demotion, the
/// poll-timeout/reissue loop. Rows are normalized to the mechanism's own
/// fault-free run. Failed jobs surface as FAILED rows instead of killing
/// the sweep (continue-on-error).
pub fn ablate_faults(scale: &Scale) -> Result<Table> {
    let rates: &[f64] = if scale.quick { &[0.0, 0.05] } else { &[0.0, 0.01, 0.05, 0.2] };
    let mechs = ["tl-ooo", "tl-lf", "amu", "pcie"];
    let mut jobs = Vec::new();
    for mech in mechs {
        for &rate in rates {
            let base = preset(mech)?;
            // The fault-free anchor is the untouched preset (the
            // `faulted` builder also arms demotion, which must not
            // perturb the baseline).
            let c = if rate > 0.0 { base.faulted(rate) } else { base };
            jobs.push((scale.cfg(c), scale.spec(WorkloadKind::Gups, scale.medium)));
        }
    }
    let outcomes = try_run_parallel(&jobs, scale.threads);
    let mut t = Table::new(
        "Ablation: fault injection — degradation curves (GUPS)",
        &[
            "Mechanism",
            "Fault rate",
            "Perf vs fault-free",
            "Faults",
            "Retries",
            "Demoted",
            "ECC corr",
            "Rec p99 (ns)",
        ],
    );
    for (mi, mech) in mechs.iter().enumerate() {
        let base = outcomes[mi * rates.len()].as_ref().ok();
        for (ri, &rate) in rates.iter().enumerate() {
            match &outcomes[mi * rates.len() + ri] {
                Ok(r) => {
                    let perf =
                        base.map(|b| f3(r.perf_vs(b))).unwrap_or_else(|| "-".into());
                    t.row(&[
                        (*mech).into(),
                        format!("{rate:.2}"),
                        perf,
                        r.faults_injected.to_string(),
                        r.twin_retries.to_string(),
                        r.demotions.to_string(),
                        r.ecc_corrected.to_string(),
                        format!("{:.0}", r.recovery_p99 as f64 / 1000.0),
                    ]);
                }
                Err(e) => t.row(&[
                    (*mech).into(),
                    format!("{rate:.2}"),
                    format!("FAILED: {}", e.message),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                ]),
            }
        }
    }
    Ok(t)
}

/// Robustness ablation: correlated-fault bursts × quarantine-driven
/// degraded mode. Each mechanism is swept over a burst-intensity ladder,
/// once with the online health detector off (`quarantine_threshold = 0`)
/// and once with it armed; rows report availability (the fraction of
/// extended accesses not degraded by a bad window, a fault, or demoted
/// service), performance retained vs the mechanism's burst-free anchor,
/// retry storms, and the detector's MTTD/MTTR/time-in-degraded numbers.
/// Availability is expected monotone non-increasing in burst intensity
/// for every mechanism; the quarantine-on column shows fewer retry
/// storms (whole-domain §4.5 demotion breaks the per-line streaks).
/// Failed jobs surface as FAILED rows (continue-on-error).
pub fn ablate_degrade(scale: &Scale) -> Result<Table> {
    let rates: &[f64] = if scale.quick { &[0.0, 0.4] } else { &[0.0, 0.1, 0.4] };
    let mechs = ["tl-ooo", "tl-lf", "amu", "pcie"];
    let quars = [false, true];
    let mut jobs = Vec::new();
    for mech in mechs {
        for &rate in rates {
            for &quar in &quars {
                let base = preset(mech)?;
                // The burst-free anchor stays the untouched preset (the
                // `bursty` builder also arms demotion, which must not
                // perturb the baseline); quarantine knobs on a burst-free
                // config are structurally inert, which the paired rate-0
                // rows demonstrate by matching exactly.
                let mut c = if rate > 0.0 { base.bursty(rate) } else { base };
                if quar {
                    c.quarantine_threshold = 0.5;
                    c.probe_ok = 4;
                }
                jobs.push((scale.cfg(c), scale.spec(WorkloadKind::Gups, scale.medium)));
            }
        }
    }
    let outcomes = try_run_parallel(&jobs, scale.threads);
    let mut t = Table::new(
        "Ablation: correlated fault bursts — availability & degraded mode (GUPS)",
        &[
            "Mechanism",
            "Burst rate",
            "Quarantine",
            "Availability",
            "Perf vs clean",
            "Storms",
            "Quar/Readm",
            "MTTD/MTTR (ns)",
            "Degraded (ns)",
        ],
    );
    let per_mech = rates.len() * quars.len();
    for (mi, mech) in mechs.iter().enumerate() {
        // Anchor: rate 0, quarantine off — the first job of the block.
        let base = outcomes[mi * per_mech].as_ref().ok();
        for (ri, &rate) in rates.iter().enumerate() {
            for (qi, &quar) in quars.iter().enumerate() {
                let quar_label = if quar { "on" } else { "off" };
                match &outcomes[mi * per_mech + ri * quars.len() + qi] {
                    Ok(r) => {
                        let perf =
                            base.map(|b| f3(r.perf_vs(b))).unwrap_or_else(|| "-".into());
                        t.row(&[
                            (*mech).into(),
                            format!("{rate:.2}"),
                            quar_label.into(),
                            format!("{:.4}", r.availability),
                            perf,
                            r.retry_storms.to_string(),
                            format!("{}/{}", r.quarantines, r.readmits),
                            format!("{:.0}/{:.0}", r.mttd_ns, r.mttr_ns),
                            format!("{:.0}", r.degraded_ns),
                        ]);
                    }
                    Err(e) => t.row(&[
                        (*mech).into(),
                        format!("{rate:.2}"),
                        quar_label.into(),
                        format!("FAILED: {}", e.message),
                        "-".into(),
                        "-".into(),
                        "-".into(),
                        "-".into(),
                        "-".into(),
                    ]),
                }
            }
        }
    }
    Ok(t)
}

// ---------------------------------------------------------------- Serving

/// Open-loop latency-throughput sweep: Poisson arrivals at a fixed
/// system-wide offered load per row, memcached requests with Zipfian
/// key popularity, one row block per extension mechanism. The "knee"
/// row reports the highest offered load each mechanism sustained
/// (achieved ≥ 95 % of offered) — the paper's scalability argument
/// restated as max-sustainable throughput instead of closed-loop
/// runtime. The "slo-knee" row tightens that to the highest load in the
/// contiguous prefix that also kept p99 end-to-end latency within
/// `slo_p99_us` (CLI `--slo-p99-us`, INI `slo_p99_us`) — sustained
/// throughput alone hides latency collapse near saturation. Failed jobs
/// surface as FAILED rows (continue-on-error), mirroring
/// [`ablate_faults`].
pub fn serve(scale: &Scale, slo_p99_us: u64, sampled: bool) -> Result<Table> {
    // One memcached request lowers to ~8 logical ops, so a geometric
    // ladder from 0.5M to 32M req/s spans clearly-under-loaded to
    // clearly-saturated for every mechanism at these core counts.
    let offered: &[u64] = if scale.quick {
        &[500_000, 4_000_000, 32_000_000]
    } else {
        &[500_000, 1_000_000, 2_000_000, 4_000_000, 8_000_000, 16_000_000, 32_000_000]
    };
    let mechs = ["ideal", "tl-ooo", "tl-lf", "numa", "pcie", "amu"];
    let mut jobs = Vec::new();
    for mech in mechs {
        for &rps in offered {
            let c = preset(mech)?;
            let mut spec = scale
                .spec(WorkloadKind::Memcached, scale.medium)
                .open_loop(ArrivalKind::Poisson, rps);
            if sampled {
                // SMARTS cadence: 1/16 of ops in a detailed window, the
                // same fraction warming up, the rest fast-forwarded.
                spec = spec.sampled(1024, 64, 64);
            }
            jobs.push((scale.cfg(c), spec));
        }
    }
    let outcomes = try_run_parallel(&jobs, scale.threads);
    let mut t = Table::new(
        "Serving: open-loop latency-throughput (memcached, Poisson arrivals)",
        &[
            "Mechanism",
            "Offered (kreq/s)",
            "Achieved (kreq/s)",
            "p50 (ns)",
            "p99 (ns)",
            "p99.9 (ns)",
            "Drops",
            "Queue peak",
        ],
    );
    for (mi, mech) in mechs.iter().enumerate() {
        let mut achieved_col: Vec<Option<f64>> = Vec::with_capacity(offered.len());
        let mut p99_col: Vec<Option<u64>> = Vec::with_capacity(offered.len());
        for (ri, &rps) in offered.iter().enumerate() {
            match &outcomes[mi * offered.len() + ri] {
                Ok(r) => {
                    let achieved =
                        r.served_requests as f64 * 1e9 / r.runtime_ns().max(1e-9);
                    achieved_col.push(Some(achieved));
                    p99_col.push(Some(r.req_p99_ns));
                    t.row(&[
                        (*mech).into(),
                        krps(rps),
                        f2(achieved / 1e3),
                        r.req_p50_ns.to_string(),
                        r.req_p99_ns.to_string(),
                        r.req_p999_ns.to_string(),
                        r.dropped_requests.to_string(),
                        r.queue_peak.to_string(),
                    ]);
                }
                Err(e) => {
                    achieved_col.push(None);
                    p99_col.push(None);
                    t.row(&[
                        (*mech).into(),
                        krps(rps),
                        format!("FAILED: {}", e.message),
                        "-".into(),
                        "-".into(),
                        "-".into(),
                        "-".into(),
                        "-".into(),
                    ]);
                }
            }
        }
        t.row(&[
            (*mech).into(),
            "knee".into(),
            sustained_knee(offered, &achieved_col).map(krps).unwrap_or_else(|| "-".into()),
            "-".into(),
            "-".into(),
            "-".into(),
            "-".into(),
            "-".into(),
        ]);
        t.row(&[
            (*mech).into(),
            "slo-knee".into(),
            slo_knee(offered, &achieved_col, &p99_col, slo_p99_us * 1000)
                .map(krps)
                .unwrap_or_else(|| "-".into()),
            "-".into(),
            format!("p99<={slo_p99_us}us"),
            "-".into(),
            "-".into(),
            "-".into(),
        ]);
    }
    Ok(t)
}

/// The knee of a latency-throughput sweep: the highest offered load in
/// the *contiguous sustained prefix* of the ladder, where a point
/// sustains its load when it achieved ≥ 95 % of offered (`None` for a
/// failed job). The scan stops at the first unsustained point — a
/// post-collapse point that transiently clears 95 % again (achieved
/// throughput is not monotone in offered load once queues overflow)
/// must not overstate the knee.
fn sustained_knee(offered: &[u64], achieved: &[Option<f64>]) -> Option<u64> {
    let mut knee = None;
    for (&rps, a) in offered.iter().zip(achieved) {
        match a {
            Some(v) if *v >= 0.95 * rps as f64 => knee = Some(rps),
            _ => break,
        }
    }
    knee
}

/// The SLO knee: the highest offered load in the contiguous prefix that
/// both sustained its load (≥ 95 % of offered, as [`sustained_knee`])
/// *and* kept p99 end-to-end latency within `slo_ns`. Same
/// stop-at-first-violation semantics — a post-collapse point whose p99
/// transiently recovers (drops shed the queue) must not overstate the
/// SLO-respecting capacity.
fn slo_knee(
    offered: &[u64],
    achieved: &[Option<f64>],
    p99_ns: &[Option<u64>],
    slo_ns: u64,
) -> Option<u64> {
    let mut knee = None;
    for ((&rps, a), p) in offered.iter().zip(achieved).zip(p99_ns) {
        match (a, p) {
            (Some(v), Some(q)) if *v >= 0.95 * rps as f64 && *q <= slo_ns => {
                knee = Some(rps)
            }
            _ => break,
        }
    }
    knee
}

/// Render a req/s load in kreq/s, rounded to nearest (truncating
/// division printed a 1 999 600 req/s knee as "1999").
fn krps(rps: u64) -> String {
    ((rps + 500) / 1000).to_string()
}

/// Deviation-#1 ablation: the paper's host runs two SMT threads per
/// core. Statically-partitioned SMT (see `SystemConfig::smt`) shows the
/// Figure-7 ratios moving toward the paper as thread-level memory
/// parallelism returns — most visibly for fence-serialized TL-LF.
pub fn ablate_smt(scale: &Scale) -> Table {
    let workloads = [WorkloadKind::Gups, WorkloadKind::Cg, WorkloadKind::Bfs];
    let systems = [
        SystemConfig::ideal(),
        SystemConfig::tl_lf(),
        SystemConfig::tl_ooo(),
        SystemConfig::numa(),
    ];
    let mut t = Table::new(
        "Ablation: SMT threads per core (normalized to Ideal at same SMT)",
        &["SMT", "Workload", "TL-LF", "TL-OoO", "NUMA"],
    );
    for smt in [1usize, 2] {
        let mut jobs = Vec::new();
        for &wl in &workloads {
            for sys in &systems {
                let mut c = scale.cfg(sys.clone());
                c.smt = smt;
                jobs.push((c, scale.spec(wl, scale.medium)));
            }
        }
        let reports = run_parallel(&jobs, scale.threads);
        for (i, &wl) in workloads.iter().enumerate() {
            let base = &reports[i * systems.len()];
            t.row(&[
                smt.to_string(),
                wl.name().into(),
                f3(reports[i * systems.len() + 1].perf_vs(base)),
                f3(reports[i * systems.len() + 2].perf_vs(base)),
                f3(reports[i * systems.len() + 3].perf_vs(base)),
            ]);
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_matches_preset() {
        let t = table1();
        let s = t.render();
        assert!(s.contains("13.75"));
        assert!(s.contains("35.00"));
    }

    #[test]
    fn table2_reproduces_paper_states() {
        let t = table2();
        let csv = t.to_csv();
        let lines: Vec<&str> = csv.lines().collect();
        // State 1: two DRAM reads, one real one fake.
        assert!(lines[1].contains("2,"), "state 1: {}", lines[1]);
        assert!(lines[1].contains("v"), "{}", lines[1]);
        // State 2: zero DRAM reads.
        assert!(lines[2].contains(",0,"), "state 2: {}", lines[2]);
        // State 3: one DRAM read.
        assert!(lines[3].contains(",1,"), "state 3: {}", lines[3]);
        // State 4: one DRAM read, both fake (v', v').
        assert!(lines[4].contains(",1,"), "state 4: {}", lines[4]);
        assert!(lines[4].contains("v', v'"), "state 4: {}", lines[4]);
    }

    #[test]
    fn table3_lists_five_systems() {
        assert_eq!(table3().unwrap().num_rows(), 5);
    }

    #[test]
    fn unknown_preset_is_a_typed_error() {
        let err = preset("bogus");
        assert!(err.is_err());
        assert!(format!("{:#}", err.err().unwrap()).contains("bogus"));
    }

    #[test]
    fn ablate_faults_reports_degradation_without_failures() {
        // A tiny custom scale keeps this unit-test cheap: 4 mechanisms ×
        // 2 rates at 1.5k ops.
        let scale = Scale {
            ops: 1_500,
            cores: 2,
            medium: 16 << 20,
            large: 16 << 20,
            seed: 7,
            threads: 2,
            quick: true,
        };
        let t = ablate_faults(&scale).unwrap();
        assert_eq!(t.num_rows(), 4 * 2);
        let csv = t.to_csv();
        assert!(!csv.contains("FAILED"), "sweep had failed jobs:\n{csv}");
        // Every faulted twin-load row injects something.
        for mech in ["tl-ooo", "tl-lf"] {
            let row = csv
                .lines()
                .find(|l| l.starts_with(mech) && l.contains("0.05"))
                .unwrap_or_else(|| panic!("no faulted row for {mech}:\n{csv}"));
            let faults: u64 = row.split(',').nth(3).unwrap().parse().unwrap();
            assert!(faults > 0, "{mech} at rate 0.05 injected nothing: {row}");
        }
    }

    #[test]
    fn serve_sweep_reports_latency_throughput() {
        let scale = Scale {
            ops: 1_500,
            cores: 2,
            medium: 16 << 20,
            large: 16 << 20,
            seed: 7,
            threads: 2,
            quick: true,
        };
        let t = serve(&scale, 500, false).unwrap();
        // 6 mechanisms × (3 offered points + knee + slo-knee rows).
        assert_eq!(t.num_rows(), 6 * 5);
        let csv = t.to_csv();
        assert!(!csv.contains("FAILED"), "sweep had failed jobs:\n{csv}");
        // The lightly-loaded ideal run actually served requests and
        // measured a non-degenerate end-to-end latency.
        let row = csv
            .lines()
            .find(|l| l.starts_with("ideal,500,"))
            .unwrap_or_else(|| panic!("no ideal low-load row:\n{csv}"));
        let p50: u64 = row.split(',').nth(3).unwrap().parse().unwrap();
        assert!(p50 > 0, "zero p50 latency: {row}");
    }

    #[test]
    fn ablate_mims_packs_beat_the_unpacked_baseline() {
        let scale = Scale {
            ops: 1_500,
            cores: 2,
            medium: 16 << 20,
            large: 16 << 20,
            seed: 7,
            threads: 2,
            quick: true,
        };
        let t = ablate_mims(&scale).unwrap();
        // 2 packing factors × 2 workloads in quick mode.
        assert_eq!(t.num_rows(), 2 * 2);
        let csv = t.to_csv();
        assert!(!csv.contains("FAILED"), "sweep had failed jobs:\n{csv}");
        for wl in ["gups", "bfs"] {
            let row = csv
                .lines()
                .find(|l| l.starts_with(&format!("4,{wl},")))
                .unwrap_or_else(|| panic!("no pack-4 row for {wl}:\n{csv}"));
            let cols: Vec<&str> = row.split(',').collect();
            let packed: f64 = cols[3].parse().unwrap();
            let baseline: f64 = cols[4].parse().unwrap();
            assert!(
                packed > baseline,
                "{wl}: pack-4 bus util {packed}% not above the TL-LF baseline {baseline}%\n{csv}"
            );
            // Packing actually happened (messages carry > 1 txn on
            // average once stores stop flushing the batch).
            let pack_mean: f64 = cols[7].parse().unwrap();
            assert!(pack_mean > 1.0, "{wl}: pack mean {pack_mean} <= 1\n{csv}");
        }
    }

    #[test]
    fn slo_knee_stops_at_first_latency_violation() {
        let offered = [500_000u64, 1_000_000, 2_000_000, 4_000_000];
        let achieved =
            [Some(500_000.0), Some(990_000.0), Some(2_000_000.0), Some(4_000_000.0)];
        // Throughput sustains everywhere, but p99 blows past the SLO at
        // 2M: the plain knee says 4M, the SLO knee stops at 1M.
        let p99 = [Some(80_000u64), Some(120_000), Some(900_000), Some(150_000)];
        assert_eq!(sustained_knee(&offered, &achieved), Some(4_000_000));
        assert_eq!(slo_knee(&offered, &achieved, &p99, 500_000), Some(1_000_000));
        // A tight SLO no point meets: no knee.
        assert_eq!(slo_knee(&offered, &achieved, &p99, 10_000), None);
        // A loose SLO degenerates to the throughput knee.
        assert_eq!(slo_knee(&offered, &achieved, &p99, u64::MAX), Some(4_000_000));
        // Unsustained throughput still gates even when latency is fine.
        let sagging =
            [Some(500_000.0), Some(700_000.0), Some(2_000_000.0), Some(4_000_000.0)];
        assert_eq!(slo_knee(&offered, &sagging, &p99, 500_000), Some(500_000));
        // A failed job ends the prefix.
        let failed = [Some(80_000u64), None, Some(90_000), Some(90_000)];
        assert_eq!(slo_knee(&offered, &achieved, &failed, 500_000), Some(500_000));
    }

    #[test]
    fn degrade_sweep_quarantine_tames_burst_storms() {
        let scale = Scale {
            ops: 1_500,
            cores: 2,
            medium: 16 << 20,
            large: 16 << 20,
            seed: 7,
            threads: 2,
            quick: true,
        };
        let t = ablate_degrade(&scale).unwrap();
        // 4 mechanisms × 2 burst rates × quarantine {off, on}.
        assert_eq!(t.num_rows(), 4 * 2 * 2);
        let csv = t.to_csv();
        assert!(!csv.contains("FAILED"), "sweep had failed jobs:\n{csv}");
        let col = |row: &str, i: usize| row.split(',').nth(i).unwrap().to_string();
        for mech in ["tl-ooo", "tl-lf", "amu", "pcie"] {
            let find = |rate: &str, quar: &str| {
                csv.lines()
                    .find(|l| l.starts_with(&format!("{mech},{rate},{quar},")))
                    .unwrap_or_else(|| panic!("no {mech}/{rate}/{quar} row:\n{csv}"))
                    .to_string()
            };
            // Quarantine knobs without bursts are structurally inert:
            // the paired rate-0 rows match column-for-column.
            assert_eq!(
                find("0.00", "off").replace(",off,", ",_,"),
                find("0.00", "on").replace(",on,", ",_,"),
                "quarantine knobs perturbed a burst-free run"
            );
            // Availability is monotone non-increasing in burst intensity
            // (both with and without the detector).
            for quar in ["off", "on"] {
                let clean: f64 = col(&find("0.00", quar), 3).parse().unwrap();
                let bursty: f64 = col(&find("0.40", quar), 3).parse().unwrap();
                assert_eq!(clean, 1.0, "{mech} burst-free availability");
                assert!(
                    bursty <= clean,
                    "{mech}/{quar}: availability rose under bursts ({bursty} > {clean})"
                );
                assert!(
                    bursty < 1.0,
                    "{mech}/{quar}: bursts at rate 0.4 degraded nothing"
                );
            }
        }
        // The flagship claim on the twin mechanism: whole-domain demotion
        // measurably shortens retry storms, and the detector actually
        // fired.
        let row_off = csv
            .lines()
            .find(|l| l.starts_with("tl-ooo,0.40,off,"))
            .unwrap()
            .to_string();
        let row_on = csv
            .lines()
            .find(|l| l.starts_with("tl-ooo,0.40,on,"))
            .unwrap()
            .to_string();
        let storms_off: u64 = col(&row_off, 5).parse().unwrap();
        let storms_on: u64 = col(&row_on, 5).parse().unwrap();
        let quars: String = col(&row_on, 6);
        let fired: u64 = quars.split('/').next().unwrap().parse().unwrap();
        assert!(fired >= 1, "detector never quarantined under 0.4 bursts: {row_on}");
        assert!(
            storms_on <= storms_off,
            "quarantine did not tame retry storms: on={storms_on} off={storms_off}"
        );
        assert_eq!(
            col(&row_off, 6),
            "0/0",
            "threshold 0 must keep the detector disarmed: {row_off}"
        );
    }

    #[test]
    fn knee_stops_at_first_unsustained_point() {
        let offered = [500_000u64, 1_000_000, 2_000_000, 4_000_000];
        // Non-monotone achieved throughput: the 1M point collapses, the
        // 2M and 4M points transiently clear 95 % again. The old
        // max-over-all-sustained definition reported 4M; the knee is the
        // end of the contiguous sustained prefix: 500k.
        let achieved =
            [Some(499_000.0), Some(700_000.0), Some(1_990_000.0), Some(3_990_000.0)];
        assert_eq!(sustained_knee(&offered, &achieved), Some(500_000));
        // Fully sustained ladder: knee is the last point.
        let all = [Some(500_000.0), Some(990_000.0), Some(2_000_000.0), Some(4_000_000.0)];
        assert_eq!(sustained_knee(&offered, &all), Some(4_000_000));
        // First point already unsustained: no knee.
        let none = [Some(100_000.0), Some(990_000.0), None, None];
        assert_eq!(sustained_knee(&offered, &none), None);
        // A failed job ends the prefix even if later points sustain.
        let failed = [Some(500_000.0), None, Some(2_000_000.0), Some(4_000_000.0)];
        assert_eq!(sustained_knee(&offered, &failed), Some(500_000));
    }

    #[test]
    fn knee_render_rounds_to_nearest_krps() {
        // Truncating division printed 1_999_600 req/s as "1999".
        assert_eq!(krps(1_999_600), "2000");
        assert_eq!(krps(1_999_000), "1999");
        assert_eq!(krps(500), "1");
        assert_eq!(krps(4_000_000), "4000");
    }

    #[test]
    fn fig14_and_table5_available() {
        assert!(table5().render().contains("Total"));
        assert!(fig14().num_rows() == 11);
    }

    #[test]
    fn scale_presets() {
        assert!(Scale::quick().ops < Scale::full().ops);
        assert!(Scale::quick().quick);
    }
}
