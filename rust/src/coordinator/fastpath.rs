//! The PJRT fast path: batched analytic DRAM timing over trace chunks.
//!
//! Wide parameter sweeps don't need the full platform simulation — the
//! paper's own §7.2 comparison is trace-driven. The coordinator chunks a
//! workload's extended-memory access trace, ships it through the
//! AOT-compiled JAX/Pallas `trace_latency` artifact (see
//! `python/compile/model.py`), and post-processes the classification
//! counts under different timing parameters. The cycle-accurate Rust
//! simulator is the oracle this estimator is validated against
//! (`twinload validate`).

use crate::config::SystemConfig;
use crate::memmgr::Allocator;
use crate::runtime::{ArgValue, PjrtRuntime};
use crate::twinload::{Mechanism, Transform};
use crate::cpu::trace::{MicroOp, OpSource};
use crate::workloads::{self, WorkloadKind};
use anyhow::{anyhow, Result};

/// Chunk length compiled into the artifact (model.TRACE_CHUNK).
pub const CHUNK: usize = 16_384;
/// Bank count compiled into the kernel (bank_scan.NUM_BANKS).
pub const NUM_BANKS: i32 = 64;

/// Latency classes compiled into the artifact, in nanoseconds
/// (model.py LAT_*): keep in sync with python/compile/model.py.
pub const LAT_HIT_NS: i64 = 5;
pub const LAT_MISS_NS: i64 = 28;
pub const LAT_CONFLICT_NS: i64 = 49;

/// Classification counts for a trace.
#[derive(Debug, Clone, Copy, Default)]
pub struct TraceCounts {
    pub accesses: u64,
    pub hits: u64,
    pub conflicts: u64,
    /// Serial latency total at the compiled DDR3-1600 classes (ns).
    pub total_ns: u64,
}

impl TraceCounts {
    pub fn misses(&self) -> u64 {
        self.accesses - self.hits - self.conflicts
    }

    pub fn hit_rate(&self) -> f64 {
        if self.accesses == 0 {
            0.0
        } else {
            self.hits as f64 / self.accesses as f64
        }
    }

    /// Re-weight the classification under different latency classes —
    /// e.g. an increased-tRL system adds `delta` to every access and
    /// extends the bank-hold on conflicts (§7.2).
    pub fn estimate_ns(&self, hit: i64, miss: i64, conflict: i64) -> u64 {
        (self.hits as i64 * hit
            + self.misses() as i64 * miss
            + self.conflicts as i64 * conflict) as u64
    }
}

pub struct FastPath {
    rt: PjrtRuntime,
}

impl FastPath {
    /// Load the `trace_latency` artifact from `artifacts/`.
    pub fn new(artifacts_dir: &str) -> Result<FastPath> {
        let mut rt = PjrtRuntime::cpu()?;
        let path = std::path::Path::new(artifacts_dir).join("trace_latency.hlo.txt");
        if !path.exists() {
            return Err(anyhow!(
                "{} missing — run `make artifacts` first",
                path.display()
            ));
        }
        rt.load_hlo("trace_latency", &path)?;
        Ok(FastPath { rt })
    }

    /// Classify a trace (length truncated to whole chunks).
    pub fn classify(&self, bank: &[i32], row: &[i32]) -> Result<TraceCounts> {
        assert_eq!(bank.len(), row.len());
        let n = (bank.len() / CHUNK) * CHUNK;
        if n == 0 {
            return Err(anyhow!("trace shorter than one chunk ({CHUNK})"));
        }
        let mut counts = TraceCounts::default();
        for c in 0..n / CHUNK {
            let lo = c * CHUNK;
            let hi = lo + CHUNK;
            let outs = self.rt.execute(
                "trace_latency",
                &[
                    ArgValue::i32(bank[lo..hi].to_vec(), &[CHUNK as i64]),
                    ArgValue::i32(row[lo..hi].to_vec(), &[CHUNK as i64]),
                ],
            )?;
            counts.total_ns += outs[1].as_i32()?[0] as u64;
            counts.hits += outs[2].as_i32()?[0] as u64;
            counts.conflicts += outs[3].as_i32()?[0] as u64;
            counts.accesses += CHUNK as u64;
        }
        Ok(counts)
    }

    /// Figure-15-style analytic comparison on one trace: serial DRAM
    /// latency of twin-load (unchanged tRL, twins force conflicts —
    /// already in the trace when synthesized with a TL mechanism) vs a
    /// single-load system with tRL increased by `delta`.
    pub fn twin_vs_inc_trl(
        &self,
        twin_counts: &TraceCounts,
        single_counts: &TraceCounts,
        delta_ns: i64,
    ) -> (u64, u64) {
        let twin = twin_counts.total_ns;
        // Increased tRL: every access pays +delta; conflicts additionally
        // hold the bank until the (later) data transfer completes.
        let conflict = LAT_CONFLICT_NS + delta_ns + (delta_ns - LAT_HIT_NS).max(0);
        let single = single_counts.estimate_ns(
            LAT_HIT_NS + delta_ns,
            LAT_MISS_NS + delta_ns,
            conflict,
        );
        (twin, single)
    }
}

/// Synthesize `(bank, row)` streams of the extended-channel accesses a
/// workload generates under `mech` (whole chunks; deterministic by seed).
pub fn synthesize_trace(
    cfg: &SystemConfig,
    wl: WorkloadKind,
    mech: Mechanism,
    chunks: usize,
    seed: u64,
) -> (Vec<i32>, Vec<i32>) {
    let layout = cfg.layout;
    let mut alloc = Allocator::new(layout, 1 << 20);
    let sig = wl.signature();
    let data = workloads::DataRegions::place(&mut alloc, 16 << 20, &sig);
    // A generous op budget; we stop once enough ext accesses are seen.
    let want = chunks * CHUNK;
    let gen = workloads::build_with_regions(wl, data, u64::MAX / 2, seed);
    let mut transform = Transform::new(gen, mech, layout);
    let map = crate::dram::address::AddressMapping::new(&cfg.mec_channel_geometry(), 1);
    let (mut banks, mut rows) = (Vec::with_capacity(want), Vec::with_capacity(want));
    while banks.len() < want {
        match transform.next_op() {
            Some(MicroOp::Mem(m)) => {
                if m.vaddr >= layout.ext_base() {
                    let off = layout.ext_channel_offset(m.vaddr) % map.capacity();
                    let d = map.decode(off);
                    banks.push((d.flat_bank(map.banks_per_rank()) as i32) % NUM_BANKS);
                    rows.push(d.row as i32);
                }
            }
            Some(_) => {}
            None => break,
        }
    }
    banks.truncate(want);
    rows.truncate(want);
    (banks, rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fast() -> Option<FastPath> {
        let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
        FastPath::new(dir).ok()
    }

    #[test]
    fn synthesized_trace_shape() {
        let cfg = SystemConfig::tl_ooo();
        let (b, r) = synthesize_trace(&cfg, WorkloadKind::Gups, Mechanism::TlOoO, 1, 7);
        assert_eq!(b.len(), CHUNK);
        assert_eq!(r.len(), CHUNK);
        assert!(b.iter().all(|&x| (0..NUM_BANKS).contains(&x)));
        assert!(r.iter().all(|&x| x >= 0));
    }

    #[test]
    fn classify_counts_consistent() {
        let Some(fp) = fast() else {
            eprintln!("artifacts missing; skipping");
            return;
        };
        let cfg = SystemConfig::tl_ooo();
        let (b, r) = synthesize_trace(&cfg, WorkloadKind::Gups, Mechanism::TlOoO, 1, 7);
        let c = fp.classify(&b, &r).unwrap();
        assert_eq!(c.accesses, CHUNK as u64);
        assert_eq!(c.hits + c.conflicts + c.misses(), c.accesses);
        let expect = c.estimate_ns(LAT_HIT_NS, LAT_MISS_NS, LAT_CONFLICT_NS);
        assert_eq!(c.total_ns, expect, "summary vs re-weighting mismatch");
    }

    #[test]
    fn twin_traces_conflict_more_than_single() {
        let Some(fp) = fast() else {
            return;
        };
        let cfg = SystemConfig::tl_ooo();
        let (tb, tr) = synthesize_trace(&cfg, WorkloadKind::Gups, Mechanism::TlOoO, 1, 7);
        let (sb, sr) = synthesize_trace(&cfg, WorkloadKind::Gups, Mechanism::Ideal, 1, 7);
        let twin = fp.classify(&tb, &tr).unwrap();
        let single = fp.classify(&sb, &sr).unwrap();
        // Twins to the same bank/different row force conflicts.
        assert!(
            twin.conflicts as f64 / twin.accesses as f64
                > single.conflicts as f64 / single.accesses as f64
        );
    }

    #[test]
    fn inc_trl_crossover_shape() {
        // At +0ns a single load beats twin-load; at large deltas the
        // bank-holding makes it lose — the Figure 15 crossover.
        let Some(fp) = fast() else {
            return;
        };
        let cfg = SystemConfig::tl_ooo();
        let (tb, tr) = synthesize_trace(&cfg, WorkloadKind::Gups, Mechanism::TlOoO, 1, 7);
        let (sb, sr) = synthesize_trace(&cfg, WorkloadKind::Gups, Mechanism::Ideal, 1, 7);
        let twin = fp.classify(&tb, &tr).unwrap();
        let single = fp.classify(&sb, &sr).unwrap();
        let (t0, s0) = fp.twin_vs_inc_trl(&twin, &single, 0);
        let (t135, s135) = fp.twin_vs_inc_trl(&twin, &single, 135);
        assert!(s0 < t0, "at +0ns single-load must win: {s0} vs {t0}");
        assert!(s135 > t135, "at +135ns twin-load must win: {s135} vs {t135}");
    }
}
