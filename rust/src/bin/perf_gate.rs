//! CI perf-regression gate.
//!
//! ```text
//! perf_gate [BENCH_hotpath.json] [BENCH_baseline.json]
//! ```
//!
//! Compares a fresh hotpath bench run against the checked-in baseline
//! (see `twinload::stats::bench` for the rules) and exits non-zero when
//! the gate fails: 1 for a perf regression, 2 for missing/unreadable
//! inputs. Run via `make perf-gate`.

use twinload::stats::bench::{perf_gate, BenchReport, MAX_REGRESSION, PAIR_TOLERANCE};

fn load(path: &str) -> Result<BenchReport, String> {
    let text = std::fs::read_to_string(path).map_err(|e| format!("reading {path}: {e}"))?;
    BenchReport::parse(&text).map_err(|e| format!("parsing {path}: {e}"))
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cur_path = args.first().map(String::as_str).unwrap_or("BENCH_hotpath.json");
    let base_path = args.get(1).map(String::as_str).unwrap_or("BENCH_baseline.json");
    let (current, baseline) = match (load(cur_path), load(base_path)) {
        (Ok(c), Ok(b)) => (c, b),
        (c, b) => {
            for r in [c.err(), b.err()].into_iter().flatten() {
                eprintln!("perf-gate: {r}");
            }
            std::process::exit(2);
        }
    };

    println!(
        "== perf gate: {cur_path} vs {base_path}{} ==",
        if baseline.provisional { " (provisional baseline)" } else { "" }
    );
    let gate = perf_gate(&current, &baseline);
    for line in &gate.lines {
        println!("{line}");
    }
    for w in &gate.warnings {
        println!("[warn] {w}");
    }
    if gate.passed() {
        println!(
            "perf gate OK ({} row comparisons; thresholds: {:.0} % regression, {:.2}x pair)",
            gate.lines.len(),
            MAX_REGRESSION * 100.0,
            PAIR_TOLERANCE
        );
        return;
    }
    for f in &gate.failures {
        eprintln!("[FAIL] {f}");
    }
    eprintln!(
        "perf gate FAILED ({} failure{}). If this slowdown is intentional, regenerate the \
         baseline with `make baseline` and commit BENCH_baseline.json.",
        gate.failures.len(),
        if gate.failures.len() == 1 { "" } else { "s" }
    );
    std::process::exit(1);
}
