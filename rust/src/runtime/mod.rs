//! PJRT runtime: load AOT-compiled JAX/Pallas artifacts (HLO text) and
//! execute them from the Rust hot path. Python never runs here — the
//! interchange is `artifacts/*.hlo.txt`, produced once by
//! `python/compile/aot.py` (see DESIGN.md §Three-layer architecture).
//!
//! HLO **text** (not serialized `HloModuleProto`) is the format: jax ≥0.5
//! emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the
//! text parser reassigns ids and round-trips cleanly (see
//! /opt/xla-example/README.md).
//!
//! The real implementation needs the `xla` crate plus native XLA
//! libraries, which the build container does not ship. It is therefore
//! gated behind the off-by-default `pjrt` cargo feature; enabling it also
//! requires adding an `xla` dependency entry to `Cargo.toml` (see the
//! feature's comment there). The default build compiles a stub whose
//! constructor returns a descriptive error, so every consumer (the PJRT
//! fast path, the hotpath bench, examples) degrades gracefully.

use anyhow::Result;

/// Argument to an AOT computation.
#[derive(Debug, Clone)]
pub enum ArgValue {
    F32(Vec<f32>, Vec<i64>),
    I32(Vec<i32>, Vec<i64>),
}

impl ArgValue {
    pub fn f32(data: Vec<f32>, dims: &[i64]) -> ArgValue {
        ArgValue::F32(data, dims.to_vec())
    }

    pub fn i32(data: Vec<i32>, dims: &[i64]) -> ArgValue {
        ArgValue::I32(data, dims.to_vec())
    }

    pub fn len(&self) -> usize {
        match self {
            ArgValue::F32(d, _) => d.len(),
            ArgValue::I32(d, _) => d.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// One output tensor.
#[derive(Debug, Clone)]
pub enum OutValue {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl OutValue {
    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            OutValue::F32(v) => Ok(v),
            _ => Err(anyhow::anyhow!("output is not f32")),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            OutValue::I32(v) => Ok(v),
            _ => Err(anyhow::anyhow!("output is not i32")),
        }
    }
}

#[cfg(feature = "pjrt")]
mod pjrt_impl {
    use super::{ArgValue, OutValue};
    use anyhow::{anyhow, Context, Result};
    use std::collections::HashMap;
    use std::path::Path;

    impl ArgValue {
        fn to_literal(&self) -> Result<xla::Literal> {
            let lit = match self {
                ArgValue::F32(data, dims) => xla::Literal::vec1(data).reshape(dims)?,
                ArgValue::I32(data, dims) => xla::Literal::vec1(data).reshape(dims)?,
            };
            Ok(lit)
        }
    }

    /// A CPU PJRT client holding compiled executables keyed by name.
    pub struct PjrtRuntime {
        client: xla::PjRtClient,
        executables: HashMap<String, xla::PjRtLoadedExecutable>,
    }

    impl PjrtRuntime {
        /// Create the CPU client.
        pub fn cpu() -> Result<PjrtRuntime> {
            let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            Ok(PjrtRuntime { client, executables: HashMap::new() })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load + compile an HLO text artifact under `name`.
        pub fn load_hlo(&mut self, name: &str, path: impl AsRef<Path>) -> Result<()> {
            let path = path.as_ref();
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling {}", path.display()))?;
            self.executables.insert(name.to_string(), exe);
            Ok(())
        }

        /// Load every `*.hlo.txt` under a directory, keyed by file stem.
        pub fn load_dir(&mut self, dir: impl AsRef<Path>) -> Result<Vec<String>> {
            let mut loaded = Vec::new();
            for entry in std::fs::read_dir(dir.as_ref())
                .with_context(|| format!("reading {}", dir.as_ref().display()))?
            {
                let path = entry?.path();
                let Some(fname) = path.file_name().and_then(|s| s.to_str()) else { continue };
                if let Some(stem) = fname.strip_suffix(".hlo.txt") {
                    let stem = stem.to_string();
                    self.load_hlo(&stem, &path)?;
                    loaded.push(stem);
                }
            }
            loaded.sort();
            Ok(loaded)
        }

        pub fn has(&self, name: &str) -> bool {
            self.executables.contains_key(name)
        }

        pub fn names(&self) -> Vec<&str> {
            let mut v: Vec<&str> = self.executables.keys().map(|s| s.as_str()).collect();
            v.sort();
            v
        }

        /// Execute `name` with the given arguments; returns the flattened
        /// tuple outputs (aot.py always lowers with `return_tuple=True`).
        pub fn execute(&self, name: &str, args: &[ArgValue]) -> Result<Vec<OutValue>> {
            let exe = self
                .executables
                .get(name)
                .ok_or_else(|| anyhow!("no executable named '{name}'"))?;
            let literals: Vec<xla::Literal> =
                args.iter().map(|a| a.to_literal()).collect::<Result<_>>()?;
            let result = exe.execute::<xla::Literal>(&literals)?;
            let out = result
                .first()
                .and_then(|d| d.first())
                .ok_or_else(|| anyhow!("empty result"))?
                .to_literal_sync()?;
            let parts = out.to_tuple()?;
            parts
                .into_iter()
                .map(|lit| match lit.element_type()? {
                    xla::ElementType::F32 => Ok(OutValue::F32(lit.to_vec::<f32>()?)),
                    xla::ElementType::S32 => Ok(OutValue::I32(lit.to_vec::<i32>()?)),
                    other => Err(anyhow!("unsupported output dtype {other:?}")),
                })
                .collect()
        }
    }
}

#[cfg(feature = "pjrt")]
pub use pjrt_impl::PjrtRuntime;

#[cfg(not(feature = "pjrt"))]
mod stub {
    use super::{ArgValue, OutValue};
    use anyhow::{anyhow, Result};
    use std::path::Path;

    const UNAVAILABLE: &str =
        "PJRT runtime unavailable: built without the `pjrt` cargo feature \
         (requires the xla crate + native XLA libraries)";

    /// Stub standing in for the XLA-backed runtime in default builds.
    /// `cpu()` fails, so the other methods are unreachable on a real
    /// instance but keep the full API surface type-checking.
    pub struct PjrtRuntime {}

    impl PjrtRuntime {
        pub fn cpu() -> Result<PjrtRuntime> {
            Err(anyhow!(UNAVAILABLE))
        }

        pub fn platform(&self) -> String {
            "unavailable".to_string()
        }

        pub fn load_hlo(&mut self, _name: &str, _path: impl AsRef<Path>) -> Result<()> {
            Err(anyhow!(UNAVAILABLE))
        }

        pub fn load_dir(&mut self, _dir: impl AsRef<Path>) -> Result<Vec<String>> {
            Err(anyhow!(UNAVAILABLE))
        }

        pub fn has(&self, _name: &str) -> bool {
            false
        }

        pub fn names(&self) -> Vec<&str> {
            Vec::new()
        }

        pub fn execute(&self, _name: &str, _args: &[ArgValue]) -> Result<Vec<OutValue>> {
            Err(anyhow!(UNAVAILABLE))
        }
    }
}

#[cfg(not(feature = "pjrt"))]
pub use stub::PjrtRuntime;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arg_values_report_length() {
        let a = ArgValue::f32(vec![1.0, 2.0], &[2]);
        assert_eq!(a.len(), 2);
        assert!(!a.is_empty());
        let b = ArgValue::i32(vec![], &[0]);
        assert!(b.is_empty());
    }

    #[test]
    fn out_value_downcasts() {
        let f = OutValue::F32(vec![1.0]);
        assert!(f.as_f32().is_ok());
        assert!(f.as_i32().is_err());
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_runtime_fails_gracefully() {
        let err = PjrtRuntime::cpu().err().expect("stub must not construct");
        assert!(err.to_string().contains("pjrt"), "unexpected error: {err}");
    }

    #[cfg(feature = "pjrt")]
    mod with_pjrt {
        use super::super::*;

        fn artifacts_dir() -> Option<std::path::PathBuf> {
            let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
            if dir.exists() {
                Some(dir)
            } else {
                None
            }
        }

        #[test]
        fn cpu_client_comes_up() {
            let rt = PjrtRuntime::cpu().expect("client");
            assert!(rt.platform().to_lowercase().contains("cpu"));
        }

        #[test]
        fn missing_executable_is_an_error() {
            let rt = PjrtRuntime::cpu().unwrap();
            assert!(rt.execute("nope", &[]).is_err());
            assert!(!rt.has("nope"));
        }

        /// Full round trip through a real artifact (skipped until
        /// `make artifacts` has produced them — CI runs it first).
        #[test]
        fn loads_and_runs_artifacts_when_present() {
            let Some(dir) = artifacts_dir() else {
                eprintln!("artifacts/ not built; skipping");
                return;
            };
            let mut rt = PjrtRuntime::cpu().unwrap();
            let loaded = rt.load_dir(&dir).expect("load artifacts");
            if loaded.is_empty() {
                eprintln!("no artifacts found; skipping");
                return;
            }
            assert!(rt.has(&loaded[0]));
        }

        /// Numerics: the AOT trace-latency model classifies a known trace
        /// exactly like the Rust-side constants (cross-layer consistency).
        #[test]
        fn trace_latency_numerics_match() {
            let Some(dir) = artifacts_dir() else {
                return;
            };
            let path = dir.join("trace_latency.hlo.txt");
            if !path.exists() {
                return;
            }
            let mut rt = PjrtRuntime::cpu().unwrap();
            rt.load_hlo("trace_latency", &path).unwrap();
            const N: usize = 16_384;
            // All accesses to bank 0, alternating rows: first = miss (28 ns),
            // rest = conflicts (49 ns).
            let bank = vec![0i32; N];
            let row: Vec<i32> = (0..N as i32).map(|i| i % 2).collect();
            let outs = rt
                .execute(
                    "trace_latency",
                    &[
                        ArgValue::i32(bank, &[N as i64]),
                        ArgValue::i32(row, &[N as i64]),
                    ],
                )
                .unwrap();
            let lat = outs[0].as_i32().unwrap();
            assert_eq!(lat[0], 28);
            assert!(lat[1..].iter().all(|&l| l == 49));
            let total = outs[1].as_i32().unwrap()[0] as i64;
            assert_eq!(total, 28 + 49 * (N as i64 - 1));
            let hits = outs[2].as_i32().unwrap()[0];
            assert_eq!(hits, 0);
            let conflicts = outs[3].as_i32().unwrap()[0];
            assert_eq!(conflicts, N as i32 - 1);
        }
    }
}
