//! MEC tree topologies (paper Figure 3) and the downstream path.
//!
//! Command-forwarding semantics: the host memory controller's ACT/RD
//! stream *is* the DRAM command stream — middle MECs route each command
//! toward the leaf whose physical-DIMM id sits in the high row bits
//! (§4.3), adding propagation delay per hop in each direction. MEC1
//! suppresses second-load (shadow) commands downstream — they are served
//! from the LVC — so the leaf sees exactly the first-load sequence, with
//! ACT already tRCD ahead of RD courtesy of host timing. The prefetched
//! data is therefore back at MEC1 at
//!
//! ```text
//!   t(RD) + 2·tPD + tRL_leaf + tBURST
//! ```
//!
//! which is the paper's LVC round-trip `2·tPD + tRL` plus the burst tail.
//! Per-leaf upstream data-bus serialization is modeled (consecutive
//! prefetch returns from one leaf cannot overlap).

use crate::dram::timing::{TimingParams, T_PD_LOGIC_HOP};
use crate::util::time::Ps;

/// Shape of the extension tree.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Topology {
    /// Number of MEC layers (1 = just MEC1 in front of DIMMs).
    pub layers: u32,
    /// Children per MEC (leaves = fanout^(layers-1), must stay pow2).
    pub fanout: u32,
    /// Per-hop, per-direction propagation delay.
    pub hop_delay: Ps,
}

impl Topology {
    /// Figure 3's four-layer tree (binary fanout keeps leaf count pow2).
    pub fn paper_fig3() -> Topology {
        Topology { layers: 4, fanout: 2, hop_delay: T_PD_LOGIC_HOP }
    }

    /// Two-layer system with logic processing (§2.1's ≈20 ns example).
    pub fn two_layer() -> Topology {
        Topology { layers: 2, fanout: 4, hop_delay: T_PD_LOGIC_HOP }
    }

    /// Single MEC layer (LRDIMM-like, but asynchronous behind MEC1).
    pub fn one_layer() -> Topology {
        Topology { layers: 1, fanout: 4, hop_delay: T_PD_LOGIC_HOP }
    }

    /// The paper's five-layer simple-forwarding limit case: 3.4 ns hops.
    pub fn five_layer_simple() -> Topology {
        Topology { layers: 5, fanout: 2, hop_delay: 3_400 }
    }

    pub fn num_leaves(&self) -> u32 {
        self.fanout.pow(self.layers.saturating_sub(1))
    }

    /// One-way propagation delay MEC1 → leaf DRAM.
    pub fn one_way(&self) -> Ps {
        self.layers as Ps * self.hop_delay
    }

    /// Round-trip propagation (the `2·tPD` of the paper's LVC formula).
    pub fn round_trip(&self) -> Ps {
        2 * self.one_way()
    }

    /// Can TL-OoO's forced row-miss window cover this topology? The
    /// budget from the first RD is `turnaround + tRL_host` (second RD is
    /// ≥35 ns later and MEC1 must drive data tRL after that); the cost is
    /// `2·tPD + tRL_leaf` — first-beat semantics, since MEC1 relays the
    /// burst cut-through (this is how the paper's five-layer example and
    /// its `M > (2·tPD + tRL)/tCCD` sizing both come out).
    pub fn ooo_tolerable(&self, host: &TimingParams, leaf: &TimingParams) -> bool {
        self.round_trip() + leaf.t_rl <= host.row_miss_turnaround() + host.t_rl
    }
}

/// Downstream model: routing + per-leaf upstream bus serialization.
#[derive(Debug, Clone)]
pub struct MecTree {
    topo: Topology,
    leaf_timing: TimingParams,
    leaf_capacity: u64,
    /// Per-leaf: when its upstream data path is next free.
    leaf_data_free: Vec<Ps>,
    pub prefetches: u64,
    pub writes: u64,
    /// Prefetches delayed by leaf data-path contention.
    pub leaf_contention: u64,
}

impl MecTree {
    /// Cover `ext_bytes` of extended memory with `topo` and the given
    /// leaf DRAM/SCM timing.
    pub fn new(ext_bytes: u64, topo: Topology, leaf_timing: TimingParams) -> MecTree {
        let leaves = topo.num_leaves() as u64;
        assert!(ext_bytes.is_power_of_two() && leaves.is_power_of_two());
        assert!(ext_bytes >= leaves, "fewer bytes than leaves");
        MecTree {
            topo,
            leaf_timing,
            leaf_capacity: ext_bytes / leaves,
            leaf_data_free: vec![0; leaves as usize],
            prefetches: 0,
            writes: 0,
            leaf_contention: 0,
        }
    }

    pub fn topology(&self) -> &Topology {
        &self.topo
    }

    pub fn num_leaves(&self) -> usize {
        self.leaf_data_free.len()
    }

    /// Route an extended-space offset (shadow bit already stripped) to
    /// `(leaf index, leaf-local offset)` — high bits = physical DIMM id.
    pub fn route(&self, ext_offset: u64) -> (usize, u64) {
        let leaf = (ext_offset / self.leaf_capacity) as usize;
        (leaf % self.num_leaves(), ext_offset % self.leaf_capacity)
    }

    /// Forward a first-load prefetch whose RD issued at `rd_at`; returns
    /// when the data is fully back **at MEC1**.
    pub fn prefetch(&mut self, ext_offset: u64, rd_at: Ps) -> Ps {
        self.prefetches += 1;
        let (leaf, _) = self.route(ext_offset);
        // Leaf drives data tRL after the forwarded RD arrives.
        let data_start = rd_at + self.topo.one_way() + self.leaf_timing.t_rl;
        // Upstream data-path serialization per leaf.
        let start = data_start.max(self.leaf_data_free[leaf]);
        if start > data_start {
            self.leaf_contention += 1;
        }
        self.leaf_data_free[leaf] = start + self.leaf_timing.t_burst;
        // First beat back at MEC1 (cut-through relay of the burst).
        start + self.topo.one_way()
    }

    /// Forward a write (dirty eviction writeback). Posted; returns the
    /// completion time at the leaf for stats.
    pub fn write(&mut self, ext_offset: u64, wr_at: Ps) -> Ps {
        self.writes += 1;
        let (_leaf, _) = self.route(ext_offset);
        wr_at + self.topo.one_way() + self.leaf_timing.t_wl + self.leaf_timing.t_burst
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::time::NS;

    fn tree(topo: Topology) -> MecTree {
        MecTree::new(256 << 20, topo, TimingParams::ddr3_1600())
    }

    #[test]
    fn leaf_counts() {
        assert_eq!(Topology::paper_fig3().num_leaves(), 8);
        assert_eq!(Topology::two_layer().num_leaves(), 4);
        assert_eq!(Topology::one_layer().num_leaves(), 1);
        assert_eq!(Topology::five_layer_simple().num_leaves(), 16);
    }

    #[test]
    fn paper_five_layer_simple_is_tolerable() {
        // §3.1: 35 ns "is enough to tolerate propagation delays for up to
        // five MEC layers" at 3.4 ns per simple-forwarding hop.
        let host = TimingParams::ddr3_1600();
        let t = Topology::five_layer_simple();
        assert!(t.ooo_tolerable(&host, &host), "rtt={}", t.round_trip());
    }

    #[test]
    fn ooo_tolerance_boundary() {
        let host = TimingParams::ddr3_1600();
        // Budget = 35 + 13.75 = 48.75 ns; cost = RTT + 13.75 ns →
        // RTT ≤ 35 ns: 3 layers × 5 ns hops (30 ns) ok, 4 (40 ns) not.
        let t3 = Topology { layers: 3, fanout: 2, hop_delay: 5 * NS };
        let t4 = Topology { layers: 4, fanout: 2, hop_delay: 5 * NS };
        assert!(t3.ooo_tolerable(&host, &host));
        assert!(!t4.ooo_tolerable(&host, &host));
    }

    #[test]
    fn scm_leaf_shrinks_tolerance() {
        // Slow SCM leaves eat the budget: a topology fine with DRAM
        // leaves fails with SCM leaves.
        let host = TimingParams::ddr3_1600();
        let scm = TimingParams::scm_leaf();
        let t = Topology { layers: 2, fanout: 2, hop_delay: 5 * NS };
        assert!(t.ooo_tolerable(&host, &host));
        assert!(!t.ooo_tolerable(&host, &scm));
    }

    #[test]
    fn routing_partitions_space() {
        let t = tree(Topology::paper_fig3());
        let cap = 256u64 << 20;
        let leaves = t.num_leaves() as u64;
        let per = cap / leaves;
        assert_eq!(t.route(0), (0, 0));
        assert_eq!(t.route(per), (1, 0));
        assert_eq!(t.route(per * (leaves - 1) + 64), ((leaves - 1) as usize, 64));
    }

    #[test]
    fn prefetch_round_trip_formula() {
        // The paper's `2·tPD + tRL` round trip, first-beat semantics.
        let mut t = tree(Topology::two_layer());
        let p = TimingParams::ddr3_1600();
        let back = t.prefetch(0x40, 100 * NS);
        assert_eq!(back, 100 * NS + t.topology().round_trip() + p.t_rl);
        assert_eq!(t.prefetches, 1);
    }

    #[test]
    fn same_leaf_back_to_back_serializes() {
        let mut t = tree(Topology::two_layer());
        let a = t.prefetch(0x0, 0);
        let b = t.prefetch(0x40, 0); // same leaf, same instant
        assert_eq!(b - a, TimingParams::ddr3_1600().t_burst);
        assert_eq!(t.leaf_contention, 1);
    }

    #[test]
    fn different_leaves_do_not_serialize() {
        let mut t = tree(Topology::paper_fig3());
        let per_leaf = (256u64 << 20) / 8;
        let a = t.prefetch(0, 0);
        let b = t.prefetch(per_leaf, 0);
        assert_eq!(a, b);
        assert_eq!(t.leaf_contention, 0);
    }

    #[test]
    fn writes_complete() {
        let mut t = tree(Topology::one_layer());
        let done = t.write(0x1000, 5 * NS);
        assert!(done > 5 * NS);
        assert_eq!(t.writes, 1);
    }
}
