//! MEC1: the top-level Memory Extending Chip (paper §3.1, §4.3).
//!
//! MEC1 snoops the host channel's DDR command stream. ACT/PRE maintain the
//! Bank State Table; each RD is reconstructed to a full address and looked
//! up in the Load Value Cache:
//!
//! * **LVC miss → first load**: allocate an entry, forward the request
//!   down the tree (prefetch), and drive *fake* data (0x5a pattern) on
//!   the bus exactly tRL later — the synchronous interface is never
//!   violated.
//! * **LVC hit → second load**: if the prefetched data arrived by the bus
//!   deadline, drive it (real) and free the entry; if the data is still
//!   in flight (topology too deep) drive fake data and keep the entry; if
//!   the entry was evicted the load is treated as a first load again
//!   (re-prefetch) — software retries handle both (§4.4).

use super::bst::BankStateTable;
use super::lvc::{LoadValueCache, LvcLookup};
use super::topology::{MecTree, Topology};
use crate::cache::DataKind;
use crate::dram::address::{AddressMapping, DecodedAddr};
use crate::dram::command::{Command, CommandKind};
use crate::dram::timing::TimingParams;
use crate::sim::fault::{FaultCounters, FaultPlan, FillFault};
use crate::util::time::Ps;

/// MEC1 configuration.
#[derive(Debug, Clone, Copy)]
pub struct MecConfig {
    /// LVC entry count M (paper: must exceed ~10 for TL-OoO; default 32 —
    /// bus monitoring showed twins separated by ~6 other loads).
    pub lvc_entries: usize,
    pub topology: Topology,
    /// Leaf DRAM timing (DRAM by default; SCM preset for §8 experiments).
    pub leaf_timing: TimingParams,
}

impl MecConfig {
    pub fn default_tl() -> MecConfig {
        MecConfig {
            lvc_entries: 32,
            topology: Topology::two_layer(),
            leaf_timing: TimingParams::ddr3_1600(),
        }
    }
}

/// What the host observes for one RD to the extended channel.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReadOutcome {
    /// Prefetch launched; fake data on the bus.
    FirstLoad,
    /// Real data on the bus.
    SecondLoadReal,
    /// Entry present but data still in flight; fake data, entry kept.
    SecondLoadLate,
}

impl ReadOutcome {
    pub fn data(self) -> DataKind {
        match self {
            ReadOutcome::SecondLoadReal => DataKind::Real,
            _ => DataKind::Fake,
        }
    }
}

/// MEC1 statistics.
#[derive(Debug, Default, Clone, Copy)]
pub struct MecStats {
    pub first_loads: u64,
    pub second_real: u64,
    pub second_late: u64,
    pub writes: u64,
    pub reads_without_act: u64,
    /// Injected prefetch-buffer fill faults: fills dropped outright (the
    /// LVC never sees the value; the next twin re-prefetches).
    pub fill_drops: u64,
    /// Injected fill faults: fills landing late (the second twin observes
    /// not-ready data and the host retries).
    pub fill_lates: u64,
}

pub struct Mec1 {
    cfg: MecConfig,
    bst: BankStateTable,
    lvc: LoadValueCache,
    tree: MecTree,
    /// Host-side extended-channel address mapping (single channel).
    host_map: AddressMapping,
    host_t_rl: Ps,
    /// Deterministic fill-fault schedule (`None` = inert, the default).
    fault: Option<FaultPlan>,
    fault_seq: FaultCounters,
    pub stats: MecStats,
}

impl Mec1 {
    /// `ext_bytes` is the real extended capacity (the host channel space
    /// is 2× that: extended + shadow, distinguished by the row MSB).
    pub fn new(
        cfg: MecConfig,
        ext_bytes: u64,
        host_map: AddressMapping,
        host: &TimingParams,
    ) -> Mec1 {
        Mec1 {
            // One BST entry per logical bank the fake SPD advertises.
            bst: BankStateTable::new(host_map.num_flat_banks()),
            lvc: LoadValueCache::new(cfg.lvc_entries),
            tree: MecTree::new(ext_bytes, cfg.topology, cfg.leaf_timing),
            host_map,
            host_t_rl: host.t_rl,
            fault: None,
            fault_seq: FaultCounters::default(),
            cfg,
            stats: MecStats::default(),
        }
    }

    /// Arm deterministic prefetch-fill fault injection (`sim/fault.rs`).
    pub fn set_fault_plan(&mut self, plan: Option<FaultPlan>) {
        self.fault = plan;
    }

    pub fn config(&self) -> &MecConfig {
        &self.cfg
    }

    pub fn tree(&self) -> &MecTree {
        &self.tree
    }

    pub fn lvc(&self) -> &LoadValueCache {
        &self.lvc
    }

    /// Strip the shadow (row-MSB) bit: both twins map to the same target.
    /// The mapping's `twin()` flips the physical-address MSB == row MSB, so
    /// the canonical (extended-space) form is simply the smaller twin.
    fn strip_shadow(&self, d: &DecodedAddr) -> (DecodedAddr, u64) {
        let phys = self.host_map.encode(d);
        let low = phys.min(self.host_map.twin(phys));
        (self.host_map.decode(low), low)
    }

    /// LVC tag from a reconstructed, shadow-stripped address.
    fn tag_of(d: &DecodedAddr) -> u64 {
        ((d.row as u64) << 32) | ((d.rank as u64) << 24) | ((d.bank as u64) << 16) | d.col as u64
    }

    /// Observe one host-channel command stream entry (from the host
    /// controller's `ServiceResult::commands`). Returns the outcome for
    /// RD commands, `None` otherwise.
    pub fn on_command(&mut self, cmd: &Command) -> Option<ReadOutcome> {
        let flat = cmd.flat_bank(self.host_map.banks_per_rank());
        match cmd.kind {
            CommandKind::Act => {
                self.bst.on_act(flat, cmd.row);
                None
            }
            CommandKind::Pre => {
                self.bst.on_pre(flat);
                None
            }
            CommandKind::Rd => {
                let Some(row) = self.bst.open_row(flat) else {
                    self.stats.reads_without_act += 1;
                    return Some(ReadOutcome::FirstLoad);
                };
                let d = DecodedAddr {
                    channel: 0,
                    rank: cmd.rank,
                    bank: cmd.bank,
                    row,
                    col: cmd.col,
                };
                Some(self.on_read(&d, cmd.at))
            }
            CommandKind::Wr => {
                if let Some(row) = self.bst.open_row(flat) {
                    let d = DecodedAddr {
                        channel: 0,
                        rank: cmd.rank,
                        bank: cmd.bank,
                        row,
                        col: cmd.col,
                    };
                    let (stripped, offset) = self.strip_shadow(&d);
                    let _ = stripped;
                    self.tree.write(offset, cmd.at);
                    self.stats.writes += 1;
                }
                None
            }
            CommandKind::Ref => None,
        }
    }

    /// Process a reconstructed read at time `t` (RD command issue time).
    fn on_read(&mut self, d: &DecodedAddr, t: Ps) -> ReadOutcome {
        let (stripped, offset) = self.strip_shadow(d);
        let tag = Self::tag_of(&stripped);
        match self.lvc.lookup(tag) {
            LvcLookup::Miss => {
                // First load: allocate + forward prefetch downstream.
                let mut data_back = self.tree.prefetch(offset, t);
                let mut dropped = false;
                if let Some(plan) = &self.fault {
                    // Late fills miss the twin-spacing window by a wide
                    // margin, so the second twin observes not-ready data;
                    // the host's retry finds the (by then arrived) value.
                    let late_by = 8 * self.host_t_rl;
                    match plan.mec_fill(tag, self.fault_seq.next(tag), late_by) {
                        FillFault::None => {}
                        FillFault::Dropped => {
                            self.stats.fill_drops += 1;
                            dropped = true;
                        }
                        FillFault::Late(d) => {
                            self.stats.fill_lates += 1;
                            data_back += d;
                        }
                    }
                }
                if !dropped {
                    self.lvc.allocate(tag, data_back);
                }
                self.stats.first_loads += 1;
                ReadOutcome::FirstLoad
            }
            LvcLookup::Hit { data_at } => {
                // MEC1 must drive data tRL after the RD: the deadline.
                let deadline = t + self.host_t_rl;
                if data_at <= deadline {
                    self.lvc.release(tag);
                    self.stats.second_real += 1;
                    ReadOutcome::SecondLoadReal
                } else {
                    self.stats.second_late += 1;
                    ReadOutcome::SecondLoadLate
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dram::timing::Geometry;
    use crate::util::time::NS;

    /// Host-channel mapping over 2×256 MiB (extended + shadow).
    fn host_map() -> AddressMapping {
        // 512 MiB channel: dual rank, 8 banks, 128 cols → rows = 512 MiB /
        // (2*8*128*64) = 4096.
        let geo = Geometry { ranks: 2, banks_per_rank: 8, rows_per_bank: 4096, cols_per_row: 128 };
        AddressMapping::new(&geo, 1)
    }

    fn mec(topology: Topology) -> Mec1 {
        let cfg = MecConfig { lvc_entries: 32, topology, leaf_timing: TimingParams::ddr3_1600() };
        Mec1::new(cfg, 256 << 20, host_map(), &TimingParams::ddr3_1600())
    }

    /// Drive an ACT+RD for the address at `phys`, at RD time `t`.
    fn read_at(m: &mut Mec1, phys: u64, t: Ps) -> ReadOutcome {
        let d = host_map().decode(phys);
        m.on_command(&Command::act(d.rank, d.bank, d.row, t.saturating_sub(14 * NS)));
        m.on_command(&Command::rd(d.rank, d.bank, d.col, t)).unwrap()
    }

    #[test]
    fn first_then_second_load_real() {
        let mut m = mec(Topology::two_layer());
        let phys = 0x40;
        let o1 = read_at(&mut m, phys, 20 * NS);
        assert_eq!(o1, ReadOutcome::FirstLoad);
        assert_eq!(o1.data(), DataKind::Fake);
        // Twin arrives 35 ns later (row-miss spacing): data should be back.
        let twin = host_map().twin(phys);
        let o2 = read_at(&mut m, twin, 55 * NS);
        assert_eq!(o2, ReadOutcome::SecondLoadReal);
        assert_eq!(o2.data(), DataKind::Real);
    }

    #[test]
    fn too_deep_topology_returns_late() {
        // 6 layers × 5 ns hop = 60 ns round trip + leaf access ≫ 35 ns
        // window: the second load finds the data still in flight.
        let deep = Topology { layers: 6, fanout: 2, hop_delay: 5 * NS };
        let mut m = mec(deep);
        let phys = 0x40;
        read_at(&mut m, phys, 20 * NS);
        let o2 = read_at(&mut m, host_map().twin(phys), 55 * NS);
        assert_eq!(o2, ReadOutcome::SecondLoadLate);
        // A later retry (well past arrival) succeeds.
        let o3 = read_at(&mut m, phys, 400 * NS);
        assert_eq!(o3, ReadOutcome::SecondLoadReal);
    }

    #[test]
    fn evicted_entry_re_prefetches() {
        let mut m = mec(Topology::one_layer());
        let phys = 0x40;
        read_at(&mut m, phys, 20 * NS);
        // Flood the LVC with 32 other first-loads to evict the entry.
        for i in 1..=32u64 {
            read_at(&mut m, phys + i * (128 * 64) * 16, (20 + i) * 100 * NS);
        }
        // The intended second load is identified as a first load again.
        let o = read_at(&mut m, host_map().twin(phys), 10_000 * NS);
        assert_eq!(o, ReadOutcome::FirstLoad);
        assert!(m.lvc().evictions > 0);
    }

    #[test]
    fn twins_share_the_lvc_tag() {
        let mut m = mec(Topology::one_layer());
        let phys = 0x7c0;
        // First load via the SHADOW address, second via the extended —
        // TL-OoO order is arbitrary and both must map to one entry.
        let o1 = read_at(&mut m, host_map().twin(phys), 20 * NS);
        let o2 = read_at(&mut m, phys, 200 * NS);
        assert_eq!(o1, ReadOutcome::FirstLoad);
        assert_eq!(o2, ReadOutcome::SecondLoadReal);
        assert_eq!(m.stats.first_loads, 1);
        assert_eq!(m.stats.second_real, 1);
    }

    #[test]
    fn writes_forward_downstream() {
        let mut m = mec(Topology::one_layer());
        let d = host_map().decode(0x40);
        m.on_command(&Command::act(d.rank, d.bank, d.row, 0));
        m.on_command(&Command::wr(d.rank, d.bank, d.col, 10 * NS));
        assert_eq!(m.stats.writes, 1);
        assert_eq!(m.tree().writes, 1);
    }

    fn fault_plan(rate: f64) -> FaultPlan {
        let mut cfg = crate::config::SystemConfig::tl_ooo();
        cfg.fault_rate = rate;
        FaultPlan::from_cfg(&cfg).unwrap()
    }

    #[test]
    fn full_rate_fills_drop_or_arrive_late_and_late_recovers() {
        let mut m = mec(Topology::two_layer());
        m.set_fault_plan(Some(fault_plan(1.0)));
        let (mut drops, mut lates) = (0u32, 0u32);
        for i in 0..16u64 {
            // Distinct rows so each pair is an independent first load.
            let phys = 0x40 + i * (128 * 64) * 16;
            let t = (20 + 1_000 * i) * NS;
            assert_eq!(read_at(&mut m, phys, t), ReadOutcome::FirstLoad);
            match read_at(&mut m, host_map().twin(phys), t + 35 * NS) {
                // Dropped fill: the LVC never filled, so the twin re-misses.
                ReadOutcome::FirstLoad => drops += 1,
                // Late fill: not-ready data → §4.4 retry finds it arrived.
                ReadOutcome::SecondLoadLate => {
                    lates += 1;
                    let o = read_at(&mut m, phys, t + 900 * NS);
                    assert_eq!(o, ReadOutcome::SecondLoadReal);
                }
                ReadOutcome::SecondLoadReal => panic!("rate-1.0 fault missing"),
            }
        }
        assert!(drops > 0 && lates > 0, "drops={drops} lates={lates}");
        assert!(m.stats.fill_drops > 0 && m.stats.fill_lates > 0);
    }

    #[test]
    fn fill_faults_are_deterministic_and_partial_at_low_rate() {
        let run = || {
            let mut m = mec(Topology::two_layer());
            m.set_fault_plan(Some(fault_plan(0.3)));
            for i in 0..32u64 {
                read_at(&mut m, 0x40 + i * (128 * 64) * 16, (20 + 100 * i) * NS);
            }
            (m.stats.fill_drops, m.stats.fill_lates)
        };
        let (d, l) = run();
        assert_eq!((d, l), run(), "fill faults must be schedule-deterministic");
        assert!(d + l > 0 && d + l < 32, "rate 0.3 over 32 loads: {d}+{l}");
    }

    #[test]
    fn unarmed_mec_injects_nothing() {
        let mut m = mec(Topology::two_layer());
        for i in 0..8u64 {
            let phys = 0x40 + i * (128 * 64) * 16;
            let t = (20 + 1_000 * i) * NS;
            read_at(&mut m, phys, t);
            let o = read_at(&mut m, host_map().twin(phys), t + 35 * NS);
            assert_eq!(o, ReadOutcome::SecondLoadReal);
        }
        assert_eq!(m.stats.fill_drops + m.stats.fill_lates, 0);
    }

    #[test]
    fn bst_tracks_per_bank_rows() {
        let mut m = mec(Topology::one_layer());
        // Open different rows on two banks, then read both.
        let a = host_map()
            .encode(&DecodedAddr { channel: 0, rank: 0, bank: 0, row: 5, col: 1 });
        let b = host_map()
            .encode(&DecodedAddr { channel: 0, rank: 0, bank: 1, row: 9, col: 2 });
        assert_eq!(read_at(&mut m, a, 20 * NS), ReadOutcome::FirstLoad);
        assert_eq!(read_at(&mut m, b, 30 * NS), ReadOutcome::FirstLoad);
        assert_eq!(m.stats.first_loads, 2);
    }
}
