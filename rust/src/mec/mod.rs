//! Memory Extending Chip (MEC) models — the paper's hardware contribution.
//!
//! MEC1 (top of the tree) implements one slave DDRx interface toward the
//! host memory controller and master interfaces toward the next layer.
//! It advertises *logical* DIMMs via a fake SPD, observes the host's
//! command bus, and implements the two §4.3 structures:
//!
//! * the **Bank State Table** ([`bst::BankStateTable`]) — per logical
//!   bank, the open row last ACTivated, used to reconstruct the full
//!   `<row, column, bank>` address when a RD arrives (RDs only carry the
//!   column);
//! * the **Load Value Cache** ([`lvc::LoadValueCache`]) — an M-entry LRU
//!   cache of prefetched values keyed by reconstructed address; an LVC
//!   miss identifies a *first* (prefetch) load, a hit the *second*.
//!
//! Lower MECs just route commands toward leaf DRAM ([`topology`]); each
//! hop adds propagation delay, which is exactly the latency the
//! synchronous interface cannot tolerate and twin-load hides.

pub mod bst;
pub mod chip;
pub mod lvc;
pub mod topology;

pub use bst::BankStateTable;
pub use chip::{Mec1, MecConfig, ReadOutcome};
pub use lvc::LoadValueCache;
pub use topology::{MecTree, Topology};
