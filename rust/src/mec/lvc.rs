//! Load Value Cache (paper §4.3, Figure 6 right).
//!
//! M-entry, fully-associative, LRU-replaced buffer of prefetched values.
//! The tag is the reconstructed load address; `data_at` is when the value
//! returned by the downstream tree actually lands in the entry (an entry
//! can exist with its data still in flight). The paper sizes it as
//! `M > (2·tPD + tRL) / tCCD` (M > 10 for TL-OoO); the default here is 32
//! and the ablation bench sweeps it.

use crate::util::time::Ps;

#[derive(Debug, Clone, Copy)]
struct LvcEntry {
    tag: u64,
    valid: bool,
    /// When the prefetched data arrives at MEC1 (Ps::MAX = still unknown).
    data_at: Ps,
    stamp: u64,
}

#[derive(Debug, Clone)]
pub struct LoadValueCache {
    entries: Vec<LvcEntry>,
    clock: u64,
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    /// Evictions of entries whose data had not even arrived yet (wasted
    /// prefetch — the case the paper wants M large enough to avoid).
    pub early_evictions: u64,
}

/// Lookup outcome.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LvcLookup {
    /// No entry: this is a *first* (prefetch) load.
    Miss,
    /// Entry present with data arrival time: a *second* load.
    Hit { data_at: Ps },
}

impl LoadValueCache {
    pub fn new(m: usize) -> LoadValueCache {
        assert!(m > 0);
        LoadValueCache {
            entries: vec![
                LvcEntry { tag: 0, valid: false, data_at: 0, stamp: 0 };
                m
            ],
            clock: 0,
            hits: 0,
            misses: 0,
            evictions: 0,
            early_evictions: 0,
        }
    }

    /// The paper's minimum for TL-OoO: `M > (2·tPD + tRL)/tCCD ≈ 10`.
    pub fn paper_min(t_pd: Ps, t_rl: Ps, t_ccd: Ps) -> usize {
        ((2 * t_pd + t_rl) / t_ccd) as usize + 1
    }

    pub fn capacity(&self) -> usize {
        self.entries.len()
    }

    /// Probe for `tag` without allocating.
    pub fn lookup(&mut self, tag: u64) -> LvcLookup {
        self.clock += 1;
        for e in &mut self.entries {
            if e.valid && e.tag == tag {
                e.stamp = self.clock;
                self.hits += 1;
                return LvcLookup::Hit { data_at: e.data_at };
            }
        }
        self.misses += 1;
        LvcLookup::Miss
    }

    /// Allocate an entry for a first load; evicts LRU if full. The data
    /// arrival time is set later via [`Self::fill`] (or given here if the
    /// downstream latency is already known).
    pub fn allocate(&mut self, tag: u64, data_at: Ps) {
        self.clock += 1;
        let mut victim = 0;
        let mut victim_stamp = u64::MAX;
        for (i, e) in self.entries.iter().enumerate() {
            if !e.valid {
                victim = i;
                break;
            }
            if e.stamp < victim_stamp {
                victim = i;
                victim_stamp = e.stamp;
            }
        }
        if self.entries[victim].valid {
            self.evictions += 1;
            if self.entries[victim].data_at == Ps::MAX {
                self.early_evictions += 1;
            }
        }
        self.entries[victim] =
            LvcEntry { tag, valid: true, data_at, stamp: self.clock };
    }

    /// Record the arrival of prefetched data for `tag` (downstream return
    /// carries the LVC entry id in the real hardware; tag search here).
    pub fn fill(&mut self, tag: u64, data_at: Ps) -> bool {
        for e in &mut self.entries {
            if e.valid && e.tag == tag {
                e.data_at = data_at;
                return true;
            }
        }
        false // entry was evicted before data returned
    }

    /// Free the entry after the second load consumed it (valid bit clear).
    pub fn release(&mut self, tag: u64) -> bool {
        for e in &mut self.entries {
            if e.valid && e.tag == tag {
                e.valid = false;
                return true;
            }
        }
        false
    }

    pub fn occupancy(&self) -> usize {
        self.entries.iter().filter(|e| e.valid).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn miss_allocate_hit_release() {
        let mut lvc = LoadValueCache::new(4);
        assert_eq!(lvc.lookup(0x100), LvcLookup::Miss);
        lvc.allocate(0x100, 500);
        assert_eq!(lvc.lookup(0x100), LvcLookup::Hit { data_at: 500 });
        assert!(lvc.release(0x100));
        assert_eq!(lvc.lookup(0x100), LvcLookup::Miss);
    }

    #[test]
    fn lru_eviction_order() {
        let mut lvc = LoadValueCache::new(2);
        lvc.allocate(1, 0);
        lvc.allocate(2, 0);
        lvc.lookup(1); // 1 most recent
        lvc.allocate(3, 0); // evicts 2
        assert_eq!(lvc.lookup(2), LvcLookup::Miss);
        assert!(matches!(lvc.lookup(1), LvcLookup::Hit { .. }));
        assert_eq!(lvc.evictions, 1);
    }

    #[test]
    fn fill_updates_arrival() {
        let mut lvc = LoadValueCache::new(2);
        lvc.allocate(7, Ps::MAX);
        assert!(lvc.fill(7, 1234));
        assert_eq!(lvc.lookup(7), LvcLookup::Hit { data_at: 1234 });
        assert!(!lvc.fill(99, 1)); // unknown tag
    }

    #[test]
    fn early_eviction_counted() {
        let mut lvc = LoadValueCache::new(1);
        lvc.allocate(1, Ps::MAX); // data still in flight
        lvc.allocate(2, 0); // evicts 1 before data arrived
        assert_eq!(lvc.early_evictions, 1);
    }

    #[test]
    fn paper_min_formula() {
        // 2*3.4ns + 13.75ns over tCCD=5ns → floor(4.11)+1 = 5 for one hop;
        // at the 35 ns max tolerable tPD… the paper's M>10 example uses
        // tPD such that the quotient exceeds 10.
        let m = LoadValueCache::paper_min(3_400, 13_750, 5_000);
        assert_eq!(m, 5);
        let m_max = LoadValueCache::paper_min(17_500, 13_750, 5_000);
        assert!(m_max > 9, "m_max={m_max}");
    }

    #[test]
    fn occupancy_tracks() {
        let mut lvc = LoadValueCache::new(4);
        lvc.allocate(1, 0);
        lvc.allocate(2, 0);
        assert_eq!(lvc.occupancy(), 2);
        lvc.release(1);
        assert_eq!(lvc.occupancy(), 1);
    }
}
