//! Bank State Table (paper §4.3, Figure 6 left).
//!
//! One entry per *logical* bank: whether the bank is open and the row
//! address of the last ACT. N = number of logical banks; the MEC snoops
//! ACT/PRE commands to keep it coherent with the host controller's view.

/// Entry: `open` + last row address (+ the physical DIMM id the row maps
/// to, which MEC1 passes along with non-ACT commands for routing — §4.3).
#[derive(Debug, Clone, Copy, Default)]
pub struct BstEntry {
    pub open: bool,
    pub row: u32,
}

#[derive(Debug, Clone)]
pub struct BankStateTable {
    entries: Vec<BstEntry>,
}

impl BankStateTable {
    pub fn new(num_banks: u32) -> BankStateTable {
        BankStateTable { entries: vec![BstEntry::default(); num_banks as usize] }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Record an ACT: bank opens `row`.
    pub fn on_act(&mut self, bank: u32, row: u32) {
        let e = &mut self.entries[bank as usize];
        e.open = true;
        e.row = row;
    }

    /// Record a PRE: bank closes (row retained for debug only).
    pub fn on_pre(&mut self, bank: u32) {
        self.entries[bank as usize].open = false;
    }

    /// Row to use when reconstructing a RD/WR address on `bank`.
    /// Returns `None` if the MEC never saw an ACT (protocol violation —
    /// the host controller must open a row before column commands).
    pub fn open_row(&self, bank: u32) -> Option<u32> {
        let e = self.entries[bank as usize];
        if e.open {
            Some(e.row)
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn act_then_rd_reconstructs_row() {
        let mut bst = BankStateTable::new(16);
        bst.on_act(3, 0x1a2);
        assert_eq!(bst.open_row(3), Some(0x1a2));
        assert_eq!(bst.open_row(4), None);
    }

    #[test]
    fn pre_closes() {
        let mut bst = BankStateTable::new(16);
        bst.on_act(0, 7);
        bst.on_pre(0);
        assert_eq!(bst.open_row(0), None);
    }

    #[test]
    fn reopen_replaces_row() {
        let mut bst = BankStateTable::new(4);
        bst.on_act(1, 10);
        bst.on_pre(1);
        bst.on_act(1, 20);
        assert_eq!(bst.open_row(1), Some(20));
    }

    #[test]
    fn sized_per_logical_bank() {
        let bst = BankStateTable::new(64);
        assert_eq!(bst.len(), 64);
    }
}
