//! Simulation statistics: counters, histograms, time-weighted averages,
//! and the table / CSV renderers used by the figure-reproduction benches.

pub mod bench;
pub mod hist;
pub mod table;

pub use hist::Histogram;
pub use table::Table;

use crate::util::time::{ps_to_s, Ps};
use std::collections::BTreeMap;

/// A named bag of monotonically increasing counters.
///
/// `BTreeMap` keeps deterministic iteration order for reporting.
#[derive(Debug, Default, Clone)]
pub struct Counters {
    map: BTreeMap<&'static str, u64>,
}

impl Counters {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn add(&mut self, key: &'static str, v: u64) {
        *self.map.entry(key).or_insert(0) += v;
    }

    #[inline]
    pub fn inc(&mut self, key: &'static str) {
        self.add(key, 1);
    }

    #[inline]
    pub fn get(&self, key: &str) -> u64 {
        self.map.get(key).copied().unwrap_or(0)
    }

    pub fn iter(&self) -> impl Iterator<Item = (&'static str, u64)> + '_ {
        self.map.iter().map(|(k, v)| (*k, *v))
    }

    /// Merge another counter bag into this one (used when aggregating
    /// per-core stats into a platform total).
    pub fn merge(&mut self, other: &Counters) {
        for (k, v) in other.iter() {
            self.add(k, v);
        }
    }

    /// Ratio of two counters, `0.0` when the denominator is zero.
    pub fn ratio(&self, num: &str, den: &str) -> f64 {
        let d = self.get(den);
        if d == 0 {
            0.0
        } else {
            self.get(num) as f64 / d as f64
        }
    }

    /// Misses per kilo-instruction style metric against an explicit
    /// instruction count (the paper normalizes TL-OoO MPKI to *Ideal*
    /// retired instructions, so the denominator must be injectable).
    pub fn mpki(&self, miss_key: &str, instructions: u64) -> f64 {
        if instructions == 0 {
            0.0
        } else {
            self.get(miss_key) as f64 * 1000.0 / instructions as f64
        }
    }
}

/// Time-weighted running average of an integer level (e.g. outstanding
/// off-core reads, Figure 11). Integrates `level × dt`.
#[derive(Debug, Clone)]
pub struct LevelMeter {
    level: u64,
    last_change: Ps,
    integral: u128,
    peak: u64,
}

impl LevelMeter {
    pub fn new() -> Self {
        LevelMeter { level: 0, last_change: 0, integral: 0, peak: 0 }
    }

    /// Record that the level changed to `level` at time `now`.
    pub fn set(&mut self, now: Ps, level: u64) {
        debug_assert!(now >= self.last_change, "time went backwards");
        self.integral += self.level as u128 * (now - self.last_change) as u128;
        self.level = level;
        self.last_change = now;
        self.peak = self.peak.max(level);
    }

    #[inline]
    pub fn up(&mut self, now: Ps) {
        self.set(now, self.level + 1);
    }

    #[inline]
    pub fn down(&mut self, now: Ps) {
        debug_assert!(self.level > 0, "level underflow");
        self.set(now, self.level - 1);
    }

    #[inline]
    pub fn level(&self) -> u64 {
        self.level
    }

    #[inline]
    pub fn peak(&self) -> u64 {
        self.peak
    }

    /// Time-weighted mean level over `[0, now]`.
    pub fn mean(&self, now: Ps) -> f64 {
        if now == 0 {
            return self.level as f64;
        }
        let integral =
            self.integral + self.level as u128 * (now.saturating_sub(self.last_change)) as u128;
        integral as f64 / now as f64
    }
}

impl Default for LevelMeter {
    fn default() -> Self {
        Self::new()
    }
}

/// Byte-rate meter for bandwidth reporting (Figure 12).
#[derive(Debug, Default, Clone)]
pub struct RateMeter {
    bytes: u64,
}

impl RateMeter {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn add(&mut self, bytes: u64) {
        self.bytes += bytes;
    }

    #[inline]
    pub fn bytes(&self) -> u64 {
        self.bytes
    }

    /// GB/s over the elapsed interval.
    pub fn gbps(&self, elapsed: Ps) -> f64 {
        if elapsed == 0 {
            0.0
        } else {
            self.bytes as f64 / ps_to_s(elapsed) / 1e9
        }
    }
}

/// Summary statistics over a sample of f64s (benches use trimmed means).
#[derive(Debug, Clone, Copy, Default)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub stddev: f64,
    pub min: f64,
    pub max: f64,
    pub median: f64,
}

impl Summary {
    pub fn of(samples: &[f64]) -> Summary {
        if samples.is_empty() {
            return Summary::default();
        }
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = if n % 2 == 1 {
            sorted[n / 2]
        } else {
            0.5 * (sorted[n / 2 - 1] + sorted[n / 2])
        };
        Summary {
            n,
            mean,
            stddev: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            median,
        }
    }

    /// Geometric mean of strictly positive samples (the paper's "average"
    /// for normalized performance is closer to geomean semantics).
    pub fn geomean(samples: &[f64]) -> f64 {
        if samples.is_empty() {
            return 0.0;
        }
        let log_sum: f64 = samples.iter().map(|x| x.max(1e-300).ln()).sum();
        (log_sum / samples.len() as f64).exp()
    }
}

/// Mean and 95 % CLT confidence half-width (`1.96 · s / √n`, with `s`
/// the *sample* standard deviation) of a sample set — the SMARTS-style
/// sampling estimator behind the `sample_ci_*` report fields. Returns
/// `(0, 0)` for an empty sample and half-width 0 for a single sample
/// (no variance information, not "certain").
pub fn mean_ci(samples: &[f64]) -> (f64, f64) {
    let n = samples.len();
    if n == 0 {
        return (0.0, 0.0);
    }
    let mean = samples.iter().sum::<f64>() / n as f64;
    if n < 2 {
        return (mean, 0.0);
    }
    let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64;
    (mean, 1.96 * (var / n as f64).sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_add_get_merge() {
        let mut a = Counters::new();
        a.inc("x");
        a.add("x", 2);
        a.add("y", 5);
        let mut b = Counters::new();
        b.add("x", 10);
        a.merge(&b);
        assert_eq!(a.get("x"), 13);
        assert_eq!(a.get("y"), 5);
        assert_eq!(a.get("missing"), 0);
    }

    #[test]
    fn counters_ratio_and_mpki() {
        let mut c = Counters::new();
        c.add("miss", 50);
        c.add("acc", 200);
        assert_eq!(c.ratio("miss", "acc"), 0.25);
        assert_eq!(c.ratio("miss", "nothing"), 0.0);
        assert_eq!(c.mpki("miss", 10_000), 5.0);
        assert_eq!(c.mpki("miss", 0), 0.0);
    }

    #[test]
    fn level_meter_integrates() {
        let mut m = LevelMeter::new();
        m.set(0, 2); // level 2 during [0, 10)
        m.set(10, 4); // level 4 during [10, 20)
        assert_eq!(m.peak(), 4);
        let mean = m.mean(20);
        assert!((mean - 3.0).abs() < 1e-9, "mean={mean}");
    }

    #[test]
    fn level_meter_up_down() {
        let mut m = LevelMeter::new();
        m.up(0);
        m.up(5);
        m.down(10);
        assert_eq!(m.level(), 1);
        // integral: 1*5 + 2*5 = 15 over 10 => 1.5
        assert!((m.mean(10) - 1.5).abs() < 1e-9);
    }

    #[test]
    fn rate_meter_gbps() {
        let mut r = RateMeter::new();
        r.add(128);
        // 128 B over 10 ns = 12.8 GB/s
        assert!((r.gbps(10_000) - 12.8).abs() < 1e-9);
    }

    #[test]
    fn summary_of_samples() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert!((s.median - 2.5).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
    }

    #[test]
    fn geomean_basics() {
        let g = Summary::geomean(&[1.0, 4.0]);
        assert!((g - 2.0).abs() < 1e-12);
        assert_eq!(Summary::geomean(&[]), 0.0);
    }

    #[test]
    fn mean_ci_constant_stream_has_zero_width() {
        let (m, ci) = mean_ci(&[5.0; 64]);
        assert!((m - 5.0).abs() < 1e-12);
        assert_eq!(ci, 0.0, "no variance -> zero-width interval");
    }

    #[test]
    fn mean_ci_known_variance_gives_expected_half_width() {
        // Alternating ±1 around 10: sample variance n/(n-1), so the
        // half-width is 1.96 * sqrt(n/(n-1)/n) = 1.96 / sqrt(n-1).
        let n = 101usize;
        let samples: Vec<f64> =
            (0..n).map(|i| 10.0 + if i % 2 == 0 { 1.0 } else { -1.0 }).collect();
        let (m, ci) = mean_ci(&samples);
        // 51 highs, 50 lows -> mean slightly above 10.
        assert!((m - (10.0 + 1.0 / n as f64)).abs() < 1e-12);
        let s2 = samples.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (n - 1) as f64;
        let expect = 1.96 * (s2 / n as f64).sqrt();
        assert!((ci - expect).abs() < 1e-12, "ci={ci} expect={expect}");
        // And the closed-form sanity bound: just under 1.96/sqrt(n-1).
        assert!((ci - 1.96 / (n as f64 - 1.0).sqrt()).abs() < 1e-3);
    }

    #[test]
    fn mean_ci_degenerate_inputs() {
        assert_eq!(mean_ci(&[]), (0.0, 0.0));
        let (m, ci) = mean_ci(&[3.25]);
        assert_eq!(m, 3.25);
        assert_eq!(ci, 0.0, "one sample carries no variance information");
    }
}
