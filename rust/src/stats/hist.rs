//! Power-of-two bucketed histogram for latency distributions.

/// Histogram with log2 buckets: bucket `i` holds values in `[2^i, 2^(i+1))`
/// (bucket 0 holds 0 and 1). Cheap enough to sit on the hot path.
#[derive(Debug, Clone)]
pub struct Histogram {
    buckets: [u64; 64],
    count: u64,
    sum: u128,
    min: u64,
    max: u64,
}

impl Histogram {
    pub fn new() -> Self {
        Histogram { buckets: [0; 64], count: 0, sum: 0, min: u64::MAX, max: 0 }
    }

    #[inline]
    pub fn record(&mut self, v: u64) {
        let b = 64 - v.max(1).leading_zeros() as usize - 1;
        self.buckets[b] += 1;
        self.count += 1;
        self.sum += v as u128;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    #[inline]
    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    pub fn min(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> u64 {
        self.max
    }

    /// Approximate quantile: the geometric midpoint of the bucket
    /// containing the q-th sample, clamped to the observed `[min, max]`.
    ///
    /// Reporting the bucket's *upper* bound (the previous behavior) put a
    /// systematic up-to-2x upward bias on every quantile — a sample of
    /// identical values `v` reported `2^(i+1)-1` instead of `v`. The
    /// geometric midpoint `2^i * sqrt(2)` is the log-space center of
    /// `[2^i, 2^(i+1))`, and the clamp makes single-bucket distributions
    /// exact at the edges (`min == max` reports the value itself).
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = ((self.count as f64) * q.clamp(0.0, 1.0)).ceil() as u64;
        let mut seen = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target.max(1) {
                let mid = if i >= 63 {
                    u64::MAX
                } else {
                    // Geometric midpoint of [2^i, 2^(i+1)), rounded.
                    ((1u64 << i) as f64 * std::f64::consts::SQRT_2).round() as u64
                };
                return mid.clamp(self.min, self.max);
            }
        }
        self.max
    }

    pub fn merge(&mut self, other: &Histogram) {
        for i in 0..64 {
            self.buckets[i] += other.buckets[i];
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// Non-empty buckets as `(lower_bound, count)` for rendering.
    pub fn nonzero_buckets(&self) -> Vec<(u64, u64)> {
        self.buckets
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, &c)| (if i == 0 { 0 } else { 1u64 << i }, c))
            .collect()
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_mean() {
        let mut h = Histogram::new();
        for v in [1, 2, 3, 4] {
            h.record(v);
        }
        assert_eq!(h.count(), 4);
        assert!((h.mean() - 2.5).abs() < 1e-12);
        assert_eq!(h.min(), 1);
        assert_eq!(h.max(), 4);
    }

    #[test]
    fn zero_value_goes_to_bucket_zero() {
        let mut h = Histogram::new();
        h.record(0);
        assert_eq!(h.count(), 1);
        assert_eq!(h.nonzero_buckets(), vec![(0, 1)]);
    }

    #[test]
    fn quantile_monotone() {
        let mut h = Histogram::new();
        for v in 1..=1000u64 {
            h.record(v);
        }
        let p50 = h.quantile(0.5);
        let p99 = h.quantile(0.99);
        assert!(p50 <= p99);
        assert!(p50 >= 256 && p50 <= 1023, "p50={p50}");
        // Quantiles are monotone in q across the whole range.
        let mut prev = 0;
        for q in [0.0, 0.1, 0.25, 0.5, 0.9, 0.99, 0.999, 1.0] {
            let v = h.quantile(q);
            assert!(v >= prev, "quantile({q}) = {v} < {prev}");
            prev = v;
        }
    }

    #[test]
    fn quantile_exact_on_single_bucket_samples() {
        // A distribution of identical values must report the value
        // itself, not the bucket's upper bound (which overstated by up
        // to 2x: 100 sits in [64, 128) and used to report 127).
        for v in [1u64, 7, 100, 1_000, 1 << 40] {
            let mut h = Histogram::new();
            for _ in 0..10 {
                h.record(v);
            }
            for q in [0.5, 0.99, 0.999] {
                assert_eq!(h.quantile(q), v, "quantile({q}) of constant {v}");
            }
        }
    }

    #[test]
    fn quantile_midpoint_stays_within_observed_range() {
        // Mixed sample: every quantile stays inside [min, max], and a
        // bucket's estimate is its geometric midpoint (not its edge).
        let mut h = Histogram::new();
        h.record(1);
        for _ in 0..100 {
            h.record(800); // bucket [512, 1024)
        }
        let p50 = h.quantile(0.5);
        assert!(p50 >= h.min() && p50 <= h.max());
        // Geometric midpoint of [512, 1024) is round(512 * sqrt(2)) = 724.
        assert_eq!(p50, 724);
        assert_eq!(h.quantile(0.0), 1, "q=0 clamps down to the observed min");
    }

    #[test]
    fn merge_combines() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        a.record(10);
        b.record(20);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert_eq!(a.max(), 20);
        assert_eq!(a.min(), 10);
    }

    #[test]
    fn empty_histogram_defaults() {
        let h = Histogram::new();
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.quantile(0.5), 0);
        assert_eq!(h.min(), 0);
    }
}
