//! `BENCH_*.json` model and the CI perf-regression gate.
//!
//! `benches/hotpath.rs` emits a small hand-rolled JSON document (the
//! vendored registry carries no serde); this module parses that subset,
//! models the rows, and implements the gate the `perf_gate` binary and
//! the `make perf-gate` / CI step run: compare a fresh
//! `BENCH_hotpath.json` against the checked-in `BENCH_baseline.json`
//! and fail on large throughput regressions.
//!
//! Two rule families:
//!
//! * **Baseline rule** — per matching row, fail when median throughput
//!   drops more than [`MAX_REGRESSION`] below the baseline. A baseline
//!   marked `"provisional": true` (placeholder numbers, not yet measured
//!   on the CI runner class) only fails on catastrophic (>
//!   [`PROVISIONAL_FACTOR`]×) slowdowns and downgrades the rest to
//!   warnings.
//! * **Pair rule** — machine-independent: an optimized engine/policy row
//!   (`… [calendar]`, `… [bank-indexed]`, `… [frontend]`, `… [sharded]`)
//!   must not run slower than its retained reference row (`… [ref-heap]`,
//!   `… [ref-scan]`, `… [frontend-ref]`, `… [calendar]`) measured in the
//!   same process,
//!   beyond a small [`PAIR_TOLERANCE`] noise band. This holds even while
//!   the baseline is provisional.

/// Hard-fail threshold for the baseline rule: >25 % median regression.
pub const MAX_REGRESSION: f64 = 0.25;
/// Provisional baselines only catch catastrophic (>4×) slowdowns.
pub const PROVISIONAL_FACTOR: f64 = 4.0;
/// Pair rule hard floor: the optimized row must reach at least 85 % of
/// its reference row's throughput (CI-runner noise band on top of the
/// "no slower" target; anything between the floor and parity is
/// reported as a warning, not a failure).
pub const PAIR_TOLERANCE: f64 = 0.85;

/// (reference suffix, optimized suffix) row-name pairs the pair rule
/// checks within one run. A reference row may anchor several optimized
/// rows (e.g. both calendar variants against the heap, both candidate-
/// cache invalidation granularities against the full scan).
const ENGINE_PAIRS: &[(&str, &str)] = &[
    (" [ref-heap]", " [calendar]"),
    (" [ref-heap]", " [adaptive]"),
    (" [ref-scan]", " [bank-indexed]"),
    (" [ref-scan]", " [rank-inval]"),
    (" [frontend-ref]", " [frontend]"),
    // The sharded engine is bit-identical to calendar by construction,
    // so the only thing left to gate is throughput: at >= 2 channel
    // groups it must not lose to the single-thread calendar engine
    // beyond the noise band (a single-CPU runner degrades sharded to
    // serial pumping, and the tolerance absorbs its dispatch overhead).
    (" [calendar]", " [sharded]"),
];

// ---------------------------------------------------------------------
// Minimal JSON (subset) parser.
// ---------------------------------------------------------------------

/// Parsed JSON value (subset: no number niceties beyond f64).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { s: text.as_bytes(), i: 0 };
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.s.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kv) => kv.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s.as_str()),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

struct Parser<'a> {
    s: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.s.len() && self.s[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.s.get(self.i).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", b as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected input at byte {}", self.i)),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.s[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.s[start..self.i]).map_err(|e| e.to_string())?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| format!("bad number '{text}' at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        // Accumulate bytes so multi-byte UTF-8 runs pass through intact.
        let mut out: Vec<u8> = Vec::new();
        let mut buf = [0u8; 4];
        loop {
            let Some(c) = self.peek() else {
                return Err("unterminated string".into());
            };
            self.i += 1;
            match c {
                b'"' => {
                    return String::from_utf8(out).map_err(|_| "invalid UTF-8 in string".into())
                }
                b'\\' => {
                    let Some(esc) = self.peek() else {
                        return Err("unterminated escape".into());
                    };
                    self.i += 1;
                    let ch = match esc {
                        b'"' => '"',
                        b'\\' => '\\',
                        b'/' => '/',
                        b'n' => '\n',
                        b't' => '\t',
                        b'r' => '\r',
                        b'b' => '\u{8}',
                        b'f' => '\u{c}',
                        b'u' => {
                            if self.i + 4 > self.s.len() {
                                return Err("truncated \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.s[self.i..self.i + 4])
                                .map_err(|e| e.to_string())?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| format!("bad \\u escape '{hex}'"))?;
                            self.i += 4;
                            char::from_u32(code).unwrap_or('\u{fffd}')
                        }
                        other => return Err(format!("bad escape '\\{}'", other as char)),
                    };
                    out.extend_from_slice(ch.encode_utf8(&mut buf).as_bytes());
                }
                _ => out.push(c),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut kv = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(kv));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            kv.push((k, v));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(kv));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

// ---------------------------------------------------------------------
// Bench-report model.
// ---------------------------------------------------------------------

/// One benchmark row (median across the run's trials).
#[derive(Debug, Clone)]
pub struct BenchRow {
    pub name: String,
    pub seconds: f64,
    pub units: f64,
    pub unit: String,
    pub units_per_s: f64,
    pub trials: u32,
}

/// A parsed `BENCH_*.json` document.
#[derive(Debug, Clone)]
pub struct BenchReport {
    pub bench: String,
    /// Placeholder baseline not yet measured on the CI runner class:
    /// the baseline rule downgrades to catastrophic-only.
    pub provisional: bool,
    pub rows: Vec<BenchRow>,
}

impl BenchReport {
    pub fn parse(text: &str) -> Result<BenchReport, String> {
        let root = Json::parse(text)?;
        let bench = root.get("bench").and_then(Json::as_str).unwrap_or("").to_string();
        let provisional = root.get("provisional").and_then(Json::as_bool).unwrap_or(false);
        let Some(Json::Arr(raw_rows)) = root.get("rows") else {
            return Err("missing 'rows' array".into());
        };
        let mut rows = Vec::with_capacity(raw_rows.len());
        for (i, r) in raw_rows.iter().enumerate() {
            let name = r
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| format!("row {i}: missing 'name'"))?
                .to_string();
            let seconds = r.get("seconds").and_then(Json::as_f64).unwrap_or(0.0);
            let units = r.get("units").and_then(Json::as_f64).unwrap_or(0.0);
            let unit = r.get("unit").and_then(Json::as_str).unwrap_or("").to_string();
            let units_per_s = match r.get("units_per_s").and_then(Json::as_f64) {
                Some(v) => v,
                None if seconds > 0.0 => units / seconds,
                None => 0.0,
            };
            let trials = r.get("trials").and_then(Json::as_f64).unwrap_or(1.0) as u32;
            rows.push(BenchRow { name, seconds, units, unit, units_per_s, trials });
        }
        Ok(BenchReport { bench, provisional, rows })
    }

    pub fn row(&self, name: &str) -> Option<&BenchRow> {
        self.rows.iter().find(|r| r.name == name)
    }
}

// ---------------------------------------------------------------------
// The gate.
// ---------------------------------------------------------------------

/// Outcome of one gate evaluation.
#[derive(Debug, Default)]
pub struct GateReport {
    /// Per-row comparison lines (informational).
    pub lines: Vec<String>,
    /// Non-fatal notes (missing rows, provisional downgrades).
    pub warnings: Vec<String>,
    /// Hard failures; non-empty means the CI step must fail.
    pub failures: Vec<String>,
}

impl GateReport {
    pub fn passed(&self) -> bool {
        self.failures.is_empty()
    }
}

/// Compare a fresh bench run against the checked-in baseline.
pub fn perf_gate(current: &BenchReport, baseline: &BenchReport) -> GateReport {
    let mut g = GateReport::default();

    // Baseline rule: per-row median throughput vs the baseline.
    for base in &baseline.rows {
        let Some(cur) = current.row(&base.name) else {
            g.warnings.push(format!("baseline row '{}' missing from current run", base.name));
            continue;
        };
        if base.units_per_s <= 0.0 {
            g.warnings.push(format!("baseline row '{}' has no throughput; skipped", base.name));
            continue;
        }
        let ratio = cur.units_per_s / base.units_per_s;
        g.lines.push(format!(
            "{:<40} baseline {:>14.0}/s   current {:>14.0}/s   ({:+.1} %)",
            base.name,
            base.units_per_s,
            cur.units_per_s,
            (ratio - 1.0) * 100.0
        ));
        if ratio < 1.0 - MAX_REGRESSION {
            let msg = format!(
                "'{}' regressed {:.0} % vs baseline ({:.0}/s -> {:.0}/s)",
                base.name,
                (1.0 - ratio) * 100.0,
                base.units_per_s,
                cur.units_per_s
            );
            if !baseline.provisional {
                g.failures.push(msg);
            } else if ratio < 1.0 / PROVISIONAL_FACTOR {
                g.failures.push(format!("{msg} [catastrophic; provisional baseline]"));
            } else {
                g.warnings.push(format!("{msg} [provisional baseline: warning only]"));
            }
        }
    }
    for cur in &current.rows {
        if baseline.row(&cur.name).is_none() {
            g.warnings.push(format!("no baseline for new row '{}'", cur.name));
        }
    }

    // Pair rule: optimized engines/policies must keep up with their
    // retained reference implementations measured in the same run.
    for reference in &current.rows {
        for (ref_sfx, fast_sfx) in ENGINE_PAIRS {
            let Some(stem) = reference.name.strip_suffix(ref_sfx) else {
                continue;
            };
            let partner = format!("{stem}{fast_sfx}");
            let Some(fast) = current.row(&partner) else {
                g.warnings.push(format!(
                    "'{}' has no optimized partner row '{partner}'",
                    reference.name
                ));
                continue;
            };
            if reference.units_per_s <= 0.0 {
                continue;
            }
            let speedup = fast.units_per_s / reference.units_per_s;
            g.lines.push(format!(
                "{partner:<40} {speedup:>6.2}x its reference implementation"
            ));
            if speedup < PAIR_TOLERANCE {
                g.failures.push(format!(
                    "'{partner}' slower than its reference '{}': {:.0}/s vs {:.0}/s ({:.2}x)",
                    reference.name, fast.units_per_s, reference.units_per_s, speedup
                ));
            } else if speedup < 1.0 {
                g.warnings.push(format!(
                    "'{partner}' within the noise floor of its reference ({speedup:.2}x)"
                ));
            }
        }
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report(rows: &[(&str, f64)], provisional: bool) -> BenchReport {
        BenchReport {
            bench: "hotpath".into(),
            provisional,
            rows: rows
                .iter()
                .map(|&(name, rate)| BenchRow {
                    name: name.into(),
                    seconds: 1.0,
                    units: rate,
                    unit: "op".into(),
                    units_per_s: rate,
                    trials: 3,
                })
                .collect(),
        }
    }

    #[test]
    fn parses_the_emitted_format() {
        let text = r#"{
  "bench": "hotpath",
  "provisional": true,
  "rows": [
    {"name": "sim tl-ooo/gups [calendar]", "seconds": 0.5, "units": 1000,
     "unit": "logical-op", "units_per_s": 2000.0, "trials": 3},
    {"name": "quote \" backslash \\", "seconds": 2, "units": 10, "unit": "op"}
  ]
}
"#;
        let r = BenchReport::parse(text).unwrap();
        assert_eq!(r.bench, "hotpath");
        assert!(r.provisional);
        assert_eq!(r.rows.len(), 2);
        let row = r.row("sim tl-ooo/gups [calendar]").unwrap();
        assert_eq!(row.units_per_s, 2000.0);
        assert_eq!(row.trials, 3);
        // units_per_s derived when absent; default trials = 1.
        let q = &r.rows[1];
        assert_eq!(q.name, "quote \" backslash \\");
        assert_eq!(q.units_per_s, 5.0);
        assert_eq!(q.trials, 1);
    }

    #[test]
    fn rejects_malformed_documents() {
        assert!(BenchReport::parse("{").is_err());
        assert!(BenchReport::parse("{\"bench\": \"x\"}").is_err()); // no rows
        assert!(BenchReport::parse("{\"rows\": [{\"seconds\": 1}]}").is_err()); // no name
        assert!(Json::parse("[1, 2,]").is_err());
        assert!(Json::parse("{\"a\": 1} trailing").is_err());
    }

    #[test]
    fn flat_run_passes() {
        let base = report(&[("a", 100.0), ("b", 200.0)], false);
        let cur = report(&[("a", 101.0), ("b", 190.0)], false);
        let g = perf_gate(&cur, &base);
        assert!(g.passed(), "{:?}", g.failures);
        assert_eq!(g.lines.len(), 2);
    }

    #[test]
    fn small_regression_within_threshold_passes() {
        let base = report(&[("a", 100.0)], false);
        let cur = report(&[("a", 80.0)], false); // -20 % < 25 %
        assert!(perf_gate(&cur, &base).passed());
    }

    #[test]
    fn large_regression_fails_the_gate() {
        let base = report(&[("a", 100.0), ("b", 100.0)], false);
        let cur = report(&[("a", 70.0), ("b", 100.0)], false); // -30 %
        let g = perf_gate(&cur, &base);
        assert!(!g.passed());
        assert_eq!(g.failures.len(), 1);
        assert!(g.failures[0].contains("'a'"), "{}", g.failures[0]);
    }

    #[test]
    fn provisional_baseline_downgrades_to_warning() {
        let base = report(&[("a", 100.0)], true);
        let cur = report(&[("a", 50.0)], false); // -50 %: warn, don't fail
        let g = perf_gate(&cur, &base);
        assert!(g.passed(), "{:?}", g.failures);
        assert_eq!(g.warnings.len(), 1);
        assert!(g.warnings[0].contains("provisional"));
    }

    #[test]
    fn provisional_baseline_still_catches_catastrophic_slowdowns() {
        let base = report(&[("a", 100.0)], true);
        let cur = report(&[("a", 20.0)], false); // 5x below
        let g = perf_gate(&cur, &base);
        assert!(!g.passed());
        assert!(g.failures[0].contains("catastrophic"));
    }

    #[test]
    fn pair_rule_fails_when_optimized_engine_lags_reference() {
        let rows = report(
            &[("event engine [calendar]", 50.0), ("event engine [ref-heap]", 100.0)],
            false,
        );
        let g = perf_gate(&rows, &rows); // baseline == current: no regressions
        assert!(!g.passed());
        assert!(g.failures[0].contains("event engine [calendar]"), "{}", g.failures[0]);
    }

    #[test]
    fn pair_rule_passes_when_optimized_engine_keeps_up() {
        for policy_pair in [
            [("event engine [calendar]", 300.0), ("event engine [ref-heap]", 100.0)],
            [("event engine [adaptive]", 290.0), ("event engine [ref-heap]", 100.0)],
            [("dram controller [bank-indexed]", 95.0), ("dram controller [ref-scan]", 100.0)],
            [("dram controller [rank-inval]", 95.0), ("dram controller [ref-scan]", 100.0)],
        ] {
            let rows = report(&policy_pair, false);
            let g = perf_gate(&rows, &rows);
            assert!(g.passed(), "{:?}", g.failures);
        }
    }

    #[test]
    fn pair_rule_covers_the_frontend_pair() {
        let lagging = report(
            &[
                ("sim tl-ooo/gups [frontend]", 50.0),
                ("sim tl-ooo/gups [frontend-ref]", 100.0),
            ],
            false,
        );
        let g = perf_gate(&lagging, &lagging);
        assert!(!g.passed(), "slab front end lagging its reference must fail");
        assert!(g.failures[0].contains("[frontend]"), "{}", g.failures[0]);

        let healthy = report(
            &[
                ("sim tl-ooo/gups [frontend]", 120.0),
                ("sim tl-ooo/gups [frontend-ref]", 100.0),
            ],
            false,
        );
        assert!(perf_gate(&healthy, &healthy).passed());
    }

    #[test]
    fn pair_rule_checks_every_optimized_row_of_a_shared_reference() {
        // One reference row anchors two optimized rows; a lagging
        // adaptive engine must fail even when the fixed calendar wins.
        let rows = report(
            &[
                ("event engine [calendar]", 300.0),
                ("event engine [adaptive]", 50.0),
                ("event engine [ref-heap]", 100.0),
            ],
            false,
        );
        let g = perf_gate(&rows, &rows);
        assert!(!g.passed());
        assert_eq!(g.failures.len(), 1);
        assert!(g.failures[0].contains("[adaptive]"), "{}", g.failures[0]);
    }

    #[test]
    fn pair_rule_covers_the_amu_sim_rows() {
        // The AMU mechanism rows are tagged with the same engine /
        // front-end suffixes as every other sim row, so the existing
        // pair rules cover them with no new configuration: a lagging
        // optimized row under the amu workload must still fail.
        let lagging = report(
            &[
                ("sim amu/gups [calendar]", 50.0),
                ("sim amu/gups [ref-heap]", 100.0),
                ("sim amu/gups [frontend]", 120.0),
                ("sim amu/gups [frontend-ref]", 100.0),
            ],
            false,
        );
        let g = perf_gate(&lagging, &lagging);
        assert!(!g.passed());
        assert_eq!(g.failures.len(), 1);
        assert!(g.failures[0].contains("sim amu/gups [calendar]"), "{}", g.failures[0]);
    }

    #[test]
    fn pair_rule_holds_sharded_to_its_single_thread_reference() {
        // Sharded is bit-identical to calendar by construction, so the
        // gate only has to police throughput: losing to the retained
        // single-thread engine beyond the noise band fails the run.
        let lagging = report(
            &[
                ("sim ideal/gups [calendar]", 100.0),
                ("sim ideal/gups [sharded]", 50.0),
            ],
            false,
        );
        let g = perf_gate(&lagging, &lagging);
        assert!(!g.passed(), "sharded losing to calendar must fail");
        assert!(g.failures[0].contains("[sharded]"), "{}", g.failures[0]);

        // Within the tolerance band (a serial-pumping single-CPU
        // runner): sub-parity is a warning, not a failure.
        let healthy = report(
            &[
                ("sim ideal/gups [calendar]", 100.0),
                ("sim ideal/gups [sharded]", 90.0),
            ],
            false,
        );
        let g = perf_gate(&healthy, &healthy);
        assert!(g.passed(), "{:?}", g.failures);
        assert!(
            g.warnings.iter().any(|w| w.contains("noise floor")),
            "{:?}",
            g.warnings
        );
    }

    #[test]
    fn missing_rows_warn_but_do_not_fail() {
        let base = report(&[("gone", 100.0)], false);
        let cur = report(&[("new", 100.0)], false);
        let g = perf_gate(&cur, &base);
        assert!(g.passed());
        assert_eq!(g.warnings.len(), 2);
    }
}
