//! Plain-text table and CSV rendering for figure/table reproduction output.
//!
//! The benches print the same rows/series the paper reports; this module
//! keeps the formatting in one place (aligned text for humans, CSV for
//! downstream plotting).

/// A simple column-aligned table builder.
#[derive(Debug, Clone)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    /// Convenience: row from displayable items.
    pub fn rowd<D: std::fmt::Display>(&mut self, cells: &[D]) -> &mut Self {
        let cells: Vec<String> = cells.iter().map(|c| c.to_string()).collect();
        self.row(&cells)
    }

    pub fn num_rows(&self) -> usize {
        self.rows.len()
    }

    /// Render aligned human-readable text.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for i in 0..ncol {
                if i > 0 {
                    line.push_str("  ");
                }
                let pad = widths[i].saturating_sub(cells[i].len());
                line.push_str(&cells[i]);
                line.push_str(&" ".repeat(pad));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncol - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    /// Render CSV (RFC-4180-ish; quotes cells containing commas).
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| -> String {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        out.push_str(&self.header.iter().map(|h| esc(h)).collect::<Vec<_>>().join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }

    /// Write CSV next to the repo's `results/` directory; best-effort.
    pub fn save_csv(&self, path: &str) -> std::io::Result<()> {
        if let Some(parent) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.to_csv())
    }
}

/// Format a float with 3 significant decimals (figure output convention).
pub fn f3(v: f64) -> String {
    format!("{v:.3}")
}

/// Format a float with 2 decimals.
pub fn f2(v: f64) -> String {
    format!("{v:.2}")
}

/// Format a ratio as `x.xx×`.
pub fn times(v: f64) -> String {
    format!("{v:.2}x")
}

/// Format a percentage.
pub fn pct(v: f64) -> String {
    format!("{:.1}%", v * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_contains_cells() {
        let mut t = Table::new("Demo", &["name", "value"]);
        t.row(&["gups".into(), "0.74".into()]);
        let s = t.render();
        assert!(s.contains("Demo"));
        assert!(s.contains("gups"));
        assert!(s.contains("0.74"));
    }

    #[test]
    fn csv_escapes_commas() {
        let mut t = Table::new("x", &["a"]);
        t.row(&["v,1".into()]);
        assert!(t.to_csv().contains("\"v,1\""));
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn formatters() {
        assert_eq!(f3(0.12345), "0.123");
        assert_eq!(f2(1.005), "1.00"); // banker-ish rounding is fine
        assert_eq!(times(2.5), "2.50x");
        assert_eq!(pct(0.256), "25.6%");
    }

    #[test]
    fn rowd_display_rows() {
        let mut t = Table::new("n", &["a", "b"]);
        t.rowd(&[1, 2]);
        assert_eq!(t.num_rows(), 1);
        assert!(t.to_csv().contains("1,2"));
    }
}
