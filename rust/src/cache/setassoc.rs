//! Set-associative cache with true-LRU replacement and writeback.

use super::DataKind;
use crate::util::log2_exact;

/// Geometry + behaviour of one cache level.
#[derive(Debug, Clone, Copy)]
pub struct CacheConfig {
    pub size_bytes: u64,
    pub ways: u32,
    pub line_bytes: u64,
}

impl CacheConfig {
    /// Per-core L1D: 32 KiB, 8-way.
    pub fn l1d() -> CacheConfig {
        CacheConfig { size_bytes: 32 << 10, ways: 8, line_bytes: 64 }
    }

    /// Scaled shared LLC. The paper host has a 15 MiB LLC for ~16 GB
    /// footprints; we scale footprints by 64× (DESIGN.md), so 256 KiB–2 MiB
    /// keeps the miss regime equivalent. Default 1 MiB, 16-way.
    pub fn llc_scaled() -> CacheConfig {
        CacheConfig { size_bytes: 1 << 20, ways: 16, line_bytes: 64 }
    }

    pub fn sets(&self) -> u64 {
        self.size_bytes / (self.ways as u64 * self.line_bytes)
    }
}

#[derive(Debug, Clone, Copy)]
struct Line {
    tag: u64,
    valid: bool,
    dirty: bool,
    content: DataKind,
    /// LRU stamp: larger = more recently used.
    stamp: u64,
}

const INVALID: Line =
    Line { tag: 0, valid: false, dirty: false, content: DataKind::Real, stamp: 0 };

/// A victim evicted by a fill.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Evicted {
    pub addr: u64,
    pub dirty: bool,
}

/// Result of a lookup.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LookupResult {
    Hit(DataKind),
    Miss,
}

#[derive(Debug, Clone)]
pub struct SetAssocCache {
    cfg: CacheConfig,
    lines: Vec<Line>,
    set_bits: u32,
    line_bits: u32,
    clock: u64,
    pub hits: u64,
    pub misses: u64,
    pub writebacks: u64,
}

impl SetAssocCache {
    pub fn new(cfg: CacheConfig) -> SetAssocCache {
        let sets = cfg.sets();
        assert!(sets.is_power_of_two(), "set count must be a power of two");
        SetAssocCache {
            lines: vec![INVALID; (sets * cfg.ways as u64) as usize],
            set_bits: log2_exact(sets),
            line_bits: log2_exact(cfg.line_bytes),
            cfg,
            clock: 0,
            hits: 0,
            misses: 0,
            writebacks: 0,
        }
    }

    #[inline]
    fn set_of(&self, addr: u64) -> usize {
        (((addr >> self.line_bits) & ((1 << self.set_bits) - 1)) * self.cfg.ways as u64) as usize
    }

    #[inline]
    fn tag_of(&self, addr: u64) -> u64 {
        addr >> (self.line_bits + self.set_bits)
    }

    #[inline]
    fn line_addr(&self, tag: u64, set_index: u64) -> u64 {
        (tag << (self.line_bits + self.set_bits)) | (set_index << self.line_bits)
    }

    /// Look up `addr`; a hit refreshes LRU and optionally sets dirty.
    pub fn access(&mut self, addr: u64, write: bool) -> LookupResult {
        self.clock += 1;
        let base = self.set_of(addr);
        let tag = self.tag_of(addr);
        for i in base..base + self.cfg.ways as usize {
            let line = &mut self.lines[i];
            if line.valid && line.tag == tag {
                line.stamp = self.clock;
                if write {
                    line.dirty = true;
                }
                self.hits += 1;
                return LookupResult::Hit(line.content);
            }
        }
        self.misses += 1;
        LookupResult::Miss
    }

    /// Peek without updating LRU or counters.
    pub fn probe(&self, addr: u64) -> Option<DataKind> {
        let base = self.set_of(addr);
        let tag = self.tag_of(addr);
        self.lines[base..base + self.cfg.ways as usize]
            .iter()
            .find(|l| l.valid && l.tag == tag)
            .map(|l| l.content)
    }

    /// Install `addr`; returns the evicted victim if one was displaced.
    pub fn fill(&mut self, addr: u64, dirty: bool, content: DataKind) -> Option<Evicted> {
        self.clock += 1;
        let base = self.set_of(addr);
        let tag = self.tag_of(addr);
        // Refill over an existing copy (e.g. write-allocate race) just updates.
        let mut victim_i = base;
        let mut victim_stamp = u64::MAX;
        for i in base..base + self.cfg.ways as usize {
            let line = &self.lines[i];
            if line.valid && line.tag == tag {
                let line = &mut self.lines[i];
                line.stamp = self.clock;
                line.dirty |= dirty;
                line.content = content;
                return None;
            }
            if !line.valid {
                victim_i = i;
                victim_stamp = 0;
            } else if line.stamp < victim_stamp {
                victim_i = i;
                victim_stamp = line.stamp;
            }
        }
        let set_index = ((addr >> self.line_bits) & ((1 << self.set_bits) - 1)) as u64;
        let old = self.lines[victim_i];
        let evicted = if old.valid {
            if old.dirty {
                self.writebacks += 1;
            }
            Some(Evicted { addr: self.line_addr(old.tag, set_index), dirty: old.dirty })
        } else {
            None
        };
        self.lines[victim_i] =
            Line { tag, valid: true, dirty, content, stamp: self.clock };
        evicted
    }

    /// Invalidate the line holding `addr` (twin-load retry path uses this
    /// clflush-equivalent). Returns true if a line was dropped; dirty data
    /// is counted as a writeback.
    pub fn invalidate(&mut self, addr: u64) -> bool {
        let base = self.set_of(addr);
        let tag = self.tag_of(addr);
        for i in base..base + self.cfg.ways as usize {
            let line = &mut self.lines[i];
            if line.valid && line.tag == tag {
                if line.dirty {
                    self.writebacks += 1;
                }
                *line = INVALID;
                return true;
            }
        }
        false
    }

    /// Update the content flag of a resident line (MEC data arrival).
    pub fn set_content(&mut self, addr: u64, content: DataKind) -> bool {
        let base = self.set_of(addr);
        let tag = self.tag_of(addr);
        for i in base..base + self.cfg.ways as usize {
            let line = &mut self.lines[i];
            if line.valid && line.tag == tag {
                line.content = content;
                return true;
            }
        }
        false
    }

    pub fn miss_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.misses as f64 / total as f64
        }
    }

    pub fn config(&self) -> &CacheConfig {
        &self.cfg
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> SetAssocCache {
        // 4 sets x 2 ways x 64B = 512 B
        SetAssocCache::new(CacheConfig { size_bytes: 512, ways: 2, line_bytes: 64 })
    }

    #[test]
    fn miss_then_hit_after_fill() {
        let mut c = tiny();
        assert_eq!(c.access(0x1000, false), LookupResult::Miss);
        assert!(c.fill(0x1000, false, DataKind::Real).is_none());
        assert_eq!(c.access(0x1000, false), LookupResult::Hit(DataKind::Real));
        assert_eq!(c.hits, 1);
        assert_eq!(c.misses, 1);
    }

    #[test]
    fn lru_evicts_least_recent() {
        let mut c = tiny();
        // Three lines mapping to set 0 (set stride = 4 sets * 64 = 256).
        let a = 0x0000;
        let b = 0x0400;
        let d = 0x0800;
        c.fill(a, false, DataKind::Real);
        c.fill(b, false, DataKind::Real);
        c.access(a, false); // a most recent
        let ev = c.fill(d, false, DataKind::Real).expect("must evict");
        assert_eq!(ev.addr, b, "b was LRU");
        assert!(c.probe(a).is_some());
        assert!(c.probe(b).is_none());
    }

    #[test]
    fn dirty_eviction_reports_writeback() {
        let mut c = tiny();
        c.fill(0x0000, false, DataKind::Real);
        c.access(0x0000, true); // dirty it
        c.fill(0x0400, false, DataKind::Real);
        let ev = c.fill(0x0800, false, DataKind::Real).unwrap();
        assert!(ev.dirty);
        assert_eq!(c.writebacks, 1);
    }

    #[test]
    fn invalidate_drops_line() {
        let mut c = tiny();
        c.fill(0x40, false, DataKind::Fake);
        assert!(c.invalidate(0x40));
        assert!(!c.invalidate(0x40));
        assert_eq!(c.access(0x40, false), LookupResult::Miss);
    }

    #[test]
    fn content_flag_tracked() {
        let mut c = tiny();
        c.fill(0x80, false, DataKind::Fake);
        assert_eq!(c.probe(0x80), Some(DataKind::Fake));
        assert!(c.set_content(0x80, DataKind::Real));
        assert_eq!(c.access(0x80, false), LookupResult::Hit(DataKind::Real));
    }

    #[test]
    fn refill_existing_updates_in_place() {
        let mut c = tiny();
        c.fill(0xC0, false, DataKind::Fake);
        assert!(c.fill(0xC0, true, DataKind::Real).is_none());
        assert_eq!(c.probe(0xC0), Some(DataKind::Real));
    }

    #[test]
    fn distinct_sets_do_not_conflict() {
        let mut c = tiny();
        for i in 0..4u64 {
            c.fill(i * 64, false, DataKind::Real);
        }
        for i in 0..4u64 {
            assert!(c.probe(i * 64).is_some());
        }
    }

    #[test]
    fn miss_rate_math() {
        let mut c = tiny();
        c.access(0, false);
        c.fill(0, false, DataKind::Real);
        c.access(0, false);
        assert!((c.miss_rate() - 0.5).abs() < 1e-12);
    }
}
