//! Miss Status Holding Registers.
//!
//! The MSHR file bounds how many distinct line misses can be outstanding —
//! the processor's "available memory access concurrency" the paper says
//! TL-OoO exploits (§6.1, Figure 11). Secondary misses to an in-flight
//! line merge instead of consuming a new entry.

use crate::util::FastMap;

/// Outcome of requesting an MSHR for a line address.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MshrOutcome {
    /// New entry allocated — issue the memory request.
    Allocated,
    /// Same line already in flight — merged; do not issue.
    Merged,
    /// File full — the requester must stall.
    Full,
}

#[derive(Debug, Clone)]
pub struct MshrFile {
    capacity: usize,
    /// line address -> number of merged waiters.
    entries: FastMap<u64, u32>,
    pub peak: usize,
    pub allocs: u64,
    pub merges: u64,
    pub stalls: u64,
}

impl MshrFile {
    pub fn new(capacity: usize) -> MshrFile {
        MshrFile {
            capacity,
            entries: FastMap::with_capacity_and_hasher(capacity * 2, Default::default()),
            peak: 0,
            allocs: 0,
            merges: 0,
            stalls: 0,
        }
    }

    /// Xeon-class line-fill buffer count per core (the paper's host).
    pub fn xeon_core() -> MshrFile {
        MshrFile::new(10)
    }

    pub fn request(&mut self, line_addr: u64) -> MshrOutcome {
        if let Some(w) = self.entries.get_mut(&line_addr) {
            *w += 1;
            self.merges += 1;
            return MshrOutcome::Merged;
        }
        if self.entries.len() >= self.capacity {
            self.stalls += 1;
            return MshrOutcome::Full;
        }
        self.entries.insert(line_addr, 1);
        self.allocs += 1;
        self.peak = self.peak.max(self.entries.len());
        MshrOutcome::Allocated
    }

    /// Retire the entry for `line_addr`; returns the waiter count (primary
    /// + merged) that should be woken.
    pub fn complete(&mut self, line_addr: u64) -> u32 {
        self.entries.remove(&line_addr).unwrap_or(0)
    }

    #[inline]
    pub fn outstanding(&self) -> usize {
        self.entries.len()
    }

    #[inline]
    pub fn is_full(&self) -> bool {
        self.entries.len() >= self.capacity
    }

    #[inline]
    pub fn pending(&self, line_addr: u64) -> bool {
        self.entries.contains_key(&line_addr)
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allocate_merge_complete() {
        let mut m = MshrFile::new(2);
        assert_eq!(m.request(0x40), MshrOutcome::Allocated);
        assert_eq!(m.request(0x40), MshrOutcome::Merged);
        assert_eq!(m.outstanding(), 1);
        assert_eq!(m.complete(0x40), 2);
        assert_eq!(m.outstanding(), 0);
    }

    #[test]
    fn full_file_stalls() {
        let mut m = MshrFile::new(2);
        assert_eq!(m.request(0x00), MshrOutcome::Allocated);
        assert_eq!(m.request(0x40), MshrOutcome::Allocated);
        assert!(m.is_full());
        assert_eq!(m.request(0x80), MshrOutcome::Full);
        assert_eq!(m.stalls, 1);
        // Completion frees a slot.
        m.complete(0x00);
        assert_eq!(m.request(0x80), MshrOutcome::Allocated);
    }

    #[test]
    fn peak_tracks_high_water() {
        let mut m = MshrFile::new(4);
        m.request(0x00);
        m.request(0x40);
        m.request(0x80);
        m.complete(0x00);
        assert_eq!(m.peak, 3);
    }

    #[test]
    fn complete_unknown_is_zero() {
        let mut m = MshrFile::new(2);
        assert_eq!(m.complete(0x123), 0);
    }

    #[test]
    fn pending_query() {
        let mut m = MshrFile::new(2);
        m.request(0x40);
        assert!(m.pending(0x40));
        assert!(!m.pending(0x80));
    }
}
