//! TLB model (paper Figure 10).
//!
//! Twin-load doubles the virtual footprint of extended-memory data (every
//! object also has a shadow mapping at `p + EXT_MEM_SIZE`), which the paper
//! shows roughly doubles TLB misses for extended-heavy workloads. A
//! set-associative 512-entry TLB with 4 KiB pages reproduces that effect;
//! coverage = 2 MiB, matching §6.1's "2MB for a 512-entry TLB".

use crate::util::log2_exact;

#[derive(Debug, Clone, Copy)]
struct TlbEntry {
    vpn: u64,
    valid: bool,
    stamp: u64,
}

#[derive(Debug, Clone)]
pub struct Tlb {
    entries: Vec<TlbEntry>,
    ways: u32,
    set_bits: u32,
    page_bits: u32,
    clock: u64,
    pub hits: u64,
    pub misses: u64,
}

impl Tlb {
    pub fn new(num_entries: u32, ways: u32, page_bytes: u64) -> Tlb {
        assert!(num_entries % ways == 0);
        let sets = (num_entries / ways) as u64;
        assert!(sets.is_power_of_two());
        Tlb {
            entries: vec![TlbEntry { vpn: 0, valid: false, stamp: 0 }; num_entries as usize],
            ways,
            set_bits: log2_exact(sets),
            page_bits: log2_exact(page_bytes),
            clock: 0,
            hits: 0,
            misses: 0,
        }
    }

    /// The paper host's DTLB-ish configuration: 512 entries, 4 KiB pages.
    pub fn xeon_dtlb() -> Tlb {
        Tlb::new(512, 4, 4 << 10)
    }

    /// Coverage in bytes (entries × page size).
    pub fn coverage(&self) -> u64 {
        self.entries.len() as u64 * (1u64 << self.page_bits)
    }

    /// Translate `vaddr`: returns true on hit; a miss installs the entry
    /// (LRU within set) — the page-walk cost is charged by the caller.
    pub fn access(&mut self, vaddr: u64) -> bool {
        self.clock += 1;
        let vpn = vaddr >> self.page_bits;
        let set = (vpn & ((1 << self.set_bits) - 1)) as usize * self.ways as usize;
        let tag = vpn >> self.set_bits;
        let mut victim = set;
        let mut victim_stamp = u64::MAX;
        for i in set..set + self.ways as usize {
            let e = &mut self.entries[i];
            if e.valid && e.vpn == tag {
                e.stamp = self.clock;
                self.hits += 1;
                return true;
            }
            let s = if e.valid { e.stamp } else { 0 };
            if s < victim_stamp {
                victim_stamp = s;
                victim = i;
            }
        }
        self.misses += 1;
        self.entries[victim] = TlbEntry { vpn: tag, valid: true, stamp: self.clock };
        false
    }

    /// Flush everything (context switch / retry-path fence tests).
    pub fn flush(&mut self) {
        for e in &mut self.entries {
            e.valid = false;
        }
    }

    pub fn miss_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.misses as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hit_after_install() {
        let mut t = Tlb::new(16, 4, 4096);
        assert!(!t.access(0x1000));
        assert!(t.access(0x1fff)); // same page
        assert!(!t.access(0x2000)); // next page
    }

    #[test]
    fn xeon_coverage_is_2mb() {
        let t = Tlb::xeon_dtlb();
        assert_eq!(t.coverage(), 2 << 20);
    }

    #[test]
    fn working_set_beyond_coverage_thrashes() {
        let mut t = Tlb::new(16, 4, 4096); // 64 KiB coverage
        // Sweep 128 pages twice: second sweep still misses heavily.
        for _ in 0..2 {
            for p in 0..128u64 {
                t.access(p * 4096);
            }
        }
        assert!(t.miss_rate() > 0.9, "rate={}", t.miss_rate());
    }

    #[test]
    fn working_set_within_coverage_hits() {
        let mut t = Tlb::new(16, 4, 4096);
        for _ in 0..10 {
            for p in 0..8u64 {
                t.access(p * 4096);
            }
        }
        assert!(t.miss_rate() < 0.15, "rate={}", t.miss_rate());
    }

    #[test]
    fn doubling_footprint_past_coverage_explodes_misses() {
        // The Figure-10 mechanism: a footprint within coverage mostly hits;
        // doubling it past coverage (shadow space!) thrashes the TLB.
        let mut fits = Tlb::new(64, 4, 4096); // 64-page coverage
        let mut thrash = Tlb::new(64, 4, 4096);
        let mut rng = crate::util::Rng::new(3);
        for _ in 0..20_000 {
            fits.access((rng.below(48)) * 4096);
            thrash.access((rng.below(96)) * 4096);
        }
        let ratio = thrash.misses as f64 / fits.misses.max(1) as f64;
        assert!(ratio > 2.0, "ratio={ratio}");
    }

    #[test]
    fn flush_invalidates() {
        let mut t = Tlb::new(16, 4, 4096);
        t.access(0x1000);
        t.flush();
        assert!(!t.access(0x1000));
    }
}
