//! Processor-side memory hierarchy models: set-associative caches, MSHRs
//! (the structure that bounds memory-level parallelism — Figure 11 is an
//! MSHR-occupancy plot), and the TLB (Figure 10).
//!
//! Cache lines carry a [`DataKind`] so the simulator can track which lines
//! currently hold *fake* twin-load placeholder data vs real data — the
//! four cache states of paper Table 2 fall out of this bookkeeping.

pub mod mshr;
pub mod setassoc;
pub mod tlb;

pub use mshr::{MshrFile, MshrOutcome};
pub use setassoc::{CacheConfig, Evicted, LookupResult, SetAssocCache};
pub use tlb::Tlb;

/// Content carried by a cache line in extended/shadow space.
///
/// `Fake` is the MEC placeholder pattern (the paper uses repetitive 0x5a);
/// lines in local memory are always `Real`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DataKind {
    Real,
    Fake,
}

impl DataKind {
    pub fn is_real(self) -> bool {
        self == DataKind::Real
    }
}
