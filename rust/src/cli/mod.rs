//! Minimal CLI argument parser (the vendored registry has no clap).
//!
//! Grammar: `binary <subcommand> [--flag value]... [--switch]... [pos]...`
//! Flags known to take values are declared by the caller; everything
//! else starting with `--` is a boolean switch.

use std::collections::{HashMap, HashSet};

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    flags: HashMap<String, String>,
    switches: HashSet<String>,
}

impl Args {
    /// Parse argv (excluding the binary name). `value_flags` lists flags
    /// that consume the next token.
    pub fn parse<I: IntoIterator<Item = String>>(
        argv: I,
        value_flags: &[&str],
    ) -> Result<Args, String> {
        let mut args = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                } else if value_flags.contains(&name) {
                    let v = it
                        .next()
                        .ok_or_else(|| format!("--{name} expects a value"))?;
                    args.flags.insert(name.to_string(), v);
                } else {
                    args.switches.insert(name.to_string());
                }
            } else if args.subcommand.is_none() {
                args.subcommand = Some(tok);
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    pub fn get(&self, flag: &str) -> Option<&str> {
        self.flags.get(flag).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, flag: &str, default: &'a str) -> &'a str {
        self.get(flag).unwrap_or(default)
    }

    pub fn get_u64(&self, flag: &str) -> Result<Option<u64>, String> {
        match self.get(flag) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| format!("--{flag}: '{v}' is not an integer")),
        }
    }

    pub fn get_f64(&self, flag: &str) -> Result<Option<f64>, String> {
        match self.get(flag) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| format!("--{flag}: '{v}' is not a number")),
        }
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.contains(switch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(
            s.split_whitespace().map(|t| t.to_string()),
            &["workload", "ops", "frac"],
        )
        .unwrap()
    }

    #[test]
    fn subcommand_flags_switches() {
        let a = parse("run --workload gups --ops 100 --quick fig7");
        assert_eq!(a.subcommand.as_deref(), Some("run"));
        assert_eq!(a.get("workload"), Some("gups"));
        assert_eq!(a.get_u64("ops").unwrap(), Some(100));
        assert!(a.has("quick"));
        assert_eq!(a.positional, vec!["fig7"]);
    }

    #[test]
    fn equals_form() {
        let a = parse("run --workload=bfs --frac=0.5");
        assert_eq!(a.get("workload"), Some("bfs"));
        assert_eq!(a.get_f64("frac").unwrap(), Some(0.5));
    }

    #[test]
    fn missing_value_is_error() {
        let r = Args::parse(
            vec!["run".to_string(), "--workload".to_string()],
            &["workload"],
        );
        assert!(r.is_err());
    }

    #[test]
    fn bad_number_is_error() {
        let a = parse("run --ops abc");
        assert!(a.get_u64("ops").is_err());
    }

    #[test]
    fn defaults() {
        let a = parse("run");
        assert_eq!(a.get_or("workload", "gups"), "gups");
        assert!(!a.has("quick"));
    }
}
