//! Minimal INI-style config parser (the vendored registry has no serde/
//! toml, so we carry a small, strict `key = value` + `[section]` format).
//!
//! ```ini
//! [system]
//! mechanism = tl-ooo
//! cores = 4
//!
//! [run]
//! workload = gups
//! footprint_mb = 64
//! ops = 100000
//! seed = 7
//! ```

use std::collections::BTreeMap;

/// Parsed file: section → key → value.
#[derive(Debug, Default, Clone)]
pub struct Ini {
    sections: BTreeMap<String, BTreeMap<String, String>>,
}

impl Ini {
    pub fn parse(text: &str) -> Result<Ini, String> {
        let mut ini = Ini::default();
        let mut section = String::from("");
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[') {
                let name = name
                    .strip_suffix(']')
                    .ok_or_else(|| format!("line {}: unterminated section", lineno + 1))?;
                section = name.trim().to_string();
                ini.sections.entry(section.clone()).or_default();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
            ini.sections
                .entry(section.clone())
                .or_default()
                .insert(k.trim().to_string(), v.trim().to_string());
        }
        Ok(ini)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&str> {
        self.sections.get(section)?.get(key).map(|s| s.as_str())
    }

    pub fn get_u64(&self, section: &str, key: &str) -> Result<Option<u64>, String> {
        match self.get(section, key) {
            None => Ok(None),
            Some(v) => v
                .parse::<u64>()
                .map(Some)
                .map_err(|_| format!("{section}.{key}: '{v}' is not an integer")),
        }
    }

    pub fn get_f64(&self, section: &str, key: &str) -> Result<Option<f64>, String> {
        match self.get(section, key) {
            None => Ok(None),
            Some(v) => v
                .parse::<f64>()
                .map(Some)
                .map_err(|_| format!("{section}.{key}: '{v}' is not a number")),
        }
    }

    pub fn sections(&self) -> impl Iterator<Item = &str> {
        self.sections.keys().map(|s| s.as_str())
    }
}

/// Apply `[system]` / `[run]` overrides from an INI file to a base
/// config + spec. Unknown keys are an error (catches typos).
pub fn apply(
    ini: &Ini,
    cfg: &mut super::SystemConfig,
    spec: &mut super::RunSpec,
) -> Result<(), String> {
    if let Some(sys) = ini.sections.get("system") {
        // `mechanism` resets the whole config, so apply it before any
        // refining key regardless of file/map order.
        if let Some(v) = sys.get("mechanism") {
            *cfg = super::SystemConfig::by_name(v)
                .ok_or_else(|| format!("unknown mechanism '{v}'"))?;
        }
        for (k, v) in sys {
            match k.as_str() {
                "mechanism" => {}
                "cores" => cfg.cores = v.parse().map_err(|_| "bad cores")?,
                "smt" => cfg.smt = v.parse().map_err(|_| "bad smt")?,
                "mshrs" => cfg.mshrs_per_core = v.parse().map_err(|_| "bad mshrs")?,
                "lvc_entries" => cfg.mec.lvc_entries = v.parse().map_err(|_| "bad lvc")?,
                "mec_layers" => {
                    cfg.mec.topology.layers = v.parse().map_err(|_| "bad layers")?
                }
                "pcie_local_frac" => {
                    cfg.pcie_local_frac = v.parse().map_err(|_| "bad frac")?
                }
                "trl_extra_ns" => {
                    cfg.trl_extra =
                        v.parse::<u64>().map_err(|_| "bad trl_extra_ns")? * 1_000
                }
                "amu_depth" => cfg.amu_depth = v.parse().map_err(|_| "bad amu_depth")?,
                "amu_issue_ns" => {
                    cfg.amu_issue =
                        v.parse::<u64>().map_err(|_| "bad amu_issue_ns")? * 1_000
                }
                "amu_notify_ns" => {
                    cfg.amu_notify =
                        v.parse::<u64>().map_err(|_| "bad amu_notify_ns")? * 1_000
                }
                "amu_svc_ps" => {
                    cfg.amu_svc = v.parse::<u64>().map_err(|_| "bad amu_svc_ps")?
                }
                "mims_pack" => {
                    let pack = v.parse().map_err(|_| "bad mims_pack")?;
                    cfg.mims_pack = pack;
                    // The mechanism payload carries the pack into the
                    // lowering layer; keep them in lockstep.
                    if let crate::twinload::Mechanism::Mims(_) = cfg.mechanism {
                        cfg.mechanism = crate::twinload::Mechanism::Mims(pack);
                    }
                }
                "mims_frame_ns" => {
                    cfg.mims_frame =
                        v.parse::<u64>().map_err(|_| "bad mims_frame_ns")? * 1_000
                }
                "mims_granule" => {
                    cfg.mims_granule = v.parse().map_err(|_| "bad mims_granule")?
                }
                "fault_rate" => {
                    cfg.fault_rate = v.parse().map_err(|_| "bad fault_rate")?
                }
                "fault_ecc_rate" => {
                    cfg.fault_ecc_rate = v.parse().map_err(|_| "bad fault_ecc_rate")?
                }
                "fault_seed" => {
                    cfg.fault_seed = v.parse().map_err(|_| "bad fault_seed")?
                }
                "demote_after" => {
                    cfg.demote_after = v.parse().map_err(|_| "bad demote_after")?
                }
                "fault_poll_timeout_ns" => {
                    cfg.fault_poll_timeout =
                        v.parse::<u64>().map_err(|_| "bad fault_poll_timeout_ns")? * 1_000
                }
                "fault_reissue_max" => {
                    cfg.fault_reissue_max =
                        v.parse().map_err(|_| "bad fault_reissue_max")?
                }
                "fault_backoff_mult" => {
                    cfg.fault_backoff_mult =
                        v.parse().map_err(|_| "bad fault_backoff_mult")?
                }
                "burst_rate" => {
                    cfg.burst_rate = v.parse().map_err(|_| "bad burst_rate")?
                }
                "burst_len_ns" => {
                    cfg.burst_len =
                        v.parse::<u64>().map_err(|_| "bad burst_len_ns")? * 1_000
                }
                "burst_slow_mult" => {
                    cfg.burst_slow_mult =
                        v.parse().map_err(|_| "bad burst_slow_mult")?
                }
                "quarantine_threshold" => {
                    cfg.quarantine_threshold =
                        v.parse().map_err(|_| "bad quarantine_threshold")?
                }
                "probe_ok" => {
                    cfg.probe_ok = v.parse().map_err(|_| "bad probe_ok")?
                }
                "slo_p99_us" => {
                    cfg.slo_p99_us = v.parse().map_err(|_| "bad slo_p99_us")?
                }
                "routing" => {
                    cfg.routing = crate::sim::backend::Routing::by_name(v)
                        .ok_or_else(|| format!("unknown routing '{v}'"))?
                }
                "engine" => {
                    cfg.engine = crate::sim::engine::EngineKind::by_name(v)
                        .ok_or_else(|| format!("unknown engine '{v}'"))?
                }
                "sched" => {
                    cfg.sched = crate::dram::SchedPolicy::by_name(v)
                        .ok_or_else(|| format!("unknown sched policy '{v}'"))?
                }
                "frontend" => {
                    cfg.frontend = crate::cpu::FrontEnd::by_name(v)
                        .ok_or_else(|| format!("unknown frontend '{v}'"))?
                }
                other => return Err(format!("unknown [system] key '{other}'")),
            }
        }
    }
    if let Some(run) = ini.sections.get("run") {
        for (k, v) in run {
            match k.as_str() {
                "workload" => {
                    spec.workload = crate::workloads::WorkloadKind::from_name(v)
                        .ok_or_else(|| format!("unknown workload '{v}'"))?;
                }
                "footprint_mb" => {
                    spec.footprint =
                        v.parse::<u64>().map_err(|_| "bad footprint_mb")? << 20
                }
                "ops" => spec.ops_per_core = v.parse().map_err(|_| "bad ops")?,
                "seed" => spec.seed = v.parse().map_err(|_| "bad seed")?,
                "arrival" => {
                    spec.arrival = crate::workloads::arrival::ArrivalKind::by_name(v)
                        .ok_or_else(|| format!("unknown arrival process '{v}'"))?
                }
                "offered_rps" => {
                    spec.offered_rps = v.parse().map_err(|_| "bad offered_rps")?
                }
                "zipf_theta" => {
                    spec.zipf_theta = v.parse().map_err(|_| "bad zipf_theta")?
                }
                "arrival_seed" => {
                    spec.arrival_seed = v.parse().map_err(|_| "bad arrival_seed")?
                }
                "queue_depth" => {
                    spec.queue_depth = v.parse().map_err(|_| "bad queue_depth")?
                }
                "sample_period" => {
                    spec.sample_period = v.parse().map_err(|_| "bad sample_period")?
                }
                "sample_warmup" => {
                    spec.sample_warmup = v.parse().map_err(|_| "bad sample_warmup")?
                }
                "sample_detail" => {
                    spec.sample_detail = v.parse().map_err(|_| "bad sample_detail")?
                }
                "sample_seed" => {
                    spec.sample_seed = v.parse().map_err(|_| "bad sample_seed")?
                }
                other => return Err(format!("unknown [run] key '{other}'")),
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{RunSpec, SystemConfig};
    use crate::workloads::WorkloadKind;

    #[test]
    fn parse_sections_and_comments() {
        let ini = Ini::parse("# top\n[a]\nx = 1 # trailing\n\n[b]\ny = hello\n").unwrap();
        assert_eq!(ini.get("a", "x"), Some("1"));
        assert_eq!(ini.get("b", "y"), Some("hello"));
        assert_eq!(ini.get_u64("a", "x").unwrap(), Some(1));
        assert_eq!(ini.get("a", "missing"), None);
    }

    #[test]
    fn rejects_malformed() {
        assert!(Ini::parse("[unterminated\n").is_err());
        assert!(Ini::parse("keyonly\n").is_err());
        assert!(Ini::parse("[s]\nx = notanum\n").unwrap().get_u64("s", "x").is_err());
    }

    #[test]
    fn apply_overrides() {
        let ini = Ini::parse(
            "[system]\nmechanism = tl-lf\ncores = 2\n[run]\nworkload = bfs\nops = 5\nseed = 9\nfootprint_mb = 32\n",
        )
        .unwrap();
        let mut cfg = SystemConfig::ideal();
        let mut spec = RunSpec::smoke(WorkloadKind::Gups);
        apply(&ini, &mut cfg, &mut spec).unwrap();
        assert_eq!(cfg.mechanism.name(), "tl-lf");
        assert_eq!(cfg.cores, 2);
        assert_eq!(spec.workload, WorkloadKind::Bfs);
        assert_eq!(spec.ops_per_core, 5);
        assert_eq!(spec.footprint, 32 << 20);
    }

    #[test]
    fn engine_key_selects_event_engine() {
        use crate::sim::engine::EngineKind;
        let ini = Ini::parse("[system]\nengine = reference-heap\n").unwrap();
        let mut cfg = SystemConfig::ideal();
        let mut spec = RunSpec::smoke(WorkloadKind::Gups);
        apply(&ini, &mut cfg, &mut spec).unwrap();
        assert_eq!(cfg.engine, EngineKind::ReferenceHeap);
        let bad = Ini::parse("[system]\nengine = bogus\n").unwrap();
        assert!(apply(&bad, &mut cfg, &mut spec).is_err());
    }

    #[test]
    fn sched_key_selects_scheduler_policy() {
        use crate::dram::SchedPolicy;
        let ini = Ini::parse("[system]\nsched = reference-scan\n").unwrap();
        let mut cfg = SystemConfig::ideal();
        let mut spec = RunSpec::smoke(WorkloadKind::Gups);
        apply(&ini, &mut cfg, &mut spec).unwrap();
        assert_eq!(cfg.sched, SchedPolicy::ReferenceScan);
        let bad = Ini::parse("[system]\nsched = bogus\n").unwrap();
        assert!(apply(&bad, &mut cfg, &mut spec).is_err());
    }

    #[test]
    fn frontend_key_selects_request_tracking() {
        use crate::cpu::FrontEnd;
        let ini = Ini::parse("[system]\nfrontend = reference\n").unwrap();
        let mut cfg = SystemConfig::ideal();
        let mut spec = RunSpec::smoke(WorkloadKind::Gups);
        apply(&ini, &mut cfg, &mut spec).unwrap();
        assert_eq!(cfg.frontend, FrontEnd::Reference);
        let back = Ini::parse("[system]\nfrontend = slab\n").unwrap();
        apply(&back, &mut cfg, &mut spec).unwrap();
        assert_eq!(cfg.frontend, FrontEnd::Slab);
        let bad = Ini::parse("[system]\nfrontend = bogus\n").unwrap();
        assert!(apply(&bad, &mut cfg, &mut spec).is_err());
    }

    #[test]
    fn amu_keys_configure_the_async_unit() {
        let ini = Ini::parse(
            "[system]\nmechanism = amu\namu_depth = 8\namu_issue_ns = 20\n\
             amu_notify_ns = 5\namu_svc_ps = 2500\n",
        )
        .unwrap();
        let mut cfg = SystemConfig::ideal();
        let mut spec = RunSpec::smoke(WorkloadKind::Gups);
        apply(&ini, &mut cfg, &mut spec).unwrap();
        assert_eq!(cfg.mechanism.name(), "amu");
        assert_eq!(cfg.amu_depth, 8);
        assert_eq!(cfg.amu_issue, 20_000);
        assert_eq!(cfg.amu_notify, 5_000);
        assert_eq!(cfg.amu_svc, 2_500);
        let bad = Ini::parse("[system]\namu_depth = lots\n").unwrap();
        assert!(apply(&bad, &mut cfg, &mut spec).is_err());
    }

    #[test]
    fn fault_keys_configure_the_injection_layer() {
        let ini = Ini::parse(
            "[system]\nmechanism = tl-ooo\nfault_rate = 0.05\nfault_ecc_rate = 0.01\n\
             fault_seed = 99\ndemote_after = 3\nfault_poll_timeout_ns = 150\n\
             fault_reissue_max = 6\nfault_backoff_mult = 3\n",
        )
        .unwrap();
        let mut cfg = SystemConfig::ideal();
        let mut spec = RunSpec::smoke(WorkloadKind::Gups);
        apply(&ini, &mut cfg, &mut spec).unwrap();
        assert_eq!(cfg.fault_rate, 0.05);
        assert_eq!(cfg.fault_ecc_rate, 0.01);
        assert_eq!(cfg.fault_seed, 99);
        assert_eq!(cfg.demote_after, 3);
        assert_eq!(cfg.fault_poll_timeout, 150_000);
        assert_eq!(cfg.fault_reissue_max, 6);
        assert_eq!(cfg.fault_backoff_mult, 3);
        for bad in [
            "[system]\nfault_rate = lots\n",
            "[system]\nfault_ecc_rate = x\n",
            "[system]\nfault_seed = -1\n",
            "[system]\ndemote_after = soon\n",
            "[system]\nfault_poll_timeout_ns = never\n",
            "[system]\nfault_reissue_max = 1.5\n",
            "[system]\nfault_backoff_mult = two\n",
        ] {
            let ini = Ini::parse(bad).unwrap();
            assert!(apply(&ini, &mut cfg, &mut spec).is_err(), "accepted {bad}");
        }
    }

    #[test]
    fn burst_keys_configure_the_correlated_layer() {
        let ini = Ini::parse(
            "[system]\nmechanism = tl-ooo\nburst_rate = 0.2\nburst_len_ns = 2500\n\
             burst_slow_mult = 6\nquarantine_threshold = 0.5\nprobe_ok = 4\n\
             slo_p99_us = 250\n",
        )
        .unwrap();
        let mut cfg = SystemConfig::ideal();
        let mut spec = RunSpec::smoke(WorkloadKind::Gups);
        apply(&ini, &mut cfg, &mut spec).unwrap();
        assert_eq!(cfg.burst_rate, 0.2);
        assert_eq!(cfg.burst_len, 2_500_000, "burst_len_ns must scale to ps");
        assert_eq!(cfg.burst_slow_mult, 6);
        assert_eq!(cfg.quarantine_threshold, 0.5);
        assert_eq!(cfg.probe_ok, 4);
        assert_eq!(cfg.slo_p99_us, 250);
        for bad in [
            "[system]\nburst_rate = sometimes\n",
            "[system]\nburst_len_ns = -3\n",
            "[system]\nburst_len_ns = 2.5\n",
            "[system]\nburst_slow_mult = fast\n",
            "[system]\nquarantine_threshold = maybe\n",
            "[system]\nprobe_ok = 1.5\n",
            "[system]\nslo_p99_us = tight\n",
        ] {
            let ini = Ini::parse(bad).unwrap();
            assert!(apply(&ini, &mut cfg, &mut spec).is_err(), "accepted {bad}");
        }
    }

    #[test]
    fn serving_keys_configure_the_open_loop_front_end() {
        use crate::workloads::arrival::ArrivalKind;
        let ini = Ini::parse(
            "[run]\nworkload = memcached\narrival = poisson\noffered_rps = 4000000\n\
             zipf_theta = 0.75\narrival_seed = 123\nqueue_depth = 32\n",
        )
        .unwrap();
        let mut cfg = SystemConfig::ideal();
        let mut spec = RunSpec::smoke(WorkloadKind::Gups);
        apply(&ini, &mut cfg, &mut spec).unwrap();
        assert_eq!(spec.workload, WorkloadKind::Memcached);
        assert_eq!(spec.arrival, ArrivalKind::Poisson);
        assert_eq!(spec.offered_rps, 4_000_000);
        assert_eq!(spec.zipf_theta, 0.75);
        assert_eq!(spec.arrival_seed, 123);
        assert_eq!(spec.queue_depth, 32);
        let back = Ini::parse("[run]\narrival = mmpp\n").unwrap();
        apply(&back, &mut cfg, &mut spec).unwrap();
        assert_eq!(spec.arrival, ArrivalKind::Mmpp);
        for bad in [
            "[run]\narrival = bogus\n",
            "[run]\noffered_rps = fast\n",
            "[run]\nzipf_theta = skewed\n",
            "[run]\narrival_seed = -1\n",
            "[run]\nqueue_depth = deep\n",
        ] {
            let ini = Ini::parse(bad).unwrap();
            assert!(apply(&ini, &mut cfg, &mut spec).is_err(), "accepted {bad}");
        }
    }

    #[test]
    fn sample_keys_configure_the_smarts_cadence() {
        let ini = Ini::parse(
            "[run]\nsample_period = 2000\nsample_warmup = 100\nsample_detail = 50\n\
             sample_seed = 77\n",
        )
        .unwrap();
        let mut cfg = SystemConfig::ideal();
        let mut spec = RunSpec::smoke(WorkloadKind::Gups);
        apply(&ini, &mut cfg, &mut spec).unwrap();
        assert_eq!(spec.sample_period, 2000);
        assert_eq!(spec.sample_warmup, 100);
        assert_eq!(spec.sample_detail, 50);
        assert_eq!(spec.sample_seed, 77);
        for bad in [
            "[run]\nsample_period = often\n",
            "[run]\nsample_warmup = -3\n",
            "[run]\nsample_detail = all\n",
            "[run]\nsample_seed = x\n",
        ] {
            let ini = Ini::parse(bad).unwrap();
            assert!(apply(&ini, &mut cfg, &mut spec).is_err(), "accepted {bad}");
        }
    }

    #[test]
    fn routing_key_selects_backend_implementation() {
        use crate::sim::backend::Routing;
        let ini = Ini::parse("[system]\nrouting = legacy\n").unwrap();
        let mut cfg = SystemConfig::ideal();
        let mut spec = RunSpec::smoke(WorkloadKind::Gups);
        apply(&ini, &mut cfg, &mut spec).unwrap();
        assert_eq!(cfg.routing, Routing::Legacy);
        let back = Ini::parse("[system]\nrouting = backend\n").unwrap();
        apply(&back, &mut cfg, &mut spec).unwrap();
        assert_eq!(cfg.routing, Routing::Backend);
        let bad = Ini::parse("[system]\nrouting = bogus\n").unwrap();
        assert!(apply(&bad, &mut cfg, &mut spec).is_err());
    }

    #[test]
    fn unknown_key_is_error() {
        let ini = Ini::parse("[system]\nbogus = 1\n").unwrap();
        let mut cfg = SystemConfig::ideal();
        let mut spec = RunSpec::smoke(WorkloadKind::Gups);
        assert!(apply(&ini, &mut cfg, &mut spec).is_err());
    }

    #[test]
    fn mims_keys_configure_the_message_interface() {
        let ini = Ini::parse(
            "[system]\nmechanism = mims\nmims_pack = 8\nmims_frame_ns = 25\n\
             mims_granule = 16\n",
        )
        .unwrap();
        let mut cfg = SystemConfig::ideal();
        let mut spec = RunSpec::smoke(WorkloadKind::Gups);
        apply(&ini, &mut cfg, &mut spec).unwrap();
        assert_eq!(cfg.mechanism.name(), "mims");
        assert_eq!(cfg.mims_pack, 8);
        // The mechanism payload follows the knob (validate() enforces
        // the lockstep this parser maintains).
        assert_eq!(cfg.mechanism, crate::twinload::Mechanism::Mims(8));
        assert_eq!(cfg.mims_frame, 25_000);
        assert_eq!(cfg.mims_granule, 16);
        cfg.validate().unwrap();
        let bad = Ini::parse("[system]\nmims_pack = lots\n").unwrap();
        assert!(apply(&bad, &mut cfg, &mut spec).is_err());
    }

    #[test]
    fn mechanism_override_order_matters() {
        // mechanism key resets the config; later keys refine it. BTreeMap
        // iterates alphabetically, so "cores" < "mechanism"… guard against
        // silent loss by checking both outcomes are consistent with docs:
        let ini = Ini::parse("[system]\nmechanism = numa\nmshrs = 4\n").unwrap();
        let mut cfg = SystemConfig::ideal();
        let mut spec = RunSpec::smoke(WorkloadKind::Gups);
        apply(&ini, &mut cfg, &mut spec).unwrap();
        assert_eq!(cfg.mechanism.name(), "numa");
        assert_eq!(cfg.mshrs_per_core, 4);
    }
}
