//! System configuration: Table-3 emulated-system presets and Table-1
//! timing presets, plus an INI-style config file loader for the CLI.

pub mod parser;

use crate::cache::CacheConfig;
use crate::cpu::{CoreParams, FrontEnd};
use crate::dram::timing::{Geometry, TimingParams, QPI_EXTRA_NS};
use crate::dram::SchedPolicy;
use crate::mec::MecConfig;
use crate::memmgr::MemLayout;
use crate::sim::backend::Routing;
use crate::sim::engine::EngineKind;
use crate::twinload::Mechanism;
use crate::util::time::{Ps, NS};
use crate::workloads::arrival::ArrivalKind;

/// Full description of one emulated system (a Table-3 column).
#[derive(Debug, Clone)]
pub struct SystemConfig {
    pub mechanism: Mechanism,
    pub layout: MemLayout,
    /// Simulated physical cores (the paper's host: one 6-core Xeon
    /// E5-2640).
    pub cores: usize,
    /// Hardware threads per core (the paper runs 12 threads on 6 2-way
    /// SMT cores). Modeled by static partitioning: each thread gets
    /// ROB/2, MSHRs/2, L1/2 and TLB/2 — no dynamic sharing benefits, but
    /// the thread-level memory parallelism that dominates TL-LF's
    /// behaviour is captured (EXPERIMENTS.md §Deviations #1).
    pub smt: usize,
    pub core: CoreParams,
    pub l1: CacheConfig,
    pub llc: CacheConfig,
    pub mshrs_per_core: usize,
    pub tlb_entries: u32,
    pub host_timing: TimingParams,
    /// Channels carrying local memory.
    pub local_channels: u32,
    /// MEC configuration (TL systems).
    pub mec: MecConfig,
    /// QPI link (NUMA system).
    pub numa_one_way: Ps,
    pub numa_gbps: f64,
    /// PCIe system: fraction of extended data resident locally.
    pub pcie_local_frac: f64,
    /// Increased-tRL system: extra read latency.
    pub trl_extra: Ps,
    /// AMU system: bounded request-queue depth.
    pub amu_depth: usize,
    /// AMU system: one-way request latency to the extended controllers.
    pub amu_issue: Ps,
    /// AMU system: completion-notify latency back to the core.
    pub amu_notify: Ps,
    /// AMU system: serial dispatch interval (one request per `amu_svc`).
    pub amu_svc: Ps,
    /// MIMS system: message packing factor (twin-load pairs per packed
    /// message; 1 degenerates to the unpacked MEC path).
    pub mims_pack: u32,
    /// MIMS system: per-message framing cost, amortized over the pack.
    pub mims_frame: Ps,
    /// MIMS system: fine-granularity transfer size in bytes (1..=64;
    /// 64 = full bursts). Sub-64 B settings model the message
    /// interface's dense transfers for pointer-chasing workloads.
    pub mims_granule: u32,
    /// Extension-memory routing implementation (the typed backend by
    /// default; the pre-refactor legacy layout is retained for
    /// differential testing).
    pub routing: Routing,
    /// Event-queue engine for the platform simulator (calendar queue by
    /// default; the adaptive calendar resamples its bucket width from
    /// observed event spacing; the reference binary heap is retained for
    /// differential testing and benchmarking).
    pub engine: EngineKind,
    /// FR-FCFS scheduler implementation for every memory controller
    /// (bank-indexed with bank-granular invalidation by default; the
    /// rank-granular and full-scan variants are retained for
    /// differential testing and benchmarking).
    pub sched: SchedPolicy,
    /// Front-end request-tracking implementation (generational slabs +
    /// intrusive waiter chains by default; the map-based path is retained
    /// for differential testing and benchmarking).
    pub frontend: FrontEnd,
    /// Content model for the TL extended channel. `true` (default)
    /// reproduces the paper's emulation (§5): extended-space lines carry
    /// real values and shadow-space lines fake ones, unconditionally —
    /// the MEC machinery still determines *timing* and statistics.
    /// `false` models real MEC1 content (first load fake, second real),
    /// which exposes the prefetcher/twin interaction and state-4 retry
    /// storms the paper's emulation cannot see (DESIGN.md §6
    /// emulation-fidelity experiment).
    pub emulate_content: bool,
    /// Extension-path fault probability per injection opportunity
    /// (not-ready responses, MEC fill drops, lost AMU notifies, PCIe
    /// transfer failures). `0.0` (default) disables injection entirely —
    /// the fault layer is structurally inert and behaviour is
    /// bit-identical to a build without it (`sim/fault.rs`).
    pub fault_rate: f64,
    /// Transient-bit-error probability per delivered demand line
    /// (detect/correct ECC model; applies to every mechanism).
    pub fault_ecc_rate: f64,
    /// Seed decorrelating the deterministic fault schedule from the
    /// workload seed.
    pub fault_seed: u64,
    /// Graceful degradation: after this many *consecutive* §4.4 twin
    /// retries on one line, demote the access to the §4.5 safe path.
    /// `0` (default) disables demotion — required for bit-identical
    /// fault-free behaviour, since content-collision retries can recur
    /// naturally on a hot line.
    pub demote_after: u32,
    /// Lost-notify recovery: software poll timeout before the first AMU
    /// reissue.
    pub fault_poll_timeout: Ps,
    /// Lost-notify recovery: bound on reissue attempts (the last attempt
    /// always delivers, guaranteeing termination).
    pub fault_reissue_max: u32,
    /// Lost-notify recovery: poll-timeout multiplier per reissue
    /// (exponential backoff).
    pub fault_backoff_mult: u32,
    /// Correlated-fault layer: probability a burst episode *starts* in
    /// any virtual-time window of a fault domain (Gilbert-Elliott bad
    /// state; see `sim/fault.rs::BurstPlan`). `0.0` (default) builds no
    /// burst state at all — structural inertness mirrors `fault_rate`.
    pub burst_rate: f64,
    /// Correlated-fault layer: virtual-time window length (INI
    /// `burst_len_ns`). Burst episodes run 1–4 windows.
    pub burst_len: Ps,
    /// Fail-slow episodes multiply service latency through the domain
    /// (backend ingress/egress seam) by this factor.
    pub burst_slow_mult: u64,
    /// Host-side health detection: quarantine a fault domain when its
    /// EWMA unhealthy-access score reaches this threshold, demoting all
    /// its traffic to the §4.5 safe path. `0.0` (default) disables the
    /// tracker; it only arms when the burst layer is armed, so
    /// `burst_rate = 0` runs stay bit-identical regardless.
    pub quarantine_threshold: f64,
    /// Half-open probation: re-admit a quarantined domain after this many
    /// consecutive clean probe observations.
    pub probe_ok: u32,
    /// Serving SLO for the second `serve`-sweep knee: highest
    /// contiguously-sustained offered load whose p99 request latency
    /// stays at or below this bound, in µs. `0` hides the SLO knee row.
    pub slo_p99_us: u64,
    // Fixed-hierarchy latencies.
    pub l1_lat: Ps,
    pub llc_lat: Ps,
    pub walk_lat: Ps,
    pub inv_lat: Ps,
    pub safe_lat: Ps,
}

impl SystemConfig {
    /// Base configuration shared by every system; mechanism-specific
    /// constructors specialize it.
    fn base(mechanism: Mechanism) -> SystemConfig {
        SystemConfig {
            mechanism,
            layout: MemLayout::sim_default(), // 128 MiB local + 256 MiB ext
            cores: 4,
            smt: 1,
            core: CoreParams::xeon(),
            l1: CacheConfig::l1d(),
            llc: CacheConfig::llc_scaled(),
            mshrs_per_core: 10,
            tlb_entries: 512,
            host_timing: TimingParams::ddr3_1600(),
            local_channels: 2,
            mec: MecConfig::default_tl(),
            numa_one_way: QPI_EXTRA_NS / 2,
            numa_gbps: 25.6, // dual QPI links on E5-2600
            pcie_local_frac: 0.75,
            trl_extra: 0,
            amu_depth: 32,
            amu_issue: 10 * NS,
            amu_notify: 10 * NS,
            amu_svc: 1_250,
            mims_pack: 4,
            mims_frame: 10 * NS,
            mims_granule: 64,
            routing: Routing::Backend,
            engine: EngineKind::Calendar,
            sched: SchedPolicy::BankIndexed,
            frontend: FrontEnd::Slab,
            emulate_content: true,
            fault_rate: 0.0,
            fault_ecc_rate: 0.0,
            fault_seed: 0xF417_ED,
            demote_after: 0,
            fault_poll_timeout: 200 * NS,
            fault_reissue_max: 4,
            fault_backoff_mult: 2,
            burst_rate: 0.0,
            burst_len: 5_000 * NS,
            burst_slow_mult: 8,
            quarantine_threshold: 0.0,
            probe_ok: 8,
            slo_p99_us: 500,
            l1_lat: 1_600,      // 4 cycles @ 2.5 GHz
            llc_lat: 14 * NS,   // ~35 cycles
            walk_lat: 40 * NS,  // page walk on TLB miss
            inv_lat: 20 * NS,   // clflush-ish
            safe_lat: 500 * NS, // 3 serialized uncacheable MMIO ops (§4.5)
        }
    }

    /// Ideal: all memory locally attached.
    pub fn ideal() -> SystemConfig {
        Self::base(Mechanism::Ideal)
    }

    /// TL-OoO: twin-load, out-of-order twins.
    pub fn tl_ooo() -> SystemConfig {
        Self::base(Mechanism::TlOoO)
    }

    /// TL-LF: twin-load with a load fence.
    pub fn tl_lf() -> SystemConfig {
        Self::base(Mechanism::TlLf)
    }

    /// §6.1 future-work batched TL-LF.
    pub fn tl_lf_batched(k: u32) -> SystemConfig {
        Self::base(Mechanism::TlLfBatched(k))
    }

    /// NUMA: extended memory behind one QPI hop.
    pub fn numa() -> SystemConfig {
        Self::base(Mechanism::Numa)
    }

    /// PCIe page swapping with the given locally-resident fraction.
    pub fn pcie(local_frac: f64) -> SystemConfig {
        let mut c = Self::base(Mechanism::Pcie);
        c.pcie_local_frac = local_frac.clamp(0.0, 1.0);
        c
    }

    /// §7.2: single loads with tRL increased by `extra`.
    pub fn increased_trl(extra: Ps) -> SystemConfig {
        let mut c = Self::base(Mechanism::IncreasedTrl);
        c.trl_extra = extra;
        c
    }

    /// AMU-style asynchronous access unit (explicit request/notify).
    pub fn amu() -> SystemConfig {
        Self::base(Mechanism::Amu)
    }

    /// MIMS-style message interface with the given packing factor.
    pub fn mims_packed(pack: u32) -> SystemConfig {
        let mut c = Self::base(Mechanism::Mims(pack));
        c.mims_pack = pack;
        c
    }

    /// MIMS-style message interface at the default packing factor.
    pub fn mims() -> SystemConfig {
        let pack = Self::base(Mechanism::Ideal).mims_pack;
        Self::mims_packed(pack)
    }

    pub fn by_name(name: &str) -> Option<SystemConfig> {
        match name {
            "ideal" => Some(Self::ideal()),
            "tl-ooo" => Some(Self::tl_ooo()),
            "tl-lf" => Some(Self::tl_lf()),
            "tl-lf-batched" => Some(Self::tl_lf_batched(8)),
            "numa" => Some(Self::numa()),
            "pcie" => Some(Self::pcie(0.75)),
            "inc-trl" => Some(Self::increased_trl(35 * NS)),
            "amu" => Some(Self::amu()),
            "mims" => Some(Self::mims()),
            _ => None,
        }
    }

    /// Geometry of one local-class channel (local_size / channels).
    pub fn local_channel_geometry(&self) -> Geometry {
        geometry_for(self.layout.local_size / self.local_channels as u64)
    }

    /// Geometry of the MEC host channel: extended + shadow space.
    pub fn mec_channel_geometry(&self) -> Geometry {
        geometry_for(2 * self.layout.ext_size)
    }

    /// Geometry of one ext-class channel for Ideal/NUMA (ext over the
    /// host's four channels, as the paper's emulation places it).
    pub fn ext_channel_geometry(&self) -> Geometry {
        geometry_for(self.layout.ext_size / 4)
    }

    pub fn validate(&self) -> Result<(), String> {
        self.host_timing.validate()?;
        if self.cores == 0 {
            return Err("cores must be positive".into());
        }
        if !self.layout.ext_size.is_power_of_two() {
            return Err("ext size must be a power of two".into());
        }
        if self.mechanism == Mechanism::Amu && self.amu_depth == 0 {
            return Err("amu_depth must be at least 1".into());
        }
        if let Mechanism::Mims(k) = self.mechanism {
            if k == 0 || self.mims_pack == 0 {
                return Err("mims_pack must be at least 1".into());
            }
            if k != self.mims_pack {
                return Err("mechanism packing factor disagrees with mims_pack".into());
            }
            if self.mims_granule == 0 || self.mims_granule > 64 {
                return Err("mims_granule must be in 1..=64 bytes".into());
            }
        }
        if !(0.0..=1.0).contains(&self.fault_rate) {
            return Err("fault_rate must be within [0, 1]".into());
        }
        if !(0.0..=1.0).contains(&self.fault_ecc_rate) {
            return Err("fault_ecc_rate must be within [0, 1]".into());
        }
        if self.fault_rate > 0.0 || self.burst_rate > 0.0 {
            if self.fault_reissue_max == 0 {
                return Err("fault_reissue_max must be at least 1".into());
            }
            if self.fault_backoff_mult == 0 {
                return Err("fault_backoff_mult must be at least 1".into());
            }
            if self.fault_poll_timeout == 0 {
                return Err("fault_poll_timeout must be positive".into());
            }
        }
        if !(0.0..=1.0).contains(&self.burst_rate) {
            return Err("burst_rate must be within [0, 1]".into());
        }
        if !(0.0..=1.0).contains(&self.quarantine_threshold) {
            return Err("quarantine_threshold must be within [0, 1]".into());
        }
        if self.burst_rate > 0.0 {
            if self.burst_len == 0 {
                return Err("burst_len_ns must be positive when burst_rate > 0".into());
            }
            if self.burst_slow_mult == 0 {
                return Err("burst_slow_mult must be at least 1".into());
            }
        }
        if self.quarantine_threshold > 0.0 && self.probe_ok == 0 {
            return Err("probe_ok must be at least 1 when quarantine is armed".into());
        }
        Ok(())
    }

    /// Robustness-study variant of a preset: nonzero fault schedule plus
    /// the graceful-degradation policy armed (used by the faulted golden
    /// rows, the chaos tests, and `ablate faults`).
    pub fn faulted(mut self, rate: f64) -> SystemConfig {
        self.fault_rate = rate.clamp(0.0, 1.0);
        self.fault_ecc_rate = (rate / 8.0).clamp(0.0, 1.0);
        self.demote_after = 3;
        self
    }

    /// Correlated-fault variant of a preset: the burst layer armed at the
    /// given per-window episode start rate, per-line demotion enabled
    /// (storms need a streak policy to be visible). Quarantine knobs are
    /// left to the caller — `ablate degrade` sweeps them explicitly.
    pub fn bursty(mut self, rate: f64) -> SystemConfig {
        self.burst_rate = rate.clamp(0.0, 1.0);
        if self.demote_after == 0 {
            self.demote_after = 3;
        }
        self
    }
}

/// Derive a dual-rank 8-bank geometry with 8 KiB rows for a capacity.
pub fn geometry_for(bytes: u64) -> Geometry {
    let row_bytes = 128 * 64u64;
    let rows = bytes / (2 * 8 * row_bytes);
    assert!(
        rows.is_power_of_two() && rows >= 4,
        "capacity {bytes} does not give a pow2 row count (rows={rows})"
    );
    Geometry { ranks: 2, banks_per_rank: 8, rows_per_bank: rows as u32, cols_per_row: 128 }
}

/// Per-run workload parameters.
#[derive(Debug, Clone, Copy)]
pub struct RunSpec {
    pub workload: crate::workloads::WorkloadKind,
    /// Data footprint in bytes (paper: ~4 GB medium / ~16 GB large;
    /// scaled 64×: 64 MiB / 256 MiB).
    pub footprint: u64,
    /// Logical ops per core.
    pub ops_per_core: u64,
    pub seed: u64,
    /// Arrival discipline: `Closed` (default, self-pacing cores —
    /// bit-identical to pre-serving behaviour) or an open-loop process
    /// (`Poisson` / `Mmpp`) pacing requests at [`RunSpec::offered_rps`].
    pub arrival: ArrivalKind,
    /// Open-loop offered load, *system-wide* requests per second (split
    /// evenly across hardware threads). Ignored when `arrival = closed`;
    /// must be positive otherwise.
    pub offered_rps: u64,
    /// Zipf skew θ of key popularity in the memcached workload
    /// (0 = uniform, → 1 = heavily skewed; default 0.9, the memslap
    /// calibration). Other workloads ignore it.
    pub zipf_theta: f64,
    /// Seed of the arrival process (decorrelated from the workload
    /// seed; per-thread streams are forked from it).
    pub arrival_seed: u64,
    /// Bounded request-queue depth per hardware thread; arrivals beyond
    /// it are dropped (the overload signal). Must be positive for
    /// open-loop runs.
    pub queue_depth: u32,
    /// SMARTS sampling cadence in retired ops per core: each period
    /// fast-forwards functionally, runs `sample_warmup` detailed ops,
    /// then measures `sample_detail` ops. 0 = sampling off (every op
    /// detailed — bit-identical to pre-sampling behaviour).
    pub sample_period: u64,
    /// Detailed-but-unmeasured ops at the head of each window (timing
    /// state refill after the functional fast-forward).
    pub sample_warmup: u64,
    /// Measured ops per window; must be ≥ 1 when sampling is on, and
    /// `sample_warmup + sample_detail` must fit in the period.
    pub sample_detail: u64,
    /// Seed of the window placement inside the period (decorrelated
    /// from the workload and arrival seeds).
    pub sample_seed: u64,
    /// Upper bound on intra-sim pump shards for the `sharded` engine
    /// (`usize::MAX` = bounded only by channels and host threads). The
    /// sweep runner lowers it so sweep fan-out × per-sim shards cannot
    /// oversubscribe the host. Sizes the worker pool only — it cannot
    /// change simulated results.
    pub shard_cap: usize,
}

impl RunSpec {
    /// Closed-loop serving defaults shared by every constructor.
    const CLOSED: (ArrivalKind, u64, f64, u64, u32) =
        (ArrivalKind::Closed, 0, 0.9, 0xA221_7A1, 64);

    /// Sampling-off defaults shared by every constructor:
    /// (period, warmup, detail, seed). The warmup/detail defaults only
    /// take effect once a period is set (via the `sampled` builder, INI,
    /// or CLI flags).
    const UNSAMPLED: (u64, u64, u64, u64) = (0, 64, 64, 0x5A3D_11);

    fn with_defaults(workload: crate::workloads::WorkloadKind, footprint: u64, ops: u64, seed: u64) -> RunSpec {
        let (arrival, offered_rps, zipf_theta, arrival_seed, queue_depth) = Self::CLOSED;
        let (sample_period, sample_warmup, sample_detail, sample_seed) = Self::UNSAMPLED;
        RunSpec {
            workload,
            footprint,
            ops_per_core: ops,
            seed,
            arrival,
            offered_rps,
            zipf_theta,
            arrival_seed,
            queue_depth,
            sample_period,
            sample_warmup,
            sample_detail,
            sample_seed,
            shard_cap: usize::MAX,
        }
    }

    pub fn medium(workload: crate::workloads::WorkloadKind) -> RunSpec {
        Self::with_defaults(workload, 64 << 20, 150_000, 42)
    }

    pub fn large(workload: crate::workloads::WorkloadKind) -> RunSpec {
        Self::with_defaults(workload, 192 << 20, 150_000, 42)
    }

    /// Small spec for unit/integration tests.
    pub fn smoke(workload: crate::workloads::WorkloadKind) -> RunSpec {
        Self::with_defaults(workload, 16 << 20, 8_000, 42)
    }

    /// Open-loop variant: the given arrival process at `offered_rps`
    /// system-wide requests/s (keeps every other field).
    pub fn open_loop(mut self, arrival: ArrivalKind, offered_rps: u64) -> RunSpec {
        self.arrival = arrival;
        self.offered_rps = offered_rps;
        self
    }

    /// SMARTS-sampled variant: measure `detail` ops after `warmup`
    /// detailed ops every `period` retired ops, fast-forwarding the
    /// rest (keeps every other field, including the seeded placement).
    pub fn sampled(mut self, period: u64, warmup: u64, detail: u64) -> RunSpec {
        self.sample_period = period;
        self.sample_warmup = warmup;
        self.sample_detail = detail;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::WorkloadKind;

    #[test]
    fn presets_validate() {
        for name in [
            "ideal",
            "tl-ooo",
            "tl-lf",
            "tl-lf-batched",
            "numa",
            "pcie",
            "inc-trl",
            "amu",
            "mims",
        ] {
            let c = SystemConfig::by_name(name).unwrap();
            c.validate().unwrap();
        }
        assert!(SystemConfig::by_name("bogus").is_none());
    }

    #[test]
    fn amu_knobs_validated() {
        let mut c = SystemConfig::amu();
        c.validate().unwrap();
        c.amu_depth = 0;
        let err = c.validate().unwrap_err();
        assert!(err.contains("amu_depth"), "{err}");
        // The knob is AMU-specific: other mechanisms ignore it.
        let mut ideal = SystemConfig::ideal();
        ideal.amu_depth = 0;
        ideal.validate().unwrap();
    }

    #[test]
    fn mims_knobs_validated() {
        let mut c = SystemConfig::mims();
        c.validate().unwrap();
        c.mims_granule = 0;
        assert!(c.validate().unwrap_err().contains("mims_granule"));
        c.mims_granule = 65;
        assert!(c.validate().unwrap_err().contains("mims_granule"));
        c.mims_granule = 8;
        c.validate().unwrap();
        // The mechanism payload and the knob must agree (the parser
        // keeps them in lockstep).
        c.mims_pack += 1;
        assert!(c.validate().unwrap_err().contains("mims_pack"));
        let zero = SystemConfig::mims_packed(0);
        assert!(zero.validate().unwrap_err().contains("mims_pack"));
        // The knobs are MIMS-specific: other mechanisms ignore them.
        let mut ideal = SystemConfig::ideal();
        ideal.mims_granule = 0;
        ideal.validate().unwrap();
    }

    #[test]
    fn fault_knobs_validated() {
        let mut c = SystemConfig::tl_ooo();
        c.validate().unwrap();
        c.fault_rate = 1.5;
        assert!(c.validate().unwrap_err().contains("fault_rate"));
        c.fault_rate = 0.1;
        c.validate().unwrap();
        c.fault_ecc_rate = -0.2;
        assert!(c.validate().unwrap_err().contains("fault_ecc_rate"));
        c.fault_ecc_rate = 0.0;
        c.fault_reissue_max = 0;
        assert!(c.validate().unwrap_err().contains("fault_reissue_max"));
        c.fault_reissue_max = 4;
        c.fault_backoff_mult = 0;
        assert!(c.validate().unwrap_err().contains("fault_backoff_mult"));
        c.fault_backoff_mult = 2;
        c.fault_poll_timeout = 0;
        assert!(c.validate().unwrap_err().contains("fault_poll_timeout"));
        // Recovery knobs only matter when injection is armed.
        c.fault_rate = 0.0;
        c.validate().unwrap();
    }

    #[test]
    fn faulted_variant_arms_injection_and_demotion() {
        let c = SystemConfig::tl_ooo().faulted(0.25);
        assert_eq!(c.fault_rate, 0.25);
        assert!(c.fault_ecc_rate > 0.0);
        assert_eq!(c.demote_after, 3);
        c.validate().unwrap();
        // Defaults stay inert.
        let base = SystemConfig::tl_ooo();
        assert_eq!(base.fault_rate, 0.0);
        assert_eq!(base.fault_ecc_rate, 0.0);
        assert_eq!(base.demote_after, 0);
    }

    #[test]
    fn burst_and_quarantine_knobs_validated() {
        let mut c = SystemConfig::tl_ooo();
        c.burst_rate = 1.5;
        assert!(c.validate().unwrap_err().contains("burst_rate"));
        c.burst_rate = 0.2;
        c.validate().unwrap();
        c.burst_len = 0;
        assert!(c.validate().unwrap_err().contains("burst_len_ns"));
        c.burst_len = 5_000 * NS;
        c.burst_slow_mult = 0;
        assert!(c.validate().unwrap_err().contains("burst_slow_mult"));
        c.burst_slow_mult = 8;
        c.quarantine_threshold = -0.1;
        assert!(c.validate().unwrap_err().contains("quarantine_threshold"));
        c.quarantine_threshold = 0.5;
        c.probe_ok = 0;
        assert!(c.validate().unwrap_err().contains("probe_ok"));
        c.probe_ok = 4;
        c.validate().unwrap();
        // Burst arming requires the recovery knobs even with fault_rate 0.
        c.fault_poll_timeout = 0;
        assert!(c.validate().unwrap_err().contains("fault_poll_timeout"));
        // With the burst layer off the degenerate values are ignored.
        c.burst_rate = 0.0;
        c.burst_len = 0;
        c.quarantine_threshold = 0.0;
        c.probe_ok = 0;
        c.validate().unwrap();
    }

    #[test]
    fn bursty_variant_arms_burst_layer_only() {
        let c = SystemConfig::tl_ooo().bursty(0.3);
        assert_eq!(c.burst_rate, 0.3);
        assert_eq!(c.fault_rate, 0.0, "bursty must not arm per-draw faults");
        assert_eq!(c.demote_after, 3);
        assert_eq!(c.quarantine_threshold, 0.0, "quarantine is the caller's call");
        c.validate().unwrap();
        let base = SystemConfig::tl_ooo();
        assert_eq!(base.burst_rate, 0.0);
        assert_eq!(base.quarantine_threshold, 0.0);
        assert_eq!(base.slo_p99_us, 500);
    }

    #[test]
    fn geometries_cover_layout() {
        let c = SystemConfig::tl_ooo();
        let g_local = c.local_channel_geometry();
        assert_eq!(
            g_local.capacity_bytes() * c.local_channels as u64,
            c.layout.local_size
        );
        let g_mec = c.mec_channel_geometry();
        assert_eq!(g_mec.capacity_bytes(), 2 * c.layout.ext_size);
    }

    #[test]
    fn frontend_defaults_to_slab() {
        assert_eq!(SystemConfig::ideal().frontend, FrontEnd::Slab);
        assert_eq!(FrontEnd::by_name("reference"), Some(FrontEnd::Reference));
    }

    #[test]
    fn pcie_frac_clamped() {
        assert_eq!(SystemConfig::pcie(1.5).pcie_local_frac, 1.0);
        assert_eq!(SystemConfig::pcie(-0.5).pcie_local_frac, 0.0);
    }

    #[test]
    fn run_specs_scale() {
        let m = RunSpec::medium(WorkloadKind::Gups);
        let l = RunSpec::large(WorkloadKind::Gups);
        assert!(l.footprint > m.footprint);
    }

    #[test]
    fn run_specs_default_closed_loop() {
        let s = RunSpec::smoke(WorkloadKind::Memcached);
        assert_eq!(s.arrival, ArrivalKind::Closed);
        assert_eq!(s.offered_rps, 0);
        assert_eq!(s.zipf_theta, 0.9);
        assert_eq!(s.queue_depth, 64);
        let o = s.open_loop(ArrivalKind::Poisson, 1_000_000);
        assert_eq!(o.arrival, ArrivalKind::Poisson);
        assert_eq!(o.offered_rps, 1_000_000);
        assert_eq!(o.seed, s.seed, "open_loop must keep the other fields");
    }

    #[test]
    #[should_panic]
    fn geometry_for_rejects_non_pow2_rows() {
        geometry_for(100 << 20);
    }
}
