//! The twin-load access discipline (paper §3, §4.1, Figure 5).
//!
//! This module is the *software half* of the paper's contribution: the
//! compiler/programmer transform that replaces loads and stores to
//! identified extended-memory objects with inlined twin-load sequences:
//!
//! * **TL-OoO** — `load_type(p)`: issue loads to `p` and its shadow `p'`
//!   concurrently, compare both returned values against the fake pattern
//!   and keep the real one; retry via invalidate+fence if both are fake
//!   (Table 2 state 4). `store_type(p,v)`: twin-load first, then an
//!   atomic CAS so an interrupt-induced eviction can never corrupt memory
//!   (§3.2).
//! * **TL-LF** — issue the shadow prefetch, a load fence, then the demand
//!   load; simple and latency-tolerant but serializing (§3.1).
//! * **TL-LF-batched** — the §6.1 future-work optimization: batch k
//!   prefetches, one fence, then k demand loads.
//!
//! [`protocol::Transform`] lowers a workload's logical operation stream
//! into the micro-op stream the core executes; the hardware half (MEC1's
//! first/second-load handling) lives in [`crate::mec`]. The runtime retry
//! and safe-path sequences are injected by the core when twin pairs
//! resolve fake (see `cpu::core`), mirroring the inlined retry handlers.

pub mod logical;
pub mod protocol;

pub use logical::{LogicalMem, LogicalOp, LogicalSource};
pub use protocol::{Mechanism, Transform, TransformStats};
