//! Lowering logical operations into mechanism-specific micro-op streams
//! (paper Figure 5: `load_type` / `store_type` inlined functions).

use super::logical::{LogicalMem, LogicalOp, LogicalSource};
use crate::cpu::trace::{AccessKind, MemAccess, MicroOp, OpSource};
use crate::memmgr::MemLayout;
use std::collections::VecDeque;

/// Access mechanism under evaluation (paper Table 3 bottom row).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mechanism {
    /// All memory local (no transform).
    Ideal,
    /// Extended memory behind a QPI hop (no transform; latency added by
    /// the platform).
    Numa,
    /// Extended memory behind PCIe page swapping (no transform; faults
    /// modeled by the platform).
    Pcie,
    /// Twin-load with a load fence between the twins.
    TlLf,
    /// Twin-load with dynamic first/second identification.
    TlOoO,
    /// §6.1 future-work: batch `k` prefetches behind one fence.
    TlLfBatched(u32),
    /// §7.2 comparison: single loads with tRL increased by the given
    /// extra latency (no transform; ext-channel timing altered).
    IncreasedTrl,
    /// AMU-style asynchronous access (MIMS / "Asynchronous Memory Access
    /// Unit" line of work): extended accesses are rewritten into an
    /// explicit async-issue (request descriptor + enqueue) and a
    /// completion poll; the bounded request queue and notify latency are
    /// modeled by the platform's AMU backend.
    Amu,
    /// MIMS-style message interface (arxiv 1301.0051, same ICT group):
    /// up to `k` logically-adjacent twin-load pairs — loads *and*
    /// stores — pack into one request/response message sharing a single
    /// fence, so the prefetch/fence round trip is amortized across the
    /// message. Unlike [`Mechanism::TlLfBatched`], a store does not
    /// flush the batch (the message carries writes), which is what lets
    /// read-modify-write workloads (gups) pack at all; only a value
    /// dependency on an access still waiting in the batch forces a
    /// flush. Message framing overhead and the sub-64 B fine-granularity
    /// mode are modeled by the platform's MIMS backend. `Mims(1)` lowers
    /// every access exactly like [`Mechanism::TlLf`].
    Mims(u32),
}

impl Mechanism {
    /// Does this mechanism rewrite extended-memory accesses?
    pub fn transforms(&self) -> bool {
        matches!(
            self,
            Mechanism::TlLf
                | Mechanism::TlOoO
                | Mechanism::TlLfBatched(_)
                | Mechanism::Amu
                | Mechanism::Mims(_)
        )
    }

    pub fn name(&self) -> &'static str {
        match self {
            Mechanism::Ideal => "ideal",
            Mechanism::Numa => "numa",
            Mechanism::Pcie => "pcie",
            Mechanism::TlLf => "tl-lf",
            Mechanism::TlOoO => "tl-ooo",
            Mechanism::TlLfBatched(_) => "tl-lf-batched",
            Mechanism::IncreasedTrl => "inc-trl",
            Mechanism::Amu => "amu",
            Mechanism::Mims(_) => "mims",
        }
    }
}

/// Instruction overheads of the inlined twin-load functions. Calibrated
/// so extended-heavy workloads land near the paper's +64 % retired
/// instructions (Figure 8): compute `p'`, two value compares against the
/// fake pattern, a select, and loop/branch glue.
pub const OOO_LOAD_CHECK: u32 = 8;
pub const OOO_STORE_CAS: u32 = 6;
pub const LF_LOAD_CHECK: u32 = 4;
/// AMU async-issue overhead: build the request descriptor (address,
/// size, completion slot) and post it to the unit's doorbell.
pub const AMU_ISSUE: u32 = 3;
/// AMU completion poll: test the notify flag before consuming the value.
pub const AMU_POLL: u32 = 2;

/// Transform statistics (feeds the Table-4 "% in extended" validation and
/// the Figure-8 instruction accounting).
#[derive(Debug, Default, Clone, Copy)]
pub struct TransformStats {
    pub logical_mem: u64,
    pub logical_insts: u64,
    pub ext_loads: u64,
    pub ext_stores: u64,
    pub local_accesses: u64,
    pub micro_insts: u64,
    pub fences: u64,
}

impl TransformStats {
    /// Fraction of logical accesses that targeted extended memory.
    pub fn ext_fraction(&self) -> f64 {
        if self.logical_mem == 0 {
            0.0
        } else {
            (self.ext_loads + self.ext_stores) as f64 / self.logical_mem as f64
        }
    }

    /// Ratio of emitted to logical instructions (Figure 8 x-axis).
    pub fn inst_expansion(&self) -> f64 {
        if self.logical_insts == 0 {
            0.0
        } else {
            self.micro_insts as f64 / self.logical_insts as f64
        }
    }
}

/// Lowers a [`LogicalSource`] into the core's micro-op stream.
///
/// The simulator instantiates this with the concrete
/// [`crate::workloads::WorkloadSource`] enum, so `next_op` is a direct
/// (devirtualized, inlinable) call chain; `Box<dyn LogicalSource>`
/// instantiations remain available for trait-object consumers.
pub struct Transform<S: LogicalSource> {
    source: S,
    mech: Mechanism,
    layout: MemLayout,
    /// Ready-to-emit micro-ops. One persistent ring per transform: the
    /// deque is created once and recycled across expansions, so the
    /// steady-state lowering path performs zero heap allocations (it
    /// grows only the first time an expansion exceeds the capacity).
    out: VecDeque<MicroOp>,
    /// TL-LF-batched: demand halves waiting for the fence. Cleared (not
    /// dropped) on flush, so capacity persists.
    batch: Vec<LogicalMem>,
    batch_logicals: Vec<u64>,
    next_logical: u64,
    next_pair: u64,
    pub stats: TransformStats,
}

impl<S: LogicalSource> Transform<S> {
    pub fn new(source: S, mech: Mechanism, layout: MemLayout) -> Transform<S> {
        Transform {
            source,
            mech,
            layout,
            out: VecDeque::with_capacity(8),
            batch: Vec::new(),
            batch_logicals: Vec::new(),
            next_logical: 0,
            next_pair: 0,
            stats: TransformStats::default(),
        }
    }

    fn push(&mut self, op: MicroOp) {
        self.stats.micro_insts += op.insts() as u64;
        if matches!(op, MicroOp::Fence) {
            self.stats.fences += 1;
        }
        self.out.push_back(op);
    }

    fn fresh_pair(&mut self) -> u64 {
        let p = self.next_pair;
        self.next_pair += 1;
        p
    }

    /// Emit a plain (local / untransformed) access.
    fn passthrough(&mut self, m: &LogicalMem, logical: u64) {
        let kind = if m.is_store { AccessKind::Store } else { AccessKind::Load };
        self.push(MicroOp::Mem(MemAccess {
            vaddr: m.vaddr,
            kind,
            logical,
            dep_on: m.dep_on,
            pair: None,
            retry: false,
        }));
    }

    /// TL-OoO lowering of one extended access (Figure 5).
    fn lower_ooo(&mut self, m: &LogicalMem, logical: u64) {
        let pair = self.fresh_pair();
        let shadow = self.layout.shadow_of(m.vaddr);
        let ld = |vaddr, dep| MicroOp::Mem(MemAccess {
            vaddr,
            kind: AccessKind::Load,
            logical,
            dep_on: dep,
            pair: Some(pair),
            retry: false,
        });
        // Both twins issue concurrently — the OoO window interleaves them
        // with whatever else is ready. The SHADOW load is emitted first:
        // in program order it tends to reach MEC1 first, so the demand
        // address `p` samples the *real* value — which the CAS of a
        // following store compares against (§3.2). Loads are indifferent
        // to the order (software selects the real register value).
        self.push(ld(shadow, m.dep_on));
        self.push(ld(m.vaddr, m.dep_on));
        if m.is_store {
            // value check + CAS (§3.2); the store's RFO rechecks content.
            self.push(MicroOp::Compute(OOO_STORE_CAS));
            self.push(MicroOp::Mem(MemAccess {
                vaddr: m.vaddr,
                kind: AccessKind::Store,
                logical,
                dep_on: Some(logical),
                pair: None,
                retry: false,
            }));
        } else {
            self.push(MicroOp::Compute(OOO_LOAD_CHECK));
        }
    }

    /// TL-LF lowering: prefetch → fence → demand (§3.1).
    fn lower_lf(&mut self, m: &LogicalMem, logical: u64) {
        let pair = self.fresh_pair();
        let shadow = self.layout.shadow_of(m.vaddr);
        self.push(MicroOp::Mem(MemAccess {
            vaddr: shadow,
            kind: AccessKind::Load,
            logical,
            dep_on: m.dep_on,
            pair: Some(pair),
            retry: false,
        }));
        self.push(MicroOp::Fence);
        self.push(MicroOp::Mem(MemAccess {
            vaddr: m.vaddr,
            kind: AccessKind::Load,
            logical,
            dep_on: m.dep_on,
            pair: Some(pair),
            retry: false,
        }));
        self.push(MicroOp::Compute(LF_LOAD_CHECK));
        if m.is_store {
            self.push(MicroOp::Compute(2));
            self.push(MicroOp::Mem(MemAccess {
                vaddr: m.vaddr,
                kind: AccessKind::Store,
                logical,
                dep_on: Some(logical),
                pair: None,
                retry: false,
            }));
        }
    }

    /// AMU lowering: explicit async issue → the access → completion
    /// poll. The access itself stays a single load/store (the AMU
    /// backend adds queueing, dispatch, and notify latency at the
    /// platform level); the instruction stream carries the issue/poll
    /// overhead the async software interface costs.
    fn lower_amu(&mut self, m: &LogicalMem, logical: u64) {
        let kind = if m.is_store { AccessKind::Store } else { AccessKind::Load };
        self.push(MicroOp::Compute(AMU_ISSUE));
        self.push(MicroOp::Mem(MemAccess {
            vaddr: m.vaddr,
            kind,
            logical,
            dep_on: m.dep_on,
            pair: None,
            retry: false,
        }));
        if !m.is_store {
            // Stores are fire-and-forget; loads poll for the notify.
            self.push(MicroOp::Compute(AMU_POLL));
        }
    }

    /// Flush the TL-LF batch: k prefetches, one fence, k demands.
    /// Allocation-free: iterates the persistent batch buffers in place
    /// and derives the k sequential pair ids arithmetically (identical
    /// ids to one `fresh_pair` call per item).
    fn flush_batch(&mut self) {
        if self.batch.is_empty() {
            return;
        }
        let n = self.batch.len();
        let base_pair = self.next_pair;
        self.next_pair += n as u64;
        for i in 0..n {
            let (m, logical) = (self.batch[i], self.batch_logicals[i]);
            let shadow = self.layout.shadow_of(m.vaddr);
            self.push(MicroOp::Mem(MemAccess {
                vaddr: shadow,
                kind: AccessKind::Load,
                logical,
                dep_on: m.dep_on,
                pair: Some(base_pair + i as u64),
                retry: false,
            }));
        }
        self.push(MicroOp::Fence);
        for i in 0..n {
            let (m, logical) = (self.batch[i], self.batch_logicals[i]);
            self.push(MicroOp::Mem(MemAccess {
                vaddr: m.vaddr,
                kind: AccessKind::Load,
                logical,
                dep_on: m.dep_on,
                pair: Some(base_pair + i as u64),
                retry: false,
            }));
            self.push(MicroOp::Compute(LF_LOAD_CHECK));
            if m.is_store {
                self.push(MicroOp::Compute(2));
                self.push(MicroOp::Mem(MemAccess {
                    vaddr: m.vaddr,
                    kind: AccessKind::Store,
                    logical,
                    dep_on: Some(logical),
                    pair: None,
                    retry: false,
                }));
            }
        }
        self.batch.clear();
        self.batch_logicals.clear();
    }

    /// Does `m` depend on a logical access still waiting in the batch?
    fn depends_on_batch(&self, m: &LogicalMem) -> bool {
        match m.dep_on {
            Some(d) => self.batch_logicals.contains(&d),
            None => false,
        }
    }

    fn lower(&mut self, op: LogicalOp) {
        self.stats.logical_insts += op.insts() as u64;
        match op {
            LogicalOp::Compute(n) => {
                // Compute passes through without flushing the batch —
                // non-memory work neither reads the batched values nor
                // needs ordering against loads, and flushing here would
                // cap batches at one access for compute-interleaved code.
                self.push(MicroOp::Compute(n));
            }
            LogicalOp::Mem(m) => {
                let logical = self.next_logical;
                self.next_logical += 1;
                self.stats.logical_mem += 1;
                let ext = self.layout.is_extended(m.vaddr);
                if !ext || !self.mech.transforms() {
                    self.stats.local_accesses += u64::from(!ext);
                    if ext {
                        if m.is_store {
                            self.stats.ext_stores += 1;
                        } else {
                            self.stats.ext_loads += 1;
                        }
                    }
                    self.passthrough(&m, logical);
                    return;
                }
                if m.is_store {
                    self.stats.ext_stores += 1;
                } else {
                    self.stats.ext_loads += 1;
                }
                match self.mech {
                    Mechanism::TlOoO => self.lower_ooo(&m, logical),
                    Mechanism::TlLf => self.lower_lf(&m, logical),
                    Mechanism::Amu => self.lower_amu(&m, logical),
                    Mechanism::TlLfBatched(k) => {
                        if m.is_store || self.depends_on_batch(&m) {
                            self.flush_batch();
                        }
                        if m.is_store {
                            self.lower_lf(&m, logical);
                        } else {
                            self.batch.push(m);
                            self.batch_logicals.push(logical);
                            if self.batch.len() >= k as usize {
                                self.flush_batch();
                            }
                        }
                    }
                    Mechanism::Mims(k) => {
                        // The message carries writes, so stores join the
                        // batch; only a value dependency on an access
                        // still waiting behind the shared fence forces a
                        // flush (its demand half must retire first).
                        if self.depends_on_batch(&m) {
                            self.flush_batch();
                        }
                        self.batch.push(m);
                        self.batch_logicals.push(logical);
                        if self.batch.len() >= k.max(1) as usize {
                            self.flush_batch();
                        }
                    }
                    _ => unreachable!(),
                }
            }
        }
    }
}

impl<S: LogicalSource> Transform<S> {
    /// Lower exactly one application-level *request* (as delimited by
    /// [`LogicalSource::at_request_boundary`]) and append its micro-ops
    /// to `dst`. Returns the number of micro-ops produced; `0` means the
    /// underlying source is exhausted. Used by the open-loop serving gate
    /// (`workloads::arrival`) to hand out work one request at a time so
    /// per-request latency has a well-defined completion point.
    pub fn next_request(&mut self, dst: &mut VecDeque<MicroOp>) -> usize {
        debug_assert!(self.out.is_empty(), "next_request interleaved with next_op");
        loop {
            match self.source.next_logical() {
                Some(op) => {
                    self.lower(op);
                    if self.source.at_request_boundary() {
                        break;
                    }
                }
                None => break,
            }
        }
        // A request must be self-contained: flush any batched prefetches
        // so its completion point is observable (and deterministic).
        self.flush_batch();
        let n = self.out.len();
        dst.extend(self.out.drain(..));
        n
    }
}

impl<S: LogicalSource> OpSource for Transform<S> {
    fn next_op(&mut self) -> Option<MicroOp> {
        loop {
            if let Some(op) = self.out.pop_front() {
                return Some(op);
            }
            match self.source.next_logical() {
                Some(op) => self.lower(op),
                None => {
                    if self.batch.is_empty() {
                        return None;
                    }
                    self.flush_batch();
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layout() -> MemLayout {
        MemLayout::new(1 << 20, 1 << 20)
    }

    fn ext(a: u64) -> u64 {
        layout().ext_base() + a
    }

    fn drain<S: LogicalSource>(t: &mut Transform<S>) -> Vec<MicroOp> {
        let mut v = Vec::new();
        while let Some(op) = t.next_op() {
            v.push(op);
        }
        v
    }

    fn mem_kinds(ops: &[MicroOp]) -> Vec<&'static str> {
        ops.iter()
            .map(|o| match o {
                MicroOp::Compute(_) => "c",
                MicroOp::Fence => "f",
                MicroOp::Mem(m) => match m.kind {
                    AccessKind::Load => "L",
                    AccessKind::Store => "S",
                    AccessKind::Invalidate => "I",
                    AccessKind::SafePath => "X",
                },
            })
            .collect()
    }

    #[test]
    fn ideal_passes_through() {
        let ops = vec![LogicalOp::load(ext(0)), LogicalOp::Compute(5)];
        let mut t = Transform::new(ops.into_iter(), Mechanism::Ideal, layout());
        let out = drain(&mut t);
        assert_eq!(mem_kinds(&out), vec!["L", "c"]);
        assert_eq!(t.stats.inst_expansion(), 1.0);
    }

    #[test]
    fn ooo_load_becomes_twin_pair_plus_check() {
        let ops = vec![LogicalOp::load(ext(0x40))];
        let mut t = Transform::new(ops.into_iter(), Mechanism::TlOoO, layout());
        let out = drain(&mut t);
        assert_eq!(mem_kinds(&out), vec!["L", "L", "c"]);
        // The two loads form one pair, to twin addresses.
        let (a, b) = match (&out[0], &out[1]) {
            (MicroOp::Mem(a), MicroOp::Mem(b)) => (*a, *b),
            _ => panic!(),
        };
        assert_eq!(a.pair, b.pair);
        assert!(a.pair.is_some());
        assert_eq!(a.logical, b.logical);
        // Shadow twin is emitted first (see lower_ooo), demand second.
        assert!(layout().is_shadow(a.vaddr));
        assert!(layout().is_extended(b.vaddr));
        assert_eq!(a.vaddr - b.vaddr, layout().ext_size);
        assert!(t.stats.inst_expansion() > 2.0);
    }

    #[test]
    fn ooo_local_access_untouched() {
        let ops = vec![LogicalOp::load(0x40)];
        let mut t = Transform::new(ops.into_iter(), Mechanism::TlOoO, layout());
        let out = drain(&mut t);
        assert_eq!(mem_kinds(&out), vec!["L"]);
        assert_eq!(t.stats.local_accesses, 1);
        assert_eq!(t.stats.ext_loads, 0);
    }

    #[test]
    fn ooo_store_is_twinload_then_cas_store() {
        let ops = vec![LogicalOp::store(ext(0x80))];
        let mut t = Transform::new(ops.into_iter(), Mechanism::TlOoO, layout());
        let out = drain(&mut t);
        assert_eq!(mem_kinds(&out), vec!["L", "L", "c", "S"]);
        // The store depends on the twin value (CAS compares it).
        let st = match &out[3] {
            MicroOp::Mem(m) => *m,
            _ => panic!(),
        };
        assert_eq!(st.dep_on, Some(st.logical));
        assert_eq!(t.stats.ext_stores, 1);
    }

    #[test]
    fn lf_load_has_fence_between_twins() {
        let ops = vec![LogicalOp::load(ext(0))];
        let mut t = Transform::new(ops.into_iter(), Mechanism::TlLf, layout());
        let out = drain(&mut t);
        assert_eq!(mem_kinds(&out), vec!["L", "f", "L", "c"]);
        // Prefetch goes to the shadow, demand to the extended address.
        let (pre, dem) = match (&out[0], &out[2]) {
            (MicroOp::Mem(a), MicroOp::Mem(b)) => (*a, *b),
            _ => panic!(),
        };
        assert!(layout().is_shadow(pre.vaddr));
        assert!(layout().is_extended(dem.vaddr));
    }

    #[test]
    fn batched_lf_shares_one_fence() {
        let ops: Vec<LogicalOp> = (0..4).map(|i| LogicalOp::load(ext(i * 64))).collect();
        let mut t =
            Transform::new(ops.into_iter(), Mechanism::TlLfBatched(4), layout());
        let out = drain(&mut t);
        // 4 prefetches, 1 fence, 4 × (demand + check).
        assert_eq!(
            mem_kinds(&out),
            vec!["L", "L", "L", "L", "f", "L", "c", "L", "c", "L", "c", "L", "c"]
        );
        assert_eq!(t.stats.fences, 1);
    }

    #[test]
    fn mims_pack1_lowers_exactly_like_tl_lf() {
        // The unpacked message interface degenerates to the synchronous
        // twin-load stream op-for-op (pairs, deps, fences, computes) —
        // the foundation of the pack-1 ≡ MEC differential.
        let ops = vec![
            LogicalOp::load(ext(0)),
            LogicalOp::store(ext(0x40)),
            LogicalOp::Compute(3),
            LogicalOp::load_dep(ext(0x100), 0),
            LogicalOp::load(0x80), // local: passthrough
            LogicalOp::Mem(LogicalMem { vaddr: ext(0x40), is_store: true, dep_on: Some(3) }),
        ];
        let mut lf = Transform::new(ops.clone().into_iter(), Mechanism::TlLf, layout());
        let mut mims = Transform::new(ops.into_iter(), Mechanism::Mims(1), layout());
        let a = drain(&mut lf);
        let b = drain(&mut mims);
        assert_eq!(format!("{a:?}"), format!("{b:?}"));
        assert_eq!(lf.stats.fences, mims.stats.fences);
        assert_eq!(lf.stats.micro_insts, mims.stats.micro_insts);
    }

    #[test]
    fn mims_stores_join_the_batch() {
        // Three loads and a store, no dependencies: unlike
        // TlLfBatched(4) (where the store flushes and pays its own
        // fence), the whole message shares one fence.
        let ops = vec![
            LogicalOp::load(ext(0)),
            LogicalOp::load(ext(0x40)),
            LogicalOp::load(ext(0x80)),
            LogicalOp::store(ext(0xc0)),
        ];
        let mut t = Transform::new(ops.clone().into_iter(), Mechanism::Mims(4), layout());
        let out = drain(&mut t);
        // 4 prefetches, one fence, 3 × (demand + check), demand + check
        // + store-update for the store entry.
        assert_eq!(
            mem_kinds(&out),
            vec!["L", "L", "L", "L", "f", "L", "c", "L", "c", "L", "c", "L", "c", "c", "S"]
        );
        assert_eq!(t.stats.fences, 1);
        let mut batched =
            Transform::new(ops.into_iter(), Mechanism::TlLfBatched(4), layout());
        drain(&mut batched);
        assert!(batched.stats.fences > 1, "the batched-LF store pays its own fence");
    }

    #[test]
    fn mims_flushes_only_on_in_batch_dependency() {
        // GUPS rhythm: load, dependent store to the same line, repeat.
        // The store's value dependency on the in-batch load forces a
        // flush (its demand half must retire before the store can
        // issue), but the store then *joins* the next batch, so steady
        // state packs (store, next load) pairs: half the fences of
        // TL-LF's one per access.
        let ops = vec![
            LogicalOp::load(ext(0)),
            LogicalOp::Mem(LogicalMem { vaddr: ext(0), is_store: true, dep_on: Some(0) }),
            LogicalOp::load(ext(0x40)),
            LogicalOp::Mem(LogicalMem { vaddr: ext(0x40), is_store: true, dep_on: Some(2) }),
        ];
        let mut t = Transform::new(ops.clone().into_iter(), Mechanism::Mims(4), layout());
        drain(&mut t);
        assert_eq!(t.stats.fences, 3, "[L], [S L], [S]: three messages");
        let mut lf = Transform::new(ops.into_iter(), Mechanism::TlLf, layout());
        drain(&mut lf);
        assert_eq!(lf.stats.fences, 4, "TL-LF fences every access");
    }

    #[test]
    fn mims_partial_final_batch_flushes_on_exhaustion() {
        // 5 independent loads at pack 4: one full message and a partial
        // single-entry one — nothing is lost at stream end.
        let ops: Vec<LogicalOp> = (0..5).map(|i| LogicalOp::load(ext(i * 64))).collect();
        let mut t = Transform::new(ops.into_iter(), Mechanism::Mims(4), layout());
        let out = drain(&mut t);
        let kinds = mem_kinds(&out);
        let loads = kinds.iter().filter(|k| **k == "L").count();
        assert_eq!(loads, 10, "5 prefetches + 5 demands");
        assert_eq!(t.stats.fences, 2, "one full message, one partial");
    }

    #[test]
    fn ring_growth_preserves_order_for_large_batches() {
        // A 32-wide batch expands to 97 micro-ops in one flush, forcing
        // the persistent output ring through multiple growth steps; order
        // and pairing (arithmetic pair ids) must survive.
        let ops: Vec<LogicalOp> = (0..32).map(|i| LogicalOp::load(ext(i * 64))).collect();
        let mut t = Transform::new(ops.into_iter(), Mechanism::TlLfBatched(32), layout());
        let out = drain(&mut t);
        assert_eq!(out.len(), 32 + 1 + 64);
        assert_eq!(t.stats.fences, 1);
        assert!(matches!(out[32], MicroOp::Fence));
        for i in 0..32usize {
            let (pre, dem) = match (&out[i], &out[33 + 2 * i]) {
                (MicroOp::Mem(a), MicroOp::Mem(b)) => (*a, *b),
                other => panic!("unexpected ops {other:?}"),
            };
            assert_eq!(pre.pair, dem.pair, "prefetch {i} mispaired");
            assert!(layout().is_shadow(pre.vaddr));
            assert_eq!(pre.vaddr, layout().shadow_of(dem.vaddr));
        }
    }

    #[test]
    fn batched_lf_flushes_on_dependency() {
        // Second load depends on the first (still in batch) → flush.
        let ops = vec![LogicalOp::load(ext(0)), LogicalOp::load_dep(ext(0x100), 0)];
        let mut t =
            Transform::new(ops.into_iter(), Mechanism::TlLfBatched(8), layout());
        let out = drain(&mut t);
        // Two separate fenced groups.
        assert_eq!(t.stats.fences, 2);
        assert!(out.len() >= 8);
    }

    #[test]
    fn ext_fraction_statistic() {
        let ops = vec![
            LogicalOp::load(0),
            LogicalOp::load(ext(0)),
            LogicalOp::load(ext(64)),
            LogicalOp::store(ext(128)),
        ];
        let mut t = Transform::new(ops.into_iter(), Mechanism::TlOoO, layout());
        drain(&mut t);
        assert!((t.stats.ext_fraction() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn numa_does_not_transform() {
        let ops = vec![LogicalOp::load(ext(0))];
        let mut t = Transform::new(ops.into_iter(), Mechanism::Numa, layout());
        let out = drain(&mut t);
        assert_eq!(mem_kinds(&out), vec!["L"]);
        assert_eq!(t.stats.ext_loads, 1, "ext accesses still counted");
    }

    #[test]
    fn mechanism_names() {
        assert_eq!(Mechanism::TlOoO.name(), "tl-ooo");
        assert_eq!(Mechanism::Amu.name(), "amu");
        assert!(Mechanism::TlLfBatched(8).transforms());
        assert!(Mechanism::Amu.transforms());
        assert!(!Mechanism::IncreasedTrl.transforms());
    }

    #[test]
    fn amu_load_is_issue_access_poll() {
        let ops = vec![LogicalOp::load(ext(0x40))];
        let mut t = Transform::new(ops.into_iter(), Mechanism::Amu, layout());
        let out = drain(&mut t);
        assert_eq!(mem_kinds(&out), vec!["c", "L", "c"]);
        // Single access to the extended address itself — no twin, no
        // shadow traffic, no pair id.
        let m = match &out[1] {
            MicroOp::Mem(m) => *m,
            other => panic!("unexpected {other:?}"),
        };
        assert!(layout().is_extended(m.vaddr));
        assert_eq!(m.pair, None);
        // Issue + poll overhead accounted against the logical stream.
        assert_eq!(t.stats.micro_insts, (AMU_ISSUE + AMU_POLL + 1) as u64);
        assert_eq!(t.stats.ext_loads, 1);
    }

    #[test]
    fn amu_store_skips_the_poll() {
        let ops = vec![LogicalOp::store(ext(0x80))];
        let mut t = Transform::new(ops.into_iter(), Mechanism::Amu, layout());
        let out = drain(&mut t);
        assert_eq!(mem_kinds(&out), vec!["c", "S"]);
        assert_eq!(t.stats.ext_stores, 1);
    }

    #[test]
    fn amu_local_access_untouched() {
        let ops = vec![LogicalOp::load(0x40)];
        let mut t = Transform::new(ops.into_iter(), Mechanism::Amu, layout());
        let out = drain(&mut t);
        assert_eq!(mem_kinds(&out), vec!["L"]);
        assert_eq!(t.stats.local_accesses, 1);
        assert_eq!(t.stats.ext_loads, 0);
    }

    #[test]
    fn amu_preserves_dependencies() {
        let ops = vec![LogicalOp::load(ext(0)), LogicalOp::load_dep(ext(0x100), 0)];
        let mut t = Transform::new(ops.into_iter(), Mechanism::Amu, layout());
        let out = drain(&mut t);
        let dep = match &out[4] {
            MicroOp::Mem(m) => m.dep_on,
            other => panic!("unexpected {other:?}"),
        };
        assert_eq!(dep, Some(0), "pointer-chase dependence lost in lowering");
    }
}
