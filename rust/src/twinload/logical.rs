//! Logical (pre-transform) operation stream — what the *program* does,
//! before the access mechanism decides how each access is realized.

/// A logical memory access.
#[derive(Debug, Clone, Copy)]
pub struct LogicalMem {
    /// Virtual address (cache-line aligned by generators).
    pub vaddr: u64,
    pub is_store: bool,
    /// Logical index of an earlier access whose loaded *value* this
    /// access's address depends on (pointer chase), if any.
    pub dep_on: Option<u64>,
}

/// One logical operation.
#[derive(Debug, Clone, Copy)]
pub enum LogicalOp {
    Mem(LogicalMem),
    /// `n` non-memory instructions between accesses.
    Compute(u32),
}

impl LogicalOp {
    pub fn load(vaddr: u64) -> LogicalOp {
        LogicalOp::Mem(LogicalMem { vaddr, is_store: false, dep_on: None })
    }

    pub fn store(vaddr: u64) -> LogicalOp {
        LogicalOp::Mem(LogicalMem { vaddr, is_store: true, dep_on: None })
    }

    pub fn load_dep(vaddr: u64, dep_on: u64) -> LogicalOp {
        LogicalOp::Mem(LogicalMem { vaddr, is_store: false, dep_on: Some(dep_on) })
    }

    /// Instruction count of the logical op (mem = 1).
    pub fn insts(&self) -> u32 {
        match self {
            LogicalOp::Compute(n) => *n,
            LogicalOp::Mem(_) => 1,
        }
    }
}

/// Pull-based logical stream (implemented by every workload generator).
pub trait LogicalSource {
    fn next_logical(&mut self) -> Option<LogicalOp>;

    /// True when the stream currently sits *between* requests — the last
    /// op popped completed one application-level request (a memcached
    /// GET/SET, a graph-traversal step, ...) and the next op would start
    /// a new one. The open-loop serving gate (`workloads::arrival`) uses
    /// this to hand out work one whole request at a time. The default
    /// (`true`) treats every op as its own request, which is correct for
    /// synthetic/test streams with no request structure.
    fn at_request_boundary(&self) -> bool {
        true
    }
}

impl<I: Iterator<Item = LogicalOp>> LogicalSource for I {
    fn next_logical(&mut self) -> Option<LogicalOp> {
        self.next()
    }
}

impl LogicalSource for Box<dyn LogicalSource + Send> {
    fn next_logical(&mut self) -> Option<LogicalOp> {
        (**self).next_logical()
    }

    fn at_request_boundary(&self) -> bool {
        (**self).at_request_boundary()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors() {
        assert!(matches!(LogicalOp::load(64), LogicalOp::Mem(m) if !m.is_store));
        assert!(matches!(LogicalOp::store(64), LogicalOp::Mem(m) if m.is_store));
        assert!(
            matches!(LogicalOp::load_dep(64, 3), LogicalOp::Mem(m) if m.dep_on == Some(3))
        );
    }

    #[test]
    fn inst_weights() {
        assert_eq!(LogicalOp::Compute(9).insts(), 9);
        assert_eq!(LogicalOp::load(0).insts(), 1);
    }

    #[test]
    fn iterators_are_sources() {
        let mut s = vec![LogicalOp::Compute(1)].into_iter();
        assert!(s.next_logical().is_some());
        assert!(s.next_logical().is_none());
    }
}
