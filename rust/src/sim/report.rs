//! Aggregated run statistics — the raw material of Figures 7–13.

use super::platform::Platform;
use crate::twinload::TransformStats;
use crate::util::time::{gbps, ps_to_ns, Ps};

/// Everything a figure bench needs from one simulation run.
#[derive(Debug, Clone)]
pub struct SimReport {
    /// Extension mechanism name (`ideal`, `tl-ooo`, `amu`, …), as
    /// printed by [`crate::twinload::Mechanism::name`].
    pub mechanism: &'static str,
    /// Workload name (`gups`, `memcached`, …).
    pub workload: &'static str,
    /// Number of physical cores simulated (SMT threads not included).
    pub cores: usize,
    /// Wall-clock of the simulated execution (max core finish), in ps.
    pub finish: Ps,
    /// CPU clock period in ps (1250 ps at the paper's 800 MHz).
    pub cpu_period: Ps,
    // Core aggregates.
    /// Instructions retired across all cores (count).
    pub retired_insts: u64,
    /// Micro-ops retired across all cores (count; ≥ `retired_insts`).
    pub retired_ops: u64,
    /// Load micro-ops retired (count).
    pub loads: u64,
    /// Store micro-ops retired (count).
    pub stores: u64,
    /// Fence micro-ops retired (count).
    pub fences: u64,
    /// Twin-load second loads re-issued because both halves returned
    /// fake data (count).
    pub twin_retries: u64,
    /// Accesses demoted to the synchronous safe path (count).
    pub safe_paths: u64,
    /// Failed compare-and-swap retirements (count).
    pub cas_fails: u64,
    // Hierarchy.
    /// LLC lookups that hit (count).
    pub llc_hits: u64,
    /// LLC lookups that missed (count).
    pub llc_misses: u64,
    /// TLB lookups that missed (count).
    pub tlb_misses: u64,
    /// Total TLB lookups (count).
    pub tlb_accesses: u64,
    // DRAM.
    /// DRAM read bursts serviced across all channels (count).
    pub dram_reads: u64,
    /// DRAM write bursts serviced across all channels (count).
    pub dram_writes: u64,
    /// Bytes moved by DRAM reads (64 B per burst).
    pub dram_read_bytes: u64,
    /// Bytes moved by DRAM writes (64 B per burst).
    pub dram_write_bytes: u64,
    /// Row-buffer hit fraction across all banks (0.0–1.0).
    pub row_hit_rate: f64,
    /// Commands issued on every channel's command bus (count).
    pub dram_cmds: u64,
    /// Mean data-bus utilization across all channels over the run
    /// (fraction of wall-clock spent transferring bursts, 0.0–1.0).
    pub data_bus_util: f64,
    // Concurrency.
    /// Mean outstanding memory requests over the run (requests).
    pub mlp_mean: f64,
    /// Peak outstanding memory requests (requests).
    pub mlp_peak: u64,
    // Transform.
    /// Logical→micro-op lowering counters summed over all cores.
    pub transform: TransformStats,
    // Mechanism extras.
    /// MEC: first (twin) loads observed by the extension controller
    /// (count).
    pub mec_first_loads: u64,
    /// MEC: second loads answered with real data (count).
    pub mec_second_real: u64,
    /// MEC: second loads that arrived before the data was ready (count).
    pub mec_second_late: u64,
    /// MEC: last-value-cache capacity evictions (count).
    pub lvc_evictions: u64,
    /// PCIe engine: page faults taken on the far side (count).
    pub pcie_faults: u64,
    // AMU backend: bounded request-queue behavior.
    /// AMU: asynchronous access requests accepted (count).
    pub amu_requests: u64,
    /// AMU: core stalls due to a full AMU request queue (count).
    pub amu_queue_stalls: u64,
    /// AMU: peak request-queue occupancy (entries).
    pub amu_occ_peak: u64,
    /// AMU: time-weighted mean request-queue occupancy (entries).
    pub amu_occ_mean: f64,
    // MIMS backend: message packing/framing.
    /// MIMS: extended transactions carried inside messages (count).
    pub mims_requests: u64,
    /// MIMS: messages framed on the extension channel (count).
    pub mims_messages: u64,
    /// MIMS: mean transactions per framed message.
    pub mims_pack_mean: f64,
    /// MIMS: bytes moved by the fine-granularity interface (count).
    pub mims_delivered_bytes: u64,
    /// MIMS: bytes a fixed 64 B-burst interface would have moved.
    pub mims_requested_bytes: u64,
    // Fault injection + recovery (all zero when `fault_rate = 0`).
    /// Faults injected across every class: platform sites (not-ready
    /// responses, lost notifies, link redeliveries, PCIe retransfers,
    /// ECC detections) plus MEC prefetch-fill faults.
    pub faults_injected: u64,
    /// Lines that entered a ≥2-retry consecutive both-fake streak.
    pub retry_storms: u64,
    /// Safe-path demotions after `demote_after` consecutive retries.
    pub demotions: u64,
    /// Single-bit errors corrected in-line by the ECC model.
    pub ecc_corrected: u64,
    /// MEC prefetch-buffer fills dropped / landed late by injection.
    pub mec_fill_drops: u64,
    pub mec_fill_lates: u64,
    /// Mean fault-recovery added latency (ps).
    pub recovery_mean: f64,
    /// 99th-percentile fault-recovery added latency (ps, geometric
    /// log2-bucket midpoint clamped to the observed range).
    pub recovery_p99: Ps,
    /// Maximum fault-recovery added latency (ps).
    pub recovery_max: Ps,
    // Correlated fault domains + health/quarantine (all zero — and
    // `availability` 1.0 — when no fault plan is armed).
    /// Extended-memory deliveries (plus PCIe swap transfers) that passed
    /// through an armed fault plan (count).
    pub ext_accesses: u64,
    /// Of those, accesses degraded by a burst window, a per-draw fault,
    /// or quarantine-demoted service (count).
    pub degraded_accesses: u64,
    /// `1 − degraded_accesses / ext_accesses` (1.0 when no extended
    /// accesses ran under an armed plan).
    pub availability: f64,
    /// Fault domains quarantined by the online health detector (count;
    /// a domain re-entering quarantine counts again).
    pub quarantines: u64,
    /// Quarantined domains re-admitted after `probe_ok` clean probes
    /// (count).
    pub readmits: u64,
    /// Accesses served via the safe path because their whole domain was
    /// quarantined (count; subset of `safe_paths`).
    pub quarantined_served: u64,
    /// Mean time-to-detect: first unhealthy observation → quarantine
    /// entry, averaged over quarantine events (ns).
    pub mttd_ns: f64,
    /// Mean time-to-repair: quarantine entry → readmission, averaged
    /// over readmissions (ns).
    pub mttr_ns: f64,
    /// Total domain-time spent in quarantine (degraded mode), with any
    /// still-open interval closed at run end (ns).
    pub degraded_ns: f64,
    /// True if the watchdog tripped before all cores finished.
    pub deadlocked: bool,
    // Open-loop serving (all zero under `arrival = closed`).
    /// Requests generated by the arrival process, including drops
    /// (count).
    pub arrived_requests: u64,
    /// Requests fully retired by a core (count).
    pub served_requests: u64,
    /// Requests rejected because the bounded arrival queue was full
    /// (count).
    pub dropped_requests: u64,
    /// Mean end-to-end request latency, arrival to retirement (ns).
    pub req_mean_ns: f64,
    /// Median end-to-end request latency (ns, geometric log2-bucket
    /// midpoint clamped to the observed range).
    pub req_p50_ns: u64,
    /// 99th-percentile end-to-end request latency (ns, same midpoint
    /// estimate).
    pub req_p99_ns: u64,
    /// 99.9th-percentile end-to-end request latency (ns, same midpoint
    /// estimate).
    pub req_p999_ns: u64,
    /// Mean arrival-queue depth sampled at each enqueue (requests).
    pub queue_mean: f64,
    /// Peak arrival-queue depth across all cores (requests).
    pub queue_peak: u64,
    // Event-engine occupancy/housekeeping (engine-agnostic fields like
    // `engine_events`/`engine_peak` must match across engines; resize,
    // overflow, width, and resample counters are calendar-specific
    // diagnostics).
    /// Event-engine name (`calendar`, `adaptive-calendar`,
    /// `reference-heap`).
    pub engine: &'static str,
    /// Events pushed into the engine over the run (count).
    pub engine_events: u64,
    /// Peak number of pending events (count).
    pub engine_peak: u64,
    /// Calendar-queue bucket-array resizes (count; 0 for the heap).
    pub engine_resizes: u64,
    /// Events pushed beyond the calendar horizon into the overflow
    /// list (count; 0 for the heap).
    pub engine_overflow: u64,
    /// Current calendar bucket count (0 for the reference heap).
    pub engine_buckets: u64,
    /// Current calendar bucket width in ps (0 for the reference heap;
    /// differs from the seed `t_ck` only under the adaptive engine).
    pub engine_width: u64,
    /// Completed adaptive width re-bucketings (adaptive calendar only).
    pub engine_resamples: u64,
    /// Pump batches the sharded engine ran on its worker pool (0 for
    /// the single-thread engines; host-dependent diagnostic, excluded
    /// from the equivalence fingerprints).
    pub engine_parallel_pumps: u64,
    // SMARTS systematic sampling (all zero when `sample_period = 0`).
    /// Completed measurement windows across all hardware threads
    /// (count).
    pub sample_windows: u64,
    /// Ops retired in detailed mode — warmup plus measurement (count;
    /// the rest of the run fast-forwarded functionally).
    pub sample_detailed_ops: u64,
    /// Mean ns-per-op over the measurement windows.
    pub sample_ns_per_op_mean: f64,
    /// 95 % CLT confidence half-width of `sample_ns_per_op_mean` (ns;
    /// 0 with fewer than two windows).
    pub sample_ci_ns_per_op: f64,
    /// Mean per-window IPC over the measurement windows.
    pub sample_ipc_mean: f64,
    /// 95 % CLT confidence half-width of `sample_ipc_mean`.
    pub sample_ci_ipc: f64,
}

impl SimReport {
    pub(crate) fn collect(p: &Platform) -> SimReport {
        let cfg = p.cfg();
        let spec = p.spec();
        let core_stats = p.core_stats();
        let finish = core_stats.iter().map(|s| s.finish).max().unwrap_or(0);
        let (llc_hits, llc_misses) = p.llc_stats();
        let (dram_reads, dram_writes, dram_read_bytes, dram_write_bytes, row_hit_rate) =
            p.dram_totals();
        let (dram_cmds, data_bus_util) = p.bus_totals();
        let amu = p.amu_stats();
        let mims = p.mims_stats();
        let mut transform = TransformStats::default();
        for t in p.transform_stats() {
            transform.logical_mem += t.logical_mem;
            transform.logical_insts += t.logical_insts;
            transform.ext_loads += t.ext_loads;
            transform.ext_stores += t.ext_stores;
            transform.local_accesses += t.local_accesses;
            transform.micro_insts += t.micro_insts;
            transform.fences += t.fences;
        }
        let engine = p.engine_stats();
        let (mut mec_first_loads, mut mec_second_real, mut mec_second_late, mut lvc_evictions) =
            (0, 0, 0, 0);
        let (mut mec_fill_drops, mut mec_fill_lates) = (0, 0);
        for m in p.mec_refs() {
            mec_first_loads += m.stats.first_loads;
            mec_second_real += m.stats.second_real;
            mec_second_late += m.stats.second_late;
            lvc_evictions += m.lvc().evictions;
            mec_fill_drops += m.stats.fill_drops;
            mec_fill_lates += m.stats.fill_lates;
        }
        let fault = p.fault_stats();
        let health = p.health_totals();
        let serving = p.serving_totals();
        let (sample_windows, sample_detailed_ops, sample_ns, sample_ipc) = p.sample_pool();
        let (sample_ns_per_op_mean, sample_ci_ns_per_op) = crate::stats::mean_ci(&sample_ns);
        let (sample_ipc_mean, sample_ci_ipc) = crate::stats::mean_ci(&sample_ipc);
        SimReport {
            mechanism: cfg.mechanism.name(),
            workload: spec.workload.name(),
            cores: cfg.cores,
            finish,
            cpu_period: cfg.core.period,
            retired_insts: core_stats.iter().map(|s| s.retired_insts).sum(),
            retired_ops: core_stats.iter().map(|s| s.retired_ops).sum(),
            loads: core_stats.iter().map(|s| s.loads).sum(),
            stores: core_stats.iter().map(|s| s.stores).sum(),
            fences: core_stats.iter().map(|s| s.fences).sum(),
            twin_retries: core_stats.iter().map(|s| s.twin_retries).sum(),
            safe_paths: core_stats.iter().map(|s| s.safe_paths).sum(),
            cas_fails: core_stats.iter().map(|s| s.cas_fails).sum(),
            llc_hits,
            llc_misses,
            tlb_misses: p.tlb_misses(),
            tlb_accesses: p.tlb_accesses(),
            dram_reads,
            dram_writes,
            dram_read_bytes,
            dram_write_bytes,
            row_hit_rate,
            dram_cmds,
            data_bus_util,
            mlp_mean: p.mlp_meter().mean(p.now()),
            mlp_peak: p.mlp_meter().peak(),
            transform,
            mec_first_loads,
            mec_second_real,
            mec_second_late,
            lvc_evictions,
            pcie_faults: p.pcie_ref().map(|s| s.faults).unwrap_or(0),
            amu_requests: amu.requests,
            amu_queue_stalls: amu.queue_stalls,
            amu_occ_peak: amu.occ_peak,
            amu_occ_mean: amu.occ_mean(),
            mims_requests: mims.requests,
            mims_messages: mims.messages,
            mims_pack_mean: mims.pack_mean(),
            mims_delivered_bytes: mims.delivered_bytes,
            mims_requested_bytes: mims.requested_bytes,
            faults_injected: fault.injected + mec_fill_drops + mec_fill_lates,
            retry_storms: core_stats.iter().map(|s| s.retry_storms).sum(),
            demotions: core_stats.iter().map(|s| s.demotions).sum(),
            ecc_corrected: fault.ecc_corrected,
            mec_fill_drops,
            mec_fill_lates,
            recovery_mean: fault.recovery.mean(),
            recovery_p99: fault.recovery.quantile(0.99),
            recovery_max: fault.recovery.max(),
            ext_accesses: fault.ext_accesses,
            degraded_accesses: fault.degraded_accesses,
            availability: if fault.ext_accesses == 0 {
                1.0
            } else {
                1.0 - fault.degraded_accesses as f64 / fault.ext_accesses as f64
            },
            quarantines: health.quarantines,
            readmits: health.readmits,
            quarantined_served: core_stats.iter().map(|s| s.quarantine_served).sum(),
            mttd_ns: health.mttd_ns,
            mttr_ns: health.mttr_ns,
            degraded_ns: health.degraded_ns,
            deadlocked: p.deadlocked,
            arrived_requests: serving.arrived,
            served_requests: serving.served,
            dropped_requests: serving.dropped,
            req_mean_ns: serving.latency_ns.mean(),
            req_p50_ns: serving.latency_ns.quantile(0.5),
            req_p99_ns: serving.latency_ns.quantile(0.99),
            req_p999_ns: serving.latency_ns.quantile(0.999),
            queue_mean: serving.queue_mean(),
            queue_peak: serving.queue_peak,
            engine: engine.kind.name(),
            engine_events: engine.pushed,
            engine_peak: engine.peak_len,
            engine_resizes: engine.resizes,
            engine_overflow: engine.overflow_pushes,
            engine_buckets: engine.buckets,
            engine_width: engine.width,
            engine_resamples: engine.resamples,
            engine_parallel_pumps: p.parallel_pumps(),
            sample_windows,
            sample_detailed_ops,
            sample_ns_per_op_mean,
            sample_ci_ns_per_op,
            sample_ipc_mean,
            sample_ci_ipc,
        }
    }

    /// Aggregate IPC across cores (instructions / wall-clock cycles,
    /// single-core-equivalent denominator × cores).
    pub fn ipc(&self) -> f64 {
        if self.finish == 0 {
            return 0.0;
        }
        let cycles = self.finish as f64 / self.cpu_period as f64;
        self.retired_insts as f64 / (cycles * self.cores as f64)
    }

    /// Run time in nanoseconds (the normalized-performance numerator).
    pub fn runtime_ns(&self) -> f64 {
        ps_to_ns(self.finish)
    }

    /// Performance relative to a baseline run (paper Figure 7:
    /// `baseline.time / self.time`, so 1.0 = as fast as Ideal).
    pub fn perf_vs(&self, baseline: &SimReport) -> f64 {
        if self.finish == 0 {
            return 0.0;
        }
        baseline.finish as f64 / self.finish as f64
    }

    /// LLC misses per kilo-instruction relative to an instruction base
    /// (the paper plots TL-OoO MPKI against *Ideal* retired instructions).
    pub fn llc_mpki(&self, inst_base: u64) -> f64 {
        if inst_base == 0 {
            return 0.0;
        }
        self.llc_misses as f64 * 1000.0 / inst_base as f64
    }

    pub fn tlb_mpki(&self, inst_base: u64) -> f64 {
        if inst_base == 0 {
            return 0.0;
        }
        self.tlb_misses as f64 * 1000.0 / inst_base as f64
    }

    /// Average DRAM read bandwidth over the run (Figure 12).
    pub fn read_bandwidth_gbps(&self) -> f64 {
        gbps(self.dram_read_bytes, self.finish)
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        let fault = if self.faults_injected > 0 || self.ecc_corrected > 0 {
            format!(
                ", faults {} (storms {}, demoted {}, ecc {}, rec p99 {:.0} ns)",
                self.faults_injected,
                self.retry_storms,
                self.demotions,
                self.ecc_corrected,
                ps_to_ns(self.recovery_p99),
            )
        } else {
            String::new()
        };
        let health = if self.degraded_accesses > 0 || self.quarantines > 0 {
            format!(
                ", avail {:.4} ({}/{} degraded, quar {}/{} readm, mttd {:.0} ns, \
                 mttr {:.0} ns, quar-served {})",
                self.availability,
                self.degraded_accesses,
                self.ext_accesses,
                self.quarantines,
                self.readmits,
                self.mttd_ns,
                self.mttr_ns,
                self.quarantined_served,
            )
        } else {
            String::new()
        };
        let mims = if self.mims_messages > 0 {
            format!(
                ", mims {} msgs (pack {:.1}, {}/{} B)",
                self.mims_messages,
                self.mims_pack_mean,
                self.mims_delivered_bytes,
                self.mims_requested_bytes,
            )
        } else {
            String::new()
        };
        let serving = if self.arrived_requests > 0 {
            format!(
                ", served {}/{} (drops {}, p50 {} ns, p99 {} ns, p99.9 {} ns, \
                 queue peak {})",
                self.served_requests,
                self.arrived_requests,
                self.dropped_requests,
                self.req_p50_ns,
                self.req_p99_ns,
                self.req_p999_ns,
                self.queue_peak,
            )
        } else {
            String::new()
        };
        let sampled = if self.sample_windows > 0 {
            format!(
                ", sampled {} windows ({} detailed ops, {:.1} ± {:.1} ns/op, \
                 IPC {:.2} ± {:.2})",
                self.sample_windows,
                self.sample_detailed_ops,
                self.sample_ns_per_op_mean,
                self.sample_ci_ns_per_op,
                self.sample_ipc_mean,
                self.sample_ci_ipc,
            )
        } else {
            String::new()
        };
        format!(
            "{}/{}: {:.3} ms, IPC {:.2}, LLC miss {}k, TLB miss {}k, BW {:.2} GB/s \
             (bus {:.1}%), MLP {:.1}{}{}{}{}{}{}",
            self.mechanism,
            self.workload,
            self.runtime_ns() / 1e6,
            self.ipc(),
            self.llc_misses / 1000,
            self.tlb_misses / 1000,
            self.read_bandwidth_gbps(),
            self.data_bus_util * 100.0,
            self.mlp_mean,
            fault,
            health,
            mims,
            serving,
            sampled,
            if self.deadlocked { " [DEADLOCK]" } else { "" },
        )
    }
}
