//! Aggregated run statistics — the raw material of Figures 7–13.

use super::platform::Platform;
use crate::twinload::TransformStats;
use crate::util::time::{gbps, ps_to_ns, Ps};

/// Everything a figure bench needs from one simulation run.
#[derive(Debug, Clone)]
pub struct SimReport {
    pub mechanism: &'static str,
    pub workload: &'static str,
    pub cores: usize,
    /// Wall-clock of the simulated execution (max core finish).
    pub finish: Ps,
    pub cpu_period: Ps,
    // Core aggregates.
    pub retired_insts: u64,
    pub retired_ops: u64,
    pub loads: u64,
    pub stores: u64,
    pub fences: u64,
    pub twin_retries: u64,
    pub safe_paths: u64,
    pub cas_fails: u64,
    // Hierarchy.
    pub llc_hits: u64,
    pub llc_misses: u64,
    pub tlb_misses: u64,
    pub tlb_accesses: u64,
    // DRAM.
    pub dram_reads: u64,
    pub dram_writes: u64,
    pub dram_read_bytes: u64,
    pub dram_write_bytes: u64,
    pub row_hit_rate: f64,
    /// Commands issued on every channel's command bus.
    pub dram_cmds: u64,
    /// Mean data-bus utilization across all channels over the run
    /// (fraction of wall-clock spent transferring bursts).
    pub data_bus_util: f64,
    // Concurrency.
    pub mlp_mean: f64,
    pub mlp_peak: u64,
    // Transform.
    pub transform: TransformStats,
    // Mechanism extras.
    pub mec_first_loads: u64,
    pub mec_second_real: u64,
    pub mec_second_late: u64,
    pub lvc_evictions: u64,
    pub pcie_faults: u64,
    // AMU backend: bounded request-queue behavior.
    pub amu_requests: u64,
    pub amu_queue_stalls: u64,
    pub amu_occ_peak: u64,
    pub amu_occ_mean: f64,
    // Fault injection + recovery (all zero when `fault_rate = 0`).
    /// Faults injected across every class: platform sites (not-ready
    /// responses, lost notifies, link redeliveries, PCIe retransfers,
    /// ECC detections) plus MEC prefetch-fill faults.
    pub faults_injected: u64,
    /// Lines that entered a ≥2-retry consecutive both-fake streak.
    pub retry_storms: u64,
    /// Safe-path demotions after `demote_after` consecutive retries.
    pub demotions: u64,
    /// Single-bit errors corrected in-line by the ECC model.
    pub ecc_corrected: u64,
    /// MEC prefetch-buffer fills dropped / landed late by injection.
    pub mec_fill_drops: u64,
    pub mec_fill_lates: u64,
    /// Fault-recovery added latency distribution (ps).
    pub recovery_mean: f64,
    pub recovery_p99: Ps,
    pub recovery_max: Ps,
    pub deadlocked: bool,
    // Event-engine occupancy/housekeeping (engine-agnostic fields like
    // `engine_events`/`engine_peak` must match across engines; resize,
    // overflow, width, and resample counters are calendar-specific
    // diagnostics).
    pub engine: &'static str,
    pub engine_events: u64,
    pub engine_peak: u64,
    pub engine_resizes: u64,
    pub engine_overflow: u64,
    pub engine_buckets: u64,
    /// Current calendar bucket width in ps (0 for the reference heap;
    /// differs from the seed `t_ck` only under the adaptive engine).
    pub engine_width: u64,
    /// Completed adaptive width re-bucketings (adaptive calendar only).
    pub engine_resamples: u64,
}

impl SimReport {
    pub(crate) fn collect(p: &Platform) -> SimReport {
        let cfg = p.cfg();
        let spec = p.spec();
        let core_stats = p.core_stats();
        let finish = core_stats.iter().map(|s| s.finish).max().unwrap_or(0);
        let (llc_hits, llc_misses) = p.llc_stats();
        let (dram_reads, dram_writes, dram_read_bytes, dram_write_bytes, row_hit_rate) =
            p.dram_totals();
        let (dram_cmds, data_bus_util) = p.bus_totals();
        let amu = p.amu_stats();
        let mut transform = TransformStats::default();
        for t in p.transform_stats() {
            transform.logical_mem += t.logical_mem;
            transform.logical_insts += t.logical_insts;
            transform.ext_loads += t.ext_loads;
            transform.ext_stores += t.ext_stores;
            transform.local_accesses += t.local_accesses;
            transform.micro_insts += t.micro_insts;
            transform.fences += t.fences;
        }
        let engine = p.engine_stats();
        let (mut mec_first_loads, mut mec_second_real, mut mec_second_late, mut lvc_evictions) =
            (0, 0, 0, 0);
        let (mut mec_fill_drops, mut mec_fill_lates) = (0, 0);
        for m in p.mec_refs() {
            mec_first_loads += m.stats.first_loads;
            mec_second_real += m.stats.second_real;
            mec_second_late += m.stats.second_late;
            lvc_evictions += m.lvc().evictions;
            mec_fill_drops += m.stats.fill_drops;
            mec_fill_lates += m.stats.fill_lates;
        }
        let fault = p.fault_stats();
        SimReport {
            mechanism: cfg.mechanism.name(),
            workload: spec.workload.name(),
            cores: cfg.cores,
            finish,
            cpu_period: cfg.core.period,
            retired_insts: core_stats.iter().map(|s| s.retired_insts).sum(),
            retired_ops: core_stats.iter().map(|s| s.retired_ops).sum(),
            loads: core_stats.iter().map(|s| s.loads).sum(),
            stores: core_stats.iter().map(|s| s.stores).sum(),
            fences: core_stats.iter().map(|s| s.fences).sum(),
            twin_retries: core_stats.iter().map(|s| s.twin_retries).sum(),
            safe_paths: core_stats.iter().map(|s| s.safe_paths).sum(),
            cas_fails: core_stats.iter().map(|s| s.cas_fails).sum(),
            llc_hits,
            llc_misses,
            tlb_misses: p.tlb_misses(),
            tlb_accesses: p.tlb_accesses(),
            dram_reads,
            dram_writes,
            dram_read_bytes,
            dram_write_bytes,
            row_hit_rate,
            dram_cmds,
            data_bus_util,
            mlp_mean: p.mlp_meter().mean(p.now()),
            mlp_peak: p.mlp_meter().peak(),
            transform,
            mec_first_loads,
            mec_second_real,
            mec_second_late,
            lvc_evictions,
            pcie_faults: p.pcie_ref().map(|s| s.faults).unwrap_or(0),
            amu_requests: amu.requests,
            amu_queue_stalls: amu.queue_stalls,
            amu_occ_peak: amu.occ_peak,
            amu_occ_mean: amu.occ_mean(),
            faults_injected: fault.injected + mec_fill_drops + mec_fill_lates,
            retry_storms: core_stats.iter().map(|s| s.retry_storms).sum(),
            demotions: core_stats.iter().map(|s| s.demotions).sum(),
            ecc_corrected: fault.ecc_corrected,
            mec_fill_drops,
            mec_fill_lates,
            recovery_mean: fault.recovery.mean(),
            recovery_p99: fault.recovery.quantile(0.99),
            recovery_max: fault.recovery.max(),
            deadlocked: p.deadlocked,
            engine: engine.kind.name(),
            engine_events: engine.pushed,
            engine_peak: engine.peak_len,
            engine_resizes: engine.resizes,
            engine_overflow: engine.overflow_pushes,
            engine_buckets: engine.buckets,
            engine_width: engine.width,
            engine_resamples: engine.resamples,
        }
    }

    /// Aggregate IPC across cores (instructions / wall-clock cycles,
    /// single-core-equivalent denominator × cores).
    pub fn ipc(&self) -> f64 {
        if self.finish == 0 {
            return 0.0;
        }
        let cycles = self.finish as f64 / self.cpu_period as f64;
        self.retired_insts as f64 / (cycles * self.cores as f64)
    }

    /// Run time in nanoseconds (the normalized-performance numerator).
    pub fn runtime_ns(&self) -> f64 {
        ps_to_ns(self.finish)
    }

    /// Performance relative to a baseline run (paper Figure 7:
    /// `baseline.time / self.time`, so 1.0 = as fast as Ideal).
    pub fn perf_vs(&self, baseline: &SimReport) -> f64 {
        if self.finish == 0 {
            return 0.0;
        }
        baseline.finish as f64 / self.finish as f64
    }

    /// LLC misses per kilo-instruction relative to an instruction base
    /// (the paper plots TL-OoO MPKI against *Ideal* retired instructions).
    pub fn llc_mpki(&self, inst_base: u64) -> f64 {
        if inst_base == 0 {
            return 0.0;
        }
        self.llc_misses as f64 * 1000.0 / inst_base as f64
    }

    pub fn tlb_mpki(&self, inst_base: u64) -> f64 {
        if inst_base == 0 {
            return 0.0;
        }
        self.tlb_misses as f64 * 1000.0 / inst_base as f64
    }

    /// Average DRAM read bandwidth over the run (Figure 12).
    pub fn read_bandwidth_gbps(&self) -> f64 {
        gbps(self.dram_read_bytes, self.finish)
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        let fault = if self.faults_injected > 0 || self.ecc_corrected > 0 {
            format!(
                ", faults {} (storms {}, demoted {}, ecc {}, rec p99 {:.0} ns)",
                self.faults_injected,
                self.retry_storms,
                self.demotions,
                self.ecc_corrected,
                ps_to_ns(self.recovery_p99),
            )
        } else {
            String::new()
        };
        format!(
            "{}/{}: {:.3} ms, IPC {:.2}, LLC miss {}k, TLB miss {}k, BW {:.2} GB/s \
             (bus {:.1}%), MLP {:.1}{}{}",
            self.mechanism,
            self.workload,
            self.runtime_ns() / 1e6,
            self.ipc(),
            self.llc_misses / 1000,
            self.tlb_misses / 1000,
            self.read_bandwidth_gbps(),
            self.data_bus_util * 100.0,
            self.mlp_mean,
            fault,
            if self.deadlocked { " [DEADLOCK]" } else { "" },
        )
    }
}
