//! SMARTS-style systematic sampling (Wunderlich et al., ISCA '03).
//!
//! A sampled run alternates between three execution modes on a fixed
//! cadence measured in retired memory operations:
//!
//! ```text
//!  |-- functional --|-- warmup --|-- detail --|-- functional --| ...
//!  '------------------------ period ------------------------'
//! ```
//!
//! * **Functional** — operations complete against the cache/TLB content
//!   model at a cheap constant latency: state keeps warming (tags,
//!   residency) but the MSHR/DRAM/backend machinery is bypassed, so
//!   most of the run costs almost nothing.
//! * **Warmup** — full detailed execution, discarded from measurement:
//!   it refills the timing state (queues, row buffers, MLP) that
//!   functional mode cannot maintain.
//! * **Detail** — full detailed execution, measured: each completed
//!   window contributes one ns-per-op and one IPC sample.
//!
//! Window placement inside the period is drawn once from the seeded
//! [`window_offset`] so the cadence is deterministic — the same
//! `sample_seed` reproduces identical window placements, and results
//! are independent of engine/front-end/routing choices exactly like
//! unsampled runs. Per-window samples pool into a CLT confidence
//! interval (`stats::mean_ci`) reported as `sample_ci_*` in
//! [`SimReport`](super::report::SimReport).

use crate::util::time::Ps;
use crate::util::Rng;

/// Execution mode of one core at a given retired-op index.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SampleMode {
    /// Cheap content-model execution (fast-forward).
    Functional,
    /// Detailed execution, not measured (timing-state refill).
    Warmup,
    /// Detailed execution, measured.
    Detail,
}

/// Seeded placement of the warmup+detail window inside the period:
/// a single uniform draw in `[0, period - warmup - detail]`. Pure in
/// its arguments, so every core of a run (and every re-run with the
/// same seed) agrees on the cadence.
pub fn window_offset(seed: u64, period: u64, warmup: u64, detail: u64) -> u64 {
    let slack = period.saturating_sub(warmup.saturating_add(detail));
    if slack == 0 {
        return 0;
    }
    Rng::new(seed).below(slack + 1)
}

/// Per-core sampling state machine. The platform consults
/// [`Sampler::functional`] before each core advance (it decides the
/// memory port's execution mode) and feeds retired-op progress back
/// through [`Sampler::observe`], which detects window boundaries and
/// records per-window samples.
#[derive(Debug)]
pub struct Sampler {
    period: u64,
    warmup: u64,
    detail: u64,
    offset: u64,
    cpu_period: Ps,
    mode: SampleMode,
    /// Anchors of the currently-open detail window.
    win_t: Ps,
    win_ops: u64,
    win_insts: u64,
    /// Cumulative ops at the previous `observe` (for detailed-op
    /// accounting).
    last_ops: u64,
    /// Ops retired while in warmup or detail mode.
    pub detailed_ops: u64,
    /// One ns-per-op sample per completed detail window.
    pub ns_per_op: Vec<f64>,
    /// One IPC sample per completed detail window.
    pub ipc: Vec<f64>,
}

impl Sampler {
    /// `period` must be ≥ `warmup + detail` ≥ 1 (enforced by
    /// `Platform::build`'s spec validation before a sampler exists).
    pub fn new(period: u64, warmup: u64, detail: u64, seed: u64, cpu_period: Ps) -> Sampler {
        debug_assert!(detail >= 1 && warmup + detail <= period);
        let offset = window_offset(seed, period, warmup, detail);
        let mut s = Sampler {
            period,
            warmup,
            detail,
            offset,
            cpu_period,
            mode: SampleMode::Functional,
            win_t: 0,
            win_ops: 0,
            win_insts: 0,
            last_ops: 0,
            detailed_ops: 0,
            ns_per_op: Vec::new(),
            ipc: Vec::new(),
        };
        s.mode = s.mode_at(0);
        s
    }

    /// Mode for the op at cumulative index `op`: a pure function of the
    /// cadence parameters, so mode sequences survive resharding and
    /// engine swaps by construction.
    pub fn mode_at(&self, op: u64) -> SampleMode {
        if op < self.offset {
            return SampleMode::Functional;
        }
        let r = (op - self.offset) % self.period;
        if r < self.warmup {
            SampleMode::Warmup
        } else if r < self.warmup + self.detail {
            SampleMode::Detail
        } else {
            SampleMode::Functional
        }
    }

    /// Whether the core's next advance should run the cheap functional
    /// memory path.
    pub fn functional(&self) -> bool {
        self.mode == SampleMode::Functional
    }

    /// Fold retired-op progress (cumulative ops/insts at sim time
    /// `now`) into the state machine. Called after every core advance;
    /// opens a measurement window on entry to detail mode and closes it
    /// (recording samples) on exit.
    pub fn observe(&mut self, ops: u64, insts: u64, now: Ps) {
        let new_mode = self.mode_at(ops);
        if self.mode != SampleMode::Functional {
            self.detailed_ops += ops - self.last_ops;
        }
        match (self.mode, new_mode) {
            (SampleMode::Detail, SampleMode::Detail) => {}
            (SampleMode::Detail, _) => self.close(ops, insts, now),
            (_, SampleMode::Detail) => {
                self.win_t = now;
                self.win_ops = ops;
                self.win_insts = insts;
            }
            _ => {}
        }
        self.mode = new_mode;
        self.last_ops = ops;
    }

    fn close(&mut self, ops: u64, insts: u64, now: Ps) {
        let d_ops = ops - self.win_ops;
        let d_t = now.saturating_sub(self.win_t);
        // An advance can overshoot a whole window (retire past it in
        // one burst); a window with no ops or no elapsed time carries
        // no information, so drop it rather than divide by zero.
        if d_ops == 0 || d_t == 0 {
            return;
        }
        self.ns_per_op.push(d_t as f64 / 1_000.0 / d_ops as f64);
        let cycles = d_t as f64 / self.cpu_period as f64;
        self.ipc.push((insts - self.win_insts) as f64 / cycles);
    }

    /// Completed measurement windows.
    pub fn windows(&self) -> u64 {
        self.ns_per_op.len() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn drive(s: &mut Sampler, schedule: &[(u64, u64, Ps)]) {
        for &(ops, insts, t) in schedule {
            s.observe(ops, insts, t);
        }
    }

    #[test]
    fn offset_is_deterministic_and_in_range() {
        for seed in [0u64, 1, 42, 0x5A3D, u64::MAX] {
            let a = window_offset(seed, 1000, 64, 64);
            let b = window_offset(seed, 1000, 64, 64);
            assert_eq!(a, b, "same seed must reproduce the placement");
            assert!(a <= 1000 - 128, "offset must leave room for the window");
        }
        // No slack -> window pinned at the period start.
        assert_eq!(window_offset(7, 128, 64, 64), 0);
        assert_eq!(window_offset(7, 100, 64, 64), 0);
        // Different seeds should (generically) move the window.
        let spread: std::collections::BTreeSet<u64> =
            (0..16u64).map(|s| window_offset(s, 100_000, 64, 64)).collect();
        assert!(spread.len() > 1, "placements must actually depend on the seed");
    }

    #[test]
    fn mode_sequence_follows_the_cadence() {
        let s = Sampler::new(100, 10, 5, 3, 1_250);
        let off = s.offset;
        for op in 0..off {
            assert_eq!(s.mode_at(op), SampleMode::Functional);
        }
        for rep in 0..3u64 {
            let base = off + rep * 100;
            for i in 0..10 {
                assert_eq!(s.mode_at(base + i), SampleMode::Warmup, "warmup at {i}");
            }
            for i in 10..15 {
                assert_eq!(s.mode_at(base + i), SampleMode::Detail, "detail at {i}");
            }
            for i in 15..100 {
                assert_eq!(s.mode_at(base + i), SampleMode::Functional, "functional at {i}");
            }
        }
    }

    #[test]
    fn windows_record_ns_per_op_and_ipc() {
        let mut s = Sampler::new(100, 10, 5, 3, 1_000);
        // Drive exactly three full periods past the seeded offset so
        // the expected counts are exact for any offset draw.
        let total = s.offset + 300;
        // Walk op-by-op at 2 ns per op, 3 insts per op.
        let mut sched = Vec::new();
        for op in 1..=total {
            sched.push((op, op * 3, op * 2_000));
        }
        drive(&mut s, &sched);
        assert_eq!(s.windows(), 3, "three full periods -> three windows");
        for w in &s.ns_per_op {
            assert!((w - 2.0).abs() < 1e-9, "uniform stream -> 2 ns/op, got {w}");
        }
        for ipc in &s.ipc {
            // 3 insts per 2 cycles (cpu_period 1000 ps, 2000 ps per op).
            assert!((ipc - 1.5).abs() < 1e-9, "expected IPC 1.5, got {ipc}");
        }
        // Exactly the three (warmup + detail) windows ran detailed;
        // everything else fast-forwarded.
        assert_eq!(s.detailed_ops, 3 * 15);
        assert!((s.detailed_ops as f64) <= 0.2 * total as f64);
    }

    #[test]
    fn overshooting_a_window_drops_it_cleanly() {
        let mut s = Sampler::new(100, 10, 5, 3, 1_000);
        let off = s.offset;
        // One giant advance that jumps from before the window to far
        // past it: no sample, no panic, accounting still sane.
        drive(&mut s, &[(off + 50, (off + 50) * 3, 1_000_000)]);
        assert_eq!(s.windows(), 0);
        assert_eq!(s.mode, SampleMode::Functional);
    }

    #[test]
    fn same_seed_same_windows_different_seed_moves_them() {
        let a = Sampler::new(1_000, 64, 64, 0x5A3D, 1_250);
        let b = Sampler::new(1_000, 64, 64, 0x5A3D, 1_250);
        assert_eq!(a.offset, b.offset);
        let moved = (0..32u64).any(|s| Sampler::new(1_000, 64, 64, s, 1_250).offset != a.offset);
        assert!(moved, "window placement must depend on sample_seed");
    }
}
