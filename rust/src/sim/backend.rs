//! Pluggable extension-memory backends.
//!
//! Every system the paper compares realizes "more memory than the
//! interface scales to" differently: plain local DIMMs (Ideal), a remote
//! socket behind QPI (NUMA), OS page swapping over PCIe, a longer read
//! latency (increased tRL), the MEC tree driven by twin loads, and — the
//! asynchronous future the paper gestures at (§8) — an AMU-style unit
//! with an explicit request/notify interface. This module is the seam
//! that keeps [`crate::sim::platform::Platform`] mechanism-agnostic: all
//! per-mechanism state and routing decisions live behind [`ExtBackend`],
//! a typed enum constructed up front (no `Option` fields, no `.expect`
//! panics at routing time).
//!
//! Two interchangeable routing implementations sit behind the
//! crate-internal `Router` dispatch:
//!
//! * [`ExtBackend`] (default) — one enum variant per mechanism, each
//!   owning exactly the state its mechanism needs.
//! * [`LegacyRouter`] — the pre-refactor structure (a bag of `Option`
//!   fields consulted per hook), retained as the differential reference
//!   in the same spirit as `EngineKind::ReferenceHeap` and
//!   `FrontEnd::Reference`: the `backend-routing` equivalence tests and
//!   the golden backend-independence row prove both routings produce
//!   bit-identical `SimReport`s for every mechanism.
//!
//! The hooks are deliberately few: construction (which also builds the
//! extended `ChannelGroup`), transaction ingress (arrival-time
//! adjustment on the way to the controllers), service observation (the
//! MEC watches the command bus), completion egress (extra latency on the
//! way back), and a handful of read-only accessors for `SimReport`.

use crate::baselines::{increased_trl, NumaLink, PcieSwap};
use crate::cache::DataKind;
use crate::config::SystemConfig;
use crate::dram::address::AddressMapping;
use crate::dram::{MemController, ServiceResult};
use crate::mec::Mec1;
use crate::twinload::Mechanism;
use crate::util::time::Ps;
use crate::workloads::DataRegions;
use anyhow::{bail, Result};

/// How a channel group realizes its accesses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum GroupKind {
    /// Plain local DRAM.
    Local,
    /// The MEC'd extended channel (TL systems): spans ext + shadow.
    ExtMec,
    /// Remote DRAM behind the QPI link (NUMA).
    ExtRemote,
    /// Extended channel with increased tRL (§7.2).
    ExtTrl,
    /// Extended channel behind the asynchronous memory-access unit.
    ExtAmu,
    /// The MEC'd extended channel carrying packed MIMS messages: same
    /// trees and span as [`GroupKind::ExtMec`], plus per-message framing
    /// modeled by the MIMS unit at ingress.
    ExtMims,
}

/// A set of interleaved channels covering one address range.
pub(crate) struct ChannelGroup {
    pub(crate) kind: GroupKind,
    pub(crate) base: u64,
    pub(crate) span: u64,
    pub(crate) map: AddressMapping,
    pub(crate) channels: Vec<MemController>,
    /// Earliest scheduled Pump event (spam guard; stale events are
    /// harmless because pumping is idempotent).
    pub(crate) next_pump: Option<Ps>,
}

impl ChannelGroup {
    /// Route a line address within this group: (channel, channel-local).
    pub(crate) fn route(&self, vaddr: u64) -> (usize, u64) {
        let rel = (vaddr - self.base) % self.span;
        let line = rel / 64;
        let n = self.channels.len() as u64;
        let ch = (line % n) as usize;
        let ch_addr = (line / n) * 64;
        (ch, ch_addr)
    }
}

/// Which routing implementation carries the extension-memory state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Routing {
    /// Typed per-mechanism backend (default).
    Backend,
    /// Pre-refactor `Option`-field routing, retained for differential
    /// testing (proves the backend refactor is behavior-preserving).
    Legacy,
}

impl Routing {
    pub fn name(&self) -> &'static str {
        match self {
            Routing::Backend => "backend",
            Routing::Legacy => "legacy",
        }
    }

    pub fn by_name(name: &str) -> Option<Routing> {
        match name {
            "backend" => Some(Routing::Backend),
            "legacy" | "reference" => Some(Routing::Legacy),
            _ => None,
        }
    }
}

// ---------------------------------------------------------------------
// AMU: asynchronous memory-access unit.
// ---------------------------------------------------------------------

/// Occupancy/housekeeping counters of the AMU request queue, surfaced
/// through `SimReport`.
#[derive(Debug, Default, Clone, Copy)]
pub struct AmuStats {
    /// Requests accepted by the unit (reads, writes, and prefetches).
    pub requests: u64,
    /// Requests that found the bounded queue full and had to wait for a
    /// slot before the unit would accept them.
    pub queue_stalls: u64,
    /// Sum over requests of the queue occupancy observed at arrival
    /// (divide by `requests` for the mean).
    pub occ_sum: u64,
    /// Peak queue occupancy observed at any arrival.
    pub occ_peak: u64,
}

impl AmuStats {
    /// Mean queue occupancy observed at request arrival.
    pub fn occ_mean(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            self.occ_sum as f64 / self.requests as f64
        }
    }
}

/// An AMU-style asynchronous access unit (after MIMS and the
/// "Asynchronous Memory Access Unit" line of work in PAPERS.md): the
/// core posts an explicit request message into a *bounded* queue, the
/// unit dispatches requests toward the extended controllers at its
/// service rate, and completions travel back as notify messages (the
/// platform schedules them through the event engine as ordinary
/// `Deliver` events). Cached extended lines are synchronous hits — the
/// notify fills the cache exactly like a DDR data burst would.
///
/// The bounded queue is modeled exactly and allocation-free with a ring
/// of the last `depth` dispatch times: a request arriving at `t` must
/// wait for the slot of the request `depth` positions back to dispatch
/// (queue-full backpressure), then for the unit's serial dispatch cursor
/// (one request per `svc`), then pays the one-way `issue_lat` to reach
/// the remote controllers. Completions add `notify_lat` on the way back.
#[derive(Debug, Clone)]
pub struct AmuUnit {
    issue_lat: Ps,
    notify_lat: Ps,
    svc: Ps,
    /// Dispatch times of the last `depth` accepted requests (ring).
    ring: Vec<Ps>,
    head: usize,
    /// Earliest time the serial dispatch stage is free again.
    next_free: Ps,
    pub stats: AmuStats,
}

impl AmuUnit {
    /// Build a unit; `depth` is the bounded request-queue depth.
    pub fn new(depth: usize, issue_lat: Ps, notify_lat: Ps, svc: Ps) -> Result<AmuUnit> {
        if depth == 0 {
            bail!("amu_depth must be at least 1");
        }
        Ok(AmuUnit {
            issue_lat,
            notify_lat,
            svc,
            ring: vec![0; depth],
            head: 0,
            next_free: 0,
            stats: AmuStats::default(),
        })
    }

    fn from_cfg(cfg: &SystemConfig) -> Result<AmuUnit> {
        AmuUnit::new(cfg.amu_depth, cfg.amu_issue, cfg.amu_notify, cfg.amu_svc)
    }

    /// A request reaches the unit at `arrive`; returns its arrival time
    /// at the remote controller (after queueing, serial dispatch, and
    /// the one-way transfer).
    pub fn ingress(&mut self, arrive: Ps) -> Ps {
        // Occupancy at arrival: previously accepted requests that have
        // not yet dispatched. The ring holds exactly the last `depth`
        // dispatch times, so occupancy is bounded by the queue depth.
        let occ = self.occupancy_at(arrive);
        self.stats.requests += 1;
        self.stats.occ_sum += occ;
        self.stats.occ_peak = self.stats.occ_peak.max(occ);
        // Bounded queue: a full queue delays acceptance until the
        // request `depth` positions back has dispatched.
        let slot_free = self.ring[self.head];
        let eff = arrive.max(slot_free);
        if eff > arrive {
            self.stats.queue_stalls += 1;
        }
        let dispatch = eff.max(self.next_free);
        self.next_free = dispatch + self.svc;
        self.ring[self.head] = dispatch;
        self.head = (self.head + 1) % self.ring.len();
        dispatch + self.issue_lat
    }

    /// Queue occupancy at time `t`: how many of the last `depth`
    /// accepted requests dispatch strictly after `t`. Dispatch times are
    /// non-decreasing in insertion order (`dispatch >= next_free >=
    /// previous dispatch`), and reading the ring circularly from `head`
    /// (oldest first) is exactly insertion order — the never-written
    /// zero slots of a cold ring sort before every real dispatch — so
    /// the `> t` entries form a suffix and a binary search finds its
    /// start in O(log depth) instead of scanning the ring per request.
    fn occupancy_at(&self, t: Ps) -> u64 {
        let depth = self.ring.len();
        let (mut lo, mut hi) = (0usize, depth);
        while lo < hi {
            let mid = (lo + hi) / 2;
            if self.ring[(self.head + mid) % depth] > t {
                hi = mid;
            } else {
                lo = mid + 1;
            }
        }
        (depth - lo) as u64
    }

    /// Completion-notify latency added on the way back to the core.
    pub fn notify_lat(&self) -> Ps {
        self.notify_lat
    }

    /// Configured queue depth.
    pub fn depth(&self) -> usize {
        self.ring.len()
    }
}

// ---------------------------------------------------------------------
// MIMS: message-interface packing unit.
// ---------------------------------------------------------------------

/// Packing/framing counters of the MIMS message interface, surfaced
/// through `SimReport`.
#[derive(Debug, Default, Clone, Copy)]
pub struct MimsStats {
    /// Extended transactions carried inside messages.
    pub requests: u64,
    /// Messages framed (one per `pack` transactions, last one partial).
    pub messages: u64,
    /// Bytes the fine-granularity interface actually moved
    /// (`granule` per transaction).
    pub delivered_bytes: u64,
    /// Bytes a fixed 64 B-burst interface would have moved.
    pub requested_bytes: u64,
}

impl MimsStats {
    /// Mean transactions per framed message.
    pub fn pack_mean(&self) -> f64 {
        if self.messages == 0 {
            0.0
        } else {
            self.requests as f64 / self.messages as f64
        }
    }
}

/// The MIMS message-interface unit (after "MIMS: Towards a Message
/// Interface based Memory System", PAPERS.md): the extension channel
/// carries variable-size packed request/response *messages* instead of
/// fixed synchronous 64 B bursts. The lowering side
/// ([`Mechanism::Mims`]) packs up to `pack` twin-load pairs behind one
/// fence; this unit models the channel side — a per-message framing
/// cost amortized over the `pack` transactions sharing the message, and
/// the sub-64 B fine-granularity accounting (`granule` bytes delivered
/// per transaction instead of a full burst).
///
/// At `pack == 1` the unit is inert (no framing delay), so the `mims`
/// mechanism degenerates to exactly the unpacked MEC path — the
/// differential tests pin that identity.
#[derive(Debug, Clone)]
pub struct MimsUnit {
    pack: u32,
    frame: Ps,
    granule: u32,
    pub stats: MimsStats,
}

impl MimsUnit {
    /// Build a unit; `pack` is the message packing factor and `granule`
    /// the fine-granularity transfer size in bytes (64 = full bursts).
    pub fn new(pack: u32, frame: Ps, granule: u32) -> Result<MimsUnit> {
        if pack == 0 {
            bail!("mims_pack must be at least 1");
        }
        if granule == 0 || granule > 64 {
            bail!("mims_granule must be in 1..=64 bytes");
        }
        Ok(MimsUnit { pack, frame, granule, stats: MimsStats::default() })
    }

    fn from_cfg(cfg: &SystemConfig) -> Result<MimsUnit> {
        MimsUnit::new(cfg.mims_pack, cfg.mims_frame, cfg.mims_granule)
    }

    /// A transaction reaches the channel at `arrive`; returns its
    /// arrival at the controller after its amortized share of the
    /// message-framing cost. Inert (identity) at `pack == 1`.
    pub fn ingress(&mut self, arrive: Ps) -> Ps {
        if self.stats.requests % self.pack as u64 == 0 {
            self.stats.messages += 1;
        }
        self.stats.requests += 1;
        self.stats.delivered_bytes += self.granule as u64;
        self.stats.requested_bytes += 64;
        if self.pack <= 1 {
            arrive
        } else {
            arrive + self.frame / self.pack as u64
        }
    }

    /// Configured packing factor.
    pub fn pack(&self) -> u32 {
        self.pack
    }

    /// Configured fine-granularity transfer size (bytes).
    pub fn granule(&self) -> u32 {
        self.granule
    }
}

// ---------------------------------------------------------------------
// Shared construction helpers (both routings build identical hardware).
// ---------------------------------------------------------------------

/// The MEC'd extended channel plan, shared by the group builder and the
/// MEC-tree builder so the trees always observe the command stream with
/// the exact mapping the controllers decode: (channel count, per-channel
/// geometry, per-channel address mapping).
fn mec_channel_plan(cfg: &SystemConfig) -> (u64, crate::dram::timing::Geometry, AddressMapping) {
    // Extended + shadow space line-interleaved over the same number of
    // channels as the Ideal system's extra DIMMs (paper Table 3:
    // extended memory lives on the host's own channels).
    let nch = 4u64;
    let geo = crate::config::geometry_for(2 * cfg.layout.ext_size / nch);
    let map = AddressMapping::new(&geo, 1);
    (nch, geo, map)
}

/// Build the extended channel group for `cfg`, if the mechanism has one
/// (PCIe swaps into local DRAM and has none).
fn ext_group(cfg: &SystemConfig) -> Option<ChannelGroup> {
    let layout = cfg.layout;
    match cfg.mechanism {
        Mechanism::TlLf | Mechanism::TlOoO | Mechanism::TlLfBatched(_) => {
            // Each channel carries its own MEC tree (built by
            // `build_mecs` from the same plan).
            let (nch, geo, map) = mec_channel_plan(cfg);
            Some(ChannelGroup {
                kind: GroupKind::ExtMec,
                base: layout.ext_base(),
                span: 2 * layout.ext_size,
                map,
                channels: (0..nch)
                    .map(|_| MemController::with_policy(cfg.host_timing, geo, cfg.sched))
                    .collect(),
                next_pump: None,
            })
        }
        Mechanism::Ideal => {
            // Extended data on equally-local channels (the paper's
            // emulation spreads it over the host's four channels).
            let geo = cfg.ext_channel_geometry();
            Some(ChannelGroup {
                kind: GroupKind::Local,
                base: layout.ext_base(),
                span: layout.ext_size,
                map: AddressMapping::new(&geo, 1),
                channels: (0..4)
                    .map(|_| MemController::with_policy(cfg.host_timing, geo, cfg.sched))
                    .collect(),
                next_pump: None,
            })
        }
        Mechanism::Numa => {
            let geo = cfg.ext_channel_geometry();
            Some(ChannelGroup {
                kind: GroupKind::ExtRemote,
                base: layout.ext_base(),
                span: layout.ext_size,
                map: AddressMapping::new(&geo, 1),
                channels: (0..4)
                    .map(|_| MemController::with_policy(cfg.host_timing, geo, cfg.sched))
                    .collect(),
                next_pump: None,
            })
        }
        Mechanism::IncreasedTrl => {
            // Same four-channel layout as every other system — only
            // the timing differs (tRL + extra, bank held longer).
            let geo = cfg.ext_channel_geometry();
            let timing = increased_trl(&cfg.host_timing, cfg.trl_extra);
            Some(ChannelGroup {
                kind: GroupKind::ExtTrl,
                base: layout.ext_base(),
                span: layout.ext_size,
                map: AddressMapping::new(&geo, 1),
                channels: (0..4)
                    .map(|_| MemController::with_policy(timing, geo, cfg.sched))
                    .collect(),
                next_pump: None,
            })
        }
        Mechanism::Amu => {
            // Extended memory behind the asynchronous unit, spread over
            // the same four channels as Ideal/NUMA: the unit changes how
            // requests *reach* the controllers, not the DRAM behind them.
            let geo = cfg.ext_channel_geometry();
            Some(ChannelGroup {
                kind: GroupKind::ExtAmu,
                base: layout.ext_base(),
                span: layout.ext_size,
                map: AddressMapping::new(&geo, 1),
                channels: (0..4)
                    .map(|_| MemController::with_policy(cfg.host_timing, geo, cfg.sched))
                    .collect(),
                next_pump: None,
            })
        }
        Mechanism::Mims(_) => {
            // Same MEC'd hardware as the twin-load systems (the message
            // interface rides the extension channel; the trees still
            // answer from their prefetch buffers) — only the GroupKind
            // differs, so the MIMS unit can frame messages at ingress.
            let (nch, geo, map) = mec_channel_plan(cfg);
            Some(ChannelGroup {
                kind: GroupKind::ExtMims,
                base: layout.ext_base(),
                span: 2 * layout.ext_size,
                map,
                channels: (0..nch)
                    .map(|_| MemController::with_policy(cfg.host_timing, geo, cfg.sched))
                    .collect(),
                next_pump: None,
            })
        }
        Mechanism::Pcie => {
            // Extended data swaps into local DRAM; DRAM-level routing
            // aliases ext addresses onto the local channels (cache and
            // TLB still see distinct virtual lines).
            None
        }
    }
}

/// One MEC tree per extended channel (a real deployment extends each DDR
/// channel with its own MEC1 — Figure 3 shows one channel's tree). Uses
/// the same [`mec_channel_plan`] as the group builder, so tree mapping
/// and controller decoding can never drift apart.
fn build_mecs(cfg: &SystemConfig) -> Vec<Mec1> {
    let (nch, _geo, map) = mec_channel_plan(cfg);
    // Arming here (not per routing) keeps Backend and Legacy fault
    // schedules bit-identical: the plan is pure state-free hashing, so
    // identical command streams see identical fill faults.
    let plan = crate::sim::fault::FaultPlan::from_cfg(cfg);
    (0..nch)
        .map(|_| {
            let mut m = Mec1::new(cfg.mec, cfg.layout.ext_size / nch, map, &cfg.host_timing);
            m.set_fault_plan(plan);
            m
        })
        .collect()
}

/// PCIe residency pool sized from the workload's extended footprint.
fn build_pcie(cfg: &SystemConfig, data: &DataRegions) -> PcieSwap {
    let ext_pages = (data.ext_len / 4096) as usize;
    let resident = ((ext_pages as f64) * cfg.pcie_local_frac).max(1.0) as usize;
    PcieSwap::paper(resident)
}

// ---------------------------------------------------------------------
// The typed backend (default routing).
// ---------------------------------------------------------------------

/// Per-mechanism extension-memory state, one variant per mechanism.
/// Constructed once by [`ExtBackend::build`]; no hook ever has to
/// unwrap an `Option` to reach its mechanism's state.
///
/// # Hook contract
///
/// The platform drives a backend through exactly three hooks, all keyed
/// on the `GroupKind` of the channel group the transaction targets
/// (a backend must no-op for kinds it does not own):
///
/// * **ingress** — called once per transaction on its way to the
///   extended controllers, with the arrival time in ps; returns the
///   (possibly delayed) time the transaction reaches the controller.
///   May mutate backend state (link occupancy, AMU queue), so it must
///   be called exactly once per transaction, in controller-arrival
///   order.
/// * **egress_delay** — read-only; the extra completion latency in ps
///   added on the way back to the core. Must be stable for a given
///   backend state (the platform may query it repeatedly).
/// * **observe_commands** — called once per serviced transaction with
///   the DRAM command stream it generated; returns the [`DataKind`]
///   the host-facing interface produced (the MEC's real-vs-fake
///   answer; `Real` for every other backend). This is the only hook
///   that may change the *content* a core observes.
pub enum ExtBackend {
    /// Ideal: extended data on equally-local channels; stateless.
    Direct,
    /// NUMA: extended accesses cross a QPI-like link both ways.
    Numa(NumaLink),
    /// PCIe page swapping: a residency pool faulted at access time.
    Pcie(PcieSwap),
    /// Increased tRL: the timing difference lives in the channel group;
    /// stateless at routing time.
    IncreasedTrl,
    /// Twin-load: one MEC tree per extended channel observes the
    /// command stream.
    Mec(Vec<Mec1>),
    /// AMU-style asynchronous unit with a bounded request queue.
    Amu(AmuUnit),
    /// MIMS message interface: the same per-channel MEC trees as
    /// [`ExtBackend::Mec`] behind a packing/framing unit.
    Mims { mecs: Vec<Mec1>, unit: MimsUnit },
}

impl ExtBackend {
    /// Typed construction from the system config (plus the workload
    /// placement, which sizes the PCIe residency pool).
    pub fn build(cfg: &SystemConfig, data: &DataRegions) -> Result<ExtBackend> {
        Ok(match cfg.mechanism {
            Mechanism::TlLf | Mechanism::TlOoO | Mechanism::TlLfBatched(_) => {
                ExtBackend::Mec(build_mecs(cfg))
            }
            Mechanism::Ideal => ExtBackend::Direct,
            Mechanism::Numa => ExtBackend::Numa(NumaLink::new(cfg.numa_one_way, cfg.numa_gbps)),
            Mechanism::Pcie => ExtBackend::Pcie(build_pcie(cfg, data)),
            Mechanism::IncreasedTrl => ExtBackend::IncreasedTrl,
            Mechanism::Amu => ExtBackend::Amu(AmuUnit::from_cfg(cfg)?),
            Mechanism::Mims(_) => {
                ExtBackend::Mims { mecs: build_mecs(cfg), unit: MimsUnit::from_cfg(cfg)? }
            }
        })
    }

    fn ingress(&mut self, kind: GroupKind, arrive: Ps) -> Ps {
        match self {
            ExtBackend::Numa(link) if kind == GroupKind::ExtRemote => link.cross(arrive),
            ExtBackend::Amu(unit) if kind == GroupKind::ExtAmu => unit.ingress(arrive),
            ExtBackend::Mims { unit, .. } if kind == GroupKind::ExtMims => unit.ingress(arrive),
            _ => arrive,
        }
    }

    fn egress_delay(&self, kind: GroupKind) -> Ps {
        match self {
            ExtBackend::Numa(link) if kind == GroupKind::ExtRemote => link.one_way,
            ExtBackend::Amu(unit) if kind == GroupKind::ExtAmu => unit.notify_lat(),
            _ => 0,
        }
    }

    fn observe_commands(&mut self, kind: GroupKind, ch: usize, r: &ServiceResult) -> DataKind {
        match self {
            ExtBackend::Mec(mecs) if kind == GroupKind::ExtMec => {
                let mut data = DataKind::Real;
                let mec = &mut mecs[ch];
                for cmd in &r.commands {
                    if let Some(outcome) = mec.on_command(cmd) {
                        data = outcome.data();
                    }
                }
                data
            }
            ExtBackend::Mims { mecs, .. } if kind == GroupKind::ExtMims => {
                let mut data = DataKind::Real;
                let mec = &mut mecs[ch];
                for cmd in &r.commands {
                    if let Some(outcome) = mec.on_command(cmd) {
                        data = outcome.data();
                    }
                }
                data
            }
            _ => DataKind::Real,
        }
    }
}

// ---------------------------------------------------------------------
// The retained pre-refactor routing (differential reference).
// ---------------------------------------------------------------------

/// The pre-refactor extension-memory state layout: a bag of `Option`
/// fields, each hook consulting whichever happens to be populated.
/// Retained purely as the differential reference proving the typed
/// backend is behavior-preserving (see the module docs); the unwrap
/// panics of the original are gone — an unpopulated field simply routes
/// as a no-op, which is unreachable for validated configs.
pub struct LegacyRouter {
    numa: Option<NumaLink>,
    pcie: Option<PcieSwap>,
    mecs: Vec<Mec1>,
    amu: Option<AmuUnit>,
    mims: Option<MimsUnit>,
}

impl LegacyRouter {
    pub fn build(cfg: &SystemConfig, data: &DataRegions) -> Result<LegacyRouter> {
        let mut numa = None;
        let mut pcie = None;
        let mut mecs = Vec::new();
        let mut amu = None;
        let mut mims = None;
        match cfg.mechanism {
            Mechanism::TlLf | Mechanism::TlOoO | Mechanism::TlLfBatched(_) => {
                mecs = build_mecs(cfg);
            }
            Mechanism::Numa => numa = Some(NumaLink::new(cfg.numa_one_way, cfg.numa_gbps)),
            Mechanism::Pcie => pcie = Some(build_pcie(cfg, data)),
            Mechanism::Amu => amu = Some(AmuUnit::from_cfg(cfg)?),
            Mechanism::Mims(_) => {
                mecs = build_mecs(cfg);
                mims = Some(MimsUnit::from_cfg(cfg)?);
            }
            Mechanism::Ideal | Mechanism::IncreasedTrl => {}
        }
        Ok(LegacyRouter { numa, pcie, mecs, amu, mims })
    }

    fn ingress(&mut self, kind: GroupKind, arrive: Ps) -> Ps {
        match kind {
            GroupKind::ExtRemote => match &mut self.numa {
                Some(link) => link.cross(arrive),
                None => arrive,
            },
            GroupKind::ExtAmu => match &mut self.amu {
                Some(unit) => unit.ingress(arrive),
                None => arrive,
            },
            GroupKind::ExtMims => match &mut self.mims {
                Some(unit) => unit.ingress(arrive),
                None => arrive,
            },
            _ => arrive,
        }
    }

    fn egress_delay(&self, kind: GroupKind) -> Ps {
        match kind {
            GroupKind::ExtRemote => self.numa.as_ref().map_or(0, |l| l.one_way),
            GroupKind::ExtAmu => self.amu.as_ref().map_or(0, |u| u.notify_lat()),
            _ => 0,
        }
    }

    fn observe_commands(&mut self, kind: GroupKind, ch: usize, r: &ServiceResult) -> DataKind {
        let mut data = DataKind::Real;
        if matches!(kind, GroupKind::ExtMec | GroupKind::ExtMims) {
            let mec = &mut self.mecs[ch];
            for cmd in &r.commands {
                if let Some(outcome) = mec.on_command(cmd) {
                    data = outcome.data();
                }
            }
        }
        data
    }
}

// ---------------------------------------------------------------------
// The router the platform holds.
// ---------------------------------------------------------------------

/// Routing dispatch: the typed backend or the retained legacy layout,
/// selected by `SystemConfig::routing` (INI `routing =`, CLI
/// `--routing`).
pub(crate) enum Router {
    Backend(ExtBackend),
    Legacy(LegacyRouter),
}

impl Router {
    /// Build the routing state plus the extended channel group.
    pub(crate) fn build(
        cfg: &SystemConfig,
        data: &DataRegions,
    ) -> Result<(Router, Option<ChannelGroup>)> {
        let group = ext_group(cfg);
        let router = match cfg.routing {
            Routing::Backend => Router::Backend(ExtBackend::build(cfg, data)?),
            Routing::Legacy => Router::Legacy(LegacyRouter::build(cfg, data)?),
        };
        Ok((router, group))
    }

    /// Adjust a transaction's controller arrival time on the way in.
    pub(crate) fn ingress(&mut self, kind: GroupKind, arrive: Ps) -> Ps {
        match self {
            Router::Backend(b) => b.ingress(kind, arrive),
            Router::Legacy(l) => l.ingress(kind, arrive),
        }
    }

    /// Ingress with the correlated-fault layer applied on top: a domain
    /// in a fail-slow window stretches whatever latency the hook itself
    /// added by `burst_slow_mult`. Pure in (plan, kind, `arrive`), so
    /// both routings and all engines see the same degraded schedule; a
    /// `None` plan (or a plan without a burst layer) is exactly
    /// [`Router::ingress`].
    pub(crate) fn ingress_degraded(
        &mut self,
        kind: GroupKind,
        arrive: Ps,
        plan: Option<&crate::sim::fault::FaultPlan>,
    ) -> Ps {
        let t = self.ingress(kind, arrive);
        match plan.and_then(|p| p.burst_slow(kind, arrive)) {
            Some(mult) => t + (t - arrive) * (mult - 1),
            None => t,
        }
    }

    /// Extra completion latency on the way back to the core.
    pub(crate) fn egress_delay(&self, kind: GroupKind) -> Ps {
        match self {
            Router::Backend(b) => b.egress_delay(kind),
            Router::Legacy(l) => l.egress_delay(kind),
        }
    }

    /// Egress with the correlated-fault layer applied on top: a fail-slow
    /// window multiplies the whole return path (egress hop plus the
    /// `fill_lat` cache-fill leg, the component every mechanism shares)
    /// by `burst_slow_mult`. `at` is the service-completion instant the
    /// window is evaluated at — identical across implementations.
    pub(crate) fn egress_degraded(
        &self,
        kind: GroupKind,
        at: Ps,
        fill_lat: Ps,
        plan: Option<&crate::sim::fault::FaultPlan>,
    ) -> Ps {
        let eg = self.egress_delay(kind);
        match plan.and_then(|p| p.burst_slow(kind, at)) {
            Some(mult) => eg + (fill_lat + eg) * (mult - 1),
            None => eg,
        }
    }

    /// Let the backend observe one serviced transaction's command
    /// stream; returns the content the host-facing interface produced.
    pub(crate) fn observe_commands(
        &mut self,
        kind: GroupKind,
        ch: usize,
        r: &ServiceResult,
    ) -> DataKind {
        match self {
            Router::Backend(b) => b.observe_commands(kind, ch, r),
            Router::Legacy(l) => l.observe_commands(kind, ch, r),
        }
    }

    /// Extended addresses alias onto the local channels (PCIe swapping).
    pub(crate) fn aliases_local(&self) -> bool {
        match self {
            Router::Backend(b) => matches!(b, ExtBackend::Pcie(_)),
            Router::Legacy(l) => l.pcie.is_some(),
        }
    }

    /// Extended pages' leaf PTEs live on the remote node (NUMA): page
    /// walks to them pay remote latency and walker occupancy.
    pub(crate) fn remote_page_walks(&self) -> bool {
        match self {
            Router::Backend(b) => matches!(b, ExtBackend::Numa(_)),
            Router::Legacy(l) => l.numa.is_some(),
        }
    }

    pub(crate) fn pcie_mut(&mut self) -> Option<&mut PcieSwap> {
        match self {
            Router::Backend(ExtBackend::Pcie(p)) => Some(p),
            Router::Backend(_) => None,
            Router::Legacy(l) => l.pcie.as_mut(),
        }
    }

    pub(crate) fn pcie(&self) -> Option<&PcieSwap> {
        match self {
            Router::Backend(ExtBackend::Pcie(p)) => Some(p),
            Router::Backend(_) => None,
            Router::Legacy(l) => l.pcie.as_ref(),
        }
    }

    pub(crate) fn mecs(&self) -> &[Mec1] {
        match self {
            Router::Backend(ExtBackend::Mec(m)) => m,
            Router::Backend(ExtBackend::Mims { mecs, .. }) => mecs,
            Router::Backend(_) => &[],
            Router::Legacy(l) => &l.mecs,
        }
    }

    pub(crate) fn mims(&self) -> Option<&MimsUnit> {
        match self {
            Router::Backend(ExtBackend::Mims { unit, .. }) => Some(unit),
            Router::Backend(_) => None,
            Router::Legacy(l) => l.mims.as_ref(),
        }
    }

    pub(crate) fn amu(&self) -> Option<&AmuUnit> {
        match self {
            Router::Backend(ExtBackend::Amu(u)) => Some(u),
            Router::Backend(_) => None,
            Router::Legacy(l) => l.amu.as_ref(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;

    fn data_stub() -> DataRegions {
        DataRegions { ext_base: 128 << 20, ext_len: 8 << 20, local_base: 0, local_len: 8 << 20 }
    }

    #[test]
    fn amu_serializes_at_the_service_rate() {
        let mut u = AmuUnit::new(8, 10_000, 10_000, 1_250).unwrap();
        let a = u.ingress(0);
        let b = u.ingress(0);
        assert_eq!(a, 10_000, "first request dispatches immediately");
        assert_eq!(b - a, 1_250, "second request waits one service slot");
        assert_eq!(u.stats.requests, 2);
        assert_eq!(u.stats.queue_stalls, 0, "queue not full yet");
    }

    #[test]
    fn amu_bounded_queue_backpressure() {
        // Depth 1: the slot frees when the previous request dispatches.
        let mut u = AmuUnit::new(1, 0, 0, 1_000).unwrap();
        u.ingress(0); // dispatch at 0
        u.ingress(0); // slot free at 0, dispatch serialized to 1_000
        let c = u.ingress(0); // slot free at 1_000: queue-full stall
        assert_eq!(c, 2_000);
        assert_eq!(u.stats.queue_stalls, 1);
        assert!(u.stats.occ_peak <= u.depth() as u64, "occupancy bounded by depth");
    }

    #[test]
    fn amu_idle_unit_accepts_immediately() {
        let mut u = AmuUnit::new(4, 5_000, 7_000, 1_000).unwrap();
        let a = u.ingress(1_000_000);
        assert_eq!(a, 1_005_000);
        assert_eq!(u.notify_lat(), 7_000);
        assert_eq!(u.stats.queue_stalls, 0);
        assert_eq!(u.stats.occ_sum, 0);
    }

    #[test]
    fn amu_rejects_zero_depth() {
        assert!(AmuUnit::new(0, 1, 1, 1).is_err());
    }

    #[test]
    fn amu_occupancy_binary_search_matches_naive_scan() {
        // Drive rings of several depths (cold, partially filled, and
        // wrapped) through a bursty arrival pattern and check the
        // O(log depth) suffix search against the O(depth) definition at
        // every step.
        for depth in [1usize, 2, 3, 7, 32] {
            let mut u = AmuUnit::new(depth, 500, 500, 300).unwrap();
            let mut t: Ps = 0;
            for i in 0..(4 * depth as u64 + 8) {
                // Bursts of same-instant arrivals with occasional gaps.
                if i % 5 == 0 {
                    t += 1 + (i % 3) * 1_000;
                }
                let naive = u.ring.iter().filter(|&&d| d > t).count() as u64;
                assert_eq!(
                    u.occupancy_at(t),
                    naive,
                    "depth {depth}, step {i}: occupancy diverged from the scan"
                );
                u.ingress(t);
            }
        }
    }

    #[test]
    fn backend_variants_match_mechanisms() {
        let data = data_stub();
        let build = |name: &str| {
            ExtBackend::build(&SystemConfig::by_name(name).unwrap(), &data).unwrap()
        };
        assert!(matches!(build("ideal"), ExtBackend::Direct));
        assert!(matches!(build("tl-ooo"), ExtBackend::Mec(_)));
        assert!(matches!(build("tl-lf"), ExtBackend::Mec(_)));
        assert!(matches!(build("numa"), ExtBackend::Numa(_)));
        assert!(matches!(build("pcie"), ExtBackend::Pcie(_)));
        assert!(matches!(build("inc-trl"), ExtBackend::IncreasedTrl));
        assert!(matches!(build("amu"), ExtBackend::Amu(_)));
        assert!(matches!(build("mims"), ExtBackend::Mims { .. }));
    }

    #[test]
    fn mims_unit_is_inert_at_pack_one() {
        let mut u = MimsUnit::new(1, 20_000, 64).unwrap();
        for t in [0u64, 1_000, 5_000] {
            assert_eq!(u.ingress(t), t, "pack=1 must add no framing delay");
        }
        assert_eq!(u.stats.requests, 3);
        assert_eq!(u.stats.messages, 3, "pack=1: one message per transaction");
    }

    #[test]
    fn mims_unit_amortizes_framing_over_the_pack() {
        let mut u = MimsUnit::new(4, 20_000, 64).unwrap();
        assert_eq!(u.ingress(1_000), 1_000 + 20_000 / 4);
        for _ in 0..7 {
            u.ingress(2_000);
        }
        assert_eq!(u.stats.requests, 8);
        assert_eq!(u.stats.messages, 2, "8 transactions at pack 4 = 2 messages");
        assert!((u.stats.pack_mean() - 4.0).abs() < 1e-12);
    }

    #[test]
    fn mims_partial_final_message_counts_as_a_message() {
        let mut u = MimsUnit::new(4, 8_000, 64).unwrap();
        for _ in 0..5 {
            u.ingress(0);
        }
        // 4 full + 1 in a partial second message.
        assert_eq!(u.stats.messages, 2);
        assert!((u.stats.pack_mean() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn mims_fine_granularity_never_delivers_more_than_requested() {
        for granule in [1u32, 8, 32, 64] {
            let mut u = MimsUnit::new(4, 8_000, granule).unwrap();
            for _ in 0..13 {
                u.ingress(0);
            }
            assert!(
                u.stats.delivered_bytes <= u.stats.requested_bytes,
                "granule {granule}: delivered {} > requested {}",
                u.stats.delivered_bytes,
                u.stats.requested_bytes
            );
            assert_eq!(u.stats.delivered_bytes, 13 * granule as u64);
            assert_eq!(u.stats.requested_bytes, 13 * 64);
        }
    }

    #[test]
    fn mims_rejects_invalid_knobs() {
        assert!(MimsUnit::new(0, 1_000, 64).is_err(), "pack 0");
        assert!(MimsUnit::new(4, 1_000, 0).is_err(), "granule 0");
        assert!(MimsUnit::new(4, 1_000, 65).is_err(), "granule > 64");
    }

    #[test]
    fn backend_build_rejects_invalid_mims_knobs() {
        let mut cfg = SystemConfig::mims();
        cfg.mims_granule = 0;
        let err = ExtBackend::build(&cfg, &data_stub());
        assert!(err.is_err(), "mims_granule = 0 must be a typed error");
        assert!(format!("{:#}", err.err().unwrap()).contains("mims_granule"));
    }

    #[test]
    fn backend_build_rejects_invalid_amu_knob() {
        let mut cfg = SystemConfig::amu();
        cfg.amu_depth = 0;
        let err = ExtBackend::build(&cfg, &data_stub());
        assert!(err.is_err(), "amu_depth = 0 must be a typed error");
        assert!(format!("{:#}", err.err().unwrap()).contains("amu_depth"));
    }

    #[test]
    fn both_routings_build_the_same_group_shape() {
        let data = data_stub();
        for name in ["ideal", "tl-ooo", "numa", "pcie", "inc-trl", "amu", "mims"] {
            let mut cfg = SystemConfig::by_name(name).unwrap();
            for routing in [Routing::Backend, Routing::Legacy] {
                cfg.routing = routing;
                let (_, group) = Router::build(&cfg, &data).unwrap();
                match name {
                    "pcie" => assert!(group.is_none(), "pcie has no extended group"),
                    _ => assert!(group.is_some(), "{name} missing its extended group"),
                }
            }
        }
    }

    #[test]
    fn routing_names_round_trip() {
        assert_eq!(Routing::by_name("backend"), Some(Routing::Backend));
        assert_eq!(Routing::by_name("legacy"), Some(Routing::Legacy));
        assert_eq!(Routing::by_name(Routing::Backend.name()), Some(Routing::Backend));
        assert!(Routing::by_name("bogus").is_none());
    }

    #[test]
    fn degraded_wrappers_stretch_only_fail_slow_windows() {
        use crate::sim::fault::{BurstState, FaultPlan};
        use crate::util::time::NS;

        let mut cfg = SystemConfig::numa();
        cfg.burst_rate = 1.0; // every window opens an episode
        cfg.burst_len = 1_000 * NS;
        cfg.burst_slow_mult = 4;
        let plan = FaultPlan::from_cfg(&cfg).unwrap();
        let data = data_stub();
        let kind = GroupKind::ExtRemote;
        let fill = 10 * NS;

        // Locate one fail-slow and one fail-stop window (the per-episode
        // kind hash splits them ~evenly; 64 windows is overwhelming).
        let mut slow_at = None;
        let mut stop_at = None;
        for w in 0..64u64 {
            let at = w * cfg.burst_len + 1;
            match plan.burst_state(kind, at) {
                BurstState::Slow(m) => {
                    assert_eq!(m, 4);
                    slow_at.get_or_insert(at);
                }
                BurstState::Stop => {
                    stop_at.get_or_insert(at);
                }
                BurstState::Good => panic!("rate 1.0 left window {w} Good"),
            }
        }
        let (slow_at, stop_at) = (slow_at.unwrap(), stop_at.unwrap());

        // Egress is stateless: Slow multiplies the return path, Stop and
        // no-plan leave it untouched (fail-stop is handled at the
        // injection sites, not by stretching).
        let (r, _) = Router::build(&cfg, &data).unwrap();
        let eg = r.egress_delay(kind);
        assert!(eg > 0, "numa egress hop expected nonzero");
        assert_eq!(r.egress_degraded(kind, slow_at, fill, None), eg);
        assert_eq!(r.egress_degraded(kind, stop_at, fill, Some(&plan)), eg);
        assert_eq!(
            r.egress_degraded(kind, slow_at, fill, Some(&plan)),
            eg + (fill + eg) * 3,
        );

        // Ingress is stateful (the QPI link serializes): compare fresh
        // routers at the same arrive instant.
        let (mut plain, _) = Router::build(&cfg, &data).unwrap();
        let (mut degraded, _) = Router::build(&cfg, &data).unwrap();
        let base = plain.ingress(kind, slow_at);
        let slow = degraded.ingress_degraded(kind, slow_at, Some(&plan));
        assert!(base > slow_at, "numa ingress adds latency");
        assert_eq!(slow - slow_at, (base - slow_at) * 4);

        // A plan without a burst layer degrades nothing.
        let mut quiet = SystemConfig::numa();
        quiet.fault_rate = 0.1;
        let inert = FaultPlan::from_cfg(&quiet).unwrap();
        let (mut a, _) = Router::build(&cfg, &data).unwrap();
        let (mut b, _) = Router::build(&cfg, &data).unwrap();
        assert_eq!(
            a.ingress_degraded(kind, stop_at, Some(&inert)),
            b.ingress(kind, stop_at),
        );
        assert_eq!(r.egress_degraded(kind, slow_at, fill, Some(&inert)), eg);
    }
}
