//! Event-driven platform simulator: cores → L1/TLB/MSHR → shared LLC →
//! memory controllers → (MEC tree | QPI | PCIe | plain DRAM).
//!
//! One [`platform::Platform`] instance is one emulated system from paper
//! Table 3 running one workload; [`run_workload`] is the one-call entry
//! point that builds, runs, and reports.

pub mod backend;
pub mod engine;
pub mod fault;
pub mod platform;
pub mod report;
pub mod sample;
pub mod shard;

pub use backend::Routing;
pub use fault::FaultPlan;
pub use engine::EngineKind;
pub use platform::Platform;
pub use report::SimReport;

use crate::config::{RunSpec, SystemConfig};
use crate::workloads::WorkloadKind;

/// Build and run one (system, workload) pair to completion.
pub fn run_workload(
    cfg: &SystemConfig,
    workload: WorkloadKind,
    ops_per_core: u64,
    seed: u64,
) -> SimReport {
    let mut spec = RunSpec::smoke(workload);
    spec.ops_per_core = ops_per_core;
    spec.seed = seed;
    run_spec(cfg, &spec)
}

/// Build and run with a full [`RunSpec`], surfacing invalid
/// configurations as typed errors (the CLI entry point).
pub fn try_run_spec(cfg: &SystemConfig, spec: &RunSpec) -> anyhow::Result<SimReport> {
    let mut p = Platform::build(cfg, spec)?;
    p.run();
    Ok(p.report())
}

/// Build and run with a full [`RunSpec`].
///
/// Infallible convenience wrapper for callers that construct their
/// configs programmatically (sweeps, benches, tests); a rejected config
/// panics here with the typed error's message. Callers handling user
/// input should prefer [`try_run_spec`].
pub fn run_spec(cfg: &SystemConfig, spec: &RunSpec) -> SimReport {
    try_run_spec(cfg, spec).unwrap_or_else(|e| panic!("{e:#}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn smoke(cfg: &SystemConfig, wl: WorkloadKind) -> SimReport {
        let mut spec = RunSpec::smoke(wl);
        spec.ops_per_core = 3_000;
        let mut cfg = cfg.clone();
        cfg.cores = 2;
        let r = run_spec(&cfg, &spec);
        assert!(!r.deadlocked, "{}/{} deadlocked", r.mechanism, r.workload);
        assert!(r.finish > 0);
        assert!(r.retired_insts > 1_000);
        r
    }

    #[test]
    fn every_mechanism_completes_gups() {
        for cfg in [
            SystemConfig::ideal(),
            SystemConfig::tl_ooo(),
            SystemConfig::tl_lf(),
            SystemConfig::tl_lf_batched(8),
            SystemConfig::numa(),
            SystemConfig::pcie(0.9),
            SystemConfig::increased_trl(35_000),
            SystemConfig::amu(),
        ] {
            let r = smoke(&cfg, WorkloadKind::Gups);
            assert!(r.ipc() > 0.0, "{}: zero IPC", r.mechanism);
        }
    }

    #[test]
    fn amu_runs_end_to_end_with_queue_stats() {
        let r = smoke(&SystemConfig::amu(), WorkloadKind::Gups);
        assert!(r.amu_requests > 100, "AMU saw no traffic: {}", r.amu_requests);
        assert!(
            r.amu_occ_peak <= SystemConfig::amu().amu_depth as u64,
            "occupancy exceeded the bounded queue: {} > {}",
            r.amu_occ_peak,
            SystemConfig::amu().amu_depth
        );
        // The async unit adds round-trip latency: slower than ideal.
        let ideal = smoke(&SystemConfig::ideal(), WorkloadKind::Gups);
        assert!(r.finish > ideal.finish, "AMU should not beat ideal");
        // Extended accesses carry the issue/poll instruction overhead.
        assert!(r.retired_insts > ideal.retired_insts);
    }

    #[test]
    fn amu_shallow_queue_backpressures() {
        let mut shallow = SystemConfig::amu();
        shallow.amu_depth = 1;
        let r = smoke(&shallow, WorkloadKind::Gups);
        assert!(r.amu_queue_stalls > 0, "depth-1 queue never stalled");
        assert!(r.amu_occ_peak <= 1);
    }

    #[test]
    fn invalid_config_is_a_typed_error_not_a_panic() {
        let mut cfg = SystemConfig::amu();
        cfg.amu_depth = 0;
        let spec = RunSpec::smoke(WorkloadKind::Gups);
        let err = Platform::build(&cfg, &spec);
        assert!(err.is_err());
        let msg = format!("{:#}", err.err().unwrap());
        assert!(msg.contains("amu_depth"), "unhelpful error: {msg}");

        let mut cfg = SystemConfig::ideal();
        cfg.cores = 0;
        let err = Platform::build(&cfg, &RunSpec::smoke(WorkloadKind::Gups));
        assert!(err.is_err());
        assert!(format!("{:#}", err.err().unwrap()).contains("cores"));
    }

    #[test]
    fn every_workload_completes_on_tl_ooo() {
        for &wl in crate::workloads::ALL_WORKLOADS {
            smoke(&SystemConfig::tl_ooo(), wl);
        }
    }

    #[test]
    fn tl_ooo_slower_than_ideal_faster_than_tl_lf() {
        let ideal = smoke(&SystemConfig::ideal(), WorkloadKind::Gups);
        let ooo = smoke(&SystemConfig::tl_ooo(), WorkloadKind::Gups);
        let lf = smoke(&SystemConfig::tl_lf(), WorkloadKind::Gups);
        let p_ooo = ooo.perf_vs(&ideal);
        let p_lf = lf.perf_vs(&ideal);
        assert!(p_ooo < 1.0, "TL-OoO not slower than ideal: {p_ooo}");
        assert!(p_lf < p_ooo, "TL-LF ({p_lf}) not slower than TL-OoO ({p_ooo})");
        assert!(p_ooo > 0.2, "TL-OoO unreasonably slow: {p_ooo}");
    }

    #[test]
    fn tl_mec_sees_twin_traffic() {
        let r = smoke(&SystemConfig::tl_ooo(), WorkloadKind::Gups);
        assert!(r.mec_first_loads > 100, "first loads: {}", r.mec_first_loads);
        assert!(
            r.mec_second_real > r.mec_first_loads / 4,
            "second loads rarely got real data: {} vs {}",
            r.mec_second_real,
            r.mec_first_loads
        );
        // Retries are the rare case.
        assert!(
            r.twin_retries < r.mec_first_loads / 4,
            "too many retries: {}",
            r.twin_retries
        );
    }

    #[test]
    fn tl_increases_instructions_and_misses() {
        let ideal = smoke(&SystemConfig::ideal(), WorkloadKind::Gups);
        let ooo = smoke(&SystemConfig::tl_ooo(), WorkloadKind::Gups);
        assert!(
            ooo.retired_insts as f64 > 1.3 * ideal.retired_insts as f64,
            "instruction expansion missing: {} vs {}",
            ooo.retired_insts,
            ideal.retired_insts
        );
        assert!(
            ooo.llc_misses as f64 > 1.3 * ideal.llc_misses as f64,
            "LLC miss increase missing: {} vs {}",
            ooo.llc_misses,
            ideal.llc_misses
        );
        assert!(
            ooo.tlb_misses > ideal.tlb_misses,
            "TLB miss increase missing"
        );
    }

    #[test]
    fn lf_serializes_concurrency() {
        let ooo = smoke(&SystemConfig::tl_ooo(), WorkloadKind::Cg);
        let lf = smoke(&SystemConfig::tl_lf(), WorkloadKind::Cg);
        assert!(
            lf.mlp_mean < ooo.mlp_mean,
            "fence did not reduce MLP: lf={} ooo={}",
            lf.mlp_mean,
            ooo.mlp_mean
        );
        assert!(lf.fences > 100);
    }

    #[test]
    fn pcie_faults_dominate_at_low_residency() {
        // Long enough that steady-state faulting (not cold misses)
        // dominates the comparison.
        let run = |frac: f64| {
            let mut cfg = SystemConfig::pcie(frac);
            cfg.cores = 2;
            let mut spec = RunSpec::smoke(WorkloadKind::Gups);
            spec.ops_per_core = 12_000;
            run_spec(&cfg, &spec)
        };
        let hi = run(0.95);
        let lo = run(0.10);
        // hi-residency faults are mostly cold (one per touched page); the
        // 10%-resident run faults on ~90 % of iterations.
        assert!(lo.pcie_faults > hi.pcie_faults * 3 / 2,
            "lo={} hi={}", lo.pcie_faults, hi.pcie_faults);
        // Both runs are fault-bound (the swap device serializes), so the
        // slowdown tracks the fault ratio.
        assert!(
            lo.finish > hi.finish * 3 / 2,
            "faults did not slow the run: lo={} hi={}",
            lo.finish,
            hi.finish
        );
    }

    #[test]
    fn numa_slower_than_ideal() {
        let ideal = smoke(&SystemConfig::ideal(), WorkloadKind::Bfs);
        let numa = smoke(&SystemConfig::numa(), WorkloadKind::Bfs);
        let p = numa.perf_vs(&ideal);
        assert!(p < 1.0 && p > 0.3, "NUMA perf {p}");
    }

    #[test]
    fn deterministic_given_seed() {
        let a = smoke(&SystemConfig::tl_ooo(), WorkloadKind::Memcached);
        let b = smoke(&SystemConfig::tl_ooo(), WorkloadKind::Memcached);
        assert_eq!(a.finish, b.finish);
        assert_eq!(a.retired_insts, b.retired_insts);
        assert_eq!(a.llc_misses, b.llc_misses);
    }
}
