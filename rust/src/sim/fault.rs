//! Deterministic extension-path fault injection (§4.4 robustness).
//!
//! The paper's liveness argument is that twin-load survives a misbehaving
//! extension path — not-ready data, reordered prefetches, lost state — via
//! content checking, software retry, and the §4.5 safe fallback path. This
//! module makes the backend misbehave *on purpose*, deterministically, so
//! those recovery paths run under test and measurement instead of staying
//! dead code.
//!
//! Design constraints:
//!
//! * **Structurally inert when disabled.** [`FaultPlan::from_cfg`] returns
//!   `None` when every fault rate is zero; all injection sites are gated on
//!   that `Option`, so a `fault_rate = 0` run takes exactly the pre-fault
//!   code path — no hash draws, no counter state, no timing deltas. The
//!   golden corpus and the chaos differential proptest enforce this.
//! * **Independent of engine / front end / scheduler / routing.** Every
//!   fault decision is a pure function of (fault seed, site salt, line
//!   identity, per-line occurrence number) via [`mix64`] — the same
//!   stateless-hash idiom the differential mocks use — so equivalent
//!   implementations observe identical fault schedules. Occurrence numbers
//!   are tracked *per line* ([`FaultCounters`]), which makes the schedule
//!   insensitive to cross-line service reordering.
//! * **Bounded recovery.** Every injected fault has a recovery path that
//!   terminates: not-ready responses fall to §4.4 retry and, past the
//!   `demote_after` streak, the §4.5 safe path; lost AMU notifies fall to a
//!   poll-timeout + bounded-reissue loop whose final attempt always
//!   delivers. The chaos proptest asserts exactly-once completion of every
//!   logical op under arbitrary fault schedules.

use crate::config::SystemConfig;
use crate::stats::Histogram;
use crate::util::rng::mix64;
use crate::util::time::{Ps, NS};
use crate::util::FastMap;

/// In-line single-bit ECC correction: a couple of nanoseconds of extra
/// controller occupancy on the faulted beat.
pub const ECC_CORRECT_PS: Ps = 2 * NS;
/// Detected (uncorrectable) multi-bit error: the controller re-reads the
/// line, a full row-cycle-class penalty.
pub const ECC_REREAD_PS: Ps = 60 * NS;

// Site salts: decorrelate the fault classes drawn from one seed.
const SALT_NOT_READY: u64 = 0x4E52_0001;
const SALT_MEC_FILL: u64 = 0x4D45_0002;
const SALT_MEC_KIND: u64 = 0x4D45_0003;
const SALT_NOTIFY: u64 = 0x414D_0004;
const SALT_PCIE: u64 = 0x5043_0005;
const SALT_ECC: u64 = 0x4543_0006;
const SALT_ECC_KIND: u64 = 0x4543_0007;

/// Outcome of a MEC prefetch-buffer fill under fault.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FillFault {
    /// Fill lands normally.
    None,
    /// Fill is dropped: the LVC never sees the value, the second twin
    /// misses again and the host retries.
    Dropped,
    /// Fill lands late by the given delta: the second twin observes
    /// not-ready data (`SecondLoadLate`).
    Late(Ps),
}

/// Outcome of the transient-bit-error model on one data beat.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EccFault {
    None,
    /// Single-bit flip: ECC corrects in-line for a small latency adder.
    Corrected,
    /// Multi-bit flip: ECC detects but cannot correct; the controller
    /// re-reads the line (a full row-turnaround class penalty).
    Detected,
}

/// Seeded, deterministic fault schedule. Cheap to copy into every
/// component that injects (platform, MEC chips).
#[derive(Debug, Clone, Copy)]
pub struct FaultPlan {
    /// Extension-path fault probability, parts per million.
    rate_ppm: u64,
    /// Transient-bit-error probability, parts per million.
    ecc_ppm: u64,
    seed: u64,
}

impl FaultPlan {
    /// Build the plan from config knobs; `None` when fault injection is
    /// fully disabled (the inertness guarantee hangs on this).
    pub fn from_cfg(cfg: &SystemConfig) -> Option<FaultPlan> {
        let rate_ppm = ppm(cfg.fault_rate);
        let ecc_ppm = ppm(cfg.fault_ecc_rate);
        if rate_ppm == 0 && ecc_ppm == 0 {
            return None;
        }
        Some(FaultPlan { rate_ppm, ecc_ppm, seed: mix64(cfg.fault_seed) })
    }

    /// One Bernoulli draw: pure in (seed, salt, line, nth).
    #[inline]
    fn roll(&self, ppm: u64, salt: u64, line: u64, nth: u64) -> bool {
        if ppm == 0 {
            return false;
        }
        let h = mix64(line ^ nth.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ self.seed ^ salt);
        h % 1_000_000 < ppm
    }

    /// Not-ready first response on an extension-path demand read: the
    /// returned data fails the §4.4 content check and forces a software
    /// retry (or, on a non-twin mechanism, a modeled re-read delay).
    #[inline]
    pub fn not_ready(&self, line: u64, nth: u64) -> bool {
        self.roll(self.rate_ppm, SALT_NOT_READY, line, nth)
    }

    /// MEC prefetch-buffer fill fault for the `nth` tree fetch of `tag`.
    /// Late fills land `late_by` after the nominal fill time.
    #[inline]
    pub fn mec_fill(&self, tag: u64, nth: u64, late_by: Ps) -> FillFault {
        if !self.roll(self.rate_ppm, SALT_MEC_FILL, tag, nth) {
            return FillFault::None;
        }
        if mix64(tag ^ nth ^ self.seed ^ SALT_MEC_KIND) & 1 == 0 {
            FillFault::Dropped
        } else {
            FillFault::Late(late_by)
        }
    }

    /// Lost AMU completion notify for the given (line, attempt) pair.
    /// Attempt 0 is the original notify; attempts ≥ 1 are reissues.
    #[inline]
    pub fn notify_lost(&self, line: u64, nth: u64, attempt: u32) -> bool {
        self.roll(
            self.rate_ppm,
            SALT_NOTIFY,
            line,
            nth.wrapping_mul(64).wrapping_add(attempt as u64),
        )
    }

    /// PCIe transfer failure on the `nth` swap of `page`.
    #[inline]
    pub fn pcie_fail(&self, page: u64, nth: u64) -> bool {
        self.roll(self.rate_ppm, SALT_PCIE, page, nth)
    }

    /// Transient bit error on a delivered beat; 1-in-8 faulted beats are
    /// multi-bit (detected, re-read), the rest correct in-line.
    #[inline]
    pub fn ecc(&self, line: u64, nth: u64) -> EccFault {
        if !self.roll(self.ecc_ppm, SALT_ECC, line, nth) {
            return EccFault::None;
        }
        if mix64(line ^ nth ^ self.seed ^ SALT_ECC_KIND) & 7 == 0 {
            EccFault::Detected
        } else {
            EccFault::Corrected
        }
    }

    /// Software recovery of a lost AMU notify: poll until `timeout`
    /// expires, reissue, and back off exponentially; the `reissue_max`-th
    /// attempt always delivers (the bound that guarantees exactly-once
    /// completion). Returns the added recovery latency and the number of
    /// reissues taken.
    pub fn amu_recovery(
        &self,
        line: u64,
        nth: u64,
        timeout: Ps,
        reissue_max: u32,
        backoff_mult: u32,
    ) -> (Ps, u32) {
        let max = reissue_max.max(1);
        let mult = backoff_mult.max(1) as u64;
        let mut window = timeout.max(1);
        let mut delay: Ps = 0;
        let mut attempt = 1u32;
        loop {
            // One poll window expires before the reissue goes out.
            delay = delay.saturating_add(window);
            if attempt >= max || !self.notify_lost(line, nth, attempt) {
                return (delay, attempt);
            }
            attempt += 1;
            window = window.saturating_mul(mult);
        }
    }
}

fn ppm(rate: f64) -> u64 {
    (rate.clamp(0.0, 1.0) * 1_000_000.0).round() as u64
}

/// Per-line occurrence counters backing the `nth` argument of every
/// [`FaultPlan`] draw. Only touched when a plan is active.
#[derive(Debug, Default)]
pub struct FaultCounters {
    map: FastMap<u64, u64>,
}

impl FaultCounters {
    /// Return the occurrence number for `line` and advance it.
    #[inline]
    pub fn next(&mut self, line: u64) -> u64 {
        let n = self.map.entry(line).or_insert(0);
        let v = *n;
        *n += 1;
        v
    }
}

/// Aggregated fault/recovery accounting for one platform run.
#[derive(Debug, Default)]
pub struct FaultStats {
    /// Faults injected across every class (platform-side sites; MEC fill
    /// faults are counted by the chips and summed at report time).
    pub injected: u64,
    /// Bit errors corrected in-line by the ECC model.
    pub ecc_corrected: u64,
    /// Added latency of each fault recovery (retry redelivery, ECC
    /// re-read, AMU reissue loop, PCIe retransfer), in ps.
    pub recovery: Histogram,
}

impl FaultStats {
    #[inline]
    pub fn record(&mut self, recovery_delay: Ps) {
        self.injected += 1;
        self.recovery.record(recovery_delay);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::time::NS;

    fn plan(rate: f64, ecc: f64, seed: u64) -> FaultPlan {
        let mut cfg = SystemConfig::tl_ooo();
        cfg.fault_rate = rate;
        cfg.fault_ecc_rate = ecc;
        cfg.fault_seed = seed;
        FaultPlan::from_cfg(&cfg).expect("nonzero rates build a plan")
    }

    #[test]
    fn zero_rates_build_no_plan() {
        let cfg = SystemConfig::tl_ooo();
        assert!(FaultPlan::from_cfg(&cfg).is_none());
    }

    #[test]
    fn draws_are_deterministic_and_seed_sensitive() {
        let a = plan(0.2, 0.1, 7);
        let b = plan(0.2, 0.1, 7);
        let c = plan(0.2, 0.1, 8);
        let mut diff = 0;
        for line in 0..512u64 {
            assert_eq!(a.not_ready(line, 0), b.not_ready(line, 0));
            assert_eq!(a.ecc(line, 3), b.ecc(line, 3));
            if a.not_ready(line, 0) != c.not_ready(line, 0) {
                diff += 1;
            }
        }
        assert!(diff > 0, "seed change did not move the schedule");
    }

    #[test]
    fn rates_are_roughly_respected() {
        let p = plan(0.25, 0.0, 42);
        let hits = (0..10_000u64).filter(|&l| p.not_ready(l * 64, 0)).count();
        assert!((1_800..3_200).contains(&hits), "25% rate gave {hits}/10000");
        // Occurrence number decorrelates retries of the same line.
        let line = 0x1234_5678u64;
        let again = (0..1_000u64).filter(|&n| p.not_ready(line, n)).count();
        assert!((100..450).contains(&again), "per-line resample gave {again}/1000");
    }

    #[test]
    fn ecc_mixes_corrected_and_detected() {
        let p = plan(0.0, 0.5, 11);
        let (mut corr, mut det) = (0, 0);
        for l in 0..4_000u64 {
            match p.ecc(l * 64, 0) {
                EccFault::Corrected => corr += 1,
                EccFault::Detected => det += 1,
                EccFault::None => {}
            }
        }
        assert!(corr > det, "corrected ({corr}) should dominate detected ({det})");
        assert!(det > 0, "multi-bit errors never drawn");
    }

    #[test]
    fn mec_fill_faults_split_dropped_and_late() {
        let p = plan(0.5, 0.0, 3);
        let (mut drop, mut late) = (0, 0);
        for t in 0..4_000u64 {
            match p.mec_fill(t * 64, 0, 100 * NS) {
                FillFault::Dropped => drop += 1,
                FillFault::Late(d) => {
                    assert_eq!(d, 100 * NS);
                    late += 1;
                }
                FillFault::None => {}
            }
        }
        assert!(drop > 500 && late > 500, "drop={drop} late={late}");
    }

    #[test]
    fn amu_recovery_terminates_and_backs_off() {
        let p = plan(1.0, 0.0, 5);
        // rate 1.0: every reissue notify is lost too — the bound must
        // still terminate, with exponentially grown windows summed.
        let (delay, attempts) = p.amu_recovery(0x40, 0, 100 * NS, 4, 2);
        assert_eq!(attempts, 4);
        assert_eq!(delay, (100 + 200 + 400 + 800) * NS);
        // Benign plan: a single poll window when the reissue succeeds.
        let q = plan(1e-9, 0.0, 5);
        let (delay, attempts) = q.amu_recovery(0x40, 0, 100 * NS, 4, 2);
        assert_eq!(attempts, 1);
        assert_eq!(delay, 100 * NS);
        // Degenerate knobs clamp instead of hanging or dividing by zero.
        let (_, attempts) = p.amu_recovery(0x40, 0, 0, 0, 0);
        assert_eq!(attempts, 1);
    }

    #[test]
    fn counters_advance_per_line() {
        let mut c = FaultCounters::default();
        assert_eq!(c.next(0x40), 0);
        assert_eq!(c.next(0x40), 1);
        assert_eq!(c.next(0x80), 0);
        assert_eq!(c.next(0x40), 2);
    }

    #[test]
    fn stats_record_and_histogram() {
        let mut s = FaultStats::default();
        s.record(10 * NS);
        s.record(500 * NS);
        s.ecc_corrected += 1;
        assert_eq!(s.injected, 2);
        assert_eq!(s.recovery.count(), 2);
        assert!(s.recovery.max() >= 500 * NS);
    }
}
