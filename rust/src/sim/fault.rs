//! Deterministic extension-path fault injection (§4.4 robustness).
//!
//! The paper's liveness argument is that twin-load survives a misbehaving
//! extension path — not-ready data, reordered prefetches, lost state — via
//! content checking, software retry, and the §4.5 safe fallback path. This
//! module makes the backend misbehave *on purpose*, deterministically, so
//! those recovery paths run under test and measurement instead of staying
//! dead code.
//!
//! Design constraints:
//!
//! * **Structurally inert when disabled.** [`FaultPlan::from_cfg`] returns
//!   `None` when every fault rate is zero; all injection sites are gated on
//!   that `Option`, so a `fault_rate = 0` run takes exactly the pre-fault
//!   code path — no hash draws, no counter state, no timing deltas. The
//!   golden corpus and the chaos differential proptest enforce this.
//! * **Independent of engine / front end / scheduler / routing.** Every
//!   fault decision is a pure function of (fault seed, site salt, line
//!   identity, per-line occurrence number) via [`mix64`] — the same
//!   stateless-hash idiom the differential mocks use — so equivalent
//!   implementations observe identical fault schedules. Occurrence numbers
//!   are tracked *per line* ([`FaultCounters`]), which makes the schedule
//!   insensitive to cross-line service reordering.
//! * **Bounded recovery.** Every injected fault has a recovery path that
//!   terminates: not-ready responses fall to §4.4 retry and, past the
//!   `demote_after` streak, the §4.5 safe path; lost AMU notifies fall to a
//!   poll-timeout + bounded-reissue loop whose final attempt always
//!   delivers. The chaos proptest asserts exactly-once completion of every
//!   logical op under arbitrary fault schedules.
//!
//! On top of the memoryless per-draw model sits the **correlated-fault
//! layer** ([`BurstPlan`]): a seeded two-state Gilbert-Elliott burst
//! process evaluated per *fault domain* (the MEC chips, the plain
//! extension channel group, the AMU/MIMS unit, the PCIe link) as a pure
//! function of (seed, domain id, virtual-time window index). A window is
//! bad when a burst *started* in one of the last few windows and its drawn
//! run length still covers it — bounded lookback keeps the query O(1) and
//! stateless, so burst schedules inherit the same engine/front-end/sched/
//! routing independence as the Bernoulli draws. Each burst episode is
//! classified (by a hash of its start window) as **fail-slow** — service
//! latency through the domain is multiplied by `burst_slow_mult` at the
//! backend ingress/egress seam — or **fail-stop** — every draw in the
//! window faults, forcing retry storms. `burst_rate = 0` builds no
//! [`BurstPlan`] at all, preserving the structural-inertness guarantee.

use crate::config::SystemConfig;
use crate::sim::backend::GroupKind;
use crate::stats::Histogram;
use crate::util::rng::mix64;
use crate::util::time::{Ps, NS};
use crate::util::FastMap;

/// In-line single-bit ECC correction: a couple of nanoseconds of extra
/// controller occupancy on the faulted beat.
pub const ECC_CORRECT_PS: Ps = 2 * NS;
/// Detected (uncorrectable) multi-bit error: the controller re-reads the
/// line, a full row-cycle-class penalty.
pub const ECC_REREAD_PS: Ps = 60 * NS;

// Site salts: decorrelate the fault classes drawn from one seed.
const SALT_NOT_READY: u64 = 0x4E52_0001;
const SALT_MEC_FILL: u64 = 0x4D45_0002;
const SALT_MEC_KIND: u64 = 0x4D45_0003;
const SALT_NOTIFY: u64 = 0x414D_0004;
const SALT_PCIE: u64 = 0x5043_0005;
const SALT_ECC: u64 = 0x4543_0006;
const SALT_ECC_KIND: u64 = 0x4543_0007;
const SALT_BURST_SEED: u64 = 0x4255_0008;
const SALT_BURST_START: u64 = 0x4255_0009;
const SALT_BURST_LEN: u64 = 0x4255_000A;
const SALT_BURST_KIND: u64 = 0x4255_000B;

/// Draw resolution: parts per billion. A `fault_rate` as low as 1e-9
/// still rounds to a nonzero plan (the old parts-per-million grid
/// silently zeroed anything below 5e-7).
const PPB: u64 = 1_000_000_000;

const PHI: u64 = 0x9E37_79B9_7F4A_7C15;

// ---------------------------------------------------------------------
// Fault domains (correlated burst layer).
// ---------------------------------------------------------------------

/// The PCIe link domain — injected at the swap site, where no channel
/// group is in play (PCIe traffic aliases local DRAM).
pub(crate) const DOM_PCIE: u64 = 0x5;

/// Fault-domain identity for a channel-group kind: the MEC chips, the
/// plain extension channel group, or the AMU/MIMS unit. Local DRAM is
/// never a fault domain.
pub(crate) fn domain_of(kind: GroupKind) -> Option<u64> {
    match kind {
        GroupKind::Local => None,
        GroupKind::ExtMec => Some(0x1),
        GroupKind::ExtRemote | GroupKind::ExtTrl => Some(0x2),
        GroupKind::ExtAmu => Some(0x3),
        GroupKind::ExtMims => Some(0x4),
    }
}

/// What the correlated layer says about a domain at one instant.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum BurstState {
    /// Domain healthy: only the memoryless per-draw model applies.
    Good,
    /// Fail-slow episode: service latency through the domain is
    /// multiplied by the carried factor.
    Slow(u64),
    /// Fail-stop episode: every draw in the window faults.
    Stop,
}

/// Longest burst run, in windows: run lengths draw uniformly from
/// `1..=MAX_RUN_WINDOWS`, which bounds the lookback of the pure
/// window-state query.
const MAX_RUN_WINDOWS: u64 = 4;

/// Seeded two-state burst process, evaluated per (domain, window) with
/// no mutable state. Built only when `burst_rate > 0`.
#[derive(Debug, Clone, Copy)]
pub(crate) struct BurstPlan {
    /// Probability a burst episode starts in any given window, ppb.
    rate_ppb: u64,
    /// Window length (virtual time per state-machine step), ps.
    len: Ps,
    /// Fail-slow service-latency multiplier.
    slow_mult: u64,
    seed: u64,
}

impl BurstPlan {
    fn from_cfg(cfg: &SystemConfig) -> Option<BurstPlan> {
        let rate_ppb = ppb(cfg.burst_rate);
        if rate_ppb == 0 {
            return None;
        }
        Some(BurstPlan {
            rate_ppb,
            len: cfg.burst_len.max(1),
            slow_mult: cfg.burst_slow_mult.max(1),
            seed: mix64(cfg.fault_seed ^ SALT_BURST_SEED),
        })
    }

    /// Does a burst episode start at window `w` of `dom`?
    #[inline]
    fn starts(&self, dom: u64, w: u64) -> bool {
        mix64(w.wrapping_mul(PHI) ^ dom ^ self.seed ^ SALT_BURST_START) % PPB < self.rate_ppb
    }

    /// Run length (in windows) of the episode starting at window `w`.
    #[inline]
    fn run_len(&self, dom: u64, w: u64) -> u64 {
        1 + mix64(w.wrapping_mul(PHI) ^ dom ^ self.seed ^ SALT_BURST_LEN) % MAX_RUN_WINDOWS
    }

    /// Start window of the episode covering `at`, if any (the most
    /// recent start wins when runs overlap).
    fn episode(&self, dom: u64, at: Ps) -> Option<u64> {
        let w = at / self.len;
        (0..MAX_RUN_WINDOWS)
            .map(|j| w.wrapping_sub(j))
            .find(|&ws| self.starts(dom, ws) && self.run_len(dom, ws) > w.wrapping_sub(ws))
    }

    /// Pure state query: good, fail-slow, or fail-stop at instant `at`.
    pub(crate) fn state(&self, dom: u64, at: Ps) -> BurstState {
        match self.episode(dom, at) {
            None => BurstState::Good,
            Some(ws) => {
                if mix64(ws ^ dom ^ self.seed ^ SALT_BURST_KIND) & 1 == 0 {
                    BurstState::Stop
                } else {
                    BurstState::Slow(self.slow_mult)
                }
            }
        }
    }
}

/// Outcome of a MEC prefetch-buffer fill under fault.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FillFault {
    /// Fill lands normally.
    None,
    /// Fill is dropped: the LVC never sees the value, the second twin
    /// misses again and the host retries.
    Dropped,
    /// Fill lands late by the given delta: the second twin observes
    /// not-ready data (`SecondLoadLate`).
    Late(Ps),
}

/// Outcome of the transient-bit-error model on one data beat.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EccFault {
    None,
    /// Single-bit flip: ECC corrects in-line for a small latency adder.
    Corrected,
    /// Multi-bit flip: ECC detects but cannot correct; the controller
    /// re-reads the line (a full row-turnaround class penalty).
    Detected,
}

/// Seeded, deterministic fault schedule. Cheap to copy into every
/// component that injects (platform, MEC chips).
#[derive(Debug, Clone, Copy)]
pub struct FaultPlan {
    /// Extension-path fault probability, parts per billion.
    rate_ppb: u64,
    /// Transient-bit-error probability, parts per billion.
    ecc_ppb: u64,
    seed: u64,
    /// Correlated burst layer; `None` when `burst_rate = 0`.
    burst: Option<BurstPlan>,
}

impl FaultPlan {
    /// Build the plan from config knobs; `None` when fault injection is
    /// fully disabled (the inertness guarantee hangs on this).
    pub fn from_cfg(cfg: &SystemConfig) -> Option<FaultPlan> {
        let rate_ppb = ppb(cfg.fault_rate);
        let ecc_ppb = ppb(cfg.fault_ecc_rate);
        let burst = BurstPlan::from_cfg(cfg);
        if rate_ppb == 0 && ecc_ppb == 0 && burst.is_none() {
            return None;
        }
        Some(FaultPlan { rate_ppb, ecc_ppb, seed: mix64(cfg.fault_seed), burst })
    }

    /// One Bernoulli draw: pure in (seed, salt, line, nth).
    #[inline]
    fn roll(&self, ppb: u64, salt: u64, line: u64, nth: u64) -> bool {
        if ppb == 0 {
            return false;
        }
        let h = mix64(line ^ nth.wrapping_mul(PHI) ^ self.seed ^ salt);
        h % PPB < ppb
    }

    /// Is the correlated layer armed? (Gates the host-side health /
    /// quarantine machinery so zero-burst runs build no tracker.)
    #[inline]
    pub(crate) fn burst_armed(&self) -> bool {
        self.burst.is_some()
    }

    /// Correlated-layer state of an explicit domain id at instant `at`.
    #[inline]
    pub(crate) fn burst_state_dom(&self, dom: u64, at: Ps) -> BurstState {
        match self.burst {
            Some(b) => b.state(dom, at),
            None => BurstState::Good,
        }
    }

    /// Correlated-layer state of a channel-group kind's domain.
    #[inline]
    pub(crate) fn burst_state(&self, kind: GroupKind, at: Ps) -> BurstState {
        match domain_of(kind) {
            Some(d) => self.burst_state_dom(d, at),
            None => BurstState::Good,
        }
    }

    /// Fail-slow multiplier for `kind`'s domain at `at`, if in one.
    #[inline]
    pub(crate) fn burst_slow(&self, kind: GroupKind, at: Ps) -> Option<u64> {
        match self.burst_state(kind, at) {
            BurstState::Slow(m) => Some(m),
            _ => None,
        }
    }

    /// Is `kind`'s domain in a fail-stop window at `at`?
    #[inline]
    pub(crate) fn burst_stop(&self, kind: GroupKind, at: Ps) -> bool {
        self.burst_state(kind, at) == BurstState::Stop
    }

    /// Not-ready first response on an extension-path demand read: the
    /// returned data fails the §4.4 content check and forces a software
    /// retry (or, on a non-twin mechanism, a modeled re-read delay).
    #[inline]
    pub fn not_ready(&self, line: u64, nth: u64) -> bool {
        self.roll(self.rate_ppb, SALT_NOT_READY, line, nth)
    }

    /// MEC prefetch-buffer fill fault for the `nth` tree fetch of `tag`.
    /// Late fills land `late_by` after the nominal fill time.
    #[inline]
    pub fn mec_fill(&self, tag: u64, nth: u64, late_by: Ps) -> FillFault {
        if !self.roll(self.rate_ppb, SALT_MEC_FILL, tag, nth) {
            return FillFault::None;
        }
        if mix64(tag ^ nth ^ self.seed ^ SALT_MEC_KIND) & 1 == 0 {
            FillFault::Dropped
        } else {
            FillFault::Late(late_by)
        }
    }

    /// Lost AMU completion notify for the given (line, attempt) pair.
    /// Attempt 0 is the original notify; attempts ≥ 1 are reissues.
    #[inline]
    pub fn notify_lost(&self, line: u64, nth: u64, attempt: u32) -> bool {
        self.roll(
            self.rate_ppb,
            SALT_NOTIFY,
            line,
            nth.wrapping_mul(64).wrapping_add(attempt as u64),
        )
    }

    /// PCIe transfer failure on the `nth` swap of `page`.
    #[inline]
    pub fn pcie_fail(&self, page: u64, nth: u64) -> bool {
        self.roll(self.rate_ppb, SALT_PCIE, page, nth)
    }

    /// Transient bit error on a delivered beat; 1-in-8 faulted beats are
    /// multi-bit (detected, re-read), the rest correct in-line.
    #[inline]
    pub fn ecc(&self, line: u64, nth: u64) -> EccFault {
        if !self.roll(self.ecc_ppb, SALT_ECC, line, nth) {
            return EccFault::None;
        }
        if mix64(line ^ nth ^ self.seed ^ SALT_ECC_KIND) & 7 == 0 {
            EccFault::Detected
        } else {
            EccFault::Corrected
        }
    }

    /// Software recovery of a lost AMU notify: poll until `timeout`
    /// expires, reissue, and back off exponentially; the `reissue_max`-th
    /// attempt always delivers (the bound that guarantees exactly-once
    /// completion). Returns the added recovery latency and the number of
    /// reissues taken.
    pub fn amu_recovery(
        &self,
        line: u64,
        nth: u64,
        timeout: Ps,
        reissue_max: u32,
        backoff_mult: u32,
    ) -> (Ps, u32) {
        let max = reissue_max.max(1);
        let mult = backoff_mult.max(1) as u64;
        let mut window = timeout.max(1);
        let mut delay: Ps = 0;
        let mut attempt = 1u32;
        loop {
            // One poll window expires before the reissue goes out.
            delay = delay.saturating_add(window);
            if attempt >= max || !self.notify_lost(line, nth, attempt) {
                return (delay, attempt);
            }
            attempt += 1;
            window = window.saturating_mul(mult);
        }
    }
}

fn ppb(rate: f64) -> u64 {
    (rate.clamp(0.0, 1.0) * PPB as f64).round() as u64
}

/// Per-line occurrence counters backing the `nth` argument of every
/// [`FaultPlan`] draw. Only touched when a plan is active.
#[derive(Debug, Default)]
pub struct FaultCounters {
    map: FastMap<u64, u64>,
}

impl FaultCounters {
    /// Return the occurrence number for `line` and advance it.
    #[inline]
    pub fn next(&mut self, line: u64) -> u64 {
        let n = self.map.entry(line).or_insert(0);
        let v = *n;
        *n += 1;
        v
    }
}

/// Aggregated fault/recovery accounting for one platform run.
#[derive(Debug, Default)]
pub struct FaultStats {
    /// Faults injected across every class (platform-side sites; MEC fill
    /// faults are counted by the chips and summed at report time).
    pub injected: u64,
    /// Bit errors corrected in-line by the ECC model.
    pub ecc_corrected: u64,
    /// Added latency of each fault recovery (retry redelivery, ECC
    /// re-read, AMU reissue loop, PCIe retransfer), in ps.
    pub recovery: Histogram,
    /// Extension-domain demand accesses observed while a plan is armed
    /// (the availability denominator).
    pub ext_accesses: u64,
    /// Of those, served degraded: an injected fault, a burst bad-state
    /// window, or a quarantine demotion to the safe path.
    pub degraded_accesses: u64,
}

impl FaultStats {
    #[inline]
    pub fn record(&mut self, recovery_delay: Ps) {
        self.injected += 1;
        self.recovery.record(recovery_delay);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::time::NS;

    fn plan(rate: f64, ecc: f64, seed: u64) -> FaultPlan {
        let mut cfg = SystemConfig::tl_ooo();
        cfg.fault_rate = rate;
        cfg.fault_ecc_rate = ecc;
        cfg.fault_seed = seed;
        FaultPlan::from_cfg(&cfg).expect("nonzero rates build a plan")
    }

    fn bplan(rate: f64, len: Ps, mult: u64, seed: u64) -> FaultPlan {
        let mut cfg = SystemConfig::tl_ooo();
        cfg.burst_rate = rate;
        cfg.burst_len = len;
        cfg.burst_slow_mult = mult;
        cfg.fault_seed = seed;
        FaultPlan::from_cfg(&cfg).expect("nonzero burst rate builds a plan")
    }

    #[test]
    fn zero_rates_build_no_plan() {
        let cfg = SystemConfig::tl_ooo();
        assert!(FaultPlan::from_cfg(&cfg).is_none());
    }

    #[test]
    fn sub_ppm_rates_build_a_plan_and_inject() {
        // Regression: the old parts-per-million grid rounded any rate
        // below 5e-7 to zero, silently disabling injection.
        let mut cfg = SystemConfig::tl_ooo();
        cfg.fault_rate = 1e-7;
        cfg.fault_seed = 42;
        let p = FaultPlan::from_cfg(&cfg).expect("1e-7 must build a plan");
        assert_eq!(p.rate_ppb, 100);
        // Injects at roughly the configured rate: ~20 expected hits over
        // 200M distinct lines (deterministic for this seed; the bounds
        // leave ~5x slack either way so they hold for any seed short of
        // astronomically unlucky).
        let hits = (0..200_000_000u64).filter(|&l| p.not_ready(l * 64, 0)).count();
        assert!(
            (2..=100).contains(&hits),
            "1e-7 rate gave {hits}/200M draws (expected ~20)"
        );
    }

    #[test]
    fn zero_burst_rate_builds_no_burst_layer() {
        let p = plan(0.1, 0.0, 7);
        assert!(!p.burst_armed());
        assert_eq!(p.burst_state_dom(DOM_PCIE, 123 * NS), BurstState::Good);
        assert_eq!(p.burst_state(GroupKind::ExtMec, 0), BurstState::Good);
    }

    #[test]
    fn burst_rate_alone_builds_a_plan() {
        let p = bplan(0.5, 1000 * NS, 8, 9);
        assert!(p.burst_armed());
        assert_eq!(p.rate_ppb, 0, "burst arming must not enable per-draw faults");
        assert!(!p.not_ready(0x40, 0));
    }

    #[test]
    fn burst_windows_are_deterministic_and_domain_split() {
        let a = bplan(0.3, 1000 * NS, 4, 11);
        let b = bplan(0.3, 1000 * NS, 4, 11);
        let c = bplan(0.3, 1000 * NS, 4, 12);
        let dom_a = domain_of(GroupKind::ExtMec).unwrap();
        let dom_b = domain_of(GroupKind::ExtAmu).unwrap();
        let (mut bad, mut seed_diff, mut dom_diff) = (0u32, 0u32, 0u32);
        for w in 0..512u64 {
            let at = w * 1000 * NS + 5;
            let s = a.burst_state_dom(dom_a, at);
            assert_eq!(s, b.burst_state_dom(dom_a, at));
            if s != BurstState::Good {
                bad += 1;
            }
            if s != c.burst_state_dom(dom_a, at) {
                seed_diff += 1;
            }
            if s != a.burst_state_dom(dom_b, at) {
                dom_diff += 1;
            }
        }
        assert!(bad > 100, "30% start rate left only {bad}/512 bad windows");
        assert!(bad < 500, "almost every window bad: {bad}/512");
        assert!(seed_diff > 0, "seed change did not move the burst schedule");
        assert!(dom_diff > 0, "domains share one burst schedule");
    }

    #[test]
    fn burst_episodes_run_for_their_drawn_length() {
        let p = bplan(0.05, 1000 * NS, 4, 3);
        let b = p.burst.unwrap();
        let dom = domain_of(GroupKind::ExtMec).unwrap();
        let mut checked = 0;
        for w in MAX_RUN_WINDOWS..2048u64 {
            if !b.starts(dom, w) {
                continue;
            }
            let run = b.run_len(dom, w);
            assert!((1..=MAX_RUN_WINDOWS).contains(&run));
            // Every window the run covers reports a bad state.
            for j in 0..run {
                let at = (w + j) * 1000 * NS;
                assert_ne!(
                    b.state(dom, at),
                    BurstState::Good,
                    "window {w}+{j} inside a run of {run} reads Good"
                );
            }
            checked += 1;
        }
        assert!(checked > 10, "too few episodes to check: {checked}");
    }

    #[test]
    fn burst_states_split_slow_and_stop() {
        let p = bplan(0.25, 1000 * NS, 6, 21);
        let dom = domain_of(GroupKind::ExtMims).unwrap();
        let (mut slow, mut stop) = (0, 0);
        for w in 0..2048u64 {
            match p.burst_state_dom(dom, w * 1000 * NS) {
                BurstState::Slow(m) => {
                    assert_eq!(m, 6);
                    slow += 1;
                }
                BurstState::Stop => stop += 1,
                BurstState::Good => {}
            }
        }
        assert!(slow > 50 && stop > 50, "slow={slow} stop={stop}");
    }

    #[test]
    fn draws_are_deterministic_and_seed_sensitive() {
        let a = plan(0.2, 0.1, 7);
        let b = plan(0.2, 0.1, 7);
        let c = plan(0.2, 0.1, 8);
        let mut diff = 0;
        for line in 0..512u64 {
            assert_eq!(a.not_ready(line, 0), b.not_ready(line, 0));
            assert_eq!(a.ecc(line, 3), b.ecc(line, 3));
            if a.not_ready(line, 0) != c.not_ready(line, 0) {
                diff += 1;
            }
        }
        assert!(diff > 0, "seed change did not move the schedule");
    }

    #[test]
    fn rates_are_roughly_respected() {
        let p = plan(0.25, 0.0, 42);
        let hits = (0..10_000u64).filter(|&l| p.not_ready(l * 64, 0)).count();
        assert!((1_800..3_200).contains(&hits), "25% rate gave {hits}/10000");
        // Occurrence number decorrelates retries of the same line.
        let line = 0x1234_5678u64;
        let again = (0..1_000u64).filter(|&n| p.not_ready(line, n)).count();
        assert!((100..450).contains(&again), "per-line resample gave {again}/1000");
    }

    #[test]
    fn ecc_mixes_corrected_and_detected() {
        let p = plan(0.0, 0.5, 11);
        let (mut corr, mut det) = (0, 0);
        for l in 0..4_000u64 {
            match p.ecc(l * 64, 0) {
                EccFault::Corrected => corr += 1,
                EccFault::Detected => det += 1,
                EccFault::None => {}
            }
        }
        assert!(corr > det, "corrected ({corr}) should dominate detected ({det})");
        assert!(det > 0, "multi-bit errors never drawn");
    }

    #[test]
    fn mec_fill_faults_split_dropped_and_late() {
        let p = plan(0.5, 0.0, 3);
        let (mut drop, mut late) = (0, 0);
        for t in 0..4_000u64 {
            match p.mec_fill(t * 64, 0, 100 * NS) {
                FillFault::Dropped => drop += 1,
                FillFault::Late(d) => {
                    assert_eq!(d, 100 * NS);
                    late += 1;
                }
                FillFault::None => {}
            }
        }
        assert!(drop > 500 && late > 500, "drop={drop} late={late}");
    }

    #[test]
    fn amu_recovery_terminates_and_backs_off() {
        let p = plan(1.0, 0.0, 5);
        // rate 1.0: every reissue notify is lost too — the bound must
        // still terminate, with exponentially grown windows summed.
        let (delay, attempts) = p.amu_recovery(0x40, 0, 100 * NS, 4, 2);
        assert_eq!(attempts, 4);
        assert_eq!(delay, (100 + 200 + 400 + 800) * NS);
        // Benign plan: a single poll window when the reissue succeeds.
        let q = plan(1e-9, 0.0, 5);
        let (delay, attempts) = q.amu_recovery(0x40, 0, 100 * NS, 4, 2);
        assert_eq!(attempts, 1);
        assert_eq!(delay, 100 * NS);
        // Degenerate knobs clamp instead of hanging or dividing by zero.
        let (_, attempts) = p.amu_recovery(0x40, 0, 0, 0, 0);
        assert_eq!(attempts, 1);
    }

    #[test]
    fn counters_advance_per_line() {
        let mut c = FaultCounters::default();
        assert_eq!(c.next(0x40), 0);
        assert_eq!(c.next(0x40), 1);
        assert_eq!(c.next(0x80), 0);
        assert_eq!(c.next(0x40), 2);
    }

    #[test]
    fn stats_record_and_histogram() {
        let mut s = FaultStats::default();
        s.record(10 * NS);
        s.record(500 * NS);
        s.ecc_corrected += 1;
        assert_eq!(s.injected, 2);
        assert_eq!(s.recovery.count(), 2);
        assert!(s.recovery.max() >= 500 * NS);
    }
}
