//! Deterministic event queue for the platform simulator.

use crate::cache::DataKind;
use crate::util::time::Ps;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Event payloads.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Ev {
    /// Re-advance a core.
    CoreWake { core: usize },
    /// Pump a channel group's controllers.
    Pump { group: usize },
    /// A memory line arrived for a core (fills caches, wakes waiters).
    Deliver { core: usize, line: u64, data: DataKind },
}

/// A timestamped event; `seq` breaks ties deterministically in insertion
/// order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Event {
    pub t: Ps,
    pub seq: u64,
    pub ev: Ev,
}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reversed for min-heap behaviour inside BinaryHeap.
        other.t.cmp(&self.t).then(other.seq.cmp(&self.seq))
    }
}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Min-heap event queue with deterministic tie-breaking.
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Event>,
    next_seq: u64,
    pub pushed: u64,
}

impl EventQueue {
    pub fn new() -> EventQueue {
        EventQueue { heap: BinaryHeap::with_capacity(1024), next_seq: 0, pushed: 0 }
    }

    pub fn push(&mut self, t: Ps, ev: Ev) {
        self.heap.push(Event { t, seq: self.next_seq, ev });
        self.next_seq += 1;
        self.pushed += 1;
    }

    pub fn pop(&mut self) -> Option<Event> {
        self.heap.pop()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(30, Ev::CoreWake { core: 0 });
        q.push(10, Ev::CoreWake { core: 1 });
        q.push(20, Ev::CoreWake { core: 2 });
        let order: Vec<Ps> = std::iter::from_fn(|| q.pop().map(|e| e.t)).collect();
        assert_eq!(order, vec![10, 20, 30]);
    }

    #[test]
    fn ties_break_by_insertion() {
        let mut q = EventQueue::new();
        q.push(5, Ev::CoreWake { core: 0 });
        q.push(5, Ev::CoreWake { core: 1 });
        let a = q.pop().unwrap();
        let b = q.pop().unwrap();
        assert_eq!(a.ev, Ev::CoreWake { core: 0 });
        assert_eq!(b.ev, Ev::CoreWake { core: 1 });
    }

    #[test]
    fn empty_and_len() {
        let mut q = EventQueue::new();
        assert!(q.is_empty());
        q.push(1, Ev::Pump { group: 0 });
        assert_eq!(q.len(), 1);
        q.pop();
        assert!(q.is_empty());
    }
}
