//! Deterministic event queues for the platform simulator.
//!
//! Three interchangeable engines sit behind [`EventQueue`]:
//!
//! * [`EngineKind::Calendar`] (default) — a calendar/bucket queue: an
//!   array of time-bucketed FIFO lanes whose width comes from the host
//!   command-clock tick, a far-future overflow heap for refresh-scale
//!   gaps, and occupancy-watermark resizing. Push and pop are O(1) at
//!   the short-horizon, high-density event distributions a DRAM-timing
//!   simulator produces.
//! * [`EngineKind::AdaptiveCalendar`] — the calendar queue with the
//!   classic adaptive-width refinement: a watermark trip opens a
//!   sampling window over the next [`SAMPLE_POPS`] dequeues, and the
//!   observed inter-dequeue spacing re-derives the bucket width (with
//!   hysteresis), so workloads whose event density drifts over a run
//!   keep ~O(1) behaviour instead of degrading toward the overflow
//!   heap. The chosen width and resample count surface through
//!   [`EngineStats`] / `SimReport`.
//! * [`EngineKind::ReferenceHeap`] — the original `BinaryHeap` engine,
//!   retained as the oracle for differential testing (the same pattern
//!   as the controller's `SchedPolicy::ReferenceScan`).
//!
//! All engines pop in strictly identical order: ascending `(t, seq)`,
//! where `seq` is the global insertion counter — the `engine-equivalence`
//! proptest proves bit-identical streams.

use crate::cache::DataKind;
use crate::util::time::{Ps, CYCLE_800MHZ};
use std::cmp::Ordering;
use std::collections::{BinaryHeap, VecDeque};

/// Event payloads.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Ev {
    /// Re-advance a core.
    CoreWake { core: usize },
    /// Pump a channel group's controllers.
    Pump { group: usize },
    /// A memory line arrived for a core (fills caches, wakes waiters).
    Deliver { core: usize, line: u64, data: DataKind },
}

/// A timestamped event; `seq` breaks ties deterministically in insertion
/// order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Event {
    pub t: Ps,
    pub seq: u64,
    pub ev: Ev,
}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reversed for min-heap behaviour inside BinaryHeap.
        other.t.cmp(&self.t).then(other.seq.cmp(&self.seq))
    }
}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Which event-queue implementation a platform runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineKind {
    /// Time-bucketed calendar queue at a fixed bucket width (the default).
    Calendar,
    /// Calendar queue that resamples its bucket width from observed
    /// inter-dequeue spacing after each watermark trip.
    AdaptiveCalendar,
    /// The original binary-heap engine, retained as the differential
    /// oracle. Identical pop order.
    ReferenceHeap,
    /// Calendar queue plus conservative-parallel controller pumping:
    /// the platform partitions its channel groups into worker shards
    /// (`sim/shard.rs`) that pump concurrently inside the lookahead
    /// window bounded by the minimum cross-shard latency, then applies
    /// their results serially in deterministic group order. Pop order
    /// and every `SimReport` are bit-identical to `Calendar` by
    /// construction (the `sharded-equivalence` proptest proves it).
    Sharded,
}

impl EngineKind {
    pub fn name(&self) -> &'static str {
        match self {
            EngineKind::Calendar => "calendar",
            EngineKind::AdaptiveCalendar => "adaptive-calendar",
            EngineKind::ReferenceHeap => "reference-heap",
            EngineKind::Sharded => "sharded",
        }
    }

    pub fn by_name(name: &str) -> Option<EngineKind> {
        match name {
            "calendar" => Some(EngineKind::Calendar),
            "adaptive-calendar" | "adaptive" => Some(EngineKind::AdaptiveCalendar),
            "reference-heap" | "ref-heap" | "heap" => Some(EngineKind::ReferenceHeap),
            "sharded" => Some(EngineKind::Sharded),
            _ => None,
        }
    }
}

/// Occupancy / housekeeping counters for one queue's lifetime.
#[derive(Debug, Clone, Copy)]
pub struct EngineStats {
    pub kind: EngineKind,
    /// Total events ever pushed.
    pub pushed: u64,
    /// Peak simultaneous occupancy.
    pub peak_len: u64,
    /// Watermark-triggered bucket-array resizes (calendar only).
    pub resizes: u64,
    /// Events routed through the far-future overflow heap (calendar only).
    pub overflow_pushes: u64,
    /// Final bucket count (calendar only; 0 for the heap).
    pub buckets: u64,
    /// Current bucket width in ps (calendar only; 0 for the heap).
    pub width: Ps,
    /// Completed adaptive width re-bucketings (adaptive calendar only).
    pub resamples: u64,
}

/// Initial bucket count (power of two).
const INIT_BUCKETS: usize = 256;
/// Resize floor.
const MIN_BUCKETS: usize = 64;
/// Dequeues sampled per adaptive resample window.
pub const SAMPLE_POPS: usize = 32;
/// Hysteresis factor: re-bucket only when the resampled width leaves
/// the `[width / 2, width * 2)` band, preventing oscillation.
const WIDTH_HYSTERESIS: Ps = 2;
/// Widest bucket the resampler will pick (1 µs): beyond that, gaps are
/// refresh-scale and the overflow heap already absorbs them.
const MAX_WIDTH: Ps = 1_000_000;

/// Calendar-queue state. A "day" is `t / width`; each day maps to bucket
/// `day & mask`. Buckets hold events of several wheel rotations at once,
/// each kept sorted by `(t, seq)`, so the current day's events are always
/// a prefix of their bucket.
#[derive(Debug)]
struct Calendar {
    /// Bucket span in ps (≥ 1; seeded from the host command-clock tick,
    /// resampled from observed spacing when `adaptive`).
    width: Ps,
    buckets: Vec<VecDeque<Event>>,
    /// `buckets.len() - 1`; bucket count is a power of two.
    mask: u64,
    /// Drain position: no stored event has a day below this.
    cursor: u64,
    /// Events currently in buckets (excludes the overflow heap).
    in_buckets: usize,
    /// Events at least one full wheel beyond the cursor at push time.
    overflow: BinaryHeap<Event>,
    resizes: u64,
    overflow_pushes: u64,
    /// Adaptive width resampling: a watermark trip opens a sampling
    /// window; the next `SAMPLE_POPS` dequeue timestamps derive the new
    /// width.
    adaptive: bool,
    sampling: bool,
    sample: Vec<Ps>,
    resamples: u64,
}

impl Calendar {
    fn new(width: Ps, adaptive: bool) -> Calendar {
        Calendar {
            width: width.max(1),
            buckets: (0..INIT_BUCKETS).map(|_| VecDeque::new()).collect(),
            mask: INIT_BUCKETS as u64 - 1,
            cursor: 0,
            in_buckets: 0,
            overflow: BinaryHeap::new(),
            resizes: 0,
            overflow_pushes: 0,
            adaptive,
            sampling: false,
            sample: Vec::new(),
            resamples: 0,
        }
    }

    #[inline]
    fn day_of(&self, t: Ps) -> u64 {
        t / self.width
    }

    #[inline]
    fn horizon(&self) -> u64 {
        self.cursor + self.buckets.len() as u64
    }

    fn push(&mut self, e: Event) {
        let day = self.day_of(e.t);
        if day < self.cursor {
            // An event behind the drain point (the platform never does
            // this, but pop order must stay globally `(t, seq)` for the
            // differential oracle): move the cursor back. Bucket slots
            // are a pure function of the day, so stored events keep
            // their positions.
            self.cursor = day;
        }
        if day >= self.horizon() {
            self.overflow.push(e);
            self.overflow_pushes += 1;
            return;
        }
        self.insert_bucket(e, day);
        self.in_buckets += 1;
        if self.in_buckets > 2 * self.buckets.len() {
            self.resize_to(self.buckets.len() * 2);
        }
    }

    /// Sorted insert by `(t, seq)`; the common case appends at the back.
    fn insert_bucket(&mut self, e: Event, day: u64) {
        let q = &mut self.buckets[(day & self.mask) as usize];
        let mut i = q.len();
        while i > 0 {
            let prev = &q[i - 1];
            if (prev.t, prev.seq) <= (e.t, e.seq) {
                break;
            }
            i -= 1;
        }
        q.insert(i, e);
    }

    /// Pull far-future events whose day is now within the wheel horizon
    /// out of the overflow heap and into their buckets.
    fn migrate_overflow(&mut self) {
        loop {
            let within = match self.overflow.peek() {
                Some(top) => self.day_of(top.t) < self.horizon(),
                None => false,
            };
            if !within {
                break;
            }
            let e = self.overflow.pop().expect("peeked");
            let day = self.day_of(e.t);
            self.insert_bucket(e, day);
            self.in_buckets += 1;
            if self.in_buckets > 2 * self.buckets.len() {
                self.resize_to(self.buckets.len() * 2);
            }
        }
    }

    fn pop(&mut self) -> Option<Event> {
        let e = self.pop_min()?;
        if self.sampling {
            self.sample.push(e.t);
            if self.sample.len() >= SAMPLE_POPS {
                self.finish_resample();
            }
        }
        Some(e)
    }

    fn pop_min(&mut self) -> Option<Event> {
        if self.in_buckets == 0 && self.overflow.is_empty() {
            return None;
        }
        loop {
            self.migrate_overflow();
            // Scan one wheel rotation from the cursor. A bucket's front
            // is its minimum, so a front on the scanned day is the global
            // minimum: earlier days were just checked empty, same-bucket
            // events of later rotations sort behind it, and overflow
            // events all lie at or beyond the horizon.
            let nb = self.buckets.len() as u64;
            for k in 0..nb {
                let day = self.cursor + k;
                let b = (day & self.mask) as usize;
                if let Some(front) = self.buckets[b].front() {
                    if self.day_of(front.t) == day {
                        self.cursor = day;
                        let e = self.buckets[b].pop_front();
                        self.in_buckets -= 1;
                        if self.buckets.len() > MIN_BUCKETS
                            && self.in_buckets * 8 < self.buckets.len()
                        {
                            self.resize_to(self.buckets.len() / 2);
                        }
                        return e;
                    }
                }
            }
            // Nothing within one rotation: jump the cursor across the gap
            // to the earliest remaining event (refresh-scale idle periods).
            let bucket_min = self
                .buckets
                .iter()
                .filter_map(|q| q.front())
                .min_by_key(|e| (e.t, e.seq))
                .map(|e| self.day_of(e.t));
            let over_min = self.overflow.peek().map(|e| self.day_of(e.t));
            self.cursor = match (bucket_min, over_min) {
                (Some(a), Some(b)) => a.min(b),
                (Some(a), None) => a,
                (None, Some(b)) => b,
                (None, None) => return None,
            };
        }
    }

    /// Rebuild the wheel at `new_nb` buckets (clamped to the floor and
    /// rounded to a power of two). The adaptive engine additionally opens
    /// a width-sampling window on every trip: the resize is the signal
    /// that event density moved.
    fn resize_to(&mut self, new_nb: usize) {
        let new_nb = new_nb.max(MIN_BUCKETS).next_power_of_two();
        if new_nb == self.buckets.len() {
            return;
        }
        self.resizes += 1;
        // Keep the drain point: cursor is in day units, width unchanged.
        let floor_t = self.cursor.saturating_mul(self.width);
        self.rebuild(new_nb, self.width, floor_t);
        if self.adaptive && !self.sampling {
            self.sampling = true;
            self.sample.clear();
        }
    }

    /// Close an adaptive sampling window: derive the bucket width from
    /// the observed mean inter-dequeue gap (targeting ~2 dequeues per
    /// bucket-day) and re-bucket when it moved past the hysteresis band.
    fn finish_resample(&mut self) {
        self.sampling = false;
        let first = self.sample[0];
        let last = *self.sample.last().expect("non-empty sample window");
        self.sample.clear();
        let mean_gap = last.saturating_sub(first) / (SAMPLE_POPS as Ps - 1);
        let new_width = (2 * mean_gap).clamp(1, MAX_WIDTH);
        if new_width.saturating_mul(WIDTH_HYSTERESIS) < self.width
            || new_width >= self.width.saturating_mul(WIDTH_HYSTERESIS)
        {
            // `last` was just popped, so every pending event has
            // `(t, seq)` beyond it: it is an exact cursor floor under
            // the new width.
            self.rebuild(self.buckets.len(), new_width, last);
            self.resamples += 1;
        }
    }

    /// Redistribute every stored event (buckets *and* overflow heap —
    /// a width change moves the horizon in both directions) over
    /// `new_nb` buckets of `new_width`. Events are reinserted in global
    /// `(t, seq)` order, which keeps each bucket individually sorted,
    /// so pop order is bit-identical across rebuilds. `floor_t` is a
    /// timestamp at or before every pending event; it re-anchors the
    /// cursor when the wheel is empty.
    fn rebuild(&mut self, new_nb: usize, new_width: Ps, floor_t: Ps) {
        let mut wheel: Vec<Event> = Vec::with_capacity(self.in_buckets);
        for q in self.buckets.iter_mut() {
            wheel.extend(q.drain(..));
        }
        let mut ovf = std::mem::take(&mut self.overflow).into_vec();
        wheel.sort_unstable_by_key(|e| (e.t, e.seq));
        ovf.sort_unstable_by_key(|e| (e.t, e.seq));
        self.width = new_width.max(1);
        if new_nb != self.buckets.len() {
            self.buckets = (0..new_nb).map(|_| VecDeque::new()).collect();
            self.mask = new_nb as u64 - 1;
        }
        self.in_buckets = 0;
        let first_t = match (wheel.first(), ovf.first()) {
            (Some(a), Some(b)) => a.t.min(b.t),
            (Some(a), None) => a.t,
            (None, Some(b)) => b.t,
            (None, None) => floor_t,
        };
        self.cursor = first_t / self.width;
        let horizon = self.horizon();
        // Merge the two sorted runs so buckets fill in global `(t, seq)`
        // order. Spills that originate in the wheel count as overflow
        // routing; returning overflow events do not recount.
        let (mut i, mut j) = (0, 0);
        while i < wheel.len() || j < ovf.len() {
            let take_wheel = match (wheel.get(i), ovf.get(j)) {
                (Some(a), Some(b)) => (a.t, a.seq) <= (b.t, b.seq),
                (Some(_), None) => true,
                (None, _) => false,
            };
            let (e, from_wheel) = if take_wheel {
                i += 1;
                (wheel[i - 1], true)
            } else {
                j += 1;
                (ovf[j - 1], false)
            };
            let day = self.day_of(e.t);
            if day >= horizon {
                self.overflow.push(e);
                if from_wheel {
                    self.overflow_pushes += 1;
                }
            } else {
                self.buckets[(day & self.mask) as usize].push_back(e);
                self.in_buckets += 1;
            }
        }
    }
}

#[derive(Debug)]
enum Imp {
    Heap(BinaryHeap<Event>),
    Calendar(Calendar),
}

/// Min-queue of events with deterministic tie-breaking, over a selectable
/// engine. Pops ascending `(t, seq)` regardless of the engine.
#[derive(Debug)]
pub struct EventQueue {
    imp: Imp,
    /// The kind requested at construction. Stored rather than derived
    /// from `imp` because `Sharded` shares the fixed calendar storage:
    /// the sharding lives in how the platform *pumps*, not in pop order.
    kind: EngineKind,
    next_seq: u64,
    len: usize,
    peak_len: usize,
    pub pushed: u64,
}

impl Default for EventQueue {
    fn default() -> Self {
        EventQueue::new()
    }
}

impl EventQueue {
    /// Calendar engine at the default DDR3-1600 command-clock tick.
    pub fn new() -> EventQueue {
        EventQueue::with_kind(EngineKind::Calendar, CYCLE_800MHZ)
    }

    /// Build the selected engine; `tick` is the (initial) calendar
    /// bucket width in ps (the host `TimingParams::t_ck`; ignored by the
    /// heap, refined at runtime by the adaptive calendar).
    pub fn with_kind(kind: EngineKind, tick: Ps) -> EventQueue {
        let imp = match kind {
            // Sharded reuses the fixed calendar storage: parallelism
            // happens in the platform's pump phase, not in the queue.
            EngineKind::Calendar | EngineKind::Sharded => {
                Imp::Calendar(Calendar::new(tick, false))
            }
            EngineKind::AdaptiveCalendar => Imp::Calendar(Calendar::new(tick, true)),
            EngineKind::ReferenceHeap => Imp::Heap(BinaryHeap::with_capacity(1024)),
        };
        EventQueue { imp, kind, next_seq: 0, len: 0, peak_len: 0, pushed: 0 }
    }

    pub fn kind(&self) -> EngineKind {
        self.kind
    }

    pub fn push(&mut self, t: Ps, ev: Ev) {
        let e = Event { t, seq: self.next_seq, ev };
        self.next_seq += 1;
        self.pushed += 1;
        self.len += 1;
        if self.len > self.peak_len {
            self.peak_len = self.len;
        }
        match &mut self.imp {
            Imp::Heap(h) => h.push(e),
            Imp::Calendar(c) => c.push(e),
        }
    }

    pub fn pop(&mut self) -> Option<Event> {
        let e = match &mut self.imp {
            Imp::Heap(h) => h.pop(),
            Imp::Calendar(c) => c.pop(),
        };
        if e.is_some() {
            self.len -= 1;
        }
        e
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn stats(&self) -> EngineStats {
        let (resizes, overflow_pushes, buckets, width, resamples) = match &self.imp {
            Imp::Heap(_) => (0, 0, 0, 0, 0),
            Imp::Calendar(c) => {
                (c.resizes, c.overflow_pushes, c.buckets.len() as u64, c.width, c.resamples)
            }
        };
        EngineStats {
            kind: self.kind(),
            pushed: self.pushed,
            peak_len: self.peak_len as u64,
            resizes,
            overflow_pushes,
            buckets,
            width,
            resamples,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn both() -> [EventQueue; 4] {
        [
            EventQueue::with_kind(EngineKind::Calendar, CYCLE_800MHZ),
            EventQueue::with_kind(EngineKind::AdaptiveCalendar, CYCLE_800MHZ),
            EventQueue::with_kind(EngineKind::ReferenceHeap, 0),
            EventQueue::with_kind(EngineKind::Sharded, CYCLE_800MHZ),
        ]
    }

    #[test]
    fn pops_in_time_order() {
        for mut q in both() {
            q.push(30, Ev::CoreWake { core: 0 });
            q.push(10, Ev::CoreWake { core: 1 });
            q.push(20, Ev::CoreWake { core: 2 });
            let order: Vec<Ps> = std::iter::from_fn(|| q.pop().map(|e| e.t)).collect();
            assert_eq!(order, vec![10, 20, 30], "{:?}", q.kind());
        }
    }

    #[test]
    fn ties_break_by_insertion() {
        for mut q in both() {
            q.push(5, Ev::CoreWake { core: 0 });
            q.push(5, Ev::CoreWake { core: 1 });
            let a = q.pop().unwrap();
            let b = q.pop().unwrap();
            assert_eq!(a.ev, Ev::CoreWake { core: 0 }, "{:?}", q.kind());
            assert_eq!(b.ev, Ev::CoreWake { core: 1 }, "{:?}", q.kind());
        }
    }

    #[test]
    fn empty_and_len() {
        for mut q in both() {
            assert!(q.is_empty());
            q.push(1, Ev::Pump { group: 0 });
            assert_eq!(q.len(), 1);
            q.pop();
            assert!(q.is_empty(), "{:?}", q.kind());
        }
    }

    #[test]
    fn far_future_events_overflow_and_return() {
        let mut q = EventQueue::with_kind(EngineKind::Calendar, CYCLE_800MHZ);
        // One wheel is INIT_BUCKETS * 1250 ps = 320 ns; a refresh-scale
        // 7.8 us event must take the overflow path and still pop last.
        q.push(7_800_000, Ev::Pump { group: 1 });
        q.push(100, Ev::CoreWake { core: 0 });
        q.push(200_000, Ev::CoreWake { core: 1 });
        assert!(q.stats().overflow_pushes >= 1);
        let order: Vec<Ps> = std::iter::from_fn(|| q.pop().map(|e| e.t)).collect();
        assert_eq!(order, vec![100, 200_000, 7_800_000]);
    }

    #[test]
    fn occupancy_watermark_grows_and_shrinks_buckets() {
        let mut q = EventQueue::with_kind(EngineKind::Calendar, 1_000);
        let n = 4 * INIT_BUCKETS as u64;
        for i in 0..n {
            // Dense same-window cluster: forces the high watermark.
            q.push(i % 50_000, Ev::CoreWake { core: i as usize });
        }
        let grown = q.stats();
        assert!(grown.buckets > INIT_BUCKETS as u64, "no growth: {grown:?}");
        assert!(grown.resizes >= 1);
        let mut last = 0;
        let mut popped = 0u64;
        while let Some(e) = q.pop() {
            assert!(e.t >= last, "order violated: {} after {last}", e.t);
            last = e.t;
            popped += 1;
        }
        assert_eq!(popped, n);
        let drained = q.stats();
        assert_eq!(drained.buckets, MIN_BUCKETS as u64, "no shrink: {drained:?}");
        assert_eq!(drained.peak_len, n);
        assert_eq!(drained.pushed, n);
    }

    #[test]
    fn past_push_after_pop_still_orders() {
        // The heap oracle accepts pushes behind the last pop; the
        // calendar must regress its cursor and agree.
        for mut q in both() {
            q.push(10_000_000, Ev::CoreWake { core: 0 });
            let first = q.pop().unwrap();
            assert_eq!(first.t, 10_000_000);
            q.push(5_000, Ev::CoreWake { core: 1 });
            q.push(20_000_000, Ev::CoreWake { core: 2 });
            let order: Vec<Ps> = std::iter::from_fn(|| q.pop().map(|e| e.t)).collect();
            assert_eq!(order, vec![5_000, 20_000_000], "{:?}", q.kind());
        }
    }

    #[test]
    fn engine_kind_names_round_trip() {
        for kind in [
            EngineKind::Calendar,
            EngineKind::AdaptiveCalendar,
            EngineKind::ReferenceHeap,
            EngineKind::Sharded,
        ] {
            assert_eq!(EngineKind::by_name(kind.name()), Some(kind));
        }
        assert_eq!(EngineKind::by_name("ref-heap"), Some(EngineKind::ReferenceHeap));
        assert_eq!(EngineKind::by_name("adaptive"), Some(EngineKind::AdaptiveCalendar));
        assert!(EngineKind::by_name("bogus").is_none());
    }

    #[test]
    fn sharded_queue_reports_its_kind_and_shares_calendar_storage() {
        // `Sharded` differs from `Calendar` only in how the platform
        // pumps; the queue itself must behave exactly like the fixed
        // calendar while still reporting its requested kind.
        let mut q = EventQueue::with_kind(EngineKind::Sharded, CYCLE_800MHZ);
        assert_eq!(q.kind(), EngineKind::Sharded);
        assert_eq!(q.stats().kind, EngineKind::Sharded);
        assert_eq!(q.stats().width, CYCLE_800MHZ);
        q.push(7_800_000, Ev::Pump { group: 1 });
        q.push(100, Ev::CoreWake { core: 0 });
        assert!(q.stats().overflow_pushes >= 1, "calendar overflow path not shared");
        assert_eq!(q.stats().resamples, 0, "sharded must use the fixed-width calendar");
        let order: Vec<Ps> = std::iter::from_fn(|| q.pop().map(|e| e.t)).collect();
        assert_eq!(order, vec![100, 7_800_000]);
    }

    #[test]
    fn adaptive_narrows_width_on_dense_streams() {
        // A dense burst (events ~1 ps apart, far tighter than the DDR
        // tick) trips the grow watermark; the sampling window over the
        // next SAMPLE_POPS dequeues must narrow the bucket width.
        let mut q = EventQueue::with_kind(EngineKind::AdaptiveCalendar, CYCLE_800MHZ);
        assert_eq!(q.stats().width, CYCLE_800MHZ);
        let n = 4 * INIT_BUCKETS as u64;
        for i in 0..n {
            q.push(i, Ev::CoreWake { core: i as usize });
        }
        assert!(q.stats().resizes >= 1, "no watermark trip: {:?}", q.stats());
        let mut last = 0;
        while let Some(e) = q.pop() {
            assert!(e.t >= last);
            last = e.t;
        }
        let s = q.stats();
        assert!(s.resamples >= 1, "no resample: {s:?}");
        assert!(s.width < CYCLE_800MHZ, "width did not narrow: {s:?}");
    }

    #[test]
    fn adaptive_widens_width_on_sparse_streams() {
        // Seeded far too narrow (1 ps) for a ~1 ns-spaced stream: the
        // near-empty wheel shrink-trips, and the resample must widen the
        // buckets toward the observed spacing.
        let mut q = EventQueue::with_kind(EngineKind::AdaptiveCalendar, 1);
        for i in 0..INIT_BUCKETS as u64 {
            q.push(i * 1_000, Ev::CoreWake { core: i as usize });
        }
        let mut last = 0;
        while let Some(e) = q.pop() {
            assert!(e.t >= last);
            last = e.t;
        }
        let s = q.stats();
        assert!(s.resamples >= 1, "no resample: {s:?}");
        assert!(s.width > 1, "width did not widen: {s:?}");
    }

    #[test]
    fn adaptive_resample_preserves_exact_order() {
        // Drifting density with same-tick ties: the adaptive queue must
        // still pop ascending (t, seq) — including across re-bucketings.
        let mut adp = EventQueue::with_kind(EngineKind::AdaptiveCalendar, CYCLE_800MHZ);
        let mut heap = EventQueue::with_kind(EngineKind::ReferenceHeap, 0);
        let mut t = 0;
        for i in 0..(3 * INIT_BUCKETS as u64) {
            // Phase 1 dense (ties every 4th) — long enough to trip the
            // grow watermark and open a sampling window — phase 2 sparse.
            t += if i < 2 * INIT_BUCKETS as u64 + 64 {
                if i % 4 == 0 { 0 } else { 100 }
            } else {
                500_000
            };
            adp.push(t, Ev::CoreWake { core: i as usize });
            heap.push(t, Ev::CoreWake { core: i as usize });
        }
        loop {
            let (a, b) = (adp.pop(), heap.pop());
            assert_eq!(a, b);
            if a.is_none() {
                break;
            }
        }
    }

    #[test]
    fn fixed_calendar_never_resamples() {
        let mut q = EventQueue::with_kind(EngineKind::Calendar, 1_000);
        for i in 0..4 * INIT_BUCKETS as u64 {
            q.push(i % 50_000, Ev::CoreWake { core: i as usize });
        }
        while q.pop().is_some() {}
        let s = q.stats();
        assert!(s.resizes >= 1);
        assert_eq!(s.resamples, 0);
        assert_eq!(s.width, 1_000);
    }
}
