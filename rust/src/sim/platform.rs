//! The platform: wiring of cores, caches, controllers, and the
//! extension-memory backend layer into one event-driven simulation.
//!
//! The platform itself is mechanism-agnostic: everything specific to how
//! extended memory is reached (MEC trees, the QPI link, PCIe swapping,
//! the AMU request queue) lives behind [`super::backend`]'s router, and
//! this file only wires the generic hooks — ingress on submit, command
//! observation and egress on service, plus read-only stat accessors.

use super::backend::{AmuStats, ChannelGroup, GroupKind, MimsStats, Router};
use super::engine::{EngineKind, Ev, EventQueue};
use super::fault::{
    domain_of, BurstState, EccFault, FaultCounters, FaultPlan, FaultStats, DOM_PCIE,
    ECC_CORRECT_PS, ECC_REREAD_PS,
};
use super::report::SimReport;
use super::sample::Sampler;
use super::shard::{self, PumpJob, ShardPool};
use crate::baselines::SwapOutcome;
use crate::cache::{CacheConfig, DataKind, LookupResult, MshrFile, MshrOutcome, SetAssocCache, Tlb};
use crate::config::{RunSpec, SystemConfig};
use crate::cpu::frontend::{ReqSlab, TagSlab, WaiterTable, NIL};
use crate::cpu::{Core, FrontEnd, IssueResult, MemAccess, MemoryPort, AccessKind};
use crate::dram::address::AddressMapping;
use crate::dram::{MemController, ServiceResult, Transaction};
use crate::mec::Mec1;
use crate::memmgr::Allocator;
use crate::stats::LevelMeter;
use crate::twinload::Transform;
use crate::util::time::Ps;
use crate::util::Rng;
use crate::workloads;
use crate::workloads::arrival::{ArrivalKind, ServingSource, ServingStats};
use crate::util::FastMap;
use anyhow::{bail, Context, Result};

/// Per-core private state.
struct CoreBundle {
    core: Core,
    /// The serving gate over the devirtualized lowering: the transform
    /// is instantiated over the concrete workload enum (so `next_op` is
    /// a direct match), wrapped by the open/closed-loop arrival gate
    /// (a transparent passthrough when `arrival = closed`).
    source: ServingSource,
    l1: SetAssocCache,
    tlb: Tlb,
    mshr: MshrFile,
    /// line → (req_id, is_store) waiters for in-flight misses
    /// (reference front end only).
    waiters: FastMap<u64, Vec<(u64, bool)>>,
    next_req: u64,
    /// Slab front end: outstanding miss requests with intrusive per-line
    /// waiter chains (heads in `wtab`, next-links in `reqs`).
    reqs: ReqSlab,
    wtab: WaiterTable,
    /// Earliest scheduled CoreWake (dedup guard against wake pileup).
    next_wake: Option<Ps>,
    /// Hardware page-walker occupancy: walks serialize per core (the
    /// mechanism behind the paper's "GUPS concurrency is likely limited
    /// by the many TLB misses", §6.1/Figure 11).
    walker_free: Ps,
    /// Stride-prefetcher stream table (multiple concurrent streams, as
    /// real L2 prefetchers track): (last line, run length, lru stamp).
    streams: [(u64, u32, u64); 8],
    stream_clock: u64,
    /// SMARTS sampling state machine (`None` = every op runs detailed).
    sampler: Option<Sampler>,
}

/// A read transaction in flight at a controller.
#[derive(Debug, Clone, Copy)]
struct PendingTxn {
    /// Demand read for a core, or a hardware prefetch (LLC fill only).
    core: Option<usize>,
    line: u64,
}

/// EWMA weight of each new health observation (1/8: a retry storm of a
/// few consecutive faulted accesses crosses any threshold below ~0.6,
/// while isolated blips decay away within tens of accesses).
const HEALTH_ALPHA: f64 = 0.125;

/// Per-fault-domain host-side health state.
struct DomainHealth {
    /// EWMA of unhealthy-access outcomes in [0, 1].
    score: f64,
    /// First unhealthy observation of the current episode (MTTD anchor);
    /// cleared once the score decays back below half the threshold.
    bad_since: Option<Ps>,
    /// Quarantine entry time; `Some` means currently quarantined.
    quarantined_at: Option<Ps>,
    /// Consecutive clean probe outcomes observed while quarantined.
    probe_streak: u32,
}

/// Host-side online health detection and quarantine over fault domains.
///
/// One EWMA unhealthy score per domain (MEC chip, extension channel
/// group, AMU/MIMS unit, PCIe link), fed by the per-access retry and
/// recovery outcomes the host observes at delivery. When a score crosses
/// `quarantine_threshold` the domain is quarantined: *all* its traffic is
/// demoted to the §4.5 safe path (real data through the uncacheable
/// mapping plus `safe_penalty`, no content check, no retry storm). While
/// quarantined the tracker runs half-open probation — each access still
/// evaluates its would-be fault outcome without applying it — and
/// `probe_ok` consecutive clean probes re-admit the domain.
///
/// Built only when the burst layer is armed *and* the threshold is
/// positive, so a `burst_rate = 0` run carries no tracker state at all.
pub(crate) struct HealthTracker {
    threshold: f64,
    probe_ok: u32,
    domains: FastMap<u64, DomainHealth>,
    quarantines: u64,
    readmits: u64,
    /// Sum over quarantine events of (quarantine entry − first unhealthy
    /// observation): total time-to-detect.
    mttd_sum: Ps,
    /// Sum over readmissions of the quarantine interval length.
    mttr_sum: Ps,
    /// Total time spent quarantined across closed intervals (open
    /// intervals are added at report time).
    degraded: Ps,
}

/// Finalized health/quarantine numbers for the report.
#[derive(Debug, Default, Clone, Copy)]
pub(crate) struct HealthTotals {
    pub quarantines: u64,
    pub readmits: u64,
    /// Mean time-to-detect (first unhealthy observation → quarantine), ns.
    pub mttd_ns: f64,
    /// Mean time-to-repair (quarantine → readmission), ns.
    pub mttr_ns: f64,
    /// Total domain-time spent quarantined (degraded mode), ns.
    pub degraded_ns: f64,
}

impl HealthTracker {
    fn new(threshold: f64, probe_ok: u32) -> HealthTracker {
        HealthTracker {
            threshold,
            probe_ok: probe_ok.max(1),
            domains: FastMap::default(),
            quarantines: 0,
            readmits: 0,
            mttd_sum: 0,
            mttr_sum: 0,
            degraded: 0,
        }
    }

    /// Is the fault domain behind `kind` currently quarantined?
    fn quarantined(&self, kind: GroupKind) -> bool {
        domain_of(kind).is_some_and(|dom| {
            self.domains.get(&dom).is_some_and(|d| d.quarantined_at.is_some())
        })
    }

    /// Fold one delivery outcome into the domain score and run the
    /// quarantine state machine. `at` is the service-completion instant
    /// (`saturating_sub` everywhere: completion times are not monotone
    /// across channels).
    fn observe(&mut self, kind: GroupKind, unhealthy: bool, at: Ps) {
        let Some(dom) = domain_of(kind) else { return };
        self.observe_dom(dom, unhealthy, at);
    }

    fn observe_dom(&mut self, dom: u64, unhealthy: bool, at: Ps) {
        let d = self.domains.entry(dom).or_insert(DomainHealth {
            score: 0.0,
            bad_since: None,
            quarantined_at: None,
            probe_streak: 0,
        });
        match d.quarantined_at {
            Some(since) => {
                // Half-open probation: the caller evaluated the would-be
                // outcome without applying it.
                if unhealthy {
                    d.probe_streak = 0;
                } else {
                    d.probe_streak += 1;
                    if d.probe_streak >= self.probe_ok {
                        let held = at.saturating_sub(since);
                        self.degraded += held;
                        self.mttr_sum += held;
                        self.readmits += 1;
                        d.quarantined_at = None;
                        d.probe_streak = 0;
                        d.score = 0.0;
                        d.bad_since = None;
                    }
                }
            }
            None => {
                if unhealthy && d.bad_since.is_none() {
                    d.bad_since = Some(at);
                }
                d.score += HEALTH_ALPHA * ((unhealthy as u8 as f64) - d.score);
                if d.score >= self.threshold {
                    self.mttd_sum += at.saturating_sub(d.bad_since.unwrap_or(at));
                    self.quarantines += 1;
                    d.quarantined_at = Some(at);
                    d.probe_streak = 0;
                } else if !unhealthy && d.score < 0.5 * self.threshold {
                    // The episode decayed on its own: drop the MTTD
                    // anchor so a later episode measures its own onset.
                    d.bad_since = None;
                }
            }
        }
    }

    /// Report-time totals; still-open quarantine intervals are closed at
    /// `now` for the degraded-time figure (but don't count as repairs).
    fn totals(&self, now: Ps) -> HealthTotals {
        let mut degraded = self.degraded;
        for d in self.domains.values() {
            if let Some(since) = d.quarantined_at {
                degraded += now.saturating_sub(since);
            }
        }
        HealthTotals {
            quarantines: self.quarantines,
            readmits: self.readmits,
            mttd_ns: if self.quarantines > 0 {
                self.mttd_sum as f64 / self.quarantines as f64 / 1000.0
            } else {
                0.0
            },
            mttr_ns: if self.readmits > 0 {
                self.mttr_sum as f64 / self.readmits as f64 / 1000.0
            } else {
                0.0
            },
            degraded_ns: degraded as f64 / 1000.0,
        }
    }
}

pub struct Platform {
    cfg: SystemConfig,
    spec: RunSpec,
    cores: Vec<CoreBundle>,
    llc: SetAssocCache,
    groups: Vec<ChannelGroup>,
    /// The extension-memory backend layer: all per-mechanism state
    /// (MEC trees, QPI link, PCIe residency pool, AMU queue) and the
    /// routing hooks the platform calls, constructed typed up front.
    router: Router,
    /// Which bookkeeping implementation tracks in-flight transactions and
    /// waiters (`pending` vs `txns`/`reqs`).
    frontend: FrontEnd,
    pending: FastMap<u64, PendingTxn>,
    /// Slab front end: in-flight reads keyed by `{counter, slot}` handles
    /// so completion is an array index. The counter in the handle's high
    /// bits preserves submit order, which the controller's `(arrive, id)`
    /// tie-break depends on — both front ends service transactions in the
    /// exact same order.
    txns: TagSlab<PendingTxn>,
    next_txn: u64,
    /// Reusable per-channel service-result buffers for the two-phase
    /// pump (sized to the widest group; each channel appends into its
    /// own slot so phase 1 can run the channels in parallel).
    pump_bufs: Vec<Vec<ServiceResult>>,
    /// Per-channel wake times produced by phase 1.
    pump_wakes: Vec<Option<Ps>>,
    /// Worker shards for `EngineKind::Sharded` (`None` = serial phase 1:
    /// other engines, single-CPU hosts, or an exhausted thread budget).
    shards: Option<ShardPool>,
    /// Pump batches that actually ran on the shard pool (diagnostics).
    parallel_pumps: u64,
    /// Deterministic fault schedule (`None` = injection fully disabled;
    /// every injection site below is gated on it, so a zero-rate run is
    /// bit-identical to a build without this subsystem).
    fault: Option<FaultPlan>,
    /// Per-line occurrence counters for the fault draws.
    fault_seq: FaultCounters,
    fault_stats: FaultStats,
    /// Online health detection and quarantine (armed only when the
    /// correlated-fault burst layer is on and the threshold is positive,
    /// so `burst_rate = 0` runs are bit-identical to builds without it).
    health: Option<HealthTracker>,
    events: EventQueue,
    mlp: LevelMeter,
    now: Ps,
    finished_cores: usize,
    pub deadlocked: bool,
}

/// Buffered cross-component actions produced while a core is borrowed.
#[derive(Default)]
struct Outbox {
    /// (line address, controller arrive time) for demand reads / RFOs.
    reads: Vec<(u64, Ps)>,
    writes: Vec<(u64, Ps)>,
    /// Stride-prefetch candidates (LLC fills, no core waiter).
    prefetches: Vec<(u64, Ps)>,
}

/// The per-core memory port: borrows the core's private hierarchy plus
/// the shared LLC and books MC work into the outbox.
struct Port<'a> {
    cfg: &'a SystemConfig,
    fe: FrontEnd,
    l1: &'a mut SetAssocCache,
    tlb: &'a mut Tlb,
    mshr: &'a mut MshrFile,
    waiters: &'a mut FastMap<u64, Vec<(u64, bool)>>,
    next_req: &'a mut u64,
    reqs: &'a mut ReqSlab,
    wtab: &'a mut WaiterTable,
    walker_free: &'a mut Ps,
    streams: &'a mut [(u64, u32, u64); 8],
    stream_clock: &'a mut u64,
    llc: &'a mut SetAssocCache,
    router: &'a mut Router,
    outbox: &'a mut Outbox,
    fault: Option<FaultPlan>,
    fault_seq: &'a mut FaultCounters,
    fault_stats: &'a mut FaultStats,
    /// SMARTS fast-forward: serve every access from the content model at
    /// a cheap constant latency instead of the detailed machinery.
    functional: bool,
}

/// Stride prefetch degree (lines fetched ahead once a stream is seen).
const PREFETCH_DEGREE: u64 = 4;
/// Misses in sequence before the prefetcher engages.
const PREFETCH_TRAIN: u32 = 2;
/// Latency of a functional-mode miss (SMARTS fast-forward): a flat
/// figure between the LLC and DRAM costs, cheap to compute but still
/// pacing the core enough that open-loop queues drain plausibly.
const FUNCTIONAL_MISS_PS: Ps = 60_000;
/// Queued transactions (across a group's channels) below which a
/// sharded pump runs serially: dispatching to the pool costs two lock
/// round-trips, which only pays off once the per-channel pumps have
/// real scheduling work to do.
const SHARD_MIN_QUEUED: usize = 8;

impl<'a> Port<'a> {
    /// Register a miss waiter for `line`; returns the request handle the
    /// platform will complete with.
    fn track_waiter(&mut self, line: u64, is_store: bool) -> u64 {
        match self.fe {
            FrontEnd::Reference => {
                let req = *self.next_req;
                *self.next_req += 1;
                self.waiters.entry(line).or_default().push((req, is_store));
                req
            }
            FrontEnd::Slab => self.reqs.push_waiter(self.wtab, line, is_store),
        }
    }

    /// Submit an L1 eviction into the LLC (writeback path).
    fn l1_evict(&mut self, addr: u64, dirty: bool, at: Ps) {
        if !dirty {
            return;
        }
        // Inclusive-ish: dirty data merges into the LLC copy if present,
        // otherwise goes straight to memory.
        match self.llc.probe(addr) {
            Some(_) => {
                self.llc.access(addr, true);
            }
            None => self.outbox.writes.push((addr, at)),
        }
    }
}

impl<'a> MemoryPort for Port<'a> {
    fn issue(&mut self, now: Ps, acc: &MemAccess) -> IssueResult {
        match acc.kind {
            AccessKind::Invalidate => {
                // clflush: drop from both levels (dirty data written back).
                if self.l1.invalidate(acc.vaddr) {
                    // write-back cost folded into inv_lat
                }
                self.llc.invalidate(acc.vaddr);
                return IssueResult::Done { at: now + self.cfg.inv_lat, data: DataKind::Real };
            }
            AccessKind::SafePath => {
                return IssueResult::Done { at: now + self.cfg.safe_lat, data: DataKind::Real };
            }
            AccessKind::Load | AccessKind::Store => {}
        }
        let is_store = acc.kind == AccessKind::Store;
        let line = acc.vaddr & !63;

        if self.functional {
            // SMARTS fast-forward: keep the content model warm (TLB,
            // cache tags, residency) at a constant cheap latency and
            // bypass the MSHR/DRAM/backend machinery entirely. Dropped
            // dirty evictions are deliberate — functional mode maintains
            // state, not timing, and the next detailed window rebuilds
            // timing state during its warmup.
            self.tlb.access(acc.vaddr);
            if let LookupResult::Hit(d) = self.l1.access(line, is_store) {
                return IssueResult::Done { at: now + self.cfg.l1_lat, data: d };
            }
            if let LookupResult::Hit(d) = self.llc.access(line, false) {
                let _ = self.l1.fill(line, is_store, d);
                return IssueResult::Done { at: now + self.cfg.llc_lat, data: d };
            }
            let _ = self.llc.fill(line, false, DataKind::Real);
            let _ = self.l1.fill(line, is_store, DataKind::Real);
            return IssueResult::Done { at: now + FUNCTIONAL_MISS_PS, data: DataKind::Real };
        }

        // Stall check first, against *probes* only: a stalled op will be
        // re-issued, and hardware does not recount TLB/cache accesses for
        // a replayed µop — neither do the counters here.
        let l1_probe = self.l1.probe(line);
        let llc_probe = if l1_probe.is_none() { self.llc.probe(line) } else { None };
        if l1_probe.is_none()
            && llc_probe.is_none()
            && self.mshr.is_full()
            && !self.mshr.pending(line)
        {
            self.mshr.request(line); // records the stall statistic
            return IssueResult::Stall { retry_at: now + self.cfg.llc_lat };
        }

        // Committed: count TLB (virtual page of the *accessed* address —
        // twins are distinct pages, the Figure-10 effect). Misses walk
        // the page table on the core's two pipelined hardware walkers:
        // walk *throughput* is one per walk_lat/2, which caps the MLP of
        // TLB-thrashing workloads (the paper's "GUPS concurrency is
        // likely limited by the many TLB misses"). Under NUMA, extended
        // pages' leaf PTEs suffer remote page-table locality: extra
        // latency plus walker occupancy (calibrated to the paper's
        // measured NUMA slowdown on TLB-bound workloads).
        let mut delay = if self.tlb.access(acc.vaddr) {
            0
        } else {
            let remote =
                self.router.remote_page_walks() && !self.cfg.layout.is_local(acc.vaddr);
            let (lat_extra, occ_extra) = if remote {
                (self.cfg.numa_one_way, self.cfg.numa_one_way / 2)
            } else {
                (0, 0)
            };
            let start = now.max(*self.walker_free);
            *self.walker_free = start + self.cfg.walk_lat / 2 + occ_extra;
            (start + self.cfg.walk_lat + lat_extra) - now
        };

        // PCIe residency check (extended data only).
        if self.cfg.layout.is_extended(acc.vaddr) {
            if let Some(pcie) = self.router.pcie_mut() {
                if let SwapOutcome::Fault { swap_done, .. } = pcie.access(acc.vaddr, now) {
                    let mut xfer = swap_done - now;
                    if let Some(plan) = self.fault {
                        let page = acc.vaddr & !0xFFF;
                        let nth = self.fault_seq.next(page);
                        self.fault_stats.ext_accesses += 1;
                        // Correlated layer: a bad burst window on the
                        // PCIe link domain stretches the DMA (fail-slow)
                        // or force-drops it (fail-stop).
                        let state = plan.burst_state_dom(DOM_PCIE, now);
                        if let BurstState::Slow(mult) = state {
                            xfer *= mult;
                        }
                        // Injected DMA transfer failure: the completion
                        // timeout fires and the whole swap retransmits.
                        if state == BurstState::Stop || plan.pcie_fail(page, nth) {
                            self.fault_stats.record(xfer);
                            self.fault_stats.degraded_accesses += 1;
                            xfer += xfer;
                        } else if state != BurstState::Good {
                            self.fault_stats.degraded_accesses += 1;
                        }
                    }
                    delay += xfer;
                }
            }
        }

        // L1.
        if let LookupResult::Hit(d) = self.l1.access(line, is_store) {
            return IssueResult::Done { at: now + delay + self.cfg.l1_lat, data: d };
        }
        // LLC.
        if let LookupResult::Hit(d) = self.llc.access(line, false) {
            if let Some(ev) = self.l1.fill(line, is_store, d) {
                self.l1_evict(ev.addr, ev.dirty, now);
            }
            return IssueResult::Done { at: now + delay + self.cfg.llc_lat, data: d };
        }
        // Off-core: MSHR + memory transaction.
        match self.mshr.request(line) {
            MshrOutcome::Full => IssueResult::Stall { retry_at: now + self.cfg.llc_lat },
            MshrOutcome::Merged => {
                let req = self.track_waiter(line, is_store);
                IssueResult::Pending { req_id: req }
            }
            MshrOutcome::Allocated => {
                let req = self.track_waiter(line, is_store);
                self.outbox.reads.push((line, now + delay + self.cfg.llc_lat));
                // Stride prefetcher: the stream table matches this miss
                // against tracked sequential streams; a trained stream
                // pulls the next lines into the LLC (stopping at the page
                // boundary, as hardware prefetchers do).
                *self.stream_clock += 1;
                let clock = *self.stream_clock;
                let mut trained = false;
                match self.streams.iter_mut().find(|s| line == s.0.wrapping_add(64)) {
                    Some(s) => {
                        s.0 = line;
                        s.1 += 1;
                        s.2 = clock;
                        trained = s.1 >= PREFETCH_TRAIN;
                    }
                    None => {
                        // Allocate over the LRU stream.
                        let s = self.streams.iter_mut().min_by_key(|s| s.2).unwrap();
                        *s = (line, 0, clock);
                    }
                }
                if trained {
                    for k in 1..=PREFETCH_DEGREE {
                        let pf = line + 64 * k;
                        if pf / 4096 != line / 4096 {
                            break; // page boundary
                        }
                        if self.llc.probe(pf).is_none() && !self.mshr.pending(pf) {
                            self.outbox
                                .prefetches
                                .push((pf, now + delay + self.cfg.llc_lat));
                        }
                    }
                }
                IssueResult::Pending { req_id: req }
            }
        }
    }
}

impl Platform {
    /// Build the platform for one (system, run) pair. Invalid
    /// configurations (including backend knobs) surface as typed errors,
    /// not panics.
    pub fn build(cfg: &SystemConfig, spec: &RunSpec) -> Result<Platform> {
        cfg.validate()
            .map_err(anyhow::Error::msg)
            .context("invalid system config")?;
        let layout = cfg.layout;

        // --- Channel groups ---
        let mut groups = Vec::new();
        // Local memory: always present.
        {
            let geo = cfg.local_channel_geometry();
            groups.push(ChannelGroup {
                kind: GroupKind::Local,
                base: 0,
                span: layout.local_size,
                map: AddressMapping::new(&geo, 1),
                channels: (0..cfg.local_channels)
                    .map(|_| MemController::with_policy(cfg.host_timing, geo, cfg.sched))
                    .collect(),
                next_pump: None,
            });
        }

        // --- Workload placement (the PCIe backend sizes its residency
        // pool from the extended footprint) + the backend layer, which
        // owns all per-mechanism state and the extended channel group.
        let mut alloc = Allocator::new(layout, 1 << 20);
        let sig = spec.workload.signature();
        let data = workloads::DataRegions::place(&mut alloc, spec.footprint, &sig);
        let (router, ext_group) =
            Router::build(cfg, &data).context("building extension-memory backend")?;
        if let Some(g) = ext_group {
            groups.push(g);
        }

        // SMT by static partitioning: each hardware thread is a bundle
        // with its share of the core's window and private structures.
        let smt = cfg.smt.max(1);
        let hw_threads = cfg.cores * smt;

        // Serving-knob validation (typed errors, like backend knobs).
        if spec.arrival != ArrivalKind::Closed {
            if spec.offered_rps == 0 {
                bail!("open-loop arrival ({}) requires offered_rps > 0", spec.arrival.name());
            }
            if spec.queue_depth == 0 {
                bail!("open-loop arrival ({}) requires queue_depth > 0", spec.arrival.name());
            }
        }
        if !(0.0..1.0).contains(&spec.zipf_theta) {
            bail!("zipf_theta must be in [0, 1), got {}", spec.zipf_theta);
        }
        // Sampling-knob validation (SMARTS cadence; period 0 = off).
        if spec.sample_period > 0 {
            if spec.sample_detail == 0 {
                bail!("sample_period > 0 requires sample_detail >= 1");
            }
            if spec.sample_warmup + spec.sample_detail > spec.sample_period {
                bail!(
                    "sample window does not fit: sample_warmup {} + sample_detail {} > sample_period {}",
                    spec.sample_warmup,
                    spec.sample_detail,
                    spec.sample_period
                );
            }
        }
        let mut tp = cfg.core;
        tp.rob_size = (tp.rob_size / smt).max(16);
        tp.demote_after = cfg.demote_after;
        let mut l1 = cfg.l1;
        l1.size_bytes = (l1.size_bytes / smt as u64).max(l1.ways as u64 * 64);
        let thread_mshrs = (cfg.mshrs_per_core / smt).max(1);
        let thread_tlb = (cfg.tlb_entries / smt as u32).max(16);
        let cores: Vec<CoreBundle> = (0..hw_threads)
            .map(|i| {
                let wl = workloads::build_source_with(
                    spec.workload,
                    data,
                    spec.ops_per_core,
                    spec.seed.wrapping_add(i as u64 * 0x9E37_79B9),
                    spec.zipf_theta,
                );
                let transform = Transform::new(wl, cfg.mechanism, layout);
                let source = match spec.arrival {
                    ArrivalKind::Closed => ServingSource::closed(transform),
                    kind => {
                        // Offered load is system-wide; each hardware
                        // thread serves an equal share, with a per-thread
                        // arrival stream forked off the arrival seed.
                        let per_core = spec.offered_rps as f64 / hw_threads as f64;
                        let mut master = Rng::new(spec.arrival_seed);
                        ServingSource::open(
                            transform,
                            kind,
                            per_core,
                            spec.queue_depth as usize,
                            master.fork(i as u64),
                        )
                    }
                };
                CoreBundle {
                    core: Core::with_frontend(tp, cfg.frontend),
                    source,
                    l1: SetAssocCache::new(l1),
                    tlb: Tlb::new(thread_tlb, 4, 4 << 10),
                    mshr: MshrFile::new(thread_mshrs),
                    waiters: FastMap::default(),
                    next_req: 1,
                    reqs: ReqSlab::new(),
                    wtab: WaiterTable::new(thread_mshrs),
                    next_wake: None,
                    walker_free: 0,
                    streams: [(u64::MAX, 0, 0); 8],
                    stream_clock: 0,
                    // The cadence parameters (including the seeded
                    // window offset) are identical across cores, so
                    // every core measures the same op ranges.
                    sampler: (spec.sample_period > 0).then(|| {
                        Sampler::new(
                            spec.sample_period,
                            spec.sample_warmup,
                            spec.sample_detail,
                            spec.sample_seed,
                            cfg.core.period,
                        )
                    }),
                }
            })
            .collect();

        let mut events = EventQueue::with_kind(cfg.engine, cfg.host_timing.t_ck);
        for i in 0..hw_threads {
            events.push(0, Ev::CoreWake { core: i });
        }

        // Shard pool for the parallel engine: one slot per channel of
        // the widest group, capped by the sweep-level thread budget and
        // the host. A plan of 1 (single-CPU host, exhausted budget, or a
        // one-channel platform) keeps `Sharded` selectable but pumps
        // serially — results are bit-identical either way.
        let max_ch = groups.iter().map(|g| g.channels.len()).max().unwrap_or(0);
        let shards = if cfg.engine == EngineKind::Sharded {
            let n = shard::plan_shards(max_ch, spec.shard_cap);
            (n >= 2).then(|| ShardPool::new(n - 1))
        } else {
            None
        };

        Ok(Platform {
            cfg: cfg.clone(),
            spec: *spec,
            cores,
            llc: SetAssocCache::new(CacheConfig { ..cfg.llc }),
            groups,
            router,
            frontend: cfg.frontend,
            pending: FastMap::default(),
            txns: TagSlab::new(),
            next_txn: 1,
            pump_bufs: (0..max_ch).map(|_| Vec::new()).collect(),
            pump_wakes: vec![None; max_ch],
            shards,
            parallel_pumps: 0,
            fault: FaultPlan::from_cfg(cfg),
            fault_seq: FaultCounters::default(),
            fault_stats: FaultStats::default(),
            health: match FaultPlan::from_cfg(cfg) {
                Some(p) if p.burst_armed() && cfg.quarantine_threshold > 0.0 => Some(
                    HealthTracker::new(cfg.quarantine_threshold, cfg.probe_ok),
                ),
                _ => None,
            },
            events,
            mlp: LevelMeter::new(),
            now: 0,
            finished_cores: 0,
            deadlocked: false,
        })
    }

    /// Find the channel group serving `vaddr`.
    fn group_of(&self, vaddr: u64) -> usize {
        if self.router.aliases_local() {
            return 0; // everything lives in local DRAM (resident pages)
        }
        for (i, g) in self.groups.iter().enumerate() {
            if vaddr >= g.base && vaddr < g.base + g.span {
                return i;
            }
        }
        // Shadow addresses fall inside the MEC group's span; anything else
        // is a bug in the generators.
        panic!("address {vaddr:#x} outside all channel groups");
    }

    /// Enqueue a read/write transaction; schedules a pump.
    /// `read_for`: `Some(Some(core))` demand read, `Some(None)` hardware
    /// prefetch, `None` posted write.
    fn submit(&mut self, line: u64, arrive: Ps, read_for: Option<Option<usize>>) {
        let gi = self.group_of(line);
        let kind = self.groups[gi].kind;
        let mut arrive = arrive;
        if kind != GroupKind::Local {
            // Backend ingress: NUMA crosses the QPI link, the AMU queues
            // the request; other mechanisms pass through unchanged. A
            // fail-slow burst window stretches whatever the hook added.
            arrive = self.router.ingress_degraded(kind, arrive, self.fault.as_ref());
        }
        let (ch, ch_addr) = self.groups[gi].route(line);
        // Both front ends draw from the same submit counter: the slab
        // handle carries it in its high bits, so the controller's
        // `(arrive, id)` tie-break orders transactions identically.
        let tag = self.next_txn;
        self.next_txn += 1;
        let id = match self.frontend {
            FrontEnd::Reference => {
                if let Some(kind) = read_for {
                    self.pending.insert(tag, PendingTxn { core: kind, line });
                    self.mlp.up(self.now);
                }
                tag
            }
            FrontEnd::Slab => match read_for {
                Some(kind) => {
                    self.mlp.up(self.now);
                    self.txns.insert(tag, PendingTxn { core: kind, line })
                }
                // Posted writes are untracked: low bits that never match
                // a slab slot, submit order still in the high bits.
                None => (tag << 32) | NIL as u64,
            },
        };
        let g = &mut self.groups[gi];
        let addr = g.map.decode(ch_addr);
        g.channels[ch].enqueue(Transaction {
            id,
            addr,
            is_write: read_for.is_none(),
            arrive,
        });
        self.schedule_pump(gi, arrive.max(self.now));
    }

    /// Schedule a Pump for group `gi` no later than `t` (dedup guard).
    fn schedule_pump(&mut self, gi: usize, t: Ps) {
        let g = &mut self.groups[gi];
        match g.next_pump {
            Some(s) if s <= t => {}
            _ => {
                g.next_pump = Some(t);
                self.events.push(t, Ev::Pump { group: gi });
            }
        }
    }

    /// Advance one core at `now`, then flush its outbox.
    fn advance_core(&mut self, ci: usize, now: Ps) {
        let mut outbox = Outbox::default();
        let was_finished = self.cores[ci].core.finished();
        {
            let b = &mut self.cores[ci];
            if matches!(b.next_wake, Some(w) if w <= now) {
                b.next_wake = None;
            }
            let functional = b.sampler.as_ref().is_some_and(|s| s.functional());
            let mut port = Port {
                cfg: &self.cfg,
                fe: self.frontend,
                l1: &mut b.l1,
                tlb: &mut b.tlb,
                mshr: &mut b.mshr,
                waiters: &mut b.waiters,
                next_req: &mut b.next_req,
                reqs: &mut b.reqs,
                wtab: &mut b.wtab,
                walker_free: &mut b.walker_free,
                streams: &mut b.streams,
                stream_clock: &mut b.stream_clock,
                llc: &mut self.llc,
                router: &mut self.router,
                outbox: &mut outbox,
                fault: self.fault,
                fault_seq: &mut self.fault_seq,
                fault_stats: &mut self.fault_stats,
                functional,
            };
            if let Some(wake) = b.core.advance(now, &mut b.source, &mut port) {
                // Dedup: keep only the earliest outstanding wake per core.
                match b.next_wake {
                    Some(s) if s <= wake => {}
                    _ => {
                        b.next_wake = Some(wake);
                        self.events.push(wake, Ev::CoreWake { core: ci });
                    }
                }
            }
            // Open-loop completion hook: the core retires in order, so
            // the serving gate can match the cumulative retired-op count
            // against each in-flight request's handed-out boundary.
            // No-op in closed-loop runs.
            let retired = b.core.stats.retired_ops;
            b.source.observe_retired(retired, now);
            // SMARTS cadence: fold retired progress into the sampler so
            // the next advance runs in the right mode, and completed
            // detail windows record their ns-per-op / IPC samples.
            if let Some(s) = b.sampler.as_mut() {
                s.observe(retired, b.core.stats.retired_insts, now);
            }
        }
        for (line, at) in outbox.reads.drain(..) {
            self.submit(line, at, Some(Some(ci)));
        }
        for (line, at) in outbox.prefetches.drain(..) {
            self.submit(line, at, Some(None));
        }
        for (line, at) in outbox.writes.drain(..) {
            self.submit(line, at, None);
        }
        if !was_finished && self.cores[ci].core.finished() {
            self.finished_cores += 1;
        }
    }

    /// Pump all controllers of a group at `now`; deliver service results.
    ///
    /// Two phases. **Phase 1** pumps every channel into its own result
    /// buffer: a controller pump touches only channel-local state, so
    /// under [`EngineKind::Sharded`] the channels run on the worker
    /// shards in parallel. The conservative lookahead window that makes
    /// this safe is the minimum cross-shard latency: every consequence a
    /// serviced transaction has outside its own channel (LLC fill,
    /// delivery, eviction writeback) lands at `data_end + llc_lat` plus
    /// the backend egress — strictly after `now`, so no pump at `now`
    /// can observe work a sibling produces at `now`. **Phase 2** folds
    /// the buffered results into the shared state serially in ascending
    /// channel order, so the event stream — and every `SimReport` — is
    /// bit-identical whether phase 1 ran serially or sharded.
    fn pump_group(&mut self, gi: usize, now: Ps) {
        if matches!(self.groups[gi].next_pump, Some(s) if s <= now) {
            self.groups[gi].next_pump = None;
        }
        let kind = self.groups[gi].kind;
        let nch = self.groups[gi].channels.len();

        // --- Phase 1: pump each channel into its own buffer. ---
        let parallel = self.shards.is_some()
            && nch >= 2
            && self.groups[gi]
                .channels
                .iter()
                .map(|c| c.queue_len())
                .sum::<usize>()
                >= SHARD_MIN_QUEUED;
        if parallel {
            self.parallel_pumps += 1;
            let chans = self.groups[gi].channels.as_mut_ptr();
            let bufs = self.pump_bufs.as_mut_ptr();
            let wakes = self.pump_wakes.as_mut_ptr();
            // Safety: every job targets a distinct channel index, so the
            // controller/buffer/wake pointers are disjoint, and
            // `ShardPool::run` joins the whole batch before returning —
            // the pointers never outlive this call's exclusive borrow.
            let jobs: Vec<PumpJob> = (0..nch)
                .map(|ch| unsafe {
                    PumpJob { mc: chans.add(ch), now, out: bufs.add(ch), wake: wakes.add(ch) }
                })
                .collect();
            self.shards.as_ref().unwrap().run(jobs);
        } else {
            for ch in 0..nch {
                self.pump_bufs[ch].clear();
                self.pump_wakes[ch] =
                    self.groups[gi].channels[ch].pump(now, &mut self.pump_bufs[ch]);
            }
        }

        // --- Phase 2: apply results serially in channel order. ---
        let mut next_wake: Option<Ps> = None;
        for ch in 0..nch {
            if let Some(w) = self.pump_wakes[ch] {
                next_wake = Some(next_wake.map_or(w, |x: Ps| x.min(w)));
            }
            // Taken out of self so the apply loop can borrow self freely
            // (put back below to keep the capacity).
            let results = std::mem::take(&mut self.pump_bufs[ch]);
            for r in &results {
                // The backend observes the serviced command stream (the
                // MEC watches the DDR bus exactly as §4.3 describes).
                let mut data = match kind {
                    GroupKind::Local => DataKind::Real,
                    _ => self.router.observe_commands(kind, ch, r),
                };
                if matches!(kind, GroupKind::ExtMec | GroupKind::ExtMims)
                    && self.cfg.emulate_content
                {
                    // Paper-emulation content model (§5): extended
                    // lines hold real values, shadow lines fake — the
                    // MEC machinery above still sets the timing and
                    // statistics.
                    let p = match self.frontend {
                        FrontEnd::Reference => self.pending.get(&r.id),
                        FrontEnd::Slab => self.txns.get(r.id),
                    };
                    if let Some(p) = p {
                        data = if self.cfg.layout.is_shadow(p.line) {
                            DataKind::Fake
                        } else {
                            DataKind::Real
                        };
                    }
                }
                if r.is_write {
                    continue;
                }
                let p = match self.frontend {
                    FrontEnd::Reference => self.pending.remove(&r.id),
                    FrontEnd::Slab => self.txns.remove(r.id),
                };
                let Some(p) = p else {
                    continue;
                };
                let mut done = r.data_end + self.cfg.llc_lat; // fill path back up
                // Backend egress: the NUMA return hop / AMU notify; a
                // fail-slow burst window stretches the whole fill path.
                done += self.router.egress_degraded(
                    kind,
                    r.data_end,
                    self.cfg.llc_lat,
                    self.fault.as_ref(),
                );
                match p.core {
                    Some(core) => {
                        if kind != GroupKind::Local {
                            if let Some(plan) = self.fault {
                                let nth = self.fault_seq.next(p.line);
                                // Correlated layer: the burst window this
                                // delivery falls in, on this kind's fault
                                // domain. Fail-stop windows fault every
                                // draw; fail-slow already stretched the
                                // egress above.
                                let state = plan.burst_state(kind, r.data_end);
                                let stop = state == BurstState::Stop;
                                let mut unhealthy = state != BurstState::Good;
                                self.fault_stats.ext_accesses += 1;
                                if self.health.as_ref().is_some_and(|h| h.quarantined(kind)) {
                                    // Domain-level §4.5 demotion: the host
                                    // stopped trusting this domain's twin
                                    // protocol and serves through the
                                    // uncacheable safe mapping — real data
                                    // plus `safe_penalty`, no content
                                    // check, no retry storm. Half-open
                                    // probation still evaluates the
                                    // would-be outcome (without applying
                                    // it) so clean windows re-admit.
                                    unhealthy |= stop
                                        || match kind {
                                            GroupKind::ExtMec | GroupKind::ExtMims => {
                                                data == DataKind::Real
                                                    && plan.not_ready(p.line, nth)
                                            }
                                            GroupKind::ExtRemote | GroupKind::ExtTrl => {
                                                plan.not_ready(p.line, nth)
                                            }
                                            GroupKind::ExtAmu => {
                                                plan.notify_lost(p.line, nth, 0)
                                            }
                                            GroupKind::Local => false,
                                        };
                                    data = DataKind::Real;
                                    done += self.cfg.core.safe_penalty;
                                    self.cores[core].core.note_quarantined_safe();
                                    self.fault_stats.degraded_accesses += 1;
                                } else {
                                    let mut faulted = false;
                                    match kind {
                                        // Not-ready first response: the line
                                        // fails the §4.4 content check and the
                                        // core pays a software retry (or, past
                                        // the streak threshold, demotes to the
                                        // §4.5 safe path).
                                        // MIMS messages ride the same MEC'd
                                        // channel and content check, so a
                                        // not-ready response faults exactly
                                        // like the synchronous twin-load path.
                                        GroupKind::ExtMec | GroupKind::ExtMims => {
                                            // First loads and shadow lines are
                                            // already fake; flipping them would
                                            // be a no-op fault.
                                            if data == DataKind::Real
                                                && (stop || plan.not_ready(p.line, nth))
                                            {
                                                data = DataKind::Fake;
                                                self.fault_stats.record(self.cfg.core.retry_penalty);
                                                faulted = true;
                                            }
                                        }
                                        // Non-twin links have no content check:
                                        // a lost transfer is detected by the
                                        // poll-timeout window and redelivered.
                                        GroupKind::ExtRemote | GroupKind::ExtTrl => {
                                            if stop || plan.not_ready(p.line, nth) {
                                                done += self.cfg.fault_poll_timeout;
                                                self.fault_stats.record(self.cfg.fault_poll_timeout);
                                                faulted = true;
                                            }
                                        }
                                        // Lost completion notify: software
                                        // polls out the timeout and reissues
                                        // with exponential backoff; the bounded
                                        // final attempt always delivers.
                                        GroupKind::ExtAmu => {
                                            if stop || plan.notify_lost(p.line, nth, 0) {
                                                let (rec, _) = plan.amu_recovery(
                                                    p.line,
                                                    nth,
                                                    self.cfg.fault_poll_timeout,
                                                    self.cfg.fault_reissue_max,
                                                    self.cfg.fault_backoff_mult,
                                                );
                                                done += rec;
                                                self.fault_stats.record(rec);
                                                faulted = true;
                                            }
                                        }
                                        GroupKind::Local => {}
                                    }
                                    // Transient bit errors on the returning
                                    // beat: ECC corrects single-bit flips
                                    // in-line; multi-bit detections force a
                                    // controller re-read.
                                    match plan.ecc(p.line, nth) {
                                        EccFault::None => {}
                                        EccFault::Corrected => {
                                            self.fault_stats.ecc_corrected += 1;
                                            done += ECC_CORRECT_PS;
                                        }
                                        EccFault::Detected => {
                                            done += ECC_REREAD_PS;
                                            self.fault_stats.record(ECC_REREAD_PS);
                                            faulted = true;
                                        }
                                    }
                                    unhealthy |= faulted;
                                    if unhealthy {
                                        self.fault_stats.degraded_accesses += 1;
                                    }
                                }
                                if let Some(h) = self.health.as_mut() {
                                    h.observe(kind, unhealthy, r.data_end);
                                }
                            }
                        }
                        self.events.push(done, Ev::Deliver { core, line: p.line, data })
                    }
                    None => {
                        // Hardware prefetch: fill the LLC, wake nobody.
                        self.mlp.down(now.max(self.now));
                        if let Some(ev) = self.llc.fill(p.line, false, data) {
                            if ev.dirty {
                                self.submit(ev.addr, r.data_end, None);
                            }
                        }
                    }
                }
            }
            self.pump_bufs[ch] = results;
        }
        if let Some(w) = next_wake {
            self.schedule_pump(gi, w.max(now));
        }
    }

    /// A line arrived for a core: fill caches, wake waiters.
    fn deliver(&mut self, ci: usize, line: u64, data: DataKind, at: Ps) {
        self.mlp.down(at);
        // Fill LLC (evictions → writebacks).
        if let Some(ev) = self.llc.fill(line, false, data) {
            if ev.dirty {
                self.submit(ev.addr, at, None);
            }
        }
        // Detach this line's waiters (reference: the Vec; slab: the
        // intrusive chain head) and note whether any of them stores.
        let (waiters, chain, any_store) = match self.frontend {
            FrontEnd::Reference => {
                let w = self.cores[ci].waiters.remove(&line).unwrap_or_default();
                let any = w.iter().any(|&(_, s)| s);
                (w, NIL, any)
            }
            FrontEnd::Slab => {
                let b = &mut self.cores[ci];
                let head = b.wtab.remove(line);
                let mut any = false;
                let mut c = head;
                while c != NIL {
                    any |= b.reqs.is_store(c);
                    c = b.reqs.next_of(c);
                }
                (Vec::new(), head, any)
            }
        };
        if let Some(ev) = self.cores[ci].l1.fill(line, any_store, data) {
            if ev.dirty {
                // L1 dirty eviction merges into LLC if present.
                match self.llc.probe(ev.addr) {
                    Some(_) => {
                        self.llc.access(ev.addr, true);
                    }
                    None => self.submit(ev.addr, at, None),
                }
            }
        }
        self.cores[ci].mshr.complete(line);
        match self.frontend {
            FrontEnd::Reference => {
                for (req, _) in waiters {
                    self.cores[ci].core.complete(req, at, data);
                }
            }
            FrontEnd::Slab => {
                // Walk the chain in insertion (FIFO) order, freeing each
                // slot before waking its micro-op.
                let mut c = chain;
                while c != NIL {
                    let b = &mut self.cores[ci];
                    let (req, next) = b.reqs.release(c);
                    b.core.complete(req, at, data);
                    c = next;
                }
            }
        }
        self.advance_core(ci, at);
    }

    /// Run to completion.
    pub fn run(&mut self) {
        let mut steps: u64 = 0;
        while let Some(evt) = self.events.pop() {
            debug_assert!(evt.t >= self.now, "time went backwards");
            self.now = evt.t.max(self.now);
            match evt.ev {
                Ev::CoreWake { core } => self.advance_core(core, self.now),
                Ev::Pump { group } => self.pump_group(group, self.now),
                Ev::Deliver { core, line, data } => self.deliver(core, line, data, self.now),
            }
            steps += 1;
            if steps % 1_000_000 == 0 && std::env::var_os("TWINLOAD_TRACE").is_some() {
                eprintln!(
                    "[trace] steps={steps} now={} events={} finished={}/{} pending={}",
                    self.now,
                    self.events.len(),
                    self.finished_cores,
                    self.cores.len(),
                    self.pending_len()
                );
            }
            if steps > 2_000_000_000 {
                self.deadlocked = true;
                break;
            }
        }
        if self.finished_cores != self.cores.len() {
            self.deadlocked = true;
            if std::env::var_os("TWINLOAD_TRACE").is_some() {
                eprintln!("[deadlock] now={} pending_txns={}", self.now, self.pending_len());
                for (i, b) in self.cores.iter().enumerate() {
                    if !b.core.finished() {
                        let waiters = match self.frontend {
                            FrontEnd::Reference => b.waiters.len(),
                            FrontEnd::Slab => b.wtab.len(),
                        };
                        eprintln!(
                            "[deadlock] core {i}: {} mshr={} waiters={waiters}",
                            b.core.debug_state(),
                            b.mshr.outstanding(),
                        );
                    }
                }
            }
        }
    }

    /// In-flight read transactions (diagnostics only).
    fn pending_len(&self) -> usize {
        match self.frontend {
            FrontEnd::Reference => self.pending.len(),
            FrontEnd::Slab => self.txns.len(),
        }
    }

    /// Collect the run's statistics.
    pub fn report(&self) -> SimReport {
        SimReport::collect(self)
    }

    // --- accessors for report.rs ---
    pub(crate) fn cfg(&self) -> &SystemConfig {
        &self.cfg
    }

    pub(crate) fn spec(&self) -> &RunSpec {
        &self.spec
    }

    pub(crate) fn core_stats(&self) -> Vec<crate::cpu::CoreStats> {
        self.cores.iter().map(|b| b.core.stats).collect()
    }

    pub(crate) fn transform_stats(&self) -> Vec<crate::twinload::TransformStats> {
        self.cores.iter().map(|b| *b.source.transform_stats()).collect()
    }

    /// Merged open-loop serving statistics across all hardware threads
    /// (all-zero with an empty histogram for closed-loop runs).
    pub(crate) fn serving_totals(&self) -> ServingStats {
        let mut total = ServingStats::default();
        for b in &self.cores {
            if let Some(s) = b.source.serving_stats() {
                total.merge(s);
            }
        }
        total
    }

    pub(crate) fn llc_stats(&self) -> (u64, u64) {
        (self.llc.hits, self.llc.misses)
    }

    pub(crate) fn tlb_misses(&self) -> u64 {
        self.cores.iter().map(|b| b.tlb.misses).sum()
    }

    pub(crate) fn tlb_accesses(&self) -> u64 {
        self.cores.iter().map(|b| b.tlb.hits + b.tlb.misses).sum()
    }

    pub(crate) fn dram_totals(&self) -> (u64, u64, u64, u64, f64) {
        let (mut reads, mut writes, mut rbytes, mut wbytes) = (0, 0, 0, 0);
        let (mut hits, mut total) = (0u64, 0u64);
        for g in &self.groups {
            for c in &g.channels {
                reads += c.stats.reads;
                writes += c.stats.writes;
                rbytes += c.stats.read_bytes;
                wbytes += c.stats.write_bytes;
                hits += c.stats.row_hits;
                total += c.stats.row_hits + c.stats.row_misses + c.stats.row_conflicts;
            }
        }
        let hit_rate = if total == 0 { 0.0 } else { hits as f64 / total as f64 };
        (reads, writes, rbytes, wbytes, hit_rate)
    }

    pub(crate) fn mlp_meter(&self) -> &LevelMeter {
        &self.mlp
    }

    pub(crate) fn now(&self) -> Ps {
        self.now
    }

    pub(crate) fn engine_stats(&self) -> super::engine::EngineStats {
        self.events.stats()
    }

    pub(crate) fn mec_refs(&self) -> &[Mec1] {
        self.router.mecs()
    }

    pub(crate) fn pcie_ref(&self) -> Option<&crate::baselines::PcieSwap> {
        self.router.pcie()
    }

    /// AMU queue statistics (zeros for every other backend).
    pub(crate) fn amu_stats(&self) -> AmuStats {
        self.router.amu().map(|u| u.stats).unwrap_or_default()
    }

    /// MIMS packing/framing statistics (zeros for every other backend).
    pub(crate) fn mims_stats(&self) -> MimsStats {
        self.router.mims().map(|u| u.stats).unwrap_or_default()
    }

    /// Platform-side fault/recovery accounting (MEC fill faults are
    /// counted by the chips; report.rs sums both).
    pub(crate) fn fault_stats(&self) -> &FaultStats {
        &self.fault_stats
    }

    /// Health/quarantine totals (zeros when the tracker isn't armed).
    /// Still-open quarantine intervals are closed at the current time.
    pub(crate) fn health_totals(&self) -> HealthTotals {
        self.health.as_ref().map(|h| h.totals(self.now)).unwrap_or_default()
    }

    /// Channel-bus totals over every controller: (commands issued,
    /// mean data-bus utilization over `[0, now]`).
    pub(crate) fn bus_totals(&self) -> (u64, f64) {
        let (mut cmds, mut util_sum, mut n) = (0u64, 0.0f64, 0u32);
        for g in &self.groups {
            for c in &g.channels {
                let (cc, _) = c.bus_counts();
                cmds += cc;
                util_sum += c.data_bus_util(self.now);
                n += 1;
            }
        }
        (cmds, if n == 0 { 0.0 } else { util_sum / n as f64 })
    }

    /// Pooled SMARTS sampling data across all hardware threads:
    /// (completed windows, detailed ops, per-window ns-per-op samples,
    /// per-window IPC samples). Everything zero/empty when sampling is
    /// off. Cores pool in index order, so the sample vectors — and the
    /// CIs computed from them — are deterministic.
    pub(crate) fn sample_pool(&self) -> (u64, u64, Vec<f64>, Vec<f64>) {
        let (mut windows, mut dops) = (0u64, 0u64);
        let (mut ns, mut ipc) = (Vec::new(), Vec::new());
        for b in &self.cores {
            if let Some(s) = &b.sampler {
                windows += s.windows();
                dops += s.detailed_ops;
                ns.extend_from_slice(&s.ns_per_op);
                ipc.extend_from_slice(&s.ipc);
            }
        }
        (windows, dops, ns, ipc)
    }

    /// Pump batches phase 1 actually ran on the shard pool (0 for the
    /// single-thread engines; a diagnostic, deliberately excluded from
    /// the equivalence fingerprints — it depends on the host).
    pub(crate) fn parallel_pumps(&self) -> u64 {
        self.parallel_pumps
    }
}
