//! Conservative-parallel controller pumping for [`EngineKind::Sharded`].
//!
//! The platform partitions each channel group's controllers into worker
//! shards. A `Pump { group }` event is executed in two phases:
//!
//! 1. **Pump phase (parallel under `Sharded`)** — every channel's
//!    [`MemController::pump`] runs against its own result buffer. A
//!    controller pump touches only that controller's state, so distinct
//!    channels are data-independent by construction.
//! 2. **Apply phase (always serial, channel order)** — service results
//!    are folded into the shared platform state (backend observation,
//!    fault draws, deliveries, prefetch fills) exactly as the
//!    single-thread engines do.
//!
//! The conservative lookahead window that makes phase 1 safe is the
//! minimum cross-shard latency: every cross-channel consequence of a
//! serviced transaction (a writeback, a delivery, a prefetch fill)
//! re-enters the calendar queue at least `llc_lat` plus the backend's
//! egress floor *after* the pump instant, so no phase-1 pump at time
//! `t` can observe work another shard produces at `t`. Phase 2 applies
//! those consequences in deterministic channel order, which is why the
//! `sharded-equivalence` differential proptest can demand bit-identical
//! `SimReport`s against the serial calendar engine.
//!
//! [`EngineKind::Sharded`]: super::engine::EngineKind::Sharded
//! [`MemController::pump`]: crate::dram::MemController::pump

use crate::dram::{MemController, ServiceResult};
use crate::util::time::Ps;
use std::sync::{Arc, Condvar, Mutex, MutexGuard, PoisonError};
use std::thread::JoinHandle;

/// One channel pump: raw pointers into the platform's controller and
/// per-channel buffer slots. The dispatcher guarantees every job in a
/// batch targets a distinct channel index and blocks until the whole
/// batch completes, so the pointers are exclusive and live for the
/// duration of the job.
pub(crate) struct PumpJob {
    pub mc: *mut MemController,
    pub now: Ps,
    pub out: *mut Vec<ServiceResult>,
    pub wake: *mut Option<Ps>,
}

// Safety: jobs are only created by `Platform::pump_group` over disjoint
// channel/buffer slots, and `ShardPool::run` joins the batch before
// returning, so no pointer outlives the exclusive borrow it came from.
unsafe impl Send for PumpJob {}

impl PumpJob {
    /// Safety: the caller guarantees exclusive access to all three
    /// targets until the owning dispatch returns.
    unsafe fn run(&self) {
        let out = &mut *self.out;
        out.clear();
        *self.wake = (*self.mc).pump(self.now, out);
    }
}

struct PoolState {
    jobs: Vec<PumpJob>,
    /// Jobs handed in but not yet finished (queued + running).
    outstanding: usize,
    shutdown: bool,
}

struct PoolShared {
    state: Mutex<PoolState>,
    /// Workers wait here for jobs.
    work: Condvar,
    /// The dispatcher waits here for batch completion.
    done: Condvar,
}

/// Recover the guard from a poisoned lock: pool state is a plain job
/// queue plus counters, valid at every instruction boundary, so a
/// panicking peer (impossible in practice — `pump` is straight-line
/// arithmetic) must not wedge every later simulation in the process.
fn lock(m: &Mutex<PoolState>) -> MutexGuard<'_, PoolState> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Persistent worker pool for the sharded engine. One pool lives for
/// one `Platform`; workers park on a condvar between pump batches, so
/// the steady-state dispatch cost is two lock round-trips per batch,
/// not a thread spawn.
pub(crate) struct ShardPool {
    shared: Arc<PoolShared>,
    workers: Vec<JoinHandle<()>>,
}

impl ShardPool {
    /// Spawn `extra_workers` parked worker threads. The dispatching
    /// thread participates in every batch, so total pump parallelism is
    /// `extra_workers + 1`.
    pub(crate) fn new(extra_workers: usize) -> ShardPool {
        let shared = Arc::new(PoolShared {
            state: Mutex::new(PoolState { jobs: Vec::new(), outstanding: 0, shutdown: false }),
            work: Condvar::new(),
            done: Condvar::new(),
        });
        let workers = (0..extra_workers)
            .map(|_| {
                let sh = Arc::clone(&shared);
                std::thread::spawn(move || worker_loop(&sh))
            })
            .collect();
        ShardPool { shared, workers }
    }

    pub(crate) fn extra_workers(&self) -> usize {
        self.workers.len()
    }

    /// Run one batch of channel pumps to completion. The calling thread
    /// steals jobs alongside the workers and only returns once every
    /// job has finished (the raw-pointer liveness contract).
    pub(crate) fn run(&self, jobs: Vec<PumpJob>) {
        {
            let mut st = lock(&self.shared.state);
            st.outstanding += jobs.len();
            st.jobs.extend(jobs);
        }
        self.shared.work.notify_all();
        loop {
            let job = {
                let mut st = lock(&self.shared.state);
                match st.jobs.pop() {
                    Some(j) => j,
                    None => {
                        while st.outstanding > 0 {
                            st = self
                                .shared
                                .done
                                .wait(st)
                                .unwrap_or_else(PoisonError::into_inner);
                        }
                        return;
                    }
                }
            };
            unsafe { job.run() };
            let mut st = lock(&self.shared.state);
            st.outstanding -= 1;
            if st.outstanding == 0 {
                self.shared.done.notify_all();
            }
        }
    }
}

fn worker_loop(sh: &PoolShared) {
    loop {
        let job = {
            let mut st = lock(&sh.state);
            loop {
                if st.shutdown {
                    return;
                }
                if let Some(j) = st.jobs.pop() {
                    break j;
                }
                st = sh.work.wait(st).unwrap_or_else(PoisonError::into_inner);
            }
        };
        unsafe { job.run() };
        let mut st = lock(&sh.state);
        st.outstanding -= 1;
        if st.outstanding == 0 {
            sh.done.notify_all();
        }
    }
}

impl Drop for ShardPool {
    fn drop(&mut self) {
        lock(&self.shared.state).shutdown = true;
        self.shared.work.notify_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

/// The budget arithmetic: with `sweep_threads` simulations running
/// concurrently on `host_threads` hardware threads, each simulation may
/// use at most `host / sweep` shards (floor, at least 1 — a sharded
/// platform degrades to serial pumping rather than failing). The sweep
/// runner lowers each job's [`RunSpec::shard_cap`] to this budget so
/// sweep fan-out times per-platform shards cannot oversubscribe the
/// host.
///
/// [`RunSpec::shard_cap`]: crate::config::RunSpec::shard_cap
pub fn shard_budget(host_threads: usize, sweep_threads: usize) -> usize {
    (host_threads / sweep_threads.max(1)).max(1)
}

/// Shards a platform with `max_channels` controllers on its widest
/// group may use: bounded by the channel count (more shards than
/// channels is pure overhead), the spec's shard cap (lowered by the
/// sweep runner's thread budget), and the host's hardware threads.
/// The plan only sizes the worker pool — it cannot affect simulated
/// results, so depending on host parallelism here is safe.
pub(crate) fn plan_shards(max_channels: usize, cap: usize) -> usize {
    let host = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    max_channels.max(1).min(cap.max(1)).min(host)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;
    use crate::dram::{AddressMapping, Transaction};

    #[test]
    fn budget_arithmetic_never_oversubscribes() {
        // sweep_threads concurrent sims x budget shards each <= host.
        for host in 1..=64usize {
            for sweep in 1..=16usize {
                let per_sim = shard_budget(host, sweep);
                assert!(per_sim >= 1, "budget must keep sharded runs alive");
                if per_sim > 1 {
                    assert!(
                        per_sim * sweep <= host,
                        "host={host} sweep={sweep} budget={per_sim} oversubscribes"
                    );
                }
            }
        }
        // Degenerate inputs clamp instead of dividing by zero.
        assert_eq!(shard_budget(8, 0), 8);
        assert_eq!(shard_budget(0, 4), 1);
    }

    #[test]
    fn plan_is_bounded_by_channels_cap_and_host() {
        let host = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        assert_eq!(plan_shards(0, usize::MAX), 1);
        assert_eq!(plan_shards(1, usize::MAX), 1);
        assert!(plan_shards(2, usize::MAX) <= 2);
        assert!(plan_shards(64, 3) <= 3, "plan must honor the spec cap");
        assert_eq!(plan_shards(64, 0), 1, "a zero cap clamps to serial, not zero shards");
        assert!(plan_shards(1024, usize::MAX) <= host);
    }

    #[test]
    fn pool_runs_batches_and_shuts_down() {
        // Drive real controller pumps through the pool and compare
        // against serial pumps of identically-loaded controllers.
        let cfg = SystemConfig::ideal();
        let geo = cfg.local_channel_geometry();
        let map = AddressMapping::new(&geo, 1);
        let build = || {
            let mut mcs: Vec<MemController> = (0..4)
                .map(|_| MemController::with_policy(cfg.host_timing, geo, cfg.sched))
                .collect();
            for (ci, mc) in mcs.iter_mut().enumerate() {
                for i in 0..8u64 {
                    mc.enqueue(Transaction {
                        id: (ci as u64) << 32 | i,
                        addr: map.decode((i * 7 + ci as u64) * 64),
                        is_write: i % 3 == 0,
                        arrive: 0,
                    });
                }
            }
            mcs
        };
        let mut serial = build();
        let mut serial_out: Vec<(Vec<ServiceResult>, Option<Ps>)> = Vec::new();
        for mc in serial.iter_mut() {
            let mut buf = Vec::new();
            let wake = mc.pump(1_000_000, &mut buf);
            serial_out.push((buf, wake));
        }

        let mut pooled = build();
        let mut bufs: Vec<Vec<ServiceResult>> = vec![Vec::new(); 4];
        let mut wakes: Vec<Option<Ps>> = vec![None; 4];
        let pool = ShardPool::new(2);
        assert_eq!(pool.extra_workers(), 2);
        let jobs: Vec<PumpJob> = (0..4)
            .map(|ch| PumpJob {
                mc: &mut pooled[ch] as *mut MemController,
                now: 1_000_000,
                out: &mut bufs[ch] as *mut Vec<ServiceResult>,
                wake: &mut wakes[ch] as *mut Option<Ps>,
            })
            .collect();
        pool.run(jobs);
        for ch in 0..4 {
            assert_eq!(wakes[ch], serial_out[ch].1, "channel {ch} wake diverged");
            assert_eq!(
                bufs[ch].len(),
                serial_out[ch].0.len(),
                "channel {ch} result count diverged"
            );
            for (a, b) in bufs[ch].iter().zip(&serial_out[ch].0) {
                assert_eq!(a.id, b.id, "channel {ch} service order diverged");
                assert_eq!(a.data_end, b.data_end, "channel {ch} timing diverged");
            }
        }
        drop(pool); // must join cleanly with parked workers
    }
}
