//! Extended-memory management (paper §4.2, Figure 4).
//!
//! The paper reserves the extended and shadow physical ranges from the OS
//! at boot and hands out large blocks (64 MB) via `mmap()`, mapping each
//! object at virtual address `p` with its shadow at `p + EXT_MEM_SIZE`.
//! This module reproduces that manager: a three-region virtual layout
//! (local / extended / shadow), a power-of-two block allocator for the
//! extended space, and the shadow-address arithmetic used by the protocol
//! transform.
//!
//! Simulated addresses are identity-mapped (VA == PA) — the paper's
//! manager also constructs direct mappings at block granularity, so the
//! TLB and row/bank behaviour are equivalent; the page table exists for
//! allocation bookkeeping, not for indirection.

pub mod alloc;

pub use alloc::{Allocator, Region, Space};

/// Virtual/physical layout: `[0, local)` local DRAM, `[local, local+ext)`
/// extended memory, `[local+ext, local+2·ext)` shadow (no real storage).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MemLayout {
    pub local_size: u64,
    pub ext_size: u64,
}

impl MemLayout {
    pub fn new(local_size: u64, ext_size: u64) -> MemLayout {
        assert!(local_size.is_power_of_two(), "local size must be 2^n");
        assert!(ext_size.is_power_of_two(), "ext size must be 2^n");
        MemLayout { local_size, ext_size }
    }

    /// The paper's host ratio (8 GB local : 24 GB extended) scaled 64×
    /// down: 128 MiB local, 256 MiB extended (large footprint ≈ 256 MiB).
    pub fn sim_default() -> MemLayout {
        MemLayout::new(128 << 20, 256 << 20)
    }

    #[inline]
    pub fn ext_base(&self) -> u64 {
        self.local_size
    }

    #[inline]
    pub fn shadow_base(&self) -> u64 {
        self.local_size + self.ext_size
    }

    #[inline]
    pub fn total_span(&self) -> u64 {
        self.local_size + 2 * self.ext_size
    }

    #[inline]
    pub fn is_local(&self, va: u64) -> bool {
        va < self.local_size
    }

    #[inline]
    pub fn is_extended(&self, va: u64) -> bool {
        va >= self.ext_base() && va < self.shadow_base()
    }

    #[inline]
    pub fn is_shadow(&self, va: u64) -> bool {
        va >= self.shadow_base() && va < self.total_span()
    }

    /// Shadow twin of an extended address: `p + EXT_MEM_SIZE` (§4.2).
    #[inline]
    pub fn shadow_of(&self, va: u64) -> u64 {
        debug_assert!(self.is_extended(va), "shadow_of on non-extended address {va:#x}");
        va + self.ext_size
    }

    /// Inverse of [`Self::shadow_of`].
    #[inline]
    pub fn extended_of(&self, va: u64) -> u64 {
        debug_assert!(self.is_shadow(va));
        va - self.ext_size
    }

    /// Offset within the extended channel's physical space for an
    /// extended *or* shadow address; the shadow bit (MSB of that space)
    /// survives, which is what the host memory controller row-decodes.
    #[inline]
    pub fn ext_channel_offset(&self, va: u64) -> u64 {
        debug_assert!(va >= self.ext_base() && va < self.total_span());
        va - self.ext_base()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layout() -> MemLayout {
        MemLayout::new(128 << 20, 256 << 20)
    }

    #[test]
    fn region_classification() {
        let l = layout();
        assert!(l.is_local(0));
        assert!(l.is_local(l.local_size - 1));
        assert!(l.is_extended(l.ext_base()));
        assert!(l.is_extended(l.shadow_base() - 1));
        assert!(l.is_shadow(l.shadow_base()));
        assert!(l.is_shadow(l.total_span() - 1));
    }

    #[test]
    fn shadow_roundtrip() {
        let l = layout();
        let p = l.ext_base() + 0x0234_0000;
        let s = l.shadow_of(p);
        assert!(l.is_shadow(s));
        assert_eq!(l.extended_of(s), p);
        assert_eq!(s - p, l.ext_size, "shadow distance is EXT_MEM_SIZE");
    }

    #[test]
    fn channel_offset_preserves_shadow_bit() {
        let l = layout();
        let p = l.ext_base() + 0x40;
        let s = l.shadow_of(p);
        let po = l.ext_channel_offset(p);
        let so = l.ext_channel_offset(s);
        // Offsets differ exactly in the MSB of the 2·ext space.
        assert_eq!(po ^ so, l.ext_size);
    }

    #[test]
    #[should_panic]
    fn non_pow2_rejected() {
        MemLayout::new(100 << 20, 256 << 20);
    }
}
