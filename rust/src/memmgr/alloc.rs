//! Block allocator for local / extended memory (§4.2).
//!
//! "To simplify memory management, we allocate/deallocate extended and
//! shadow memory together in large blocks (e.g., 64MB)" — big-memory
//! applications allocate almost everything at initialization, so a simple
//! block cursor + free list suffices (no fragmentation-minimizing
//! machinery, as the paper argues).

use super::MemLayout;

/// Which space an allocation lives in.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Space {
    Local,
    Extended,
}

/// An allocated virtual region.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Region {
    pub base: u64,
    pub len: u64,
    pub space: Space,
}

impl Region {
    #[inline]
    pub fn contains(&self, va: u64) -> bool {
        va >= self.base && va < self.base + self.len
    }

    /// Address of byte `i` within the region (panics in debug if OOB).
    #[inline]
    pub fn at(&self, i: u64) -> u64 {
        debug_assert!(i < self.len, "region index {i} out of {len}", len = self.len);
        self.base + i
    }
}

/// Block-granular allocator over a [`MemLayout`]. Extended allocations
/// implicitly reserve the shadow twin block (same index, +EXT_MEM_SIZE),
/// mirroring the paper's paired `mmap()` calls.
#[derive(Debug, Clone)]
pub struct Allocator {
    layout: MemLayout,
    block: u64,
    local_free: Vec<u64>,
    ext_free: Vec<u64>,
    local_cursor: u64,
    ext_cursor: u64,
    pub allocated_local: u64,
    pub allocated_ext: u64,
}

/// Default block size: the paper uses 64 MB at full scale; scaled 64× down
/// that is 1 MiB.
pub const SIM_BLOCK: u64 = 1 << 20;

impl Allocator {
    pub fn new(layout: MemLayout, block: u64) -> Allocator {
        assert!(block.is_power_of_two());
        Allocator {
            layout,
            block,
            local_free: Vec::new(),
            ext_free: Vec::new(),
            local_cursor: 0,
            ext_cursor: layout.ext_base(),
            allocated_local: 0,
            allocated_ext: 0,
        }
    }

    pub fn layout(&self) -> &MemLayout {
        &self.layout
    }

    fn blocks_for(&self, bytes: u64) -> u64 {
        crate::util::div_ceil(bytes.max(1), self.block)
    }

    /// Allocate `bytes` (rounded up to whole blocks) in `space`.
    /// Returns `None` when the space is exhausted.
    pub fn alloc(&mut self, space: Space, bytes: u64) -> Option<Region> {
        let nblocks = self.blocks_for(bytes);
        let len = nblocks * self.block;
        match space {
            Space::Local => {
                // Try the free list for a single-block request first.
                if nblocks == 1 {
                    if let Some(base) = self.local_free.pop() {
                        self.allocated_local += len;
                        return Some(Region { base, len, space });
                    }
                }
                if self.local_cursor + len > self.layout.local_size {
                    return None;
                }
                let base = self.local_cursor;
                self.local_cursor += len;
                self.allocated_local += len;
                Some(Region { base, len, space })
            }
            Space::Extended => {
                if nblocks == 1 {
                    if let Some(base) = self.ext_free.pop() {
                        self.allocated_ext += len;
                        return Some(Region { base, len, space });
                    }
                }
                if self.ext_cursor + len > self.layout.shadow_base() {
                    return None;
                }
                let base = self.ext_cursor;
                self.ext_cursor += len;
                self.allocated_ext += len;
                Some(Region { base, len, space })
            }
        }
    }

    /// Return a region's blocks to the allocator.
    pub fn free(&mut self, region: Region) {
        let list = match region.space {
            Space::Local => {
                self.allocated_local = self.allocated_local.saturating_sub(region.len);
                &mut self.local_free
            }
            Space::Extended => {
                self.allocated_ext = self.allocated_ext.saturating_sub(region.len);
                &mut self.ext_free
            }
        };
        let mut base = region.base;
        while base < region.base + region.len {
            list.push(base);
            base += self.block;
        }
    }

    /// Fraction of requested data placed in extended memory so far —
    /// the "Proportion in extended memory" column of Table 4.
    pub fn ext_fraction(&self) -> f64 {
        let total = self.allocated_local + self.allocated_ext;
        if total == 0 {
            0.0
        } else {
            self.allocated_ext as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn alloc() -> Allocator {
        Allocator::new(MemLayout::new(16 << 20, 64 << 20), SIM_BLOCK)
    }

    #[test]
    fn local_and_ext_disjoint() {
        let mut a = alloc();
        let l = a.alloc(Space::Local, 3 << 20).unwrap();
        let e = a.alloc(Space::Extended, 3 << 20).unwrap();
        assert!(a.layout().is_local(l.base));
        assert!(a.layout().is_local(l.base + l.len - 1));
        assert!(a.layout().is_extended(e.base));
        assert!(a.layout().is_extended(e.base + e.len - 1));
    }

    #[test]
    fn rounds_to_blocks() {
        let mut a = alloc();
        let r = a.alloc(Space::Local, 1).unwrap();
        assert_eq!(r.len, SIM_BLOCK);
    }

    #[test]
    fn exhaustion_returns_none() {
        let mut a = alloc();
        assert!(a.alloc(Space::Local, 16 << 20).is_some());
        assert!(a.alloc(Space::Local, 1).is_none());
    }

    #[test]
    fn free_then_realloc_reuses() {
        let mut a = alloc();
        let r = a.alloc(Space::Extended, SIM_BLOCK).unwrap();
        let base = r.base;
        a.free(r);
        let r2 = a.alloc(Space::Extended, SIM_BLOCK).unwrap();
        assert_eq!(r2.base, base);
    }

    #[test]
    fn ext_fraction_tracks_table4_style() {
        let mut a = alloc();
        a.alloc(Space::Local, 1 << 20).unwrap();
        a.alloc(Space::Extended, 3 << 20).unwrap();
        assert!((a.ext_fraction() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn shadow_never_allocated_directly() {
        let mut a = alloc();
        // Fill extended completely; every region stays below shadow_base.
        while let Some(r) = a.alloc(Space::Extended, 8 << 20) {
            assert!(r.base + r.len <= a.layout().shadow_base());
        }
    }

    #[test]
    fn region_helpers() {
        let r = Region { base: 0x1000, len: 0x100, space: Space::Local };
        assert!(r.contains(0x10ff));
        assert!(!r.contains(0x1100));
        assert_eq!(r.at(0x40), 0x1040);
    }
}
