//! Cost / TCO model (paper §7.1, Table 5, Figure 14).
//!
//! Components and assumptions follow the paper exactly: three-year
//! amortization, server power at 8 % of TCO for mid-end servers, "other
//! costs" (capital + opex) from Barroso & Hölzle, Intel/Amazon list
//! prices circa 2014. The model reproduces the paper's two headline
//! claims analytically: TL beats NUMA on perf/$ by ≈7 %, and beats
//! Cluster whenever parallel efficiency is below ≈60 %.

use crate::stats::Table;

/// One system's bill of materials (per-year costs in dollars).
#[derive(Debug, Clone, Copy)]
pub struct SystemCost {
    pub name: &'static str,
    pub processors: f64,
    pub memory: f64,
    pub motherboard_disk: f64,
    pub mec: f64,
    pub power: f64,
    pub other: f64,
    /// Peak speedup factor relative to baseline (×x for doubled memory).
    pub potential_speedup: f64,
    /// Correction factor c (mechanism overhead; §7.1 performance model).
    pub correction: f64,
}

impl SystemCost {
    pub fn total(&self) -> f64 {
        self.processors + self.memory + self.motherboard_disk + self.mec + self.power + self.other
    }

    /// Performance per dollar in units of `x/$` (the paper's Figure 14
    /// y-axis before normalization). `efficiency` scales mechanisms that
    /// depend on parallelization quality (NUMA c₂ / Cluster c).
    pub fn perf_per_dollar(&self, efficiency: f64) -> f64 {
        self.potential_speedup * self.correction * efficiency / self.total()
    }
}

/// Paper Table 5 constants (three-year amortization where marked).
pub mod prices {
    pub const XEON_E5_2650V2: f64 = 1166.0;
    pub const XEON_E5_4650V2: f64 = 3616.0;
    pub const RDIMM_16GB: f64 = 175.0;
    pub const MOTHERBOARD_DISK: f64 = 1000.0;
    pub const MEC: f64 = 100.0;
    pub const SERVER_POWER: f64 = 252.0;
    pub const OTHER: f64 = 1325.0;
    pub const YEARS: f64 = 3.0;
}

/// The four Table-5 systems. `x` is the memory-doubling speedup factor
/// (cancels in relative comparisons; kept explicit for absolute output).
pub fn table5_systems() -> [SystemCost; 4] {
    use prices::*;
    [
        SystemCost {
            name: "Baseline",
            processors: 2.0 * XEON_E5_2650V2 / YEARS,
            memory: 8.0 * RDIMM_16GB / YEARS,
            motherboard_disk: MOTHERBOARD_DISK / YEARS,
            mec: 0.0,
            power: SERVER_POWER,
            other: OTHER,
            potential_speedup: 1.0,
            correction: 1.0,
        },
        SystemCost {
            name: "TL-OoO",
            processors: 2.0 * XEON_E5_2650V2 / YEARS,
            memory: 16.0 * RDIMM_16GB / YEARS,
            motherboard_disk: MOTHERBOARD_DISK / YEARS,
            mec: 8.0 * MEC / YEARS,
            power: 1.3 * SERVER_POWER,
            other: OTHER,
            potential_speedup: 1.0, // ×x
            correction: 0.74,       // §6: TL-OoO at 74 % of Ideal
        },
        SystemCost {
            name: "NUMA",
            processors: 4.0 * XEON_E5_4650V2 / YEARS,
            memory: 16.0 * RDIMM_16GB / YEARS,
            motherboard_disk: 1.5 * MOTHERBOARD_DISK / YEARS,
            mec: 0.0,
            power: 1.8 * SERVER_POWER,
            other: 1.5 * OTHER,
            potential_speedup: 2.0, // ×2x (more processors too)
            correction: 0.76,       // c₁; c₂ (parallel efficiency) varies
        },
        SystemCost {
            name: "Cluster",
            processors: 4.0 * XEON_E5_2650V2 / YEARS,
            memory: 16.0 * RDIMM_16GB / YEARS,
            motherboard_disk: 2.0 * MOTHERBOARD_DISK / YEARS,
            mec: 0.0,
            power: 2.0 * SERVER_POWER,
            other: 2.0 * OTHER,
            potential_speedup: 2.0,
            correction: 1.0, // c = parallel efficiency, varies
        },
    ]
}

/// Render Table 5.
pub fn table5() -> Table {
    let mut t = Table::new(
        "Table 5: Costs of various memory extension mechanisms ($/year)",
        &["Component", "Baseline", "TL-OoO", "NUMA", "Cluster"],
    );
    let systems = table5_systems();
    let row = |label: &str, f: &dyn Fn(&SystemCost) -> f64| -> Vec<String> {
        let mut cells = vec![label.to_string()];
        cells.extend(systems.iter().map(|s| format!("{:.0}", f(s))));
        cells
    };
    t.row(&row("Processor", &|s| s.processors));
    t.row(&row("Memory", &|s| s.memory));
    t.row(&row("Motherboard+Disk", &|s| s.motherboard_disk));
    t.row(&row("MEC", &|s| s.mec));
    t.row(&row("Server power", &|s| s.power));
    t.row(&row("Other costs", &|s| s.other));
    t.row(&row("Total", &|s| s.total()));
    t
}

/// Figure 14: performance-per-dollar (normalized to TL-OoO) as parallel
/// efficiency sweeps 0→1. Returns rows of
/// `(efficiency, tl_norm, numa_norm, cluster_norm)`.
pub fn fig14_series(points: usize) -> Vec<(f64, f64, f64, f64)> {
    let systems = table5_systems();
    let tl = systems[1].perf_per_dollar(1.0);
    (0..=points)
        .map(|i| {
            let eff = i as f64 / points as f64;
            (
                eff,
                1.0,
                systems[2].perf_per_dollar(eff) / tl,
                systems[3].perf_per_dollar(eff) / tl,
            )
        })
        .collect()
}

/// The crossover efficiency where Cluster matches TL (paper: ≈60 %).
pub fn cluster_crossover() -> f64 {
    let systems = table5_systems();
    let tl = systems[1].perf_per_dollar(1.0);
    // eff such that cluster(eff) == tl.
    tl * systems[3].total() / (systems[3].potential_speedup * systems[3].correction)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn totals_match_paper_table5() {
        let s = table5_systems();
        assert!((s[0].total() - 3154.0).abs() < 10.0, "baseline {}", s[0].total());
        assert!((s[1].total() - 3963.0).abs() < 10.0, "tl {}", s[1].total());
        assert!((s[2].total() - 8696.0).abs() < 10.0, "numa {}", s[2].total());
        assert!((s[3].total() - 6308.0).abs() < 10.0, "cluster {}", s[3].total());
    }

    #[test]
    fn tl_beats_numa_by_about_7_percent() {
        let s = table5_systems();
        let tl = s[1].perf_per_dollar(1.0);
        let numa = s[2].perf_per_dollar(1.0); // best case for NUMA (c₂=1)
        let advantage = tl / numa - 1.0;
        assert!(
            (0.04..0.10).contains(&advantage),
            "TL vs NUMA perf/$ advantage = {advantage:.3} (paper: ≥7 %)"
        );
    }

    #[test]
    fn cluster_crossover_near_60_percent() {
        let x = cluster_crossover();
        assert!((0.55..0.65).contains(&x), "crossover {x:.3} (paper ≈0.6)");
    }

    #[test]
    fn fig14_series_monotone_in_efficiency() {
        let series = fig14_series(10);
        assert_eq!(series.len(), 11);
        for w in series.windows(2) {
            assert!(w[1].2 >= w[0].2);
            assert!(w[1].3 >= w[0].3);
        }
        // At eff=0 both parallel mechanisms deliver nothing.
        assert_eq!(series[0].2, 0.0);
        assert_eq!(series[0].3, 0.0);
    }

    #[test]
    fn table5_renders() {
        let t = table5();
        let s = t.render();
        assert!(s.contains("TL-OoO"));
        assert!(s.contains("Cluster"));
        assert_eq!(t.num_rows(), 7);
    }
}
