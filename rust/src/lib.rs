//! # twinload — a scalable memory system over the non-scalable interface
//!
//! Production-quality reproduction of *Twin-Load: Building a Scalable
//! Memory System over the Non-Scalable Interface* (Cui et al., 2015).
//!
//! The crate is a full platform simulator plus the paper's twin-load
//! protocol and all evaluated baselines:
//!
//! * [`dram`] — timestamp-algebra DDRx model (banks/ranks/channels,
//!   FR-FCFS controller, JEDEC Table-1 timing).
//! * [`cache`] — LLC / MSHR / TLB models.
//! * [`cpu`] — trace-driven out-of-order core model.
//! * [`mec`] — Memory Extending Chip: Bank State Table, Load Value Cache,
//!   tree topologies, propagation delay.
//! * [`twinload`] — the paper's contribution: TL-LF / TL-OoO access
//!   discipline, shadow addressing, CAS stores, retry and safe path.
//! * [`memmgr`] — extended-memory block allocator (§4.2).
//! * [`baselines`] — NUMA, PCIe page swapping, Ideal, increased-tRL.
//! * [`workloads`] — the ten Table-4 benchmark trace generators.
//! * [`sim`] — event-driven platform simulator producing Figure 7–13 stats.
//! * [`coordinator`] — experiment registry, parallel sweeps, PJRT fast path.
//! * [`runtime`] — loads AOT-compiled JAX/Pallas artifacts via PJRT.
//! * [`cost`] — Table-5 / Figure-14 cost model.

pub mod baselines;
pub mod cache;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod cost;
pub mod cpu;
pub mod dram;
pub mod mec;
pub mod memmgr;
pub mod runtime;
pub mod sim;
pub mod stats;
pub mod testing;
pub mod twinload;
pub mod util;
pub mod workloads;
