//! Deterministic pseudo-random number generation.
//!
//! xoshiro256** seeded via SplitMix64 — the same construction the reference
//! implementations recommend. Good statistical quality for workload
//! generation; *not* cryptographic.

/// SplitMix64 step: used for seeding and for cheap stateless hashing.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Stateless 64-bit mix of a value (useful for hashing addresses to banks
/// in synthetic workloads without carrying a generator).
#[inline]
pub fn mix64(v: u64) -> u64 {
    let mut s = v;
    splitmix64(&mut s)
}

/// xoshiro256** generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream for a subcomponent (`label` decorrelates
    /// streams drawn from the same master seed).
    pub fn fork(&mut self, label: u64) -> Rng {
        Rng::new(self.next_u64() ^ mix64(label))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, bound)` via Lemire's multiply-shift (unbiased enough
    /// for simulation purposes; exact rejection for small bounds).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        // 128-bit multiply avoids modulo bias to ~2^-64.
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo < hi);
        lo + self.below(hi - lo)
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Geometric-ish burst length: number of successes with continue
    /// probability `p`, capped at `max`.
    pub fn burst(&mut self, p: f64, max: u64) -> u64 {
        let mut n = 1;
        while n < max && self.chance(p) {
            n += 1;
        }
        n
    }

    /// Approximate Zipf(theta) sample over `[0, n)` using the inverse-CDF
    /// power approximation — adequate for skewed key popularity modeling
    /// (memcached) without a full Zipfian rejection sampler.
    pub fn zipf(&mut self, n: u64, theta: f64) -> u64 {
        debug_assert!(n > 0);
        if theta <= 0.0 {
            return self.below(n);
        }
        let u = self.f64().max(1e-12);
        let exp = 1.0 / (1.0 - theta.min(0.99));
        let v = (n as f64) * u.powf(exp) / (n as f64).powf(exp - 1.0);
        (v as u64).min(n - 1)
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn below_in_bounds() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            assert!(r.below(13) < 13);
        }
    }

    #[test]
    fn f64_unit_interval_and_roughly_uniform() {
        let mut r = Rng::new(9);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn zipf_is_skewed_toward_small_ids() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let hot = (0..n).filter(|_| r.zipf(1000, 0.9) < 100).count();
        // With theta=0.9, the first decile should receive far more than 10%.
        assert!(hot as f64 / n as f64 > 0.3, "hot fraction {}", hot as f64 / n as f64);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_decorrelated() {
        let mut master = Rng::new(5);
        let mut a = master.fork(1);
        let mut b = master.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn burst_bounds() {
        let mut r = Rng::new(17);
        for _ in 0..1000 {
            let b = r.burst(0.9, 16);
            assert!((1..=16).contains(&b));
        }
    }
}
