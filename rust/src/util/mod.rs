//! Foundation utilities: deterministic PRNGs, time units, and small math
//! helpers shared by every layer of the simulator.
//!
//! The vendored registry has no `rand` crate, so we carry our own
//! SplitMix64 / xoshiro256** implementations (public-domain algorithms by
//! Vigna et al.). All simulation randomness flows through [`Rng`] so runs
//! are reproducible from a single seed.

pub mod fastmap;
pub mod rng;
pub mod time;

pub use fastmap::FastMap;
pub use rng::Rng;
pub use time::{Ps, CYCLE_800MHZ, GHZ, KHZ, MHZ, NS, US};

/// Integer ceiling division for unsigned quantities.
#[inline]
pub fn div_ceil(a: u64, b: u64) -> u64 {
    debug_assert!(b > 0);
    (a + b - 1) / b
}

/// Round `v` up to the next multiple of `align` (power of two not required).
#[inline]
pub fn round_up(v: u64, align: u64) -> u64 {
    debug_assert!(align > 0);
    div_ceil(v, align) * align
}

/// `log2` of a power-of-two `v`; panics in debug if `v` is not a power of two.
#[inline]
pub fn log2_exact(v: u64) -> u32 {
    debug_assert!(v.is_power_of_two(), "log2_exact({v}): not a power of two");
    v.trailing_zeros()
}

/// Population-weighted mean of `(value, weight)` pairs; 0.0 when empty.
pub fn weighted_mean(pairs: &[(f64, f64)]) -> f64 {
    let (num, den) = pairs
        .iter()
        .fold((0.0, 0.0), |(n, d), &(v, w)| (n + v * w, d + w));
    if den == 0.0 {
        0.0
    } else {
        num / den
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn div_ceil_basics() {
        assert_eq!(div_ceil(0, 4), 0);
        assert_eq!(div_ceil(1, 4), 1);
        assert_eq!(div_ceil(4, 4), 1);
        assert_eq!(div_ceil(5, 4), 2);
    }

    #[test]
    fn round_up_basics() {
        assert_eq!(round_up(0, 64), 0);
        assert_eq!(round_up(1, 64), 64);
        assert_eq!(round_up(64, 64), 64);
        assert_eq!(round_up(65, 64), 128);
    }

    #[test]
    fn log2_exact_powers() {
        assert_eq!(log2_exact(1), 0);
        assert_eq!(log2_exact(4096), 12);
    }

    #[test]
    fn weighted_mean_basics() {
        assert_eq!(weighted_mean(&[]), 0.0);
        let m = weighted_mean(&[(1.0, 1.0), (3.0, 3.0)]);
        assert!((m - 2.5).abs() < 1e-12);
    }
}
