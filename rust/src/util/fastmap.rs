//! Fast integer-keyed hash maps for the simulator hot path.
//!
//! std's default SipHash showed up as ~24 % of simulation time in `perf`
//! (EXPERIMENTS.md §Perf). Simulation keys are sequence numbers and line
//! addresses — not attacker-controlled — so a Fibonacci-multiply mixer is
//! both safe and ~5× faster here.

use std::collections::HashMap;
use std::hash::{BuildHasherDefault, Hasher};

/// Multiplicative hasher for integer keys (splitmix-style finalizer).
#[derive(Default)]
pub struct FastHasher {
    state: u64,
}

impl Hasher for FastHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.state
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        // Generic path (rare): fold bytes in u64 chunks.
        for chunk in bytes.chunks(8) {
            let mut buf = [0u8; 8];
            buf[..chunk.len()].copy_from_slice(chunk);
            self.write_u64(u64::from_le_bytes(buf));
        }
    }

    #[inline]
    fn write_u64(&mut self, v: u64) {
        let mut z = self.state ^ v;
        z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        self.state = z ^ (z >> 31);
    }

    #[inline]
    fn write_usize(&mut self, v: usize) {
        self.write_u64(v as u64);
    }
}

pub type FastBuild = BuildHasherDefault<FastHasher>;

/// Drop-in HashMap with the fast hasher.
pub type FastMap<K, V> = HashMap<K, V, FastBuild>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn behaves_like_a_map() {
        let mut m: FastMap<u64, u32> = FastMap::default();
        for i in 0..1000u64 {
            m.insert(i * 64, i as u32);
        }
        assert_eq!(m.len(), 1000);
        for i in 0..1000u64 {
            assert_eq!(m.get(&(i * 64)), Some(&(i as u32)));
        }
        assert_eq!(m.remove(&640), Some(10));
        assert_eq!(m.len(), 999);
    }

    #[test]
    fn distributes_sequential_keys() {
        // Sequential keys must not collide into few buckets: sanity-check
        // by hashing and counting distinct low bits.
        use std::hash::{BuildHasher, Hash};
        let b = FastBuild::default();
        let mut low = std::collections::HashSet::new();
        for i in 0..256u64 {
            let mut h = b.build_hasher();
            i.hash(&mut h);
            low.insert(h.finish() & 0xFF);
        }
        assert!(low.len() > 150, "poor dispersion: {}", low.len());
    }
}
