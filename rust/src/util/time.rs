//! Simulation time base.
//!
//! All timestamps are **picoseconds** in a `u64` (`Ps`). Picoseconds give
//! exact representation of every JEDEC parameter (e.g. tCK(DDR3-1600) =
//! 1250 ps, tCL = 13.75 ns = 13_750 ps) with headroom for ~213 days of
//! simulated time — far beyond any run we do.

/// Picosecond timestamp / duration.
pub type Ps = u64;

/// One nanosecond in `Ps`.
pub const NS: Ps = 1_000;
/// One microsecond in `Ps`.
pub const US: Ps = 1_000_000;
/// One millisecond in `Ps`.
pub const MS: Ps = 1_000_000_000;

/// Clock helper constants: period of common frequencies, in `Ps`.
pub const GHZ: Ps = 1_000; // 1 GHz -> 1000 ps period
pub const MHZ: Ps = 1_000_000; // 1 MHz -> 1e6 ps period
pub const KHZ: Ps = 1_000_000_000;

/// Period of the DDR3-1600 command clock (800 MHz).
pub const CYCLE_800MHZ: Ps = 1_250;

/// Convert a frequency in MHz to its period in `Ps`.
#[inline]
pub fn period_of_mhz(mhz: u64) -> Ps {
    debug_assert!(mhz > 0);
    MHZ / mhz
}

/// Convert picoseconds to (fractional) nanoseconds for reporting.
#[inline]
pub fn ps_to_ns(ps: Ps) -> f64 {
    ps as f64 / NS as f64
}

/// Convert picoseconds to seconds for bandwidth math.
#[inline]
pub fn ps_to_s(ps: Ps) -> f64 {
    ps as f64 * 1e-12
}

/// Bandwidth in GB/s given bytes moved over a `Ps` interval.
#[inline]
pub fn gbps(bytes: u64, interval: Ps) -> f64 {
    if interval == 0 {
        return 0.0;
    }
    bytes as f64 / ps_to_s(interval) / 1e9
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ddr3_1600_period() {
        assert_eq!(period_of_mhz(800), CYCLE_800MHZ);
    }

    #[test]
    fn jedec_params_representable() {
        // tCL = 13.75 ns must be exact in ps.
        let tcl = 13_750;
        assert_eq!(ps_to_ns(tcl), 13.75);
    }

    #[test]
    fn bandwidth_math() {
        // 64 bytes in 5 ns -> 12.8 GB/s (one DDR3-1600 burst).
        let bw = gbps(64, 5 * NS);
        assert!((bw - 12.8).abs() < 1e-9, "bw={bw}");
    }

    #[test]
    fn zero_interval_bandwidth_is_zero() {
        assert_eq!(gbps(100, 0), 0.0);
    }
}
