//! Physical-address ↔ DRAM-coordinate mapping.
//!
//! The paper's twin-load trick depends on the mapping: "memory controllers
//! generally use the most significant bit (MSB) of the physical address in
//! the row address, we choose it" (§4). The default layout here therefore
//! places the row field at the top of the physical address:
//!
//! ```text
//!   MSB                                              LSB
//!   | row | rank | bank | col | channel | offset(6) |
//! ```
//!
//! so that flipping the physical-address MSB flips the row MSB while
//! keeping channel/rank/bank/col identical — exactly the property TL-OoO
//! needs (shadow twin lands on the *same bank, different row* → forced row
//! miss → ≈35 ns spacing between the twins).

use super::timing::Geometry;
use crate::util::log2_exact;

/// Decoded DRAM coordinates of a physical address.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct DecodedAddr {
    pub channel: u32,
    pub rank: u32,
    pub bank: u32,
    pub row: u32,
    /// Column in cache-line (64 B) units.
    pub col: u32,
}

impl DecodedAddr {
    /// Flat bank id within the channel (rank-major).
    pub fn flat_bank(&self, banks_per_rank: u32) -> u32 {
        self.rank * banks_per_rank + self.bank
    }
}

/// Bit-slicing address mapping. Field widths derived from a [`Geometry`]
/// plus a channel count; all dimensions must be powers of two.
#[derive(Debug, Clone, Copy)]
pub struct AddressMapping {
    channel_bits: u32,
    col_bits: u32,
    bank_bits: u32,
    rank_bits: u32,
    row_bits: u32,
}

pub const LINE_BITS: u32 = 6; // 64-byte cache lines
pub const LINE_BYTES: u64 = 64;

impl AddressMapping {
    pub fn new(geo: &Geometry, channels: u32) -> AddressMapping {
        AddressMapping {
            channel_bits: log2_exact(channels as u64),
            col_bits: log2_exact(geo.cols_per_row as u64),
            bank_bits: log2_exact(geo.banks_per_rank as u64),
            rank_bits: log2_exact(geo.ranks as u64),
            row_bits: log2_exact(geo.rows_per_bank as u64),
        }
    }

    /// Total addressable bytes under this mapping.
    pub fn capacity(&self) -> u64 {
        1u64 << (self.channel_bits
            + self.col_bits
            + self.bank_bits
            + self.rank_bits
            + self.row_bits
            + LINE_BITS)
    }

    /// Number of address bits (above which the address is out of range).
    pub fn addr_bits(&self) -> u32 {
        self.channel_bits + self.col_bits + self.bank_bits + self.rank_bits + self.row_bits
            + LINE_BITS
    }

    /// The physical-address bit that is the row MSB — the bit MEC1 uses to
    /// distinguish extended vs shadow space (§4: "we choose the MSB").
    pub fn row_msb_bit(&self) -> u32 {
        self.addr_bits() - 1
    }

    /// Banks per rank under this mapping.
    pub fn banks_per_rank(&self) -> u32 {
        1 << self.bank_bits
    }

    /// Total (rank × bank) flat banks per channel.
    pub fn num_flat_banks(&self) -> u32 {
        1 << (self.bank_bits + self.rank_bits)
    }

    pub fn decode(&self, addr: u64) -> DecodedAddr {
        debug_assert!(
            addr < self.capacity(),
            "address {:#x} out of range (capacity {:#x})",
            addr,
            self.capacity()
        );
        let mut a = addr >> LINE_BITS;
        let take = |a: &mut u64, bits: u32| -> u32 {
            let v = (*a & ((1u64 << bits) - 1)) as u32;
            *a >>= bits;
            v
        };
        let mut a2 = a;
        let channel = take(&mut a2, self.channel_bits);
        a = a2;
        let col = take(&mut a, self.col_bits);
        let bank = take(&mut a, self.bank_bits);
        let rank = take(&mut a, self.rank_bits);
        let row = take(&mut a, self.row_bits);
        DecodedAddr { channel, rank, bank, row, col }
    }

    pub fn encode(&self, d: &DecodedAddr) -> u64 {
        let mut a: u64 = d.row as u64;
        a = (a << self.rank_bits) | d.rank as u64;
        a = (a << self.bank_bits) | d.bank as u64;
        a = (a << self.col_bits) | d.col as u64;
        a = (a << self.channel_bits) | d.channel as u64;
        a << LINE_BITS
    }

    /// Flip the row-MSB of a physical address — produce the shadow twin.
    pub fn twin(&self, addr: u64) -> u64 {
        addr ^ (1u64 << self.row_msb_bit())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn mapping() -> AddressMapping {
        AddressMapping::new(&Geometry::sim_small(), 2)
    }

    #[test]
    fn roundtrip_random_addresses() {
        let m = mapping();
        let mut rng = Rng::new(1234);
        for _ in 0..10_000 {
            let addr = rng.below(m.capacity()) & !(LINE_BYTES - 1);
            let d = m.decode(addr);
            assert_eq!(m.encode(&d), addr, "roundtrip failed for {addr:#x}");
        }
    }

    #[test]
    fn twin_same_bank_different_row_msb() {
        let m = mapping();
        let mut rng = Rng::new(99);
        for _ in 0..1_000 {
            let addr = rng.below(m.capacity() / 2) & !(LINE_BYTES - 1); // in "extended" half
            let t = m.twin(addr);
            let d = m.decode(addr);
            let dt = m.decode(t);
            assert_eq!(d.channel, dt.channel);
            assert_eq!(d.rank, dt.rank);
            assert_eq!(d.bank, dt.bank);
            assert_eq!(d.col, dt.col);
            assert_ne!(d.row, dt.row, "twin must differ in row");
            // specifically the row MSB
            let row_msb = 1u32 << (m.row_bits - 1);
            assert_eq!(d.row ^ dt.row, row_msb);
        }
    }

    #[test]
    fn twin_is_involution() {
        let m = mapping();
        let addr = 0x12340;
        assert_eq!(m.twin(m.twin(addr)), addr);
    }

    #[test]
    fn adjacent_lines_interleave_channels() {
        let m = mapping();
        let d0 = m.decode(0);
        let d1 = m.decode(64);
        assert_ne!(d0.channel, d1.channel, "line interleave across channels");
    }

    #[test]
    fn sequential_lines_same_row_hit_friendly() {
        // Lines 0 and 2 (same channel under 2-way interleave) should share a
        // row — open-page locality for streaming workloads.
        let m = mapping();
        let d0 = m.decode(0);
        let d2 = m.decode(128);
        assert_eq!(d0.channel, d2.channel);
        assert_eq!(d0.row, d2.row);
        assert_eq!(d0.bank, d2.bank);
        assert_eq!(d2.col, d0.col + 1);
    }

    #[test]
    fn capacity_matches_geometry() {
        let m = mapping();
        let g = Geometry::sim_small();
        assert_eq!(m.capacity(), g.capacity_bytes() * 2); // 2 channels
    }
}
