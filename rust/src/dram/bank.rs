//! Per-bank state machine with timestamp algebra.

use super::timing::TimingParams;
use crate::util::time::Ps;

/// One DRAM bank: the open row plus earliest-allowed issue times for each
/// command class. All constraints of paper Table 1 that are *intra-bank*
/// live here; rank- and channel-level constraints (tRRD, tFAW, tCCD, data
/// bus) are layered on top by `rank.rs` / `channel.rs`.
#[derive(Debug, Clone)]
pub struct Bank {
    open_row: Option<u32>,
    next_act: Ps,
    next_rd: Ps,
    next_wr: Ps,
    next_pre: Ps,
    /// Counters for row-buffer locality stats.
    pub row_hits: u64,
    pub row_misses: u64,
    pub row_conflicts: u64,
}

impl Bank {
    pub fn new() -> Bank {
        Bank {
            open_row: None,
            next_act: 0,
            next_rd: 0,
            next_wr: 0,
            next_pre: 0,
            row_hits: 0,
            row_misses: 0,
            row_conflicts: 0,
        }
    }

    #[inline]
    pub fn open_row(&self) -> Option<u32> {
        self.open_row
    }

    /// Is an access to `row` a row hit right now?
    #[inline]
    pub fn is_hit(&self, row: u32) -> bool {
        self.open_row == Some(row)
    }

    /// Earliest time an ACT could issue (intra-bank constraints only).
    #[inline]
    pub fn earliest_act(&self) -> Ps {
        self.next_act
    }

    /// Earliest time a RD to the open row could issue.
    #[inline]
    pub fn earliest_rd(&self) -> Ps {
        self.next_rd
    }

    #[inline]
    pub fn earliest_wr(&self) -> Ps {
        self.next_wr
    }

    #[inline]
    pub fn earliest_pre(&self) -> Ps {
        self.next_pre
    }

    /// Earliest column command of the given direction (intra-bank only).
    #[inline]
    pub fn earliest_col(&self, is_write: bool) -> Ps {
        if is_write {
            self.next_wr
        } else {
            self.next_rd
        }
    }

    /// Apply an ACT at `t` opening `row`.
    pub fn do_act(&mut self, t: Ps, row: u32, p: &TimingParams) {
        debug_assert!(t >= self.next_act, "ACT issued too early");
        debug_assert!(self.open_row.is_none(), "ACT to an open bank");
        self.open_row = Some(row);
        self.next_rd = self.next_rd.max(t + p.t_rcd);
        self.next_wr = self.next_wr.max(t + p.t_rcd);
        self.next_pre = self.next_pre.max(t + p.t_ras);
        self.next_act = self.next_act.max(t + p.t_rc);
    }

    /// Apply a RD at `t`; returns the time of the last data beat.
    pub fn do_rd(&mut self, t: Ps, p: &TimingParams) -> Ps {
        debug_assert!(t >= self.next_rd, "RD issued too early");
        debug_assert!(self.open_row.is_some(), "RD to a closed bank");
        self.next_pre = self.next_pre.max(t + p.t_rtp);
        // Same-bank RD-to-RD also spaced by tCCD (rank enforces cross-bank).
        self.next_rd = self.next_rd.max(t + p.t_ccd);
        self.next_wr = self.next_wr.max(t + p.t_ccd);
        t + p.t_rl + p.t_burst
    }

    /// Apply a WR at `t`; returns the time of the last data beat.
    pub fn do_wr(&mut self, t: Ps, p: &TimingParams) -> Ps {
        debug_assert!(t >= self.next_wr, "WR issued too early");
        debug_assert!(self.open_row.is_some(), "WR to a closed bank");
        let data_end = t + p.t_wl + p.t_burst;
        self.next_pre = self.next_pre.max(data_end + p.t_wr);
        self.next_rd = self.next_rd.max(t + p.t_ccd);
        self.next_wr = self.next_wr.max(t + p.t_ccd);
        data_end
    }

    /// Apply a PRE at `t`.
    pub fn do_pre(&mut self, t: Ps, p: &TimingParams) {
        debug_assert!(t >= self.next_pre, "PRE issued too early");
        self.open_row = None;
        self.next_act = self.next_act.max(t + p.t_rp);
    }

    /// Force-close for refresh: bank unusable until `until`.
    pub fn block_until(&mut self, until: Ps) {
        self.open_row = None;
        self.next_act = self.next_act.max(until);
        self.next_rd = self.next_rd.max(until);
        self.next_wr = self.next_wr.max(until);
        self.next_pre = self.next_pre.max(until);
    }
}

impl Default for Bank {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::time::NS;

    fn p() -> TimingParams {
        TimingParams::ddr3_1600()
    }

    #[test]
    fn closed_access_sequence() {
        let p = p();
        let mut b = Bank::new();
        assert!(!b.is_hit(5));
        b.do_act(0, 5, &p);
        assert!(b.is_hit(5));
        assert_eq!(b.earliest_rd(), p.t_rcd);
        let data_end = b.do_rd(p.t_rcd, &p);
        assert_eq!(data_end, p.t_rcd + p.t_rl + p.t_burst);
    }

    #[test]
    fn row_miss_turnaround_is_35ns_path() {
        // RD @ t, then PRE no earlier than t+tRTP, ACT no earlier than
        // +tRP, next RD no earlier than +tRCD: total 35 ns after the RD.
        let p = p();
        let mut b = Bank::new();
        b.do_act(0, 1, &p);
        let t_rd = b.earliest_rd();
        b.do_rd(t_rd, &p);
        let t_pre = b.earliest_pre().max(t_rd + p.t_rtp);
        assert_eq!(t_pre, p.t_ras.max(t_rd + p.t_rtp)); // tRAS also binds early
        b.do_pre(t_pre, &p);
        let t_act = b.earliest_act();
        assert!(t_act >= t_pre + p.t_rp);
        b.do_act(t_act, 2, &p);
        let t_rd2 = b.earliest_rd();
        assert!(t_rd2 >= t_act + p.t_rcd);
        // For a late-enough first RD (tRAS satisfied), spacing is exactly 35 ns.
        let mut b2 = Bank::new();
        b2.do_act(0, 1, &p);
        let first_rd = 40 * NS; // beyond tRAS so tRTP is the binding PRE constraint
        b2.do_rd(first_rd, &p);
        let pre = first_rd + p.t_rtp;
        b2.do_pre(pre, &p);
        let act = pre + p.t_rp;
        b2.do_act(act, 2, &p);
        let rd2 = act + p.t_rcd;
        assert_eq!(rd2 - first_rd, p.row_miss_turnaround());
        assert_eq!(rd2 - first_rd, 35 * NS);
    }

    #[test]
    fn back_to_back_row_hits_spaced_by_tccd() {
        let p = p();
        let mut b = Bank::new();
        b.do_act(0, 7, &p);
        let t1 = b.earliest_rd();
        b.do_rd(t1, &p);
        let t2 = b.earliest_rd();
        assert_eq!(t2 - t1, p.t_ccd);
    }

    #[test]
    fn write_recovery_delays_precharge() {
        let p = p();
        let mut b = Bank::new();
        b.do_act(0, 3, &p);
        let t_wr = b.earliest_wr();
        let data_end = b.do_wr(t_wr, &p);
        assert!(b.earliest_pre() >= data_end + p.t_wr);
    }

    #[test]
    fn refresh_blocks_bank() {
        let p = p();
        let mut b = Bank::new();
        b.do_act(0, 3, &p);
        b.block_until(500 * NS);
        assert_eq!(b.open_row(), None);
        assert!(b.earliest_act() >= 500 * NS);
    }

    #[test]
    #[should_panic]
    fn rd_to_closed_bank_panics_in_debug() {
        let p = p();
        let mut b = Bank::new();
        b.do_rd(100, &p);
    }
}
