//! DRAM command vocabulary (paper Figure 1).

use crate::util::time::Ps;

/// Command kinds on the DDRx command bus.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CommandKind {
    /// Open a row into the bank's sense amplifiers.
    Act,
    /// Column read from the open row.
    Rd,
    /// Column write to the open row.
    Wr,
    /// Close (precharge) the bank.
    Pre,
    /// Refresh (modeled per rank).
    Ref,
}

/// A timestamped command to a specific (rank, bank, row, col).
///
/// The MEC model consumes these to maintain its Bank State Table exactly the
/// way §4.3 describes: ACT carries the row address; RD/WR carry only the
/// column, so the MEC must reconstruct `<row, column, bank>` via the BST.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Command {
    pub kind: CommandKind,
    pub rank: u32,
    pub bank: u32,
    /// Row address: meaningful for `Act` (and kept for debugging on others).
    pub row: u32,
    /// Column address: meaningful for `Rd`/`Wr`.
    pub col: u32,
    /// Issue time on the command bus.
    pub at: Ps,
}

impl Command {
    pub fn act(rank: u32, bank: u32, row: u32, at: Ps) -> Command {
        Command { kind: CommandKind::Act, rank, bank, row, col: 0, at }
    }

    pub fn rd(rank: u32, bank: u32, col: u32, at: Ps) -> Command {
        Command { kind: CommandKind::Rd, rank, bank, row: 0, col, at }
    }

    pub fn wr(rank: u32, bank: u32, col: u32, at: Ps) -> Command {
        Command { kind: CommandKind::Wr, rank, bank, row: 0, col, at }
    }

    pub fn pre(rank: u32, bank: u32, at: Ps) -> Command {
        Command { kind: CommandKind::Pre, rank, bank, row: 0, col: 0, at }
    }

    /// Global bank index within a channel (rank-major).
    pub fn flat_bank(&self, banks_per_rank: u32) -> u32 {
        self.rank * banks_per_rank + self.bank
    }
}

/// Inline, fixed-capacity command sequence.
///
/// One serviced transaction issues at most PRE + ACT + RD/WR, so the hot
/// path can carry its command stream by value instead of allocating a
/// `Vec<Command>` per `ServiceResult`. Derefs to `[Command]`, so indexing,
/// `len()`, and iteration all work as on a slice.
/// Worst case per transaction: PRE, ACT, then the column command.
const CMD_SEQ_CAP: usize = 3;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CommandSeq {
    cmds: [Command; CMD_SEQ_CAP],
    len: u8,
}

impl CommandSeq {
    /// Maximum commands one serviced transaction can issue.
    pub const CAP: usize = CMD_SEQ_CAP;

    pub fn new() -> CommandSeq {
        CommandSeq { cmds: [Command::pre(0, 0, 0); CMD_SEQ_CAP], len: 0 }
    }

    pub fn push(&mut self, c: Command) {
        assert!((self.len as usize) < CommandSeq::CAP, "command sequence overflow");
        self.cmds[self.len as usize] = c;
        self.len += 1;
    }

    pub fn as_slice(&self) -> &[Command] {
        &self.cmds[..self.len as usize]
    }
}

impl Default for CommandSeq {
    fn default() -> Self {
        CommandSeq::new()
    }
}

impl std::ops::Deref for CommandSeq {
    type Target = [Command];

    fn deref(&self) -> &[Command] {
        self.as_slice()
    }
}

impl<'a> IntoIterator for &'a CommandSeq {
    type Item = &'a Command;
    type IntoIter = std::slice::Iter<'a, Command>;

    fn into_iter(self) -> Self::IntoIter {
        self.as_slice().iter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors_set_kind() {
        assert_eq!(Command::act(0, 1, 2, 3).kind, CommandKind::Act);
        assert_eq!(Command::rd(0, 1, 2, 3).kind, CommandKind::Rd);
        assert_eq!(Command::wr(0, 1, 2, 3).kind, CommandKind::Wr);
        assert_eq!(Command::pre(0, 1, 3).kind, CommandKind::Pre);
    }

    #[test]
    fn flat_bank_rank_major() {
        let c = Command::rd(1, 3, 0, 0);
        assert_eq!(c.flat_bank(8), 11);
    }

    #[test]
    fn command_seq_acts_like_a_slice() {
        let mut s = CommandSeq::new();
        assert!(s.is_empty());
        s.push(Command::act(0, 1, 2, 10));
        s.push(Command::rd(0, 1, 5, 20));
        assert_eq!(s.len(), 2);
        assert_eq!(s[0].kind, CommandKind::Act);
        assert_eq!(s[1].col, 5);
        let ats: Vec<_> = s.iter().map(|c| c.at).collect();
        assert_eq!(ats, vec![10, 20]);
        let by_ref: Vec<_> = (&s).into_iter().map(|c| c.kind).collect();
        assert_eq!(by_ref, vec![CommandKind::Act, CommandKind::Rd]);
    }

    #[test]
    #[should_panic(expected = "overflow")]
    fn command_seq_overflow_panics() {
        let mut s = CommandSeq::new();
        for _ in 0..=CommandSeq::CAP {
            s.push(Command::pre(0, 0, 0));
        }
    }
}
