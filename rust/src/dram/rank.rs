//! Rank-level constraints: tRRD, tFAW, read/write turnaround, refresh.

use super::bank::Bank;
use super::timing::TimingParams;
use crate::util::time::Ps;

/// A rank: a set of banks sharing activation-power and turnaround limits.
#[derive(Debug, Clone)]
pub struct Rank {
    pub banks: Vec<Bank>,
    /// Issue times of the last four ACTs (sliding window for tFAW).
    act_window: [Ps; 4],
    act_ptr: usize,
    /// Total ACTs so far (the FAW bound only applies once 4 have issued).
    act_count: u64,
    /// Last ACT anywhere in the rank (tRRD).
    last_act: Ps,
    /// Earliest next RD / WR considering same-rank turnaround (tWTR etc.).
    next_rd_turn: Ps,
    next_wr_turn: Ps,
    /// Next scheduled refresh boundary.
    next_refresh: Ps,
    pub refreshes: u64,
}

impl Rank {
    pub fn new(num_banks: u32, p: &TimingParams) -> Rank {
        Rank {
            banks: (0..num_banks).map(|_| Bank::new()).collect(),
            act_window: [0; 4],
            act_ptr: 0,
            act_count: 0,
            last_act: 0,
            next_rd_turn: 0,
            next_wr_turn: 0,
            next_refresh: p.t_refi,
            refreshes: 0,
        }
    }

    /// Earliest ACT time for `bank` including tRRD and tFAW.
    pub fn earliest_act(&self, bank: u32, p: &TimingParams) -> Ps {
        self.banks[bank as usize].earliest_act().max(self.act_bound(p))
    }

    /// Rank-wide component of the next ACT time (tRRD from the previous
    /// ACT, tFAW from the 4-ago ACT; neither applies before that many
    /// ACTs have issued). The controller's bank-granular invalidation
    /// watches this bound to decide which cached summaries an ACT moved.
    #[inline]
    pub fn act_bound(&self, p: &TimingParams) -> Ps {
        let last = if self.act_count >= 1 { Some(self.last_act) } else { None };
        let fourth =
            if self.act_count >= 4 { Some(self.act_window[self.act_ptr]) } else { None };
        p.act_spacing_bound(last, fourth)
    }

    /// Rank-wide read-turnaround component of the next RD (tCCD / tWTR
    /// floors shared by every bank of the rank).
    #[inline]
    pub fn rd_turn(&self) -> Ps {
        self.next_rd_turn
    }

    /// Rank-wide write-turnaround component of the next WR.
    #[inline]
    pub fn wr_turn(&self) -> Ps {
        self.next_wr_turn
    }

    pub fn earliest_rd(&self, bank: u32) -> Ps {
        self.banks[bank as usize].earliest_rd().max(self.next_rd_turn)
    }

    pub fn earliest_wr(&self, bank: u32) -> Ps {
        self.banks[bank as usize].earliest_wr().max(self.next_wr_turn)
    }

    /// Earliest column command of the given direction on `bank`, including
    /// same-rank turnaround — uniform across every queued access of that
    /// direction to the bank, which is what lets the controller cache one
    /// ready time per (bank, direction) instead of one per transaction.
    #[inline]
    pub fn earliest_col(&self, bank: u32, is_write: bool) -> Ps {
        let turn = if is_write { self.next_wr_turn } else { self.next_rd_turn };
        self.banks[bank as usize].earliest_col(is_write).max(turn)
    }

    pub fn do_act(&mut self, t: Ps, bank: u32, row: u32, p: &TimingParams) {
        self.banks[bank as usize].do_act(t, row, p);
        self.act_window[self.act_ptr] = t;
        self.act_ptr = (self.act_ptr + 1) % 4;
        self.act_count += 1;
        self.last_act = t;
    }

    pub fn do_rd(&mut self, t: Ps, bank: u32, p: &TimingParams) -> Ps {
        let data_end = self.banks[bank as usize].do_rd(t, p);
        // Spacing of subsequent same-rank column commands (tCCD) across banks.
        self.next_rd_turn = self.next_rd_turn.max(t + p.t_ccd);
        // Read-to-write: write data can't start before read data clears.
        self.next_wr_turn = self.next_wr_turn.max(t + p.t_ccd);
        data_end
    }

    pub fn do_wr(&mut self, t: Ps, bank: u32, p: &TimingParams) -> Ps {
        let data_end = self.banks[bank as usize].do_wr(t, p);
        self.next_wr_turn = self.next_wr_turn.max(t + p.t_ccd);
        // Write-to-read turnaround: tWTR after last write data beat.
        self.next_rd_turn = self.next_rd_turn.max(data_end + p.t_wtr);
        data_end
    }

    pub fn do_pre(&mut self, t: Ps, bank: u32, p: &TimingParams) {
        self.banks[bank as usize].do_pre(t, p);
    }

    /// If a refresh is due at or before `now`, perform it (all banks busy
    /// for tRFC) and return the completion time.
    pub fn maybe_refresh(&mut self, now: Ps, p: &TimingParams) -> Option<Ps> {
        if now < self.next_refresh {
            return None;
        }
        let start = self.next_refresh;
        let done = start + p.t_rfc;
        for b in &mut self.banks {
            b.block_until(done);
        }
        self.next_refresh += p.t_refi;
        self.refreshes += 1;
        Some(done)
    }

    pub fn open_row(&self, bank: u32) -> Option<u32> {
        self.banks[bank as usize].open_row()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::time::NS;

    fn p() -> TimingParams {
        TimingParams::ddr3_1600()
    }

    #[test]
    fn trrd_spaces_activates_across_banks() {
        let p = p();
        let mut r = Rank::new(8, &p);
        r.do_act(0, 0, 10, &p);
        assert!(r.earliest_act(1, &p) >= p.t_rrd);
    }

    #[test]
    fn act_bound_decomposes_earliest_act() {
        let p = p();
        let mut r = Rank::new(8, &p);
        assert_eq!(r.act_bound(&p), 0);
        r.do_act(0, 0, 10, &p);
        assert_eq!(r.act_bound(&p), p.t_rrd);
        // earliest_act is exactly the bank component ∨ the rank bound —
        // the decomposition the bank-granular invalidation relies on.
        for bank in 0..8 {
            assert_eq!(
                r.earliest_act(bank, &p),
                r.banks[bank as usize].earliest_act().max(r.act_bound(&p))
            );
        }
    }

    #[test]
    fn tfaw_limits_four_activates() {
        let p = p();
        let mut r = Rank::new(8, &p);
        // Four ACTs as fast as tRRD allows.
        let mut t = 0;
        for bank in 0..4 {
            t = r.earliest_act(bank, &p).max(t);
            r.do_act(t, bank, 1, &p);
        }
        // Fifth ACT must wait for the FAW window from the first ACT.
        let t5 = r.earliest_act(4, &p);
        assert!(t5 >= p.t_faw, "t5={t5} < tFAW={}", p.t_faw);
    }

    #[test]
    fn write_to_read_turnaround() {
        let p = p();
        let mut r = Rank::new(8, &p);
        r.do_act(0, 0, 1, &p);
        let t_wr = r.earliest_wr(0);
        let data_end = r.do_wr(t_wr, 0, &p);
        assert!(r.earliest_rd(0) >= data_end + p.t_wtr);
    }

    #[test]
    fn refresh_fires_on_schedule() {
        let p = p();
        let mut r = Rank::new(8, &p);
        assert!(r.maybe_refresh(0, &p).is_none());
        let done = r.maybe_refresh(p.t_refi + NS, &p).unwrap();
        assert_eq!(done, p.t_refi + p.t_rfc);
        assert_eq!(r.refreshes, 1);
        // All banks blocked until refresh completes.
        assert!(r.earliest_act(3, &p) >= done);
    }

    #[test]
    fn independent_banks_overlap() {
        // Two different banks can both have rows open simultaneously —
        // the bank-level parallelism TL-OoO exploits.
        let p = p();
        let mut r = Rank::new(8, &p);
        r.do_act(0, 0, 1, &p);
        let t1 = r.earliest_act(1, &p);
        r.do_act(t1, 1, 2, &p);
        assert_eq!(r.open_row(0), Some(1));
        assert_eq!(r.open_row(1), Some(2));
        assert!(t1 < p.t_rc, "bank 1 ACT did not wait for bank 0 tRC");
    }
}
