//! Cycle-level (timestamp-algebra) DDRx DRAM model.
//!
//! This is the substrate the paper reasons over (its Table 1 / Figure 1):
//! JEDEC DDR3-style banks with ACT / RD / WR / PRE commands and the
//! inter-command constraints tRCD, tRL(tCL), tCCD, tRTP, tRP, tRAS, tRC,
//! tFAW, tRRD, tWR, tWTR, plus refresh. Instead of stepping every DRAM
//! clock, each component tracks *earliest-allowed timestamps* per command
//! class ("timestamp algebra", the approach fast simulators like Ramulator
//! use); command interleaving across banks and data-bus serialization are
//! modeled exactly, at transaction granularity.
//!
//! The same model instance serves three roles in the reproduction:
//! * the host memory controller's view of **logical** banks (what MEC1's
//!   fake SPD advertises — this is where the twin-load row-miss delay
//!   comes from),
//! * the **leaf DRAM** behind the deepest MECs,
//! * the local-memory channels of every baseline system.

pub mod address;
pub mod bank;
pub mod channel;
pub mod command;
pub mod controller;
pub mod rank;
pub mod timing;

pub use address::{AddressMapping, DecodedAddr};
pub use command::{Command, CommandKind, CommandSeq};
pub use controller::{MemController, SchedPolicy, ServiceResult, Transaction};
pub use timing::TimingParams;
