//! Channel-level shared resources: command bus and data bus.

use super::rank::Rank;
use super::timing::{Geometry, TimingParams};
use crate::util::time::Ps;

/// A DDRx channel: ranks sharing one command bus and one data bus.
///
/// Command-bus modeling: DDRx issues one command per tCK, but commands of
/// *different* transactions interleave freely in the gaps between one
/// transaction's ACT and its RD. A monotonic busy-cursor would serialize
/// transactions at ~tRCD spacing (grossly wrong); an exact slot-reservation
/// table costs more than it informs, since worst-case command-bus
/// utilization for 64-byte bursts is ≤ 2 commands per 4-cycle burst. We
/// therefore model the command bus as collision-free and track only a
/// utilization estimate; the data bus and bank timing carry the real
/// contention (see DESIGN.md §DRAM-model).
#[derive(Debug, Clone)]
pub struct Channel {
    pub ranks: Vec<Rank>,
    /// Next time the data bus is free (bursts serialize).
    next_data: Ps,
    /// Which rank last drove the data bus (rank switch penalty tRTRS).
    last_data_rank: Option<u32>,
    pub cmd_count: u64,
    pub data_bursts: u64,
}

impl Channel {
    pub fn new(geo: &Geometry, p: &TimingParams) -> Channel {
        Channel {
            ranks: (0..geo.ranks).map(|_| Rank::new(geo.banks_per_rank, p)).collect(),
            next_data: 0,
            last_data_rank: None,
            cmd_count: 0,
            data_bursts: 0,
        }
    }

    /// Earliest time a command can occupy the command bus at or after `t`
    /// (collision-free model — see the type-level comment).
    #[inline]
    pub fn earliest_cmd(&self, t: Ps) -> Ps {
        t
    }

    /// Record one command-bus slot use at `t`.
    pub fn claim_cmd(&mut self, t: Ps, p: &TimingParams) {
        let _ = (t, p);
        self.cmd_count += 1;
    }

    /// Earliest time a data burst from `rank` can start at or after `t`.
    pub fn earliest_data(&self, t: Ps, rank: u32, p: &TimingParams) -> Ps {
        let switch = match self.last_data_rank {
            Some(r) if r != rank => p.t_rtrs,
            _ => 0,
        };
        t.max(self.next_data + switch)
    }

    /// Claim the data bus for a burst starting at `t`.
    pub fn claim_data(&mut self, t: Ps, rank: u32, p: &TimingParams) {
        debug_assert!(t >= self.next_data);
        self.next_data = t + p.t_burst;
        self.last_data_rank = Some(rank);
        self.data_bursts += 1;
    }

    /// Data-bus utilization over `[0, now]` (fraction of time transferring).
    pub fn data_utilization(&self, now: Ps, p: &TimingParams) -> f64 {
        if now == 0 {
            return 0.0;
        }
        (self.data_bursts as f64 * p.t_burst as f64 / now as f64).min(1.0)
    }

    /// Run due refreshes on all ranks; returns latest completion if any.
    pub fn maybe_refresh(&mut self, now: Ps, p: &TimingParams) -> Option<Ps> {
        let mut latest = None;
        for r in &mut self.ranks {
            if let Some(done) = r.maybe_refresh(now, p) {
                latest = Some(latest.map_or(done, |l: Ps| l.max(done)));
            }
        }
        latest
    }

    /// Run *all* refreshes due up to `now` (long idle gaps may owe several
    /// back-to-back). Returns whether any fired — the controller uses that
    /// as the signal to invalidate its cached bank ready times.
    pub fn catch_up_refresh(&mut self, now: Ps, p: &TimingParams) -> bool {
        let mut fired = false;
        while self.maybe_refresh(now, p).is_some() {
            fired = true;
        }
        fired
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> (Channel, TimingParams) {
        let p = TimingParams::ddr3_1600();
        (Channel::new(&Geometry::sim_small(), &p), p)
    }

    #[test]
    fn command_bus_is_collision_free_but_counted() {
        let (mut c, p) = setup();
        let t0 = c.earliest_cmd(0);
        c.claim_cmd(t0, &p);
        // Commands interleave freely between transactions.
        assert_eq!(c.earliest_cmd(0), 0);
        assert_eq!(c.cmd_count, 1);
    }

    #[test]
    fn data_bus_serializes_bursts() {
        let (mut c, p) = setup();
        let t0 = c.earliest_data(0, 0, &p);
        c.claim_data(t0, 0, &p);
        let t1 = c.earliest_data(0, 0, &p);
        assert_eq!(t1, t0 + p.t_burst);
    }

    #[test]
    fn rank_switch_penalty() {
        let (mut c, p) = setup();
        c.claim_data(0, 0, &p);
        let same = c.earliest_data(0, 0, &p);
        let other = c.earliest_data(0, 1, &p);
        assert_eq!(other - same, p.t_rtrs);
    }

    #[test]
    fn utilization_bounded() {
        let (mut c, p) = setup();
        c.claim_data(0, 0, &p);
        let u = c.data_utilization(p.t_burst, &p);
        assert!((u - 1.0).abs() < 1e-12);
        assert_eq!(c.data_utilization(0, &p), 0.0);
    }

    #[test]
    fn channel_refresh_covers_all_ranks() {
        let (mut c, p) = setup();
        let done = c.maybe_refresh(p.t_refi, &p).unwrap();
        assert_eq!(done, p.t_refi + p.t_rfc);
        assert!(c.maybe_refresh(p.t_refi, &p).is_none()); // already done
    }
}
