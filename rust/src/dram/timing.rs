//! JEDEC DDRx timing parameters (paper Table 1) and presets.

use crate::util::time::{Ps, NS, US};

/// All parameters are stored in picoseconds (see `util::time`).
///
/// Field names follow JEDEC / the paper's Table 1. `t_rl` is the read
/// latency (a.k.a. tCL/tAA): *fixed* latency from RD command to first data —
/// the constraint twin-load exists to work around.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TimingParams {
    /// Command clock period (e.g. 1250 ps for DDR3-1600).
    pub t_ck: Ps,
    /// RD command to first data beat (tCL). Paper: 13.75 ns.
    pub t_rl: Ps,
    /// Write latency: WR command to first data beat (CWL).
    pub t_wl: Ps,
    /// Data burst duration (BL8 on a x64 bus = 4 clocks). Paper: 4 cycles.
    pub t_burst: Ps,
    /// Minimum RD-to-RD (same rank) spacing. Paper: 4 cycles.
    pub t_ccd: Ps,
    /// RD to PRE minimum (same bank). Paper: 7.5 ns.
    pub t_rtp: Ps,
    /// PRE to ACT minimum (same bank). Paper: 13.75 ns.
    pub t_rp: Ps,
    /// ACT to RD/WR minimum (same bank). Paper: 13.75 ns.
    pub t_rcd: Ps,
    /// ACT to PRE minimum (row must stay open this long).
    pub t_ras: Ps,
    /// ACT to ACT minimum, same bank (= tRAS + tRP).
    pub t_rc: Ps,
    /// ACT to ACT minimum, different banks of the same rank.
    pub t_rrd: Ps,
    /// Four-activate window per rank.
    pub t_faw: Ps,
    /// End of write data to PRE (write recovery).
    pub t_wr: Ps,
    /// End of write data to RD command (same rank turnaround).
    pub t_wtr: Ps,
    /// Rank-to-rank data-bus switch penalty.
    pub t_rtrs: Ps,
    /// Refresh cycle time (all banks busy).
    pub t_rfc: Ps,
    /// Average refresh interval.
    pub t_refi: Ps,
}

impl TimingParams {
    /// DDR3-1600 (11-11-11), the configuration the paper's host uses.
    /// tRL = tRP = tRCD = 13.75 ns, tCCD = tBURST = 4 clocks = 5 ns,
    /// tRTP = 7.5 ns: the row-miss turnaround tRTP + tRP + tRCD = 35 ns
    /// matches the paper's "minimum total delay is about 35ns at DDR3-1600".
    pub fn ddr3_1600() -> TimingParams {
        let t_ck = 1_250; // 800 MHz command clock
        TimingParams {
            t_ck,
            t_rl: 13_750,
            t_wl: 8 * t_ck, // CWL = 8
            t_burst: 4 * t_ck,
            t_ccd: 4 * t_ck,
            t_rtp: 7_500,
            t_rp: 13_750,
            t_rcd: 13_750,
            t_ras: 35 * NS,
            t_rc: 35 * NS + 13_750,
            t_rrd: 6 * NS,
            t_faw: 30 * NS,
            t_wr: 15 * NS,
            t_wtr: 7_500,
            t_rtrs: 2 * t_ck,
            t_rfc: 160 * NS, // 4 Gb device
            t_refi: 7_800 * NS,
        }
    }

    /// DDR3-1866 (13-13-13): the higher-frequency point the paper cites for
    /// the one-DIMM-per-channel SI limitation.
    pub fn ddr3_1866() -> TimingParams {
        let t_ck = 1_072; // ~933 MHz command clock (rounded to ps)
        TimingParams {
            t_ck,
            t_rl: 13_910, // 13 clocks
            t_wl: 9 * t_ck,
            t_burst: 4 * t_ck,
            t_ccd: 4 * t_ck,
            t_rtp: 7_500,
            t_rp: 13_910,
            t_rcd: 13_910,
            t_ras: 34 * NS,
            t_rc: 34 * NS + 13_910,
            t_rrd: 6 * NS,
            t_faw: 27 * NS,
            t_wr: 15 * NS,
            t_wtr: 7_500,
            t_rtrs: 2 * t_ck,
            t_rfc: 160 * NS,
            t_refi: 7_800 * NS,
        }
    }

    /// A slow "storage-class memory" leaf preset for the §8 heterogeneous
    /// DRAM/NVM extension experiments: reads ~2.5× and row activation ~4×
    /// slower than DRAM (PCM-like, per Lee et al. \[35\]).
    pub fn scm_leaf() -> TimingParams {
        let base = TimingParams::ddr3_1600();
        TimingParams {
            t_rl: base.t_rl * 5 / 2,
            t_rcd: base.t_rcd * 4,
            t_rp: base.t_rp * 2,
            t_ras: base.t_ras * 4,
            t_rc: base.t_ras * 4 + base.t_rp * 2,
            t_wr: base.t_wr * 10,
            ..base
        }
    }

    /// The paper's headline number: extra latency of a row-miss turnaround
    /// (RD→PRE→ACT→RD on the same bank) = tRTP + tRP + tRCD ≈ 35 ns.
    pub fn row_miss_turnaround(&self) -> Ps {
        self.t_rtp + self.t_rp + self.t_rcd
    }

    /// Rank-level ACT spacing bound: tRRD measured from the previous ACT
    /// and tFAW from the fourth-previous. `None` means that ACT has not
    /// issued yet, so the corresponding constraint does not yet apply.
    /// Shared by `Rank::earliest_act` and the controller's bank-granular
    /// cache invalidation, which must agree exactly on when this bound
    /// moves.
    #[inline]
    pub fn act_spacing_bound(&self, last_act: Option<Ps>, fourth_last_act: Option<Ps>) -> Ps {
        let rrd = last_act.map_or(0, |t| t + self.t_rrd);
        let faw = fourth_last_act.map_or(0, |t| t + self.t_faw);
        rrd.max(faw)
    }

    /// Closed-bank access latency: ACT → RD → data end.
    pub fn closed_access(&self) -> Ps {
        self.t_rcd + self.t_rl + self.t_burst
    }

    /// Peak data-bus bandwidth in bytes/ps-interval terms: one 64-byte
    /// burst every `t_burst`.
    pub fn peak_gbps(&self) -> f64 {
        64.0 / (self.t_burst as f64 * 1e-12) / 1e9
    }

    /// Validate internal consistency (used by config loading and tests).
    pub fn validate(&self) -> Result<(), String> {
        if self.t_ck == 0 {
            return Err("t_ck must be positive".into());
        }
        if self.t_rc < self.t_ras + self.t_rp {
            return Err(format!(
                "tRC ({}) < tRAS + tRP ({})",
                self.t_rc,
                self.t_ras + self.t_rp
            ));
        }
        if self.t_faw < self.t_rrd {
            return Err("tFAW must cover at least one tRRD".into());
        }
        if self.t_refi < self.t_rfc {
            return Err("tREFI must exceed tRFC".into());
        }
        Ok(())
    }
}

/// Geometry of one DRAM channel as the controller sees it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Geometry {
    pub ranks: u32,
    pub banks_per_rank: u32,
    pub rows_per_bank: u32,
    /// Columns counted in cache-line-sized (64 B) units.
    pub cols_per_row: u32,
}

impl Geometry {
    /// An 8 GB dual-rank DIMM-oid (paper host: 8×8 GB DIMMs).
    pub fn dimm_8gb() -> Geometry {
        Geometry { ranks: 2, banks_per_rank: 8, rows_per_bank: 1 << 16, cols_per_row: 1 << 7 }
    }

    /// Scaled-down geometry for fast simulation: 64 MB per rank keeps the
    /// row/bank structure but shrinks row count (documented in DESIGN.md
    /// footprint scaling).
    pub fn sim_small() -> Geometry {
        Geometry { ranks: 2, banks_per_rank: 8, rows_per_bank: 1 << 10, cols_per_row: 1 << 7 }
    }

    pub fn bytes_per_row(&self) -> u64 {
        self.cols_per_row as u64 * 64
    }

    pub fn capacity_bytes(&self) -> u64 {
        self.ranks as u64
            * self.banks_per_rank as u64
            * self.rows_per_bank as u64
            * self.bytes_per_row()
    }

    pub fn total_banks(&self) -> u32 {
        self.ranks * self.banks_per_rank
    }
}

/// Propagation delay constants from the paper (§2.1): ~3.4 ns per direction
/// per simple forwarding hop; a two-layer system with logic approaches 20 ns.
pub const T_PD_SIMPLE_HOP: Ps = 3_400;
/// Per-hop delay including MEC logic processing (paper: "minimal logic
/// processing" pushes two layers toward 20 ns round trip).
pub const T_PD_LOGIC_HOP: Ps = 5 * NS;

/// The paper's measured host access latencies (§6.2): local ≈100 ns,
/// remote-QPI ≈170 ns.
pub const LOCAL_ACCESS_NS: Ps = 100 * NS;
pub const QPI_EXTRA_NS: Ps = 70 * NS;

/// PCIe page-swap cost measured on the paper's prototype (§6.3): 7.8 µs.
pub const PCIE_SWAP_COST: Ps = 7_800 * NS;
const _: () = assert!(PCIE_SWAP_COST == 78 * US / 10);

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ddr3_1600_matches_paper_table1() {
        let t = TimingParams::ddr3_1600();
        assert_eq!(t.t_rl, 13_750); // 13.75 ns
        assert_eq!(t.t_burst, 4 * t.t_ck); // 4 cycles
        assert_eq!(t.t_ccd, 4 * t.t_ck); // 4 cycles
        assert_eq!(t.t_rtp, 7_500); // 7.5 ns
        assert_eq!(t.t_rp, 13_750);
        assert_eq!(t.t_rcd, 13_750);
        t.validate().unwrap();
    }

    #[test]
    fn row_miss_turnaround_is_35ns() {
        let t = TimingParams::ddr3_1600();
        assert_eq!(t.row_miss_turnaround(), 35 * NS);
    }

    #[test]
    fn peak_bandwidth_ddr3_1600() {
        let t = TimingParams::ddr3_1600();
        // 64 B / 5 ns = 12.8 GB/s
        assert!((t.peak_gbps() - 12.8).abs() < 1e-9);
    }

    #[test]
    fn presets_validate() {
        TimingParams::ddr3_1866().validate().unwrap();
        TimingParams::scm_leaf().validate().unwrap();
    }

    #[test]
    fn act_spacing_bound_applies_constraints_in_order() {
        let t = TimingParams::ddr3_1600();
        // No ACT yet: unconstrained.
        assert_eq!(t.act_spacing_bound(None, None), 0);
        // Only tRRD once one ACT has issued.
        assert_eq!(t.act_spacing_bound(Some(100), None), 100 + t.t_rrd);
        // tFAW dominates once four have issued close together.
        let b = t.act_spacing_bound(Some(3 * t.t_rrd), Some(0));
        assert_eq!(b, t.t_faw, "tFAW must bind: {b}");
    }

    #[test]
    fn geometry_capacity() {
        let g = Geometry::dimm_8gb();
        assert_eq!(g.capacity_bytes(), 8 << 30);
        let s = Geometry::sim_small();
        assert_eq!(s.capacity_bytes(), 2 * (64 << 20));
    }

    #[test]
    fn validate_rejects_bad_params() {
        let mut t = TimingParams::ddr3_1600();
        t.t_rc = 0;
        assert!(t.validate().is_err());
        let mut t2 = TimingParams::ddr3_1600();
        t2.t_refi = 0;
        assert!(t2.validate().is_err());
    }
}
