//! FR-FCFS memory controller over one channel.
//!
//! Transaction-granularity scheduling with exact command timestamps:
//! when the controller commits to servicing a transaction it walks the
//! PRE?/ACT?/RD|WR command sequence through the bank/rank/channel algebra,
//! claiming the command and data buses at each step. First-Ready FCFS:
//! row hits are prioritized over misses, ties broken by arrival order —
//! the policy commodity controllers implement and the one that produces
//! the twin-load row-miss spacing the paper relies on.

use super::address::DecodedAddr;
use super::channel::Channel;
use super::command::Command;
use super::timing::{Geometry, TimingParams};
use crate::util::time::Ps;

/// A read or write request at the controller.
#[derive(Debug, Clone, Copy)]
pub struct Transaction {
    pub id: u64,
    pub addr: DecodedAddr,
    pub is_write: bool,
    pub arrive: Ps,
}

/// Outcome of servicing one transaction.
#[derive(Debug, Clone)]
pub struct ServiceResult {
    pub id: u64,
    pub is_write: bool,
    pub addr: DecodedAddr,
    /// Column command (RD/WR) issue time.
    pub col_cmd_at: Ps,
    /// First / last data beat times.
    pub data_start: Ps,
    pub data_end: Ps,
    pub row_hit: bool,
    /// Full command sequence issued — consumed by the MEC model, which
    /// observes the DDR bus exactly as §4.3 describes (BST from ACTs,
    /// address reconstruction on RDs).
    pub commands: Vec<Command>,
}

/// Per-controller statistics.
#[derive(Debug, Default, Clone)]
pub struct CtrlStats {
    pub reads: u64,
    pub writes: u64,
    pub row_hits: u64,
    pub row_misses: u64,
    pub row_conflicts: u64,
    pub read_bytes: u64,
    pub write_bytes: u64,
    pub queue_peak: usize,
}

/// Write-queue drain thresholds.
const WQ_HIGH: usize = 32;
const WQ_LOW: usize = 8;
/// Read queue capacity (admission control / backpressure signal).
pub const RQ_CAP: usize = 64;

#[derive(Debug, Clone)]
pub struct MemController {
    p: TimingParams,
    geo: Geometry,
    channel: Channel,
    reads: Vec<Transaction>,
    writes: Vec<Transaction>,
    draining: bool,
    pub stats: CtrlStats,
}

impl MemController {
    pub fn new(p: TimingParams, geo: Geometry) -> MemController {
        MemController {
            channel: Channel::new(&geo, &p),
            p,
            geo,
            reads: Vec::with_capacity(RQ_CAP),
            writes: Vec::with_capacity(WQ_HIGH + 4),
            draining: false,
            stats: CtrlStats::default(),
        }
    }

    pub fn timing(&self) -> &TimingParams {
        &self.p
    }

    pub fn queue_len(&self) -> usize {
        self.reads.len() + self.writes.len()
    }

    pub fn has_room(&self) -> bool {
        self.reads.len() < RQ_CAP
    }

    pub fn enqueue(&mut self, t: Transaction) {
        if t.is_write {
            self.writes.push(t);
        } else {
            self.reads.push(t);
        }
        self.stats.queue_peak = self.stats.queue_peak.max(self.queue_len());
    }

    /// Earliest time the *first* command of `t` could issue, plus whether
    /// it would be a row hit, given current bank state.
    fn first_cmd_time(&self, t: &Transaction) -> (Ps, bool) {
        let rank = &self.channel.ranks[t.addr.rank as usize];
        let bank = &rank.banks[t.addr.bank as usize];
        let base = t.arrive;
        match bank.open_row() {
            Some(r) if r == t.addr.row => {
                let col = if t.is_write {
                    rank.earliest_wr(t.addr.bank)
                } else {
                    rank.earliest_rd(t.addr.bank)
                };
                (self.channel.earliest_cmd(col.max(base)), true)
            }
            Some(_) => {
                let pre = bank.earliest_pre();
                (self.channel.earliest_cmd(pre.max(base)), false)
            }
            None => {
                let act = rank.earliest_act(t.addr.bank, &self.p);
                (self.channel.earliest_cmd(act.max(base)), false)
            }
        }
    }

    /// Service one chosen transaction: walk its command sequence through
    /// the algebra and return the timed result.
    fn service(&mut self, t: Transaction) -> ServiceResult {
        let (rank_i, bank_i, row) = (t.addr.rank, t.addr.bank, t.addr.row);
        let mut commands = Vec::with_capacity(3);
        let p = self.p;

        // 1. PRE if a different row is open (row conflict).
        let open = self.channel.ranks[rank_i as usize].open_row(bank_i);
        let row_hit = open == Some(row);
        if let Some(r) = open {
            if r != row {
                let pre_t = {
                    let rank = &self.channel.ranks[rank_i as usize];
                    self.channel
                        .earliest_cmd(rank.banks[bank_i as usize].earliest_pre().max(t.arrive))
                };
                self.channel.claim_cmd(pre_t, &p);
                self.channel.ranks[rank_i as usize].do_pre(pre_t, bank_i, &p);
                commands.push(Command::pre(rank_i, bank_i, pre_t));
                self.stats.row_conflicts += 1;
                self.channel.ranks[rank_i as usize].banks[bank_i as usize].row_conflicts += 1;
            }
        }

        // 2. ACT if the bank is (now) closed.
        if self.channel.ranks[rank_i as usize].open_row(bank_i).is_none() {
            let act_t = {
                let rank = &self.channel.ranks[rank_i as usize];
                self.channel.earliest_cmd(rank.earliest_act(bank_i, &p).max(t.arrive))
            };
            self.channel.claim_cmd(act_t, &p);
            self.channel.ranks[rank_i as usize].do_act(act_t, bank_i, row, &p);
            commands.push(Command::act(rank_i, bank_i, row, act_t));
            if !row_hit {
                self.stats.row_misses += 1;
                self.channel.ranks[rank_i as usize].banks[bank_i as usize].row_misses += 1;
            }
        } else if row_hit {
            self.stats.row_hits += 1;
            self.channel.ranks[rank_i as usize].banks[bank_i as usize].row_hits += 1;
        }

        // 3. Column command; align with both command-bus and data-bus slots.
        let lat = if t.is_write { p.t_wl } else { p.t_rl };
        let col_t = {
            let rank = &self.channel.ranks[rank_i as usize];
            let ready = if t.is_write {
                rank.earliest_wr(bank_i)
            } else {
                rank.earliest_rd(bank_i)
            }
            .max(t.arrive);
            // Data burst starts `lat` after the column command: back-solve
            // so the data bus is free when the burst arrives.
            let mut ct = self.channel.earliest_cmd(ready);
            loop {
                let want_data = ct + lat;
                let data_ok = self.channel.earliest_data(want_data, rank_i, &p);
                if data_ok == want_data {
                    break;
                }
                ct = self.channel.earliest_cmd(data_ok - lat);
            }
            ct
        };
        self.channel.claim_cmd(col_t, &p);
        let data_end = if t.is_write {
            self.channel.ranks[rank_i as usize].do_wr(col_t, bank_i, &p)
        } else {
            self.channel.ranks[rank_i as usize].do_rd(col_t, bank_i, &p)
        };
        let data_start = col_t + lat;
        self.channel.claim_data(data_start, rank_i, &p);
        commands.push(if t.is_write {
            Command::wr(rank_i, bank_i, t.addr.col, col_t)
        } else {
            Command::rd(rank_i, bank_i, t.addr.col, col_t)
        });

        if t.is_write {
            self.stats.writes += 1;
            self.stats.write_bytes += 64;
        } else {
            self.stats.reads += 1;
            self.stats.read_bytes += 64;
        }

        ServiceResult {
            id: t.id,
            is_write: t.is_write,
            addr: t.addr,
            col_cmd_at: col_t,
            data_start,
            data_end,
            row_hit,
            commands,
        }
    }

    /// Advance the controller to `now`: run refreshes, service everything
    /// that is first-ready, and report `(results, next_wake)`.
    ///
    /// `next_wake` is `Some(t)` when work remains that becomes ready at `t`.
    pub fn pump(&mut self, now: Ps) -> (Vec<ServiceResult>, Option<Ps>) {
        let mut out = Vec::new();
        // Catch up on refreshes (loop: long idle periods may owe several).
        while self.channel.maybe_refresh(now, &self.p).is_some() {}

        loop {
            // Enter/leave write-drain mode.
            if self.writes.len() >= WQ_HIGH || (self.reads.is_empty() && !self.writes.is_empty()) {
                self.draining = true;
            }
            if self.writes.len() <= WQ_LOW && !self.reads.is_empty() {
                self.draining = false;
            }

            // Candidate pool: reads normally; writes when draining.
            let pool: &Vec<Transaction> =
                if self.draining && !self.writes.is_empty() { &self.writes } else { &self.reads };
            if pool.is_empty() {
                let wake = if self.writes.is_empty() && self.reads.is_empty() {
                    None
                } else {
                    // The other queue has work (e.g. reads while draining off).
                    let other = if self.draining { &self.reads } else { &self.writes };
                    other.iter().map(|t| self.first_cmd_time(t).0).min()
                };
                return (out, wake);
            }

            // FR-FCFS pick among candidates ready at `now`; ties on
            // arrival break by transaction id so the outcome does not
            // depend on queue layout (swap_remove shuffles positions).
            let mut best: Option<(usize, bool, Ps, u64)> = None; // (idx, hit, arrive, id)
            let mut min_ready = Ps::MAX;
            for (i, t) in pool.iter().enumerate() {
                let (ready, hit) = self.first_cmd_time(t);
                min_ready = min_ready.min(ready);
                if ready > now {
                    continue;
                }
                let better = match best {
                    None => true,
                    Some((_, bhit, barr, bid)) => {
                        (hit && !bhit)
                            || (hit == bhit
                                && (t.arrive, t.id) < (barr, bid))
                    }
                };
                if better {
                    best = Some((i, hit, t.arrive, t.id));
                }
            }

            match best {
                Some((i, _, _, _)) => {
                    // swap_remove is safe: FR-FCFS selects by (row-hit,
                    // arrival time), never by queue position.
                    let t = if self.draining && !self.writes.is_empty() {
                        self.writes.swap_remove(i)
                    } else {
                        self.reads.swap_remove(i)
                    };
                    out.push(self.service(t));
                }
                None => {
                    return (out, if min_ready == Ps::MAX { None } else { Some(min_ready) });
                }
            }
        }
    }

    /// Read row-buffer hit rate so far.
    pub fn hit_rate(&self) -> f64 {
        let total = self.stats.row_hits + self.stats.row_misses + self.stats.row_conflicts;
        if total == 0 {
            0.0
        } else {
            self.stats.row_hits as f64 / total as f64
        }
    }

    pub fn geometry(&self) -> &Geometry {
        &self.geo
    }

    pub fn data_utilization(&self, now: Ps) -> f64 {
        self.channel.data_utilization(now, &self.p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dram::command::CommandKind;
    use crate::dram::address::AddressMapping;
    use crate::util::time::NS;

    fn ctrl() -> (MemController, AddressMapping) {
        let geo = Geometry::sim_small();
        (MemController::new(TimingParams::ddr3_1600(), geo), AddressMapping::new(&geo, 1))
    }

    fn read_to(map: &AddressMapping, id: u64, row: u32, col: u32, bank: u32, arrive: Ps) -> Transaction {
        let addr = DecodedAddr { channel: 0, rank: 0, bank, row, col };
        let _ = map;
        Transaction { id, addr, is_write: false, arrive }
    }

    #[test]
    fn single_read_closed_bank_latency() {
        let (mut c, m) = ctrl();
        c.enqueue(read_to(&m, 1, 5, 0, 0, 0));
        let (res, wake) = c.pump(0);
        assert_eq!(res.len(), 1);
        let r = &res[0];
        assert!(!r.row_hit);
        // ACT@0, RD@tRCD, data ends at tRCD+tRL+tBURST.
        let p = TimingParams::ddr3_1600();
        assert_eq!(r.data_end, p.closed_access());
        assert!(wake.is_none());
    }

    #[test]
    fn row_hit_prioritized_over_older_miss() {
        let (mut c, m) = ctrl();
        // Open row 1 on bank 0.
        c.enqueue(read_to(&m, 1, 1, 0, 0, 0));
        let _ = c.pump(0);
        // Older request misses (row 2), newer hits (row 1): FR-FCFS serves
        // the hit first.
        c.enqueue(read_to(&m, 2, 2, 0, 0, 10));
        c.enqueue(read_to(&m, 3, 1, 1, 0, 11));
        let (res, _) = c.pump(200 * NS);
        let order: Vec<u64> = res.iter().map(|r| r.id).collect();
        assert_eq!(order, vec![3, 2]);
        assert!(res[0].row_hit && !res[1].row_hit);
    }

    #[test]
    fn twin_pair_forced_row_miss_spacing() {
        // The twin-load core property: two loads to the same bank but rows
        // differing in the MSB are spaced by >= 35 ns at the column command.
        let (mut c, m) = ctrl();
        let row = 0x0123;
        let twin_row = row | (1 << 9); // MSB of sim_small's 10-bit row space
        c.enqueue(read_to(&m, 1, row, 7, 3, 0));
        c.enqueue(read_to(&m, 2, twin_row, 7, 3, 0));
        let (res, _) = c.pump(1_000 * NS);
        assert_eq!(res.len(), 2);
        let gap = res[1].col_cmd_at - res[0].col_cmd_at;
        assert!(
            gap >= TimingParams::ddr3_1600().row_miss_turnaround(),
            "twin spacing {gap} < 35ns"
        );
    }

    #[test]
    fn bank_parallel_reads_overlap() {
        let (mut c, m) = ctrl();
        c.enqueue(read_to(&m, 1, 1, 0, 0, 0));
        c.enqueue(read_to(&m, 2, 1, 0, 1, 0));
        let (res, _) = c.pump(1_000 * NS);
        let p = TimingParams::ddr3_1600();
        // Both finish well before 2x the serial closed-access latency.
        let last = res.iter().map(|r| r.data_end).max().unwrap();
        assert!(last < 2 * p.closed_access(), "no bank overlap: {last}");
    }

    #[test]
    fn writes_drain_when_no_reads() {
        let (mut c, m) = ctrl();
        let mut t = read_to(&m, 1, 3, 0, 0, 0);
        t.is_write = true;
        c.enqueue(t);
        let (res, _) = c.pump(0);
        assert_eq!(res.len(), 1);
        assert!(res[0].is_write);
        assert_eq!(c.stats.writes, 1);
    }

    #[test]
    fn not_ready_returns_wake_time() {
        let (mut c, m) = ctrl();
        c.enqueue(read_to(&m, 1, 1, 0, 0, 0));
        let _ = c.pump(0);
        // Conflict on same bank: PRE can't go until tRAS; pumping at t=1
        // must return a wake time instead of servicing.
        c.enqueue(read_to(&m, 2, 9, 0, 0, 1));
        let (res, wake) = c.pump(1);
        assert!(res.is_empty());
        let w = wake.expect("needs wake");
        assert!(w >= TimingParams::ddr3_1600().t_ras);
    }

    #[test]
    fn commands_stream_observable() {
        let (mut c, m) = ctrl();
        c.enqueue(read_to(&m, 1, 4, 2, 1, 0));
        let (res, _) = c.pump(0);
        let cmds = &res[0].commands;
        assert_eq!(cmds.len(), 2);
        assert_eq!(cmds[0].kind, CommandKind::Act);
        assert_eq!(cmds[0].row, 4);
        assert_eq!(cmds[1].kind, CommandKind::Rd);
        assert_eq!(cmds[1].col, 2);
        assert!(cmds[0].at < cmds[1].at);
    }

    #[test]
    fn hit_rate_tracks() {
        let (mut c, m) = ctrl();
        c.enqueue(read_to(&m, 1, 1, 0, 0, 0));
        c.enqueue(read_to(&m, 2, 1, 1, 0, 0));
        c.enqueue(read_to(&m, 3, 1, 2, 0, 0));
        let _ = c.pump(1_000 * NS);
        // First is a miss, next two are hits.
        assert!((c.hit_rate() - 2.0 / 3.0).abs() < 1e-9);
    }
}
