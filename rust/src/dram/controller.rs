//! FR-FCFS memory controller over one channel.
//!
//! Transaction-granularity scheduling with exact command timestamps:
//! when the controller commits to servicing a transaction it walks the
//! PRE?/ACT?/RD|WR command sequence through the bank/rank/channel algebra,
//! claiming the command and data buses at each step. First-Ready FCFS:
//! row hits are prioritized over misses, ties broken by arrival order —
//! the policy commodity controllers implement and the one that produces
//! the twin-load row-miss spacing the paper relies on.
//!
//! ## Scheduling structure
//!
//! The queues are kept **per (rank, bank)**, sorted by `(arrive, id)`, with
//! a cached per-bank candidate summary (`BankCand`). The FR-FCFS pick
//! only has to compare two representatives per bank — the oldest row hit
//! and the oldest row miss — because within one bank every hit shares the
//! same column-ready time and every miss shares the same PRE/ACT-ready
//! time (bank and rank constraints are uniform across the bank's queue).
//! Servicing a transaction perturbs only its own rank's state (bank
//! timings, tRRD/tFAW window, read/write turnaround); the data-bus claim
//! is channel-global but does not enter first-command readiness.
//!
//! ## Invalidation granularity
//!
//! A cached summary only goes stale when a value it folded actually
//! moved. Rank-level changes are monotone `max` floors (turnaround,
//! tRRD/tFAW ACT bound), so a serviced command moves another bank's
//! summary **iff** the new floor exceeds the cached ready time — and the
//! bank-granular default ([`SchedPolicy::BankIndexed`]) invalidates
//! exactly those banks plus the serviced bank itself. The PR-1
//! whole-rank invalidation is retained as [`SchedPolicy::RankInval`]
//! (the intermediate differential stage) and the original full scan as
//! [`SchedPolicy::ReferenceScan`] (the oracle); all three are proven to
//! produce the same pick, same timestamps, bit-identical
//! [`ServiceResult`]s by differential property tests
//! (`rust/tests/proptests.rs`).

use super::address::DecodedAddr;
use super::channel::Channel;
use super::command::{Command, CommandSeq};
use super::timing::{Geometry, TimingParams};
use crate::util::time::Ps;

/// A read or write request at the controller.
#[derive(Debug, Clone, Copy)]
pub struct Transaction {
    pub id: u64,
    pub addr: DecodedAddr,
    pub is_write: bool,
    pub arrive: Ps,
}

/// Outcome of servicing one transaction.
#[derive(Debug, Clone, Copy)]
pub struct ServiceResult {
    pub id: u64,
    pub is_write: bool,
    pub addr: DecodedAddr,
    /// Column command (RD/WR) issue time.
    pub col_cmd_at: Ps,
    /// First / last data beat times.
    pub data_start: Ps,
    pub data_end: Ps,
    pub row_hit: bool,
    /// Full command sequence issued — consumed by the MEC model, which
    /// observes the DDR bus exactly as §4.3 describes (BST from ACTs,
    /// address reconstruction on RDs). Inline (at most PRE+ACT+column),
    /// so the hot path allocates nothing per serviced transaction.
    pub commands: CommandSeq,
}

/// Per-controller statistics.
#[derive(Debug, Default, Clone)]
pub struct CtrlStats {
    pub reads: u64,
    pub writes: u64,
    pub row_hits: u64,
    pub row_misses: u64,
    pub row_conflicts: u64,
    pub read_bytes: u64,
    pub write_bytes: u64,
    pub queue_peak: usize,
}

/// Which FR-FCFS pick implementation a controller runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedPolicy {
    /// Per-bank queues with cached ready-time summaries and
    /// bank-granular invalidation (the default): a serviced command
    /// invalidates only the banks whose cached ready times it moved.
    BankIndexed,
    /// Bank-indexed scheduling with the PR-1 rank-granular
    /// invalidation, retained as the intermediate differential stage.
    RankInval,
    /// The original O(queue) full scan, retained as the oracle for
    /// differential testing. Identical pick order and timestamps.
    ReferenceScan,
}

impl SchedPolicy {
    pub fn name(&self) -> &'static str {
        match self {
            SchedPolicy::BankIndexed => "bank-indexed",
            SchedPolicy::RankInval => "rank-inval",
            SchedPolicy::ReferenceScan => "reference-scan",
        }
    }

    pub fn by_name(name: &str) -> Option<SchedPolicy> {
        match name {
            "bank-indexed" | "bank" => Some(SchedPolicy::BankIndexed),
            "rank-inval" | "rank" => Some(SchedPolicy::RankInval),
            "reference-scan" | "ref-scan" | "scan" => Some(SchedPolicy::ReferenceScan),
            _ => None,
        }
    }
}

/// Cached scheduling summary for one bank's queue (one per direction).
///
/// Valid until the bank's queue or its rank's timing state changes;
/// `None` in the cache slot marks it stale.
#[derive(Debug, Clone, Copy)]
struct BankCand {
    /// Oldest row-hit candidate: (arrive, id, queue position).
    hit: Option<(Ps, u64, u32)>,
    /// Oldest row-miss/conflict candidate.
    miss: Option<(Ps, u64, u32)>,
    /// Ready component shared by every hit: the column command time.
    col_ready: Ps,
    /// Ready component shared by every miss: PRE if a row is open,
    /// ACT if the bank is closed.
    miss_ready: Ps,
}

/// Write-queue drain thresholds.
const WQ_HIGH: usize = 32;
const WQ_LOW: usize = 8;
/// Read queue capacity (admission control / backpressure signal).
pub const RQ_CAP: usize = 64;

#[derive(Debug, Clone)]
pub struct MemController {
    p: TimingParams,
    geo: Geometry,
    channel: Channel,
    /// Per-(rank, bank) read/write queues (rank-major flat index), each
    /// kept sorted by (arrive, id).
    rq: Vec<Vec<Transaction>>,
    wq: Vec<Vec<Transaction>>,
    rq_len: usize,
    wq_len: usize,
    /// Cached per-bank candidate summaries; `None` = stale.
    cand_r: Vec<Option<BankCand>>,
    cand_w: Vec<Option<BankCand>>,
    draining: bool,
    policy: SchedPolicy,
    pub stats: CtrlStats,
}

impl MemController {
    pub fn new(p: TimingParams, geo: Geometry) -> MemController {
        MemController::with_policy(p, geo, SchedPolicy::BankIndexed)
    }

    pub fn with_policy(p: TimingParams, geo: Geometry, policy: SchedPolicy) -> MemController {
        let nb = geo.total_banks() as usize;
        MemController {
            channel: Channel::new(&geo, &p),
            p,
            geo,
            rq: (0..nb).map(|_| Vec::with_capacity(8)).collect(),
            wq: (0..nb).map(|_| Vec::with_capacity(8)).collect(),
            rq_len: 0,
            wq_len: 0,
            cand_r: vec![None; nb],
            cand_w: vec![None; nb],
            draining: false,
            policy,
            stats: CtrlStats::default(),
        }
    }

    pub fn timing(&self) -> &TimingParams {
        &self.p
    }

    pub fn policy(&self) -> SchedPolicy {
        self.policy
    }

    pub fn queue_len(&self) -> usize {
        self.rq_len + self.wq_len
    }

    /// Channel-bus counters: (commands issued, data bursts transferred).
    pub fn bus_counts(&self) -> (u64, u64) {
        (self.channel.cmd_count, self.channel.data_bursts)
    }

    /// Data-bus utilization over `[0, now]` (fraction of the interval the
    /// bus spent transferring bursts).
    pub fn data_bus_util(&self, now: Ps) -> f64 {
        self.channel.data_utilization(now, &self.p)
    }

    pub fn has_room(&self) -> bool {
        self.rq_len < RQ_CAP
    }

    #[inline]
    fn flat_bank(&self, a: &DecodedAddr) -> usize {
        debug_assert!(a.rank < self.geo.ranks && a.bank < self.geo.banks_per_rank);
        (a.rank * self.geo.banks_per_rank + a.bank) as usize
    }

    pub fn enqueue(&mut self, t: Transaction) {
        let fb = self.flat_bank(&t.addr);
        let key = (t.arrive, t.id);
        let (q, cand) = if t.is_write {
            self.wq_len += 1;
            (&mut self.wq[fb], &mut self.cand_w[fb])
        } else {
            self.rq_len += 1;
            (&mut self.rq[fb], &mut self.cand_r[fb])
        };
        let pos = q.partition_point(|x| (x.arrive, x.id) <= key);
        q.insert(pos, t);
        *cand = None;
        self.stats.queue_peak = self.stats.queue_peak.max(self.rq_len + self.wq_len);
    }

    fn invalidate_rank(&mut self, rank: u32) {
        let bpr = self.geo.banks_per_rank as usize;
        let base = rank as usize * bpr;
        for fb in base..base + bpr {
            self.cand_r[fb] = None;
            self.cand_w[fb] = None;
        }
    }

    /// Bank-granular invalidation: after servicing a transaction on
    /// `serviced_fb`, drop only the summaries whose cached ready times
    /// actually moved. Rank-level state advances as monotone `max`
    /// floors, so for any *other* bank of the rank:
    ///
    /// * hits fold the rank turnaround into `col_ready`: the summary
    ///   moved iff the new turnaround floor exceeds the cached value;
    /// * misses on a *closed* bank fold the tRRD/tFAW ACT bound into
    ///   `miss_ready`: moved iff the new bound exceeds the cached value;
    /// * misses on an *open* bank wait on that bank's own PRE time,
    ///   which no other bank's commands can move.
    ///
    /// The serviced bank itself changed its queue, open row, and every
    /// timing field, so both its summaries always drop. Other ranks are
    /// untouched (the data-bus claim is channel-global but does not
    /// enter first-command readiness).
    fn invalidate_moved(&mut self, rank_i: u32, serviced_fb: usize) {
        let bpr = self.geo.banks_per_rank as usize;
        let base = rank_i as usize * bpr;
        let rank = &self.channel.ranks[rank_i as usize];
        let rd_turn = rank.rd_turn();
        let wr_turn = rank.wr_turn();
        let act_bound = rank.act_bound(&self.p);
        for b in 0..bpr {
            let fb = base + b;
            if fb == serviced_fb {
                self.cand_r[fb] = None;
                self.cand_w[fb] = None;
                continue;
            }
            let closed = rank.banks[b].open_row().is_none();
            if let Some(c) = self.cand_r[fb] {
                if rd_turn > c.col_ready || (closed && act_bound > c.miss_ready) {
                    self.cand_r[fb] = None;
                }
            }
            if let Some(c) = self.cand_w[fb] {
                if wr_turn > c.col_ready || (closed && act_bound > c.miss_ready) {
                    self.cand_w[fb] = None;
                }
            }
        }
    }

    fn invalidate_all(&mut self) {
        self.cand_r.fill(None);
        self.cand_w.fill(None);
    }

    /// Earliest time the *first* command of `t` could issue, plus whether
    /// it would be a row hit, given current bank state. (Used by the
    /// reference scan; the indexed path computes the same quantities once
    /// per bank in the cached `BankCand` summaries.)
    fn first_cmd_time(&self, t: &Transaction) -> (Ps, bool) {
        let rank = &self.channel.ranks[t.addr.rank as usize];
        let bank = &rank.banks[t.addr.bank as usize];
        let base = t.arrive;
        match bank.open_row() {
            Some(r) if r == t.addr.row => {
                let col = rank.earliest_col(t.addr.bank, t.is_write);
                (self.channel.earliest_cmd(col.max(base)), true)
            }
            Some(_) => {
                let pre = bank.earliest_pre();
                (self.channel.earliest_cmd(pre.max(base)), false)
            }
            None => {
                let act = rank.earliest_act(t.addr.bank, &self.p);
                (self.channel.earliest_cmd(act.max(base)), false)
            }
        }
    }

    /// Cached per-bank candidate summary; recomputes on a stale slot by a
    /// single pass over that bank's (sorted) queue.
    fn cand(&mut self, fb: usize, is_write: bool) -> BankCand {
        let cached = if is_write { self.cand_w[fb] } else { self.cand_r[fb] };
        if let Some(c) = cached {
            return c;
        }
        let bpr = self.geo.banks_per_rank as usize;
        let rank = &self.channel.ranks[fb / bpr];
        let bank_i = (fb % bpr) as u32;
        let bank = &rank.banks[bank_i as usize];
        let open = bank.open_row();
        let col_ready = rank.earliest_col(bank_i, is_write);
        let miss_ready = match open {
            Some(_) => bank.earliest_pre(),
            None => rank.earliest_act(bank_i, &self.p),
        };
        let q = if is_write { &self.wq[fb] } else { &self.rq[fb] };
        let mut hit = None;
        let mut miss = None;
        for (pos, t) in q.iter().enumerate() {
            let slot = if open == Some(t.addr.row) { &mut hit } else { &mut miss };
            if slot.is_none() {
                *slot = Some((t.arrive, t.id, pos as u32));
            }
            if hit.is_some() && miss.is_some() {
                break;
            }
        }
        let c = BankCand { hit, miss, col_ready, miss_ready };
        if is_write {
            self.cand_w[fb] = Some(c);
        } else {
            self.cand_r[fb] = Some(c);
        }
        c
    }

    /// One FR-FCFS pick over the given pool: the best candidate ready at
    /// `now` as (flat bank, queue position), plus the minimum ready time
    /// across the whole pool (the wake time when nothing is ready).
    fn scan(&mut self, now: Ps, is_write: bool) -> (Option<(usize, usize)>, Ps) {
        match self.policy {
            SchedPolicy::BankIndexed | SchedPolicy::RankInval => {
                self.scan_indexed(now, is_write)
            }
            SchedPolicy::ReferenceScan => self.scan_reference(now, is_write),
        }
    }

    fn scan_indexed(&mut self, now: Ps, is_write: bool) -> (Option<(usize, usize)>, Ps) {
        let nb = self.rq.len();
        // (is_hit, arrive, id, flat bank, queue position)
        let mut best: Option<(bool, Ps, u64, usize, usize)> = None;
        let mut min_ready = Ps::MAX;
        for fb in 0..nb {
            let empty = if is_write { self.wq[fb].is_empty() } else { self.rq[fb].is_empty() };
            if empty {
                continue;
            }
            let c = self.cand(fb, is_write);
            // Two representatives cover the bank: the oldest hit and the
            // oldest miss. Any other queued access of the same class has a
            // later (arrive, id) and the same ready component, so it can
            // be neither the pick nor the minimum ready time.
            let reprs = [(c.hit, true, c.col_ready), (c.miss, false, c.miss_ready)];
            for (repr, is_hit, component) in reprs {
                let Some((arrive, id, pos)) = repr else { continue };
                let ready = component.max(arrive);
                min_ready = min_ready.min(ready);
                if ready > now {
                    continue;
                }
                let better = match best {
                    None => true,
                    Some((bhit, barr, bid, _, _)) => {
                        (is_hit && !bhit) || (is_hit == bhit && (arrive, id) < (barr, bid))
                    }
                };
                if better {
                    best = Some((is_hit, arrive, id, fb, pos as usize));
                }
            }
        }
        (best.map(|(_, _, _, fb, pos)| (fb, pos)), min_ready)
    }

    fn scan_reference(&mut self, now: Ps, is_write: bool) -> (Option<(usize, usize)>, Ps) {
        let queues = if is_write { &self.wq } else { &self.rq };
        let mut best: Option<(bool, Ps, u64, usize, usize)> = None;
        let mut min_ready = Ps::MAX;
        for (fb, q) in queues.iter().enumerate() {
            for (pos, t) in q.iter().enumerate() {
                let (ready, hit) = self.first_cmd_time(t);
                min_ready = min_ready.min(ready);
                if ready > now {
                    continue;
                }
                let better = match best {
                    None => true,
                    Some((bhit, barr, bid, _, _)) => {
                        (hit && !bhit) || (hit == bhit && (t.arrive, t.id) < (barr, bid))
                    }
                };
                if better {
                    best = Some((hit, t.arrive, t.id, fb, pos));
                }
            }
        }
        (best.map(|(_, _, _, fb, pos)| (fb, pos)), min_ready)
    }

    /// Service one chosen transaction: walk its command sequence through
    /// the algebra and return the timed result.
    fn service(&mut self, t: Transaction) -> ServiceResult {
        let (rank_i, bank_i, row) = (t.addr.rank, t.addr.bank, t.addr.row);
        let mut commands = CommandSeq::new();
        let p = self.p;

        // 1. PRE if a different row is open (row conflict).
        let open = self.channel.ranks[rank_i as usize].open_row(bank_i);
        let row_hit = open == Some(row);
        let row_conflict = open.is_some() && !row_hit;
        if row_conflict {
            let pre_t = {
                let rank = &self.channel.ranks[rank_i as usize];
                self.channel
                    .earliest_cmd(rank.banks[bank_i as usize].earliest_pre().max(t.arrive))
            };
            self.channel.claim_cmd(pre_t, &p);
            self.channel.ranks[rank_i as usize].do_pre(pre_t, bank_i, &p);
            commands.push(Command::pre(rank_i, bank_i, pre_t));
            self.stats.row_conflicts += 1;
            self.channel.ranks[rank_i as usize].banks[bank_i as usize].row_conflicts += 1;
        }

        // 2. ACT if the bank is (now) closed. A conflict already counted
        // above — the re-opening ACT must not also count as a miss.
        if self.channel.ranks[rank_i as usize].open_row(bank_i).is_none() {
            let act_t = {
                let rank = &self.channel.ranks[rank_i as usize];
                self.channel.earliest_cmd(rank.earliest_act(bank_i, &p).max(t.arrive))
            };
            self.channel.claim_cmd(act_t, &p);
            self.channel.ranks[rank_i as usize].do_act(act_t, bank_i, row, &p);
            commands.push(Command::act(rank_i, bank_i, row, act_t));
            if !row_hit && !row_conflict {
                self.stats.row_misses += 1;
                self.channel.ranks[rank_i as usize].banks[bank_i as usize].row_misses += 1;
            }
        } else if row_hit {
            self.stats.row_hits += 1;
            self.channel.ranks[rank_i as usize].banks[bank_i as usize].row_hits += 1;
        }

        // 3. Column command; align with both command-bus and data-bus slots.
        let lat = if t.is_write { p.t_wl } else { p.t_rl };
        let col_t = {
            let rank = &self.channel.ranks[rank_i as usize];
            let ready = rank.earliest_col(bank_i, t.is_write).max(t.arrive);
            // Data burst starts `lat` after the column command: back-solve
            // so the data bus is free when the burst arrives.
            let mut ct = self.channel.earliest_cmd(ready);
            loop {
                let want_data = ct + lat;
                let data_ok = self.channel.earliest_data(want_data, rank_i, &p);
                if data_ok == want_data {
                    break;
                }
                ct = self.channel.earliest_cmd(data_ok - lat);
            }
            ct
        };
        self.channel.claim_cmd(col_t, &p);
        let data_end = if t.is_write {
            self.channel.ranks[rank_i as usize].do_wr(col_t, bank_i, &p)
        } else {
            self.channel.ranks[rank_i as usize].do_rd(col_t, bank_i, &p)
        };
        let data_start = col_t + lat;
        self.channel.claim_data(data_start, rank_i, &p);
        commands.push(if t.is_write {
            Command::wr(rank_i, bank_i, t.addr.col, col_t)
        } else {
            Command::rd(rank_i, bank_i, t.addr.col, col_t)
        });

        if t.is_write {
            self.stats.writes += 1;
            self.stats.write_bytes += 64;
        } else {
            self.stats.reads += 1;
            self.stats.read_bytes += 64;
        }

        ServiceResult {
            id: t.id,
            is_write: t.is_write,
            addr: t.addr,
            col_cmd_at: col_t,
            data_start,
            data_end,
            row_hit,
            commands,
        }
    }

    /// Advance the controller to `now`: run refreshes, service everything
    /// that is first-ready, appending results to the caller-owned `out`
    /// buffer (not cleared here — reuse it across calls to keep the hot
    /// loop allocation-free), and return the next wake time.
    ///
    /// The wake is `Some(t)` when work remains that becomes ready at `t`.
    pub fn pump(&mut self, now: Ps, out: &mut Vec<ServiceResult>) -> Option<Ps> {
        // Catch up on refreshes; a refresh rewrites every bank's timing.
        if self.channel.catch_up_refresh(now, &self.p) {
            self.invalidate_all();
        }

        loop {
            // Enter/leave write-drain mode.
            if self.wq_len >= WQ_HIGH || (self.rq_len == 0 && self.wq_len > 0) {
                self.draining = true;
            }
            if self.wq_len <= WQ_LOW && self.rq_len > 0 {
                self.draining = false;
            }

            // Candidate pool: reads normally; writes when draining. The
            // hysteresis above always selects a non-empty pool when either
            // queue has work, so an empty pool means an idle controller.
            let use_writes = self.draining && self.wq_len > 0;
            let pool_len = if use_writes { self.wq_len } else { self.rq_len };
            if pool_len == 0 {
                debug_assert!(self.rq_len == 0 && self.wq_len == 0);
                return None;
            }

            let (pick, min_ready) = self.scan(now, use_writes);
            match pick {
                Some((fb, pos)) => {
                    let t = if use_writes {
                        self.wq_len -= 1;
                        self.wq[fb].remove(pos)
                    } else {
                        self.rq_len -= 1;
                        self.rq[fb].remove(pos)
                    };
                    out.push(self.service(t));
                    // The serviced commands moved this rank's bank
                    // timings, ACT window, and turnaround state; other
                    // ranks' summaries always hold. The default narrows
                    // further to the banks whose cached ready times the
                    // service actually moved.
                    match self.policy {
                        SchedPolicy::BankIndexed => self.invalidate_moved(t.addr.rank, fb),
                        _ => self.invalidate_rank(t.addr.rank),
                    }
                }
                None => {
                    return if min_ready == Ps::MAX { None } else { Some(min_ready) };
                }
            }
        }
    }

    /// Read row-buffer hit rate so far.
    pub fn hit_rate(&self) -> f64 {
        let total = self.stats.row_hits + self.stats.row_misses + self.stats.row_conflicts;
        if total == 0 {
            0.0
        } else {
            self.stats.row_hits as f64 / total as f64
        }
    }

    pub fn geometry(&self) -> &Geometry {
        &self.geo
    }

    pub fn data_utilization(&self, now: Ps) -> f64 {
        self.channel.data_utilization(now, &self.p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dram::command::CommandKind;
    use crate::dram::address::AddressMapping;
    use crate::util::time::NS;

    fn ctrl() -> (MemController, AddressMapping) {
        let geo = Geometry::sim_small();
        (MemController::new(TimingParams::ddr3_1600(), geo), AddressMapping::new(&geo, 1))
    }

    fn read_to(
        map: &AddressMapping,
        id: u64,
        row: u32,
        col: u32,
        bank: u32,
        arrive: Ps,
    ) -> Transaction {
        let addr = DecodedAddr { channel: 0, rank: 0, bank, row, col };
        let _ = map;
        Transaction { id, addr, is_write: false, arrive }
    }

    fn pump_all(c: &mut MemController, now: Ps) -> (Vec<ServiceResult>, Option<Ps>) {
        let mut out = Vec::new();
        let wake = c.pump(now, &mut out);
        (out, wake)
    }

    #[test]
    fn single_read_closed_bank_latency() {
        let (mut c, m) = ctrl();
        c.enqueue(read_to(&m, 1, 5, 0, 0, 0));
        let (res, wake) = pump_all(&mut c, 0);
        assert_eq!(res.len(), 1);
        let r = &res[0];
        assert!(!r.row_hit);
        // ACT@0, RD@tRCD, data ends at tRCD+tRL+tBURST.
        let p = TimingParams::ddr3_1600();
        assert_eq!(r.data_end, p.closed_access());
        assert!(wake.is_none());
    }

    #[test]
    fn row_hit_prioritized_over_older_miss() {
        let (mut c, m) = ctrl();
        // Open row 1 on bank 0.
        c.enqueue(read_to(&m, 1, 1, 0, 0, 0));
        let _ = pump_all(&mut c, 0);
        // Older request misses (row 2), newer hits (row 1): FR-FCFS serves
        // the hit first.
        c.enqueue(read_to(&m, 2, 2, 0, 0, 10));
        c.enqueue(read_to(&m, 3, 1, 1, 0, 11));
        let (res, _) = pump_all(&mut c, 200 * NS);
        let order: Vec<u64> = res.iter().map(|r| r.id).collect();
        assert_eq!(order, vec![3, 2]);
        assert!(res[0].row_hit && !res[1].row_hit);
    }

    #[test]
    fn twin_pair_forced_row_miss_spacing() {
        // The twin-load core property: two loads to the same bank but rows
        // differing in the MSB are spaced by >= 35 ns at the column command.
        let (mut c, m) = ctrl();
        let row = 0x0123;
        let twin_row = row | (1 << 9); // MSB of sim_small's 10-bit row space
        c.enqueue(read_to(&m, 1, row, 7, 3, 0));
        c.enqueue(read_to(&m, 2, twin_row, 7, 3, 0));
        let (res, _) = pump_all(&mut c, 1_000 * NS);
        assert_eq!(res.len(), 2);
        let gap = res[1].col_cmd_at - res[0].col_cmd_at;
        assert!(
            gap >= TimingParams::ddr3_1600().row_miss_turnaround(),
            "twin spacing {gap} < 35ns"
        );
    }

    #[test]
    fn bank_parallel_reads_overlap() {
        let (mut c, m) = ctrl();
        c.enqueue(read_to(&m, 1, 1, 0, 0, 0));
        c.enqueue(read_to(&m, 2, 1, 0, 1, 0));
        let (res, _) = pump_all(&mut c, 1_000 * NS);
        let p = TimingParams::ddr3_1600();
        // Both finish well before 2x the serial closed-access latency.
        let last = res.iter().map(|r| r.data_end).max().unwrap();
        assert!(last < 2 * p.closed_access(), "no bank overlap: {last}");
    }

    #[test]
    fn writes_drain_when_no_reads() {
        let (mut c, m) = ctrl();
        let mut t = read_to(&m, 1, 3, 0, 0, 0);
        t.is_write = true;
        c.enqueue(t);
        let (res, _) = pump_all(&mut c, 0);
        assert_eq!(res.len(), 1);
        assert!(res[0].is_write);
        assert_eq!(c.stats.writes, 1);
    }

    #[test]
    fn not_ready_returns_wake_time() {
        let (mut c, m) = ctrl();
        c.enqueue(read_to(&m, 1, 1, 0, 0, 0));
        let _ = pump_all(&mut c, 0);
        // Conflict on same bank: PRE can't go until tRAS; pumping at t=1
        // must return a wake time instead of servicing.
        c.enqueue(read_to(&m, 2, 9, 0, 0, 1));
        let (res, wake) = pump_all(&mut c, 1);
        assert!(res.is_empty());
        let w = wake.expect("needs wake");
        assert!(w >= TimingParams::ddr3_1600().t_ras);
    }

    #[test]
    fn commands_stream_observable() {
        let (mut c, m) = ctrl();
        c.enqueue(read_to(&m, 1, 4, 2, 1, 0));
        let (res, _) = pump_all(&mut c, 0);
        let cmds = &res[0].commands;
        assert_eq!(cmds.len(), 2);
        assert_eq!(cmds[0].kind, CommandKind::Act);
        assert_eq!(cmds[0].row, 4);
        assert_eq!(cmds[1].kind, CommandKind::Rd);
        assert_eq!(cmds[1].col, 2);
        assert!(cmds[0].at < cmds[1].at);
    }

    #[test]
    fn hit_rate_tracks() {
        let (mut c, m) = ctrl();
        c.enqueue(read_to(&m, 1, 1, 0, 0, 0));
        c.enqueue(read_to(&m, 2, 1, 1, 0, 0));
        c.enqueue(read_to(&m, 3, 1, 2, 0, 0));
        let _ = pump_all(&mut c, 1_000 * NS);
        // First is a miss, next two are hits.
        assert!((c.hit_rate() - 2.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn row_conflict_counted_exactly_once() {
        // Regression: the ACT that re-opens a precharged bank after a
        // conflict must not also increment the miss counter.
        let (mut c, m) = ctrl();
        c.enqueue(read_to(&m, 1, 1, 0, 0, 0));
        let _ = pump_all(&mut c, 1_000 * NS);
        c.enqueue(read_to(&m, 2, 2, 0, 0, 1_000 * NS));
        let _ = pump_all(&mut c, 10_000 * NS);
        assert_eq!(c.stats.row_misses, 1, "only the initial closed-bank miss");
        assert_eq!(c.stats.row_conflicts, 1);
        assert_eq!(c.stats.row_hits, 0);
        // Denominator no longer double-counts the conflict.
        assert_eq!(c.stats.row_hits + c.stats.row_misses + c.stats.row_conflicts, 2);
        assert_eq!(c.hit_rate(), 0.0);
    }

    #[test]
    fn pump_appends_without_clearing() {
        let (mut c, m) = ctrl();
        c.enqueue(read_to(&m, 1, 1, 0, 0, 0));
        let mut out = Vec::new();
        c.pump(0, &mut out);
        c.enqueue(read_to(&m, 2, 1, 1, 0, 100 * NS));
        c.pump(200 * NS, &mut out);
        let ids: Vec<u64> = out.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![1, 2]);
    }

    #[test]
    fn all_policies_match_reference_scan() {
        let geo = Geometry::sim_small();
        let p = TimingParams::ddr3_1600();
        let mut slow = MemController::with_policy(p, geo, SchedPolicy::ReferenceScan);
        let mut others = [
            MemController::with_policy(p, geo, SchedPolicy::BankIndexed),
            MemController::with_policy(p, geo, SchedPolicy::RankInval),
        ];
        let m = AddressMapping::new(&geo, 1);
        // Same-bank conflicts, a row hit, a cross-rank read, and a write.
        let txns = [
            read_to(&m, 1, 1, 0, 0, 0),
            read_to(&m, 2, 2, 0, 0, 5),
            read_to(&m, 3, 1, 3, 0, 10),
            read_to(&m, 4, 7, 0, 5, 12),
            Transaction {
                id: 5,
                addr: DecodedAddr { channel: 0, rank: 1, bank: 2, row: 9, col: 4 },
                is_write: true,
                arrive: 20,
            },
        ];
        for t in txns {
            slow.enqueue(t);
            for c in others.iter_mut() {
                c.enqueue(t);
            }
        }
        let mut now = 0;
        for _ in 0..100 {
            let (rs, ws) = pump_all(&mut slow, now);
            for fast in others.iter_mut() {
                let tag = fast.policy().name();
                let (rf, wf) = pump_all(fast, now);
                assert_eq!(rf.len(), rs.len(), "{tag}");
                for (a, b) in rf.iter().zip(rs.iter()) {
                    assert_eq!(
                        (a.id, a.col_cmd_at, a.data_start, a.data_end, a.row_hit),
                        (b.id, b.col_cmd_at, b.data_start, b.data_end, b.row_hit),
                        "{tag}"
                    );
                }
                assert_eq!(wf, ws, "{tag}");
            }
            match ws {
                Some(w) => now = w,
                None => break,
            }
        }
        assert_eq!(slow.queue_len(), 0);
        for fast in &others {
            let tag = fast.policy().name();
            assert_eq!(fast.queue_len(), 0, "{tag}");
            assert_eq!(fast.stats.row_hits, slow.stats.row_hits, "{tag}");
            assert_eq!(fast.stats.row_misses, slow.stats.row_misses, "{tag}");
            assert_eq!(fast.stats.row_conflicts, slow.stats.row_conflicts, "{tag}");
        }
    }

    #[test]
    fn sched_policy_names_round_trip() {
        for p in [SchedPolicy::BankIndexed, SchedPolicy::RankInval, SchedPolicy::ReferenceScan] {
            assert_eq!(SchedPolicy::by_name(p.name()), Some(p));
        }
        assert_eq!(SchedPolicy::by_name("ref-scan"), Some(SchedPolicy::ReferenceScan));
        assert!(SchedPolicy::by_name("bogus").is_none());
    }

    #[test]
    fn bank_granular_invalidation_preserves_cross_bank_act_bound() {
        // Four fast ACTs on banks 0-3 put tFAW in play; a queued miss on
        // bank 5 cached its ACT-ready before the window filled. The
        // bank-granular policy must still serve it no earlier than the
        // reference scan says it may.
        let geo = Geometry::sim_small();
        let p = TimingParams::ddr3_1600();
        let mut fast = MemController::new(p, geo);
        let mut slow = MemController::with_policy(p, geo, SchedPolicy::ReferenceScan);
        let m = AddressMapping::new(&geo, 1);
        for (i, bank) in [0u32, 1, 2, 3, 5].iter().enumerate() {
            let t = read_to(&m, i as u64 + 1, 1, 0, *bank, i as u64);
            fast.enqueue(t);
            slow.enqueue(t);
        }
        let mut now = 0;
        loop {
            let (rf, wf) = pump_all(&mut fast, now);
            let (rs, ws) = pump_all(&mut slow, now);
            assert_eq!(rf.len(), rs.len());
            for (a, b) in rf.iter().zip(rs.iter()) {
                assert_eq!((a.id, a.col_cmd_at), (b.id, b.col_cmd_at));
            }
            assert_eq!(wf, ws);
            match wf {
                Some(w) => now = w,
                None => break,
            }
        }
        // The 5th ACT (bank 5) was tFAW-bound against the first.
        assert_eq!(fast.stats.row_misses, 5);
    }
}
