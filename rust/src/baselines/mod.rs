//! Baseline extension mechanisms the paper compares against (§5, Table 3).
//!
//! * [`numa`] — extra processors attached over QPI (§2.3): extended
//!   accesses pay a per-hop interconnect latency.
//! * [`pcie`] — remote memory behind PCIe with OS page swapping (§2.4,
//!   §6.3): non-resident pages fault and swap at microsecond cost.
//! * [`trl`] — "just raise tRL" (§7.2): a single load with a longer read
//!   latency, which holds the bank and kills concurrency.
//!
//! `Ideal` needs no module: it is the untransformed stream on local
//! timing.
//!
//! Each baseline's state plugs into the platform through the
//! extension-memory backend layer ([`crate::sim::backend`]): the
//! [`NumaLink`] rides the `Numa` backend variant (ingress crossing +
//! egress hop), the [`PcieSwap`] pool rides the `Pcie` variant (faulted
//! from the memory port), and [`increased_trl`] derives the `IncreasedTrl`
//! variant's channel timing — no baseline is special-cased inside the
//! platform itself.

pub mod numa;
pub mod pcie;
pub mod trl;

pub use numa::NumaLink;
pub use pcie::{PcieSwap, SwapOutcome};
pub use trl::increased_trl;
