//! "Increase tRL" comparison (§7.2, Figure 15).
//!
//! Instead of twin loads, extend JEDEC's read latency so one load covers
//! the extended round trip. The catch the paper simulates: the bank must
//! stay open until its data has been transferred, so the longer tRL also
//! delays the PRE for a row turnaround — concurrency on the bank drops as
//! tRL grows, which is why this scheme loses to twin-load at high
//! latencies even though it wins at small ones.

use crate::dram::timing::TimingParams;
use crate::util::time::Ps;

/// Derive an extended-channel timing with `extra` added to tRL.
///
/// The RD→PRE constraint becomes `max(tRTP, tRL′)`: the row may not close
/// before the (now much later) data transfer has begun — the bank-holding
/// effect §7.2 describes. All other parameters are unchanged.
pub fn increased_trl(base: &TimingParams, extra: Ps) -> TimingParams {
    let t_rl = base.t_rl + extra;
    TimingParams {
        t_rl,
        t_rtp: base.t_rtp.max(t_rl),
        ..*base
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dram::bank::Bank;
    use crate::util::time::NS;

    #[test]
    fn zero_extra_changes_nothing_but_rtp_floor() {
        let base = TimingParams::ddr3_1600();
        let t = increased_trl(&base, 0);
        assert_eq!(t.t_rl, base.t_rl);
        // tRTP floors at tRL even for zero extra (13.75 > 7.5).
        assert_eq!(t.t_rtp, base.t_rl);
    }

    #[test]
    fn extra_latency_extends_bank_holding() {
        let base = TimingParams::ddr3_1600();
        let t = increased_trl(&base, 100 * NS);
        assert_eq!(t.t_rl, base.t_rl + 100 * NS);
        assert_eq!(t.t_rtp, base.t_rl + 100 * NS);
        t.validate().unwrap();
    }

    #[test]
    fn bank_throughput_degrades_with_trl() {
        // Row-miss ping-pong on one bank: time per access grows by ~extra.
        let run = |p: &TimingParams| -> Ps {
            let mut b = Bank::new();
            let mut t = 0;
            for i in 0..10u32 {
                let act = b.earliest_act().max(t);
                b.do_act(act, i, p);
                let rd = b.earliest_rd();
                b.do_rd(rd, p);
                let pre = b.earliest_pre();
                b.do_pre(pre, p);
                t = pre;
            }
            t
        };
        let base = TimingParams::ddr3_1600();
        let slow = increased_trl(&base, 60 * NS);
        let t_base = run(&base);
        let t_slow = run(&slow);
        assert!(
            t_slow > t_base + 9 * 50 * NS,
            "bank holding not modeled: base={t_base} slow={t_slow}"
        );
    }
}
