//! PCIe remote-memory page swapping (§2.4, §6.3; Lim et al. \[36,38\]).
//!
//! Data lives on a remote memory blade; only pages resident in local DRAM
//! are directly accessible. A non-resident access page-faults: the OS
//! synchronously swaps the page in over PCIe/DMA (evicting the local LRU
//! page). The paper measures 7.8 µs per swap on its prototype and then
//! *doubles* the measured performance when reporting, to compensate for
//! Linux's slow swap path vs the fastest published policy — the Figure-13
//! bench applies the same compensation.

use crate::util::time::{Ps, NS};
use crate::util::FastMap;

/// Default page size (matches the TLB model).
pub const PAGE_BYTES: u64 = 4 << 10;

/// Result of consulting the swap manager for one access.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SwapOutcome {
    /// Page resident: access proceeds at local DRAM cost.
    Resident,
    /// Page fault: the core is blocked for the swap duration; the evicted
    /// page (if any) is returned for bookkeeping.
    Fault { swap_done: Ps, evicted: Option<u64> },
}

/// LRU page residency over a fixed pool of local frames.
#[derive(Debug)]
pub struct PcieSwap {
    /// Local frame budget in pages.
    capacity: usize,
    /// page number -> LRU stamp. Keyed by the fast integer hasher (the
    /// last std-hasher map on a simulated path); LRU stamps are unique
    /// (one clock tick per access), so the victim scan's result is
    /// independent of iteration order and the hasher swap is
    /// behavior-preserving.
    resident: FastMap<u64, u64>,
    clock: u64,
    /// Swap service time per page (paper: 7.8 µs).
    pub swap_cost: Ps,
    /// The device services one swap at a time (DMA engine serialization).
    next_free: Ps,
    pub faults: u64,
    pub hits: u64,
}

impl PcieSwap {
    pub fn new(capacity_pages: usize, swap_cost: Ps) -> PcieSwap {
        assert!(capacity_pages > 0);
        PcieSwap {
            capacity: capacity_pages,
            resident: FastMap::with_capacity_and_hasher(capacity_pages * 2, Default::default()),
            clock: 0,
            swap_cost,
            next_free: 0,
            faults: 0,
            hits: 0,
        }
    }

    /// Paper prototype: 7.8 µs per page swap.
    pub fn paper(capacity_pages: usize) -> PcieSwap {
        PcieSwap::new(capacity_pages, 7_800 * NS)
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn resident_pages(&self) -> usize {
        self.resident.len()
    }

    /// Access `vaddr` at time `now`.
    pub fn access(&mut self, vaddr: u64, now: Ps) -> SwapOutcome {
        self.clock += 1;
        let page = vaddr / PAGE_BYTES;
        if let Some(stamp) = self.resident.get_mut(&page) {
            *stamp = self.clock;
            self.hits += 1;
            return SwapOutcome::Resident;
        }
        // First touch with a free frame is warm: long-running services
        // fault their working set in once, which a short simulation must
        // not charge against steady state (the paper's runs are hours).
        if self.resident.len() < self.capacity {
            self.resident.insert(page, self.clock);
            self.hits += 1;
            return SwapOutcome::Resident;
        }
        self.faults += 1;
        let evicted = if self.resident.len() >= self.capacity {
            // Evict the LRU page (linear scan: the map is the frame pool,
            // sized in the thousands; fine off the simulator hot path).
            let (&lru, _) = self
                .resident
                .iter()
                .min_by_key(|(_, &stamp)| stamp)
                .expect("non-empty");
            self.resident.remove(&lru);
            Some(lru)
        } else {
            None
        };
        let start = now.max(self.next_free);
        let swap_done = start + self.swap_cost;
        self.next_free = swap_done;
        self.resident.insert(page, self.clock);
        SwapOutcome::Fault { swap_done, evicted }
    }

    pub fn fault_rate(&self) -> f64 {
        let total = self.faults + self.hits;
        if total == 0 {
            0.0
        } else {
            self.faults as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Fill all frames (warm first-touch), so the next distinct page faults.
    fn filled(capacity: usize, cost: Ps) -> PcieSwap {
        let mut s = PcieSwap::new(capacity, cost);
        for i in 0..capacity as u64 {
            assert_eq!(s.access(i * PAGE_BYTES, 0), SwapOutcome::Resident);
        }
        s
    }

    #[test]
    fn warm_start_then_resident_hits() {
        let mut s = PcieSwap::paper(4);
        // First touches with free frames are warm (no cold-fault charge).
        let o1 = s.access(0x1000, 0);
        assert_eq!(o1, SwapOutcome::Resident);
        let o2 = s.access(0x1040, 100);
        assert_eq!(o2, SwapOutcome::Resident);
        assert_eq!(s.faults, 0);
    }

    #[test]
    fn fault_costs_7_8us_once_full() {
        let mut s = filled(4, 7_800 * NS);
        match s.access(100 * PAGE_BYTES, 1000) {
            SwapOutcome::Fault { swap_done, evicted } => {
                assert_eq!(swap_done, 1000 + 7_800 * NS);
                assert!(evicted.is_some());
            }
            _ => panic!("expected a fault with all frames occupied"),
        }
    }

    #[test]
    fn lru_eviction_when_full() {
        let mut s = PcieSwap::new(2, 100);
        s.access(0, 0);
        s.access(PAGE_BYTES, 10);
        s.access(0, 20); // touch page 0: page 1 becomes LRU
        match s.access(2 * PAGE_BYTES, 30) {
            SwapOutcome::Fault { evicted, .. } => assert_eq!(evicted, Some(1)),
            _ => panic!(),
        }
        // Page 0 still resident.
        assert_eq!(s.access(0, 40), SwapOutcome::Resident);
    }

    #[test]
    fn swap_device_serializes() {
        let mut s = filled(8, 1000);
        let d1 = match s.access(100 * PAGE_BYTES, 0) {
            SwapOutcome::Fault { swap_done, .. } => swap_done,
            _ => panic!(),
        };
        let d2 = match s.access(101 * PAGE_BYTES, 0) {
            SwapOutcome::Fault { swap_done, .. } => swap_done,
            _ => panic!(),
        };
        assert_eq!(d2, d1 + 1000);
    }

    #[test]
    fn fault_rate_metric() {
        let mut s = filled(4, 100);
        // Ping-pong across 8 pages with 4 frames: every access faults.
        for i in 0..16u64 {
            s.access((i % 8) * PAGE_BYTES, 1000 + i);
        }
        assert!(s.fault_rate() > 0.4, "rate {}", s.fault_rate());
    }
}
