//! NUMA (QPI) extension model (§2.3, §6.2).
//!
//! The paper measures ≈100 ns local and ≈170 ns remote access on its
//! host, i.e. ≈70 ns added by one QPI hop (Molka et al. report 58–110 ns
//! per hop). The model: extended-memory requests traverse the link (fixed
//! latency each way + limited link bandwidth) to a remote controller.

use crate::util::time::{Ps, NS};

/// One cache line per transfer.
const LINE_BYTES: u64 = 64;

/// A QPI-like coherent link.
#[derive(Debug, Clone)]
pub struct NumaLink {
    /// One-way latency (≈ half the 70 ns round-trip addition).
    pub one_way: Ps,
    /// Link bandwidth in bytes/ps (QPI 8 GT/s ≈ 16 GB/s usable: 0.016).
    bytes_per_ps: f64,
    next_free: Ps,
    pub transfers: u64,
    pub stalled: u64,
}

impl NumaLink {
    pub fn new(one_way: Ps, gbytes_per_s: f64) -> NumaLink {
        NumaLink {
            one_way,
            bytes_per_ps: gbytes_per_s * 1e9 * 1e-12,
            next_free: 0,
            transfers: 0,
            stalled: 0,
        }
    }

    /// The paper host's interconnect: 70 ns round-trip addition; dual
    /// QPI links on E5-2600 give ~25.6 GB/s usable per direction.
    pub fn qpi() -> NumaLink {
        NumaLink::new(35 * NS, 25.6)
    }

    /// Serialization time of one line on the link.
    pub fn line_time(&self) -> Ps {
        (LINE_BYTES as f64 / self.bytes_per_ps) as Ps
    }

    /// Request crosses the link at `t`; returns arrival at the remote
    /// controller (bandwidth-limited).
    pub fn cross(&mut self, t: Ps) -> Ps {
        let start = t.max(self.next_free);
        if start > t {
            self.stalled += 1;
        }
        self.next_free = start + self.line_time();
        self.transfers += 1;
        start + self.one_way
    }

    /// Full remote penalty for a round trip starting at `t`: out + back.
    pub fn round_trip_from(&mut self, t: Ps) -> Ps {
        let at_remote = self.cross(t);
        at_remote + self.one_way - t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qpi_adds_70ns_round_trip() {
        let mut l = NumaLink::qpi();
        let rt = l.round_trip_from(0);
        assert_eq!(rt, 70 * NS);
    }

    #[test]
    fn bandwidth_serializes_lines() {
        let mut l = NumaLink::qpi();
        // 64 B at 25.6 GB/s = 2.5 ns per line.
        assert_eq!(l.line_time(), 2_500);
        let a = l.cross(0);
        let b = l.cross(0);
        assert_eq!(b - a, l.line_time());
        assert_eq!(l.stalled, 1);
    }

    #[test]
    fn idle_link_no_stall() {
        let mut l = NumaLink::qpi();
        l.cross(0);
        l.cross(100 * NS);
        assert_eq!(l.stalled, 0);
    }
}
