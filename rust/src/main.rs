//! `twinload` — CLI for the Twin-Load reproduction.
//!
//! Subcommands:
//!   run       simulate one (mechanism, workload) pair
//!   repro     regenerate a paper table/figure (table1..5, fig7..fig15, all)
//!   ablate    design-choice sweeps (lvc | layers | batch | scm | smt | amu | mims | faults | degrade)
//!   serve     open-loop latency-throughput sweep (offered load x mechanism)
//!   validate  cross-check the PJRT analytic fast path vs the cycle sim
//!   list      show mechanisms and workloads

use twinload::cli::Args;
use twinload::config::{parser as cfgparser, RunSpec, SystemConfig};
use twinload::coordinator::{experiments as exp, fastpath};
use twinload::sim::{run_spec, try_run_spec};
use twinload::twinload::Mechanism;
use twinload::workloads::{WorkloadKind, ALL_WORKLOADS};

const VALUE_FLAGS: &[&str] = &[
    "mechanism",
    "workload",
    "ops",
    "cores",
    "footprint-mb",
    "seed",
    "config",
    "csv-dir",
    "trl-extra-ns",
    "pcie-local-frac",
    "amu-depth",
    "amu-issue-ns",
    "amu-notify-ns",
    "amu-svc-ps",
    "mims-pack",
    "mims-frame-ns",
    "mims-granule",
    "engine",
    "sched",
    "frontend",
    "routing",
    "fault-rate",
    "fault-ecc-rate",
    "fault-seed",
    "demote-after",
    "fault-poll-timeout-ns",
    "fault-reissue-max",
    "fault-backoff-mult",
    "burst-rate",
    "burst-len-ns",
    "burst-slow-mult",
    "quarantine-threshold",
    "probe-ok",
    "slo-p99-us",
    "arrival",
    "offered-rps",
    "zipf-theta",
    "arrival-seed",
    "queue-depth",
    "sample-period",
    "sample-warmup",
    "sample-detail",
    "sample-seed",
];

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = match Args::parse(argv, VALUE_FLAGS) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let code = match args.subcommand.as_deref() {
        Some("run") => cmd_run(&args),
        Some("repro") => cmd_repro(&args),
        Some("ablate") => cmd_ablate(&args),
        Some("serve") => cmd_serve(&args),
        Some("validate") => cmd_validate(&args),
        Some("list") => cmd_list(),
        _ => {
            print_usage();
            2
        }
    };
    std::process::exit(code);
}

fn print_usage() {
    eprintln!(
        "usage: twinload <run|repro|ablate|serve|validate|list> [options]\n\
         \n\
         twinload run --mechanism tl-ooo --workload gups [--ops N] [--cores C]\n\
         \x20            [--footprint-mb M] [--seed S] [--config file.ini]\n\
         \x20            [--engine calendar|adaptive-calendar|reference-heap|sharded]\n\
         \x20            [--sched bank-indexed|rank-inval|reference-scan]\n\
         \x20            [--frontend slab|reference] [--routing backend|legacy]\n\
         \x20            [--amu-depth N] [--amu-issue-ns N] [--amu-notify-ns N]\n\
         \x20            [--amu-svc-ps N] [--mims-pack N] [--mims-frame-ns N]\n\
         \x20            [--mims-granule N]\n\
         \x20            [--fault-rate F] [--fault-ecc-rate F] [--fault-seed S]\n\
         \x20            [--demote-after K] [--fault-poll-timeout-ns N]\n\
         \x20            [--fault-reissue-max N] [--fault-backoff-mult N]\n\
         \x20            [--burst-rate F] [--burst-len-ns N] [--burst-slow-mult N]\n\
         \x20            [--quarantine-threshold F] [--probe-ok N] [--slo-p99-us N]\n\
         \x20            [--arrival closed|poisson|mmpp] [--offered-rps N]\n\
         \x20            [--zipf-theta F] [--arrival-seed S] [--queue-depth N]\n\
         \x20            [--sample-period N] [--sample-warmup N] [--sample-detail N]\n\
         \x20            [--sample-seed S]\n\
         twinload repro <table1|table2|table3|table4|table5|fig7|fig8|fig9|\n\
         \x20            fig10|fig11|fig12|fig13|fig14|fig15|all> [--quick] [--csv-dir DIR]\n\
         twinload ablate <lvc|layers|batch|scm|smt|amu|mims|faults|degrade> [--quick]\n\
         twinload serve [--quick] [--sampled] [--slo-p99-us N] [--csv-dir DIR]\n\
         twinload validate\n\
         twinload list"
    );
}

fn scale_from(args: &Args) -> exp::Scale {
    if args.has("quick") {
        exp::Scale::quick()
    } else {
        exp::Scale::full()
    }
}

fn cmd_run(args: &Args) -> i32 {
    let mech = args.get_or("mechanism", "tl-ooo");
    let Some(mut cfg) = SystemConfig::by_name(mech) else {
        eprintln!("unknown mechanism '{mech}' (see `twinload list`)");
        return 2;
    };
    let wl_name = args.get_or("workload", "gups");
    let Some(wl) = WorkloadKind::from_name(wl_name) else {
        eprintln!("unknown workload '{wl_name}' (see `twinload list`)");
        return 2;
    };
    let mut spec = RunSpec::medium(wl);
    if let Some(path) = args.get("config") {
        let text = match std::fs::read_to_string(path) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("reading {path}: {e}");
                return 2;
            }
        };
        let ini = match cfgparser::Ini::parse(&text) {
            Ok(i) => i,
            Err(e) => {
                eprintln!("{path}: {e}");
                return 2;
            }
        };
        if let Err(e) = cfgparser::apply(&ini, &mut cfg, &mut spec) {
            eprintln!("{path}: {e}");
            return 2;
        }
    }
    // CLI overrides after config file.
    macro_rules! flag {
        ($name:expr, $apply:expr) => {
            match args.get_u64($name) {
                Ok(Some(v)) => $apply(v),
                Ok(None) => {}
                Err(e) => {
                    eprintln!("{e}");
                    return 2;
                }
            }
        };
    }
    flag!("ops", |v| spec.ops_per_core = v);
    flag!("cores", |v| cfg.cores = v as usize);
    flag!("footprint-mb", |v: u64| spec.footprint = v << 20);
    flag!("seed", |v| spec.seed = v);
    flag!("trl-extra-ns", |v: u64| cfg.trl_extra = v * 1000);
    flag!("amu-depth", |v| cfg.amu_depth = v as usize);
    flag!("amu-issue-ns", |v: u64| cfg.amu_issue = v * 1000);
    flag!("amu-notify-ns", |v: u64| cfg.amu_notify = v * 1000);
    flag!("amu-svc-ps", |v| cfg.amu_svc = v);
    flag!("mims-pack", |v| {
        cfg.mims_pack = v as u32;
        if let Mechanism::Mims(_) = cfg.mechanism {
            cfg.mechanism = Mechanism::Mims(v as u32);
        }
    });
    flag!("mims-frame-ns", |v: u64| cfg.mims_frame = v * 1000);
    flag!("mims-granule", |v| cfg.mims_granule = v as u32);
    flag!("fault-seed", |v| cfg.fault_seed = v);
    flag!("demote-after", |v| cfg.demote_after = v as u32);
    flag!("fault-poll-timeout-ns", |v: u64| cfg.fault_poll_timeout = v * 1000);
    flag!("fault-reissue-max", |v| cfg.fault_reissue_max = v as u32);
    flag!("fault-backoff-mult", |v| cfg.fault_backoff_mult = v as u32);
    flag!("burst-len-ns", |v: u64| cfg.burst_len = v * 1000);
    flag!("burst-slow-mult", |v| cfg.burst_slow_mult = v);
    flag!("probe-ok", |v| cfg.probe_ok = v as u32);
    flag!("slo-p99-us", |v| cfg.slo_p99_us = v);
    flag!("offered-rps", |v| spec.offered_rps = v);
    flag!("arrival-seed", |v| spec.arrival_seed = v);
    flag!("queue-depth", |v| spec.queue_depth = v as u32);
    flag!("sample-period", |v| spec.sample_period = v);
    flag!("sample-warmup", |v| spec.sample_warmup = v);
    flag!("sample-detail", |v| spec.sample_detail = v);
    flag!("sample-seed", |v| spec.sample_seed = v);
    if let Ok(Some(f)) = args.get_f64("zipf-theta") {
        spec.zipf_theta = f;
    }
    if let Some(name) = args.get("arrival") {
        let Some(kind) = twinload::workloads::arrival::ArrivalKind::by_name(name) else {
            eprintln!("unknown arrival process '{name}' (closed | poisson | mmpp)");
            return 2;
        };
        spec.arrival = kind;
    }
    if let Ok(Some(f)) = args.get_f64("pcie-local-frac") {
        cfg.pcie_local_frac = f;
    }
    if let Ok(Some(f)) = args.get_f64("fault-rate") {
        cfg.fault_rate = f;
    }
    if let Ok(Some(f)) = args.get_f64("fault-ecc-rate") {
        cfg.fault_ecc_rate = f;
    }
    if let Ok(Some(f)) = args.get_f64("burst-rate") {
        cfg.burst_rate = f;
    }
    if let Ok(Some(f)) = args.get_f64("quarantine-threshold") {
        cfg.quarantine_threshold = f;
    }
    if let Some(name) = args.get("engine") {
        let Some(kind) = twinload::sim::engine::EngineKind::by_name(name) else {
            eprintln!(
                "unknown engine '{name}' (calendar | adaptive-calendar | reference-heap | sharded)"
            );
            return 2;
        };
        cfg.engine = kind;
    }
    if let Some(name) = args.get("sched") {
        let Some(policy) = twinload::dram::SchedPolicy::by_name(name) else {
            eprintln!("unknown sched policy '{name}' (bank-indexed | rank-inval | reference-scan)");
            return 2;
        };
        cfg.sched = policy;
    }
    if let Some(name) = args.get("frontend") {
        let Some(fe) = twinload::cpu::FrontEnd::by_name(name) else {
            eprintln!("unknown frontend '{name}' (slab | reference)");
            return 2;
        };
        cfg.frontend = fe;
    }
    if let Some(name) = args.get("routing") {
        let Some(routing) = twinload::sim::Routing::by_name(name) else {
            eprintln!("unknown routing '{name}' (backend | legacy)");
            return 2;
        };
        cfg.routing = routing;
    }

    let report = match try_run_spec(&cfg, &spec) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("error: {e:#}");
            return 2;
        }
    };
    println!("{}", report.summary());
    println!(
        "  runtime       {:>12.3} us\n  retired insts {:>12}\n  IPC           {:>12.3}\n  \
         LLC misses    {:>12}\n  TLB misses    {:>12}\n  DRAM reads    {:>12}\n  \
         DRAM writes   {:>12}\n  read BW       {:>9.2} GB/s\n  outstanding   {:>12.1}\n  \
         row-hit rate  {:>11.1}%\n  ext accesses  {:>11.1}%\n  twin retries  {:>12}\n  \
         cas fails     {:>12}",
        report.runtime_ns() / 1000.0,
        report.retired_insts,
        report.ipc(),
        report.llc_misses,
        report.tlb_misses,
        report.dram_reads,
        report.dram_writes,
        report.read_bandwidth_gbps(),
        report.mlp_mean,
        report.row_hit_rate * 100.0,
        report.transform.ext_fraction() * 100.0,
        report.twin_retries,
        report.cas_fails,
    );
    println!(
        "  bus util      {:>11.1}%  ({} commands)",
        report.data_bus_util * 100.0,
        report.dram_cmds,
    );
    if report.amu_requests > 0 {
        println!(
            "  amu queue     {:>12} requests ({} stalls, occ mean {:.1}, peak {})",
            report.amu_requests,
            report.amu_queue_stalls,
            report.amu_occ_mean,
            report.amu_occ_peak,
        );
    }
    if report.mims_messages > 0 {
        println!(
            "  mims packing  {:>12} messages ({} txns, pack mean {:.1}, {}/{} B)",
            report.mims_messages,
            report.mims_requests,
            report.mims_pack_mean,
            report.mims_delivered_bytes,
            report.mims_requested_bytes,
        );
    }
    if report.arrived_requests > 0 {
        println!(
            "  serving       {:>12} arrived ({} served, {} dropped)\n  \
             req latency   {:>9.1} ns mean (p50 {} ns, p99 {} ns, p99.9 {} ns)\n  \
             arrival queue {:>12.1} mean depth (peak {})",
            report.arrived_requests,
            report.served_requests,
            report.dropped_requests,
            report.req_mean_ns,
            report.req_p50_ns,
            report.req_p99_ns,
            report.req_p999_ns,
            report.queue_mean,
            report.queue_peak,
        );
    }
    if report.faults_injected > 0 || report.ecc_corrected > 0 {
        println!(
            "  faults        {:>12} injected ({} retry storms, {} demotions, {} ecc corrected)\n  \
             recovery      {:>9.1} ns mean (p99 {:.0} ns, max {:.0} ns)",
            report.faults_injected,
            report.retry_storms,
            report.demotions,
            report.ecc_corrected,
            report.recovery_mean / 1000.0,
            report.recovery_p99 as f64 / 1000.0,
            report.recovery_max as f64 / 1000.0,
        );
    }
    if report.degraded_accesses > 0 || report.quarantines > 0 {
        println!(
            "  availability  {:>12.4} ({}/{} ext accesses degraded)\n  \
             quarantine    {:>12} events ({} readmits, {} safe-served, \
             mttd {:.0} ns, mttr {:.0} ns, degraded {:.0} ns)",
            report.availability,
            report.degraded_accesses,
            report.ext_accesses,
            report.quarantines,
            report.readmits,
            report.quarantined_served,
            report.mttd_ns,
            report.mttr_ns,
            report.degraded_ns,
        );
    }
    if report.sample_windows > 0 {
        println!(
            "  sampled       {:>12} windows ({} detailed ops)\n  \
             ns/op         {:>9.2} ± {:.2} (95% CI)\n  \
             sampled IPC   {:>9.3} ± {:.3} (95% CI)",
            report.sample_windows,
            report.sample_detailed_ops,
            report.sample_ns_per_op_mean,
            report.sample_ci_ns_per_op,
            report.sample_ipc_mean,
            report.sample_ci_ipc,
        );
    }
    println!(
        "  engine        {:>12} ({} events, peak {}, {} buckets x {} ps, {} resizes, \
         {} resamples, {} overflowed)",
        report.engine,
        report.engine_events,
        report.engine_peak,
        report.engine_buckets,
        report.engine_width,
        report.engine_resizes,
        report.engine_resamples,
        report.engine_overflow,
    );
    println!("  frontend      {:>12}", cfg.frontend.name());
    if report.deadlocked {
        eprintln!("simulation DEADLOCKED — report is partial");
        return 1;
    }
    0
}

fn emit(table: twinload::stats::Table, csv_dir: Option<&str>, name: &str) {
    println!("{}", table.render());
    if let Some(dir) = csv_dir {
        let path = format!("{dir}/{name}.csv");
        match table.save_csv(&path) {
            Ok(()) => println!("(csv -> {path})\n"),
            Err(e) => eprintln!("csv {path}: {e}"),
        }
    }
}

fn cmd_repro(args: &Args) -> i32 {
    let scale = scale_from(args);
    let csv = args.get("csv-dir");
    let what = args.positional.first().map(|s| s.as_str()).unwrap_or("all");
    let char_needed = matches!(what, "fig8" | "fig9" | "fig10" | "fig11" | "fig12" | "all");
    let data = if char_needed { Some(exp::characterize(&scale)) } else { None };
    let mut did = false;
    let mut run = |name: &str, table: twinload::stats::Table| {
        emit(table, csv, name);
        did = true;
    };
    // Result-returning experiments report their typed error and bail.
    macro_rules! runr {
        ($name:expr, $t:expr) => {
            match $t {
                Ok(t) => run($name, t),
                Err(e) => {
                    eprintln!("error: {e:#}");
                    return 2;
                }
            }
        };
    }
    match what {
        "table1" => run("table1", exp::table1()),
        "table2" => run("table2", exp::table2()),
        "table3" => runr!("table3", exp::table3()),
        "table4" => run("table4", exp::table4(&scale)),
        "table5" => run("table5", exp::table5()),
        "fig7" => run("fig7", exp::fig7(&scale)),
        "fig8" => run("fig8", exp::fig8(data.as_ref().unwrap())),
        "fig9" => run("fig9", exp::fig9(data.as_ref().unwrap())),
        "fig10" => run("fig10", exp::fig10(data.as_ref().unwrap())),
        "fig11" => run("fig11", exp::fig11(data.as_ref().unwrap())),
        "fig12" => run("fig12", exp::fig12(data.as_ref().unwrap())),
        "fig13" => run("fig13", exp::fig13(&scale)),
        "fig14" => run("fig14", exp::fig14()),
        "fig15" => run("fig15", exp::fig15(&scale)),
        "all" => {
            run("table1", exp::table1());
            run("table2", exp::table2());
            runr!("table3", exp::table3());
            run("table4", exp::table4(&scale));
            run("fig7", exp::fig7(&scale));
            let d = data.as_ref().unwrap();
            run("fig8", exp::fig8(d));
            run("fig9", exp::fig9(d));
            run("fig10", exp::fig10(d));
            run("fig11", exp::fig11(d));
            run("fig12", exp::fig12(d));
            run("fig13", exp::fig13(&scale));
            run("table5", exp::table5());
            run("fig14", exp::fig14());
            run("fig15", exp::fig15(&scale));
        }
        other => {
            eprintln!("unknown experiment '{other}'");
            return 2;
        }
    }
    if did {
        0
    } else {
        2
    }
}

fn cmd_ablate(args: &Args) -> i32 {
    let scale = scale_from(args);
    let csv = args.get("csv-dir");
    macro_rules! emitr {
        ($t:expr, $name:expr) => {
            match $t {
                Ok(t) => emit(t, csv, $name),
                Err(e) => {
                    eprintln!("error: {e:#}");
                    return 2;
                }
            }
        };
    }
    match args.positional.first().map(|s| s.as_str()) {
        Some("lvc") => emit(exp::ablate_lvc(&scale), csv, "ablate_lvc"),
        Some("layers") => emit(exp::ablate_layers(&scale), csv, "ablate_layers"),
        Some("batch") => emit(exp::ablate_batch(&scale), csv, "ablate_batch"),
        Some("scm") => emitr!(exp::ablate_scm(&scale), "ablate_scm"),
        Some("smt") => emit(exp::ablate_smt(&scale), csv, "ablate_smt"),
        Some("amu") => emit(exp::ablate_amu(&scale), csv, "ablate_amu"),
        Some("mims") => emitr!(exp::ablate_mims(&scale), "ablate_mims"),
        Some("faults") => emitr!(exp::ablate_faults(&scale), "ablate_faults"),
        Some("degrade") => emitr!(exp::ablate_degrade(&scale), "ablate_degrade"),
        _ => {
            eprintln!("usage: twinload ablate <lvc|layers|batch|scm|smt|amu|mims|faults|degrade>");
            return 2;
        }
    }
    0
}

fn cmd_serve(args: &Args) -> i32 {
    let scale = scale_from(args);
    let csv = args.get("csv-dir");
    // Default SLO comes from the preset default (INI `slo_p99_us`
    // overrides per-config; the sweep applies one budget to every row).
    let slo = match args.get_u64("slo-p99-us") {
        Ok(v) => v.unwrap_or_else(|| SystemConfig::ideal().slo_p99_us),
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    match exp::serve(&scale, slo, args.has("sampled")) {
        Ok(t) => emit(t, csv, "serve"),
        Err(e) => {
            eprintln!("error: {e:#}");
            return 2;
        }
    }
    0
}

fn cmd_validate(_args: &Args) -> i32 {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    let fp = match fastpath::FastPath::new(dir) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("fast path unavailable: {e}");
            return 1;
        }
    };
    println!("PJRT analytic fast path vs cycle-accurate simulator");
    println!("(row-buffer hit-rate on the extended channel, same trace family)\n");
    let cfg = SystemConfig::tl_ooo();
    let mut worst: f64 = 0.0;
    for &wl in &[WorkloadKind::Gups, WorkloadKind::Cg, WorkloadKind::ScalParC] {
        let (b, r) = fastpath::synthesize_trace(&cfg, wl, Mechanism::TlOoO, 2, 42);
        let counts = match fp.classify(&b, &r) {
            Ok(c) => c,
            Err(e) => {
                eprintln!("classify: {e}");
                return 1;
            }
        };
        let mut spec = RunSpec::smoke(wl);
        spec.ops_per_core = 20_000;
        let sim = run_spec(&cfg, &spec);
        let delta = (counts.hit_rate() - sim.row_hit_rate).abs();
        worst = worst.max(delta);
        println!(
            "  {:<12} analytic hit-rate {:>5.1}%   sim {:>5.1}%   |delta| {:>4.1} pts",
            wl.name(),
            counts.hit_rate() * 100.0,
            sim.row_hit_rate * 100.0,
            delta * 100.0
        );
    }
    // The analytic model is serial and single-channel; agreement within
    // 25 points indicates the classification logic matches.
    if worst > 0.25 {
        eprintln!("\nvalidation FAILED (worst delta {:.1} pts)", worst * 100.0);
        1
    } else {
        println!("\nvalidation OK (worst delta {:.1} pts)", worst * 100.0);
        0
    }
}

fn cmd_list() -> i32 {
    println!("mechanisms:");
    for m in
        ["ideal", "tl-ooo", "tl-lf", "tl-lf-batched", "numa", "pcie", "inc-trl", "amu", "mims"]
    {
        println!("  {m}");
    }
    println!("workloads:");
    for w in ALL_WORKLOADS {
        println!("  {}", w.name());
    }
    0
}
