//! Trace-driven out-of-order core model.
//!
//! The paper's performance effects are first-order consequences of how an
//! OoO window interacts with memory latency: twin-load adds ~64 % more
//! instructions yet only costs ~26 % because the extra work executes in
//! load-stall slots (Figure 8), while TL-LF's fences serialize loads and
//! cut memory concurrency by a third (Figure 11). This module models
//! exactly those mechanisms: a ROB-bounded window, frontend fetch
//! throughput, dependency-gated load issue, MSHR-limited outstanding
//! misses, load fences, and in-order retire.

pub mod core;
pub mod frontend;
pub mod trace;

pub use self::core::{Core, CoreParams, CoreStats, IssueResult, MemoryPort};
pub use frontend::FrontEnd;
pub use trace::{AccessKind, MemAccess, MicroOp, OpSource, TwinCheck};
