//! Micro-op stream vocabulary consumed by the core model.
//!
//! Workload generators emit *logical* operations (see `workloads::`);
//! the access-mechanism transform (`twinload::protocol`) lowers them into
//! this micro-op stream. The core never knows which mechanism produced
//! the stream — exactly like real hardware.

/// What a memory micro-op does at the memory port.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AccessKind {
    /// Normal cacheable load.
    Load,
    /// Cacheable store (write-allocate RFO on miss).
    Store,
    /// Invalidate the line (clflush): twin-load retry prologue (§4.4).
    Invalidate,
    /// Slow-but-safe uncacheable MMIO access via the MEC exception
    /// registers (§4.5); always returns real data.
    SafePath,
}

/// A memory micro-op.
#[derive(Debug, Clone, Copy)]
pub struct MemAccess {
    /// Virtual line address (64 B aligned by construction).
    pub vaddr: u64,
    pub kind: AccessKind,
    /// Logical load index: both twins of a pair share it; dependencies
    /// reference it.
    pub logical: u64,
    /// The logical index whose *value* this access needs before issuing
    /// (pointer-chase dependence), if any.
    pub dep_on: Option<u64>,
    /// Twin-pair id: `Some(p)` groups the two loads of one twin-load.
    pub pair: Option<u64>,
    /// This op is a software retry (a second failure escalates to the
    /// safe path instead of retrying again).
    pub retry: bool,
}

impl MemAccess {
    pub fn load(vaddr: u64, logical: u64) -> MemAccess {
        MemAccess {
            vaddr,
            kind: AccessKind::Load,
            logical,
            dep_on: None,
            pair: None,
            retry: false,
        }
    }

    pub fn store(vaddr: u64, logical: u64) -> MemAccess {
        MemAccess {
            vaddr,
            kind: AccessKind::Store,
            logical,
            dep_on: None,
            pair: None,
            retry: false,
        }
    }

    pub fn with_dep(mut self, dep: Option<u64>) -> MemAccess {
        self.dep_on = dep;
        self
    }

    pub fn with_pair(mut self, pair: u64) -> MemAccess {
        self.pair = Some(pair);
        self
    }
}

/// What the core should do when a twin pair resolves (content check).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TwinCheck {
    /// TL semantics: if *both* twins returned fake data (Table 2 state 4),
    /// invalidate + fence + retry; a second failure takes the safe path.
    RetryIfBothFake,
    /// Store discipline (§3.2): the CAS that follows fails if the line
    /// turned fake; retry the store.
    CasStore,
}

/// One micro-op.
#[derive(Debug, Clone, Copy)]
pub enum MicroOp {
    /// `n` non-memory instructions (address arithmetic, compares, the
    /// twin-load inline-function overhead...).
    Compute(u32),
    /// Load fence: later loads may not issue until all earlier loads have
    /// returned data (Intel LFENCE semantics, §3.1 TL-LF).
    Fence,
    Mem(MemAccess),
}

impl MicroOp {
    /// Retired-instruction weight of this micro-op.
    pub fn insts(&self) -> u32 {
        match self {
            MicroOp::Compute(n) => *n,
            MicroOp::Fence => 1,
            MicroOp::Mem(_) => 1,
        }
    }
}

/// Result of a time-aware pull from an [`OpSource`].
///
/// Closed-loop sources only ever produce `Op`/`Exhausted` (the default
/// [`OpSource::pull`] maps `next_op` onto them). Open-loop sources
/// (`workloads::arrival`) additionally answer `NotBefore(t)`: there is
/// more work, but the next request has not *arrived* yet — the core must
/// not treat the stream as finished, and should try again at simulated
/// time `t` (picoseconds).
#[derive(Debug, Clone, Copy)]
pub enum Pull {
    /// The next micro-op, ready now.
    Op(MicroOp),
    /// No op ready before the given simulated time (ps). The source is
    /// *not* exhausted.
    NotBefore(u64),
    /// The stream is finished; no further ops will ever be produced.
    Exhausted,
}

/// A pull-based micro-op source (workload ∘ mechanism transform).
pub trait OpSource {
    fn next_op(&mut self) -> Option<MicroOp>;

    /// Time-aware pull: like [`next_op`](OpSource::next_op), but a source
    /// that paces work by simulated arrival time may answer
    /// [`Pull::NotBefore`] instead of ending the stream. The default
    /// delegates to `next_op`, so every existing (closed-loop) source is
    /// unaffected.
    fn pull(&mut self, _now: u64) -> Pull {
        match self.next_op() {
            Some(op) => Pull::Op(op),
            None => Pull::Exhausted,
        }
    }
}

/// Blanket impl so plain iterators (tests, replays) are sources.
impl<I: Iterator<Item = MicroOp>> OpSource for I {
    fn next_op(&mut self) -> Option<MicroOp> {
        self.next()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inst_weights() {
        assert_eq!(MicroOp::Compute(7).insts(), 7);
        assert_eq!(MicroOp::Fence.insts(), 1);
        assert_eq!(MicroOp::Mem(MemAccess::load(0, 0)).insts(), 1);
    }

    #[test]
    fn builders_compose() {
        let a = MemAccess::load(0x40, 3).with_dep(Some(2)).with_pair(9);
        assert_eq!(a.kind, AccessKind::Load);
        assert_eq!(a.dep_on, Some(2));
        assert_eq!(a.pair, Some(9));
    }

    #[test]
    fn iterator_is_source() {
        let mut it = vec![MicroOp::Compute(1), MicroOp::Fence].into_iter();
        assert!(matches!(it.next_op(), Some(MicroOp::Compute(1))));
        assert!(matches!(it.next_op(), Some(MicroOp::Fence)));
        assert!(it.next_op().is_none());
    }
}
