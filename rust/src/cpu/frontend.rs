//! Allocation-free front-end bookkeeping: slab transaction tracking and
//! intrusive waiter chains.
//!
//! PRs 1–3 made the memory-side hot loops (FR-FCFS candidate scan, event
//! queue) allocation-free; this module does the same for the per-access
//! *front end* — the paper's premise is that twin-load's software path
//! stays viable only while per-request bookkeeping costs "a few extra
//! instructions" (§4.4), and the simulator should be no worse. Request
//! ids become `{tag, index}` handles into dense slabs, so `complete` is
//! an array index instead of a hash probe, and per-line waiter lists
//! become intrusive next-links threaded through the request slab instead
//! of heap-allocated `Vec`s.
//!
//! The map-based implementations are retained behind
//! [`FrontEnd::Reference`] (selected via `SystemConfig.frontend`, CLI
//! `--frontend`, or INI `frontend=`), following the
//! `SchedPolicy`/`EngineKind` convention: the optimized default is proven
//! bit-identical by the `frontend-equivalence` differential proptest and
//! the all-mechanism `SimReport` equivalence test.
//!
//! ## Handle encoding and determinism
//!
//! The DRAM controller tie-breaks co-arriving transactions by `(arrive,
//! id)`, so transaction *id order* is behaviorally significant. Slab
//! handles therefore pack a monotonically increasing submit counter into
//! the high 32 bits (`id = counter << 32 | slot`): relative id order is
//! identical to the reference path's sequential ids, the low bits give
//! O(1) completion, and the full id doubles as an ABA tag — a stale
//! handle can never alias a recycled slot because the stored id differs.

use crate::util::time::Ps;

/// Sentinel for "no slot" in intrusive links.
pub const NIL: u32 = u32::MAX;

/// Resolved-value scoreboard window (shared by the map-based
/// `LogicalBoard` and the ring-based [`BoardRing`] so both prune on the
/// same cadence and stay observationally identical).
pub(crate) const BOARD_WINDOW: u64 = 4096;

/// Which front-end implementation tracks in-flight requests.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FrontEnd {
    /// Generational slabs + intrusive waiter chains (default): the
    /// steady-state issue/complete path performs zero heap allocations
    /// and zero hash probes.
    Slab,
    /// The retained map-based path (`FastMap` pending/waiters/pairs/
    /// req_map), kept for differential testing and benchmarking.
    Reference,
}

impl FrontEnd {
    pub fn name(&self) -> &'static str {
        match self {
            FrontEnd::Slab => "slab",
            FrontEnd::Reference => "reference",
        }
    }

    pub fn by_name(name: &str) -> Option<FrontEnd> {
        match name {
            "slab" => Some(FrontEnd::Slab),
            "reference" => Some(FrontEnd::Reference),
            _ => None,
        }
    }
}

// ---------------------------------------------------------------------
// TagSlab: generational id -> value store (platform pending txns).
// ---------------------------------------------------------------------

/// A slab keyed by externally supplied tagged handles.
///
/// `insert(tag, v)` returns `id = tag << 32 | slot`; `get`/`remove` index
/// by the low bits and verify the stored id, so a stale handle (freed or
/// recycled slot) resolves to `None` exactly like a missing map key.
/// Handles whose low 32 bits are `NIL` (used for untracked writes) never
/// match a slot. Steady state allocates nothing: freed slots recycle
/// through a free list whose capacity persists.
#[derive(Debug)]
pub struct TagSlab<T> {
    /// (stored id, value); id == u64::MAX marks a free slot.
    slots: Vec<(u64, Option<T>)>,
    free: Vec<u32>,
    live: usize,
}

impl<T> Default for TagSlab<T> {
    fn default() -> TagSlab<T> {
        TagSlab::new()
    }
}

const FREE_ID: u64 = u64::MAX;

impl<T> TagSlab<T> {
    pub fn new() -> TagSlab<T> {
        TagSlab { slots: Vec::new(), free: Vec::new(), live: 0 }
    }

    /// Insert under a caller-supplied monotone tag; returns the handle.
    /// Tags must be < 2^32 (the simulator's 2e9 event cap is hit first).
    pub fn insert(&mut self, tag: u64, val: T) -> u64 {
        debug_assert!(tag < (1 << 32), "txn tag overflow");
        let slot = match self.free.pop() {
            Some(s) => s,
            None => {
                self.slots.push((FREE_ID, None));
                (self.slots.len() - 1) as u32
            }
        };
        let id = (tag << 32) | slot as u64;
        self.slots[slot as usize] = (id, Some(val));
        self.live += 1;
        id
    }

    pub fn get(&self, id: u64) -> Option<&T> {
        match self.slots.get((id & 0xFFFF_FFFF) as usize) {
            Some((sid, Some(v))) if *sid == id => Some(v),
            _ => None,
        }
    }

    pub fn remove(&mut self, id: u64) -> Option<T> {
        let slot = (id & 0xFFFF_FFFF) as usize;
        match self.slots.get_mut(slot) {
            Some(e) if e.0 == id => {
                e.0 = FREE_ID;
                self.live -= 1;
                self.free.push(slot as u32);
                e.1.take()
            }
            _ => None,
        }
    }

    pub fn len(&self) -> usize {
        self.live
    }

    pub fn is_empty(&self) -> bool {
        self.live == 0
    }
}

// ---------------------------------------------------------------------
// ReqSlab + WaiterTable: per-core miss waiters as intrusive chains.
// ---------------------------------------------------------------------

#[derive(Debug, Clone, Copy)]
struct ReqSlot {
    tag: u32,
    is_store: bool,
    /// Next waiter on the same line (or next free slot when freed).
    next: u32,
}

/// Per-core slab of outstanding miss requests. Each entry is one waiter
/// `(req handle, is_store)` with an inline `next` link; the per-line
/// chain heads live in the companion [`WaiterTable`].
#[derive(Debug)]
pub struct ReqSlab {
    slots: Vec<ReqSlot>,
    free_head: u32,
    next_tag: u32,
    live: usize,
}

impl Default for ReqSlab {
    fn default() -> ReqSlab {
        ReqSlab::new()
    }
}

impl ReqSlab {
    pub fn new() -> ReqSlab {
        ReqSlab { slots: Vec::new(), free_head: NIL, next_tag: 0, live: 0 }
    }

    fn alloc(&mut self, is_store: bool) -> u32 {
        let slot = if self.free_head != NIL {
            let s = self.free_head;
            self.free_head = self.slots[s as usize].next;
            s
        } else {
            self.slots.push(ReqSlot { tag: 0, is_store: false, next: NIL });
            (self.slots.len() - 1) as u32
        };
        let tag = self.next_tag;
        // Skip u32::MAX so the core's seq table can use it as "empty".
        self.next_tag = match tag.wrapping_add(1) {
            u32::MAX => 0,
            t => t,
        };
        self.slots[slot as usize] = ReqSlot { tag, is_store, next: NIL };
        self.live += 1;
        slot
    }

    /// Allocate a waiter for `line` and append it to the line's chain
    /// (FIFO, matching the reference `Vec` push order). Returns the
    /// request handle.
    pub fn push_waiter(&mut self, tbl: &mut WaiterTable, line: u64, is_store: bool) -> u64 {
        let slot = self.alloc(is_store);
        if let Some(tail) = tbl.link_tail(line, slot) {
            self.slots[tail as usize].next = slot;
        }
        ((self.slots[slot as usize].tag as u64) << 32) | slot as u64
    }

    #[inline]
    pub fn is_store(&self, slot: u32) -> bool {
        self.slots[slot as usize].is_store
    }

    #[inline]
    pub fn next_of(&self, slot: u32) -> u32 {
        self.slots[slot as usize].next
    }

    /// Free `slot`, returning its request handle and chain successor.
    pub fn release(&mut self, slot: u32) -> (u64, u32) {
        let s = self.slots[slot as usize];
        let id = ((s.tag as u64) << 32) | slot as u64;
        self.slots[slot as usize] =
            ReqSlot { tag: u32::MAX, is_store: false, next: self.free_head };
        self.free_head = slot;
        self.live -= 1;
        (id, s.next)
    }

    pub fn len(&self) -> usize {
        self.live
    }

    pub fn is_empty(&self) -> bool {
        self.live == 0
    }
}

#[derive(Debug, Clone, Copy)]
struct WaiterLine {
    /// u64::MAX marks an empty entry (real lines are bounded addresses).
    line: u64,
    head: u32,
    tail: u32,
}

const EMPTY_LINE: u64 = u64::MAX;

/// Per-line waiter chain heads. Distinct lines with waiters are bounded
/// by the MSHR file capacity, so a linear scan over an inline array is
/// hash-free and effectively O(10); the array only grows past its seeded
/// capacity defensively.
#[derive(Debug, Default)]
pub struct WaiterTable {
    lines: Vec<WaiterLine>,
}

impl WaiterTable {
    pub fn new(capacity: usize) -> WaiterTable {
        WaiterTable {
            lines: vec![WaiterLine { line: EMPTY_LINE, head: NIL, tail: NIL }; capacity.max(1)],
        }
    }

    /// Make `slot` the new tail of `line`'s chain. Returns the previous
    /// tail when the chain existed (the caller links it), `None` when a
    /// new chain was started.
    fn link_tail(&mut self, line: u64, slot: u32) -> Option<u32> {
        let mut empty = None;
        for (i, e) in self.lines.iter_mut().enumerate() {
            if e.line == line {
                let prev = e.tail;
                e.tail = slot;
                return Some(prev);
            }
            if e.line == EMPTY_LINE && empty.is_none() {
                empty = Some(i);
            }
        }
        let entry = WaiterLine { line, head: slot, tail: slot };
        match empty {
            Some(i) => self.lines[i] = entry,
            None => self.lines.push(entry), // beyond MSHR bound: defensive
        }
        None
    }

    /// Detach and return the chain head for `line` (`NIL` if none).
    pub fn remove(&mut self, line: u64) -> u32 {
        for e in self.lines.iter_mut() {
            if e.line == line {
                let head = e.head;
                *e = WaiterLine { line: EMPTY_LINE, head: NIL, tail: NIL };
                return head;
            }
        }
        NIL
    }

    /// Lines with live chains (debug/deadlock reporting only).
    pub fn len(&self) -> usize {
        self.lines.iter().filter(|e| e.line != EMPTY_LINE).count()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

// ---------------------------------------------------------------------
// ReqSeqTable: the core's req-handle -> ROB-sequence side table.
// ---------------------------------------------------------------------

/// Dense array mapping a request handle's slot index to the ROB sequence
/// of the micro-op waiting on it, tag-checked against the handle's high
/// bits (replaces the reference `req_map: FastMap<u64, u64>`).
#[derive(Debug, Default)]
pub struct ReqSeqTable {
    /// (tag, seq); tag == u32::MAX marks an empty slot.
    slots: Vec<(u32, u64)>,
    live: usize,
}

impl ReqSeqTable {
    pub fn set(&mut self, req_id: u64, seq: u64) {
        let slot = (req_id & 0xFFFF_FFFF) as usize;
        let tag = (req_id >> 32) as u32;
        debug_assert!(tag != u32::MAX, "tag collides with the empty marker");
        if slot >= self.slots.len() {
            self.slots.resize(slot + 1, (u32::MAX, 0));
        }
        debug_assert!(self.slots[slot].0 == u32::MAX, "slot recycled while live");
        self.slots[slot] = (tag, seq);
        self.live += 1;
    }

    pub fn take(&mut self, req_id: u64) -> Option<u64> {
        let slot = (req_id & 0xFFFF_FFFF) as usize;
        let tag = (req_id >> 32) as u32;
        match self.slots.get_mut(slot) {
            Some(e) if e.0 == tag => {
                let seq = e.1;
                *e = (u32::MAX, 0);
                self.live -= 1;
                Some(seq)
            }
            _ => None,
        }
    }

    pub fn len(&self) -> usize {
        self.live
    }

    pub fn is_empty(&self) -> bool {
        self.live == 0
    }
}

// ---------------------------------------------------------------------
// PairRing: twin-pair state without a hash map.
// ---------------------------------------------------------------------

#[derive(Debug, Clone, Copy)]
struct PairSlot {
    /// Pair id occupying the slot; u64::MAX marks empty.
    pair: u64,
    logical: u64,
    first_t: Ps,
    first_real: bool,
}

const EMPTY_PAIR: u64 = u64::MAX;

const EMPTY_SLOT: PairSlot =
    PairSlot { pair: EMPTY_PAIR, logical: 0, first_t: 0, first_real: false };

/// Twin-pair bookkeeping indexed by `pair & mask`.
///
/// Pair ids are assigned in lowering (= fetch) order, so live pair ids
/// cluster in a window bounded by the ROB plus the TL-LF batch width (a
/// batched shadow load can complete and retire long before its demand
/// twin is fetched). The ring is seeded at 2×`rob_size` — ample for the
/// shipped batch widths — and on the cold collision path doubles and
/// redistributes its live entries, so arbitrarily wide batches degrade
/// to a one-time growth instead of silently aliasing pair state.
#[derive(Debug, Default)]
pub struct PairRing {
    slots: Vec<PairSlot>,
    mask: u64,
    live: usize,
}

impl PairRing {
    pub fn new(rob_size: usize) -> PairRing {
        let cap = (2 * rob_size.max(1)).next_power_of_two();
        PairRing { slots: vec![EMPTY_SLOT; cap], mask: cap as u64 - 1, live: 0 }
    }

    /// Record one twin completion. First arrival stores `(at, real)` and
    /// returns `None`; the second detaches the entry and returns the
    /// first twin's `(t0, was_real, logical)`.
    pub fn observe(
        &mut self,
        pair: u64,
        logical: u64,
        at: Ps,
        real: bool,
    ) -> Option<(Ps, bool, u64)> {
        loop {
            let s = (pair & self.mask) as usize;
            let slot = &mut self.slots[s];
            if slot.pair == pair {
                let out = (slot.first_t, slot.first_real, slot.logical);
                slot.pair = EMPTY_PAIR;
                self.live -= 1;
                return Some(out);
            }
            if slot.pair == EMPTY_PAIR {
                *slot = PairSlot { pair, logical, first_t: at, first_real: real };
                self.live += 1;
                return None;
            }
            // Two live pairs map to one slot (batch wider than the seed
            // capacity): grow until every live id has its own slot.
            self.grow();
        }
    }

    /// Double the ring until all live entries redistribute without
    /// collision. Live pair ids are distinct, so any capacity exceeding
    /// their span succeeds; growth is a one-time cost per capacity step.
    #[cold]
    fn grow(&mut self) {
        let live: Vec<PairSlot> =
            self.slots.iter().copied().filter(|s| s.pair != EMPTY_PAIR).collect();
        let mut cap = self.slots.len();
        'retry: loop {
            cap *= 2;
            let mask = cap as u64 - 1;
            let mut next = vec![EMPTY_SLOT; cap];
            for e in &live {
                let s = (e.pair & mask) as usize;
                if next[s].pair != EMPTY_PAIR {
                    continue 'retry;
                }
                next[s] = *e;
            }
            self.slots = next;
            self.mask = mask;
            return;
        }
    }

    pub fn len(&self) -> usize {
        self.live
    }

    pub fn is_empty(&self) -> bool {
        self.live == 0
    }
}

// ---------------------------------------------------------------------
// BoardRing: the resolved-value scoreboard without a hash map.
// ---------------------------------------------------------------------

/// Ring-indexed resolved-value scoreboard, observationally identical to
/// the map-based `LogicalBoard`: entries below the pruning watermark read
/// as long-resolved (`Some(0)`), in-window resolved entries return their
/// time, unresolved ones `None`.
///
/// Capacity safety: live (≥ watermark) logical indices span at most
/// `3 × BOARD_WINDOW + rob_size` (the watermark lags the newest resolve
/// by one window plus one prune period), so a 4×-window power-of-two ring
/// can never hold two live indices in one slot.
#[derive(Debug, Default)]
pub struct BoardRing {
    /// (logical, resolved-at); logical == u64::MAX marks empty.
    slots: Vec<(u64, Ps)>,
    mask: u64,
    watermark: u64,
    inserts: u64,
}

const EMPTY_LOGICAL: u64 = u64::MAX;

impl BoardRing {
    pub fn new() -> BoardRing {
        let cap = (4 * BOARD_WINDOW) as usize; // 16384, power of two
        BoardRing {
            slots: vec![(EMPTY_LOGICAL, 0); cap],
            mask: cap as u64 - 1,
            watermark: 0,
            inserts: 0,
        }
    }

    pub fn resolve(&mut self, logical: u64, at: Ps) {
        self.slots[(logical & self.mask) as usize] = (logical, at);
        self.inserts += 1;
        // Same pruning cadence as the reference board; overwriting stale
        // slots replaces the map's retain().
        if self.inserts % (2 * BOARD_WINDOW) == 0 {
            self.watermark = self.watermark.max(logical.saturating_sub(BOARD_WINDOW));
        }
    }

    pub fn ready_at(&self, logical: u64) -> Option<Ps> {
        if logical < self.watermark {
            return Some(0);
        }
        match self.slots[(logical & self.mask) as usize] {
            (l, t) if l == logical => Some(t),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frontend_names_roundtrip() {
        for fe in [FrontEnd::Slab, FrontEnd::Reference] {
            assert_eq!(FrontEnd::by_name(fe.name()), Some(fe));
        }
        assert_eq!(FrontEnd::by_name("bogus"), None);
    }

    #[test]
    fn tag_slab_insert_get_remove() {
        let mut s: TagSlab<u64> = TagSlab::new();
        let a = s.insert(1, 100);
        let b = s.insert(2, 200);
        assert_eq!(s.len(), 2);
        assert_eq!(s.get(a), Some(&100));
        assert_eq!(s.get(b), Some(&200));
        assert_eq!(s.remove(a), Some(100));
        assert_eq!(s.get(a), None);
        assert_eq!(s.remove(a), None, "double remove");
        assert_eq!(s.len(), 1);
    }

    #[test]
    fn tag_slab_stale_handle_does_not_alias_recycled_slot() {
        // Generation reuse: free a slot, re-allocate it under a new tag;
        // the old handle must not observe (or remove) the new occupant.
        let mut s: TagSlab<u64> = TagSlab::new();
        let old = s.insert(7, 700);
        assert_eq!(s.remove(old), Some(700));
        let new = s.insert(8, 800);
        assert_eq!(new & 0xFFFF_FFFF, old & 0xFFFF_FFFF, "slot was recycled");
        assert_ne!(new, old, "handle carries the new tag");
        assert_eq!(s.get(old), None, "stale handle aliased a recycled entry");
        assert_eq!(s.remove(old), None);
        assert_eq!(s.get(new), Some(&800));
    }

    #[test]
    fn tag_slab_write_style_ids_never_match() {
        let mut s: TagSlab<u64> = TagSlab::new();
        s.insert(1, 1);
        let write_id = (2u64 << 32) | NIL as u64;
        assert_eq!(s.get(write_id), None);
        assert_eq!(s.remove(write_id), None);
    }

    #[test]
    fn waiter_chain_is_fifo_and_recycles() {
        let mut reqs = ReqSlab::new();
        let mut tbl = WaiterTable::new(4);
        let line = 0x40;
        let r1 = reqs.push_waiter(&mut tbl, line, false);
        let r2 = reqs.push_waiter(&mut tbl, line, true);
        let r3 = reqs.push_waiter(&mut tbl, line, false);
        let other = reqs.push_waiter(&mut tbl, 0x80, false);
        assert_eq!(reqs.len(), 4);
        assert_eq!(tbl.len(), 2);

        let head = tbl.remove(line);
        assert_ne!(head, NIL);
        // any_store walk sees the store; order preserved.
        let (mut any, mut got, mut c) = (false, Vec::new(), head);
        while c != NIL {
            any |= reqs.is_store(c);
            c = reqs.next_of(c);
        }
        assert!(any);
        let mut c = head;
        while c != NIL {
            let (id, next) = reqs.release(c);
            got.push(id);
            c = next;
        }
        assert_eq!(got, vec![r1, r2, r3], "chain order is insertion order");
        assert_eq!(reqs.len(), 1);
        assert_eq!(tbl.remove(line), NIL, "chain detached");

        // Recycled slots get fresh tags: new handles differ from old.
        let r4 = reqs.push_waiter(&mut tbl, 0xc0, false);
        assert!(!got.contains(&r4), "recycled slot reused a stale handle");
        let _ = other;
    }

    #[test]
    fn req_seq_table_tag_checks() {
        let mut t = ReqSeqTable::default();
        let id_a = (3u64 << 32) | 5;
        t.set(id_a, 42);
        assert_eq!(t.len(), 1);
        let stale = (2u64 << 32) | 5; // same slot, older tag
        assert_eq!(t.take(stale), None);
        assert_eq!(t.take(id_a), Some(42));
        assert_eq!(t.take(id_a), None, "double take");
        assert_eq!(t.len(), 0);
    }

    #[test]
    fn pair_ring_first_second_and_reuse() {
        let mut p = PairRing::new(168);
        assert_eq!(p.observe(0, 9, 100, false), None);
        assert_eq!(p.len(), 1);
        assert_eq!(p.observe(0, 9, 150, true), Some((100, false, 9)));
        assert_eq!(p.len(), 0);
        // The slot is reusable by a later pair that maps to it.
        let cap = 2 * 168u64.next_power_of_two();
        assert_eq!(p.observe(cap, 11, 200, true), None);
        assert_eq!(p.observe(cap, 11, 210, false), Some((200, true, 11)));
    }

    #[test]
    fn pair_ring_grows_on_collision_instead_of_aliasing() {
        // Seed a tiny ring (cap 2) and force two live pairs onto one
        // slot: ids 0 and 2 both mask to slot 0. The ring must grow and
        // keep both entries intact (the batched-TL-LF wide-batch case).
        let mut p = PairRing::new(1);
        assert_eq!(p.observe(0, 10, 100, false), None);
        assert_eq!(p.observe(2, 11, 120, true), None, "collision must grow, not alias");
        assert_eq!(p.len(), 2);
        assert_eq!(p.observe(0, 10, 200, true), Some((100, false, 10)));
        assert_eq!(p.observe(2, 11, 210, false), Some((120, true, 11)));
        assert_eq!(p.len(), 0);
    }

    #[test]
    fn board_ring_matches_reference_semantics() {
        let mut b = BoardRing::new();
        assert_eq!(b.ready_at(0), None, "unresolved in-window");
        b.resolve(0, 500);
        assert_eq!(b.ready_at(0), Some(500));
        b.resolve(3, 900);
        assert_eq!(b.ready_at(3), Some(900));
        assert_eq!(b.ready_at(1), None);
        // Push the watermark forward: resolve 2*WINDOW entries ending
        // high, then old indices read as long-resolved.
        for i in 0..2 * BOARD_WINDOW {
            b.resolve(10 * BOARD_WINDOW + i, 1_000 + i);
        }
        assert!(b.watermark > 0);
        assert_eq!(b.ready_at(0), Some(0), "pruned entries are long-resolved");
        let last = 10 * BOARD_WINDOW + 2 * BOARD_WINDOW - 1;
        assert_eq!(b.ready_at(last), Some(1_000 + 2 * BOARD_WINDOW - 1));
    }
}
