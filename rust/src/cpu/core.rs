//! The ROB-window out-of-order core.
//!
//! Mechanisms modeled (each maps to a paper phenomenon):
//! * bounded reorder window + frontend fetch throughput → extra twin-load
//!   instructions hide in load-stall slots (Figure 8: IPC *rises*);
//! * dependency-gated load issue (pointer chasing) → limited intrinsic
//!   MLP of graph workloads (§6.2);
//! * MSHR-limited outstanding misses → the concurrency ceiling of
//!   Figure 11;
//! * load fences → TL-LF's serialized twins (§3.1);
//! * twin-pair content checking with software retry and the safe path
//!   (§4.4, §4.5) → correctness under all Table-2 cache states.

use super::frontend::{BoardRing, FrontEnd, PairRing, ReqSeqTable, BOARD_WINDOW};
use super::trace::{AccessKind, MemAccess, MicroOp, OpSource, Pull};
use crate::cache::DataKind;
use crate::util::time::Ps;
use crate::util::FastMap;
use std::collections::VecDeque;

/// Core microarchitecture parameters.
#[derive(Debug, Clone, Copy)]
pub struct CoreParams {
    /// Reorder-buffer capacity in micro-ops.
    pub rob_size: usize,
    /// Frontend fetch/exec throughput (instructions per cycle).
    pub fetch_per_cycle: u32,
    /// CPU clock period in ps.
    pub period: Ps,
    /// Latency charged for a software twin retry (§4.4: invalidate both
    /// lines + mfence + re-twin-load — two serialized memory round trips
    /// plus the forced row miss). A real machine squashes and replays the
    /// dependent window; the model charges the end-to-end penalty to the
    /// pair's resolution time instead (see DESIGN.md §Retry-modeling).
    pub retry_penalty: Ps,
    /// Latency of the §4.5 uncacheable safe path (3 serialized MMIO ops).
    pub safe_penalty: Ps,
    /// Graceful degradation (§4.5): after this many *consecutive*
    /// both-fake retries on one line, demote the access to the safe path
    /// instead of retrying blind. `0` disables demotion — the default,
    /// because content-collision retries can recur naturally on a hot
    /// line and the fault-free baseline must stay bit-identical.
    pub demote_after: u32,
}

impl CoreParams {
    /// Sandy-Bridge-class core (the paper's Xeon E5-2640): 2.5 GHz,
    /// 168-entry ROB, 4-wide.
    pub fn xeon() -> CoreParams {
        CoreParams {
            rob_size: 168,
            fetch_per_cycle: 4,
            period: 400,
            retry_penalty: 400_000, // ≈ 2 serialized misses + fence + flushes
            safe_penalty: 500_000,
            demote_after: 0,
        }
    }
}

/// Result of presenting a memory micro-op to the platform.
#[derive(Debug, Clone, Copy)]
pub enum IssueResult {
    /// Satisfied synchronously (cache hit / invalidate): completion time
    /// and the content the program observes.
    Done { at: Ps, data: DataKind },
    /// Outstanding; the platform will call [`Core::complete`] with this id.
    Pending { req_id: u64 },
    /// No MSHR available; retry no earlier than `retry_at` (a completion
    /// event may free one sooner).
    Stall { retry_at: Ps },
}

/// The platform side of the core: caches + memory.
pub trait MemoryPort {
    fn issue(&mut self, now: Ps, acc: &MemAccess) -> IssueResult;
}

#[derive(Debug, Clone, Copy)]
enum MemState {
    Waiting,
    Issued,
    Done { at: Ps },
}

#[derive(Debug, Clone, Copy)]
enum SlotKind {
    Compute { done: Ps },
    Fence { resolved: Option<Ps> },
    Mem { acc: MemAccess, state: MemState },
}

#[derive(Debug, Clone, Copy)]
struct Slot {
    kind: SlotKind,
    insts: u32,
    fetch_done: Ps,
}

/// Twin-pair bookkeeping (§3.1 TL-OoO / §4.4).
#[derive(Debug, Clone, Copy)]
struct PairState {
    logical: u64,
    first: Option<(Ps, DataKind)>,
}

/// Aggregated core statistics (the per-core slice of Figures 7–11).
#[derive(Debug, Default, Clone, Copy)]
pub struct CoreStats {
    pub retired_insts: u64,
    pub retired_ops: u64,
    pub loads: u64,
    pub stores: u64,
    pub fences: u64,
    /// Both-fake twin retries taken (Table 2 state 4).
    pub twin_retries: u64,
    /// Escalations to the uncacheable safe path (§4.5).
    pub safe_paths: u64,
    /// CAS store failures retried (§3.2).
    pub cas_fails: u64,
    /// Lines that entered a retry storm (≥ 2 consecutive both-fake
    /// retries; tracked only when `demote_after` is armed).
    pub retry_storms: u64,
    /// Safe-path demotions taken by the graceful-degradation policy.
    pub demotions: u64,
    /// Loads this core had served through the §4.5 safe path because
    /// their whole fault *domain* was quarantined by the host health
    /// tracker (domain-level demotion, not a per-line streak).
    pub quarantine_served: u64,
    /// Completion time of the last retired op.
    pub finish: Ps,
}

impl CoreStats {
    pub fn ipc(&self, period: Ps) -> f64 {
        if self.finish == 0 {
            return 0.0;
        }
        self.retired_insts as f64 / (self.finish as f64 / period as f64)
    }
}

/// Resolved-value scoreboard for logical loads: maps logical index →
/// time its (correct) value became available. Bounded by pruning old
/// entries; missing-but-recent keys mean "not resolved yet".
#[derive(Debug, Default)]
struct LogicalBoard {
    map: FastMap<u64, Ps>,
    /// Keys below this are pruned and considered long-resolved.
    watermark: u64,
    inserts: u64,
}

impl LogicalBoard {
    fn resolve(&mut self, logical: u64, at: Ps) {
        self.map.insert(logical, at);
        self.inserts += 1;
        if self.inserts % (2 * BOARD_WINDOW) == 0 {
            let horizon = logical.saturating_sub(BOARD_WINDOW);
            self.map.retain(|&k, _| k >= horizon);
            self.watermark = self.watermark.max(horizon);
        }
    }

    /// `Some(t)` when the value is (or was) available at `t`; `None` when
    /// the producer has not resolved yet.
    fn ready_at(&self, logical: u64) -> Option<Ps> {
        match self.map.get(&logical) {
            Some(&t) => Some(t),
            None if logical < self.watermark => Some(0),
            None => None,
        }
    }
}

pub struct Core {
    p: CoreParams,
    rob: VecDeque<Slot>,
    head_seq: u64,
    frontend_ready: Ps,
    was_full: bool,
    /// Which bookkeeping implementation backs the board / pairs / request
    /// tracking below (only one side of each pair is ever populated).
    fe: FrontEnd,
    board: LogicalBoard,
    board_ring: BoardRing,
    pairs: FastMap<u64, PairState>,
    pair_ring: PairRing,
    req_map: FastMap<u64, u64>,
    req_seqs: ReqSeqTable,
    /// Consecutive both-fake retry streak per line (graceful-degradation
    /// policy; only touched when `demote_after > 0`).
    retry_streak: FastMap<u64, u32>,
    /// Declared MSHR-stall window: set when the port answers `Stall`,
    /// cleared by the next completion (which may free an MSHR sooner).
    /// Purely informational today — re-issues inside the window are
    /// side-effect free — but a stale window racing a same-tick
    /// completion wake is exactly the hazard
    /// `stall_retry_racing_completion_advances_once` pins down.
    stall_until: Ps,
    source_done: bool,
    /// Earliest time the op source will have work again (open-loop
    /// arrival pacing: the source answered [`Pull::NotBefore`]). `None`
    /// in closed-loop runs — the field is only ever set by a source
    /// that paces arrivals, so closed-loop behavior is untouched.
    arrival_wake: Option<Ps>,
    /// Sequence numbers of Waiting memory slots, in fetch order — the
    /// fence-free issue fast path walks this instead of the full ROB
    /// (EXPERIMENTS.md §Perf: the scan was ~35 % of simulation time).
    waiting: VecDeque<u64>,
    waiting_scratch: VecDeque<u64>,
    /// Fences currently in the window; >0 forces the full ordered scan.
    fences_in_rob: u32,
    pub stats: CoreStats,
}

impl Core {
    /// Reference (map-based) core — the historical default; tests and
    /// standalone users keep this constructor.
    pub fn new(p: CoreParams) -> Core {
        Core::with_frontend(p, FrontEnd::Reference)
    }

    /// Core with an explicit front-end implementation. Only the selected
    /// side's structures are sized; the other stays empty.
    pub fn with_frontend(p: CoreParams, fe: FrontEnd) -> Core {
        let slab = fe == FrontEnd::Slab;
        Core {
            p,
            rob: VecDeque::with_capacity(p.rob_size),
            head_seq: 0,
            frontend_ready: 0,
            was_full: false,
            fe,
            board: LogicalBoard::default(),
            board_ring: if slab { BoardRing::new() } else { BoardRing::default() },
            pairs: FastMap::default(),
            pair_ring: if slab { PairRing::new(p.rob_size) } else { PairRing::default() },
            req_map: FastMap::default(),
            req_seqs: ReqSeqTable::default(),
            retry_streak: FastMap::default(),
            stall_until: 0,
            source_done: false,
            arrival_wake: None,
            waiting: VecDeque::with_capacity(64),
            waiting_scratch: VecDeque::with_capacity(64),
            fences_in_rob: 0,
            stats: CoreStats::default(),
        }
    }

    pub fn params(&self) -> &CoreParams {
        &self.p
    }

    /// True once the stream is exhausted and the window has drained.
    pub fn finished(&self) -> bool {
        self.source_done && self.rob.is_empty()
    }

    pub fn rob_len(&self) -> usize {
        self.rob.len()
    }

    /// Diagnostic snapshot of the window head (deadlock reporting).
    pub fn debug_state(&self) -> String {
        let head = match self.rob.front() {
            None => "empty".to_string(),
            Some(s) => match &s.kind {
                SlotKind::Compute { done } => format!("compute done@{done}"),
                SlotKind::Fence { resolved } => format!("fence resolved={resolved:?}"),
                SlotKind::Mem { acc, state } => format!(
                    "mem {:?} {:#x} logical={} dep={:?} pair={:?} state={:?}",
                    acc.kind, acc.vaddr, acc.logical, acc.dep_on, acc.pair, state
                ),
            },
        };
        let (pairs, reqs) = match self.fe {
            FrontEnd::Reference => (self.pairs.len(), self.req_map.len()),
            FrontEnd::Slab => (self.pair_ring.len(), self.req_seqs.len()),
        };
        format!(
            "rob={} head=[{}] src_done={} pairs={pairs} reqs={reqs} stall_until={}",
            self.rob.len(),
            head,
            self.source_done,
            self.stall_until
        )
    }

    /// Record the resolution time of a logical load's value.
    #[inline]
    fn board_resolve(&mut self, logical: u64, at: Ps) {
        match self.fe {
            FrontEnd::Reference => self.board.resolve(logical, at),
            FrontEnd::Slab => self.board_ring.resolve(logical, at),
        }
    }

    fn fetch_cost(&self, insts: u32) -> Ps {
        (insts as u64 * self.p.period) / self.p.fetch_per_cycle as u64
    }

    fn fill<S: OpSource + ?Sized>(&mut self, now: Ps, source: &mut S) {
        // Any previously declared arrival wake is stale: this fill either
        // reaches the source again (and gets a fresh NotBefore) or fills
        // the window, in which case no arrival wake is needed.
        self.arrival_wake = None;
        if self.was_full && self.rob.len() < self.p.rob_size {
            // Frontend resumed after a full window: it cannot have fetched
            // in the past.
            self.frontend_ready = self.frontend_ready.max(now);
            self.was_full = false;
        }
        while self.rob.len() < self.p.rob_size {
            let op = match source.pull(now) {
                Pull::Op(op) => op,
                Pull::NotBefore(t) => {
                    // Open-loop pacing: more work will arrive at `t`, but
                    // the stream is NOT done — remember to wake then.
                    self.arrival_wake = Some(t.max(now + 1));
                    return;
                }
                Pull::Exhausted => {
                    self.source_done = true;
                    return;
                }
            };
            let insts = op.insts();
            let fetch_done = self.frontend_ready + self.fetch_cost(insts);
            self.frontend_ready = fetch_done;
            let seq = self.head_seq + self.rob.len() as u64;
            let kind = match op {
                MicroOp::Compute(_) => SlotKind::Compute { done: fetch_done },
                MicroOp::Fence => {
                    self.fences_in_rob += 1;
                    SlotKind::Fence { resolved: None }
                }
                MicroOp::Mem(acc) => {
                    self.waiting.push_back(seq);
                    SlotKind::Mem { acc, state: MemState::Waiting }
                }
            };
            self.rob.push_back(Slot { kind, insts, fetch_done });
        }
        self.was_full = self.rob.len() >= self.p.rob_size;
    }

    /// Issue ready memory ops / resolve fences. Returns
    /// `(made_progress, earliest_future_ready)`.
    fn issue<P: MemoryPort + ?Sized>(&mut self, now: Ps, port: &mut P) -> (bool, Option<Ps>) {
        if self.fences_in_rob == 0 {
            return self.issue_fast(now, port);
        }
        self.issue_full(now, port)
    }

    /// Fence-free fast path: only Waiting slots are visited, via the
    /// `waiting` index (fetch order preserved, matching the full scan).
    fn issue_fast<P: MemoryPort + ?Sized>(&mut self, now: Ps, port: &mut P) -> (bool, Option<Ps>) {
        let mut progressed = false;
        let mut wake: Option<Ps> = None;
        let mut done_events: Vec<(u64, Ps, DataKind)> = Vec::new();
        let mut stalled = false;
        // Deferred like `issue_full`'s `stall_wake`: applied after the
        // loop so the domination over finer-grained wakes is explicit
        // rather than an accident of iteration order.
        let mut stall_wake: Option<Ps> = None;
        self.waiting_scratch.clear();
        while let Some(seq) = self.waiting.pop_front() {
            if stalled {
                self.waiting_scratch.push_back(seq);
                continue;
            }
            let idx = (seq - self.head_seq) as usize;
            let slot = &mut self.rob[idx];
            let SlotKind::Mem { acc, state } = &mut slot.kind else {
                unreachable!("waiting index points at a non-mem slot")
            };
            debug_assert!(matches!(state, MemState::Waiting));
            // Field-level dispatch (a method call would re-borrow self
            // while the ROB slot borrow is live).
            let dep_ready = match acc.dep_on {
                None => Some(0),
                Some(l) => match self.fe {
                    FrontEnd::Reference => self.board.ready_at(l),
                    FrontEnd::Slab => self.board_ring.ready_at(l),
                },
            };
            let Some(dep_t) = dep_ready else {
                self.waiting_scratch.push_back(seq);
                continue;
            };
            let ready = slot.fetch_done.max(dep_t);
            if ready > now {
                if wake.map_or(true, |w| ready < w) {
                    wake = Some(ready);
                }
                self.waiting_scratch.push_back(seq);
                continue;
            }
            match port.issue(now, acc) {
                IssueResult::Done { at, data } => {
                    *state = MemState::Done { at };
                    progressed = true;
                    done_events.push((seq, at, data));
                }
                IssueResult::Pending { req_id } => {
                    *state = MemState::Issued;
                    match self.fe {
                        FrontEnd::Reference => {
                            self.req_map.insert(req_id, seq);
                        }
                        FrontEnd::Slab => self.req_seqs.set(req_id, seq),
                    }
                    progressed = true;
                }
                IssueResult::Stall { retry_at } => {
                    self.stall_until = retry_at;
                    stall_wake = Some(retry_at);
                    stalled = true;
                    self.waiting_scratch.push_back(seq);
                }
            }
        }
        if let Some(t) = stall_wake {
            // The stall dominates any finer-grained wake collected above:
            // nothing can issue until a completion (which re-advances us
            // and clears the window) or the retry time.
            wake = Some(t);
        }
        std::mem::swap(&mut self.waiting, &mut self.waiting_scratch);
        for (seq, at, data) in done_events {
            self.on_mem_done(seq, at, data);
        }
        (progressed, wake)
    }

    /// Full ordered scan (fences present): resolves fences against prior
    /// memory completion and enforces the issue barrier. Rebuilds the
    /// waiting index as it goes.
    fn issue_full<P: MemoryPort + ?Sized>(&mut self, now: Ps, port: &mut P) -> (bool, Option<Ps>) {
        self.waiting.clear();
        let mut progressed = false;
        let mut wake: Option<Ps> = None;
        let mut add_wake = |t: Ps| {
            if t > now {
                wake = Some(wake.map_or(t, |w: Ps| w.min(t)));
            }
        };
        // Completion-time of all prior mem ops, None if one is unfinished.
        let mut prior_mem_done: Option<Ps> = Some(0);
        // Active fence barrier: loads past it may not issue before `t`.
        let mut barrier: Option<Ps> = None;

        let mut done_events: Vec<(u64, Ps, DataKind)> = Vec::new();
        // Set when an MSHR stall aborts the scan: index past the stalled
        // slot, from which the waiting index must be rebuilt, and the
        // stall's dominating wake time (applied after the scan, once the
        // `add_wake` closure's borrow of `wake` has ended).
        let mut stalled_after: Option<usize> = None;
        let mut stall_wake: Option<Ps> = None;
        'scan: for (i, slot) in self.rob.iter_mut().enumerate() {
            let seq = self.head_seq + i as u64;
            match &mut slot.kind {
                SlotKind::Compute { .. } => {}
                SlotKind::Fence { resolved } => {
                    if resolved.is_none() {
                        if let Some(t) = prior_mem_done {
                            *resolved = Some(t.max(slot.fetch_done));
                        }
                    }
                    match *resolved {
                        Some(t) if t <= now => {}
                        Some(t) => {
                            barrier = Some(barrier.map_or(t, |b: Ps| b.max(t)));
                            add_wake(t);
                        }
                        None => barrier = Some(Ps::MAX),
                    }
                }
                SlotKind::Mem { acc, state } => match state {
                    MemState::Waiting => {
                        // An unissued op is not complete: any fence after it
                        // must not resolve (unless we complete it below).
                        let prior_before = prior_mem_done.take();
                        if let Some(b) = barrier {
                            self.waiting.push_back(seq);
                            if b == Ps::MAX {
                                continue; // resolves via a completion event
                            }
                            add_wake(b);
                            continue;
                        }
                        let dep_ready = match acc.dep_on {
                            None => Some(0),
                            Some(l) => match self.fe {
                                FrontEnd::Reference => self.board.ready_at(l),
                                FrontEnd::Slab => self.board_ring.ready_at(l),
                            },
                        };
                        let Some(dep_t) = dep_ready else {
                            self.waiting.push_back(seq);
                            continue;
                        };
                        let ready = slot.fetch_done.max(dep_t);
                        if ready > now {
                            add_wake(ready);
                            self.waiting.push_back(seq);
                            continue;
                        }
                        match port.issue(now, acc) {
                            IssueResult::Done { at, data } => {
                                *state = MemState::Done { at };
                                progressed = true;
                                done_events.push((seq, at, data));
                                prior_mem_done = prior_before.map(|t| t.max(at));
                            }
                            IssueResult::Pending { req_id } => {
                                *state = MemState::Issued;
                                match self.fe {
                                    FrontEnd::Reference => {
                                        self.req_map.insert(req_id, seq);
                                    }
                                    FrontEnd::Slab => self.req_seqs.set(req_id, seq),
                                }
                                prior_mem_done = None;
                                progressed = true;
                            }
                            IssueResult::Stall { retry_at } => {
                                self.stall_until = retry_at;
                                // In-order MSHR allocation: stop issuing,
                                // but still deliver synchronous completions
                                // collected earlier in this scan. The stall
                                // dominates all finer-grained fetch wakes:
                                // nothing can issue until a completion (which
                                // re-advances us) or the retry time.
                                stall_wake = Some(retry_at);
                                self.waiting.push_back(seq);
                                stalled_after = Some(i + 1);
                                break 'scan;
                            }
                        }
                    }
                    MemState::Issued => prior_mem_done = None,
                    MemState::Done { at } => {
                        prior_mem_done = prior_mem_done.map(|t| t.max(*at));
                    }
                },
            }
        }
        if let Some(t) = stall_wake {
            // The stall dominates any finer-grained wake collected above.
            wake = Some(t);
        }
        if let Some(start) = stalled_after {
            // Remaining Waiting slots must stay indexed (done here, after
            // the scan's mutable ROB borrow has ended).
            for (j, s) in self.rob.iter().enumerate().skip(start) {
                if matches!(s.kind, SlotKind::Mem { state: MemState::Waiting, .. }) {
                    self.waiting.push_back(self.head_seq + j as u64);
                }
            }
        }
        for (seq, at, data) in done_events {
            self.on_mem_done(seq, at, data);
        }
        (progressed, wake)
    }

    /// Handle a memory completion for the slot with sequence `seq`.
    fn on_mem_done(&mut self, seq: u64, at: Ps, data: DataKind) {
        let idx = (seq - self.head_seq) as usize;
        let acc = match &self.rob[idx].kind {
            SlotKind::Mem { acc, .. } => *acc,
            _ => unreachable!("completion for non-mem slot"),
        };
        match acc.kind {
            AccessKind::Load => {
                self.stats.loads += 1;
                match acc.pair {
                    None => self.board_resolve(acc.logical, at),
                    Some(p) => {
                        if let Some(late) = self.twin_done(p, &acc, at, data) {
                            // The software retry also delays this load's
                            // own retirement (the inlined handler runs
                            // before the program continues).
                            if let SlotKind::Mem { state, .. } =
                                &mut self.rob[idx].kind
                            {
                                *state = MemState::Done { at: late };
                            }
                        }
                    }
                }
            }
            AccessKind::Store => {
                self.stats.stores += 1;
                if data == DataKind::Fake {
                    // CAS found the placeholder pattern at `p` (the line
                    // holds fake data — RFO'd after an interrupt-eviction,
                    // or the ext twin reached MEC1 first). §3.2: software
                    // retries the store (invalidate + fence + re-twin-load
                    // + CAS). The model charges the retry's end-to-end
                    // latency and instructions to the resolution (see
                    // DESIGN.md §Retry-modeling).
                    self.stats.cas_fails += 1;
                    self.charge_retry();
                    self.board_resolve(acc.logical, at + self.p.retry_penalty);
                } else {
                    self.board_resolve(acc.logical, at);
                }
            }
            AccessKind::Invalidate => {}
            AccessKind::SafePath => {
                self.stats.loads += 1;
                self.board_resolve(acc.logical, at);
            }
        }
    }

    /// Twin-pair resolution (§4.4 Table 2). Returns `Some(t)` when a
    /// software retry delays completion to `t`.
    fn twin_done(
        &mut self,
        pair: u64,
        acc: &MemAccess,
        at: Ps,
        data: DataKind,
    ) -> Option<Ps> {
        // First twin: record and wait. Second twin: detach the pair state
        // (both twins share `logical`, so recording either is identical).
        let second = match self.fe {
            FrontEnd::Reference => {
                let entry = self.pairs.entry(pair).or_insert(PairState {
                    logical: acc.logical,
                    first: None,
                });
                match entry.first {
                    None => {
                        entry.first = Some((at, data));
                        None
                    }
                    Some((t0, d0)) => {
                        let logical = entry.logical;
                        self.pairs.remove(&pair);
                        Some((t0, d0.is_real(), logical))
                    }
                }
            }
            FrontEnd::Slab => self.pair_ring.observe(pair, acc.logical, at, data.is_real()),
        };
        let Some((t0, first_real, logical)) = second else {
            return None;
        };
        let resolved_at = t0.max(at);
        let got_real = first_real || data.is_real();
        let line = acc.vaddr & !0x3F;
        if got_real {
            if self.p.demote_after > 0 {
                self.retry_streak.remove(&line);
            }
            self.board_resolve(logical, resolved_at);
            None
        } else {
            // Table 2 state 4 (or a too-late second load): the
            // inlined handler invalidates both lines, fences, and
            // twin-loads again — charged as a lump penalty. Past
            // `demote_after` consecutive failures on the line
            // (a not-ready storm, or the true value equalling the
            // fake pattern) the handler gives up on cacheable
            // retries and re-reads through the §4.5 safe path.
            let demote = if self.p.demote_after > 0 {
                let streak = self.retry_streak.entry(line).or_insert(0);
                *streak += 1;
                let storm = *streak == 2;
                let hit = *streak >= self.p.demote_after;
                if hit {
                    *streak = 0;
                }
                if storm {
                    self.stats.retry_storms += 1;
                }
                hit
            } else {
                false
            };
            self.charge_retry();
            let done = if demote {
                self.stats.demotions += 1;
                self.stats.safe_paths += 1;
                resolved_at + self.p.safe_penalty
            } else {
                self.stats.twin_retries += 1;
                resolved_at + self.p.retry_penalty
            };
            self.board_resolve(logical, done);
            Some(done)
        }
    }

    /// Account the instruction-stream cost of one software retry
    /// (2 × clflush + mfence + 2 loads + checks ≈ 20 instructions).
    fn charge_retry(&mut self) {
        self.stats.retired_insts += 20;
    }

    /// The host health tracker quarantined this load's whole fault
    /// domain: the platform served it through the §4.5 safe path (real
    /// data, no twin content check) and charged `safe_penalty` at
    /// delivery. Only the robustness accounting lands here, so per-core
    /// safe-path totals cover both per-line streak demotions and
    /// domain-level quarantine.
    pub(crate) fn note_quarantined_safe(&mut self) {
        self.stats.safe_paths += 1;
        self.stats.quarantine_served += 1;
    }

    /// Retire completed ops from the window head. Returns progress.
    fn retire(&mut self, now: Ps) -> bool {
        let mut progressed = false;
        while let Some(slot) = self.rob.front() {
            let done_at = match &slot.kind {
                SlotKind::Compute { done } => Some(*done),
                SlotKind::Fence { resolved } => *resolved,
                SlotKind::Mem { state: MemState::Done { at }, .. } => Some(*at),
                SlotKind::Mem { .. } => None,
            };
            match done_at {
                Some(t) if t <= now => {
                    if matches!(slot.kind, SlotKind::Fence { .. }) {
                        self.stats.fences += 1;
                        self.fences_in_rob -= 1;
                    }
                    self.stats.retired_insts += slot.insts as u64;
                    self.stats.retired_ops += 1;
                    self.stats.finish = self.stats.finish.max(t);
                    self.rob.pop_front();
                    self.head_seq += 1;
                    progressed = true;
                }
                _ => break,
            }
        }
        progressed
    }

    /// Platform callback: the memory request `req_id` completed at `at`
    /// with content `data`. Returns true if the core should be re-advanced.
    pub fn complete(&mut self, req_id: u64, at: Ps, data: DataKind) -> bool {
        // The completion may have freed an MSHR: the declared stall
        // window is stale from here on. Clearing it closes the
        // double-wake hazard where a stall-retry wake racing a same-tick
        // completion would otherwise find (and act on) an expired window.
        self.stall_until = 0;
        let seq = match self.fe {
            FrontEnd::Reference => match self.req_map.remove(&req_id) {
                Some(seq) => seq,
                None => return false,
            },
            FrontEnd::Slab => match self.req_seqs.take(req_id) {
                Some(seq) => seq,
                None => return false,
            },
        };
        let idx = (seq - self.head_seq) as usize;
        match &mut self.rob[idx].kind {
            SlotKind::Mem { state, .. } => *state = MemState::Done { at },
            _ => unreachable!(),
        }
        self.on_mem_done(seq, at, data);
        true
    }

    /// Drive the core at `now`. Returns the next time-based wake, or None
    /// when progress depends only on memory completions (or it finished).
    pub fn advance<S: OpSource + ?Sized, P: MemoryPort + ?Sized>(
        &mut self,
        now: Ps,
        source: &mut S,
        port: &mut P,
    ) -> Option<Ps> {
        // Fixpoint loop; the final (unproductive) issue() scan already
        // computes the earliest future-ready wake, so no extra scan is
        // needed afterwards (it was ~15 % of simulation time — see
        // EXPERIMENTS.md §Perf).
        let mut wake;
        loop {
            self.fill(now, source);
            let (issued, w) = self.issue(now, port);
            wake = w;
            let retired = self.retire(now);
            if !issued && !retired {
                break;
            }
        }
        if let Some(slot) = self.rob.front() {
            let head_t = match &slot.kind {
                SlotKind::Compute { done } => Some(*done),
                SlotKind::Fence { resolved } => *resolved,
                SlotKind::Mem { state: MemState::Done { at }, .. } => Some(*at),
                SlotKind::Mem { .. } => None,
            };
            if let Some(t) = head_t {
                if t > now {
                    wake = Some(wake.map_or(t, |w| w.min(t)));
                }
            }
        }
        if let Some(t) = self.arrival_wake {
            // Open-loop: even an otherwise idle core must wake for the
            // next arrival (t > now by construction in `fill`).
            wake = Some(wake.map_or(t, |w| w.min(t)));
        }
        wake
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::time::NS;

    /// Fixed-latency memory with an MSHR cap; optionally returns fake data
    /// for chosen addresses (twin emulation).
    struct MockMem {
        latency: Ps,
        mshrs: usize,
        inflight: Vec<(u64, Ps, u64)>, // (req_id, done_at, addr)
        next_id: u64,
        issued: u64,
        fake_addrs: Vec<u64>,
        fake_once: bool,
    }

    impl MockMem {
        fn new(latency: Ps, mshrs: usize) -> MockMem {
            MockMem {
                latency,
                mshrs,
                inflight: Vec::new(),
                next_id: 1,
                issued: 0,
                fake_addrs: Vec::new(),
                fake_once: false,
            }
        }

        /// Deliver all completions due at or before `now` to the core.
        fn deliver(&mut self, now: Ps, core: &mut Core) {
            let mut due: Vec<(u64, Ps, u64)> =
                self.inflight.iter().copied().filter(|&(_, t, _)| t <= now).collect();
            due.sort_by_key(|&(_, t, _)| t);
            self.inflight.retain(|&(_, t, _)| t > now);
            for (id, t, addr) in due {
                let fake = self.fake_addrs.contains(&addr);
                if fake && self.fake_once {
                    self.fake_addrs.retain(|&a| a != addr);
                }
                let data = if fake { DataKind::Fake } else { DataKind::Real };
                core.complete(id, t, data);
            }
        }

        fn next_event(&self) -> Option<Ps> {
            self.inflight.iter().map(|&(_, t, _)| t).min()
        }
    }

    impl MemoryPort for MockMem {
        fn issue(&mut self, now: Ps, acc: &MemAccess) -> IssueResult {
            if acc.kind == AccessKind::Invalidate {
                return IssueResult::Done { at: now + 1, data: DataKind::Real };
            }
            if self.inflight.len() >= self.mshrs {
                return IssueResult::Stall { retry_at: now + self.latency };
            }
            self.issued += 1;
            let id = self.next_id;
            self.next_id += 1;
            self.inflight.push((id, now + self.latency, acc.vaddr));
            IssueResult::Pending { req_id: id }
        }
    }

    /// Run a micro-op list to completion; returns (stats, end_time).
    fn run(ops: Vec<MicroOp>, mem: &mut MockMem) -> (CoreStats, Ps) {
        let mut core = Core::new(CoreParams::xeon());
        let mut src = ops.into_iter();
        let mut now = 0;
        for _ in 0..1_000_000 {
            let wake = core.advance(now, &mut src, mem);
            if core.finished() {
                break;
            }
            let mem_t = mem.next_event();
            let next = match (wake, mem_t) {
                (Some(a), Some(b)) => a.min(b),
                (Some(a), None) => a,
                (None, Some(b)) => b,
                (None, None) => panic!("deadlock: no wake and no memory event"),
            };
            now = next;
            mem.deliver(now, &mut core);
        }
        assert!(core.finished(), "core did not finish");
        (core.stats, now)
    }

    #[test]
    fn compute_only_ipc_is_fetch_width() {
        let ops = vec![MicroOp::Compute(4000)];
        let mut mem = MockMem::new(100 * NS, 10);
        let (stats, _) = run(ops, &mut mem);
        assert_eq!(stats.retired_insts, 4000);
        let ipc = stats.ipc(400);
        assert!((ipc - 4.0).abs() < 0.1, "ipc={ipc}");
    }

    #[test]
    fn independent_loads_overlap() {
        // 8 independent loads at 100 ns: with MLP they finish in ~100 ns,
        // not 800 ns.
        let ops: Vec<MicroOp> =
            (0..8).map(|i| MicroOp::Mem(MemAccess::load(i * 64, i))).collect();
        let mut mem = MockMem::new(100 * NS, 10);
        let (stats, _) = run(ops, &mut mem);
        assert!(stats.finish < 150 * NS, "finish={}", stats.finish);
        assert_eq!(stats.loads, 8);
    }

    #[test]
    fn dependent_loads_serialize() {
        // A pointer chase: each load's address depends on the previous.
        let ops: Vec<MicroOp> = (0..8)
            .map(|i| {
                MicroOp::Mem(
                    MemAccess::load(i * 64, i).with_dep(if i == 0 { None } else { Some(i - 1) }),
                )
            })
            .collect();
        let mut mem = MockMem::new(100 * NS, 10);
        let (stats, _) = run(ops, &mut mem);
        assert!(stats.finish >= 800 * NS, "finish={}", stats.finish);
    }

    #[test]
    fn mshr_limit_caps_mlp() {
        // 20 independent loads but only 4 MSHRs: at least 5 serial rounds.
        let ops: Vec<MicroOp> =
            (0..20).map(|i| MicroOp::Mem(MemAccess::load(i * 64, i))).collect();
        let mut mem = MockMem::new(100 * NS, 4);
        let (stats, _) = run(ops, &mut mem);
        assert!(stats.finish >= 500 * NS, "finish={}", stats.finish);
    }

    #[test]
    fn fence_blocks_following_load() {
        // load, FENCE, load: the second load can't start until the first
        // returns → ~2 serial latencies even though both are independent.
        let ops = vec![
            MicroOp::Mem(MemAccess::load(0, 0)),
            MicroOp::Fence,
            MicroOp::Mem(MemAccess::load(64, 1)),
        ];
        let mut mem = MockMem::new(100 * NS, 10);
        let (stats, _) = run(ops, &mut mem);
        assert!(stats.finish >= 200 * NS, "finish={}", stats.finish);
        assert_eq!(stats.fences, 1);
    }

    #[test]
    fn compute_hides_under_loads() {
        // A load plus 200 instructions: the compute retires under the
        // load's shadow; total ≈ load latency, not load + compute.
        let ops = vec![
            MicroOp::Mem(MemAccess::load(0, 0)),
            MicroOp::Compute(100),
            MicroOp::Mem(MemAccess::load(64, 1)),
            MicroOp::Compute(100),
        ];
        let mut mem = MockMem::new(100 * NS, 10);
        let (stats, _) = run(ops, &mut mem);
        assert!(stats.finish < 120 * NS, "finish={}", stats.finish);
        assert_eq!(stats.retired_insts, 202);
    }

    #[test]
    fn twin_pair_with_real_value_resolves() {
        // Pair where one side returns fake (shadow) — normal TL-OoO case.
        let ops = vec![
            MicroOp::Mem(MemAccess::load(0, 0).with_pair(7)),
            MicroOp::Mem(MemAccess::load(1 << 20, 0).with_pair(7)),
            MicroOp::Compute(6),
            // Dependent on the twin value:
            MicroOp::Mem(MemAccess::load(128, 1).with_dep(Some(0))),
        ];
        let mut mem = MockMem::new(100 * NS, 10);
        mem.fake_addrs.push(1 << 20);
        let (stats, _) = run(ops, &mut mem);
        assert_eq!(stats.twin_retries, 0);
        // Dependent load waited for pair resolution: ≥ 2 serialized... no —
        // twins are concurrent, so ≈ 100ns + 100ns.
        assert!(stats.finish >= 200 * NS && stats.finish < 250 * NS,
            "finish={}", stats.finish);
    }

    #[test]
    fn both_fake_charges_retry_and_delays_dependents() {
        let a = 64u64;
        let b = 1 << 20;
        let ops = vec![
            MicroOp::Mem(MemAccess::load(a, 0).with_pair(3)),
            MicroOp::Mem(MemAccess::load(b, 0).with_pair(3)),
            MicroOp::Compute(6),
            // Dependent on the twin value: must wait out the retry penalty.
            MicroOp::Mem(MemAccess::load(4 << 20, 1).with_dep(Some(0))),
        ];
        let mut mem = MockMem::new(100 * NS, 10);
        mem.fake_addrs.push(a);
        mem.fake_addrs.push(b);
        let (stats, _) = run(ops, &mut mem);
        assert_eq!(stats.twin_retries, 1);
        // pair resolves ~100 ns + retry_penalty (400 ns); dependent load
        // then takes another 100 ns.
        let p = CoreParams::xeon();
        assert!(
            stats.finish >= 100 * NS + p.retry_penalty + 100 * NS,
            "retry penalty not charged: finish={}",
            stats.finish
        );
        // Retry instruction overhead accounted.
        assert!(stats.retired_insts > 6 + 3);
    }

    #[test]
    fn real_value_pair_pays_no_retry() {
        let ops = vec![
            MicroOp::Mem(MemAccess::load(64, 0).with_pair(3)),
            MicroOp::Mem(MemAccess::load(1 << 20, 0).with_pair(3)),
            MicroOp::Mem(MemAccess::load(4 << 20, 1).with_dep(Some(0))),
        ];
        let mut mem = MockMem::new(100 * NS, 10);
        mem.fake_addrs.push(1 << 20); // only the shadow is fake
        let (stats, _) = run(ops, &mut mem);
        assert_eq!(stats.twin_retries, 0);
        assert!(stats.finish < 300 * NS, "finish={}", stats.finish);
    }

    /// Run `ops` to completion on a specific core (demotion tests need
    /// non-default [`CoreParams`] and per-frontend cores).
    fn run_on(mut core: Core, ops: Vec<MicroOp>, mem: &mut MockMem) -> CoreStats {
        let mut src = ops.into_iter();
        let mut now = 0;
        for _ in 0..1_000_000 {
            let wake = core.advance(now, &mut src, mem);
            if core.finished() {
                break;
            }
            let next = match (wake, mem.next_event()) {
                (Some(a), Some(b)) => a.min(b),
                (Some(a), None) => a,
                (None, Some(b)) => b,
                (None, None) => panic!("deadlock: no wake and no memory event"),
            };
            now = next;
            mem.deliver(now, &mut core);
        }
        assert!(core.finished(), "core did not finish");
        core.stats
    }

    /// Five twin-loads of one line, every response fake (a pinned
    /// not-ready storm), demotion threshold K = 3: the third consecutive
    /// failure demotes to the safe path and resets the streak.
    fn storm_ops() -> Vec<MicroOp> {
        let mut ops = Vec::new();
        for k in 0..5u64 {
            ops.push(MicroOp::Mem(MemAccess::load(64, k).with_pair(k)));
            ops.push(MicroOp::Mem(MemAccess::load(1 << 20, k).with_pair(k)));
        }
        // Dependent on the demoted pair's value: correct data must still
        // arrive, delayed by the safe path.
        ops.push(MicroOp::Mem(MemAccess::load(8 << 20, 5).with_dep(Some(2))));
        ops
    }

    #[test]
    fn not_ready_storm_demotes_to_safe_path() {
        let mut p = CoreParams::xeon();
        p.demote_after = 3;
        for fe in [FrontEnd::Reference, FrontEnd::Slab] {
            let mut mem = MockMem::new(100 * NS, 10);
            mem.fake_addrs = vec![64, 1 << 20];
            let stats = run_on(Core::with_frontend(p, fe), storm_ops(), &mut mem);
            // Streak over 5 pairs: 1, 2 (storm), 3 → demote+reset, 1,
            // 2 (storm again).
            assert_eq!(stats.twin_retries, 4, "{fe:?}");
            assert_eq!(stats.demotions, 1, "{fe:?}");
            assert_eq!(stats.safe_paths, 1, "{fe:?}");
            assert_eq!(stats.retry_storms, 2, "{fe:?}");
            assert_eq!(stats.loads, 11, "{fe:?}");
            // The demoted pair resolved through the safe path, and its
            // dependent still got (correct) data afterwards.
            assert!(
                stats.finish >= 100 * NS + p.safe_penalty + 100 * NS,
                "{fe:?}: finish={}",
                stats.finish
            );
        }
    }

    #[test]
    fn demotion_disabled_by_default_keeps_retry_behavior() {
        // Same storm with demote_after = 0 (the default): every failure
        // is a plain §4.4 retry — no demotions, no safe paths, no streak
        // state (the fault-free bit-identity guarantee).
        let mut mem = MockMem::new(100 * NS, 10);
        mem.fake_addrs = vec![64, 1 << 20];
        let stats = run_on(Core::new(CoreParams::xeon()), storm_ops(), &mut mem);
        assert_eq!(stats.twin_retries, 5);
        assert_eq!(stats.demotions, 0);
        assert_eq!(stats.safe_paths, 0);
        assert_eq!(stats.retry_storms, 0);
    }

    #[test]
    fn quarantine_note_counts_domain_demotions() {
        let mut core = Core::new(CoreParams::xeon());
        core.note_quarantined_safe();
        core.note_quarantined_safe();
        assert_eq!(core.stats.quarantine_served, 2);
        assert_eq!(core.stats.safe_paths, 2);
        // Pure accounting: no timing or window state is touched.
        assert_eq!(core.stats.retired_insts, 0);
        assert_eq!(core.stats.retired_ops, 0);
    }

    #[test]
    fn demotion_frontends_bit_identical() {
        let mut p = CoreParams::xeon();
        p.demote_after = 2;
        let mut results = Vec::new();
        for fe in [FrontEnd::Reference, FrontEnd::Slab] {
            let mut mem = MockMem::new(100 * NS, 10);
            mem.fake_addrs = vec![64, 1 << 20];
            let s = run_on(Core::with_frontend(p, fe), storm_ops(), &mut mem);
            results.push((
                s.finish,
                s.retired_insts,
                s.retired_ops,
                s.twin_retries,
                s.safe_paths,
                s.demotions,
                s.retry_storms,
            ));
        }
        assert_eq!(results[0], results[1], "front ends diverged under demotion");
    }

    /// Satellite regression (PR 4's deferred-wake pattern, now under
    /// direct coverage): a stall-retry wake racing a same-tick completion
    /// advances the window exactly once — the stale stall wake alone must
    /// not issue, and a duplicate advance after the completion must not
    /// move anything again.
    #[test]
    fn stall_retry_racing_completion_advances_once() {
        let mut core = Core::new(CoreParams::xeon());
        let ops = vec![
            MicroOp::Mem(MemAccess::load(0, 0)),
            MicroOp::Mem(MemAccess::load(64, 1)),
        ];
        let mut src = ops.into_iter();
        let mut mem = MockMem::new(100 * NS, 1);
        // One MSHR: A issues, B stalls with retry_at = completion time.
        let wake = core.advance(0, &mut src, &mut mem);
        assert_eq!(mem.issued, 1);
        let t = wake.expect("stall retry wake");
        assert_eq!(t, 100 * NS, "stall wake should be the retry time");
        // The stale stall wake pops first (lower event seq than the
        // same-tick delivery): B must re-stall, not issue or retire.
        core.advance(t, &mut src, &mut mem);
        assert_eq!((core.stats.retired_ops, mem.issued), (0, 1));
        // The completion lands on the same tick and re-advances the core:
        // A retires once, B issues exactly once.
        mem.deliver(t, &mut core);
        core.advance(t, &mut src, &mut mem);
        assert_eq!((core.stats.retired_ops, mem.issued), (1, 2));
        // A second racing advance on the same tick is a no-op.
        core.advance(t, &mut src, &mut mem);
        assert_eq!((core.stats.retired_ops, mem.issued), (1, 2));
        // Drain: B completes and retires exactly once.
        mem.deliver(2 * t, &mut core);
        core.advance(2 * t, &mut src, &mut mem);
        assert!(core.finished());
        assert_eq!(core.stats.retired_ops, 2);
        assert_eq!(core.stats.loads, 2);
    }

    #[test]
    fn rob_bounds_runahead() {
        // 1000 independent loads with huge latency and plenty of MSHRs:
        // the ROB (168) caps how many can be outstanding.
        let mut core = Core::new(CoreParams::xeon());
        let ops: Vec<MicroOp> =
            (0..1000).map(|i| MicroOp::Mem(MemAccess::load(i * 64, i))).collect();
        let mut src = ops.into_iter();
        let mut mem = MockMem::new(1_000_000 * NS, 100_000);
        core.advance(0, &mut src, &mut mem);
        assert!(mem.issued <= 168, "issued={}", mem.issued);
        assert_eq!(core.rob_len(), 168);
    }

    #[test]
    fn finish_time_counts_last_retire() {
        let ops = vec![MicroOp::Mem(MemAccess::load(0, 0)), MicroOp::Compute(4)];
        let mut mem = MockMem::new(50 * NS, 10);
        let (stats, _) = run(ops, &mut mem);
        assert!(stats.finish >= 50 * NS);
        assert_eq!(stats.retired_ops, 2);
        assert_eq!(stats.retired_insts, 5);
    }

    /// Both front ends must produce bit-identical core behavior on the
    /// same micro-op stream and memory timing — including twin retries,
    /// CAS failures, fences, and dependency stalls.
    #[test]
    fn slab_frontend_matches_reference_core() {
        use crate::cpu::FrontEnd;
        let scenarios: Vec<(Vec<MicroOp>, Vec<u64>)> = vec![
            // Twin pair resolving real (shadow fake), dependent load.
            (
                vec![
                    MicroOp::Mem(MemAccess::load(0, 0).with_pair(7)),
                    MicroOp::Mem(MemAccess::load(1 << 20, 0).with_pair(7)),
                    MicroOp::Compute(8),
                    MicroOp::Mem(MemAccess::load(128, 1).with_dep(Some(0))),
                ],
                vec![1 << 20],
            ),
            // Both-fake pair: software retry path.
            (
                vec![
                    MicroOp::Mem(MemAccess::load(64, 0).with_pair(3)),
                    MicroOp::Mem(MemAccess::load(1 << 20, 0).with_pair(3)),
                    MicroOp::Mem(MemAccess::load(4 << 20, 1).with_dep(Some(0))),
                ],
                vec![64, 1 << 20],
            ),
            // Fenced loads + CAS store seeing fake data + safe path.
            (
                vec![
                    MicroOp::Mem(MemAccess::load(0, 0)),
                    MicroOp::Fence,
                    MicroOp::Mem(MemAccess::store(0, 1)),
                    MicroOp::Mem(MemAccess {
                        vaddr: 256,
                        kind: AccessKind::SafePath,
                        logical: 2,
                        dep_on: Some(1),
                        pair: None,
                        retry: false,
                    }),
                    MicroOp::Compute(40),
                ],
                vec![0],
            ),
        ];
        for (ops, fakes) in scenarios {
            let mut results = Vec::new();
            for fe in [FrontEnd::Reference, FrontEnd::Slab] {
                let mut core = Core::with_frontend(CoreParams::xeon(), fe);
                let mut src = ops.clone().into_iter();
                let mut mem = MockMem::new(100 * NS, 4);
                mem.fake_addrs = fakes.clone();
                let mut now = 0;
                for _ in 0..100_000 {
                    let wake = core.advance(now, &mut src, &mut mem);
                    if core.finished() {
                        break;
                    }
                    let next = match (wake, mem.next_event()) {
                        (Some(a), Some(b)) => a.min(b),
                        (Some(a), None) => a,
                        (None, Some(b)) => b,
                        (None, None) => panic!("deadlock"),
                    };
                    now = next;
                    mem.deliver(now, &mut core);
                }
                assert!(core.finished(), "{fe:?} did not finish");
                let s = core.stats;
                results.push((
                    s.finish,
                    s.retired_insts,
                    s.retired_ops,
                    s.loads,
                    s.stores,
                    s.fences,
                    s.twin_retries,
                    s.cas_fails,
                    s.safe_paths,
                ));
            }
            assert_eq!(results[0], results[1], "front ends diverged on {ops:?}");
        }
    }
}
