//! Cross-module integration tests: paper-shape assertions the figure
//! benches rely on, run at smoke scale.

use twinload::config::{RunSpec, SystemConfig};
use twinload::coordinator::experiments as exp;
use twinload::mec::Topology;
use twinload::sim::{run_spec, SimReport};
use twinload::util::time::NS;
use twinload::workloads::WorkloadKind;

fn run(cfg: &SystemConfig, wl: WorkloadKind, ops: u64) -> SimReport {
    let mut cfg = cfg.clone();
    cfg.cores = 2;
    let mut spec = RunSpec::smoke(wl);
    spec.ops_per_core = ops;
    let r = run_spec(&cfg, &spec);
    assert!(!r.deadlocked, "{}/{} deadlocked", r.mechanism, r.workload);
    r
}

/// Figure-7 ordering at smoke scale: Ideal > NUMA > TL-OoO > TL-LF ≫ PCIe.
#[test]
fn fig7_ordering_holds() {
    let wl = WorkloadKind::Cg;
    let ideal = run(&SystemConfig::ideal(), wl, 8_000);
    let numa = run(&SystemConfig::numa(), wl, 8_000);
    let ooo = run(&SystemConfig::tl_ooo(), wl, 8_000);
    let lf = run(&SystemConfig::tl_lf(), wl, 8_000);
    let pcie = run(&SystemConfig::pcie(0.25), wl, 8_000);
    let p = |r: &SimReport| r.perf_vs(&ideal);
    assert!(p(&numa) < 1.0, "numa {}", p(&numa));
    assert!(p(&ooo) < p(&numa) * 1.2, "tl-ooo {} vs numa {}", p(&ooo), p(&numa));
    assert!(p(&lf) < p(&ooo), "tl-lf {} vs tl-ooo {}", p(&lf), p(&ooo));
    assert!(
        p(&pcie) < p(&lf) / 5.0,
        "pcie should be orders of magnitude worse: {} vs {}",
        p(&pcie),
        p(&lf)
    );
}

/// Figure-8 effect: TL-OoO retires more instructions but holds IPC.
#[test]
fn fig8_instruction_expansion_with_ipc_retention() {
    let ideal = run(&SystemConfig::ideal(), WorkloadKind::Gups, 10_000);
    let ooo = run(&SystemConfig::tl_ooo(), WorkloadKind::Gups, 10_000);
    let expansion = ooo.retired_insts as f64 / ideal.retired_insts as f64;
    assert!(expansion > 1.4, "expansion {expansion}");
    // IPC must not fall proportionally to the instruction increase —
    // the extra work hides in stall slots.
    assert!(
        ooo.ipc() > ideal.ipc() * 0.7,
        "IPC collapsed: ideal {} tl {}",
        ideal.ipc(),
        ooo.ipc()
    );
}

/// Figure-15 shape: at +0 ns the increased-tRL system beats TL, but its
/// performance "degrades faster than for TL because high tRL values
/// limit memory concurrency" (§7.2) — TL's cost is flat in the extra
/// latency while inc-tRL's grows. (The absolute crossover point is
/// configuration-sensitive; the full-scale bench shows it near +135 ns.)
#[test]
fn fig15_inc_trl_degrades_faster_than_tl() {
    // Paper §7.2 methodology: trace-driven, no TLB effects.
    let no_tlb = |mut c: SystemConfig| {
        c.tlb_entries = 1 << 20;
        c
    };
    let wl = WorkloadKind::Gups;
    let tl = run(&no_tlb(SystemConfig::tl_ooo()), wl, 8_000);
    let trl0 = run(&no_tlb(SystemConfig::increased_trl(0)), wl, 8_000);
    let trl135 = run(&no_tlb(SystemConfig::increased_trl(135 * NS)), wl, 8_000);
    assert!(
        trl0.finish < tl.finish,
        "at +0ns single loads must win: {} vs {}",
        trl0.finish,
        tl.finish
    );
    // TL is flat in the tolerated latency; inc-tRL pays for it.
    let degradation = trl135.finish as f64 / trl0.finish as f64;
    assert!(degradation > 1.5, "inc-tRL did not degrade: {degradation}");
    // And the gap to TL must shrink by at least that factor.
    let gap0 = tl.finish as f64 / trl0.finish as f64;
    let gap135 = tl.finish as f64 / trl135.finish as f64;
    assert!(
        gap135 < gap0 / 1.5,
        "gap did not close: {gap0:.2} -> {gap135:.2}"
    );
}

/// The MEC tolerance wall: tolerable topology serves nearly all second
/// loads in time; an intolerable one does not (real-content mode).
#[test]
fn mec_tolerance_wall() {
    let mut ok = SystemConfig::tl_ooo();
    ok.emulate_content = false;
    ok.mec.topology = Topology { layers: 2, fanout: 2, hop_delay: 3_400 };
    let mut deep = ok.clone();
    deep.mec.topology = Topology { layers: 8, fanout: 2, hop_delay: 3_400 };

    let good = run(&ok, WorkloadKind::Gups, 6_000);
    let bad = run(&deep, WorkloadKind::Gups, 6_000);
    let frac = |r: &SimReport| {
        r.mec_second_real as f64 / (r.mec_second_real + r.mec_second_late).max(1) as f64
    };
    assert!(frac(&good) > 0.95, "tolerable topo late: {}", frac(&good));
    assert!(frac(&bad) < 0.6, "deep topo should miss the window: {}", frac(&bad));
    assert!(bad.twin_retries > good.twin_retries * 2 + 10);
    assert!(bad.finish > good.finish, "retries must cost time");
}

/// Batched TL-LF (§6.1 future work) recovers concurrency over plain TL-LF.
#[test]
fn batched_lf_beats_plain_lf() {
    let wl = WorkloadKind::Cg;
    let lf = run(&SystemConfig::tl_lf(), wl, 8_000);
    let batched = run(&SystemConfig::tl_lf_batched(8), wl, 8_000);
    assert!(
        batched.finish < lf.finish,
        "batching did not help: {} vs {}",
        batched.finish,
        lf.finish
    );
    assert!(batched.fences < lf.fences / 4);
    assert!(batched.mlp_mean > lf.mlp_mean);
}

/// SCM-leaf extension (§8 outlook): slower leaves still work under
/// TL-LF; TL-OoO sees late second loads (real-content mode).
#[test]
fn scm_leaves_extension() {
    use twinload::dram::timing::TimingParams;
    let mut scm = SystemConfig::tl_ooo();
    scm.emulate_content = false;
    scm.mec.leaf_timing = TimingParams::scm_leaf();
    let dram = {
        let mut c = SystemConfig::tl_ooo();
        c.emulate_content = false;
        c
    };
    let r_dram = run(&dram, WorkloadKind::ScalParC, 6_000);
    let r_scm = run(&scm, WorkloadKind::ScalParC, 6_000);
    assert!(r_scm.mec_second_late > r_dram.mec_second_late);
    assert!(r_scm.finish >= r_dram.finish);
}

/// Table-2/Table-5 generators stay paper-faithful (cheap, so run here too).
#[test]
fn static_tables_are_paper_faithful() {
    let t2 = exp::table2().to_csv();
    assert!(t2.lines().nth(4).unwrap().contains("v', v'"), "state 4 must double-fake");
    let t5 = exp::table5().render();
    assert!(t5.contains("3963") || t5.contains("3962") || t5.contains("3964"));
}

/// Both calendar event engines (fixed-width and adaptive) and the
/// conservative-parallel sharded engine are observationally identical
/// to the reference heap: every mechanism must produce an identical
/// SimReport under all four engines (engine-diagnostic counters
/// excluded — resize, overflow, width, resample, and parallel-pump
/// counts are implementation-specific by construction).
#[test]
fn event_engines_equivalent_across_all_mechanisms() {
    use twinload::sim::EngineKind;
    let systems = [
        SystemConfig::ideal(),
        SystemConfig::tl_ooo(),
        SystemConfig::tl_lf(),
        SystemConfig::tl_lf_batched(8),
        SystemConfig::numa(),
        SystemConfig::pcie(0.5),
        SystemConfig::increased_trl(35 * NS),
        SystemConfig::amu(),
        SystemConfig::mims(),
    ];
    for base in systems {
        let mut heap = base.clone();
        heap.engine = EngineKind::ReferenceHeap;
        let b = run(&heap, WorkloadKind::Gups, 4_000);
        assert_eq!(b.engine, "reference-heap");
        for kind in [EngineKind::Calendar, EngineKind::AdaptiveCalendar, EngineKind::Sharded] {
            let mut cal = base.clone();
            cal.engine = kind;
            let a = run(&cal, WorkloadKind::Gups, 4_000);
            let tag = a.engine;
            let core = |r: &SimReport| {
                (
                    r.finish,
                    r.retired_insts,
                    r.retired_ops,
                    r.loads,
                    r.stores,
                    r.fences,
                    r.twin_retries,
                )
            };
            let memory = |r: &SimReport| {
                (r.llc_hits, r.llc_misses, r.tlb_misses, r.dram_reads, r.dram_writes, r.mlp_peak)
            };
            let mech = |r: &SimReport| {
                (
                    r.mec_first_loads,
                    r.mec_second_real,
                    r.mec_second_late,
                    r.pcie_faults,
                    r.cas_fails,
                )
            };
            assert_eq!(core(&a), core(&b), "{}/{tag}: core stats diverged", a.mechanism);
            assert_eq!(memory(&a), memory(&b), "{}/{tag}: memory stats diverged", a.mechanism);
            assert_eq!(mech(&a), mech(&b), "{}/{tag}: mechanism stats diverged", a.mechanism);
            assert_eq!(
                a.row_hit_rate.to_bits(),
                b.row_hit_rate.to_bits(),
                "{}/{tag}: row-hit rate diverged",
                a.mechanism
            );
            assert_eq!(
                a.mlp_mean.to_bits(),
                b.mlp_mean.to_bits(),
                "{}/{tag}: MLP diverged",
                a.mechanism
            );
            // Every event pushed under one engine is pushed under the
            // others.
            assert_eq!(
                a.engine_events, b.engine_events,
                "{}/{tag}: event count diverged",
                a.mechanism
            );
            assert_eq!(a.engine_peak, b.engine_peak, "{}/{tag}: occupancy diverged", a.mechanism);
            assert_eq!(a.engine, kind.name());
        }
    }
}

/// The scheduler policies are observationally identical end to end:
/// bank-granular invalidation (default), rank-granular, and the
/// reference scan must produce the same SimReport on a full platform.
#[test]
fn sched_policies_equivalent_end_to_end() {
    use twinload::dram::SchedPolicy;
    for base in [SystemConfig::tl_ooo(), SystemConfig::ideal()] {
        let mut reference = base.clone();
        reference.sched = SchedPolicy::ReferenceScan;
        let b = run(&reference, WorkloadKind::Gups, 4_000);
        for policy in [SchedPolicy::BankIndexed, SchedPolicy::RankInval] {
            let mut cfg = base.clone();
            cfg.sched = policy;
            let a = run(&cfg, WorkloadKind::Gups, 4_000);
            assert_eq!(
                (a.finish, a.retired_insts, a.llc_misses, a.dram_reads, a.dram_writes),
                (b.finish, b.retired_insts, b.llc_misses, b.dram_reads, b.dram_writes),
                "{}/{}: diverged from reference scan",
                a.mechanism,
                policy.name()
            );
            assert_eq!(
                a.row_hit_rate.to_bits(),
                b.row_hit_rate.to_bits(),
                "{}/{}: row-hit rate diverged",
                a.mechanism,
                policy.name()
            );
        }
    }
}

/// The slab front end is observationally identical to the retained
/// map-based reference on the full platform: every mechanism must
/// produce an identical SimReport under both front ends — core stats,
/// memory hierarchy, DRAM service (the slab's tagged transaction ids
/// preserve the controller's (arrive, id) tie-break order), mechanism
/// extras, and even event-engine pushes.
#[test]
fn frontends_equivalent_across_all_mechanisms() {
    use twinload::cpu::FrontEnd;
    let systems = [
        SystemConfig::ideal(),
        SystemConfig::tl_ooo(),
        SystemConfig::tl_lf(),
        SystemConfig::tl_lf_batched(8),
        SystemConfig::numa(),
        SystemConfig::pcie(0.5),
        SystemConfig::increased_trl(35 * NS),
        SystemConfig::amu(),
        SystemConfig::mims(),
    ];
    for base in systems {
        let mut reference = base.clone();
        reference.frontend = FrontEnd::Reference;
        let b = run(&reference, WorkloadKind::Gups, 4_000);
        let mut slab = base.clone();
        slab.frontend = FrontEnd::Slab;
        let a = run(&slab, WorkloadKind::Gups, 4_000);
        let core = |r: &SimReport| {
            (
                r.finish,
                r.retired_insts,
                r.retired_ops,
                r.loads,
                r.stores,
                r.fences,
                r.twin_retries,
                r.safe_paths,
                r.cas_fails,
            )
        };
        let memory = |r: &SimReport| {
            (
                r.llc_hits,
                r.llc_misses,
                r.tlb_misses,
                r.dram_reads,
                r.dram_writes,
                r.dram_read_bytes,
                r.dram_write_bytes,
                r.mlp_peak,
            )
        };
        let mech = |r: &SimReport| {
            (
                r.mec_first_loads,
                r.mec_second_real,
                r.mec_second_late,
                r.pcie_faults,
                r.lvc_evictions,
            )
        };
        assert_eq!(core(&a), core(&b), "{}: core stats diverged", a.mechanism);
        assert_eq!(memory(&a), memory(&b), "{}: memory stats diverged", a.mechanism);
        assert_eq!(mech(&a), mech(&b), "{}: mechanism stats diverged", a.mechanism);
        assert_eq!(
            a.row_hit_rate.to_bits(),
            b.row_hit_rate.to_bits(),
            "{}: row-hit rate diverged",
            a.mechanism
        );
        assert_eq!(
            a.mlp_mean.to_bits(),
            b.mlp_mean.to_bits(),
            "{}: MLP diverged",
            a.mechanism
        );
        assert_eq!(a.engine_events, b.engine_events, "{}: event count diverged", a.mechanism);
        assert_eq!(a.engine_peak, b.engine_peak, "{}: occupancy diverged", a.mechanism);
    }
}

/// Behavior-preservation proof for the backend refactor: for every
/// mechanism (the seven pre-existing ones plus the new AMU), the typed
/// backend routing must produce a `SimReport` bit-identical to the
/// retained pre-refactor (legacy `Option`-field) routing — core stats,
/// memory hierarchy, DRAM service, bus counters, mechanism extras, and
/// event-engine pushes. This is the PR 2–4 style full-platform equality
/// suite applied to the routing seam itself.
#[test]
fn backend_routing_equivalent_across_all_mechanisms() {
    use twinload::sim::Routing;
    let systems = [
        SystemConfig::ideal(),
        SystemConfig::tl_ooo(),
        SystemConfig::tl_lf(),
        SystemConfig::tl_lf_batched(8),
        SystemConfig::numa(),
        SystemConfig::pcie(0.5),
        SystemConfig::increased_trl(35 * NS),
        SystemConfig::amu(),
        SystemConfig::mims(),
    ];
    for base in systems {
        let mut legacy = base.clone();
        legacy.routing = Routing::Legacy;
        let b = run(&legacy, WorkloadKind::Gups, 4_000);
        let mut backend = base.clone();
        backend.routing = Routing::Backend;
        let a = run(&backend, WorkloadKind::Gups, 4_000);
        let core = |r: &SimReport| {
            (
                r.finish,
                r.retired_insts,
                r.retired_ops,
                r.loads,
                r.stores,
                r.fences,
                r.twin_retries,
                r.safe_paths,
                r.cas_fails,
            )
        };
        let memory = |r: &SimReport| {
            (
                r.llc_hits,
                r.llc_misses,
                r.tlb_misses,
                r.dram_reads,
                r.dram_writes,
                r.dram_read_bytes,
                r.dram_write_bytes,
                r.dram_cmds,
                r.mlp_peak,
            )
        };
        let mech = |r: &SimReport| {
            (
                r.mec_first_loads,
                r.mec_second_real,
                r.mec_second_late,
                r.pcie_faults,
                r.lvc_evictions,
                r.amu_requests,
                r.amu_queue_stalls,
                r.amu_occ_peak,
                r.mims_requests,
                r.mims_messages,
                r.mims_delivered_bytes,
                r.mims_requested_bytes,
            )
        };
        assert_eq!(core(&a), core(&b), "{}: core stats diverged", a.mechanism);
        assert_eq!(memory(&a), memory(&b), "{}: memory stats diverged", a.mechanism);
        assert_eq!(mech(&a), mech(&b), "{}: mechanism stats diverged", a.mechanism);
        assert_eq!(
            a.row_hit_rate.to_bits(),
            b.row_hit_rate.to_bits(),
            "{}: row-hit rate diverged",
            a.mechanism
        );
        assert_eq!(
            a.data_bus_util.to_bits(),
            b.data_bus_util.to_bits(),
            "{}: bus utilization diverged",
            a.mechanism
        );
        assert_eq!(
            a.mlp_mean.to_bits(),
            b.mlp_mean.to_bits(),
            "{}: MLP diverged",
            a.mechanism
        );
        assert_eq!(a.engine_events, b.engine_events, "{}: event count diverged", a.mechanism);
        assert_eq!(a.engine_peak, b.engine_peak, "{}: occupancy diverged", a.mechanism);
    }
}

/// The AMU column lands where the mechanism's physics say it should at
/// smoke scale: slower than Ideal (it pays request/notify latency and
/// issue/poll instructions) but far faster than PCIe page swapping, and
/// its bounded queue never exceeds its configured depth.
#[test]
fn amu_orders_between_ideal_and_pcie() {
    let wl = WorkloadKind::Gups;
    let ideal = run(&SystemConfig::ideal(), wl, 6_000);
    let amu = run(&SystemConfig::amu(), wl, 6_000);
    let pcie = run(&SystemConfig::pcie(0.25), wl, 6_000);
    assert!(amu.finish > ideal.finish, "AMU cannot beat ideal");
    assert!(
        pcie.finish > amu.finish * 2,
        "page swapping should be far slower than the async unit: {} vs {}",
        pcie.finish,
        amu.finish
    );
    assert!(amu.amu_requests > 0);
    assert!(amu.amu_occ_peak <= SystemConfig::amu().amu_depth as u64);
}

/// The MIMS column lands where the mechanism's physics say it should at
/// smoke scale: packing amortizes the fence, so the packed message
/// interface finishes GUPS no slower than fence-per-access TL-LF while
/// moving the same bytes — which is exactly a bus-utilization win — and
/// its message accounting is self-consistent.
#[test]
fn mims_packs_messages_and_does_not_lose_to_tl_lf() {
    let wl = WorkloadKind::Gups;
    let lf = run(&SystemConfig::tl_lf(), wl, 6_000);
    let mims = run(&SystemConfig::mims(), wl, 6_000);
    assert!(
        mims.finish <= lf.finish,
        "packed messages cannot lose to fence-per-access TL-LF: {} vs {}",
        mims.finish,
        lf.finish
    );
    assert!(mims.mims_requests > 0);
    assert!(mims.mims_messages > 0);
    assert!(mims.mims_messages <= mims.mims_requests);
    assert!(
        mims.mims_pack_mean > 1.0,
        "stores must not flush the batch on GUPS (pack mean {})",
        mims.mims_pack_mean
    );
    assert!(mims.mims_delivered_bytes <= mims.mims_requested_bytes);
    // Fence amortization is the mechanism of the win.
    assert!(mims.transform.fences < lf.transform.fences);
}

/// Determinism across the parallel runner with mixed job kinds.
#[test]
fn parallel_repro_is_deterministic() {
    use twinload::coordinator::run_parallel;
    let jobs: Vec<(SystemConfig, RunSpec)> = [WorkloadKind::Gups, WorkloadKind::Bfs]
        .into_iter()
        .flat_map(|wl| {
            [SystemConfig::ideal(), SystemConfig::tl_ooo()].into_iter().map(move |mut c| {
                c.cores = 2;
                let mut s = RunSpec::smoke(wl);
                s.ops_per_core = 3_000;
                (c, s)
            })
        })
        .collect();
    let a = run_parallel(&jobs, 4);
    let b = run_parallel(&jobs, 1);
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.finish, y.finish);
        assert_eq!(x.llc_misses, y.llc_misses);
    }
}

/// Failure injection: a pathologically small LVC (M=1) evicts in-flight
/// prefetches; software retries keep the program correct at a time cost
/// (the paper's M > 10 sizing argument, inverted).
#[test]
fn tiny_lvc_forces_retries_but_stays_correct() {
    let mut tiny = SystemConfig::tl_ooo();
    tiny.emulate_content = false;
    tiny.mec.lvc_entries = 1;
    let mut sized = tiny.clone();
    sized.mec.lvc_entries = 32;
    let bad = run(&tiny, WorkloadKind::Cg, 6_000);
    let good = run(&sized, WorkloadKind::Cg, 6_000);
    assert!(bad.lvc_evictions > good.lvc_evictions * 2);
    assert!(bad.twin_retries > good.twin_retries);
    assert!(bad.finish >= good.finish);
    // Same program, same retired work despite the retries.
    assert_eq!(bad.loads, good.loads);
}

/// SMARTS physics check: on a long gups run the sampled simulation must
/// (a) execute at most 10 % of ops in detailed (warmup + measured)
/// mode, (b) retire exactly the same work as the full run, and (c)
/// estimate a mean ns/op consistent with the fully-detailed run. The
/// consistency band is the window-pool CI plus a 15 % systematic
/// allowance: the CLT interval covers window-to-window sampling noise,
/// not the residual warmup bias of smoke-scale windows (64-op warmups
/// cannot perfectly refill queue/MLP state after a fast-forward).
#[test]
fn sampled_gups_measures_a_small_detailed_fraction_faithfully() {
    let cfg = SystemConfig::tl_ooo();
    let mut full_spec = RunSpec::smoke(WorkloadKind::Gups);
    full_spec.ops_per_core = 40_000;
    // 9% nominal detailed fraction: 120 warmup + 60 measured per 2000.
    let sampled_spec = full_spec.sampled(2_000, 120, 60);

    let mut sys = cfg.clone();
    sys.cores = 2;
    let full = run_spec(&sys, &full_spec);
    let sampled = run_spec(&sys, &sampled_spec);
    assert!(!full.deadlocked && !sampled.deadlocked);

    // (b) Sampling changes timing, never work: every op still retires.
    assert_eq!(sampled.retired_ops, full.retired_ops);
    assert_eq!(sampled.loads, full.loads);
    assert_eq!(sampled.stores, full.stores);

    // (a) ≤ 10% of ops ran detailed.
    assert!(
        sampled.sample_detailed_ops * 10 <= sampled.retired_ops,
        "detailed fraction too high: {} of {} ops",
        sampled.sample_detailed_ops,
        sampled.retired_ops
    );
    // Enough windows for the CI to mean anything (~19 per core).
    assert!(
        sampled.sample_windows >= 20,
        "too few measurement windows: {}",
        sampled.sample_windows
    );

    // (c) The estimator tracks the full run's per-core ns/op.
    let full_ns_per_op = full.runtime_ns() / full_spec.ops_per_core as f64;
    let err = (sampled.sample_ns_per_op_mean - full_ns_per_op).abs();
    let band = sampled.sample_ci_ns_per_op + 0.15 * full_ns_per_op;
    assert!(
        err <= band,
        "sampled mean {:.2} ns/op missed full-run {:.2} ns/op (ci {:.2}, band {:.2})",
        sampled.sample_ns_per_op_mean,
        full_ns_per_op,
        sampled.sample_ci_ns_per_op,
        band
    );
    // The interval itself is well-formed: positive width from a
    // non-constant window pool, finite IPC estimate alongside it.
    assert!(sampled.sample_ci_ns_per_op >= 0.0);
    assert!(sampled.sample_ipc_mean > 0.0 && sampled.sample_ipc_mean.is_finite());
}

/// The sharded engine must actually engage its worker pool under load
/// (on a multi-core host): a memory-bound run with deep queues on two
/// local channels has pump instants with enough queued transactions to
/// cross the parallel-dispatch floor. Equivalence tests prove sharded
/// output is right; this proves the parallel path is the thing being
/// tested and not silently dormant.
#[test]
fn sharded_engine_engages_the_worker_pool_under_load() {
    use twinload::sim::EngineKind;
    if std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1) < 2 {
        return; // single-CPU host: the plan is 1 and sharded runs serial
    }
    let mut cfg = SystemConfig::ideal();
    cfg.cores = 4;
    cfg.engine = EngineKind::Sharded;
    let mut spec = RunSpec::smoke(WorkloadKind::Gups);
    spec.ops_per_core = 5_000;
    let r = run_spec(&cfg, &spec);
    assert!(!r.deadlocked);
    assert_eq!(r.engine, "sharded");
    assert!(
        r.engine_parallel_pumps > 0,
        "worker pool never dispatched a parallel pump batch"
    );
}

/// Failure injection: SCM leaves blow the TL-OoO timing window (retries)
/// while TL-LF absorbs them — the §8 heterogeneous-memory story.
#[test]
fn scm_leaf_hurts_ooo_not_lf() {
    use twinload::dram::timing::TimingParams;
    let mk = |mech: &str, scm: bool| {
        let mut c = SystemConfig::by_name(mech).unwrap();
        c.emulate_content = false;
        if scm {
            c.mec.leaf_timing = TimingParams::scm_leaf();
        }
        run(&c, WorkloadKind::Cg, 5_000)
    };
    let ooo_scm = mk("tl-ooo", true);
    let ooo_dram = mk("tl-ooo", false);
    let lf_scm = mk("tl-lf", true);
    assert!(ooo_scm.twin_retries > ooo_dram.twin_retries * 3);
    // TL-LF's fence gives the slow leaf all the time it needs.
    let lf_real = lf_scm.mec_second_real as f64
        / (lf_scm.mec_second_real + lf_scm.mec_second_late).max(1) as f64;
    assert!(lf_real > 0.95, "TL-LF late under SCM: {lf_real}");
}
