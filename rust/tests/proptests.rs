//! Property-based tests over the coordinator's core invariants (routing,
//! batching, state machines), via the in-house `testing` harness — the
//! proptest-equivalent coverage DESIGN.md's toolchain-substitution note
//! commits to.

use twinload::cache::{CacheConfig, DataKind, SetAssocCache};
use twinload::config::geometry_for;
use twinload::dram::address::{AddressMapping, DecodedAddr};
use twinload::dram::timing::{Geometry, TimingParams};
use twinload::dram::{MemController, SchedPolicy, Transaction};
use twinload::mec::LoadValueCache;
use twinload::memmgr::{Allocator, MemLayout, Space};
use twinload::testing::{check, PropConfig};
use twinload::twinload::{LogicalOp, Mechanism, Transform};
use twinload::cpu::trace::{MicroOp, OpSource};

fn cfg() -> PropConfig {
    PropConfig::default()
}

#[test]
fn prop_address_mapping_roundtrips() {
    check("address-roundtrip", cfg(), |rng| {
        // Random pow2 geometry.
        let geo = Geometry {
            ranks: 1 << rng.below(2),
            banks_per_rank: 1 << (2 + rng.below(2)),
            rows_per_bank: 1 << (6 + rng.below(8)),
            cols_per_row: 1 << (5 + rng.below(3)),
        };
        let channels = 1 << rng.below(3);
        let m = AddressMapping::new(&geo, channels);
        for _ in 0..64 {
            let addr = rng.below(m.capacity() / 64) * 64;
            let d = m.decode(addr);
            if m.encode(&d) != addr {
                return Err(format!("roundtrip failed: {addr:#x} -> {d:?}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_twin_is_same_bank_other_row_involution() {
    check("twin-property", cfg(), |rng| {
        let geo = geometry_for(1 << (24 + rng.below(4)));
        let m = AddressMapping::new(&geo, 1);
        for _ in 0..64 {
            let addr = rng.below(m.capacity() / 64) * 64;
            let t = m.twin(addr);
            if m.twin(t) != addr {
                return Err("twin not an involution".into());
            }
            let (a, b) = (m.decode(addr), m.decode(t));
            if a.bank != b.bank || a.rank != b.rank || a.col != b.col {
                return Err(format!("twin moved off-bank: {a:?} vs {b:?}"));
            }
            if a.row == b.row {
                return Err("twin did not change the row".into());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_controller_conserves_and_orders_transactions() {
    check("controller-conservation", cfg(), |rng| {
        let geo = Geometry::sim_small();
        let p = TimingParams::ddr3_1600();
        let mut ctrl = MemController::new(p, geo);
        let n = 1 + rng.below(48);
        let mut ids: Vec<u64> = Vec::new();
        for i in 0..n {
            let addr = DecodedAddr {
                channel: 0,
                rank: rng.below(2) as u32,
                bank: rng.below(8) as u32,
                row: rng.below(512) as u32,
                col: rng.below(128) as u32,
            };
            let is_write = rng.chance(0.3);
            if !is_write {
                ids.push(i);
            }
            ctrl.enqueue(Transaction { id: i, addr, is_write, arrive: rng.below(2000) });
        }
        // Pump to quiescence; every read must be serviced exactly once,
        // with data strictly after its column command.
        let mut now = 0;
        let mut seen = Vec::new();
        let mut results = Vec::new();
        for _ in 0..10_000 {
            results.clear();
            let wake = ctrl.pump(now, &mut results);
            for r in &results {
                if !r.is_write {
                    seen.push(r.id);
                }
                if r.data_end <= r.col_cmd_at {
                    return Err("data before column command".into());
                }
                if !r.is_write && r.data_start != r.col_cmd_at + p.t_rl {
                    return Err(format!(
                        "synchronous tRL violated: rd@{} data@{}",
                        r.col_cmd_at, r.data_start
                    ));
                }
            }
            match wake {
                Some(w) => now = w,
                None => break,
            }
        }
        seen.sort_unstable();
        ids.sort_unstable();
        if seen != ids {
            return Err(format!("lost/duplicated reads: {} vs {}", seen.len(), ids.len()));
        }
        Ok(())
    });
}

#[test]
fn prop_bank_indexed_scheduler_matches_reference_scan() {
    // Differential oracle: the bank-indexed FR-FCFS scheduler must be
    // bit-identical to the retained full-queue reference scan — same
    // service order, same timestamps, same wake times, same stats —
    // under mixed reads/writes, deliberate bank collisions, and idle
    // gaps long enough to span refresh.
    check("sched-equivalence", cfg(), |rng| {
        let geo = Geometry::sim_small();
        let p = TimingParams::ddr3_1600();
        let mut fast = MemController::new(p, geo);
        let mut slow = MemController::with_policy(p, geo, SchedPolicy::ReferenceScan);

        // Some cases are write-heavy with dense arrivals so the write
        // queue crosses WQ_HIGH while reads are still queued, exercising
        // the high-watermark drain trigger (not just the reads-empty one).
        let write_frac = if rng.chance(0.25) { 0.85 } else { 0.3 };
        let n = if write_frac > 0.5 { 48 + rng.below(16) } else { 8 + rng.below(56) };
        let mut t = 0u64;
        let mut txns = Vec::new();
        for i in 0..n {
            t += if rng.chance(0.05) {
                p.t_refi * (1 + rng.below(3))
            } else {
                rng.below(100)
            };
            // Small bank/row spaces force same-bank conflicts and hits.
            let bank = if rng.chance(0.5) { rng.below(2) } else { rng.below(8) };
            let addr = DecodedAddr {
                channel: 0,
                rank: rng.below(2) as u32,
                bank: bank as u32,
                row: rng.below(16) as u32,
                col: rng.below(128) as u32,
            };
            txns.push(Transaction { id: i, addr, is_write: rng.chance(write_frac), arrive: t });
        }

        let mut now = 0u64;
        let mut next = 0usize;
        let mut rf = Vec::new();
        let mut rs = Vec::new();
        for _ in 0..100_000 {
            while next < txns.len() && txns[next].arrive <= now {
                fast.enqueue(txns[next]);
                slow.enqueue(txns[next]);
                next += 1;
            }
            rf.clear();
            rs.clear();
            let wf = fast.pump(now, &mut rf);
            let ws = slow.pump(now, &mut rs);
            if wf != ws {
                return Err(format!("wake diverged at {now}: {wf:?} vs {ws:?}"));
            }
            if rf.len() != rs.len() {
                return Err(format!(
                    "result count diverged at {now}: {} vs {}",
                    rf.len(),
                    rs.len()
                ));
            }
            for (a, b) in rf.iter().zip(rs.iter()) {
                let ka = (a.id, a.col_cmd_at, a.data_start, a.data_end, a.row_hit);
                let kb = (b.id, b.col_cmd_at, b.data_start, b.data_end, b.row_hit);
                if ka != kb {
                    return Err(format!("service diverged at {now}: {ka:?} vs {kb:?}"));
                }
            }
            let horizon = match (wf, next < txns.len()) {
                (Some(w), true) => w.min(txns[next].arrive),
                (Some(w), false) => w,
                (None, true) => txns[next].arrive,
                (None, false) => break,
            };
            now = horizon.max(now + 1);
        }
        if next < txns.len() || fast.queue_len() != 0 || slow.queue_len() != 0 {
            return Err("streams did not quiesce".into());
        }
        if fast.stats.row_hits != slow.stats.row_hits
            || fast.stats.row_misses != slow.stats.row_misses
            || fast.stats.row_conflicts != slow.stats.row_conflicts
        {
            return Err("stats diverged".into());
        }
        Ok(())
    });
}

#[test]
fn prop_calendar_engine_matches_reference_heap() {
    // Differential oracle for the simulator's event queue: the calendar
    // (bucket) engine must pop the exact same stream — timestamps,
    // payloads, and same-tick tie-breaks — as the retained binary-heap
    // engine, under clustered short-horizon pushes, same-tick ties,
    // far-future refresh-scale events, occasional pushes behind the
    // drain point, and interleaved push/pop.
    use twinload::sim::engine::{EngineKind, Ev, EventQueue};
    check("engine-equivalence", cfg(), |rng| {
        // Vary the bucket width across cases: 1 ps (degenerate), odd,
        // the DDR3 tick, and coarse enough that many distinct
        // timestamps share a bucket.
        let tick = [1u64, 617, 1_250, 20_000][rng.below(4) as usize];
        let mut cal = EventQueue::with_kind(EngineKind::Calendar, tick);
        let mut heap = EventQueue::with_kind(EngineKind::ReferenceHeap, tick);
        let mut now: u64 = 0;
        let ops = 200 + rng.below(600);
        for _ in 0..ops {
            if rng.chance(0.55) || cal.is_empty() {
                for _ in 0..1 + rng.below(8) {
                    let t = if rng.chance(0.05) {
                        // Far-future refresh-style event (overflow path).
                        now + 7_800_000 + rng.below(1_000_000)
                    } else if rng.chance(0.1) {
                        // Behind the drain point (cursor regression).
                        now.saturating_sub(rng.below(50_000))
                    } else if rng.chance(0.35) {
                        // Same-tick ties.
                        now + rng.below(3)
                    } else {
                        // Clustered short horizon.
                        now + rng.below(30_000)
                    };
                    let ev = match rng.below(3) {
                        0 => Ev::CoreWake { core: rng.below(8) as usize },
                        1 => Ev::Pump { group: rng.below(4) as usize },
                        _ => Ev::Deliver {
                            core: rng.below(8) as usize,
                            line: rng.below(1 << 20) * 64,
                            data: DataKind::Real,
                        },
                    };
                    cal.push(t, ev);
                    heap.push(t, ev);
                }
            } else {
                let (a, b) = (cal.pop(), heap.pop());
                if a != b {
                    return Err(format!("pop diverged: {a:?} vs {b:?}"));
                }
                if let Some(e) = a {
                    now = now.max(e.t);
                }
            }
            if cal.len() != heap.len() {
                return Err(format!("len diverged: {} vs {}", cal.len(), heap.len()));
            }
        }
        // Drain both to empty; the full residual streams must agree.
        loop {
            let (a, b) = (cal.pop(), heap.pop());
            if a != b {
                return Err(format!("drain diverged: {a:?} vs {b:?}"));
            }
            if a.is_none() {
                break;
            }
        }
        if !cal.is_empty() || !heap.is_empty() {
            return Err("queues did not drain".into());
        }
        Ok(())
    });
}

#[test]
fn prop_cache_accounting_is_consistent() {
    check("cache-accounting", cfg(), |rng| {
        let mut c = SetAssocCache::new(CacheConfig {
            size_bytes: 1 << (12 + rng.below(4)),
            ways: 1 << (1 + rng.below(3)),
            line_bytes: 64,
        });
        let span = 1 << (14 + rng.below(6));
        let n = 2_000;
        let mut resident = std::collections::HashSet::new();
        for _ in 0..n {
            let a = rng.below(span / 64) * 64;
            match c.access(a, rng.chance(0.3)) {
                twinload::cache::LookupResult::Hit(_) => {
                    if !resident.contains(&a) {
                        return Err(format!("hit on non-resident line {a:#x}"));
                    }
                }
                twinload::cache::LookupResult::Miss => {
                    if let Some(ev) = c.fill(a, false, DataKind::Real) {
                        if !resident.remove(&ev.addr) {
                            return Err("evicted a line that was never resident".into());
                        }
                    }
                    resident.insert(a);
                }
            }
        }
        if c.hits + c.misses != n {
            return Err("hits + misses != accesses".into());
        }
        Ok(())
    });
}

#[test]
fn prop_lvc_occupancy_bounded() {
    check("lvc-occupancy", cfg(), |rng| {
        let cap = 1 + rng.below(32) as usize;
        let mut lvc = LoadValueCache::new(cap);
        for _ in 0..500 {
            let tag = rng.below(64);
            match lvc.lookup(tag) {
                twinload::mec::lvc::LvcLookup::Miss => lvc.allocate(tag, rng.below(1000)),
                twinload::mec::lvc::LvcLookup::Hit { .. } => {
                    if rng.chance(0.7) {
                        lvc.release(tag);
                    }
                }
            }
            if lvc.occupancy() > cap {
                return Err(format!("occupancy {} > capacity {cap}", lvc.occupancy()));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_transform_twins_are_well_formed() {
    check("transform-twins", cfg(), |rng| {
        let layout = MemLayout::new(1 << 22, 1 << 22);
        let n = 50 + rng.below(100);
        let mut ops = Vec::new();
        for _ in 0..n {
            let ext = rng.chance(0.7);
            let base = if ext { layout.ext_base() } else { 0 };
            let addr = base + rng.below(1 << 20) * 64;
            if rng.chance(0.2) {
                ops.push(LogicalOp::store(addr));
            } else {
                ops.push(LogicalOp::load(addr));
            }
        }
        let mut t = Transform::new(ops.into_iter(), Mechanism::TlOoO, layout);
        let mut pair_addr: std::collections::HashMap<u64, Vec<u64>> = Default::default();
        while let Some(op) = t.next_op() {
            if let MicroOp::Mem(m) = op {
                if let Some(p) = m.pair {
                    pair_addr.entry(p).or_default().push(m.vaddr);
                }
            }
        }
        for (p, addrs) in &pair_addr {
            if addrs.len() != 2 {
                return Err(format!("pair {p} has {} members", addrs.len()));
            }
            let (a, b) = (addrs[0].min(addrs[1]), addrs[0].max(addrs[1]));
            if b - a != layout.ext_size {
                return Err(format!("pair {p} not twins: {a:#x}/{b:#x}"));
            }
            if !layout.is_extended(a) || !layout.is_shadow(b) {
                return Err("pair members in wrong spaces".into());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_allocator_regions_disjoint() {
    check("allocator-disjoint", cfg(), |rng| {
        let layout = MemLayout::new(1 << 24, 1 << 25);
        let mut alloc = Allocator::new(layout, 1 << 20);
        let mut regions: Vec<twinload::memmgr::Region> = Vec::new();
        for _ in 0..rng.below(40) {
            let space = if rng.chance(0.5) { Space::Local } else { Space::Extended };
            let bytes = (1 + rng.below(4)) << 20;
            if rng.chance(0.2) {
                if let Some(r) = regions.pop() {
                    alloc.free(r);
                    continue;
                }
            }
            if let Some(r) = alloc.alloc(space, bytes) {
                for other in &regions {
                    let overlap = r.base < other.base + other.len
                        && other.base < r.base + r.len;
                    if overlap {
                        return Err(format!("overlap: {r:?} vs {other:?}"));
                    }
                }
                regions.push(r);
            }
        }
        Ok(())
    });
}
