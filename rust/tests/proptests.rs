//! Property-based tests over the coordinator's core invariants (routing,
//! batching, state machines), via the in-house `testing` harness — the
//! proptest-equivalent coverage DESIGN.md's toolchain-substitution note
//! commits to.

use twinload::cache::{CacheConfig, DataKind, SetAssocCache};
use twinload::config::geometry_for;
use twinload::dram::address::{AddressMapping, DecodedAddr};
use twinload::dram::timing::{Geometry, TimingParams};
use twinload::dram::{MemController, SchedPolicy, Transaction};
use twinload::mec::LoadValueCache;
use twinload::memmgr::{Allocator, MemLayout, Space};
use twinload::testing::{check, PropConfig};
use twinload::twinload::{LogicalOp, Mechanism, Transform};
use twinload::cpu::trace::{MicroOp, OpSource};

fn cfg() -> PropConfig {
    PropConfig::default()
}

#[test]
fn prop_address_mapping_roundtrips() {
    check("address-roundtrip", cfg(), |rng| {
        // Random pow2 geometry.
        let geo = Geometry {
            ranks: 1 << rng.below(2),
            banks_per_rank: 1 << (2 + rng.below(2)),
            rows_per_bank: 1 << (6 + rng.below(8)),
            cols_per_row: 1 << (5 + rng.below(3)),
        };
        let channels = 1 << rng.below(3);
        let m = AddressMapping::new(&geo, channels);
        for _ in 0..64 {
            let addr = rng.below(m.capacity() / 64) * 64;
            let d = m.decode(addr);
            if m.encode(&d) != addr {
                return Err(format!("roundtrip failed: {addr:#x} -> {d:?}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_twin_is_same_bank_other_row_involution() {
    check("twin-property", cfg(), |rng| {
        let geo = geometry_for(1 << (24 + rng.below(4)));
        let m = AddressMapping::new(&geo, 1);
        for _ in 0..64 {
            let addr = rng.below(m.capacity() / 64) * 64;
            let t = m.twin(addr);
            if m.twin(t) != addr {
                return Err("twin not an involution".into());
            }
            let (a, b) = (m.decode(addr), m.decode(t));
            if a.bank != b.bank || a.rank != b.rank || a.col != b.col {
                return Err(format!("twin moved off-bank: {a:?} vs {b:?}"));
            }
            if a.row == b.row {
                return Err("twin did not change the row".into());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_controller_conserves_and_orders_transactions() {
    check("controller-conservation", cfg(), |rng| {
        let geo = Geometry::sim_small();
        let p = TimingParams::ddr3_1600();
        let mut ctrl = MemController::new(p, geo);
        let n = 1 + rng.below(48);
        let mut ids: Vec<u64> = Vec::new();
        for i in 0..n {
            let addr = DecodedAddr {
                channel: 0,
                rank: rng.below(2) as u32,
                bank: rng.below(8) as u32,
                row: rng.below(512) as u32,
                col: rng.below(128) as u32,
            };
            let is_write = rng.chance(0.3);
            if !is_write {
                ids.push(i);
            }
            ctrl.enqueue(Transaction { id: i, addr, is_write, arrive: rng.below(2000) });
        }
        // Pump to quiescence; every read must be serviced exactly once,
        // with data strictly after its column command.
        let mut now = 0;
        let mut seen = Vec::new();
        let mut results = Vec::new();
        for _ in 0..10_000 {
            results.clear();
            let wake = ctrl.pump(now, &mut results);
            for r in &results {
                if !r.is_write {
                    seen.push(r.id);
                }
                if r.data_end <= r.col_cmd_at {
                    return Err("data before column command".into());
                }
                if !r.is_write && r.data_start != r.col_cmd_at + p.t_rl {
                    return Err(format!(
                        "synchronous tRL violated: rd@{} data@{}",
                        r.col_cmd_at, r.data_start
                    ));
                }
            }
            match wake {
                Some(w) => now = w,
                None => break,
            }
        }
        seen.sort_unstable();
        ids.sort_unstable();
        if seen != ids {
            return Err(format!("lost/duplicated reads: {} vs {}", seen.len(), ids.len()));
        }
        Ok(())
    });
}

#[test]
fn prop_bank_indexed_scheduler_matches_reference_scan() {
    // Differential oracle: the bank-indexed FR-FCFS scheduler must be
    // bit-identical to the retained full-queue reference scan — same
    // service order, same timestamps, same wake times, same stats —
    // under mixed reads/writes, deliberate bank collisions, and idle
    // gaps long enough to span refresh.
    check("sched-equivalence", cfg(), |rng| {
        let geo = Geometry::sim_small();
        let p = TimingParams::ddr3_1600();
        let mut fast = MemController::new(p, geo);
        let mut slow = MemController::with_policy(p, geo, SchedPolicy::ReferenceScan);

        // Some cases are write-heavy with dense arrivals so the write
        // queue crosses WQ_HIGH while reads are still queued, exercising
        // the high-watermark drain trigger (not just the reads-empty one).
        let write_frac = if rng.chance(0.25) { 0.85 } else { 0.3 };
        let n = if write_frac > 0.5 { 48 + rng.below(16) } else { 8 + rng.below(56) };
        let mut t = 0u64;
        let mut txns = Vec::new();
        for i in 0..n {
            t += if rng.chance(0.05) {
                p.t_refi * (1 + rng.below(3))
            } else {
                rng.below(100)
            };
            // Small bank/row spaces force same-bank conflicts and hits.
            let bank = if rng.chance(0.5) { rng.below(2) } else { rng.below(8) };
            let addr = DecodedAddr {
                channel: 0,
                rank: rng.below(2) as u32,
                bank: bank as u32,
                row: rng.below(16) as u32,
                col: rng.below(128) as u32,
            };
            txns.push(Transaction { id: i, addr, is_write: rng.chance(write_frac), arrive: t });
        }

        let mut now = 0u64;
        let mut next = 0usize;
        let mut rf = Vec::new();
        let mut rs = Vec::new();
        for _ in 0..100_000 {
            while next < txns.len() && txns[next].arrive <= now {
                fast.enqueue(txns[next]);
                slow.enqueue(txns[next]);
                next += 1;
            }
            rf.clear();
            rs.clear();
            let wf = fast.pump(now, &mut rf);
            let ws = slow.pump(now, &mut rs);
            if wf != ws {
                return Err(format!("wake diverged at {now}: {wf:?} vs {ws:?}"));
            }
            if rf.len() != rs.len() {
                return Err(format!(
                    "result count diverged at {now}: {} vs {}",
                    rf.len(),
                    rs.len()
                ));
            }
            for (a, b) in rf.iter().zip(rs.iter()) {
                let ka = (a.id, a.col_cmd_at, a.data_start, a.data_end, a.row_hit);
                let kb = (b.id, b.col_cmd_at, b.data_start, b.data_end, b.row_hit);
                if ka != kb {
                    return Err(format!("service diverged at {now}: {ka:?} vs {kb:?}"));
                }
            }
            let horizon = match (wf, next < txns.len()) {
                (Some(w), true) => w.min(txns[next].arrive),
                (Some(w), false) => w,
                (None, true) => txns[next].arrive,
                (None, false) => break,
            };
            now = horizon.max(now + 1);
        }
        if next < txns.len() || fast.queue_len() != 0 || slow.queue_len() != 0 {
            return Err("streams did not quiesce".into());
        }
        if fast.stats.row_hits != slow.stats.row_hits
            || fast.stats.row_misses != slow.stats.row_misses
            || fast.stats.row_conflicts != slow.stats.row_conflicts
        {
            return Err("stats diverged".into());
        }
        Ok(())
    });
}

#[test]
fn prop_calendar_engines_match_reference_heap() {
    // Differential oracle for the simulator's event queue: both calendar
    // engines (fixed-width and adaptive) must pop the exact same stream —
    // timestamps, payloads, and same-tick tie-breaks — as the retained
    // binary-heap engine, under *drifting event density*: dense
    // watermark-tripping floods (which open the adaptive engine's
    // sampling windows), sparse phases with microsecond gaps, same-tick
    // ties, far-future refresh-scale events, pushes behind the drain
    // point, and interleaved push/pop runs long enough to complete
    // sampling windows mid-stream.
    use std::cell::Cell;
    use twinload::sim::engine::{EngineKind, Ev, EventQueue};
    let resamples_seen = Cell::new(0u64);
    check("engine-equivalence", cfg(), |rng| {
        // Vary the seed bucket width across cases: 1 ps (degenerate),
        // odd, the DDR3 tick, and coarse enough that many distinct
        // timestamps share a bucket.
        let tick = [1u64, 617, 1_250, 20_000][rng.below(4) as usize];
        let mut cals = [
            EventQueue::with_kind(EngineKind::Calendar, tick),
            EventQueue::with_kind(EngineKind::AdaptiveCalendar, tick),
        ];
        let mut heap = EventQueue::with_kind(EngineKind::ReferenceHeap, tick);
        let mut now: u64 = 0;
        // Mean inter-event gap of the current density regime; drifts
        // over the run (the adaptive engine's reason to exist).
        let mut gap: u64 = 30_000;
        fn push_all(cals: &mut [EventQueue; 2], heap: &mut EventQueue, t: u64, ev: Ev) {
            for c in cals.iter_mut() {
                c.push(t, ev);
            }
            heap.push(t, ev);
        }
        let ops = 60 + rng.below(120);
        for _ in 0..ops {
            match rng.below(10) {
                0 => {
                    // Density drift: jump regimes by orders of magnitude.
                    gap = [2, 500, 30_000, 2_500_000][rng.below(4) as usize];
                }
                1 => {
                    // Flood: enough in-flight events to trip the grow
                    // watermark (> 2 * 256 buckets) and open a sampling
                    // window on the adaptive engine.
                    let n = 600 + rng.below(300);
                    for _ in 0..n {
                        now += rng.below(gap.min(200) + 1);
                        let ev = Ev::CoreWake { core: rng.below(8) as usize };
                        push_all(&mut cals, &mut heap, now, ev);
                    }
                }
                2..=5 if !heap.is_empty() => {
                    // Pop run (long enough to complete sampling windows).
                    let n = 1 + rng.below(64);
                    for _ in 0..n {
                        let b = heap.pop();
                        for c in cals.iter_mut() {
                            let a = c.pop();
                            if a != b {
                                return Err(format!(
                                    "{:?} pop diverged: {a:?} vs {b:?}",
                                    c.kind()
                                ));
                            }
                        }
                        match b {
                            Some(e) => now = now.max(e.t),
                            None => break,
                        }
                    }
                }
                _ => {
                    // A few pushes in the current regime.
                    for _ in 0..1 + rng.below(8) {
                        let t = if rng.chance(0.05) {
                            // Far-future refresh-style event (overflow).
                            now + 7_800_000 + rng.below(1_000_000)
                        } else if rng.chance(0.1) {
                            // Behind the drain point (cursor regression).
                            now.saturating_sub(rng.below(50_000))
                        } else if rng.chance(0.3) {
                            // Same-tick ties.
                            now + rng.below(3)
                        } else {
                            now + rng.below(2 * gap + 1)
                        };
                        let ev = match rng.below(3) {
                            0 => Ev::CoreWake { core: rng.below(8) as usize },
                            1 => Ev::Pump { group: rng.below(4) as usize },
                            _ => Ev::Deliver {
                                core: rng.below(8) as usize,
                                line: rng.below(1 << 20) * 64,
                                data: DataKind::Real,
                            },
                        };
                        push_all(&mut cals, &mut heap, t, ev);
                    }
                }
            }
            for c in &cals {
                if c.len() != heap.len() {
                    return Err(format!(
                        "{:?} len diverged: {} vs {}",
                        c.kind(),
                        c.len(),
                        heap.len()
                    ));
                }
            }
        }
        // Drain all to empty; the full residual streams must agree.
        loop {
            let b = heap.pop();
            for c in cals.iter_mut() {
                let a = c.pop();
                if a != b {
                    return Err(format!("{:?} drain diverged: {a:?} vs {b:?}", c.kind()));
                }
            }
            if b.is_none() {
                break;
            }
        }
        for c in &cals {
            if !c.is_empty() {
                return Err(format!("{:?} did not drain", c.kind()));
            }
        }
        resamples_seen.set(resamples_seen.get() + cals[1].stats().resamples);
        Ok(())
    });
    // The generator must actually reach the adaptive resampling path
    // (floods + drift + long pop runs), or the equivalence proof above
    // is vacuous for the rebucketing code. A single short case may
    // legitimately never complete a resample, so skip the vacuity check
    // when the case count is overridden downward for a failure repro
    // (TWINLOAD_PROP_CASES=1).
    if cfg().cases >= 16 {
        assert!(
            resamples_seen.get() > 0,
            "no case exercised the adaptive resample path"
        );
    }
}

#[test]
fn prop_cache_accounting_is_consistent() {
    check("cache-accounting", cfg(), |rng| {
        let mut c = SetAssocCache::new(CacheConfig {
            size_bytes: 1 << (12 + rng.below(4)),
            ways: 1 << (1 + rng.below(3)),
            line_bytes: 64,
        });
        let span = 1 << (14 + rng.below(6));
        let n = 2_000;
        let mut resident = std::collections::HashSet::new();
        for _ in 0..n {
            let a = rng.below(span / 64) * 64;
            match c.access(a, rng.chance(0.3)) {
                twinload::cache::LookupResult::Hit(_) => {
                    if !resident.contains(&a) {
                        return Err(format!("hit on non-resident line {a:#x}"));
                    }
                }
                twinload::cache::LookupResult::Miss => {
                    if let Some(ev) = c.fill(a, false, DataKind::Real) {
                        if !resident.remove(&ev.addr) {
                            return Err("evicted a line that was never resident".into());
                        }
                    }
                    resident.insert(a);
                }
            }
        }
        if c.hits + c.misses != n {
            return Err("hits + misses != accesses".into());
        }
        Ok(())
    });
}

#[test]
fn prop_lvc_occupancy_bounded() {
    check("lvc-occupancy", cfg(), |rng| {
        let cap = 1 + rng.below(32) as usize;
        let mut lvc = LoadValueCache::new(cap);
        for _ in 0..500 {
            let tag = rng.below(64);
            match lvc.lookup(tag) {
                twinload::mec::lvc::LvcLookup::Miss => lvc.allocate(tag, rng.below(1000)),
                twinload::mec::lvc::LvcLookup::Hit { .. } => {
                    if rng.chance(0.7) {
                        lvc.release(tag);
                    }
                }
            }
            if lvc.occupancy() > cap {
                return Err(format!("occupancy {} > capacity {cap}", lvc.occupancy()));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_transform_twins_are_well_formed() {
    check("transform-twins", cfg(), |rng| {
        let layout = MemLayout::new(1 << 22, 1 << 22);
        let n = 50 + rng.below(100);
        let mut ops = Vec::new();
        for _ in 0..n {
            let ext = rng.chance(0.7);
            let base = if ext { layout.ext_base() } else { 0 };
            let addr = base + rng.below(1 << 20) * 64;
            if rng.chance(0.2) {
                ops.push(LogicalOp::store(addr));
            } else {
                ops.push(LogicalOp::load(addr));
            }
        }
        let mut t = Transform::new(ops.into_iter(), Mechanism::TlOoO, layout);
        let mut pair_addr: std::collections::HashMap<u64, Vec<u64>> = Default::default();
        while let Some(op) = t.next_op() {
            if let MicroOp::Mem(m) = op {
                if let Some(p) = m.pair {
                    pair_addr.entry(p).or_default().push(m.vaddr);
                }
            }
        }
        for (p, addrs) in &pair_addr {
            if addrs.len() != 2 {
                return Err(format!("pair {p} has {} members", addrs.len()));
            }
            let (a, b) = (addrs[0].min(addrs[1]), addrs[0].max(addrs[1]));
            if b - a != layout.ext_size {
                return Err(format!("pair {p} not twins: {a:#x}/{b:#x}"));
            }
            if !layout.is_extended(a) || !layout.is_shadow(b) {
                return Err("pair members in wrong spaces".into());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_allocator_regions_disjoint() {
    check("allocator-disjoint", cfg(), |rng| {
        let layout = MemLayout::new(1 << 24, 1 << 25);
        let mut alloc = Allocator::new(layout, 1 << 20);
        let mut regions: Vec<twinload::memmgr::Region> = Vec::new();
        for _ in 0..rng.below(40) {
            let space = if rng.chance(0.5) { Space::Local } else { Space::Extended };
            let bytes = (1 + rng.below(4)) << 20;
            if rng.chance(0.2) {
                if let Some(r) = regions.pop() {
                    alloc.free(r);
                    continue;
                }
            }
            if let Some(r) = alloc.alloc(space, bytes) {
                for other in &regions {
                    let overlap = r.base < other.base + other.len
                        && other.base < r.base + r.len;
                    if overlap {
                        return Err(format!("overlap: {r:?} vs {other:?}"));
                    }
                }
                regions.push(r);
            }
        }
        Ok(())
    });
}

#[test]
fn prop_invalidation_granularities_three_way_equivalent() {
    // Three-way differential oracle for the controller's candidate-cache
    // invalidation: bank-granular (default) vs the retained rank-granular
    // stage vs the full-scan reference must produce bit-identical service
    // streams, wake times, and stats. The generator stresses exactly the
    // state the bank-granular narrowing reasons about: tFAW/tRRD window
    // shifts (bank sweeps of closed-bank ACTs across one rank),
    // read/write turnaround flips (write bursts between read runs), row
    // hits whose cached column-ready must move with tCCD, and
    // refresh-spanning idle gaps.
    check("sched-three-way", cfg(), |rng| {
        let geo = Geometry::sim_small();
        let p = TimingParams::ddr3_1600();
        let mut ctrls = [
            MemController::with_policy(p, geo, SchedPolicy::ReferenceScan),
            MemController::with_policy(p, geo, SchedPolicy::BankIndexed),
            MemController::with_policy(p, geo, SchedPolicy::RankInval),
        ];
        let mut txns = Vec::new();
        let mut t = 0u64;
        let mut id = 0u64;
        while txns.len() < 64 {
            if rng.chance(0.05) {
                // Refresh-spanning gap.
                t += p.t_refi * (1 + rng.below(2));
            }
            if rng.chance(0.4) {
                // Bank sweep: 5+ closed-bank ACT candidates on one rank
                // in a tight window — the 5th+ is tFAW-bound, and every
                // non-serviced bank's cached ACT-ready must move (or
                // provably not move) with the window.
                let rank = rng.below(2) as u32;
                let row = 1 + rng.below(8) as u32;
                let sweep = 5 + rng.below(4);
                for k in 0..sweep {
                    let addr = DecodedAddr {
                        channel: 0,
                        rank,
                        bank: ((k + rng.below(2)) % 8) as u32,
                        row,
                        col: rng.below(128) as u32,
                    };
                    txns.push(Transaction {
                        id,
                        addr,
                        is_write: false,
                        arrive: t + rng.below(40),
                    });
                    id += 1;
                }
                t += rng.below(200);
            } else if rng.chance(0.3) {
                // Write burst on a hot bank: WR→RD turnaround moves the
                // rank-wide column floors both directions.
                let rank = rng.below(2) as u32;
                let bank = rng.below(2) as u32;
                let burst = 2 + rng.below(4);
                for _ in 0..burst {
                    let addr = DecodedAddr {
                        channel: 0,
                        rank,
                        bank,
                        row: rng.below(4) as u32,
                        col: rng.below(128) as u32,
                    };
                    txns.push(Transaction {
                        id,
                        addr,
                        is_write: rng.chance(0.7),
                        arrive: t + rng.below(60),
                    });
                    id += 1;
                }
                t += rng.below(500);
            } else {
                // Background traffic: hits, misses, cross-rank.
                let addr = DecodedAddr {
                    channel: 0,
                    rank: rng.below(2) as u32,
                    bank: rng.below(8) as u32,
                    row: rng.below(16) as u32,
                    col: rng.below(128) as u32,
                };
                txns.push(Transaction {
                    id,
                    addr,
                    is_write: rng.chance(0.3),
                    arrive: t,
                });
                id += 1;
                t += rng.below(150);
            }
        }
        txns.sort_by_key(|x| (x.arrive, x.id));

        let mut now = 0u64;
        let mut next = 0usize;
        let mut bufs = [Vec::new(), Vec::new(), Vec::new()];
        for _ in 0..100_000 {
            while next < txns.len() && txns[next].arrive <= now {
                for c in ctrls.iter_mut() {
                    c.enqueue(txns[next]);
                }
                next += 1;
            }
            let mut wake = None;
            for (i, c) in ctrls.iter_mut().enumerate() {
                bufs[i].clear();
                let w = c.pump(now, &mut bufs[i]);
                if i == 0 {
                    wake = w;
                } else if w != wake {
                    return Err(format!(
                        "{} wake diverged at {now}: {w:?} vs {wake:?}",
                        c.policy().name()
                    ));
                }
            }
            for i in 1..3 {
                if bufs[i].len() != bufs[0].len() {
                    return Err(format!(
                        "{} count diverged at {now}: {} vs {}",
                        ctrls[i].policy().name(),
                        bufs[i].len(),
                        bufs[0].len()
                    ));
                }
                for (a, b) in bufs[i].iter().zip(bufs[0].iter()) {
                    let ka = (a.id, a.col_cmd_at, a.data_start, a.data_end, a.row_hit);
                    let kb = (b.id, b.col_cmd_at, b.data_start, b.data_end, b.row_hit);
                    if ka != kb {
                        return Err(format!(
                            "{} service diverged at {now}: {ka:?} vs {kb:?}",
                            ctrls[i].policy().name()
                        ));
                    }
                }
            }
            let horizon = match (wake, next < txns.len()) {
                (Some(w), true) => w.min(txns[next].arrive),
                (Some(w), false) => w,
                (None, true) => txns[next].arrive,
                (None, false) => break,
            };
            now = horizon.max(now + 1);
        }
        for c in &ctrls {
            if c.queue_len() != 0 {
                return Err(format!("{} did not quiesce", c.policy().name()));
            }
            if c.stats.row_hits != ctrls[0].stats.row_hits
                || c.stats.row_misses != ctrls[0].stats.row_misses
                || c.stats.row_conflicts != ctrls[0].stats.row_conflicts
            {
                return Err(format!("{} stats diverged", c.policy().name()));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_frontends_bit_identical_under_random_completion_orders() {
    // Differential oracle for the front end: the slab path (generational
    // request slab + intrusive waiter chains + ring-indexed pair/board
    // state) must drive the core to bit-identical statistics as the
    // retained map-based reference, under randomized twin-load micro-op
    // streams and randomized out-of-order completion: per-request latency
    // jitter reorders deliveries, shadow/extended lines return fake data
    // often enough to force twin retries and CAS-store failures, and
    // appended safe-path / invalidate ops cover the remaining access
    // kinds. MSHR pressure is randomized to exercise the stall path.
    use twinload::cache::DataKind;
    use twinload::cpu::{
        Core, CoreParams, FrontEnd, IssueResult, MemoryPort,
        trace::{AccessKind as AK, MemAccess, MicroOp},
    };
    use twinload::memmgr::MemLayout;
    use twinload::util::rng::mix64;
    use twinload::util::time::NS;

    /// Deterministic jittery memory: latency and content are pure
    /// functions of (line, per-line issue count), so two runs that issue
    /// identically observe identical timing and data — and any behavioral
    /// divergence between the cores desynchronizes the comparison.
    struct JitterMem {
        mshrs: usize,
        salt: u64,
        fake_bias: u64,
        layout: MemLayout,
        inflight: Vec<(u64, u64, u64)>, // (req_id, done_at, line)
        next_id: u64,
        seen: std::collections::HashMap<u64, u64>,
    }

    impl JitterMem {
        fn latency(&self, line: u64, nth: u64) -> u64 {
            20 * NS + mix64(line ^ nth.wrapping_mul(0x9E37) ^ self.salt) % (180 * NS)
        }

        fn content(&self, line: u64, nth: u64) -> DataKind {
            // Shadow lines are usually fake, extended lines occasionally
            // (interrupt-eviction emulation) — both-fake pairs and CAS
            // failures occur with realistic frequency.
            let h = mix64(line ^ nth.wrapping_mul(0xC2B2) ^ self.salt ^ 1);
            let fake = if self.layout.is_shadow(line) {
                h % 100 < 85
            } else {
                h % 100 < self.fake_bias
            };
            if fake { DataKind::Fake } else { DataKind::Real }
        }

        fn next_event(&self) -> Option<u64> {
            self.inflight.iter().map(|&(_, t, _)| t).min()
        }

        fn deliver(&mut self, now: u64, core: &mut Core) {
            let mut due: Vec<(u64, u64, u64)> = self
                .inflight
                .iter()
                .copied()
                .filter(|&(_, t, _)| t <= now)
                .collect();
            // Completion order randomized by the latency jitter; the
            // (t, id) sort only makes simultaneous completions stable.
            due.sort_by_key(|&(id, t, _)| (t, id));
            self.inflight.retain(|&(_, t, _)| t > now);
            for (id, t, line) in due {
                let nth = self.seen.get(&line).copied().unwrap_or(0);
                let data = self.content(line, nth);
                core.complete(id, t, data);
            }
        }
    }

    impl MemoryPort for JitterMem {
        fn issue(&mut self, now: u64, acc: &MemAccess) -> IssueResult {
            let line = acc.vaddr & !63;
            match acc.kind {
                AK::Invalidate => {
                    return IssueResult::Done { at: now + 1_000, data: DataKind::Real }
                }
                AK::SafePath => {
                    return IssueResult::Done { at: now + 500 * NS, data: DataKind::Real }
                }
                AK::Load | AK::Store => {}
            }
            if self.inflight.len() >= self.mshrs {
                return IssueResult::Stall { retry_at: now + 30 * NS };
            }
            let nth = {
                let e = self.seen.entry(line).or_insert(0);
                let n = *e;
                *e += 1;
                n
            };
            let id = self.next_id;
            self.next_id += 1;
            self.inflight.push((id, now + self.latency(line, nth), line));
            IssueResult::Pending { req_id: id }
        }
    }

    check("frontend-equivalence", cfg(), |rng| {
        let layout = MemLayout::new(1 << 22, 1 << 22);
        // Random logical stream lowered by a real twin-load transform so
        // pair/dep invariants hold by construction.
        let mech = [
            Mechanism::TlOoO,
            Mechanism::TlLf,
            Mechanism::TlLfBatched(2 + rng.below(7) as u32),
            Mechanism::Mims(1 + rng.below(8) as u32),
        ][rng.below(4) as usize];
        let n = 40 + rng.below(160);
        let mut logicals = Vec::new();
        let mut mem_count = 0u64;
        for _ in 0..n {
            if rng.chance(0.25) {
                logicals.push(LogicalOp::Compute(1 + rng.below(20) as u32));
                continue;
            }
            let ext = rng.chance(0.7);
            let base = if ext { layout.ext_base() } else { 0 };
            let addr = base + rng.below(1 << 10) * 64;
            let op = if rng.chance(0.25) {
                LogicalOp::store(addr)
            } else if mem_count > 0 && rng.chance(0.3) {
                LogicalOp::load_dep(addr, mem_count - 1)
            } else {
                LogicalOp::load(addr)
            };
            mem_count += 1;
            logicals.push(op);
        }
        let mut t = Transform::new(logicals.into_iter(), mech, layout);
        let mut ops: Vec<MicroOp> = Vec::new();
        while let Some(op) = t.next_op() {
            ops.push(op);
        }
        // Tail of safe-path and invalidate ops. Their logical indices
        // continue the transform's sequential numbering (real lowering
        // never jumps the index space, and the board ring relies on
        // that).
        for k in 0..rng.below(4) {
            let kind = if rng.chance(0.5) { AK::SafePath } else { AK::Invalidate };
            ops.push(MicroOp::Mem(MemAccess {
                vaddr: layout.ext_base() + k * 64,
                kind,
                logical: mem_count + k,
                dep_on: None,
                pair: None,
                retry: false,
            }));
        }

        let salt = rng.next_u64();
        let fake_bias = rng.below(30);
        let mshrs = 2 + rng.below(8) as usize;
        // Randomly arm the §4.5 demotion policy: the shadow-fake bias
        // makes consecutive both-fake streaks common, so low thresholds
        // exercise storm tracking and safe-path demotion on both front
        // ends (0 = disabled, the fault-free default).
        let mut params = CoreParams::xeon();
        params.demote_after = if rng.chance(0.5) { 1 + rng.below(4) as u32 } else { 0 };
        let mut outcomes = Vec::new();
        for fe in [FrontEnd::Reference, FrontEnd::Slab] {
            let mut core = Core::with_frontend(params, fe);
            let mut src = ops.clone().into_iter();
            let mut mem = JitterMem {
                mshrs,
                salt,
                fake_bias,
                layout,
                inflight: Vec::new(),
                next_id: 1,
                seen: Default::default(),
            };
            let mut now = 0u64;
            let mut steps = 0u64;
            loop {
                let wake = core.advance(now, &mut src, &mut mem);
                if core.finished() {
                    break;
                }
                let next = match (wake, mem.next_event()) {
                    (Some(a), Some(b)) => a.min(b),
                    (Some(a), None) => a,
                    (None, Some(b)) => b,
                    (None, None) => return Err(format!("{fe:?}: deadlocked")),
                };
                now = next;
                mem.deliver(now, &mut core);
                steps += 1;
                if steps > 2_000_000 {
                    return Err(format!("{fe:?}: did not converge"));
                }
            }
            let s = core.stats;
            outcomes.push((
                s.finish,
                s.retired_insts,
                s.retired_ops,
                s.loads,
                s.stores,
                s.fences,
                s.twin_retries,
                s.safe_paths,
                s.cas_fails,
                s.retry_storms,
                s.demotions,
            ));
        }
        if outcomes[0] != outcomes[1] {
            return Err(format!(
                "front ends diverged ({mech:?}): {:?} vs {:?}",
                outcomes[0], outcomes[1]
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_chaos_faults_complete_exactly_once_and_zero_rate_is_inert() {
    // Chaos differential for the fault-injection + recovery subsystem
    // (§4.4 retries, §4.5 safe-path demotion): under arbitrary fault
    // schedules — random mechanism × engine × front end × scheduler ×
    // routing × fault rate × demotion threshold — three invariants must
    // hold on the full platform:
    //
    //  1. Termination: the run never deadlocks, at any fault rate.
    //  2. Exactly-once: every logical op completes exactly once — the
    //     faulted run's retired ops / loads / stores / fences equal the
    //     fault-free run's. Faults cost *time* (retry/safe-path/ECC
    //     penalties, redeliveries), never *work* (no lost or duplicated
    //     completions).
    //  3. Schedule independence: the fault schedule is a pure function
    //     of (seed, line, occurrence), so every engine × front end
    //     combination produces a bit-identical faulted report — the
    //     faulted extension of the fault-free equivalence suites.
    //
    // Plus the inertness half of the bit-identity guarantee: zeroing
    // the rates while leaving every other fault knob armed (seed,
    // poll timeout, reissue bound, backoff) must reproduce the
    // untouched config's report bit-for-bit.
    use std::cell::Cell;
    use twinload::config::{RunSpec, SystemConfig};
    use twinload::cpu::FrontEnd;
    use twinload::dram::SchedPolicy;
    use twinload::sim::engine::EngineKind;
    use twinload::sim::{run_spec, Routing, SimReport};
    use twinload::workloads::arrival::ArrivalKind;
    use twinload::workloads::WorkloadKind;

    let injected_total = Cell::new(0u64);
    check("chaos-faults", cfg(), |rng| {
        // Every extension-path mechanism (ideal has no fault surface).
        let mech = ["tl-ooo", "tl-lf", "tl-lf-batched", "numa", "pcie", "inc-trl", "amu", "mims"]
            [rng.below(8) as usize];
        let mut base = SystemConfig::by_name(mech).expect("preset");
        base.cores = 2;
        base.sched = [SchedPolicy::BankIndexed, SchedPolicy::RankInval, SchedPolicy::ReferenceScan]
            [rng.below(3) as usize];
        base.routing = [Routing::Backend, Routing::Legacy][rng.below(2) as usize];
        base.engine = [EngineKind::Calendar, EngineKind::AdaptiveCalendar, EngineKind::ReferenceHeap]
            [rng.below(3) as usize];
        base.frontend = [FrontEnd::Slab, FrontEnd::Reference][rng.below(2) as usize];

        let wl = if rng.chance(0.25) { WorkloadKind::Cg } else { WorkloadKind::Gups };
        let mut spec = RunSpec::smoke(wl);
        spec.ops_per_core = 400 + rng.below(800);
        spec.seed = rng.next_u64();
        // Open-loop arm: faults × arrival pacing. Termination and
        // exactly-once must survive bounded-queue drops (drops never
        // consume the op budget, so retired work stays invariant).
        if rng.chance(0.3) {
            let kind = [ArrivalKind::Poisson, ArrivalKind::Mmpp][rng.below(2) as usize];
            spec = spec.open_loop(kind, (1 + rng.below(32)) * 1_000_000);
            spec.queue_depth = 2 + rng.below(62) as u32;
            spec.arrival_seed = rng.next_u64();
        }

        // Arbitrary fault schedule: rate in [0.01, 0.50], fresh seed,
        // aggressive demotion thresholds.
        let rate = (1 + rng.below(50)) as f64 / 100.0;
        let mut faulted = base.clone().faulted(rate);
        faulted.fault_seed = rng.next_u64();
        faulted.demote_after = 1 + rng.below(5) as u32;
        // Correlated-fault arm: ~half the cases arm the Gilbert-Elliott
        // burst layer on top of the per-draw rates (sometimes *instead*
        // of them), and half of those arm the online health detector +
        // quarantine. Exactly-once and cross-implementation bit-identity
        // must survive fail-slow windows, fail-stop windows, and
        // whole-domain safe-path demotion alike.
        if rng.chance(0.5) {
            faulted.burst_rate = (1 + rng.below(60)) as f64 / 100.0;
            faulted.burst_len = (500 + rng.below(4_500)) * 1_000; // 0.5–5 µs
            faulted.burst_slow_mult = 2 + rng.below(7);
            if rng.chance(0.3) {
                // Burst-only schedule: the per-draw rates off entirely.
                faulted.fault_rate = 0.0;
                faulted.fault_ecc_rate = 0.0;
            }
            if rng.chance(0.5) {
                faulted.quarantine_threshold = (2 + rng.below(7)) as f64 / 10.0;
                faulted.probe_ok = 1 + rng.below(16) as u32;
            }
        }

        let baseline = run_spec(&base, &spec);
        if baseline.deadlocked {
            return Err(format!("{mech}: fault-free baseline deadlocked"));
        }
        // Full-report fingerprint (u64-encoded so one Vec covers the
        // f64 fields bit-exactly).
        let fp = |r: &SimReport| {
            vec![
                r.finish,
                r.retired_insts,
                r.retired_ops,
                r.loads,
                r.stores,
                r.fences,
                r.twin_retries,
                r.safe_paths,
                r.cas_fails,
                r.retry_storms,
                r.demotions,
                r.faults_injected,
                r.ecc_corrected,
                r.mec_fill_drops,
                r.mec_fill_lates,
                r.recovery_p99,
                r.recovery_max,
                r.recovery_mean.to_bits(),
                r.llc_hits,
                r.llc_misses,
                r.dram_reads,
                r.dram_writes,
                r.pcie_faults,
                r.amu_requests,
                r.mims_requests,
                r.mims_messages,
                r.mims_delivered_bytes,
                r.mims_requested_bytes,
                r.engine_events,
                r.engine_peak,
                r.arrived_requests,
                r.served_requests,
                r.dropped_requests,
                r.queue_peak,
                r.req_p50_ns,
                r.req_p99_ns,
                r.req_p999_ns,
                r.req_mean_ns.to_bits(),
                r.queue_mean.to_bits(),
                r.ext_accesses,
                r.degraded_accesses,
                r.availability.to_bits(),
                r.quarantines,
                r.readmits,
                r.quarantined_served,
                r.mttd_ns.to_bits(),
                r.mttr_ns.to_bits(),
                r.degraded_ns.to_bits(),
            ]
        };

        let mut first: Option<Vec<u64>> = None;
        for engine in [EngineKind::Calendar, EngineKind::AdaptiveCalendar, EngineKind::ReferenceHeap]
        {
            for fe in [FrontEnd::Slab, FrontEnd::Reference] {
                let mut c = faulted.clone();
                c.engine = engine;
                c.frontend = fe;
                let r = run_spec(&c, &spec);
                if r.deadlocked {
                    return Err(format!(
                        "{mech} rate {rate}: deadlocked under faults ({engine:?}/{fe:?})"
                    ));
                }
                let work = |r: &SimReport| (r.retired_ops, r.loads, r.stores, r.fences);
                if work(&r) != work(&baseline) {
                    return Err(format!(
                        "{mech} rate {rate}: exactly-once violated ({engine:?}/{fe:?}): \
                         {:?} vs fault-free {:?}",
                        work(&r),
                        work(&baseline)
                    ));
                }
                injected_total.set(injected_total.get() + r.faults_injected + r.ecc_corrected);
                let f = fp(&r);
                match &first {
                    None => first = Some(f),
                    Some(f0) => {
                        if &f != f0 {
                            return Err(format!(
                                "{mech} rate {rate}: faulted report diverged across \
                                 implementations at {engine:?}/{fe:?}"
                            ));
                        }
                    }
                }
            }
        }

        // Inertness: rates back to zero (demotion disarmed with them)
        // with every other fault knob still set must be bit-identical
        // to the untouched config. The burst rate joins the zeroing; the
        // quarantine knobs deliberately stay armed — the health layer is
        // gated on the burst layer, so a zero burst rate must keep a
        // nonzero `quarantine_threshold` structurally inert too.
        let mut zeroed = faulted.clone();
        zeroed.fault_rate = 0.0;
        zeroed.fault_ecc_rate = 0.0;
        zeroed.burst_rate = 0.0;
        zeroed.demote_after = 0;
        let z = run_spec(&zeroed, &spec);
        if z.faults_injected != 0 || z.ecc_corrected != 0 || z.demotions != 0 {
            return Err(format!("{mech}: zero-rate run still injected faults"));
        }
        if z.ext_accesses != 0 || z.degraded_accesses != 0 || z.quarantines != 0 {
            return Err(format!("{mech}: zero-rate run still tracked fault domains"));
        }
        if fp(&z) != fp(&baseline) {
            return Err(format!(
                "{mech}: zero-rate run not bit-identical to the untouched config"
            ));
        }
        Ok(())
    });
    // The generator must actually inject faults (rates ≥ 1% on
    // extension-heavy workloads make this certain across cases), or the
    // exactly-once/equivalence proof above is vacuous.
    if cfg().cases >= 16 {
        assert!(injected_total.get() > 0, "no case injected a fault");
    }
}

#[test]
fn prop_config_ini_round_trips_and_rejects() {
    // The INI parser and `apply` had no property coverage: generate
    // random-but-valid [system]/[run] files (random key order, spacing,
    // comments, engine=/sched= values), assert every field round-trips
    // through parse+apply, then corrupt the file (unknown key, bogus
    // enum value, malformed line) and assert rejection.
    use twinload::config::parser::{apply, Ini};
    use twinload::config::{RunSpec, SystemConfig};
    use twinload::cpu::FrontEnd;
    use twinload::dram::SchedPolicy;
    use twinload::sim::engine::EngineKind;
    use twinload::workloads::ALL_WORKLOADS;
    check("config-roundtrip", cfg(), |rng| {
        let mech = [
            "ideal", "tl-ooo", "tl-lf", "tl-lf-batched", "numa", "pcie", "inc-trl", "amu", "mims",
        ][rng.below(9) as usize];
        let engine =
            ["calendar", "adaptive-calendar", "reference-heap", "sharded"][rng.below(4) as usize];
        let sched = ["bank-indexed", "rank-inval", "reference-scan"][rng.below(3) as usize];
        let frontend = ["slab", "reference"][rng.below(2) as usize];
        let routing = ["backend", "legacy"][rng.below(2) as usize];
        let cores = 1 + rng.below(8);
        let mshrs = 1 + rng.below(16);
        let amu_depth = 1 + rng.below(256);
        let amu_issue_ns = rng.below(100);
        let amu_notify_ns = rng.below(100);
        let amu_svc_ps = rng.below(10_000);
        let mims_pack = 1 + rng.below(32);
        let mims_frame_ns = rng.below(100);
        let mims_granule = 1 + rng.below(64);
        let wl = ALL_WORKLOADS[rng.below(ALL_WORKLOADS.len() as u64) as usize];
        let ops = 1 + rng.below(1_000_000);
        let seed = rng.below(1 << 40);
        let footprint_mb = 1 + rng.below(256);
        // Open-loop serving knobs.
        let arrival = ["closed", "poisson", "mmpp"][rng.below(3) as usize];
        let offered_rps = rng.below(100_000_000);
        let zipf_theta = rng.below(100) as f64 / 100.0;
        let arrival_seed = rng.below(1 << 40);
        let queue_depth = 1 + rng.below(4096);
        // SMARTS sampling knobs (kept valid: the window fits the period).
        let sample_period = 2 + rng.below(10_000);
        let sample_warmup = rng.below(sample_period / 2);
        let sample_detail = 1 + rng.below(sample_period - sample_warmup - 1);
        let sample_seed = rng.below(1 << 40);
        // Fault-injection knobs (reissue/backoff/poll kept valid for a
        // nonzero rate; validation rejects zeros there).
        let fault_rate = rng.below(100) as f64 / 100.0;
        let fault_ecc_rate = rng.below(100) as f64 / 800.0;
        let fault_seed = rng.below(1 << 40);
        let demote_after = rng.below(10);
        let fault_poll_ns = 1 + rng.below(1_000);
        let fault_reissue = 1 + rng.below(8);
        let fault_backoff = 1 + rng.below(4);
        // Correlated-fault / health-detector knobs (kept valid: nonzero
        // window and multiplier, probe_ok ≥ 1).
        let burst_rate = rng.below(100) as f64 / 100.0;
        let burst_len_ns = 1 + rng.below(10_000);
        let burst_slow_mult = 1 + rng.below(16);
        let quarantine_threshold = rng.below(100) as f64 / 100.0;
        let probe_ok = 1 + rng.below(32);
        let slo_p99_us = 1 + rng.below(10_000);

        // Random decoration: spacing around '=', optional comments.
        let kv = |k: &str, v: String, rng: &mut twinload::util::Rng| {
            let pad = ["", " ", "  "][rng.below(3) as usize];
            let comment = if rng.chance(0.3) { " # c" } else { "" };
            format!("{k}{pad}={pad}{v}{comment}\n")
        };
        let mut sys_keys = vec![
            kv("mechanism", mech.to_string(), rng),
            kv("engine", engine.to_string(), rng),
            kv("sched", sched.to_string(), rng),
            kv("frontend", frontend.to_string(), rng),
            kv("routing", routing.to_string(), rng),
            kv("cores", cores.to_string(), rng),
            kv("mshrs", mshrs.to_string(), rng),
            kv("amu_depth", amu_depth.to_string(), rng),
            kv("amu_issue_ns", amu_issue_ns.to_string(), rng),
            kv("amu_notify_ns", amu_notify_ns.to_string(), rng),
            kv("amu_svc_ps", amu_svc_ps.to_string(), rng),
            kv("mims_pack", mims_pack.to_string(), rng),
            kv("mims_frame_ns", mims_frame_ns.to_string(), rng),
            kv("mims_granule", mims_granule.to_string(), rng),
            kv("fault_rate", fault_rate.to_string(), rng),
            kv("fault_ecc_rate", fault_ecc_rate.to_string(), rng),
            kv("fault_seed", fault_seed.to_string(), rng),
            kv("demote_after", demote_after.to_string(), rng),
            kv("fault_poll_timeout_ns", fault_poll_ns.to_string(), rng),
            kv("fault_reissue_max", fault_reissue.to_string(), rng),
            kv("fault_backoff_mult", fault_backoff.to_string(), rng),
            kv("burst_rate", burst_rate.to_string(), rng),
            kv("burst_len_ns", burst_len_ns.to_string(), rng),
            kv("burst_slow_mult", burst_slow_mult.to_string(), rng),
            kv("quarantine_threshold", quarantine_threshold.to_string(), rng),
            kv("probe_ok", probe_ok.to_string(), rng),
            kv("slo_p99_us", slo_p99_us.to_string(), rng),
        ];
        rng.shuffle(&mut sys_keys);
        let mut run_keys = vec![
            kv("workload", wl.name().to_string(), rng),
            kv("ops", ops.to_string(), rng),
            kv("seed", seed.to_string(), rng),
            kv("footprint_mb", footprint_mb.to_string(), rng),
            kv("arrival", arrival.to_string(), rng),
            kv("offered_rps", offered_rps.to_string(), rng),
            kv("zipf_theta", zipf_theta.to_string(), rng),
            kv("arrival_seed", arrival_seed.to_string(), rng),
            kv("queue_depth", queue_depth.to_string(), rng),
            kv("sample_period", sample_period.to_string(), rng),
            kv("sample_warmup", sample_warmup.to_string(), rng),
            kv("sample_detail", sample_detail.to_string(), rng),
            kv("sample_seed", sample_seed.to_string(), rng),
        ];
        rng.shuffle(&mut run_keys);
        let mut text = String::from("# generated\n[system]\n");
        for k in &sys_keys {
            text.push_str(k);
            if rng.chance(0.2) {
                text.push('\n'); // blank lines between keys
            }
        }
        text.push_str("[run]\n");
        for k in &run_keys {
            text.push_str(k);
        }

        let ini = Ini::parse(&text).map_err(|e| format!("parse failed: {e}\n{text}"))?;
        let mut cfg = SystemConfig::ideal();
        let mut spec = RunSpec::smoke(*ALL_WORKLOADS.first().expect("workloads"));
        apply(&ini, &mut cfg, &mut spec).map_err(|e| format!("apply failed: {e}\n{text}"))?;

        if cfg.mechanism.name() != mech {
            return Err(format!("mechanism lost: {} vs {mech}", cfg.mechanism.name()));
        }
        if EngineKind::by_name(engine) != Some(cfg.engine) {
            return Err(format!("engine lost: {:?} vs {engine}", cfg.engine));
        }
        if SchedPolicy::by_name(sched) != Some(cfg.sched) {
            return Err(format!("sched lost: {:?} vs {sched}", cfg.sched));
        }
        if FrontEnd::by_name(frontend) != Some(cfg.frontend) {
            return Err(format!("frontend lost: {:?} vs {frontend}", cfg.frontend));
        }
        if twinload::sim::Routing::by_name(routing) != Some(cfg.routing) {
            return Err(format!("routing lost: {:?} vs {routing}", cfg.routing));
        }
        if cfg.cores as u64 != cores || cfg.mshrs_per_core as u64 != mshrs {
            return Err("numeric [system] key lost".into());
        }
        if cfg.amu_depth as u64 != amu_depth
            || cfg.amu_issue != amu_issue_ns * 1_000
            || cfg.amu_notify != amu_notify_ns * 1_000
            || cfg.amu_svc != amu_svc_ps
        {
            return Err("amu [system] key lost".into());
        }
        if cfg.mims_pack as u64 != mims_pack
            || cfg.mims_frame != mims_frame_ns * 1_000
            || cfg.mims_granule as u64 != mims_granule
        {
            return Err("mims [system] key lost".into());
        }
        let want_mims = twinload::twinload::Mechanism::Mims(mims_pack as u32);
        if mech == "mims" && cfg.mechanism != want_mims {
            return Err(format!(
                "mims_pack did not re-pack the mechanism payload: {:?}",
                cfg.mechanism
            ));
        }
        if cfg.fault_rate != fault_rate || cfg.fault_ecc_rate != fault_ecc_rate {
            return Err("fault rate [system] key lost".into());
        }
        if cfg.fault_seed != fault_seed
            || cfg.demote_after as u64 != demote_after
            || cfg.fault_poll_timeout != fault_poll_ns * 1_000
            || cfg.fault_reissue_max as u64 != fault_reissue
            || cfg.fault_backoff_mult as u64 != fault_backoff
        {
            return Err("fault knob [system] key lost".into());
        }
        if cfg.burst_rate.to_bits() != burst_rate.to_bits()
            || cfg.burst_len != burst_len_ns * 1_000
            || cfg.burst_slow_mult != burst_slow_mult
        {
            return Err("burst [system] key lost".into());
        }
        if cfg.quarantine_threshold.to_bits() != quarantine_threshold.to_bits()
            || cfg.probe_ok as u64 != probe_ok
            || cfg.slo_p99_us != slo_p99_us
        {
            return Err("health [system] key lost".into());
        }
        if spec.workload != wl
            || spec.ops_per_core != ops
            || spec.seed != seed
            || spec.footprint != footprint_mb << 20
        {
            return Err("numeric [run] key lost".into());
        }
        if spec.arrival.name() != arrival
            || spec.offered_rps != offered_rps
            || spec.zipf_theta.to_bits() != zipf_theta.to_bits()
            || spec.arrival_seed != arrival_seed
            || spec.queue_depth as u64 != queue_depth
        {
            return Err("serving [run] key lost".into());
        }
        if spec.sample_period != sample_period
            || spec.sample_warmup != sample_warmup
            || spec.sample_detail != sample_detail
            || spec.sample_seed != sample_seed
        {
            return Err("sampling [run] key lost".into());
        }

        // Corruptions must be rejected, not silently absorbed.
        let bad_key = format!("{text}unheard_of_key = 1\n");
        let bad_ini = Ini::parse(&bad_key).map_err(|e| format!("bad-key parse: {e}"))?;
        if apply(&bad_ini, &mut cfg, &mut spec).is_ok() {
            return Err("unknown [run] key accepted".into());
        }
        let bad_enum = ["engine", "sched", "frontend", "mechanism", "workload", "arrival"]
            [rng.below(6) as usize];
        let section =
            if matches!(bad_enum, "workload" | "arrival") { "[run]" } else { "[system]" };
        let bad_val = format!("{section}\n{bad_enum} = definitely-not-a-{bad_enum}\n");
        let bad_ini = Ini::parse(&bad_val).map_err(|e| format!("bad-enum parse: {e}"))?;
        if apply(&bad_ini, &mut cfg, &mut spec).is_ok() {
            return Err(format!("bogus {bad_enum} value accepted"));
        }
        // Malformed lines: glued onto the [run] section so an "empty
        // key" survives parsing only to be rejected by apply.
        let malformed =
            ["[unterminated\n", "key_without_value\n", "= v\n"][rng.below(3) as usize];
        let glued = format!("{text}{malformed}");
        match Ini::parse(&glued) {
            Err(_) => {}
            Ok(ini) => {
                if apply(&ini, &mut cfg, &mut spec).is_ok() {
                    return Err(format!("malformed line accepted: {malformed:?}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_mims_pack_one_is_bit_identical_to_tl_lf() {
    // At packing factor 1 every "message" carries a single twin-load
    // pair: the lowering degenerates to `lower_lf` (same micro-ops,
    // same pair-id arithmetic) and the framing model is defined to be
    // inert. The whole platform must therefore be indistinguishable
    // from the unpacked Mec path — bit-identical timing, memory-system
    // counters, and serving distributions across engines, front ends,
    // routings, and arrival modes. Only the mims_* bookkeeping counters
    // (which exist to *prove* packing elsewhere) may differ.
    use twinload::config::{RunSpec, SystemConfig};
    use twinload::cpu::FrontEnd;
    use twinload::sim::engine::EngineKind;
    use twinload::sim::{run_spec, Routing, SimReport};
    use twinload::workloads::arrival::ArrivalKind;
    use twinload::workloads::WorkloadKind;

    check("mims-pack1-differential", cfg(), |rng| {
        let wl = [WorkloadKind::Gups, WorkloadKind::Bfs, WorkloadKind::Memcached]
            [rng.below(3) as usize];
        let mut spec = RunSpec::smoke(wl);
        spec.ops_per_core = 400 + rng.below(800);
        spec.seed = rng.next_u64();
        if rng.chance(0.3) {
            let kind = [ArrivalKind::Poisson, ArrivalKind::Mmpp][rng.below(2) as usize];
            spec = spec.open_loop(kind, (1 + rng.below(32)) * 1_000_000);
            spec.arrival_seed = rng.next_u64();
        }

        let decorate = |mut c: SystemConfig, rng: &mut twinload::util::Rng| {
            c.cores = 1 + rng.below(3) as usize;
            let engines =
                [EngineKind::Calendar, EngineKind::AdaptiveCalendar, EngineKind::ReferenceHeap];
            c.engine = engines[rng.below(3) as usize];
            c.frontend = [FrontEnd::Slab, FrontEnd::Reference][rng.below(2) as usize];
            c.routing = [Routing::Backend, Routing::Legacy][rng.below(2) as usize];
            // An aggressive frame penalty must stay inert at pack 1.
            c.mims_frame = 1_000_000;
            c
        };
        let mut salt = rng.clone();
        let lf = decorate(SystemConfig::tl_lf(), rng);
        let mims = decorate(SystemConfig::mims_packed(1), &mut salt);

        let fp = |r: &SimReport| {
            vec![
                r.finish,
                r.retired_insts,
                r.retired_ops,
                r.loads,
                r.stores,
                r.fences,
                r.twin_retries,
                r.safe_paths,
                r.cas_fails,
                r.llc_hits,
                r.llc_misses,
                r.dram_reads,
                r.dram_writes,
                r.dram_cmds,
                r.data_bus_util.to_bits(),
                r.engine_events,
                r.engine_peak,
                r.arrived_requests,
                r.served_requests,
                r.dropped_requests,
                r.req_p50_ns,
                r.req_p99_ns,
                r.req_p999_ns,
                r.req_mean_ns.to_bits(),
            ]
        };
        let a = run_spec(&lf, &spec);
        let b = run_spec(&mims, &spec);
        if a.deadlocked || b.deadlocked {
            return Err("pack-1 differential run deadlocked".into());
        }
        if fp(&a) != fp(&b) {
            return Err(format!(
                "mims pack=1 diverged from tl-lf ({:?}/{:?}/{:?}): {:?} vs {:?}",
                lf.engine,
                lf.frontend,
                lf.routing,
                fp(&b),
                fp(&a)
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_sharded_engine_is_bit_identical_to_calendar() {
    // Tentpole differential for the conservative-parallel engine: under
    // arbitrary mechanism × scheduler × front end × routing × fault /
    // burst schedule × arrival mode × sampling cadence, `Sharded` must
    // produce a bit-identical `SimReport` to the serial `Calendar`
    // engine. The two-phase pump makes this true by construction (phase
    // 1 outputs are independent of worker interleaving, phase 2 always
    // applies in channel order under the `llc_lat + egress` lookahead);
    // this test is the proof obligation that construction argument is
    // actually implemented. Only `engine_parallel_pumps` (a host-
    // dependent diagnostic) may differ, so it is excluded from the
    // fingerprint.
    use std::cell::Cell;
    use twinload::config::{RunSpec, SystemConfig};
    use twinload::cpu::FrontEnd;
    use twinload::dram::SchedPolicy;
    use twinload::sim::engine::EngineKind;
    use twinload::sim::{run_spec, Routing, SimReport};
    use twinload::workloads::arrival::ArrivalKind;
    use twinload::workloads::WorkloadKind;

    let parallel_total = Cell::new(0u64);
    check("sharded-equivalence", cfg(), |rng| {
        let mech = [
            "ideal", "tl-ooo", "tl-lf", "tl-lf-batched", "numa", "pcie", "inc-trl", "amu", "mims",
        ][rng.below(9) as usize];
        let mut base = SystemConfig::by_name(mech).expect("preset");
        base.cores = 2 + rng.below(2) as usize;
        base.sched = [SchedPolicy::BankIndexed, SchedPolicy::RankInval, SchedPolicy::ReferenceScan]
            [rng.below(3) as usize];
        base.routing = [Routing::Backend, Routing::Legacy][rng.below(2) as usize];
        base.frontend = [FrontEnd::Slab, FrontEnd::Reference][rng.below(2) as usize];

        let wl = if rng.chance(0.25) { WorkloadKind::Cg } else { WorkloadKind::Gups };
        let mut spec = RunSpec::smoke(wl);
        spec.ops_per_core = 400 + rng.below(800);
        spec.seed = rng.next_u64();
        // Open-loop arm: shard parallelism × arrival pacing.
        if rng.chance(0.3) {
            let kind = [ArrivalKind::Poisson, ArrivalKind::Mmpp][rng.below(2) as usize];
            spec = spec.open_loop(kind, (1 + rng.below(32)) * 1_000_000);
            spec.queue_depth = 2 + rng.below(62) as u32;
            spec.arrival_seed = rng.next_u64();
        }
        // Sampled arm: the SMARTS cadence must be engine-independent
        // (the functional fast path touches no controller state).
        if rng.chance(0.3) {
            let period = 100 + rng.below(400);
            spec = spec.sampled(period, rng.below(50), 1 + rng.below(50));
            spec.sample_seed = rng.next_u64();
        }
        // Fault / burst arm: schedule draws happen in the serial apply
        // phase, so they must be identical under parallel pumping.
        if rng.chance(0.4) && mech != "ideal" {
            let rate = (1 + rng.below(30)) as f64 / 100.0;
            base = base.faulted(rate);
            base.fault_seed = rng.next_u64();
            base.demote_after = 1 + rng.below(5) as u32;
            if rng.chance(0.5) {
                base.burst_rate = (1 + rng.below(40)) as f64 / 100.0;
                base.burst_len = (500 + rng.below(4_500)) * 1_000;
                base.burst_slow_mult = 2 + rng.below(7);
            }
        }

        let fp = |r: &SimReport| {
            vec![
                r.finish,
                r.retired_insts,
                r.retired_ops,
                r.loads,
                r.stores,
                r.fences,
                r.twin_retries,
                r.safe_paths,
                r.cas_fails,
                r.retry_storms,
                r.demotions,
                r.faults_injected,
                r.ecc_corrected,
                r.mec_fill_drops,
                r.mec_fill_lates,
                r.recovery_p99,
                r.recovery_max,
                r.recovery_mean.to_bits(),
                r.llc_hits,
                r.llc_misses,
                r.dram_reads,
                r.dram_writes,
                r.dram_cmds,
                r.pcie_faults,
                r.amu_requests,
                r.mims_requests,
                r.mims_messages,
                r.mims_delivered_bytes,
                r.mims_requested_bytes,
                r.engine_events,
                r.engine_peak,
                r.arrived_requests,
                r.served_requests,
                r.dropped_requests,
                r.queue_peak,
                r.req_p50_ns,
                r.req_p99_ns,
                r.req_p999_ns,
                r.req_mean_ns.to_bits(),
                r.queue_mean.to_bits(),
                r.ext_accesses,
                r.degraded_accesses,
                r.availability.to_bits(),
                r.quarantines,
                r.readmits,
                r.quarantined_served,
                r.mttd_ns.to_bits(),
                r.mttr_ns.to_bits(),
                r.degraded_ns.to_bits(),
                r.sample_windows,
                r.sample_detailed_ops,
                r.sample_ns_per_op_mean.to_bits(),
                r.sample_ci_ns_per_op.to_bits(),
                r.sample_ipc_mean.to_bits(),
                r.sample_ci_ipc.to_bits(),
            ]
        };

        let mut serial_cfg = base.clone();
        serial_cfg.engine = EngineKind::Calendar;
        let mut sharded_cfg = base.clone();
        sharded_cfg.engine = EngineKind::Sharded;
        let a = run_spec(&serial_cfg, &spec);
        let b = run_spec(&sharded_cfg, &spec);
        if a.deadlocked || b.deadlocked {
            return Err(format!("{mech}: sharded differential run deadlocked"));
        }
        if b.engine != "sharded" {
            return Err(format!("engine name lost: {}", b.engine));
        }
        parallel_total.set(parallel_total.get() + b.engine_parallel_pumps);
        if fp(&a) != fp(&b) {
            return Err(format!(
                "sharded diverged from calendar ({mech}/{:?}/{:?}/{:?}): {:?} vs {:?}",
                base.sched,
                base.frontend,
                base.routing,
                fp(&b),
                fp(&a)
            ));
        }
        Ok(())
    });
    // Vacuity check: on a multi-core host the equivalence above must
    // have exercised the parallel pump path at least once, or the whole
    // proof collapses to serial-vs-serial.
    let host = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    if cfg().cases >= 16 && host >= 2 {
        assert!(parallel_total.get() > 0, "no case pumped channels in parallel");
    }
}
