//! Golden end-to-end regression corpus.
//!
//! Runs every mechanism over a small workload subset at smoke scale and
//! diffs a stable rendering of each [`SimReport`] against the checked-in
//! snapshot `rust/tests/golden.snap`. The pairwise differential tests
//! (`engine-equivalence`, `sched-equivalence`) prove *relative*
//! equivalence between implementations; this corpus freezes the
//! *absolute* end-to-end numbers, so a refactor that changes behaviour
//! identically in the optimized path and its retained reference (and
//! therefore slips past the pairwise oracles) still trips here.
//!
//! The simulation is deterministic (seeded PRNG, discrete time, no host
//! dependence) — the only theoretical machine-dependence is libm
//! (`powf` in the Zipf sampler), which is identical across the CI
//! runner class the snapshot is generated on.
//!
//! Maintenance:
//! * `make golden-update` (or `TWINLOAD_GOLDEN_UPDATE=1 cargo test
//!   --test golden`) regenerates the snapshot after an *intentional*
//!   behaviour change — commit the result.
//! * If the snapshot file is missing (fresh corpus), the test writes it
//!   and passes, so the corpus bootstraps on the first toolchain that
//!   runs it.

use twinload::config::{RunSpec, SystemConfig};
use twinload::sim::{run_spec, SimReport};
use twinload::workloads::arrival::ArrivalKind;
use twinload::workloads::WorkloadKind;

const SNAP_PATH: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/rust/tests/golden.snap");

/// Workload subset: one TLB-thrashing pointer chaser and one skewed
/// key-value mix — the two ends of the locality spectrum.
const WORKLOADS: &[WorkloadKind] = &[WorkloadKind::Gups, WorkloadKind::Memcached];

fn mechanisms() -> Vec<SystemConfig> {
    vec![
        SystemConfig::ideal(),
        SystemConfig::tl_ooo(),
        SystemConfig::tl_lf(),
        SystemConfig::tl_lf_batched(8),
        SystemConfig::numa(),
        SystemConfig::pcie(0.75),
        SystemConfig::increased_trl(35_000),
        SystemConfig::amu(),
        SystemConfig::mims(),
    ]
}

/// Stable one-line rendering of the fields a refactor must not move.
/// Engine-diagnostic counters (buckets, resizes, width…) are excluded by
/// design: they differ across engines while behaviour is identical.
fn render(r: &SimReport) -> String {
    format!(
        "{}/{} finish={} insts={} ops={} loads={} stores={} fences={} retries={} safe={} \
         cas={} llc_hits={} llc_miss={} tlb_miss={} tlb_acc={} dram_r={} dram_w={} \
         dram_rb={} dram_wb={} row_hit={:.6} mlp_mean={:.6} mlp_peak={} micro={} ext_ld={} \
         ext_st={} mec1={} mec2r={} mec2l={} lvc_ev={} pcie_faults={} events={} peak={} \
         cmds={} bus={:.6} amu_rq={} amu_stall={} amu_peak={} mims_msgs={} \
         mims_rq={} mims_db={} mims_qb={} faults={} storms={} \
         demoted={} ecc={} fdrops={} flates={} rec_p99={} arrived={} served={} \
         dropped={} qmean={:.6} qpeak={} p50={} p99={} p999={} ext_acc={} deg_acc={} \
         avail={:.6} quar={} readm={} qsrv={} mttd={:.3} mttr={:.3} degns={:.3} \
         swin={} sdet={} sns={:.6} snsci={:.6} sipc={:.6} sipcci={:.6}\n",
        r.mechanism,
        r.workload,
        r.finish,
        r.retired_insts,
        r.retired_ops,
        r.loads,
        r.stores,
        r.fences,
        r.twin_retries,
        r.safe_paths,
        r.cas_fails,
        r.llc_hits,
        r.llc_misses,
        r.tlb_misses,
        r.tlb_accesses,
        r.dram_reads,
        r.dram_writes,
        r.dram_read_bytes,
        r.dram_write_bytes,
        r.row_hit_rate,
        r.mlp_mean,
        r.mlp_peak,
        r.transform.micro_insts,
        r.transform.ext_loads,
        r.transform.ext_stores,
        r.mec_first_loads,
        r.mec_second_real,
        r.mec_second_late,
        r.lvc_evictions,
        r.pcie_faults,
        r.engine_events,
        r.engine_peak,
        r.dram_cmds,
        r.data_bus_util,
        r.amu_requests,
        r.amu_queue_stalls,
        r.amu_occ_peak,
        r.mims_messages,
        r.mims_requests,
        r.mims_delivered_bytes,
        r.mims_requested_bytes,
        r.faults_injected,
        r.retry_storms,
        r.demotions,
        r.ecc_corrected,
        r.mec_fill_drops,
        r.mec_fill_lates,
        r.recovery_p99,
        r.arrived_requests,
        r.served_requests,
        r.dropped_requests,
        r.queue_mean,
        r.queue_peak,
        r.req_p50_ns,
        r.req_p99_ns,
        r.req_p999_ns,
        r.ext_accesses,
        r.degraded_accesses,
        r.availability,
        r.quarantines,
        r.readmits,
        r.quarantined_served,
        r.mttd_ns,
        r.mttr_ns,
        r.degraded_ns,
        r.sample_windows,
        r.sample_detailed_ops,
        r.sample_ns_per_op_mean,
        r.sample_ci_ns_per_op,
        r.sample_ipc_mean,
        r.sample_ci_ipc,
    )
}

/// The correlated-burst variant used by the bursty corpus rows and the
/// implementation-independence sweeps: a hot burst layer plus an armed
/// quarantine, so the frozen lines exercise fail-slow stretching,
/// fail-stop weaving, EWMA detection, and half-open readmission at once.
fn bursty_quarantined(cfg: SystemConfig) -> SystemConfig {
    let mut cfg = cfg.bursty(0.25);
    cfg.quarantine_threshold = 0.5;
    cfg.probe_ok = 4;
    cfg
}

fn corpus() -> String {
    let mut out = String::new();
    for cfg in mechanisms() {
        for &wl in WORKLOADS {
            let mut cfg = cfg.clone();
            cfg.cores = 2;
            let mut spec = RunSpec::smoke(wl);
            spec.ops_per_core = 4_000;
            let r = run_spec(&cfg, &spec);
            assert!(!r.deadlocked, "{}/{} deadlocked", r.mechanism, r.workload);
            out.push_str(&render(&r));
        }
    }
    // One reference-front-end row: freezes the fact that the absolute
    // numbers are independent of the request-tracking implementation (a
    // slab bug that shifted behavior identically in both front ends
    // would still trip the mechanism rows above; this row pins the
    // reference path itself).
    {
        let mut cfg = SystemConfig::tl_ooo();
        cfg.cores = 2;
        cfg.frontend = twinload::cpu::FrontEnd::Reference;
        let mut spec = RunSpec::smoke(WorkloadKind::Gups);
        spec.ops_per_core = 4_000;
        let r = run_spec(&cfg, &spec);
        assert!(!r.deadlocked, "frontend=reference corpus run deadlocked");
        out.push_str(&render(&r));
    }
    // Faulted rows: every extension-path mechanism under the fixed
    // default fault seed at a 5% rate. These freeze the injection
    // schedule itself (fault counts, demotions, ECC corrections,
    // recovery tail) — a change to the site salts, the per-line
    // occurrence counters, or the recovery arithmetic moves these rows
    // even if the fault-free rows above are untouched.
    for cfg in mechanisms() {
        if cfg.mechanism.name() == "ideal" {
            continue; // no extension path, nothing to inject into
        }
        let mut cfg = cfg.faulted(0.05);
        cfg.cores = 2;
        let mut spec = RunSpec::smoke(WorkloadKind::Gups);
        spec.ops_per_core = 4_000;
        let r = run_spec(&cfg, &spec);
        assert!(!r.deadlocked, "{} deadlocked under faults", r.mechanism);
        out.push_str(&render(&r));
    }
    // Bursty rows: every extension-path mechanism under the correlated
    // Gilbert-Elliott burst layer with quarantine armed. These freeze
    // the burst window schedule (fail-slow stretch factors, fail-stop
    // windows), the EWMA health trajectory, and the quarantine/readmit
    // arithmetic — a change to the burst salts, the window math, or the
    // degraded-mode bookkeeping moves these rows even when the plain
    // faulted rows above are untouched.
    for cfg in mechanisms() {
        if cfg.mechanism.name() == "ideal" {
            continue; // no extension path, no fault domains
        }
        let mut cfg = bursty_quarantined(cfg);
        cfg.cores = 2;
        let mut spec = RunSpec::smoke(WorkloadKind::Gups);
        spec.ops_per_core = 4_000;
        let r = run_spec(&cfg, &spec);
        assert!(!r.deadlocked, "{} deadlocked under bursts", r.mechanism);
        out.push_str(&render(&r));
    }
    // Open-loop serving rows: Poisson arrivals at a fixed offered load
    // on the skewed key-value mix, one row per mechanism. These freeze
    // the arrival schedule, the bounded-queue drop behavior, and the
    // end-to-end latency distribution (the serving fields at the end of
    // each render line), plus one MMPP row pinning the bursty phase
    // machine itself.
    for cfg in mechanisms() {
        let mut cfg = cfg;
        cfg.cores = 2;
        let mut spec = RunSpec::smoke(WorkloadKind::Memcached);
        spec.ops_per_core = 4_000;
        let spec = spec.open_loop(ArrivalKind::Poisson, 4_000_000);
        let r = run_spec(&cfg, &spec);
        assert!(!r.deadlocked, "{} deadlocked open-loop", r.mechanism);
        out.push_str(&render(&r));
    }
    {
        let mut cfg = SystemConfig::tl_ooo();
        cfg.cores = 2;
        let mut spec = RunSpec::smoke(WorkloadKind::Memcached);
        spec.ops_per_core = 4_000;
        let spec = spec.open_loop(ArrivalKind::Mmpp, 4_000_000);
        let r = run_spec(&cfg, &spec);
        assert!(!r.deadlocked, "mmpp corpus run deadlocked");
        out.push_str(&render(&r));
    }
    // Sampled row: freezes the SMARTS cadence itself — the seeded
    // window placement, the functional fast-path timing, and the
    // estimator output (the sample fields at the end of the render
    // line). A change to the fast-forward latency model, the window
    // accounting, or the CI arithmetic moves this row even when every
    // fully-detailed row above is untouched.
    {
        let mut cfg = SystemConfig::tl_ooo();
        cfg.cores = 2;
        let mut spec = RunSpec::smoke(WorkloadKind::Gups);
        spec.ops_per_core = 4_000;
        let spec = spec.sampled(500, 50, 50);
        let r = run_spec(&cfg, &spec);
        assert!(!r.deadlocked, "sampled corpus run deadlocked");
        assert!(r.sample_windows > 0, "sampled corpus row measured no windows");
        out.push_str(&render(&r));
    }
    out
}

#[test]
fn golden_reports_match_snapshot() {
    let actual = corpus();
    let update = std::env::var_os("TWINLOAD_GOLDEN_UPDATE").is_some();
    let expected = if update { None } else { std::fs::read_to_string(SNAP_PATH).ok() };
    let Some(expected) = expected else {
        std::fs::write(SNAP_PATH, &actual).expect("write golden snapshot");
        eprintln!(
            "golden: wrote {} ({} runs){}",
            SNAP_PATH,
            actual.lines().count(),
            if update { "" } else { " [bootstrap: no snapshot was checked in]" }
        );
        return;
    };
    if expected == actual {
        return;
    }
    let mut diffs = expected
        .lines()
        .zip(actual.lines())
        .filter(|(e, a)| e != a)
        .map(|(e, a)| format!("  - {e}\n  + {a}"));
    let first = diffs.next().unwrap_or_else(|| {
        format!(
            "  line counts differ: snapshot {} vs run {}",
            expected.lines().count(),
            actual.lines().count()
        )
    });
    let more = diffs.count();
    panic!(
        "golden corpus diverged from {SNAP_PATH} ({more} further differing line(s)).\n\
         First difference:\n{first}\n\
         If this end-to-end change is intentional, regenerate with `make golden-update` \
         and commit the snapshot."
    );
}

/// The snapshot must be front-end-independent: the slab and reference
/// request-tracking paths reproduce the same report line bit-for-bit
/// (the corpus' final row is itself a frontend=reference run, so the
/// snapshot freezes both paths' absolute numbers).
#[test]
fn golden_corpus_is_frontend_independent() {
    use twinload::cpu::FrontEnd;
    // Fault-free, faulted, and bursty: the injection schedule is keyed
    // on (seed, line, occurrence) and the burst layer on (seed, domain,
    // window), never on the request-tracking implementation, so the
    // faulted and bursty rows are frontend-independent too.
    for variant in ["clean", "faulted", "bursty"] {
        let mut base = match variant {
            "faulted" => SystemConfig::tl_ooo().faulted(0.05),
            "bursty" => bursty_quarantined(SystemConfig::tl_ooo()),
            _ => SystemConfig::tl_ooo(),
        };
        base.cores = 2;
        let mut spec = RunSpec::smoke(WorkloadKind::Gups);
        spec.ops_per_core = 4_000;
        let mut lines = Vec::new();
        for fe in [FrontEnd::Slab, FrontEnd::Reference] {
            let mut cfg = base.clone();
            cfg.frontend = fe;
            let r = run_spec(&cfg, &spec);
            assert!(!r.deadlocked);
            lines.push(render(&r));
        }
        assert_eq!(
            lines[0], lines[1],
            "slab front end diverged from reference ({variant})"
        );
    }
}

/// The snapshot must be backend-independent: the same mechanism run
/// through the default typed backend and through the retained
/// pre-refactor (legacy `Option`-field) routing reproduces the same
/// report line bit-for-bit — the end-to-end proof that the backend
/// refactor preserved every mechanism's absolute numbers.
#[test]
fn golden_corpus_is_backend_independent() {
    use twinload::sim::Routing;
    // Faulted and bursty as well: MEC fill faults are armed in
    // `build_mecs`, which both routings share; the platform sites key
    // on the line and the burst layer on (seed, domain, window) — so
    // neither schedule can depend on the routing seam.
    for variant in ["clean", "faulted", "bursty"] {
        for base in mechanisms() {
            let base = match variant {
                "faulted" => base.faulted(0.05),
                "bursty" => bursty_quarantined(base),
                _ => base,
            };
            let mut spec = RunSpec::smoke(WorkloadKind::Gups);
            spec.ops_per_core = 4_000;
            let mut lines = Vec::new();
            for routing in [Routing::Backend, Routing::Legacy] {
                let mut cfg = base.clone();
                cfg.cores = 2;
                cfg.routing = routing;
                let r = run_spec(&cfg, &spec);
                assert!(!r.deadlocked);
                lines.push(render(&r));
            }
            assert_eq!(
                lines[0], lines[1],
                "backend routing diverged from legacy for {} ({variant})",
                base.mechanism.name()
            );
        }
    }
}

/// Open-loop serving must be implementation-independent too: the same
/// arrival seed reproduces the report line bit-for-bit across event
/// engines × front ends × backend routings — the acceptance bar for the
/// serving front end riding on the optimized-vs-reference seams.
#[test]
fn golden_open_loop_rows_are_implementation_independent() {
    use twinload::cpu::FrontEnd;
    use twinload::sim::{EngineKind, Routing};
    let mut spec = RunSpec::smoke(WorkloadKind::Memcached);
    spec.ops_per_core = 4_000;
    let spec = spec.open_loop(ArrivalKind::Poisson, 4_000_000);
    let mut lines = Vec::new();
    for engine in [
        EngineKind::Calendar,
        EngineKind::AdaptiveCalendar,
        EngineKind::ReferenceHeap,
        EngineKind::Sharded,
    ] {
        for fe in [FrontEnd::Slab, FrontEnd::Reference] {
            for routing in [Routing::Backend, Routing::Legacy] {
                let mut cfg = SystemConfig::tl_ooo();
                cfg.cores = 2;
                cfg.engine = engine;
                cfg.frontend = fe;
                cfg.routing = routing;
                let r = run_spec(&cfg, &spec);
                assert!(!r.deadlocked);
                lines.push(render(&r));
            }
        }
    }
    for l in &lines[1..] {
        assert_eq!(&lines[0], l, "open-loop run diverged across implementations");
    }
}

/// The snapshot must be engine-independent: the adaptive calendar and
/// the reference heap reproduce the frozen corpus bit-for-bit, not just
/// the default engine that happened to write it.
#[test]
fn golden_corpus_is_engine_independent() {
    use twinload::sim::EngineKind;
    // Faulted and bursty as well: per-line delivery order is
    // engine-independent, so the per-line occurrence counters and the
    // virtual-time burst windows (and with them the entire fault
    // schedule) must reproduce under every event engine.
    for variant in ["clean", "faulted", "bursty"] {
        let mut base = match variant {
            "faulted" => SystemConfig::tl_ooo().faulted(0.05),
            "bursty" => bursty_quarantined(SystemConfig::tl_ooo()),
            _ => SystemConfig::tl_ooo(),
        };
        base.cores = 2;
        let mut spec = RunSpec::smoke(WorkloadKind::Gups);
        spec.ops_per_core = 4_000;
        let mut lines = Vec::new();
        for kind in [
            EngineKind::Calendar,
            EngineKind::AdaptiveCalendar,
            EngineKind::ReferenceHeap,
            EngineKind::Sharded,
        ] {
            let mut cfg = base.clone();
            cfg.engine = kind;
            let r = run_spec(&cfg, &spec);
            assert!(!r.deadlocked);
            lines.push(render(&r));
        }
        assert_eq!(
            lines[0], lines[1],
            "adaptive calendar diverged from calendar ({variant})"
        );
        assert_eq!(
            lines[0], lines[2],
            "reference heap diverged from calendar ({variant})"
        );
        assert_eq!(
            lines[0], lines[3],
            "sharded engine diverged from calendar ({variant})"
        );
    }
}

/// The sampled corpus row must be implementation-independent too: the
/// SMARTS cadence is a pure function of (sample_seed, period, retired
/// ops), and the functional fast path touches no engine, front-end, or
/// routing state — so the same sampled run reproduces bit-for-bit
/// across every seam, including the sharded engine.
#[test]
fn golden_sampled_rows_are_implementation_independent() {
    use twinload::cpu::FrontEnd;
    use twinload::sim::{EngineKind, Routing};
    let mut spec = RunSpec::smoke(WorkloadKind::Gups);
    spec.ops_per_core = 4_000;
    let spec = spec.sampled(500, 50, 50);
    let mut lines = Vec::new();
    for engine in [
        EngineKind::Calendar,
        EngineKind::AdaptiveCalendar,
        EngineKind::ReferenceHeap,
        EngineKind::Sharded,
    ] {
        for fe in [FrontEnd::Slab, FrontEnd::Reference] {
            for routing in [Routing::Backend, Routing::Legacy] {
                let mut cfg = SystemConfig::tl_ooo();
                cfg.cores = 2;
                cfg.engine = engine;
                cfg.frontend = fe;
                cfg.routing = routing;
                let r = run_spec(&cfg, &spec);
                assert!(!r.deadlocked);
                assert!(r.sample_windows > 0, "sampled run measured no windows");
                lines.push(render(&r));
            }
        }
    }
    for l in &lines[1..] {
        assert_eq!(&lines[0], l, "sampled run diverged across implementations");
    }
}

/// With `burst_rate = 0` no burst plan is built, so the quarantine
/// knobs have nothing to observe: arming them must be bit-identical to
/// leaving them off, even under plain per-access fault injection. This
/// is the structural-inertness half of the acceptance bar — the other
/// half (a zeroed run matching the pre-PR schedule) lives in the frozen
/// faulted snapshot rows, which this PR must not move.
#[test]
fn golden_quarantine_knobs_without_bursts_are_inert() {
    let mut spec = RunSpec::smoke(WorkloadKind::Gups);
    spec.ops_per_core = 4_000;
    let mut lines = Vec::new();
    for armed in [false, true] {
        let mut cfg = SystemConfig::tl_ooo().faulted(0.05);
        cfg.cores = 2;
        if armed {
            cfg.quarantine_threshold = 0.5;
            cfg.probe_ok = 4;
        }
        let r = run_spec(&cfg, &spec);
        assert!(!r.deadlocked);
        lines.push(render(&r));
    }
    assert_eq!(
        lines[0], lines[1],
        "quarantine knobs perturbed a burst-free run"
    );
}
