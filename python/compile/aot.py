"""AOT lowering: JAX models → HLO text artifacts for the Rust runtime.

HLO **text**, not `.serialize()`: jax >= 0.5 emits HloModuleProto with
64-bit instruction ids which the image's xla_extension 0.5.1 rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md). Lowered with return_tuple=True;
the Rust side unwraps with `to_tuple()`.

Usage: python -m compile.aot --out-dir ../artifacts
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def artifacts():
    """(name, fn, example_args) for every AOT entry point."""
    i32, f32 = jnp.int32, jnp.float32
    return [
        (
            "trace_latency",
            model.trace_latency_entry,
            (spec((model.TRACE_CHUNK,), i32), spec((model.TRACE_CHUNK,), i32)),
        ),
        (
            "pagerank_step",
            model.pagerank_step,
            (
                spec((model.PAGERANK_NODES,), f32),
                spec((model.PAGERANK_EDGES,), i32),
                spec((model.PAGERANK_EDGES,), i32),
                spec((model.PAGERANK_NODES,), f32),
            ),
        ),
        (
            "gups_chunk",
            model.gups_chunk,
            (
                spec((model.GUPS_TABLE,), f32),
                spec((model.GUPS_CHUNK,), i32),
                spec((model.GUPS_CHUNK,), f32),
            ),
        ),
    ]


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    parser.add_argument("--only", default=None, help="emit a single artifact")
    args = parser.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    for name, fn, example in artifacts():
        if args.only and name != args.only:
            continue
        lowered = jax.jit(fn).lower(*example)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {len(text):>9} chars  {path}")


if __name__ == "__main__":
    main()
