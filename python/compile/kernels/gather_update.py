"""L1 Pallas kernels for the application compute path.

Two kernels back the end-to-end examples (the big-memory applications the
paper motivates — PageRank and GUPS from Table 4):

* :func:`gather_contrib` — the gather half of a PageRank/SpMV step:
  ``contrib[e] = ranks[src[e]] * inv_deg[src[e]]`` for every edge. The
  rank/degree vectors stay resident in VMEM (the TPU analogue of keeping
  the hot table in shared memory on a GPU) while edge blocks stream
  through; the scatter half (segment-sum by destination) is left to XLA,
  which fuses it with the damping arithmetic.
* :func:`gups_update` — a GUPS update chunk: ``table[idx[k]] += val[k]``
  with the table tile VMEM-resident.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_EDGES = 512


def _gather_kernel(src_ref, ranks_ref, inv_deg_ref, out_ref):
    idx = src_ref[...]
    out_ref[...] = ranks_ref[idx] * inv_deg_ref[idx]


def gather_contrib(src, ranks, inv_deg, block=BLOCK_EDGES):
    """contrib[e] = ranks[src[e]] * inv_deg[src[e]].

    Args:
      src: int32[E] source-node index per edge (E % block == 0).
      ranks: f32[N] current ranks.
      inv_deg: f32[N] 1/out-degree per node.

    Returns:
      f32[E] per-edge contribution.
    """
    e = src.shape[0]
    assert e % block == 0, f"E={e} not a multiple of {block}"
    n = ranks.shape[0]
    return pl.pallas_call(
        _gather_kernel,
        grid=(e // block,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            # Whole vectors resident per step (hot data in VMEM).
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((e,), jnp.float32),
        interpret=True,
    )(src, ranks, inv_deg)


def _gups_kernel(idx_ref, val_ref, table_ref, out_ref):
    # Sequential read-modify-write over the chunk (GUPS semantics: updates
    # may collide, so a blind scatter would lose increments).
    out_ref[...] = table_ref[...]

    def body(k, _):
        i = idx_ref[k]
        out_ref[i] = out_ref[i] + val_ref[k]
        return 0

    jax.lax.fori_loop(0, idx_ref.shape[0], body, 0)


def gups_update(table, idx, val):
    """table[idx[k]] += val[k] for every k, collision-safe.

    Args:
      table: f32[M] the update table (one VMEM-resident tile).
      idx: int32[K] update indices in [0, M).
      val: f32[K] addends.

    Returns:
      f32[M] updated table.
    """
    m = table.shape[0]
    k = idx.shape[0]
    return pl.pallas_call(
        _gups_kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((k,), lambda i: (0,)),
            pl.BlockSpec((k,), lambda i: (0,)),
            pl.BlockSpec((m,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((m,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((m,), jnp.float32),
        interpret=True,
    )(idx, val, table)
